"""CLI for the static-analysis passes: ``python -m repro.analysis``.

Runs the repo lint (stdlib-only, instant) and then the plan auditor
(lowers the whole spec lattice to HLO on forced host devices — no data
is executed, ~30 s). ``--strict`` turns any NEW finding (not in the
lint baseline; the auditor has no baseline) into a nonzero exit.
"""
from __future__ import annotations

import argparse
import os
import sys


def _force_host_devices(n: int) -> None:
    # must run before jax is imported anywhere in this process
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = \
            f"{flags} --xla_force_host_platform_device_count={n}".strip()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static plan auditor + repo lint.")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any new lint finding or audit finding")
    ap.add_argument("--min-specs", type=int, default=0, metavar="N",
                    help="fail if the audited lattice has fewer specs")
    ap.add_argument("--lint-only", action="store_true",
                    help="skip the HLO audit (no jax import)")
    ap.add_argument("--audit-only", action="store_true",
                    help="skip the repo lint")
    ap.add_argument("--devices", type=int, default=8,
                    help="forced host device count for the audit mesh "
                         "lattice (default 8)")
    args = ap.parse_args(argv)
    failed = False

    if not args.audit_only:
        from repro.analysis import lint
        new, old = lint.split_baseline(lint.lint_tree(),
                                       lint.load_baseline())
        for f in new:
            print(f"LINT NEW  {f}")
        print(f"lint: {len(new)} new finding(s), "
              f"{len(old)} grandfathered")
        failed |= bool(new)

    if not args.lint_only:
        _force_host_devices(args.devices)
        from repro.analysis import audit
        specs = audit.lattice()
        if len(specs) < args.min_specs:
            print(f"audit: lattice has {len(specs)} specs "
                  f"< --min-specs {args.min_specs}")
            failed = True
        report = audit.audit_specs(specs, strict=False)
        for f in report.findings:
            print(f"AUDIT  [{f.tag}] {f.check}: {f.detail}")
        fam = report.by_family()
        fams = " ".join(f"{k}={len(v)}" for k, v in sorted(fam.items()))
        print(f"audit: {report.specs} specs, {len(report.cells)} cells "
              f"({fams}), {len(report.findings)} finding(s)")
        failed |= bool(report.findings)

    if failed and args.strict:
        return 1
    if failed:
        print("(findings reported; rerun with --strict to fail)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
