"""Static analysis for the repro tree: plan auditor + repo lint.

Two passes, one CI entry point (``python -m repro.analysis --strict``):

* :mod:`repro.analysis.audit` — enumerate a lattice of ``FFTSpec`` /
  ``GEMMSpec`` configurations over the host-device meshes, lower each
  cached plan executor to post-partitioning HLO *without executing data*,
  and diff the parsed collectives (:mod:`repro.analysis.hlo`) against the
  analytic volume models. Any count/byte/psum-width mismatch, unexpected
  all-gather, or dtype downcast between spec and root signature fails.
* :mod:`repro.analysis.lint` — AST rules L001..L005 for repo-specific
  contracts (deprecated FFT kwargs, raw ``jnp.fft`` outside core/fft,
  assert-as-input-validation, unlocked mesh dispatch, frozen-field
  mutation), gated strict-on-new by a checked-in baseline.

:mod:`repro.analysis.hlo` is import-light (stdlib ``re`` only) so both
``launch.dryrun`` (which forces 512 host devices at import) and the audit
can share one collective parser without import-order traps.
"""
from repro.analysis.hlo import (CollectiveOp, parse_collectives,
                                root_signature, summarize)

__all__ = ["CollectiveOp", "parse_collectives", "root_signature",
           "summarize"]
