"""Collective-op parser for post-partitioning HLO text.

Promoted from ``launch/dryrun.collective_bytes`` so the plan auditor, the
dry-run pipeline, and the distributed-FFT benchmark all read one parser.
This module imports nothing but the stdlib — in particular no ``jax`` —
because ``launch/dryrun`` mutates ``XLA_FLAGS`` at import time and the
auditor must be importable before jax picks a platform.

The module under inspection is the per-device program (lowered with a
``jax.sharding.Mesh``), so every byte count here is per-device wire
traffic. Async ``-start`` ops return ``(operand buffers..., result
buffers...)`` tuples; only the result half is transferred, so those are
deduped. All-reduce wire bytes carry the ring factor 2 (reduce-scatter +
all-gather phases); every other kind moves its payload once.
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["CollectiveOp", "COLLECTIVE_KINDS", "DTYPE_BYTES", "WIRE_FACTOR",
           "parse_collectives", "summarize", "root_signature"]

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

COLLECTIVE_RE = re.compile(
    r"=\s*(?:\(.*?\)|[a-z0-9\[\]{},\s/]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s32|u32|s8|u8|pred|c64|c128)"
                      r"\[([0-9,]*)\]")
DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
               "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}
# wire-bytes multiplier per collective kind (ring algorithms)
WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}

# ENTRY %main.123 (arg0: c64[8,256]) -> (c64[8,256], f32[3]) {
ENTRY_RE = re.compile(r"^ENTRY\s+\S+\s*\(.*\)\s*->\s*(.*?)\s*\{?\s*$")


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """One collective in program order.

    ``shapes`` is the result buffer list as ``(dtype, elems)`` pairs
    (post ``-start`` dedupe), ``payload_bytes`` their byte total, and
    ``wire_bytes`` the per-device wire traffic (payload x ring factor).
    """

    kind: str
    is_async: bool
    shapes: tuple[tuple[str, int], ...]
    payload_bytes: int
    wire_bytes: float

    @property
    def dtypes(self) -> tuple[str, ...]:
        return tuple(dt for dt, _ in self.shapes)

    @property
    def elems(self) -> int:
        return sum(n for _, n in self.shapes)


def _result_shapes(line: str, op: str, *, is_start: bool):
    # result type sits between ' = ' and the op name:
    #   %x = f32[64,128]{1,0} all-reduce(...)
    #   %y = (f32[8]{0}, f32[8]{0}) all-gather-start(...)
    # Async ``-start`` results are (operand buffers..., result buffers...)
    # tuples — the operand aliases duplicate the payload, so only the result
    # half of the tuple is transferred. Sync decomposed all-to-alls also
    # return tuples, but there every element IS payload: no dedupe.
    seg = line.split(" = ", 1)[1] if " = " in line else line
    seg = seg.split(op, 1)[0]
    shapes = []
    for m in SHAPE_RE.finditer(seg):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        shapes.append((dt, n))
    if is_start and len(shapes) > 1:
        shapes = shapes[len(shapes) // 2:]
    return tuple(shapes)


def parse_collectives(hlo_text: str) -> list[CollectiveOp]:
    """All collectives in ``hlo_text``, program order, as structured
    records — kind, async-ness, per-buffer (dtype, elems), payload and
    wire bytes. This is the one classification point; every summary view
    (:func:`summarize`, ``launch.dryrun.collective_bytes``) derives from
    it."""
    ops = []
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        is_async = m.group(2) is not None
        shapes = _result_shapes(line, kind, is_start=is_async)
        payload = sum(n * DTYPE_BYTES[dt] for dt, n in shapes)
        ops.append(CollectiveOp(kind=kind, is_async=is_async, shapes=shapes,
                                payload_bytes=payload,
                                wire_bytes=payload * WIRE_FACTOR[kind]))
    return ops


def summarize(ops: list[CollectiveOp]) -> dict:
    """The legacy ``collective_bytes`` dict view of structured records:
    per-kind wire ``bytes`` / ``count``, program-order ``(kind, wire)``
    ``ops`` pairs, and the scalar ``total_bytes``."""
    out = {k: 0.0 for k in WIRE_FACTOR}
    count = {k: 0 for k in WIRE_FACTOR}
    pairs = []
    for op in ops:
        out[op.kind] += op.wire_bytes
        count[op.kind] += 1
        pairs.append((op.kind, op.wire_bytes))
    return {"bytes": out, "count": count, "ops": pairs,
            "total_bytes": float(sum(out.values()))}


def root_signature(hlo_text: str) -> tuple[str, ...]:
    """Dtype tokens of the ENTRY computation's result, in order.

    ``ENTRY %main (...) -> (c64[8,256], f32[3])`` yields ``("c64", "f32")``.
    Used by the auditor's downcast check: a ``complex128`` spec whose root
    signature carries ``c64`` buffers silently lost half its mantissa.
    Returns ``()`` when no ENTRY line parses (caller should not fail)."""
    for line in hlo_text.splitlines():
        m = ENTRY_RE.match(line.strip())
        if m:
            return tuple(mm.group(1) for mm in SHAPE_RE.finditer(m.group(1)))
    return ()
