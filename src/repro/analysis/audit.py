"""Plan auditor: lower every reachable plan executor to HLO and diff the
parsed collectives against the analytic volume models — no data executed.

The paper's contracts (one all-to-all per 1-D transform, two for the
spectral round trip, the 3G+1-scalar verdict psum, zero all-gathers in
transposed order, C transactions when chunked) are encoded in
``collective_volume`` / ``spectral_volume`` / ``collective_volume_nd`` and
the GEMM checksum-flop model. This module checks, for a generated lattice
of ``FFTSpec`` / ``GEMMSpec`` configurations over the host-device meshes:

* collective COUNTS per kind (any unexpected all-gather, reduce-scatter
  or collective-permute fails);
* per-kind wire BYTES against the model's ``all_to_all_bytes`` /
  ``gather_hlo`` / ``psum_hlo`` terms and the ``hlo_bytes`` total;
* the verdict psum WIDTH (all-reduce buffers must carry the spec's real
  dtype — f32 for complex64, f64 for complex128);
* the exposed-communication fraction of chunked pipelines (``1/C``);
* the root HLO signature (a complex128 spec whose entry computation
  returns c64 buffers silently downcast);
* the GEMM flop model (``cost_analysis``: unchecked == ``2MKN`` exactly;
  the checked overhead within [0.5x, 2x] of the four-GEMV checksum model
  — XLA's counter includes the decode, the model does not).

Everything is lowered with ``jax.ShapeDtypeStruct`` stand-ins: the audit
compiles but never allocates or executes. ``benchmarks/fft_distributed.py``
calls :func:`check_cell` on the same code path, so the benchmark's
hard-asserts and the CI gate cannot disagree.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.analysis import hlo as hlolib
from repro.core.fft import distributed as dist
from repro.core.fft import multidim as md
from repro.core.fft import spectral as spectral_mod
from repro.core.fft.api import FFTSpec
from repro.core.gemm.api import GEMMSpec
from repro.core.plan import FTConfig, plan as build_plan

__all__ = ["AuditError", "Finding", "CellReport", "AuditReport",
           "measure", "check_cell", "audit_plan", "audit_specs",
           "fft_lattice", "gemm_lattice", "lattice", "default_meshes",
           "run_audit"]

_COMPLEX_TOKEN = {"complex64": "c64", "complex128": "c128"}
_REAL_TOKEN = {"complex64": "f32", "complex128": "f64",
               "float32": "f32", "float64": "f64"}
_COMPLEX_TOKENS = frozenset(("c64", "c128"))
_FLOAT_TOKENS = frozenset(("f64", "f32", "bf16", "f16"))


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation in one audited cell."""

    tag: str
    check: str
    detail: str

    def __str__(self):
        return f"[{self.tag}] {self.check}: {self.detail}"


class AuditError(AssertionError):
    """Raised when an audited cell diverges from its analytic model."""

    def __init__(self, findings):
        self.findings = list(findings)
        super().__init__(
            "\n".join(str(f) for f in self.findings) or "audit failed")


@dataclasses.dataclass
class CellReport:
    """One lowered executor vs one model: measured summary + findings."""

    tag: str
    measured: dict
    collectives: list
    model: dict | None
    root: tuple
    findings: list


@dataclasses.dataclass
class AuditReport:
    specs: int
    cells: list
    findings: list

    def by_family(self) -> dict:
        fam: dict = {}
        for c in self.cells:
            fam.setdefault(c.tag.split(":", 1)[0], []).append(c)
        return fam


def _lower(fn, *args):
    """Compile ``fn`` on abstract operands — no data is ever allocated."""
    lowerable = fn if hasattr(fn, "lower") else jax.jit(fn)
    return lowerable.lower(*args).compile()


def measure(fn, *args) -> dict:
    """Lower + compile + parse: the legacy-shaped collective summary of
    ``fn``'s partitioned HLO (what ``benchmarks`` print as ``meas``)."""
    return hlolib.summarize(
        hlolib.parse_collectives(_lower(fn, *args).as_text()))


def _rel_off(got: float, want: float, rtol: float) -> bool:
    if want == 0:
        return got != 0
    return abs(got / want - 1.0) >= rtol


def _diff(tag, ops, root, model, *, rtol, check_exposed, dtype=None):
    """All findings for one cell (pure function of parsed artifacts)."""
    meas = hlolib.summarize(ops)
    count, by_kind = meas["count"], meas["bytes"]
    f = []

    def bad(check, detail):
        f.append(Finding(tag=tag, check=check, detail=detail))

    if model is None:
        # a local plan: the program must be collective-free, full stop
        for kind, c in count.items():
            if c:
                bad("unexpected-collective",
                    f"local plan lowered {c} {kind} op(s)")
    else:
        if "all_to_all_count" in model \
                and count["all-to-all"] != model["all_to_all_count"]:
            bad("all-to-all-count",
                f"hlo={count['all-to-all']} model={model['all_to_all_count']}")
        want_ag = int(model.get("all_gather_count", 0))
        if count["all-gather"] != want_ag:
            bad("all-gather-count",
                f"hlo={count['all-gather']} model={want_ag}"
                + (" (unexpected all-gather)"
                   if count["all-gather"] > want_ag else ""))
        if count["reduce-scatter"]:
            bad("unexpected-collective",
                f"{count['reduce-scatter']} reduce-scatter op(s); no "
                f"pipeline models any")
        if count["collective-permute"] and not model.get("permute_hlo"):
            # only the batch-sharded ft stats extraction permutes
            bad("unexpected-collective",
                f"{count['collective-permute']} collective-permute op(s); "
                f"model carries no permute term")
        for kind, key in (("all-to-all", "all_to_all_bytes"),
                          ("all-gather", "gather_hlo"),
                          ("all-reduce", "psum_hlo"),
                          ("collective-permute", "permute_hlo")):
            if key in model and _rel_off(by_kind[kind], model[key], rtol):
                bad(f"{kind}-bytes",
                    f"hlo={by_kind[kind]:.0f}B model={model[key]:.0f}B")
        if "hlo_bytes" in model and _rel_off(meas["total_bytes"],
                                             model["hlo_bytes"], rtol):
            bad("total-bytes", f"hlo={meas['total_bytes']:.0f}B "
                               f"model={model['hlo_bytes']:.0f}B")
        if check_exposed and "exposed_fraction" in model \
                and count["all-to-all"]:
            a2a = [w for k, w in meas["ops"] if k == "all-to-all"]
            exposed = max(a2a) / sum(a2a)
            if abs(exposed - model["exposed_fraction"]) >= 1e-9:
                bad("exposed-fraction",
                    f"hlo={exposed:.6f} model={model['exposed_fraction']:.6f}")

    if dtype is not None:
        ctoken = _COMPLEX_TOKEN.get(dtype)
        rtoken = _REAL_TOKEN.get(dtype)
        for op in ops:
            if op.kind == "all-reduce" and rtoken is not None:
                # the verdict psum width: f32 scalars under a complex128
                # spec would halve the detection mantissa. The ungrouped
                # ft pipeline also reduces native pred flags and an s32
                # location — those carry no mantissa, so they are exempt;
                # any FLOAT narrower than the spec real is still caught.
                wrong = set(op.dtypes) - {rtoken, "pred", "s32"}
                if wrong:
                    bad("psum-width", f"all-reduce carries {sorted(wrong)}, "
                                      f"spec wants {rtoken}")
            elif op.kind in ("all-to-all", "all-gather") \
                    and ctoken is not None:
                wrong = set(op.dtypes) - {ctoken}
                if wrong:
                    bad("collective-dtype",
                        f"{op.kind} carries {sorted(wrong)}, "
                        f"spec wants {ctoken}")
        token = _COMPLEX_TOKEN.get(dtype) or _REAL_TOKEN.get(dtype)
        fam = _COMPLEX_TOKENS if token in _COMPLEX_TOKENS else _FLOAT_TOKENS
        present = set(root) & fam
        if root and (token not in present or present - {token}):
            bad("root-dtype",
                f"entry returns {sorted(present) or ['none']} "
                f"of family {sorted(fam)}, spec wants {token}")
    return f, meas


def check_cell(fn, args, model, *, tag: str, rtol: float = 1e-3,
               check_exposed: bool = False, dtype: str | None = None,
               strict: bool = True) -> CellReport:
    """Lower one executor on abstract args and diff it against ``model``.

    This is the shared cell checker: the lattice sweep and the
    ``benchmarks/fft_distributed.py`` cells both call it, so a model==HLO
    assertion can only live here. ``model=None`` asserts a collective-free
    program (local plans). ``dtype`` (a spec dtype string) additionally
    checks collective widths and the root signature. Raises
    :class:`AuditError` with every finding when ``strict``."""
    compiled = _lower(fn, *args)
    text = compiled.as_text()
    ops = hlolib.parse_collectives(text)
    root = hlolib.root_signature(text)
    findings, meas = _diff(tag, ops, root, model, rtol=rtol,
                           check_exposed=check_exposed, dtype=dtype)
    rep = CellReport(tag=tag, measured=meas, collectives=ops, model=model,
                     root=root, findings=findings)
    if strict and findings:
        raise AuditError(findings)
    return rep


# ---------------------------------------------------------------------------
# per-plan audit cells
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _spec_tag(spec, p) -> str:
    if isinstance(spec, GEMMSpec):
        m, k, n = spec.shape
        return (f"gemm:{m}x{k}x{n}_{spec.dtype}_{p.backend}"
                + ("_ft" if spec.ft else ""))
    mesh = "local" if not p.sharded else (
        "x".join(f"{a}{p.mesh.shape[a]}" for a in p.mesh.axis_names))
    return (f"fft{'r' if spec.real else ''}{spec.rank}d:"
            f"{'x'.join(map(str, spec.shape))}_{spec.dtype}_{p.decomp}"
            f"_{mesh}"
            + ("_nat" if spec.natural_order else "_t")
            + (f"_g{p.groups}" if spec.ft else "")
            + (f"_c{p.chunks}" if p.chunks > 1 else ""))


def _fft_cells(p):
    """(tag, fn, args, model, check_exposed, rtol) cells for one FFTPlan.

    Each cell lowers the INNER jitted pipeline the plan executor is bound
    to (``_dist_fft_fn`` / ``_slab_fftn_fn`` / ...): the public wrappers
    may relayout eagerly, which would fold one-off ingest traffic into the
    steady-state contract under test.
    """
    spec, ft = p.spec, p.spec.ft
    tag = _spec_tag(spec, p)
    cdt = spec.dtype
    rdt = p._rdtype
    inj = _sds((1, 7), rdt)
    if spec.real:
        if p.rank == 1:
            if p.decomp != "pencil":
                return []
            # the packed half-length C2C is the executed transform
            n = p.tshape[0]
            fn = dist._dist_fft_fn(p.mesh, spec.axis, False, True, p.daxis, 1)
            x = _sds(spec.shape[:-1] + (n // 2,), cdt)
            return [(tag + ":fwd", fn, (x,), p.volume, False, 1e-3)]
        if p.decomp != md.DECOMP_SLAB:
            return []      # composed pencil real: no single nd model
        x = _sds(spec.shape, rdt)
        if ft is not None:
            fn = md._ft_rslab_fft2_fn(p.mesh, spec.axis, float(ft.threshold),
                                      bool(ft.correct), p.groups, p.daxis)
            return [(tag + ":fwd", fn, (x, inj), p.volume, False, 1e-3)]
        fn = md._rslab_fft2_fn(p.mesh, spec.axis, p.daxis)
        return [(tag + ":fwd", fn, (x,), p.volume, False, 1e-3)]

    x = _sds(spec.shape, cdt)
    if not p.sharded:
        return [(tag + ":fwd", jax.jit(p._fwd), (x,), None, False, 1e-3)]
    if p.rank == 1:
        if ft is not None:
            fn = dist._ft_dist_fft_fn(
                p.mesh, spec.axis, float(ft.threshold), bool(ft.correct),
                bool(spec.natural_order), p.groups, p.daxis, p.chunks)
            cells = [(tag + ":fwd", fn, (x, inj), p.volume, True, 1e-3)]
        else:
            fn = dist._dist_fft_fn(p.mesh, spec.axis, False,
                                   spec.natural_order, p.daxis, p.chunks)
            cells = [(tag + ":fwd", fn, (x,), p.volume, True, 1e-3)]
        # transposed-order non-ft plans feed the spectral round trip: audit
        # the fused convolve pair against spectral_volume too (2C a2a, 0
        # gathers). Kernel-batch 1 rides transaction 0, so the exposed-
        # fraction identity does not apply and chunked payloads are only
        # group-equal to ~2e-3 (the benchmark's historical tolerance).
        b, n = max(p.batch, 1), p.tshape[0]
        if ft is None and not spec.natural_order and p.daxis is None \
                and b % (p.shards * p.chunks) == 0:
            sfn = spectral_mod._spectral_pair_fn(p.mesh, spec.axis, None,
                                                 False, p.chunks)
            smodel = dist.spectral_volume(
                n, b, p.shards, kernel_batch=1,
                itemsize=spec.np_dtype.itemsize, chunks=p.chunks)
            cells.append((tag + ":spectral", sfn, (x, _sds((1, n), cdt)),
                          smodel, False, 2e-3))
        return cells
    if p.decomp == md.DECOMP_SLAB:
        if ft is not None:
            fn = md._ft_slab_fft2_fn(p.mesh, spec.axis, float(ft.threshold),
                                     bool(ft.correct), p.groups, p.daxis)
            return [(tag + ":fwd", fn, (x, inj), p.volume, False, 1e-3)]
        fn = md._slab_fftn_fn(p.mesh, spec.axis, p.rank, False, p.daxis)
        return [(tag + ":fwd", fn, (x,), p.volume, False, 1e-3)]
    fn = md._pencil_fftn_fn(p.mesh, spec.axis, p.rank, False,
                            bool(spec.natural_order), p.daxis, p.chunks)
    return [(tag + ":fwd", fn, (x,), p.volume, False, 1e-3)]


def _audit_gemm(p, *, strict=True):
    spec = p.spec
    tag = _spec_tag(spec, p)
    m, k, n = spec.shape
    x, w = _sds((m, k), spec.dtype), _sds((k, n), spec.dtype)
    fn = p.ft_matmul if spec.ft is not None else p.matmul
    compiled = _lower(jax.jit(fn), x, w)
    text = compiled.as_text()
    ops = hlolib.parse_collectives(text)
    root = hlolib.root_signature(text)
    findings, meas = _diff(tag, ops, root, None, rtol=1e-3,
                           check_exposed=False, dtype=spec.dtype)
    cost = compiled.cost_analysis()
    if isinstance(cost, list):        # pragma: no cover - backend-dependent
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    want = float(p.volume["flops"])
    if spec.ft is None:
        if _rel_off(flops, want, 1e-6):
            findings.append(Finding(tag, "flops",
                                    f"hlo={flops:.0f} model={want:.0f}"))
    else:
        # XLA counts the decode on top of the four-GEMV model: gate the
        # measured overhead to [0.5x, 2x] of checksum_flops — wide enough
        # for the counter, tight enough to catch a broken/missing model
        extra = flops - want
        cs = float(p.volume["checksum_flops"])
        if not (0.5 * cs <= extra <= 2.0 * cs):
            findings.append(Finding(
                tag, "checksum-flops",
                f"hlo overhead={extra:.0f} model={cs:.0f} "
                f"(allowed [{0.5 * cs:.0f}, {2 * cs:.0f}])"))
    rep = CellReport(tag=tag, measured=meas, collectives=ops, model=p.volume,
                     root=root, findings=findings)
    if strict and findings:
        raise AuditError(findings)
    return [rep]


def audit_plan(p, *, strict: bool = True) -> list[CellReport]:
    """Audit every cell of one built plan. ``strict`` raises on the first
    cell with findings; otherwise findings accumulate on the reports."""
    if isinstance(p.spec, GEMMSpec):
        return _audit_gemm(p, strict=strict)
    reports = []
    for tag, fn, args, model, exposed, rtol in _fft_cells(p):
        reports.append(check_cell(fn, args, model, tag=tag, rtol=rtol,
                                  check_exposed=exposed,
                                  dtype=p.spec.dtype, strict=strict))
    return reports


# ---------------------------------------------------------------------------
# the spec lattice
# ---------------------------------------------------------------------------

def default_meshes(ndev: int | None = None):
    """Deterministic mesh templates for ``ndev`` host devices, largest
    first: 1-D ``(fft,)`` meshes of 2 and 4 shards plus 2-D ``data x fft``
    meshes — ``(2, 2)`` from 4 devices, ``(2, 4)`` from 8."""
    if ndev is None:
        ndev = len(jax.devices())
    out = []
    for shape, axes in (((2,), ("fft",)),
                        ((4,), ("fft",)),
                        ((2, 2), ("data", "fft")),
                        ((2, 4), ("data", "fft"))):
        if int(np.prod(shape)) <= ndev:
            out.append(jax.make_mesh(shape, axes))
    return out


def fft_lattice(meshes) -> list[FFTSpec]:
    """Every audited FFT configuration: rank x decomp x real x ft/groups x
    chunks x dtype x mesh, small sizes so the sweep compiles fast. Purely
    deterministic in ``meshes`` — the CI gate audits the same lattice every
    run. Infeasible combinations are skipped at generation via the same
    validation ``plan()`` applies (a spec listed here MUST plan)."""
    specs: list[FFTSpec] = []
    g = FTConfig(groups=4)
    b, n = 8, 256
    for mesh in meshes:
        for nat in (True, False):
            for chunks in (1, 2):
                specs.append(FFTSpec(shape=(b, n), mesh=mesh,
                                     natural_order=nat, chunks=chunks))
        specs.append(FFTSpec(shape=(b, n), dtype="complex128", mesh=mesh))
        specs.append(FFTSpec(shape=(b, n), mesh=mesh, ft=g))
        specs.append(FFTSpec(shape=(b, n), mesh=mesh, ft=g,
                             natural_order=False, chunks=2))
        specs.append(FFTSpec(shape=(b, n), dtype="complex128", mesh=mesh,
                             ft=g))
        # rank-1 real: the packed half-length transform (natural only)
        specs.append(FFTSpec(shape=(b, 2 * n), mesh=mesh, real=True))
        # rank-2 slab + real slab (+ ft): needs shards | 32 and shards | 32
        specs.append(FFTSpec(shape=(b, 32, 64), rank=2, mesh=mesh,
                             decomp="slab"))
        specs.append(FFTSpec(shape=(b, 32, 64), rank=2, mesh=mesh,
                             decomp="slab", ft=g))
        specs.append(FFTSpec(shape=(b, 32, 64), rank=2, mesh=mesh,
                             decomp="slab", real=True))
        # rank-2 pencil, both orders (64 >= fft^2, 64 >= data^2)
        for nat in (True, False):
            specs.append(FFTSpec(shape=(b, 64, 64), rank=2, mesh=mesh,
                                 decomp="pencil", natural_order=nat))
        dd = dict(mesh.shape).get("data", 1)
        if dd > 1:
            # chunked pencil on the 2-D mesh (replicated batch rows split)
            specs.append(FFTSpec(shape=(b, 64, 64), rank=2, mesh=mesh,
                                 decomp="pencil", natural_order=False,
                                 chunks=2))
        else:
            # deeper lattice on the 1-D meshes: fp64 slab ft, real ft,
            # rank-3 pencil both orders
            specs.append(FFTSpec(shape=(b, 32, 64), rank=2,
                                 dtype="complex128", mesh=mesh,
                                 decomp="slab", ft=g))
            specs.append(FFTSpec(shape=(b, 32, 64), rank=2, mesh=mesh,
                                 decomp="slab", real=True, ft=g))
            # ungrouped ABFT: the native-scalar stats path (pred/s32
            # telemetry reduces), modeled separately from the grouped
            # stacked-block broadcast
            g1 = FTConfig(groups=1)
            specs.append(FFTSpec(shape=(b, n), mesh=mesh, ft=g1))
            specs.append(FFTSpec(shape=(b, n), dtype="complex128",
                                 mesh=mesh, ft=g1))
            specs.append(FFTSpec(shape=(b, 32, 64), rank=2, mesh=mesh,
                                 decomp="slab", ft=g1))
            specs.append(FFTSpec(shape=(b, 32, 64), rank=2, mesh=mesh,
                                 decomp="slab", real=True, ft=g1))
            for nat in (True, False):
                specs.append(FFTSpec(shape=(4, 16, 16, 64), rank=3,
                                     mesh=mesh, decomp="pencil",
                                     natural_order=nat))
    # local plans: collective-free by contract
    specs.append(FFTSpec(shape=(b, n)))
    specs.append(FFTSpec(shape=(b, n), dtype="complex128"))
    specs.append(FFTSpec(shape=(b, 32, 64), rank=2))
    return specs


def gemm_lattice() -> list[GEMMSpec]:
    """Checked and unchecked GEMMs (xla backend — host CI has no TPU)."""
    specs = []
    for shape in ((64, 32, 48), (128, 64, 32), (32, 128, 64)):
        for ft in (None, FTConfig()):
            specs.append(GEMMSpec(shape=shape, ft=ft, backend="xla"))
    specs.append(GEMMSpec(shape=(64, 64, 64), dtype="float64",
                          backend="xla"))
    return specs


def lattice(meshes=None) -> list:
    if meshes is None:
        meshes = default_meshes()
    return fft_lattice(meshes) + gemm_lattice()


def audit_specs(specs, *, strict: bool = True,
                progress=None) -> AuditReport:
    """Plan + audit every spec. With ``strict`` the first divergent cell
    raises :class:`AuditError`; otherwise all findings are collected."""
    cells: list[CellReport] = []
    findings: list[Finding] = []
    for s in specs:
        p = build_plan(s)
        reports = audit_plan(p, strict=strict)
        cells.extend(reports)
        for r in reports:
            findings.extend(r.findings)
        if progress is not None:
            progress(s, reports)
    return AuditReport(specs=len(specs), cells=cells, findings=findings)


def run_audit(*, meshes=None, strict: bool = True,
              progress=None) -> AuditReport:
    """Audit the full generated lattice on the visible devices."""
    return audit_specs(lattice(meshes), strict=strict, progress=progress)
