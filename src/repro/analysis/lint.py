"""Repo lint: AST rules for the plan-API migration and runtime invariants.

Rules (all purely syntactic — no imports of repro code, stdlib ``ast``
only, so the linter runs before the tree is importable):

* **L001** — call sites passing deprecated legacy FFT kwargs
  (``mesh=``, ``axis=``, ``natural_order=``, ``decomp=``, ``groups=``,
  ``group_size=``, ``recompute_uncorrectable=``) to the compat shims
  ``kernels.ops.{fft, ifft, fft2, ifft2, ft_fft}``. New code builds an
  :class:`~repro.core.fft.api.FFTSpec` and plans it instead. Scope:
  ``src/repro`` and ``benchmarks`` (tests exercise the deprecation path
  on purpose).
* **L002** — raw ``jnp.fft.* `` / ``jax.numpy.fft.*`` usage outside
  ``core/fft``: every transform must route through the plan API so the
  auditor's collective/volume contracts cover it. Scope: ``src/repro``
  minus ``core/fft``.
* **L003** — bare ``assert`` used for input validation: an ``assert``
  whose test references a parameter of the enclosing function. Asserts
  vanish under ``python -O``; validation must ``raise ValueError`` with
  the offending value. Internal invariants over locals are fine. Scope:
  ``src/repro``.
* **L004** — plan-executor dispatch (``serve_plan``) in
  ``serve/runtime.py`` outside the ``_mesh_lock`` critical section:
  sharded executors rendezvous across all mesh devices, so concurrent
  dispatch from two workers deadlocks the collective. A call is legal
  inside ``with ... _mesh_lock`` or on a branch reached only when the
  plan is not ``.sharded``.
* **L005** — ``object.__setattr__`` on frozen dataclasses outside
  ``__post_init__`` / ``__init__`` / ``__setstate__``: specs are frozen
  and hashable (they key the plan LRU); mutating one after construction
  corrupts the cache. Scope: ``src/repro``.

Suppression: append ``# noqa: LXXX`` (or bare ``# noqa``) to the line.
Baseline: ``lint_baseline.txt`` next to this module holds fingerprints
(``RULE|path|stripped-line``) of grandfathered findings; the CLI fails
only on findings NOT in the baseline (strict-on-new).
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import re

__all__ = ["LintFinding", "RULES", "lint_file", "lint_tree",
           "load_baseline", "save_baseline", "split_baseline",
           "BASELINE_PATH"]

RULES = {
    "L001": "deprecated legacy FFT kwarg at a kernels.ops call site",
    "L002": "raw jnp.fft usage outside core/fft (bypasses the plan API)",
    "L003": "bare assert validating a function parameter (use ValueError)",
    "L004": "serve_plan dispatch outside _mesh_lock in serve/runtime.py",
    "L005": "object.__setattr__ on a frozen spec outside __post_init__",
}

BASELINE_PATH = pathlib.Path(__file__).with_name("lint_baseline.txt")

_OPS_ENTRIES = {"fft", "ifft", "fft2", "ifft2", "ft_fft"}
_OPS_MODULE = "repro.kernels.ops"
_DEPRECATED_KWARGS = {"mesh", "axis", "natural_order", "decomp", "groups",
                      "group_size", "recompute_uncorrectable"}
_SETATTR_OK_SCOPES = {"__post_init__", "__init__", "__setstate__"}
_NOQA_RE = re.compile(r"#\s*noqa(?::\s*(?P<rules>[A-Z0-9, ]+))?")


@dataclasses.dataclass(frozen=True)
class LintFinding:
    """One rule violation at a source location. ``fingerprint`` is the
    line-number-free identity used by the baseline, so unrelated edits
    above a grandfathered finding don't resurrect it."""

    rule: str
    path: str           # repo-relative posix path
    line: int
    snippet: str        # stripped source line
    message: str

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}|{self.path}|{self.snippet}"

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def _suppressed(rule: str, line_text: str) -> bool:
    m = _NOQA_RE.search(line_text)
    if not m:
        return False
    rules = m.group("rules")
    if rules is None:
        return True
    return rule in {r.strip() for r in rules.split(",")}


class _Aliases:
    """Import table: local dotted prefix -> canonical dotted module."""

    def __init__(self, tree: ast.AST):
        self.map: dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.map[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and not node.level:
                for a in node.names:
                    self.map[a.asname or a.name] = \
                        f"{node.module}.{a.name}"

    def expand(self, dotted: str) -> str:
        """Rewrite the longest aliased prefix of ``dotted``."""
        parts = dotted.split(".")
        for i in range(len(parts), 0, -1):
            head = ".".join(parts[:i])
            if head in self.map:
                return ".".join([self.map[head]] + parts[i:])
        return dotted


def _dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


# -- per-rule checks --------------------------------------------------------


def _check_l001(tree, aliases, emit):
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func)
        if dotted is None:
            continue
        full = aliases.expand(dotted)
        if not (full.startswith(f"{_OPS_MODULE}.")
                and full.rsplit(".", 1)[-1] in _OPS_ENTRIES):
            # `from repro import kernels; kernels.ops.fft(...)` expands to
            # repro.kernels + ".ops.fft" which the prefix test covers; a
            # bare `ops.fft` with no repro import does not match — good.
            continue
        bad = sorted(k.arg for k in node.keywords
                     if k.arg in _DEPRECATED_KWARGS)
        if bad:
            emit("L001", node,
                 f"deprecated kwarg(s) {', '.join(bad)} passed to "
                 f"{full.removeprefix('repro.')} — build an FFTSpec and "
                 f"use plan() executors")


def _check_l002(tree, aliases, emit):
    for node in ast.walk(tree):
        if not isinstance(node, ast.Attribute):
            continue
        dotted = _dotted(node)
        if dotted is None:
            continue
        full = aliases.expand(dotted)
        # flag the *member* access (jax.numpy.fft.fft), not the bare
        # module mention, and only once per chain (outermost Attribute)
        if full.startswith("jax.numpy.fft.") \
                and full.count(".") == 3:
            emit("L002", node,
                 f"raw {dotted} bypasses the plan API — use "
                 f"core.fft executors (or add to core/fft)")


def _check_l003(tree, emit):
    funcs = [n for n in ast.walk(tree)
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    for fn in funcs:
        a = fn.args
        params = {p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)}
        for v in (a.vararg, a.kwarg):
            if v is not None:
                params.add(v.arg)
        params.discard("self")
        params.discard("cls")
        nested = {id(x) for nf in ast.walk(fn)
                  if isinstance(nf, (ast.FunctionDef, ast.AsyncFunctionDef))
                  and nf is not fn for x in ast.walk(nf)}
        for node in ast.walk(fn):
            if not isinstance(node, ast.Assert) or id(node) in nested:
                continue
            used = sorted({n.id for n in ast.walk(node.test)
                           if isinstance(n, ast.Name) and n.id in params})
            if used:
                emit("L003", node,
                     f"assert validates parameter(s) {', '.join(used)} — "
                     f"raise ValueError with the offending value instead")


def _check_l004(tree, aliases, emit):
    def is_serve_plan(call: ast.Call) -> bool:
        dotted = _dotted(call.func)
        return dotted is not None and \
            aliases.expand(dotted).endswith("serve.specs.serve_plan")

    def with_holds_mesh_lock(node: ast.With) -> bool:
        for item in node.items:
            d = _dotted(item.context_expr)
            if d is not None and d.split(".")[-1].endswith("_mesh_lock"):
                return True
        return False

    def test_mentions_sharded(test: ast.AST) -> bool:
        return any(isinstance(n, ast.Attribute) and n.attr == "sharded"
                   for n in ast.walk(test))

    def visit(node, safe: bool):
        if isinstance(node, ast.Call) and is_serve_plan(node) and not safe:
            emit("L004", node,
                 "serve_plan dispatch outside `with ... _mesh_lock` — "
                 "concurrent sharded dispatch deadlocks the collective "
                 "(guard it, or branch on `.sharded`)")
        if isinstance(node, ast.With):
            inner = safe or with_holds_mesh_lock(node)
            for c in ast.iter_child_nodes(node):
                visit(c, inner)
            return
        if isinstance(node, ast.If) and test_mentions_sharded(node.test):
            # then-branch runs when the plan IS sharded: still unsafe
            for c in node.body:
                visit(c, safe)
            for c in node.orelse:
                visit(c, True)
            return
        for c in ast.iter_child_nodes(node):
            visit(c, safe)

    visit(tree, False)


def _check_l005(tree, emit):
    scopes = [n for n in ast.walk(tree)
              if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    owner: dict[int, str] = {}
    for fn in scopes:
        for node in ast.walk(fn):
            owner.setdefault(id(node), fn.name)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if _dotted(node.func) != "object.__setattr__":
            continue
        scope = owner.get(id(node))
        if scope not in _SETATTR_OK_SCOPES:
            emit("L005", node,
                 f"object.__setattr__ in "
                 f"{scope or '<module>'} — frozen specs key the plan "
                 f"cache and must not mutate after __post_init__")


# -- drivers ----------------------------------------------------------------


def _rules_for(relpath: str) -> set[str]:
    p = pathlib.PurePosixPath(relpath)
    rules: set[str] = set()
    in_src = p.parts[:2] == ("src", "repro")
    if in_src or p.parts[:1] == ("benchmarks",):
        rules.add("L001")
    if in_src:
        rules.update({"L003", "L005"})
        if "core" not in p.parts or "fft" not in p.parts:
            rules.add("L002")
    if relpath == "src/repro/serve/runtime.py":
        rules.add("L004")
    return rules


def lint_file(path: pathlib.Path, root: pathlib.Path) -> list[LintFinding]:
    relpath = path.relative_to(root).as_posix()
    rules = _rules_for(relpath)
    if not rules:
        return []
    source = path.read_text()
    lines = source.splitlines()
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as e:
        return [LintFinding("L000", relpath, e.lineno or 0, "",
                            f"syntax error: {e.msg}")]
    aliases = _Aliases(tree)
    findings: list[LintFinding] = []

    def emit(rule, node, message):
        line = getattr(node, "lineno", 0)
        text = lines[line - 1] if 0 < line <= len(lines) else ""
        if _suppressed(rule, text):
            return
        findings.append(LintFinding(rule, relpath, line, text.strip(),
                                    message))

    if "L001" in rules:
        _check_l001(tree, aliases, emit)
    if "L002" in rules:
        _check_l002(tree, aliases, emit)
    if "L003" in rules:
        _check_l003(tree, emit)
    if "L004" in rules:
        _check_l004(tree, aliases, emit)
    if "L005" in rules:
        _check_l005(tree, emit)
    findings.sort(key=lambda f: (f.line, f.rule))
    return findings


def lint_tree(root: pathlib.Path | str | None = None) -> list[LintFinding]:
    """Lint every .py under src/repro and benchmarks of ``root`` (the
    repo checkout; defaults to the tree this module lives in)."""
    if root is None:
        root = pathlib.Path(__file__).resolve().parents[3]
    root = pathlib.Path(root)
    findings: list[LintFinding] = []
    for sub in ("src/repro", "benchmarks"):
        base = root / sub
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            findings.extend(lint_file(path, root))
    return findings


def load_baseline(path: pathlib.Path | str = BASELINE_PATH) -> set[str]:
    path = pathlib.Path(path)
    if not path.exists():
        return set()
    return {ln.strip() for ln in path.read_text().splitlines()
            if ln.strip() and not ln.lstrip().startswith("#")}


def save_baseline(findings, path: pathlib.Path | str = BASELINE_PATH):
    path = pathlib.Path(path)
    body = "\n".join(sorted({f.fingerprint for f in findings}))
    path.write_text(
        "# Grandfathered lint findings (RULE|path|stripped-line).\n"
        "# `python -m repro.analysis` fails only on findings NOT listed\n"
        "# here. Shrink this file; never grow it.\n" + body + "\n")


def split_baseline(findings, baseline: set[str]):
    """-> (new, grandfathered) preserving order."""
    new = [f for f in findings if f.fingerprint not in baseline]
    old = [f for f in findings if f.fingerprint in baseline]
    return new, old
