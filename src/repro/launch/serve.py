"""Serving driver: batched greedy decode against the KV cache, plus a
batched sharded-FFT endpoint backed by the distributed transform.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --preset tiny \
        --batch 4 --prompt-len 16 --gen 32

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve --mode fft \
        --fft-n 65536 --batch 8 --fft-shards 4 --ft
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.configs.base import ParallelConfig, RunConfig
from repro.models import Model
from repro.train import make_serve_step


def decode(model: Model, params, prompts: jax.Array, gen: int,
           max_len: int | None = None):
    """Prefill via repeated decode steps, then generate ``gen`` tokens."""
    cfg = model.cfg
    b, p = prompts.shape
    max_len = max_len or (p + gen)
    run = RunConfig(model=cfg)
    step_fn = jax.jit(make_serve_step(model, run))
    cache = model.init_cache(batch=b, max_len=max_len)
    # teacher-forced prefill (decode-path; exercises the cache end-to-end)
    nxt = prompts[:, :1]
    for i in range(p):
        tok = prompts[:, i:i + 1]
        nxt, cache, _ = step_fn(params, cache, tok, jnp.int32(i))
    out = [nxt]
    for j in range(gen - 1):
        nxt, cache, _ = step_fn(params, cache, nxt, jnp.int32(p + j))
        out.append(nxt)
    return jnp.concatenate(out, axis=1)


def serve_fft(x, *, shards: int | None = None, ft: bool = False,
              threshold: float = 1e-4):
    """Batched sharded FFT endpoint: one request = one (B, N) batch.

    Builds (and caches, via the jit/shard_map caches underneath) the
    ``fft``-axis mesh, distributes the batch so each device holds 1/D of
    the signal axis (the pipeline re-tiles blocks into pencils at entry),
    and returns ``(y, telemetry)``. With ``ft=True`` the sharded two-side
    ABFT runs online and the telemetry carries the detection verdict.
    """
    from repro.core.fft.distributed import distributed_fft, ft_distributed_fft
    from repro.launch.mesh import make_fft_mesh
    from repro.parallel.fft_sharding import shard_signals

    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    mesh = make_fft_mesh(shards)
    if mesh.shape["fft"] == 1:
        if ft:
            # single device: the fused-kernel two-side ABFT path
            from repro.kernels.ops import ft_fft

            res = ft_fft(x, threshold=threshold)
            flagged = np.asarray(res.flagged)
            g = int(np.argmax(flagged)) if flagged.any() else -1
            return res.y, {
                "shards": 1, "ft": True,
                "score": float(jnp.max(res.group_score)),
                "flagged": bool(flagged.any()),
                "location": int(np.asarray(res.location)[g]) if g >= 0 else -1,
                "corrected": int(res.corrected),
            }
        y = distributed_fft(x, None)
        return y, {"shards": 1, "ft": False}
    xs = shard_signals(x, mesh)
    if ft:
        res = ft_distributed_fft(xs, mesh, threshold=threshold)
        return res.y, {
            "shards": int(mesh.shape["fft"]), "ft": True,
            "score": float(res.score), "flagged": bool(res.flagged),
            "location": int(res.location), "corrected": int(res.corrected),
            "shard_delta_max": float(jnp.max(res.shard_delta)),
        }
    return distributed_fft(xs, mesh), {"shards": int(mesh.shape["fft"]),
                                       "ft": False}


def _main_fft(args):
    rng = np.random.default_rng(0)
    x = (rng.standard_normal((args.batch, args.fft_n)) +
         1j * rng.standard_normal((args.batch, args.fft_n))
         ).astype(np.complex64)
    y, info = serve_fft(x, shards=args.fft_shards, ft=args.ft)  # warmup
    t0 = time.time()
    for _ in range(args.fft_iters):
        y, info = serve_fft(x, shards=args.fft_shards, ft=args.ft)
        jax.block_until_ready(y)
    dt = (time.time() - t0) / args.fft_iters
    err = np.abs(np.asarray(y) - np.fft.fft(x)).max() / (
        np.abs(np.fft.fft(x)).max() + 1e-30)
    print(f"fft batch={args.batch} N={args.fft_n} {info} "
          f"{dt*1e3:.2f}ms/req rel_err={err:.2e}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="lm", choices=["lm", "fft"])
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--fft-n", type=int, default=1 << 16)
    ap.add_argument("--fft-shards", type=int, default=None)
    ap.add_argument("--fft-iters", type=int, default=5)
    ap.add_argument("--ft", action="store_true",
                    help="run the sharded two-side ABFT online")
    args = ap.parse_args()

    if args.mode == "fft":
        _main_fft(args)
        return

    cfg = (get_config if args.preset == "full" else get_smoke_config)(
        args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    t0 = time.time()
    toks = decode(model, params, prompts, args.gen)
    dt = time.time() - t0
    rate = args.batch * args.gen / dt
    print(f"generated {toks.shape} in {dt:.2f}s ({rate:.1f} tok/s)")
    print(np.asarray(toks[:, :16]))


if __name__ == "__main__":
    main()
