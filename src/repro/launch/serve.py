"""Serving driver: batched greedy decode against the KV cache, plus a
batched sharded-FFT endpoint backed by the distributed transform.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --preset tiny \
        --batch 4 --prompt-len 16 --gen 32

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve --mode fft \
        --fft-n 65536 --batch 8 --fft-shards 4 --ft

    # the same worker described by ONE consolidated plan spec (the worker
    # builds a single FFTPlan from it at startup; the --fft-* flags are
    # sugar that provide the defaults the spec string overrides)
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve --mode fft \
        --fft-spec "n=65536,batch=8,shards=4,ft=1,groups=4"

    # transposed-order convolution on a 2-D batch x pencil mesh
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve --mode fft --fft-op convolve \
        --fft-n 16384 --batch 8 --fft-shards 2 --fft-data 2

    # distributed 2-D FFT (slab|pencil|auto) with grouped ABFT on the grids
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve --mode fft --fft-dims 2 \
        --fft-rows 256 --fft-cols 512 --batch 8 --fft-shards 4 \
        --fft-decomp slab --ft
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.configs.base import ParallelConfig, RunConfig
from repro.models import Model
from repro.train import make_serve_step


def decode(model: Model, params, prompts: jax.Array, gen: int,
           max_len: int | None = None, schedule=None):
    """Prefill via repeated decode steps, then generate ``gen`` tokens.

    ``schedule`` is an optional :class:`~repro.core.ft.FaultSchedule`: each
    host-loop step arms its GEMM fault descriptor
    (:meth:`~repro.core.ft.FaultSchedule.for_step_gemm`) into the jitted
    step — the descriptor is a traced array of fixed shape, so the armed
    and clean steps share ONE compiled program. Returns ``(tokens,
    FTStats)`` when a schedule is given (online ABFT telemetry summed over
    steps), else just ``tokens``.
    """
    from repro.core.ft import FTStats

    cfg = model.cfg
    b, p = prompts.shape
    max_len = max_len or (p + gen)
    run = RunConfig(model=cfg)
    step_fn = jax.jit(make_serve_step(model, run))
    cache = model.init_cache(batch=b, max_len=max_len)
    stats = FTStats.zeros()

    def inj(step):
        return None if schedule is None else schedule.for_step_gemm(step)

    def fold(aux):
        return stats.merge(FTStats(
            detected=aux["ft_flagged"], corrected=aux["ft_corrected"],
            max_score=aux["ft_max_score"],
            skipped_updates=jnp.zeros((), jnp.float32)))

    # teacher-forced prefill (decode-path; exercises the cache end-to-end)
    nxt = prompts[:, :1]
    for i in range(p):
        tok = prompts[:, i:i + 1]
        nxt, cache, aux = step_fn(params, cache, tok, jnp.int32(i), inj(i))
        stats = fold(aux)
    out = [nxt]
    for j in range(gen - 1):
        nxt, cache, aux = step_fn(params, cache, nxt, jnp.int32(p + j),
                                  inj(p + j))
        stats = fold(aux)
        out.append(nxt)
    toks = jnp.concatenate(out, axis=1)
    return toks if schedule is None else (toks, stats)


def build_fft_spec(shape, *, mesh=None, op: str = "fft",
                   kernel_shape=None, dims: int | None = None,
                   decomp: str = "auto", ft: bool = False,
                   threshold: float = 1e-4, groups: int | None = None,
                   group_size: int | None = None,
                   recompute_uncorrectable: bool = True,
                   natural_order: bool | None = None,
                   dtype="complex64", real: bool = False,
                   chunks: int = 1):
    """Resolve one serving request description into the
    :class:`~repro.core.fft.api.FFTSpec` its plan is built from.

    ``shape`` is the request batch shape — ``(B, N)`` for 1-D, ``(B, R,
    C)`` for 2-D. For ``op="convolve"``/``"correlate"`` the spec describes
    the PADDED transform the spectral pipeline actually runs (last axes
    padded to a power of two covering the linear result), so one plan
    serves every request of that operand geometry. ``natural_order=None``
    resolves the per-op default: the order-agnostic periodogram stays
    transposed on a mesh (the digit restore is pure waste for ``|X|^2``),
    everything else is natural. The old serve flags are sugar over this
    builder — see ``--fft-spec``.

    ``real=True`` (``--fft-spec "real=1"``) declares real-valued request
    traffic: ``op="fft"`` serves the half-spectrum ``rfft``/``rfft2``
    executors, ``op="spectrum"`` the one-sided periodogram, and
    convolve/correlate ride the packed real pipelines — roughly half the
    C2C collective bytes on a mesh. Real plans are natural-order only.

    ``chunks`` (``--fft-spec "chunks=4"`` or ``"chunks=auto"``) is the
    multi-transaction overlap knob: the plan splits the batch into that
    many transactions so each transaction's all-to-all hides behind the
    next one's local Stockham passes (0 = auto; see
    :class:`~repro.core.fft.api.FFTSpec`).
    """
    from repro.core.fft import api, multidim, spectral

    dims = dims if dims is not None else max(1, len(shape) - 1)
    if dims not in (1, 2):
        raise ValueError(f"dims must be 1 or 2, got {dims}")
    if op not in ("fft", "convolve", "correlate", "spectrum"):
        raise ValueError(f"op must be fft|convolve|correlate|spectrum, "
                         f"got {op!r}")
    if op == "correlate" and dims == 2:
        raise ValueError("op='correlate' is 1-D only; dims=2 serves "
                         "fft|convolve|spectrum")
    if len(shape) != dims + 1:
        raise ValueError(f"dims={dims} expects a (batch, ...) shape with "
                         f"{dims} transform axes, got {tuple(shape)}")
    if real and natural_order is False:
        raise ValueError("real serve traffic is natural-order only — the "
                         "half spectrum indexes bins by k (drop "
                         "transposed=1 or real=1)")
    sharded = mesh is not None and "fft" in mesh.axis_names \
        and mesh.shape["fft"] > 1
    ft_cfg = None
    if ft and op == "fft":
        ft_cfg = api.FTConfig(threshold=threshold, groups=groups,
                              group_size=group_size,
                              recompute_uncorrectable=recompute_uncorrectable)
    if op in ("convolve", "correlate"):
        if kernel_shape is None:
            raise ValueError(f"op={op!r} needs a kernel")
        if dims == 1:
            nfft = spectral._conv_nfft(shape[-1], kernel_shape[-1], mesh,
                                       "fft")
            shape = tuple(shape[:-1]) + (nfft,)
        else:
            shards = mesh.shape["fft"] if sharded else 1
            nr = max(spectral._next_pow2(shape[-2] + kernel_shape[-2] - 1),
                     shards)
            nc = max(spectral._next_pow2(shape[-1] + kernel_shape[-1] - 1),
                     shards)
            shape = tuple(shape[:-2]) + (nr, nc)
            if real and sharded \
                    and not multidim.rslab_feasible((nr, nc), shards):
                decomp = "auto"   # the composed real path covers the rest
            else:
                decomp = "slab" if sharded else "auto"
        natural_order = True
    elif natural_order is None:
        # the per-op order default of the legacy endpoint; real spectra
        # are one-sided (bins indexed by k) and so always natural
        natural_order = real or not (sharded and op == "spectrum")
    return api.FFTSpec(shape=tuple(int(s) for s in shape),
                       dtype=jnp.dtype(dtype).name, rank=dims, mesh=mesh,
                       axis="fft", decomp="auto" if dims == 1 else decomp,
                       natural_order=bool(natural_order), ft=ft_cfg,
                       real=bool(real), chunks=int(chunks))


def _ft_telemetry(plan, res, info):
    """DistFFTResult -> the serve telemetry dict (grouped verdict counts)."""
    flagged = np.asarray(res.flagged)
    # the decoded location is only meaningful for correctable (single
    # data-fault) groups — checksum-row and multi-fault verdicts clip it
    # to an arbitrary healthy signal, which must not be reported
    correctable = np.asarray(res.correctable)
    locs = np.asarray(res.location)
    info.update(
        ft=True, groups=plan.groups,
        group_size=plan.batch // plan.groups,
        score=float(jnp.max(res.group_score)),
        flagged=int(flagged.sum()),
        locations=[int(l) for l, c in zip(locs, correctable) if c],
        corrected=int(res.corrected),
        uncorrectable=int(np.asarray(res.uncorrectable).sum()),
        checksum_faults=int(np.asarray(res.checksum_fault).sum()),
        recomputed=int(res.recomputed),
        shard_delta_max=float(jnp.max(res.shard_delta)))
    return info


def serve_plan(plan, x, *, op: str = "fft", kernel=None, mode: str = "same"):
    """Serve one batched request through a pre-built
    :class:`~repro.core.fft.api.FFTPlan` — the hot path: every dispatch
    decision (mesh, decomposition, ABFT groups, digit order) was resolved
    when the plan was built, so this is a straight executor call plus
    telemetry assembly. Returns ``(y, info)``.
    """
    x = jnp.asarray(x)
    info = {"shards": plan.shards, "data": plan.dsize, "op": op}
    if plan.chunks > 1:
        info["chunks"] = plan.chunks
    if plan.rank == 2:
        info["dims"] = 2
        info["decomp"] = plan.decomp
    if plan.spec.real:
        info["real"] = True
    transposed = (plan.sharded and not plan.spec.natural_order
                  and (plan.rank == 1 or plan.decomp == "pencil"))
    if op in ("convolve", "correlate"):
        if kernel is None:
            raise ValueError(f"op={op!r} needs a kernel")
        fn = plan.convolve if op == "convolve" else plan.correlate
        y = fn(x, kernel, mode=mode)
        info.update(order="natural",
                    collectives="2 a2a" if plan.sharded else "local")
        return y, info
    if op == "spectrum":
        y = plan.power_spectrum(x)
        info["order"] = "transposed" if transposed else "natural"
        return y, info
    if op != "fft":
        raise ValueError(f"op must be fft|convolve|correlate|spectrum, "
                         f"got {op!r}")
    xs = plan.shard(x)
    if plan.spec.ft is not None:
        res = plan.ft_fft(xs)
        if not plan.sharded:
            # single device: the fused-kernel two-side ABFT telemetry
            flagged = np.asarray(res.flagged)
            g = int(np.argmax(flagged)) if flagged.any() else -1
            info.update(
                ft=True, score=float(jnp.max(res.group_score)),
                flagged=bool(flagged.any()),
                location=int(np.asarray(res.location)[g]) if g >= 0 else -1,
                corrected=int(res.corrected))
            return res.y, info
        return res.y, _ft_telemetry(plan, res, info)
    y = plan.rfft(xs) if plan.spec.real else plan.fft(xs)
    info.update(ft=False)
    if plan.sharded:
        info["order"] = "transposed" if transposed else "natural"
    return y, info


def serve_fft(x, *, shards: int | None = None, data: int = 1,
              ft: bool = False, threshold: float = 1e-4,
              op: str = "fft", kernel=None, mode: str = "same",
              natural_order: bool | None = None,
              groups: int | None = None, group_size: int | None = None,
              recompute_uncorrectable: bool = True,
              dims: int = 1, decomp: str = "auto", real: bool = False,
              chunks: int = 1):
    """Batched sharded FFT endpoint: one request = one (B, N) batch
    (``dims=2``: one (B, R, C) grid batch).

    Compat sugar over the plan API: builds the ``fft``-axis mesh — 2-D
    ``data x fft`` when ``data > 1`` — resolves the request into an
    :class:`~repro.core.fft.api.FFTSpec` via :func:`build_fft_spec`,
    LRU-hits the plan, and serves through :func:`serve_plan`. A production
    worker should build the plan ONCE at startup (what ``--mode fft`` now
    does) instead of re-describing it per request; the behavior is
    identical either way thanks to the plan cache.

    With ``ft=True`` the sharded grouped two-side ABFT runs online (one
    tolerated SEU per checksum group per request; multi-fault groups are
    recomputed in place when ``recompute_uncorrectable``, the FTPolicy
    default) and the telemetry carries the per-group verdict counts.
    Spectral requests (``op="convolve" | "correlate" | "spectrum"``) stay
    in the transposed digit order end-to-end — two all-to-alls, zero
    all-gathers (see core.fft.spectral / multidim).
    """
    from repro.core.fft import api
    from repro.launch.mesh import make_fft_mesh

    x = jnp.asarray(x)
    if dims not in (1, 2):
        raise ValueError(f"dims must be 1 or 2, got {dims}")
    if dims == 2 and x.ndim != 3:
        raise ValueError(f"dims=2 expects (B, R, C) batches, got {x.shape}")
    mesh = make_fft_mesh(shards, data)
    kshape = jnp.asarray(kernel).shape if kernel is not None else None
    if real and jnp.issubdtype(x.dtype, jnp.complexfloating):
        raise ValueError(f"real=True serves real-valued traffic, "
                         f"got {x.dtype}")
    if jnp.issubdtype(x.dtype, jnp.complexfloating):
        dt = x.dtype
    else:
        dt = jnp.complex128 if (real and x.dtype == jnp.float64) \
            else jnp.complex64
    spec = build_fft_spec(
        x.shape, mesh=mesh, op=op, kernel_shape=kshape, dims=dims,
        decomp=decomp, ft=ft, threshold=threshold, groups=groups,
        group_size=group_size,
        recompute_uncorrectable=recompute_uncorrectable,
        natural_order=natural_order, dtype=dt, real=real, chunks=chunks)
    return serve_plan(api.plan(spec), x, op=op, kernel=kernel, mode=mode)


def _parse_chunks(v: str) -> int:
    """``chunks=`` values: a transaction count, or ``auto`` (-> 0, the
    plan-resolved choice from the collective-volume model)."""
    if v.strip().lower() == "auto":
        return 0
    c = int(v)
    if c < 0:
        raise ValueError(f"chunks must be >= 0 (0 = auto), got {c}")
    return c


_SPEC_KEYS = {
    # --fft-spec "k=v,..." keys -> (argparse dest, parser)
    "n": ("fft_n", int), "batch": ("batch", int),
    "shards": ("fft_shards", int), "data": ("fft_data", int),
    "dims": ("fft_dims", int), "rows": ("fft_rows", int),
    "cols": ("fft_cols", int), "op": ("fft_op", str),
    "decomp": ("fft_decomp", str), "ft": ("ft", None),
    "groups": ("fft_groups", int), "kernel_n": ("fft_kernel_n", int),
    "transposed": ("transposed", None), "threshold": ("fft_threshold", float),
    "real": ("fft_real", None), "chunks": ("fft_chunks", _parse_chunks),
}


def _parse_bool(v: str) -> bool:
    if v.lower() in ("1", "true", "yes", "on", ""):
        return True
    if v.lower() in ("0", "false", "no", "off"):
        return False
    raise ValueError(f"expected a boolean, got {v!r}")


def apply_fft_spec_arg(args, s: str):
    """Apply a consolidated ``--fft-spec "n=65536,batch=8,shards=4,ft=1"``
    string onto the parsed args — one flag describing the whole worker
    plan; the individual ``--fft-*`` flags remain as sugar and provide the
    defaults the spec string overrides.

    The string is validated strictly: an empty segment (a stray comma, as
    in ``"n=8,,n=16"``) and a repeated key both raise ``ValueError`` naming
    the offending segment — a worker must not start from a plan description
    that silently dropped or last-won half of what the operator wrote."""
    seen: set[str] = set()
    for pos, item in enumerate(s.split(","), 1):
        item = item.strip()
        if not item:
            raise ValueError(
                f"--fft-spec: empty segment at position {pos} of {s!r} — "
                f"drop the stray comma")
        k, _, v = item.partition("=")
        k = k.strip()
        if k not in _SPEC_KEYS:
            raise SystemExit(
                f"--fft-spec: unknown key {k!r} (valid: "
                f"{', '.join(sorted(_SPEC_KEYS))})")
        if k in seen:
            raise ValueError(
                f"--fft-spec: duplicate key {k!r} (segment {pos}: {item!r} "
                f"in {s!r}) — each key may appear once; last-wins would "
                f"silently mask which value the worker plans with")
        seen.add(k)
        dest, parse = _SPEC_KEYS[k]
        setattr(args, dest, _parse_bool(v) if parse is None else parse(v))
    return args


def _main_fft(args):
    from repro.core.fft import api
    from repro.launch.mesh import make_fft_mesh

    if args.fft_spec:
        apply_fft_spec_arg(args, args.fft_spec)
    rng = np.random.default_rng(0)
    kernel = None
    if args.fft_dims == 2:
        shape = (args.batch, args.fft_rows, args.fft_cols)
        size_tag = f"{args.fft_rows}x{args.fft_cols}"
    else:
        shape = (args.batch, args.fft_n)
        size_tag = f"{args.fft_n}"
    if args.fft_op in ("convolve", "correlate"):
        x = rng.standard_normal(shape).astype(np.float32)
        kshape = ((args.fft_kernel_n, args.fft_kernel_n)
                  if args.fft_dims == 2 else (args.fft_kernel_n,))
        kernel = rng.standard_normal(kshape).astype(np.float32)
    elif args.fft_real:
        x = rng.standard_normal(shape).astype(np.float32)
    else:
        x = (rng.standard_normal(shape) +
             1j * rng.standard_normal(shape)).astype(np.complex64)
    # ONE plan per worker, built at startup: every request dispatches
    # through its cached executors (the cuFFT plan-once/exec-hot contract)
    mesh = make_fft_mesh(args.fft_shards, args.fft_data)
    spec = build_fft_spec(
        shape, mesh=mesh, op=args.fft_op,
        kernel_shape=kernel.shape if kernel is not None else None,
        dims=args.fft_dims, decomp=args.fft_decomp, ft=args.ft,
        threshold=args.fft_threshold, groups=args.fft_groups,
        natural_order=False if args.transposed else None,
        real=args.fft_real, chunks=args.fft_chunks)
    p = api.plan(spec)
    print(f"# {p}")
    call = lambda: serve_plan(p, x, op=args.fft_op, kernel=kernel)
    y, info = call()  # warmup
    t0 = time.time()
    for _ in range(args.fft_iters):
        y, info = call()
        jax.block_until_ready(y)
    dt = (time.time() - t0) / args.fft_iters
    y = np.asarray(y)
    nfft = int(np.prod(shape[1:]))
    if args.fft_real:
        fwd = np.fft.rfft2 if args.fft_dims == 2 else np.fft.rfft
    else:
        fwd = np.fft.fft2 if args.fft_dims == 2 else np.fft.fft
    if args.fft_op == "convolve":
        if args.fft_dims == 2:
            rr = shape[1] + kshape[0] - 1
            cc = shape[2] + kshape[1] - 1
            full = np.real(np.fft.ifft2(np.fft.fft2(x, s=(rr, cc)) *
                                        np.fft.fft2(kernel, s=(rr, cc))))
            r0 = (min(shape[1], kshape[0]) - 1) // 2
            c0 = (min(shape[2], kshape[1]) - 1) // 2
            ref = full[:, r0:r0 + max(shape[1], kshape[0]),
                       c0:c0 + max(shape[2], kshape[1])]
        else:
            ref = np.stack([np.convolve(r, kernel, "same") for r in x])
    elif args.fft_op == "correlate":
        ref = np.stack([np.correlate(r, kernel, "same") for r in x])
    elif args.fft_op == "spectrum":
        ref = np.abs(fwd(x)) ** 2 / nfft
        if info.get("order") == "transposed":
            # order-agnostic comparison over the flattened bins
            ref = np.sort(ref.reshape(ref.shape[0], -1), axis=-1)
            y = np.sort(y.reshape(y.shape[0], -1), axis=-1)
    elif args.transposed and info.get("order") == "transposed":
        ref = y   # digit-permuted; correctness is covered by the test suite
    else:
        ref = fwd(x)
    err = np.abs(y - ref).max() / (np.abs(ref).max() + 1e-30)
    print(f"{args.fft_op} batch={args.batch} N={size_tag} {info} "
          f"{dt*1e3:.2f}ms/req rel_err={err:.2e}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="lm", choices=["lm", "fft"])
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--fft-n", type=int, default=1 << 16)
    ap.add_argument("--fft-shards", type=int, default=None)
    ap.add_argument("--fft-data", type=int, default=1,
                    help="batch-parallel mesh axis size (2-D data x fft mesh)")
    ap.add_argument("--fft-op", default="fft",
                    choices=["fft", "convolve", "correlate", "spectrum"],
                    help="spectral ops stay in transposed order end-to-end")
    ap.add_argument("--fft-dims", type=int, default=1, choices=[1, 2],
                    help="2 serves (batch, rows, cols) grids through the "
                         "multidim subsystem (core.fft.multidim)")
    ap.add_argument("--fft-decomp", default="auto",
                    choices=["auto", "slab", "pencil"],
                    help="multidim decomposition; auto = the "
                         "collective-volume heuristic (choose_decomp)")
    ap.add_argument("--fft-rows", type=int, default=256,
                    help="grid rows for --fft-dims 2")
    ap.add_argument("--fft-cols", type=int, default=256,
                    help="grid cols for --fft-dims 2")
    ap.add_argument("--fft-kernel-n", type=int, default=63,
                    help="kernel length for convolve/correlate")
    ap.add_argument("--fft-groups", type=int, default=None,
                    help="ABFT checksum groups (one tolerated SEU per "
                         "group); default: one group per data shard")
    ap.add_argument("--fft-threshold", type=float, default=1e-4,
                    help="ABFT detection threshold")
    ap.add_argument("--fft-chunks", type=_parse_chunks, default=1,
                    help="multi-transaction overlap: split the batch into "
                         "this many chunked all-to-all transactions (each "
                         "one's collective hides behind the next one's "
                         "local Stockham passes); 'auto' lets the plan "
                         "pick from the collective-volume model")
    ap.add_argument("--fft-spec", default=None,
                    help="consolidated plan description, e.g. "
                         "'n=65536,batch=8,shards=4,data=2,ft=1,groups=4' "
                         "(keys: " + ", ".join(sorted(_SPEC_KEYS)) + "); "
                         "overrides the individual --fft-* flags — the "
                         "worker builds ONE FFTPlan from it at startup")
    ap.add_argument("--fft-iters", type=int, default=5)
    ap.add_argument("--transposed", action="store_true",
                    help="keep fft/spectrum output in transposed digit order")
    ap.add_argument("--fft-real", action="store_true",
                    help="serve real-valued traffic through the packed "
                         "half-spectrum pipelines (rfft/rfft2, one-sided "
                         "spectrum, packed convolve) — ~half the C2C "
                         "collective bytes on a mesh")
    ap.add_argument("--ft", action="store_true",
                    help="FFT mode: run the sharded two-side ABFT online. "
                         "LM mode: protect every linear with the checked "
                         "GEMM plan (core.gemm) and inject a demo "
                         "FaultSchedule of SEUs that the decode must "
                         "detect and correct online")
    ap.add_argument("--ft-threshold", type=float, default=1e-3,
                    help="LM-mode ABFT detection threshold (relative "
                         "per-column checksum divergence)")
    args = ap.parse_args()

    if args.mode == "fft":
        _main_fft(args)
        return

    cfg = (get_config if args.preset == "full" else get_smoke_config)(
        args.arch)
    schedule = None
    if args.ft:
        import dataclasses as _dc

        from repro.core.ft import FaultSchedule

        cfg = _dc.replace(cfg, ft=_dc.replace(
            cfg.ft, protect_linears=True, threshold=args.ft_threshold))
        # two SEUs the online ABFT must catch: one mid-prefill, one
        # mid-generation — (step, site, row<batch, col, eps_re, eps_im)
        schedule = FaultSchedule(entries=(
            (min(2, args.prompt_len - 1), 0, args.batch - 1, 3, 275.0, 0.0),
            (args.prompt_len + 1, 1, 0, 11, -310.0, 0.0),
        ))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    t0 = time.time()
    res = decode(model, params, prompts, args.gen, schedule=schedule)
    toks, stats = res if args.ft else (res, None)
    dt = time.time() - t0
    rate = args.batch * args.gen / dt
    print(f"generated {toks.shape} in {dt:.2f}s ({rate:.1f} tok/s)")
    if stats is not None:
        print(f"ft: injected={schedule.num_faults} "
              f"detected={float(stats.detected):.0f} "
              f"corrected={float(stats.corrected):.0f} "
              f"max_score={float(stats.max_score):.3f} "
              f"backend={cfg.ft.gemm_backend}")
    print(np.asarray(toks[:, :16]))


if __name__ == "__main__":
    main()
