"""Serving driver: batched greedy decode against the KV cache, plus a
batched sharded-FFT endpoint backed by the distributed transform.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --preset tiny \
        --batch 4 --prompt-len 16 --gen 32

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve --mode fft \
        --fft-n 65536 --batch 8 --fft-shards 4 --ft

    # the same worker described by ONE consolidated plan spec (the worker
    # builds a single FFTPlan from it at startup; the --fft-* flags are
    # sugar that provide the defaults the spec string overrides)
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve --mode fft \
        --fft-spec "n=65536,batch=8,shards=4,ft=1,groups=4"

    # transposed-order convolution on a 2-D batch x pencil mesh
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve --mode fft --fft-op convolve \
        --fft-n 16384 --batch 8 --fft-shards 2 --fft-data 2

    # distributed 2-D FFT (slab|pencil|auto) with grouped ABFT on the grids
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve --mode fft --fft-dims 2 \
        --fft-rows 256 --fft-cols 512 --batch 8 --fft-shards 4 \
        --fft-decomp slab --ft

    # the multi-tenant serving runtime (repro.serve): spec bucketing +
    # deadline batching over the plan cache, one string describing plan
    # geometry AND scheduler policy
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve --mode serve \
        --fft-spec "n=4096,shards=4,workers=2,max_batch=8,deadline_ms=2"
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.configs.base import ParallelConfig, RunConfig
from repro.models import Model
from repro.train import make_serve_step


def decode(model: Model, params, prompts: jax.Array, gen: int,
           max_len: int | None = None, schedule=None):
    """Prefill via repeated decode steps, then generate ``gen`` tokens.

    ``schedule`` is an optional :class:`~repro.core.ft.FaultSchedule`: each
    host-loop step arms its GEMM fault descriptor
    (:meth:`~repro.core.ft.FaultSchedule.for_step_gemm`) into the jitted
    step — the descriptor is a traced array of fixed shape, so the armed
    and clean steps share ONE compiled program. Returns ``(tokens,
    FTStats)`` when a schedule is given (online ABFT telemetry summed over
    steps), else just ``tokens``.
    """
    from repro.core.ft import FTStats

    cfg = model.cfg
    b, p = prompts.shape
    max_len = max_len or (p + gen)
    run = RunConfig(model=cfg)
    step_fn = jax.jit(make_serve_step(model, run))
    cache = model.init_cache(batch=b, max_len=max_len)
    stats = FTStats.zeros()

    def inj(step):
        return None if schedule is None else schedule.for_step_gemm(step)

    def fold(aux):
        return stats.merge(FTStats(
            detected=aux["ft_flagged"], corrected=aux["ft_corrected"],
            max_score=aux["ft_max_score"],
            skipped_updates=jnp.zeros((), jnp.float32)))

    # teacher-forced prefill (decode-path; exercises the cache end-to-end)
    nxt = prompts[:, :1]
    for i in range(p):
        tok = prompts[:, i:i + 1]
        nxt, cache, aux = step_fn(params, cache, tok, jnp.int32(i), inj(i))
        stats = fold(aux)
    out = [nxt]
    for j in range(gen - 1):
        nxt, cache, aux = step_fn(params, cache, nxt, jnp.int32(p + j),
                                  inj(p + j))
        stats = fold(aux)
        out.append(nxt)
    toks = jnp.concatenate(out, axis=1)
    return toks if schedule is None else (toks, stats)


# The request-description layer lives in repro.serve.specs (shared with
# the multi-tenant runtime, repro.serve.runtime); these re-exports keep
# the historical launch.serve surface working.
from repro.serve.specs import (SPEC_KEYS as _SPEC_KEYS,  # noqa: E402
                               _ft_telemetry, _parse_bool, _parse_chunks,
                               apply_fft_spec_arg, build_fft_spec,
                               serve_plan)

SPEC_KEYS = _SPEC_KEYS


def serve_fft(x, *, shards: int | None = None, data: int = 1,
              ft: bool = False, threshold: float = 1e-4,
              op: str = "fft", kernel=None, mode: str = "same",
              natural_order: bool | None = None,
              groups: int | None = None, group_size: int | None = None,
              recompute_uncorrectable: bool = True,
              dims: int = 1, decomp: str = "auto", real: bool = False,
              chunks: int = 1):
    """Batched sharded FFT endpoint: one request = one (B, N) batch
    (``dims=2``: one (B, R, C) grid batch).

    Compat sugar over the plan API: builds the ``fft``-axis mesh — 2-D
    ``data x fft`` when ``data > 1`` — resolves the request into an
    :class:`~repro.core.fft.api.FFTSpec` via :func:`build_fft_spec`,
    LRU-hits the plan, and serves through :func:`serve_plan`. A production
    worker should build the plan ONCE at startup (what ``--mode fft`` now
    does) instead of re-describing it per request; the behavior is
    identical either way thanks to the plan cache.

    With ``ft=True`` the sharded grouped two-side ABFT runs online (one
    tolerated SEU per checksum group per request; multi-fault groups are
    recomputed in place when ``recompute_uncorrectable``, the FTPolicy
    default) and the telemetry carries the per-group verdict counts.
    Spectral requests (``op="convolve" | "correlate" | "spectrum"``) stay
    in the transposed digit order end-to-end — two all-to-alls, zero
    all-gathers (see core.fft.spectral / multidim).
    """
    from repro.core.fft import api
    from repro.launch.mesh import make_fft_mesh

    x = jnp.asarray(x)
    if dims not in (1, 2):
        raise ValueError(f"dims must be 1 or 2, got {dims}")
    if dims == 2 and x.ndim != 3:
        raise ValueError(f"dims=2 expects (B, R, C) batches, got {x.shape}")
    mesh = make_fft_mesh(shards, data)
    kshape = jnp.asarray(kernel).shape if kernel is not None else None
    if real and jnp.issubdtype(x.dtype, jnp.complexfloating):
        raise ValueError(f"real=True serves real-valued traffic, "
                         f"got {x.dtype}")
    if jnp.issubdtype(x.dtype, jnp.complexfloating):
        dt = x.dtype
    else:
        dt = jnp.complex128 if (real and x.dtype == jnp.float64) \
            else jnp.complex64
    spec = build_fft_spec(
        x.shape, mesh=mesh, op=op, kernel_shape=kshape, dims=dims,
        decomp=decomp, ft=ft, threshold=threshold, groups=groups,
        group_size=group_size,
        recompute_uncorrectable=recompute_uncorrectable,
        natural_order=natural_order, dtype=dt, real=real, chunks=chunks)
    return serve_plan(api.plan(spec), x, op=op, kernel=kernel, mode=mode)


def _main_fft(args):
    from repro.core.fft import api
    from repro.launch.mesh import make_fft_mesh

    if args.fft_spec:
        apply_fft_spec_arg(args, args.fft_spec)
    rng = np.random.default_rng(0)
    kernel = None
    if args.fft_dims == 2:
        shape = (args.batch, args.fft_rows, args.fft_cols)
        size_tag = f"{args.fft_rows}x{args.fft_cols}"
    else:
        shape = (args.batch, args.fft_n)
        size_tag = f"{args.fft_n}"
    if args.fft_op in ("convolve", "correlate"):
        x = rng.standard_normal(shape).astype(np.float32)
        kshape = ((args.fft_kernel_n, args.fft_kernel_n)
                  if args.fft_dims == 2 else (args.fft_kernel_n,))
        kernel = rng.standard_normal(kshape).astype(np.float32)
    elif args.fft_real:
        x = rng.standard_normal(shape).astype(np.float32)
    else:
        x = (rng.standard_normal(shape) +
             1j * rng.standard_normal(shape)).astype(np.complex64)
    # ONE plan per worker, built at startup: every request dispatches
    # through its cached executors (the cuFFT plan-once/exec-hot contract)
    mesh = make_fft_mesh(args.fft_shards, args.fft_data)
    spec = build_fft_spec(
        shape, mesh=mesh, op=args.fft_op,
        kernel_shape=kernel.shape if kernel is not None else None,
        dims=args.fft_dims, decomp=args.fft_decomp, ft=args.ft,
        threshold=args.fft_threshold, groups=args.fft_groups,
        natural_order=False if args.transposed else None,
        real=args.fft_real, chunks=args.fft_chunks)
    p = api.plan(spec)
    print(f"# {p}")
    call = lambda: serve_plan(p, x, op=args.fft_op, kernel=kernel)
    y, info = call()  # warmup
    t0 = time.time()
    for _ in range(args.fft_iters):
        y, info = call()
        jax.block_until_ready(y)
    dt = (time.time() - t0) / args.fft_iters
    y = np.asarray(y)
    nfft = int(np.prod(shape[1:]))
    if args.fft_real:
        fwd = np.fft.rfft2 if args.fft_dims == 2 else np.fft.rfft
    else:
        fwd = np.fft.fft2 if args.fft_dims == 2 else np.fft.fft
    if args.fft_op == "convolve":
        if args.fft_dims == 2:
            rr = shape[1] + kshape[0] - 1
            cc = shape[2] + kshape[1] - 1
            full = np.real(np.fft.ifft2(np.fft.fft2(x, s=(rr, cc)) *
                                        np.fft.fft2(kernel, s=(rr, cc))))
            r0 = (min(shape[1], kshape[0]) - 1) // 2
            c0 = (min(shape[2], kshape[1]) - 1) // 2
            ref = full[:, r0:r0 + max(shape[1], kshape[0]),
                       c0:c0 + max(shape[2], kshape[1])]
        else:
            ref = np.stack([np.convolve(r, kernel, "same") for r in x])
    elif args.fft_op == "correlate":
        ref = np.stack([np.correlate(r, kernel, "same") for r in x])
    elif args.fft_op == "spectrum":
        ref = np.abs(fwd(x)) ** 2 / nfft
        if info.get("order") == "transposed":
            # order-agnostic comparison over the flattened bins
            ref = np.sort(ref.reshape(ref.shape[0], -1), axis=-1)
            y = np.sort(y.reshape(y.shape[0], -1), axis=-1)
    elif args.transposed and info.get("order") == "transposed":
        ref = y   # digit-permuted; correctness is covered by the test suite
    else:
        ref = fwd(x)
    err = np.abs(y - ref).max() / (np.abs(ref).max() + 1e-30)
    print(f"{args.fft_op} batch={args.batch} N={size_tag} {info} "
          f"{dt*1e3:.2f}ms/req rel_err={err:.2e}")


def _main_serve(args):
    """Multi-tenant serving worker (``--mode serve``): stand up a
    :class:`~repro.serve.ServeRuntime` over the mesh, drive it with a
    short mixed-tenant self-test workload, and print the per-bucket
    telemetry — the operational smoke of the runtime the benchmark
    (benchmarks/fft_serving.py) measures properly."""
    import json

    from repro.launch.mesh import make_fft_mesh
    from repro.serve import RuntimeConfig, ServeRuntime

    if args.fft_spec:
        apply_fft_spec_arg(args, args.fft_spec)
    mesh = make_fft_mesh(args.fft_shards, 1)
    cfg = RuntimeConfig(
        max_batch=args.serve_max_batch, deadline_ms=args.serve_deadline_ms,
        queue_depth=args.serve_queue_depth, workers=args.serve_workers,
        timeout_ms=args.serve_timeout_ms, chunks=max(args.fft_chunks, 1))
    rng = np.random.default_rng(0)
    n = args.fft_n
    t0 = time.time()
    with ServeRuntime(cfg, mesh=mesh if mesh.shape.get("fft", 1) > 1
                      else None) as rt:
        handles = []
        for i in range(args.serve_requests):
            # mixed tenants: off-grid sizes, three request kinds
            sz = (n, max(2, n - n // 4), max(2, n // 2 + 1))[i % 3]
            x = rng.standard_normal(sz).astype(np.float32)
            kind = i % 4
            kw = ({"op": "fft"}, {"op": "spectrum"},
                  {"op": "fft", "real": True},
                  {"op": "fft", "ft": True})[kind if not args.ft else 3]
            handles.append(rt.submit(x, **kw))
        for h in handles:
            h.result(timeout=300.0)
        stats = rt.stats()
    dt = time.time() - t0
    print(f"# served {len(handles)} requests in {dt:.2f}s "
          f"({len(handles) / dt:.0f} rps) over "
          f"{dict(mesh.shape) if mesh is not None else 'single device'}")
    print(json.dumps(stats["buckets"], indent=2, sort_keys=True))
    print(f"# plan cache: {stats['plan_cache']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="lm", choices=["lm", "fft", "serve"])
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--fft-n", type=int, default=1 << 16)
    ap.add_argument("--fft-shards", type=int, default=None)
    ap.add_argument("--fft-data", type=int, default=1,
                    help="batch-parallel mesh axis size (2-D data x fft mesh)")
    ap.add_argument("--fft-op", default="fft",
                    choices=["fft", "convolve", "correlate", "spectrum"],
                    help="spectral ops stay in transposed order end-to-end")
    ap.add_argument("--fft-dims", type=int, default=1, choices=[1, 2],
                    help="2 serves (batch, rows, cols) grids through the "
                         "multidim subsystem (core.fft.multidim)")
    ap.add_argument("--fft-decomp", default="auto",
                    choices=["auto", "slab", "pencil"],
                    help="multidim decomposition; auto = the "
                         "collective-volume heuristic (choose_decomp)")
    ap.add_argument("--fft-rows", type=int, default=256,
                    help="grid rows for --fft-dims 2")
    ap.add_argument("--fft-cols", type=int, default=256,
                    help="grid cols for --fft-dims 2")
    ap.add_argument("--fft-kernel-n", type=int, default=63,
                    help="kernel length for convolve/correlate")
    ap.add_argument("--fft-groups", type=int, default=None,
                    help="ABFT checksum groups (one tolerated SEU per "
                         "group); default: one group per data shard")
    ap.add_argument("--fft-threshold", type=float, default=1e-4,
                    help="ABFT detection threshold")
    ap.add_argument("--fft-chunks", type=_parse_chunks, default=1,
                    help="multi-transaction overlap: split the batch into "
                         "this many chunked all-to-all transactions (each "
                         "one's collective hides behind the next one's "
                         "local Stockham passes); 'auto' lets the plan "
                         "pick from the collective-volume model")
    ap.add_argument("--fft-spec", default=None,
                    help="consolidated plan description, e.g. "
                         "'n=65536,batch=8,shards=4,data=2,ft=1,groups=4' "
                         "(keys: " + ", ".join(sorted(_SPEC_KEYS)) + "); "
                         "overrides the individual --fft-* flags — the "
                         "worker builds ONE FFTPlan from it at startup")
    ap.add_argument("--fft-iters", type=int, default=5)
    ap.add_argument("--serve-workers", type=int, default=2,
                    help="serve mode: executor worker threads (sharded "
                         "dispatch is serialized on the mesh lock; extra "
                         "workers overlap batch assembly/scatter)")
    ap.add_argument("--serve-max-batch", type=int, default=8,
                    help="serve mode: coalescing limit = the bucket plans' "
                         "batch dimension")
    ap.add_argument("--serve-deadline-ms", type=float, default=2.0,
                    help="serve mode: max time a request waits for batch "
                         "companions before its partial batch closes")
    ap.add_argument("--serve-queue-depth", type=int, default=64,
                    help="serve mode: bounded pending-request queue "
                         "(backpressure: overflow is rejected, not "
                         "buffered)")
    ap.add_argument("--serve-timeout-ms", type=float, default=None,
                    help="serve mode: fail requests unbatched past this "
                         "age (default: never)")
    ap.add_argument("--serve-requests", type=int, default=64,
                    help="serve mode: self-test workload size")
    ap.add_argument("--transposed", action="store_true",
                    help="keep fft/spectrum output in transposed digit order")
    ap.add_argument("--fft-real", action="store_true",
                    help="serve real-valued traffic through the packed "
                         "half-spectrum pipelines (rfft/rfft2, one-sided "
                         "spectrum, packed convolve) — ~half the C2C "
                         "collective bytes on a mesh")
    ap.add_argument("--ft", action="store_true",
                    help="FFT mode: run the sharded two-side ABFT online. "
                         "LM mode: protect every linear with the checked "
                         "GEMM plan (core.gemm) and inject a demo "
                         "FaultSchedule of SEUs that the decode must "
                         "detect and correct online")
    ap.add_argument("--ft-threshold", type=float, default=1e-3,
                    help="LM-mode ABFT detection threshold (relative "
                         "per-column checksum divergence)")
    args = ap.parse_args()

    if args.mode == "fft":
        _main_fft(args)
        return
    if args.mode == "serve":
        _main_serve(args)
        return

    cfg = (get_config if args.preset == "full" else get_smoke_config)(
        args.arch)
    schedule = None
    if args.ft:
        import dataclasses as _dc

        from repro.core.ft import FaultSchedule

        cfg = _dc.replace(cfg, ft=_dc.replace(
            cfg.ft, protect_linears=True, threshold=args.ft_threshold))
        # two SEUs the online ABFT must catch: one mid-prefill, one
        # mid-generation — (step, site, row<batch, col, eps_re, eps_im)
        schedule = FaultSchedule(entries=(
            (min(2, args.prompt_len - 1), 0, args.batch - 1, 3, 275.0, 0.0),
            (args.prompt_len + 1, 1, 0, 11, -310.0, 0.0),
        ))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    t0 = time.time()
    res = decode(model, params, prompts, args.gen, schedule=schedule)
    toks, stats = res if args.ft else (res, None)
    dt = time.time() - t0
    rate = args.batch * args.gen / dt
    print(f"generated {toks.shape} in {dt:.2f}s ({rate:.1f} tok/s)")
    if stats is not None:
        print(f"ft: injected={schedule.num_faults} "
              f"detected={float(stats.detected):.0f} "
              f"corrected={float(stats.corrected):.0f} "
              f"max_score={float(stats.max_score):.3f} "
              f"backend={cfg.ft.gemm_backend}")
    print(np.asarray(toks[:, :16]))


if __name__ == "__main__":
    main()
