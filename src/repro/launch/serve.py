"""Serving driver: batched greedy decode against the KV cache, plus a
batched sharded-FFT endpoint backed by the distributed transform.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --preset tiny \
        --batch 4 --prompt-len 16 --gen 32

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve --mode fft \
        --fft-n 65536 --batch 8 --fft-shards 4 --ft

    # transposed-order convolution on a 2-D batch x pencil mesh
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve --mode fft --fft-op convolve \
        --fft-n 16384 --batch 8 --fft-shards 2 --fft-data 2

    # distributed 2-D FFT (slab|pencil|auto) with grouped ABFT on the grids
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve --mode fft --fft-dims 2 \
        --fft-rows 256 --fft-cols 512 --batch 8 --fft-shards 4 \
        --fft-decomp slab --ft
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.configs.base import ParallelConfig, RunConfig
from repro.models import Model
from repro.train import make_serve_step


def decode(model: Model, params, prompts: jax.Array, gen: int,
           max_len: int | None = None):
    """Prefill via repeated decode steps, then generate ``gen`` tokens."""
    cfg = model.cfg
    b, p = prompts.shape
    max_len = max_len or (p + gen)
    run = RunConfig(model=cfg)
    step_fn = jax.jit(make_serve_step(model, run))
    cache = model.init_cache(batch=b, max_len=max_len)
    # teacher-forced prefill (decode-path; exercises the cache end-to-end)
    nxt = prompts[:, :1]
    for i in range(p):
        tok = prompts[:, i:i + 1]
        nxt, cache, _ = step_fn(params, cache, tok, jnp.int32(i))
    out = [nxt]
    for j in range(gen - 1):
        nxt, cache, _ = step_fn(params, cache, nxt, jnp.int32(p + j))
        out.append(nxt)
    return jnp.concatenate(out, axis=1)


def serve_fft(x, *, shards: int | None = None, data: int = 1,
              ft: bool = False, threshold: float = 1e-4,
              op: str = "fft", kernel=None, mode: str = "same",
              natural_order: bool | None = None,
              groups: int | None = None, group_size: int | None = None,
              recompute_uncorrectable: bool = True,
              dims: int = 1, decomp: str = "auto"):
    """Batched sharded FFT endpoint: one request = one (B, N) batch.

    Builds (and caches, via the jit/shard_map caches underneath) the
    ``fft``-axis mesh — 2-D ``data x fft`` when ``data > 1``, so batch rows
    shard over ``data`` while signal pencils shard over ``fft`` — and
    returns ``(y, telemetry)``. With ``ft=True`` the sharded grouped
    two-side ABFT runs online: the batch splits into ``groups`` checksum
    groups (auto: one per data shard), each with its own detect/locate/
    correct verdict, so one SEU per *group* is tolerated per request; a
    multi-fault group is recomputed in place when
    ``recompute_uncorrectable`` (the FTPolicy default). The telemetry
    carries the per-group verdict counts.

    Spectral requests stay in the transposed digit order end-to-end (two
    all-to-alls, zero all-gathers — see core.fft.spectral):

    * ``op="convolve"`` / ``op="correlate"``: linear convolution /
      cross-correlation of each signal with ``kernel`` (modes
      full/same/valid); the time-domain result is natural order.
    * ``op="spectrum"``: periodogram; the bins stay transposed (the order
      every bin-agnostic consumer wants) unless ``natural_order=True``.
    * ``op="fft"``: the plain transform; ``natural_order=False`` skips the
      final redistribution and returns transposed-order bins.

    ``dims=2`` serves (B, R, C) grid batches through the multidim
    subsystem (core.fft.multidim): ``decomp`` picks slab / pencil / auto
    (the collective-volume heuristic), ``ft`` runs the grouped two-side
    ABFT on the slab row pass, ``op="convolve"`` is the fused 2-D
    spectral pipeline (two all-to-alls, zero all-gathers), and
    ``op="spectrum"`` the 2-D periodogram.
    """
    from repro.core.fft import spectral
    from repro.core.fft.distributed import distributed_fft, ft_distributed_fft
    from repro.launch.mesh import make_fft_mesh
    from repro.parallel.fft_sharding import shard_signals

    if op not in ("fft", "convolve", "correlate", "spectrum"):
        raise ValueError(f"op must be fft|convolve|correlate|spectrum, "
                         f"got {op!r}")
    if dims not in (1, 2):
        raise ValueError(f"dims must be 1 or 2, got {dims}")
    x = jnp.asarray(x)
    if op == "fft" and not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    mesh = make_fft_mesh(shards, data)
    if dims == 2:
        return _serve_fft2(x, mesh, ft=ft, threshold=threshold, op=op,
                           kernel=kernel, mode=mode, decomp=decomp,
                           natural_order=natural_order, groups=groups,
                           group_size=group_size,
                           recompute_uncorrectable=recompute_uncorrectable)

    if op in ("convolve", "correlate"):
        if kernel is None:
            raise ValueError(f"op={op!r} needs a kernel")
        fn = spectral.fft_convolve if op == "convolve" else spectral.correlate
        y = fn(x, kernel, mesh, mode=mode)
        sharded = mesh.shape["fft"] > 1
        return y, {"shards": int(mesh.shape["fft"]),
                   "data": int(mesh.shape.get("data", 1)),
                   "op": op, "order": "natural",
                   "collectives": "2 a2a" if sharded else "local"}
    if op == "spectrum":
        y = spectral.power_spectrum(x, mesh, natural_order=natural_order)
        transposed = (natural_order is not True and mesh.shape["fft"] > 1)
        return y, {"shards": int(mesh.shape["fft"]),
                   "data": int(mesh.shape.get("data", 1)), "op": op,
                   "order": "transposed" if transposed else "natural"}

    if mesh.shape["fft"] == 1:
        if ft:
            # single device: the fused-kernel two-side ABFT path
            from repro.kernels.ops import ft_fft

            res = ft_fft(x, threshold=threshold)
            flagged = np.asarray(res.flagged)
            g = int(np.argmax(flagged)) if flagged.any() else -1
            return res.y, {
                "shards": 1, "ft": True,
                "score": float(jnp.max(res.group_score)),
                "flagged": bool(flagged.any()),
                "location": int(np.asarray(res.location)[g]) if g >= 0 else -1,
                "corrected": int(res.corrected),
            }
        y = distributed_fft(x, None)
        return y, {"shards": 1, "ft": False}
    xs = shard_signals(x, mesh)
    if ft:
        from repro.parallel.fft_sharding import abft_group_layout

        g, gsz = abft_group_layout(mesh, x.shape[0], groups=groups,
                                   group_size=group_size)
        res = ft_distributed_fft(
            xs, mesh, threshold=threshold, groups=g,
            natural_order=natural_order is not False,
            recompute_uncorrectable=recompute_uncorrectable)
        flagged = np.asarray(res.flagged)
        # the decoded location is only meaningful for correctable (single
        # data-fault) groups — checksum-row and multi-fault verdicts clip
        # it to an arbitrary healthy signal, which must not be reported
        correctable = np.asarray(res.correctable)
        locs = np.asarray(res.location)
        return res.y, {
            "shards": int(mesh.shape["fft"]),
            "data": int(mesh.shape.get("data", 1)), "ft": True,
            "groups": g, "group_size": gsz,
            "score": float(jnp.max(res.group_score)),
            "flagged": int(flagged.sum()),
            "locations": [int(l) for l, c in zip(locs, correctable) if c],
            "corrected": int(res.corrected),
            "uncorrectable": int(np.asarray(res.uncorrectable).sum()),
            "checksum_faults": int(np.asarray(res.checksum_fault).sum()),
            "recomputed": int(res.recomputed),
            "shard_delta_max": float(jnp.max(res.shard_delta)),
        }
    y = distributed_fft(xs, mesh, natural_order=natural_order is not False)
    return y, {"shards": int(mesh.shape["fft"]),
               "data": int(mesh.shape.get("data", 1)), "ft": False,
               "order": "natural" if natural_order is not False
               else "transposed"}


def _serve_fft2(x, mesh, *, ft, threshold, op, kernel, mode, decomp,
                natural_order, groups, group_size, recompute_uncorrectable):
    """The ``dims=2`` half of :func:`serve_fft`: (B, R, C) grid batches
    through ``core.fft.multidim`` (slab / pencil / auto)."""
    from repro.core.fft import multidim
    from repro.parallel.fft_sharding import shard_grid

    if x.ndim != 3:
        raise ValueError(f"dims=2 expects (B, R, C) batches, got {x.shape}")
    b, rr, cc = x.shape
    sharded = mesh.shape["fft"] > 1
    info = {"shards": int(mesh.shape["fft"]),
            "data": int(mesh.shape.get("data", 1)), "op": op, "dims": 2}
    if op == "correlate":
        raise ValueError("op='correlate' is 1-D only; dims=2 serves "
                         "fft|convolve|spectrum")
    if op == "convolve":
        if kernel is None:
            raise ValueError("op='convolve' needs a kernel")
        y = multidim.fft_convolve2(x, kernel, mesh if sharded else None,
                                   mode=mode)
        info.update(order="natural",
                    collectives="2 a2a" if sharded else "local")
        return y, info
    # the effective bin order: like the 1-D endpoint, the order-agnostic
    # periodogram defaults to the cheap transposed order on a mesh (the
    # digit restore is pure waste for |X|^2), the plain transform to
    # natural; an explicit natural_order always wins
    nat = (natural_order if natural_order is not None
           else not (sharded and op == "spectrum"))
    if decomp == "auto" and sharded:
        decomp = multidim.choose_decomp((rr, cc), mesh, batch=b, ft=ft,
                                        natural_order=nat)
    info["decomp"] = decomp if sharded else "local"
    if op == "spectrum":
        y = multidim.distributed_fft2(
            x, mesh if sharded else None, decomp=decomp, natural_order=nat)
        info["order"] = ("transposed" if (decomp == "pencil" and sharded
                                          and not nat) else "natural")
        return (jnp.abs(y) ** 2) / (rr * cc), info
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    if ft:
        if not sharded:
            raise ValueError("--ft with dims=2 runs the sharded grouped "
                             "ABFT: needs an fft axis >= 2 devices")
        if decomp == "pencil":
            raise ValueError("grouped ABFT rides the slab inter-axis "
                             "transpose: --ft needs --fft-decomp slab|auto")
        from repro.parallel.fft_sharding import abft_group_layout

        g, gsz = abft_group_layout(mesh, b, groups=groups,
                                   group_size=group_size)
        xs = shard_grid(x, mesh, 2, decomp="slab")
        res = multidim.ft_distributed_fft2(
            xs, mesh, threshold=threshold, groups=g,
            recompute_uncorrectable=recompute_uncorrectable)
        correctable = np.asarray(res.correctable)
        locs = np.asarray(res.location)
        info.update(
            ft=True, decomp="slab", groups=g, group_size=gsz,
            score=float(jnp.max(res.group_score)),
            flagged=int(np.asarray(res.flagged).sum()),
            locations=[int(l) for l, c in zip(locs, correctable) if c],
            corrected=int(res.corrected),
            uncorrectable=int(np.asarray(res.uncorrectable).sum()),
            checksum_faults=int(np.asarray(res.checksum_fault).sum()),
            recomputed=int(res.recomputed),
            shard_delta_max=float(jnp.max(res.shard_delta)))
        return res.y, info
    if sharded:
        x = shard_grid(x, mesh, 2,
                       decomp="slab" if decomp == "slab" else "pencil")
    y = multidim.distributed_fft2(x, mesh if sharded else None, decomp=decomp,
                                  natural_order=nat)
    info.update(ft=False,
                order="transposed" if (sharded and decomp == "pencil"
                                       and not nat) else "natural")
    return y, info


def _main_fft(args):
    rng = np.random.default_rng(0)
    kernel = None
    if args.fft_dims == 2:
        shape = (args.batch, args.fft_rows, args.fft_cols)
        size_tag = f"{args.fft_rows}x{args.fft_cols}"
    else:
        shape = (args.batch, args.fft_n)
        size_tag = f"{args.fft_n}"
    if args.fft_op in ("convolve", "correlate"):
        x = rng.standard_normal(shape).astype(np.float32)
        kshape = ((args.fft_kernel_n, args.fft_kernel_n)
                  if args.fft_dims == 2 else (args.fft_kernel_n,))
        kernel = rng.standard_normal(kshape).astype(np.float32)
    else:
        x = (rng.standard_normal(shape) +
             1j * rng.standard_normal(shape)).astype(np.complex64)
    call = lambda: serve_fft(
        x, shards=args.fft_shards, data=args.fft_data, ft=args.ft,
        op=args.fft_op, kernel=kernel, groups=args.fft_groups,
        dims=args.fft_dims, decomp=args.fft_decomp,
        natural_order=False if args.transposed else None)
    y, info = call()  # warmup
    t0 = time.time()
    for _ in range(args.fft_iters):
        y, info = call()
        jax.block_until_ready(y)
    dt = (time.time() - t0) / args.fft_iters
    y = np.asarray(y)
    nfft = int(np.prod(shape[1:]))
    fwd = np.fft.fft2 if args.fft_dims == 2 else np.fft.fft
    if args.fft_op == "convolve":
        if args.fft_dims == 2:
            rr = shape[1] + kshape[0] - 1
            cc = shape[2] + kshape[1] - 1
            full = np.real(np.fft.ifft2(np.fft.fft2(x, s=(rr, cc)) *
                                        np.fft.fft2(kernel, s=(rr, cc))))
            r0 = (min(shape[1], kshape[0]) - 1) // 2
            c0 = (min(shape[2], kshape[1]) - 1) // 2
            ref = full[:, r0:r0 + max(shape[1], kshape[0]),
                       c0:c0 + max(shape[2], kshape[1])]
        else:
            ref = np.stack([np.convolve(r, kernel, "same") for r in x])
    elif args.fft_op == "correlate":
        ref = np.stack([np.correlate(r, kernel, "same") for r in x])
    elif args.fft_op == "spectrum":
        ref = np.abs(fwd(x)) ** 2 / nfft
        if info.get("order") == "transposed":
            # order-agnostic comparison over the flattened bins
            ref = np.sort(ref.reshape(ref.shape[0], -1), axis=-1)
            y = np.sort(y.reshape(y.shape[0], -1), axis=-1)
    elif args.transposed and info.get("order") == "transposed":
        ref = y   # digit-permuted; correctness is covered by the test suite
    else:
        ref = fwd(x)
    err = np.abs(y - ref).max() / (np.abs(ref).max() + 1e-30)
    print(f"{args.fft_op} batch={args.batch} N={size_tag} {info} "
          f"{dt*1e3:.2f}ms/req rel_err={err:.2e}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="lm", choices=["lm", "fft"])
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--fft-n", type=int, default=1 << 16)
    ap.add_argument("--fft-shards", type=int, default=None)
    ap.add_argument("--fft-data", type=int, default=1,
                    help="batch-parallel mesh axis size (2-D data x fft mesh)")
    ap.add_argument("--fft-op", default="fft",
                    choices=["fft", "convolve", "correlate", "spectrum"],
                    help="spectral ops stay in transposed order end-to-end")
    ap.add_argument("--fft-dims", type=int, default=1, choices=[1, 2],
                    help="2 serves (batch, rows, cols) grids through the "
                         "multidim subsystem (core.fft.multidim)")
    ap.add_argument("--fft-decomp", default="auto",
                    choices=["auto", "slab", "pencil"],
                    help="multidim decomposition; auto = the "
                         "collective-volume heuristic (choose_decomp)")
    ap.add_argument("--fft-rows", type=int, default=256,
                    help="grid rows for --fft-dims 2")
    ap.add_argument("--fft-cols", type=int, default=256,
                    help="grid cols for --fft-dims 2")
    ap.add_argument("--fft-kernel-n", type=int, default=63,
                    help="kernel length for convolve/correlate")
    ap.add_argument("--fft-groups", type=int, default=None,
                    help="ABFT checksum groups (one tolerated SEU per "
                         "group); default: one group per data shard")
    ap.add_argument("--fft-iters", type=int, default=5)
    ap.add_argument("--transposed", action="store_true",
                    help="keep fft/spectrum output in transposed digit order")
    ap.add_argument("--ft", action="store_true",
                    help="run the sharded two-side ABFT online")
    args = ap.parse_args()

    if args.mode == "fft":
        _main_fft(args)
        return

    cfg = (get_config if args.preset == "full" else get_smoke_config)(
        args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    t0 = time.time()
    toks = decode(model, params, prompts, args.gen)
    dt = time.time() - t0
    rate = args.batch * args.gen / dt
    print(f"generated {toks.shape} in {dt:.2f}s ({rate:.1f} tok/s)")
    print(np.asarray(toks[:, :16]))


if __name__ == "__main__":
    main()
