"""Serving driver: batched greedy decode against the KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --preset tiny \
        --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.configs.base import ParallelConfig, RunConfig
from repro.models import Model
from repro.train import make_serve_step


def decode(model: Model, params, prompts: jax.Array, gen: int,
           max_len: int | None = None):
    """Prefill via repeated decode steps, then generate ``gen`` tokens."""
    cfg = model.cfg
    b, p = prompts.shape
    max_len = max_len or (p + gen)
    run = RunConfig(model=cfg)
    step_fn = jax.jit(make_serve_step(model, run))
    cache = model.init_cache(batch=b, max_len=max_len)
    # teacher-forced prefill (decode-path; exercises the cache end-to-end)
    nxt = prompts[:, :1]
    for i in range(p):
        tok = prompts[:, i:i + 1]
        nxt, cache, _ = step_fn(params, cache, tok, jnp.int32(i))
    out = [nxt]
    for j in range(gen - 1):
        nxt, cache, _ = step_fn(params, cache, nxt, jnp.int32(p + j))
        out.append(nxt)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = (get_config if args.preset == "full" else get_smoke_config)(
        args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    t0 = time.time()
    toks = decode(model, params, prompts, args.gen)
    dt = time.time() - t0
    rate = args.batch * args.gen / dt
    print(f"generated {toks.shape} in {dt:.2f}s ({rate:.1f} tok/s)")
    print(np.asarray(toks[:, :16]))


if __name__ == "__main__":
    main()
