"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before first jax init and only then calls it.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "make_fft_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    return jax.make_mesh((data, model), ("data", "model"))


def make_fft_mesh(shards: int | None = None, data: int = 1):
    """Mesh carrying the ``fft`` signal axis for the distributed transform.

    ``shards`` devices along ``fft`` hold pencils of each signal (see
    core/fft/distributed.py); a leading ``data`` axis batch-parallelizes
    independent transforms — the 2-D batch x pencil composition every entry
    point (distributed_fft/ifft, the spectral consumers, serve --mode fft)
    auto-detects. The multi-dimensional transforms (core/fft/multidim.py)
    reuse the same mesh: slab shards the grid over ``fft`` with the batch
    on ``data``, while the pencil decomposition spends ``data`` on the
    second transform axis, scaling ONE grid over all ``data * shards``
    devices. Defaults to all visible devices on ``fft``.

    Requests that exceed the host shrink gracefully: ``data`` is clamped
    first (dropping batch parallelism costs throughput, not correctness of
    the pencil split), then ``shards`` rounds down to a power of two so the
    default works on 3/5/6-device hosts (spare devices stay idle).
    """
    if data < 1:
        raise ValueError(f"data axis size must be >= 1, got {data}")
    n = len(jax.devices())
    if shards is None:
        shards = max(1, n // data)
    while data > 1 and data * shards > n:
        data //= 2
    if data * shards > n:
        data, shards = 1, n
    # the pencil split needs a power-of-two shard count
    shards = 1 << (shards.bit_length() - 1)
    if data > 1:
        return jax.make_mesh((data, shards), ("data", "fft"))
    return jax.make_mesh((shards,), ("fft",))
