"""Training driver.

Runs real steps on the local devices (CPU smoke / single TPU host) with the
same step function the dry-run proves at 512 chips. Features exercised here:
deterministic restart from the latest checkpoint, async checkpointing, FT
telemetry, straggler-free data (step-addressable pipeline).

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --preset tiny \
        --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint
from repro.configs import get_config, get_smoke_config
from repro.configs.base import ParallelConfig, RunConfig
from repro.data import TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.models import Model
from repro.train import make_train_step


def build(arch: str, preset: str, *, steps: int, batch: int, seq: int,
          lr: float = 3e-4, ft_linears: bool = False):
    if preset == "full":
        cfg = get_config(arch)
    elif preset == "tiny":
        cfg = get_smoke_config(arch)
    elif preset == "lm100m":
        cfg = dataclasses.replace(
            get_smoke_config(arch), name=f"{arch}-100m", num_layers=12,
            d_model=640, num_heads=10, num_kv_heads=2, d_ff=2560,
            vocab_size=32768)
    else:
        raise ValueError(preset)
    if ft_linears:
        cfg = dataclasses.replace(
            cfg, ft=dataclasses.replace(cfg.ft, protect_linears=True))
    run = RunConfig(model=cfg, parallel=ParallelConfig(remat="none"),
                    learning_rate=lr, warmup_steps=max(steps // 10, 5),
                    total_steps=steps)
    return cfg, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--preset", default="tiny",
                    choices=["tiny", "lm100m", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ft-linears", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args()

    cfg, run = build(args.arch, args.preset, steps=args.steps,
                     batch=args.batch, seq=args.seq, lr=args.lr,
                     ft_linears=args.ft_linears)
    model = Model(cfg)
    pipe = TokenPipeline(seed=run.seed, batch=args.batch, seq_len=args.seq,
                         vocab_size=cfg.vocab_size)

    params = model.init(jax.random.PRNGKey(run.seed))
    opt_state = optim.init_state(params)
    start = 0

    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir,
                                keep=cfg.ft.keep_checkpoints)
        last = latest_step(args.ckpt_dir)
        if last is not None:
            (params, opt_state), meta = restore_checkpoint(
                args.ckpt_dir, (params, opt_state))
            start = meta["step"] + 1
            print(f"[restore] resumed from step {meta['step']}")

    step_fn = jax.jit(make_train_step(model, run))
    log = []
    t_start = time.time()
    for step in range(start, args.steps):
        batch = pipe(step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch,
                                             jnp.int32(step))
        if step % args.log_every == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall_s"] = round(time.time() - t_start, 2)
            log.append(m)
            print(f"step {step:5d} loss {m['loss']:.4f} ce {m['ce']:.4f} "
                  f"gnorm {m['grad_norm']:.3f} ft_flagged {m['ft_flagged']:.0f}",
                  flush=True)
        every = args.ckpt_every or cfg.ft.checkpoint_every
        if mgr and every and step and step % every == 0:
            mgr.save(step, (params, opt_state))
    if mgr:
        mgr.save(args.steps - 1, (params, opt_state))
        mgr.wait()
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(log, f, indent=1)
    return log


if __name__ == "__main__":
    main()
