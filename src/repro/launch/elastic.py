"""Elastic restart: resume a run on a different device count / mesh shape.

Checkpoints are stored unsharded (checkpoint/ckpt.py), so elasticity is a
pure re-shard: build the new mesh, recompute param specs against it, and
``jax.device_put`` each restored leaf to its new NamedSharding. Combined with
the step-addressable data pipeline (data/synthetic.py) a job can lose nodes,
restart at N' < N chips, and continue bit-deterministically on the data
stream — the fail-stop half of the paper's fault model at framework scale.

The heartbeat monitor below is the straggler/failure detector: at real scale
each host reports per-step wall time; hosts exceeding ``straggle_factor`` x
the cluster median for ``patience`` steps trigger (1) work re-dispatch (data
is step-addressable, nothing to migrate) and (2) if persistent, an elastic
restart excluding the node.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.checkpoint import restore_checkpoint
from repro.parallel import param_specs

__all__ = ["elastic_restore", "HeartbeatMonitor"]


def elastic_restore(ckpt_dir: str, template, mesh, *, step=None,
                    fsdp: bool = True):
    """Restore (params, opt_state)-shaped ``template`` onto ``mesh``.

    The target mesh is independent of the mesh the checkpoint was written
    under — storage is unsharded, so restoring onto fewer (or more)
    devices is the same ``device_put`` re-shard: params and the
    optimizer's mu/nu follow ``param_specs(mesh)``, the step counter is
    replicated.
    """
    p_specs = param_specs(template[0], mesh, fsdp=fsdp)

    def shard_of(spec):
        return NamedSharding(mesh, spec)

    restored, meta = restore_checkpoint(ckpt_dir, template, step=step)
    params, opt = restored
    params = jax.tree_util.tree_map(
        lambda l, sp: jax.device_put(l, shard_of(sp)), params, p_specs)
    opt = type(opt)(
        step=jax.device_put(opt.step, NamedSharding(
            mesh, jax.sharding.PartitionSpec())),
        mu=jax.tree_util.tree_map(
            lambda l, sp: jax.device_put(l, shard_of(sp)), opt.mu, p_specs),
        nu=jax.tree_util.tree_map(
            lambda l, sp: jax.device_put(l, shard_of(sp)), opt.nu, p_specs),
    )
    return (params, opt), meta


class HeartbeatMonitor:
    """Median-based straggler detection over per-host step times."""

    def __init__(self, num_hosts: int, straggle_factor: float = 2.0,
                 patience: int = 3):
        self.num_hosts = num_hosts
        self.factor = straggle_factor
        self.patience = patience
        self._strikes = np.zeros(num_hosts, dtype=int)

    def observe(self, step_times: np.ndarray) -> list[int]:
        """step_times: (num_hosts,) seconds. Returns hosts flagged for
        exclusion (persistent stragglers)."""
        med = float(np.median(step_times))
        slow = step_times > self.factor * max(med, 1e-9)
        self._strikes = np.where(slow, self._strikes + 1, 0)
        return [int(i) for i in np.nonzero(
            self._strikes >= self.patience)[0]]
