import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and record memory / cost / collective analyses.

This is how the distribution config is proven coherent without hardware:
``.lower().compile()`` must succeed for every cell on the 16x16 (256-chip)
pod mesh AND the 2x16x16 (512-chip) multi-pod mesh. Failures (sharding
mismatch, OOM at compile, unsupported collective) are bugs.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b \
        --shape train_4k [--multi-pod] [--out artifacts/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs import ARCHS, SHAPES, get_config
from repro.configs.base import ParallelConfig, RunConfig
from repro.launch.mesh import make_production_mesh
from repro.models import Model, count_params, model_flops_per_token
from repro.parallel import batch_specs, cache_specs, dp_axes, param_specs
from repro.train import make_prefill_step, make_serve_step, make_train_step

# cells skipped with documented reasons (DESIGN.md §Arch-applicability)
SKIPS = {
    ("qwen1.5-110b", "long_500k"): "pure full attention: 512k KV/layer infeasible",
    ("phi3-medium-14b", "long_500k"): "pure full attention",
    ("phi4-mini-3.8b", "long_500k"): "pure full attention",
    ("internvl2-1b", "long_500k"): "pure full attention backbone",
    ("llama4-maverick-400b-a17b", "long_500k"): "full-attention text variant",
    ("whisper-base", "long_500k"): "decoder context architecturally <=448",
}

CANONICAL = {
    "qwen15_110b": "qwen1.5-110b",
    "phi3_medium_14b": "phi3-medium-14b",
    "phi4_mini_3p8b": "phi4-mini-3.8b",
    "gemma3_1b": "gemma3-1b",
    "internvl2_1b": "internvl2-1b",
    "xlstm_350m": "xlstm-350m",
    "deepseek_v3_671b": "deepseek-v3-671b",
    "llama4_maverick": "llama4-maverick-400b-a17b",
    "recurrentgemma_2b": "recurrentgemma-2b",
    "whisper_base": "whisper-base",
}

# The collective parser lives in repro.analysis.hlo (import-light, shared
# with the plan auditor); these names stay importable here for callers that
# predate the move.
from repro.analysis import hlo as _hlo
from repro.analysis.hlo import (COLLECTIVE_RE, DTYPE_BYTES,  # noqa: F401
                                SHAPE_RE, WIRE_FACTOR)


def _shape_bytes(line: str, op: str, *, is_start: bool = False) -> int:
    """Payload bytes of one collective's result buffers (compat shim over
    :func:`repro.analysis.hlo._result_shapes`)."""
    shapes = _hlo._result_shapes(line, op, is_start=is_start)
    return sum(n * DTYPE_BYTES[dt] for dt, n in shapes)


def collective_bytes(hlo_text: str) -> dict:
    """Per-device wire bytes by collective kind, parsed from the
    post-partitioning HLO (the module is the per-device program).

    Thin compat wrapper over :func:`repro.analysis.hlo.parse_collectives`
    preserving the historical dict shape: ``bytes`` / ``count`` keyed by
    kind, the scalar ``total_bytes``, and ``ops`` — one ``(kind,
    wire_bytes)`` entry per collective in program order, so callers can
    reason about individual transactions (e.g. the exposed-communication
    fraction of a chunked pipeline)."""
    return _hlo.summarize(_hlo.parse_collectives(hlo_text))


# ---------------------------------------------------------------------------
# abstract inputs per (arch, shape)
# ---------------------------------------------------------------------------

def input_specs(cfg, shape, mesh, parallel: ParallelConfig):
    """ShapeDtypeStruct stand-ins (weak-type-correct, sharded, no alloc)."""
    b, s = shape.global_batch, shape.seq_len
    seq_shard = (shape.mode == "decode" and parallel.seq_shard_decode
                 and b < int(np.prod([mesh.shape[a] for a in dp_axes(mesh)])))

    def sds(shp, dt, spec):
        return jax.ShapeDtypeStruct(shp, dt,
                                    sharding=NamedSharding(mesh, spec))

    if shape.mode == "train":
        batch = {}
        s_text = s
        if cfg.frontend == "patch_stub":
            s_text = s - cfg.num_patches
            batch["patch_embeds"] = (b, cfg.num_patches, cfg.frontend_dim,
                                     jnp.float32)
        if cfg.is_encdec:
            batch["frames"] = (b, cfg.max_source_positions, cfg.frontend_dim,
                               jnp.float32)
        batch["tokens"] = (b, s_text, jnp.int32)
        batch["labels"] = (b, s_text, jnp.int32)
        shapes = {k: jax.ShapeDtypeStruct(v[:-1], v[-1])
                  for k, v in batch.items()}
        specs = batch_specs(shapes, mesh)
        return {k: sds(v.shape, v.dtype, specs[k])
                for k, v in shapes.items()}, None

    if shape.mode == "prefill":
        batch = {}
        s_text = s
        if cfg.frontend == "patch_stub":
            s_text = s - cfg.num_patches
            batch["patch_embeds"] = (b, cfg.num_patches, cfg.frontend_dim,
                                     jnp.float32)
        if cfg.is_encdec:
            batch["frames"] = (b, cfg.max_source_positions, cfg.frontend_dim,
                               jnp.float32)
        batch["tokens"] = (b, s_text, jnp.int32)
        shapes = {k: jax.ShapeDtypeStruct(v[:-1], v[-1])
                  for k, v in batch.items()}
        specs = batch_specs(shapes, mesh)
        return {k: sds(v.shape, v.dtype, specs[k])
                for k, v in shapes.items()}, None

    # decode: tokens (B, 1) + cache + scalar position
    model = Model(cfg)
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(batch=b, max_len=s, dtype=jnp.bfloat16))
    c_specs = cache_specs(cache_shape, mesh, seq_shard=seq_shard)
    cache = jax.tree_util.tree_map(
        lambda l, sp: sds(l.shape, l.dtype, sp), cache_shape, c_specs)
    tokens = sds((b, 1), jnp.int32,
                 batch_specs(
                     {"t": jax.ShapeDtypeStruct((b, 1), jnp.int32)},
                     mesh)["t"])
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    return {"tokens": tokens, "pos": pos}, cache


# ---------------------------------------------------------------------------
# one cell
# ---------------------------------------------------------------------------

def _lower_cell(cfg, shape, mesh, parallel):
    """Build abstract inputs and lower the right step fn. No allocation."""
    run = RunConfig(model=cfg, parallel=parallel)
    model = Model(cfg)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_specs = param_specs(params_shape, mesh, fsdp=parallel.fsdp)
    params_abs = jax.tree_util.tree_map(
        lambda l, sp: jax.ShapeDtypeStruct(
            l.shape, l.dtype, sharding=NamedSharding(mesh, sp)),
        params_shape, p_specs)
    n_params = int(sum(int(np.prod(l.shape))
                       for l in jax.tree_util.tree_leaves(params_shape)))
    batch_abs, cache_abs = input_specs(cfg, shape, mesh, parallel)

    with mesh:
        if shape.mode == "train":
            o_spec_tree = param_specs(params_shape, mesh, fsdp=parallel.fsdp)
            mk = lambda tree: jax.tree_util.tree_map(
                lambda l, sp: jax.ShapeDtypeStruct(
                    l.shape, jnp.float32, sharding=NamedSharding(mesh, sp)),
                tree, o_spec_tree)
            opt_abs = optim.AdamWState(
                step=jax.ShapeDtypeStruct(
                    (), jnp.int32, sharding=NamedSharding(mesh, P())),
                mu=mk(params_shape), nu=mk(params_shape))
            step_abs = jax.ShapeDtypeStruct(
                (), jnp.int32, sharding=NamedSharding(mesh, P()))
            fn = make_train_step(model, run)
            lowered = jax.jit(fn).lower(params_abs, opt_abs, batch_abs,
                                        step_abs)
            ntoks = shape.global_batch * shape.seq_len
        elif shape.mode == "prefill":
            fn = make_prefill_step(model, run)
            lowered = jax.jit(fn).lower(params_abs, batch_abs)
            ntoks = shape.global_batch * shape.seq_len
        else:
            fn = make_serve_step(model, run)
            lowered = jax.jit(fn).lower(params_abs, cache_abs,
                                        batch_abs["tokens"],
                                        batch_abs["pos"])
            ntoks = shape.global_batch  # one new token per sequence
    return lowered, ntoks, n_params


def _analyze(compiled) -> dict:
    out: dict = {}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        out["flops"] = float(cost.get("flops", 0.0))
        out["bytes_accessed"] = float(cost.get("bytes accessed", 0.0))
        out["transcendentals"] = float(cost.get("transcendentals", 0.0))
    except Exception as e:  # pragma: no cover
        out["cost_error"] = str(e)
    try:
        hlo = compiled.as_text()
        out["collectives"] = collective_bytes(hlo)
        out["hlo_bytes"] = len(hlo)
    except Exception as e:  # pragma: no cover
        out["collectives"] = {"error": str(e), "total_bytes": 0.0}
    return out


def _period_split(cfg):
    period = len(cfg.block_pattern)
    if cfg.num_experts and cfg.moe_interval > 1:
        period = int(np.lcm(period, cfg.moe_interval))
    s = cfg.first_k_dense if cfg.num_experts else 0
    return period, s


def recurrence_flops(cfg, shape) -> float:
    """Analytic FLOPs of sequential-time recurrences (mlstm/slstm lax.scan
    bodies execute T times but are counted once by XLA's cost model and once
    by the two-point probe). Per-device."""
    from repro.models.transformer import effective_kinds
    kinds = [k.split("|")[0] for k in effective_kinds(cfg)]
    t = shape.seq_len if shape.mode != "decode" else 1
    tokens = shape.global_batch * t
    d = cfg.d_model
    h = cfg.num_heads
    e = cfg.expand_factor * d
    hd_m = e // h
    hd_s = d // h
    per_tok = 0.0
    for k in kinds:
        if k == "mlstm":
            per_tok += h * (5.0 * hd_m * hd_m)
        elif k == "slstm":
            per_tok += 8.0 * d * hd_s
    mult = 3.0 if shape.mode == "train" else 1.0
    return per_tok * tokens * mult


def _two_point_estimate(cfg, shape, mesh, parallel) -> dict | None:
    """Extrapolate per-device cost terms past scan-body undercounting.

    Compile unrolled variants with s+P and s+2P layers; the delta is one
    period's cost, linearly extended to the full depth (incl. tail layers).
    """
    import dataclasses as _dc

    from repro.models.transformer import force_unroll

    if cfg.is_encdec:
        return None  # unrolled already; module numbers are exact
    period, s = _period_split(cfg)
    n_super = (cfg.num_layers - s) // period
    tail = (cfg.num_layers - s) % period
    if n_super <= 1:
        return None
    probes = []
    for mult in (1, 2):
        c = _dc.replace(cfg, num_layers=s + mult * period)
        with force_unroll():
            lowered, _, _ = _lower_cell(c, shape, mesh, parallel)
            compiled = lowered.compile()
        probes.append(_analyze(compiled))
    m1, m2 = probes
    reps = (n_super - 1) + tail / period

    def ext(key):
        a = m1.get(key, 0.0)
        b = m2.get(key, 0.0)
        return a + reps * (b - a)

    coll1 = m1.get("collectives", {})
    coll2 = m2.get("collectives", {})
    ct1 = coll1.get("total_bytes", 0.0)
    ct2 = coll2.get("total_bytes", 0.0)
    per_kind = {}
    for k in WIRE_FACTOR:
        a = coll1.get("bytes", {}).get(k, 0.0)
        b = coll2.get("bytes", {}).get(k, 0.0)
        per_kind[k] = a + reps * (b - a)
    n_dev = float(np.prod(list(mesh.shape.values())))
    est = {
        "flops": ext("flops") + recurrence_flops(cfg, shape) / n_dev,
        "bytes_accessed": ext("bytes_accessed"),
        "transcendentals": ext("transcendentals"),
        "collective_bytes": ct1 + reps * (ct2 - ct1),
        "collective_bytes_by_kind": per_kind,
        "probe_layers": [s + period, s + 2 * period],
        "reps_extrapolated": reps,
        "analytic_recurrence_flops_per_device":
            recurrence_flops(cfg, shape)
            / float(np.prod(list(mesh.shape.values()))),
    }
    return est


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             parallel: ParallelConfig | None = None,
             skip_compile: bool = False, measure: bool = True) -> dict:
    t0 = time.time()
    canonical = CANONICAL.get(arch, arch)
    shape = SHAPES[shape_name]
    rec: dict = {"arch": canonical, "shape": shape_name,
                 "multi_pod": multi_pod, "mode": shape.mode}
    if (canonical, shape_name) in SKIPS:
        rec["status"] = "skipped"
        rec["reason"] = SKIPS[(canonical, shape_name)]
        return rec

    cfg = get_config(arch)
    parallel = parallel or ParallelConfig(multi_pod=multi_pod)
    mesh = make_production_mesh(multi_pod=multi_pod)

    lowered, ntoks, n_params = _lower_cell(cfg, shape, mesh, parallel)
    rec["params"] = n_params
    rec["lower_s"] = round(time.time() - t0, 2)
    if skip_compile:
        rec["status"] = "lowered"
        return rec
    t1 = time.time()
    with mesh:
        compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "bytes_per_device": int(getattr(mem, "temp_size_in_bytes", 0)
                                    + getattr(mem, "argument_size_in_bytes", 0)
                                    + getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
        }
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}
    rec.update({"cost": {}, "collectives": {}})
    a = _analyze(compiled)
    rec["cost"] = {k: a.get(k) for k in ("flops", "bytes_accessed",
                                         "transcendentals")}
    rec["collectives"] = a.get("collectives", {})
    rec["hlo_bytes"] = a.get("hlo_bytes")

    if measure:
        try:
            rec["roofline_est"] = _two_point_estimate(cfg, shape, mesh,
                                                      parallel)
        except Exception as e:
            rec["roofline_est"] = {"error": f"{type(e).__name__}: {e}"}

    rec["tokens_per_step"] = ntoks
    rec["model_flops_per_token"] = model_flops_per_token(cfg, n_params)
    rec["status"] = "ok"
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-compile", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ([False, True] if (args.both_meshes or args.all)
              else [args.multi_pod])
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))
    # smallest models first so early results feed the roofline analysis
    size_rank = {"whisper_base": 0, "xlstm_350m": 1, "gemma3_1b": 2,
                 "internvl2_1b": 3, "recurrentgemma_2b": 4,
                 "phi4_mini_3p8b": 5, "phi3_medium_14b": 6,
                 "qwen15_110b": 7, "llama4_maverick": 8,
                 "deepseek_v3_671b": 9}
    cells.sort(key=lambda c: (size_rank.get(c[0], 99), c[2], c[1]))

    os.makedirs(args.out, exist_ok=True)
    for a, s, mp in cells:
        tag = f"{CANONICAL.get(a, a)}__{s}__{'pod2' if mp else 'pod1'}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            print(f"[skip-done] {tag}")
            continue
        print(f"[run] {tag}", flush=True)
        try:
            # roofline probes are single-pod only (the §Roofline table
            # is single-pod per spec); multi-pod proves the pod axis shards
            rec = run_cell(a, s, multi_pod=mp,
                           skip_compile=args.skip_compile,
                           measure=not mp)
        except Exception as e:
            rec = {"arch": CANONICAL.get(a, a), "shape": s, "multi_pod": mp,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-3000:]}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"  -> {rec['status']} "
              f"(lower={rec.get('lower_s')}s compile={rec.get('compile_s')}s)",
              flush=True)


if __name__ == "__main__":
    main()
