"""Launchers: mesh construction, dry-run, train, serve, elastic restart."""
