"""Attention blocks: GQA (full / local-window / bidirectional / cross) and
DeepSeek-style MLA with compressed KV. Query-chunked score computation keeps
the activation peak at ``block_q * S`` instead of ``S^2`` (this is a perf
feature measured in EXPERIMENTS.md §Perf).

Layouts: x (B, T, D); q (B, T, KH, G, hd); k/v (B, S, KH, hd).
Decode caches: {"k": (B, S, KH, hd), "v": ...} — MLA caches only the latent:
{"ckv": (B, S, r_kv), "kr": (B, S, r_rope)} which is what makes 500k-token
decode feasible for deepseek-v3 (DESIGN.md §4).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import layers
from .layers import dense, dense_init, apply_rope, rope

__all__ = ["make_attn_params", "attention", "make_mla_params",
           "mla_attention", "init_kv_cache", "init_mla_cache"]

NEG_INF = -2.0 ** 30


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------

def _mask(q_pos, k_pos, kind: str, window: int):
    """(..., Tq, Tk) boolean mask. q_pos/k_pos: int32 position vectors."""
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    if kind == "bidir" or kind == "cross":
        return jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    causal = (k <= q) & (k >= 0)  # k < 0 marks unwritten ring-buffer slots
    if kind == "local":
        return causal & (k > q - window)
    return causal


# ---------------------------------------------------------------------------
# GQA core
# ---------------------------------------------------------------------------

def make_attn_params(key, d_model, num_heads, num_kv_heads, head_dim, *,
                     qkv_bias=False, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d_model, num_heads * head_dim), dtype),
        "wk": dense_init(ks[1], (d_model, num_kv_heads * head_dim), dtype),
        "wv": dense_init(ks[2], (d_model, num_kv_heads * head_dim), dtype),
        "wo": dense_init(ks[3], (num_heads * head_dim, d_model), dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
    return p


def _sdpa(q, k, v, q_pos, k_pos, kind, window, block_q, softcap=0.0):
    """Query-chunked scaled dot-product attention.

    q: (B, T, KH, G, hd); k, v: (B, S, KH, hd) -> (B, T, KH, G, hd).

    Local-window chunks are *banded*: each query chunk only reads the
    K/V slice that its window can see (scores cost bq*(bq+window) instead
    of bq*S — a pure mask would still compute the full rectangle; §Perf).
    """
    b, t, kh, g, hd = q.shape
    s = k.shape[1]
    dv = v.shape[-1]  # may differ from hd (MLA: qk dims != v dim)
    scale = 1.0 / np.sqrt(hd)

    def one_chunk(qc, qp, kc, vc, kp):
        # qc: (B, bq, KH, G, hd); kc/vc: (B, Sc, KH, *)
        scores = jnp.einsum("btkgh,bskh->bkgts", qc, kc,
                            preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            scores = jnp.tanh(scores / softcap) * softcap
        m = _mask(qp, kp, kind, window)          # (bq, Sc)
        scores = jnp.where(m[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(vc.dtype)
        return jnp.einsum("bkgts,bskh->btkgh", probs, vc)

    if block_q <= 0 or t <= block_q or t % block_q:
        return one_chunk(q, q_pos, k, v, k_pos)
    # Python-unrolled chunks (not lax.map): keeps every chunk visible to the
    # compiler's cost model and lets XLA schedule/fuse freely; peak memory is
    # still ~one chunk of scores thanks to liveness.
    nchunk = t // block_q
    banded = (kind == "local" and s == t and window < s)
    band = min(s, ((window + block_q + 127) // 128) * 128)
    outs = []
    for i in range(nchunk):
        qc = jax.lax.slice_in_dim(q, i * block_q, (i + 1) * block_q, axis=1)
        pc = jax.lax.slice_in_dim(q_pos, i * block_q, (i + 1) * block_q,
                                  axis=-1)
        if banded:
            lo = max(0, min((i + 1) * block_q - band, s - band))
            kc = jax.lax.slice_in_dim(k, lo, lo + band, axis=1)
            vc = jax.lax.slice_in_dim(v, lo, lo + band, axis=1)
            kp = jax.lax.slice_in_dim(k_pos, lo, lo + band, axis=-1)
        elif kind == "causal" and s == t:
            # causal triangle: chunk i sees only K[0:(i+1)*bq] — halves the
            # score rectangle vs mask-only computation
            hi = (i + 1) * block_q
            kc = jax.lax.slice_in_dim(k, 0, hi, axis=1)
            vc = jax.lax.slice_in_dim(v, 0, hi, axis=1)
            kp = jax.lax.slice_in_dim(k_pos, 0, hi, axis=-1)
        else:
            kc, vc, kp = k, v, k_pos
        outs.append(one_chunk(qc, pc, kc, vc, kp))
    return jnp.concatenate(outs, axis=1)


def attention(params, x, *, cfg, kind: str, positions, cache=None,
              cache_pos=None, kv_source=None, theta=None, use_rope=True,
              block_q=1024, ft=None):
    """GQA attention; returns (out, new_cache).

    * train/prefill: ``cache=None`` — self-attention over x.
    * decode: ``cache`` holds (B, S, KH, hd) K/V; ``cache_pos`` is the scalar
      write index; x has T=1 (or a small chunk).
    * cross-attention: ``kv_source`` supplies the encoder output; cache may
      hold its precomputed K/V.
    """
    b, t, d = x.shape
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kh
    theta = cfg.rope_theta if theta is None else theta

    q = dense({"w": params["wq"], **({"b": params["bq"]} if "bq" in params
                                     else {})}, x, ft=ft)
    q = q.reshape(b, t, kh, g, hd)

    if kind == "cross" and cache is not None and "k" in cache and \
            kv_source is None:
        k, v = cache["k"], cache["v"]
        new_cache = cache
        k_pos = jnp.arange(k.shape[1])
    else:
        src = x if kv_source is None else kv_source
        k = dense({"w": params["wk"], **({"b": params["bk"]} if "bk" in params
                                         else {})}, src, ft=ft)
        v = dense({"w": params["wv"], **({"b": params["bv"]} if "bv" in params
                                         else {})}, src, ft=ft)
        k = k.reshape(b, src.shape[1], kh, hd)
        v = v.reshape(b, src.shape[1], kh, hd)
        if use_rope and kind != "cross":
            # new K entries sit at the same absolute positions as the queries
            k = _rope_kv(k, positions, hd, theta, x.dtype)
        if cache is not None and kind != "cross":
            # Ring-buffer write: windowed caches (local attention) hold only
            # the last `window` entries; full caches degenerate to slot==pos.
            s_c = cache["k"].shape[1]
            slot = cache_pos % s_c
            k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(
                cache["k"].dtype), slot, axis=1)
            v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(
                cache["v"].dtype), slot, axis=1)
            new_cache = {"k": k, "v": v}
            # absolute position held by each ring slot (-ve => unwritten)
            k_pos = cache_pos - (cache_pos - jnp.arange(s_c)) % s_c
        elif kind == "cross":
            new_cache = {"k": k, "v": v}
            k_pos = jnp.arange(k.shape[1])
        else:
            new_cache = None
            k_pos = positions

    if use_rope and kind != "cross":
        qcos, qsin = rope(positions, hd, theta, x.dtype)
        q = apply_rope(q.reshape(b, t, kh * g, hd), qcos[None], qsin[None]
                       ).reshape(b, t, kh, g, hd)

    out = _sdpa(q, k.astype(q.dtype), v.astype(q.dtype),
                positions, k_pos, kind, cfg.window_size, block_q,
                cfg.logit_softcap)
    out = out.reshape(b, t, h * hd)
    out = dense({"w": params["wo"]}, out, ft=ft)
    return out, new_cache


def _rope_kv(k, positions, hd, theta, dtype):
    """Apply rope to K at the given absolute positions."""
    kcos, ksin = rope(positions, hd, theta, dtype)
    b, s, kh, _ = k.shape
    return apply_rope(k.reshape(b, s, kh, hd), kcos[None], ksin[None]
                      ).reshape(b, s, kh, hd)


def init_kv_cache(cfg, batch, max_len, dtype=jnp.bfloat16, layers_shape=()):
    shape = layers_shape + (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2/V3): low-rank compressed KV + decoupled RoPE
# ---------------------------------------------------------------------------

def make_mla_params(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    h = cfg.num_heads
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    p = {}
    if rq:
        p["wq_a"] = dense_init(ks[0], (d, rq), dtype)
        p["q_norm"] = layers.make_norm_params(rq)
        p["wq_b"] = dense_init(ks[1], (rq, h * (dn + dr)), dtype)
    else:
        p["wq"] = dense_init(ks[1], (d, h * (dn + dr)), dtype)
    p["wkv_a"] = dense_init(ks[2], (d, rkv + dr), dtype)
    p["kv_norm"] = layers.make_norm_params(rkv)
    p["wkv_b"] = dense_init(ks[3], (rkv, h * (dn + dv)), dtype)
    p["wo"] = dense_init(ks[4], (h * dv, d), dtype)
    return p


def mla_attention(params, x, *, cfg, positions, cache=None, cache_pos=None,
                  block_q=1024, ft=None):
    """MLA self-attention (causal). Returns (out, new_cache).

    Prefill: reconstructs full K/V from the latent (naive path).
    Decode:  weight-absorbed path — scores and values computed directly
    against the cached latent, O(S * (r_kv + d_rope)) per step.
    """
    b, t, d = x.shape
    h = cfg.num_heads
    rkv = cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    # queries
    if cfg.q_lora_rank:
        qa = dense({"w": params["wq_a"]}, x, ft=ft)
        qa = layers.rmsnorm(params["q_norm"], qa, cfg.norm_eps)
        q = dense({"w": params["wq_b"]}, qa, ft=ft)
    else:
        q = dense({"w": params["wq"]}, x, ft=ft)
    q = q.reshape(b, t, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    qcos, qsin = rope(positions, dr, cfg.rope_theta, x.dtype)
    q_rope = apply_rope(q_rope, qcos[None], qsin[None])

    # latent kv
    kv = dense({"w": params["wkv_a"]}, x, ft=ft)
    ckv, k_rope = kv[..., :rkv], kv[..., rkv:]
    ckv = layers.rmsnorm(params["kv_norm"], ckv, cfg.norm_eps)
    kr_cos, kr_sin = rope(positions, dr, cfg.rope_theta, x.dtype)
    k_rope = apply_rope(k_rope[:, :, None], kr_cos[None], kr_sin[None]
                        )[:, :, 0]

    wkv_b = params["wkv_b"].reshape(rkv, h, dn + dv)
    w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]

    if cache is None:
        # prefill/train: reconstruct per-head K/V (naive path)
        k_nope = jnp.einsum("btr,rhd->bthd", ckv, w_uk.astype(ckv.dtype))
        v = jnp.einsum("btr,rhd->bthd", ckv, w_uv.astype(ckv.dtype))
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                      (b, t, h, dr))], axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = _sdpa(qq[:, :, :, None].reshape(b, t, h, 1, dn + dr),
                    k, v, positions, positions, "causal",
                    cfg.window_size, block_q)
        out = out.reshape(b, t, h * dv)
        new_cache = None
    else:
        # decode: absorbed path against the latent cache
        ckv_c = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), cache_pos, axis=1)
        kr_c = jax.lax.dynamic_update_slice_in_dim(
            cache["kr"], k_rope.astype(cache["kr"].dtype), cache_pos, axis=1)
        new_cache = {"ckv": ckv_c, "kr": kr_c}
        s = ckv_c.shape[1]
        # absorb W_uk into q: (b,t,h,dn) x (r,h,dn) -> (b,t,h,r)
        q_abs = jnp.einsum("bthd,rhd->bthr", q_nope, w_uk.astype(q_nope.dtype))
        scores = (jnp.einsum("bthr,bsr->bhts", q_abs,
                             ckv_c.astype(q_abs.dtype),
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bthd,bsd->bhts", q_rope,
                               kr_c.astype(q_rope.dtype),
                               preferred_element_type=jnp.float32))
        scores = scores / np.sqrt(dn + dr)
        k_pos = jnp.arange(s)
        m = _mask(positions, k_pos, "causal", cfg.window_size)
        scores = jnp.where(m[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        ctx = jnp.einsum("bhts,bsr->bthr", probs, ckv_c.astype(x.dtype))
        out = jnp.einsum("bthr,rhd->bthd", ctx, w_uv.astype(x.dtype))
        out = out.reshape(b, t, h * dv)

    out = dense({"w": params["wo"]}, out, ft=ft)
    return out, new_cache


def init_mla_cache(cfg, batch, max_len, dtype=jnp.bfloat16, layers_shape=()):
    return {
        "ckv": jnp.zeros(layers_shape + (batch, max_len, cfg.kv_lora_rank),
                         dtype),
        "kr": jnp.zeros(layers_shape + (batch, max_len, cfg.qk_rope_head_dim),
                        dtype),
    }
