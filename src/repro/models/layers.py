"""Primitive layers: inits, norms, dense (with optional ABFT protection),
embeddings, RoPE. Pure-functional: params are nested dicts of jax arrays.

Every dense contraction routes through :func:`dense`, which consults the
model's FT policy — when ``protect_linears`` is on, the product is computed
through the paper's two-sided ABFT via the cached GEMM plan layer
(``core.gemm``) so compute SEUs in any projection are detected and
corrected online.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ft import FTPolicy

__all__ = ["truncated_normal", "rmsnorm", "layernorm", "make_norm_params",
           "dense", "make_dense_params", "embed", "rope", "apply_rope",
           "swiglu", "gelu_mlp", "make_mlp_params", "mlp", "FTContext"]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def truncated_normal(key, shape, scale, dtype=jnp.float32):
    stddev = scale / np.sqrt(max(shape[0], 1) if len(shape) > 1 else 1.0)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * stddev).astype(dtype)


def _fan_in(shape: Sequence[int], contract_dims: int = 1) -> float:
    f = 1
    for s in shape[:contract_dims]:
        f *= s
    return float(f)


def dense_init(key, shape, dtype=jnp.float32, contract_dims: int = 1):
    std = 1.0 / np.sqrt(_fan_in(shape, contract_dims))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


# ---------------------------------------------------------------------------
# FT context — threads detection counters out of functional layers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FTContext:
    """Mutable-during-trace accumulator for ABFT stats (functionally pure:
    entries are traced arrays collected during apply and summed by caller).

    Each protected matmul routes through :meth:`matmul` — the shared GEMM
    plan layer (``core.gemm``) built from this context's policy. ``inject``
    optionally carries a traced fault descriptor ``(5,)`` / ``(F, 5)`` rows
    ``[site, row, col, enable, eps]``: every protected matmul takes the next
    *site* number (trace order) and arms only descriptors whose site
    matches, so one fixed program can fault any layer. Under scanned layer
    super-blocks the trace runs once per block, so a site addresses that
    position in EVERY scanned block.
    """

    policy: FTPolicy
    flagged: list = dataclasses.field(default_factory=list)
    corrected: list = dataclasses.field(default_factory=list)
    scores: list = dataclasses.field(default_factory=list)
    inject: jax.Array | None = None
    sites: int = 0

    @property
    def enabled(self) -> bool:
        return self.policy is not None and self.policy.protect_linears

    def take_inject(self) -> jax.Array | None:
        """Next site's ``(F, 4)`` ``[row, col, enable, eps]`` descriptor
        (``None`` when no schedule is armed). Advances the site counter."""
        site = self.sites
        self.sites += 1
        if self.inject is None:
            return None
        d = jnp.atleast_2d(jnp.asarray(self.inject, jnp.float32))
        enable = d[:, 3] * (d[:, 0] == site).astype(jnp.float32)
        return jnp.stack([d[:, 1], d[:, 2], enable, d[:, 4]], axis=-1)

    def matmul(self, x2: jax.Array, w: jax.Array) -> jax.Array:
        """Checked ``x2 @ w`` through the cached GEMM plan; records stats."""
        from repro.core import gemm  # local: keep layers importable alone

        spec = gemm.spec_for(x2, w, ft=self.policy.to_ft_config(),
                             backend=self.policy.gemm_backend)
        y, stats = gemm.plan(spec).ft_matmul(x2, w,
                                             inject=self.take_inject())
        self.record(stats)
        return y

    def record(self, stats: dict):
        self.flagged.append(stats["flagged"])
        self.corrected.append(stats.get("corrected",
                                        jnp.zeros((), jnp.float32)))
        self.scores.append(stats["score"])

    def summary(self) -> dict:
        if not self.flagged:
            z = jnp.zeros((), jnp.float32)
            return {"ft_flagged": z, "ft_corrected": z, "ft_max_score": z}
        # entries may mix scalars with per-expert (e,) vectors — reduce each
        # before stacking
        return {
            "ft_flagged": jnp.sum(jnp.stack(
                [jnp.sum(f) for f in self.flagged])),
            "ft_corrected": jnp.sum(jnp.stack(
                [jnp.sum(c) for c in self.corrected])),
            "ft_max_score": jnp.max(jnp.stack(
                [jnp.max(s) for s in self.scores])),
        }


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def make_norm_params(d: int, kind: str = "rmsnorm") -> dict:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def rmsnorm(params, x, eps=1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm(params, x, eps=1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(
        jnp.float32)
    return y.astype(dt)


def norm(params, x, kind: str = "rmsnorm", eps: float = 1e-6):
    return rmsnorm(params, x, eps) if kind == "rmsnorm" else layernorm(
        params, x, eps)


# ---------------------------------------------------------------------------
# dense / embedding
# ---------------------------------------------------------------------------

def make_dense_params(key, d_in, d_out, *, bias=False,
                      dtype=jnp.float32) -> dict:
    p = {"w": dense_init(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(params, x, *, ft: FTContext | None = None):
    """y = x @ w (+ b), optionally through two-sided ABFT (paper's scheme)
    via the shared GEMM plan layer (``core.gemm``)."""
    w = params["w"]
    if ft is not None and ft.enabled and x.ndim >= 2 and w.ndim == 2:
        lead = x.shape[:-1]
        x2 = x.reshape((-1, x.shape[-1]))
        y2 = ft.matmul(x2, w)
        y = y2.reshape(lead + (w.shape[-1],))
    else:
        y = jnp.einsum("...k,kd->...d", x, w.astype(x.dtype))
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def embed(params, tokens, dtype):
    return jnp.take(params["embedding"], tokens, axis=0).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(positions, head_dim, theta, dtype=jnp.float32):
    """Rotary embedding tables. positions: (...,) -> (..., head_dim/2) each."""
    half = head_dim // 2
    freqs = (1.0 / theta) ** (jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, cos, sin):
    """x: (..., T, H, D) with tables (..., T, D/2), broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def make_mlp_params(key, d, d_ff, act: str, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "wi_gate": dense_init(ks[0], (d, d_ff), dtype),
            "wi_up": dense_init(ks[1], (d, d_ff), dtype),
            "wo": dense_init(ks[2], (d_ff, d), dtype),
        }
    return {
        "wi": dense_init(ks[0], (d, d_ff), dtype),
        "wo": dense_init(ks[1], (d_ff, d), dtype),
    }


def swiglu(params, x, *, ft=None):
    g = dense({"w": params["wi_gate"]}, x, ft=ft)
    u = dense({"w": params["wi_up"]}, x, ft=ft)
    h = jax.nn.silu(g) * u
    return dense({"w": params["wo"]}, h, ft=ft)


def gelu_mlp(params, x, *, ft=None):
    h = jax.nn.gelu(dense({"w": params["wi"]}, x, ft=ft))
    return dense({"w": params["wo"]}, h, ft=ft)


def mlp(params, x, act: str, *, ft=None):
    return swiglu(params, x, ft=ft) if act == "swiglu" else gelu_mlp(
        params, x, ft=ft)
