"""Block assembly + scan-stack machinery.

A *block* = pre-norm mixer (attention family / recurrent family) + pre-norm
FFN (dense or MoE). Layers are grouped into (prefix, scanned super-blocks,
tail): contiguous homogeneous layer patterns are stacked and executed with
``jax.lax.scan`` so an 80-layer model compiles as one loop — essential to
keep SPMD compile times sane at 512 devices.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import attention, layers, moe, ssm
from .layers import FTContext

__all__ = ["effective_kinds", "layer_groups", "make_block_params",
           "block_apply", "init_block_state", "LayerGroups"]


ATTN_KINDS = ("attn", "local", "global", "mla", "bidir")
RECURRENT_KINDS = ("rglru", "mlstm", "slstm")


def effective_kinds(cfg) -> tuple[str, ...]:
    """Per-layer 'mixer|ffn' descriptors, e.g. 'attn|moe', 'rglru|mlp'."""
    kinds = []
    pat = cfg.block_pattern
    for i in range(cfg.num_layers):
        base = pat[i % len(pat)]
        if base in RECURRENT_KINDS and base != "rglru":
            ffn = "none"          # xLSTM blocks integrate their FFN
        elif base == "rglru":
            ffn = "mlp"
        else:
            ffn = "moe" if cfg.is_moe_layer(i) else "mlp"
        kinds.append(f"{base}|{ffn}")
    return tuple(kinds)


@dataclasses.dataclass(frozen=True)
class LayerGroups:
    prefix: tuple[str, ...]          # unrolled leading layer kinds
    super_block: tuple[str, ...]     # kinds within one scanned super-block
    n_super: int                     # number of scanned super-blocks
    tail: tuple[str, ...]            # unrolled trailing layer kinds

    @property
    def total(self) -> int:
        return (len(self.prefix) + len(self.super_block) * self.n_super
                + len(self.tail))


# When True, layer_groups unrolls everything (no lax.scan). Used by the
# dry-run's two-point cost measurement: XLA's cost analysis counts while-loop
# bodies ONCE, so roofline FLOPs are extrapolated from small unrolled
# variants (see launch/dryrun.py) while the full scanned model is what
# actually compiles/ships.
FORCE_UNROLL = False


class force_unroll:
    def __enter__(self):
        global FORCE_UNROLL
        self._old = FORCE_UNROLL
        FORCE_UNROLL = True

    def __exit__(self, *a):
        global FORCE_UNROLL
        FORCE_UNROLL = self._old


def layer_groups(cfg) -> LayerGroups:
    kinds = effective_kinds(cfg)
    n = len(kinds)
    # leading layers that break the periodic pattern (deepseek first-k-dense)
    period = len(cfg.block_pattern)
    if cfg.num_experts and cfg.moe_interval > 1:
        period = int(np.lcm(period, cfg.moe_interval))
    s = cfg.first_k_dense if cfg.num_experts else 0
    rest = n - s
    n_super = rest // period
    tail_len = rest % period
    if FORCE_UNROLL or n_super <= 1:  # not worth scanning
        return LayerGroups(prefix=kinds, super_block=(), n_super=0, tail=())
    return LayerGroups(
        prefix=kinds[:s],
        super_block=kinds[s:s + period],
        n_super=n_super,
        tail=kinds[s + period * n_super:] if tail_len else (),
    )


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def make_block_params(key, cfg, kind: str, dtype=jnp.float32) -> dict:
    base, ffn = kind.split("|")
    ks = jax.random.split(key, 4)
    p: dict = {"norm1": layers.make_norm_params(cfg.d_model, cfg.norm)}
    if base in ("attn", "local", "global", "bidir"):
        p["attn"] = attention.make_attn_params(
            ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.head_dim, qkv_bias=cfg.qkv_bias, dtype=dtype)
    elif base == "mla":
        p["attn"] = attention.make_mla_params(ks[0], cfg, dtype)
    elif base == "rglru":
        p["mixer"] = ssm.make_rglru_params(ks[0], cfg, dtype)
    elif base == "mlstm":
        p["mixer"] = ssm.make_mlstm_params(ks[0], cfg, dtype)
    elif base == "slstm":
        p["mixer"] = ssm.make_slstm_params(ks[0], cfg, dtype)
    else:
        raise ValueError(base)
    if ffn == "mlp":
        dff = cfg.dense_d_ff or cfg.d_ff
        p["norm2"] = layers.make_norm_params(cfg.d_model, cfg.norm)
        p["mlp"] = layers.make_mlp_params(ks[1], cfg.d_model, dff, cfg.act,
                                          dtype)
    elif ffn == "moe":
        p["norm2"] = layers.make_norm_params(cfg.d_model, cfg.norm)
        p["moe"] = moe.make_moe_params(ks[1], cfg, dtype)
    return p


def _ffn_dff(cfg, kind):
    base, ffn = kind.split("|")
    return (cfg.dense_d_ff or cfg.d_ff) if ffn == "mlp" else cfg.moe_d_ff


def block_apply(params, x, *, cfg, kind: str, positions=None, cache=None,
                cache_pos=None, block_q=1024, ftp=None, inject=None):
    """One transformer block. Returns (y, new_cache, aux_dict).

    ``inject`` is an optional traced fault descriptor ``(F, 5)``
    ``[site, row, col, enable, eps]`` armed against this block's protected
    matmuls (site = matmul index within the block, trace order) — see
    :class:`FTContext`.
    """
    base, ffn = kind.split("|")
    ft = (FTContext(ftp, inject=inject)
          if (ftp is not None and ftp.protect_linears) else None)
    aux = {"moe_aux": jnp.zeros((), jnp.float32)}

    h = layers.norm(params["norm1"], x, cfg.norm, cfg.norm_eps)
    if base in ("attn", "local", "global", "bidir"):
        theta = (cfg.rope_theta_global if base == "global"
                 else cfg.rope_theta)
        mix, new_cache = attention.attention(
            params["attn"], h, cfg=cfg,
            kind={"attn": "causal", "global": "causal"}.get(base, base),
            positions=positions, cache=cache, cache_pos=cache_pos,
            theta=theta, block_q=block_q, ft=ft)
    elif base == "mla":
        mix, new_cache = attention.mla_attention(
            params["attn"], h, cfg=cfg, positions=positions, cache=cache,
            cache_pos=cache_pos, block_q=block_q, ft=ft)
    elif base == "rglru":
        mix, new_cache = ssm.rglru_block(params["mixer"], h, state=cache,
                                         ft=ft)
    elif base == "mlstm":
        mix, new_cache = ssm.mlstm_block(params["mixer"], h, cfg=cfg,
                                         state=cache, ft=ft)
    elif base == "slstm":
        mix, new_cache = ssm.slstm_block(params["mixer"], h, cfg=cfg,
                                         state=cache, ft=ft)
    else:
        raise ValueError(base)
    x = x + mix

    if ffn == "mlp":
        h = layers.norm(params["norm2"], x, cfg.norm, cfg.norm_eps)
        x = x + layers.mlp(params["mlp"], h, cfg.act, ft=ft)
    elif ffn == "moe":
        h = layers.norm(params["norm2"], x, cfg.norm, cfg.norm_eps)
        y, moe_aux = moe.moe_block(params["moe"], h, cfg, ft=ft)
        x = x + y
        aux["moe_aux"] = moe_aux

    if ft is not None:
        aux.update(ft.summary())
    else:
        aux.update({"ft_flagged": jnp.zeros((), jnp.float32),
                    "ft_corrected": jnp.zeros((), jnp.float32),
                    "ft_max_score": jnp.zeros((), jnp.float32)})
    return x, new_cache, aux


def init_block_state(cfg, kind: str, batch: int, max_len: int,
                     dtype=jnp.bfloat16):
    """Decode-time cache/state for one block (None for stateless kinds)."""
    base, _ = kind.split("|")
    if base in ("attn", "global", "bidir"):
        return attention.init_kv_cache(cfg, batch, max_len, dtype)
    if base == "local":
        return attention.init_kv_cache(cfg, batch,
                                       min(max_len, cfg.window_size), dtype)
    if base == "mla":
        return attention.init_mla_cache(cfg, batch, max_len, dtype)
    if base == "rglru":
        return ssm.init_rglru_state(cfg, batch, dtype)
    if base == "mlstm":
        return ssm.init_mlstm_state(cfg, batch, dtype)
    if base == "slstm":
        return ssm.init_slstm_state(cfg, batch, dtype)
    raise ValueError(base)
