"""Recurrent blocks: RG-LRU (RecurrentGemma/Griffin), mLSTM + sLSTM (xLSTM).

RG-LRU uses ``jax.lax.associative_scan`` (vector state -> materializing all T
states is cheap). The LSTM variants keep exact sequential semantics with
``jax.lax.scan`` — the xLSTM chunkwise-parallel form is a documented future
kernel (DESIGN.md); FLOPs are identical, only MXU utilization differs.

All blocks expose a decode path carrying an explicit recurrent state, which
is what makes the 500k-token decode shape O(1) memory per step for these
architectures.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import layers
from .layers import dense, dense_init

__all__ = [
    "make_rglru_params", "rglru_block", "init_rglru_state",
    "make_mlstm_params", "mlstm_block", "init_mlstm_state",
    "make_slstm_params", "slstm_block", "init_slstm_state",
]

_C = 8.0  # RG-LRU decay sharpness constant (Griffin)


# ---------------------------------------------------------------------------
# causal depthwise conv1d (shared by rglru / mlstm)
# ---------------------------------------------------------------------------

def _causal_conv(x, w, state=None):
    """x: (B, T, C), w: (K, C) depthwise. state: (B, K-1, C) carry or None.

    Returns (y, new_state). Train path pads with zeros; decode path uses the
    carried last K-1 inputs.
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else None
    return y, new_state


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma)
# ---------------------------------------------------------------------------

def make_rglru_params(key, cfg, dtype=jnp.float32):
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 7)
    return {
        "w_in_gate": dense_init(ks[0], (d, w), dtype),    # gelu branch
        "w_in_rec": dense_init(ks[1], (d, w), dtype),     # recurrent branch
        "conv_w": dense_init(ks[2], (cfg.conv1d_width, w), dtype),
        "w_a": dense_init(ks[3], (w, w), dtype),          # recurrence gate
        "w_x": dense_init(ks[4], (w, w), dtype),          # input gate
        # Lambda init: softplus(lam) in [2, 6] -> decay a in ~[0.86, 0.999]
        "lam": jnp.asarray(np.linspace(2.0, 6.0, w), jnp.float32),
        "w_out": dense_init(ks[5], (w, d), dtype),
    }


def _rglru_coeffs(params, u):
    """u: (B, T, W) conv output -> (a, b) recurrence coefficients (f32)."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(dense({"w": params["w_a"]}, uf))
    i = jax.nn.sigmoid(dense({"w": params["w_x"]}, uf))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)
    return a, b


def rglru_block(params, x, *, state=None, ft=None):
    """Griffin recurrent block. x: (B, T, D) -> (y, new_state).

    state: None (train) or {"h": (B, W), "conv": (B, K-1, W)} (decode).
    """
    gate = jax.nn.gelu(dense({"w": params["w_in_gate"]}, x, ft=ft))
    u = dense({"w": params["w_in_rec"]}, x, ft=ft)
    conv_state = None if state is None else state["conv"]
    u, new_conv = _causal_conv(u, params["conv_w"], conv_state)
    a, b = _rglru_coeffs(params, u)

    if state is None:
        # associative scan over time: h_t = a_t h_{t-1} + b_t
        def comb(l, r):
            return (r[0] * l[0], r[0] * l[1] + r[1])
        _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
        new_state = None
    else:
        h_prev = state["h"].astype(jnp.float32)
        hs = []
        h = h_prev
        for t in range(x.shape[1]):  # decode: t is 1 (or tiny), unrolled
            h = a[:, t] * h + b[:, t]
            hs.append(h)
        h = jnp.stack(hs, axis=1)
        new_state = {"h": h[:, -1].astype(state["h"].dtype),
                     "conv": new_conv.astype(state["conv"].dtype)}
    y = dense({"w": params["w_out"]}, (h.astype(x.dtype) * gate), ft=ft)
    return y, new_state


def init_rglru_state(cfg, batch, dtype=jnp.bfloat16, layers_shape=()):
    w = cfg.lru_width
    return {
        "h": jnp.zeros(layers_shape + (batch, w), jnp.float32),
        "conv": jnp.zeros(layers_shape + (batch, cfg.conv1d_width - 1, w),
                          dtype),
    }


# ---------------------------------------------------------------------------
# mLSTM (xLSTM): matrix-memory cell, exponential gating, m-stabilized
# ---------------------------------------------------------------------------

def make_mlstm_params(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    e = cfg.expand_factor * d
    h = cfg.num_heads
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], (d, 2 * e), dtype),
        "conv_w": dense_init(ks[1], (cfg.conv1d_width, e), dtype),
        "wq": dense_init(ks[2], (e, e), dtype),
        "wk": dense_init(ks[3], (e, e), dtype),
        "wv": dense_init(ks[4], (e, e), dtype),
        "w_ig": dense_init(ks[5], (e, h), jnp.float32),
        "w_fg": dense_init(ks[6], (e, h), jnp.float32),
        "fg_bias": jnp.full((h,), 4.0, jnp.float32),  # open forget gates
        "out_norm": layers.make_norm_params(e),
        "w_down": dense_init(ks[7], (e, d), dtype),
    }


def _mlstm_cell_scan(q, k, v, logi, logf, c0, n0, m0):
    """Exact sequential mLSTM over time (f32 state, m-stabilized).

    q,k,v: (B, T, H, hd); logi, logf: (B, T, H).
    state: C (B, H, hd, hd), n (B, H, hd), m (B, H).
    """
    hd = q.shape[-1]
    scale = 1.0 / np.sqrt(hd)

    def step(carry, xs):
        c, n, m = carry
        qt, kt, vt, li, lf = xs  # (B, H, hd), ..., (B, H)
        m_new = jnp.maximum(lf + m, li)
        fg = jnp.exp(lf + m - m_new)[..., None]
        ig = jnp.exp(li - m_new)[..., None]
        c = fg[..., None] * c + ig[..., None] * (kt[..., :, None] *
                                                 vt[..., None, :])
        n = fg * n + ig * kt
        num = jnp.einsum("bhd,bhde->bhe", qt * scale, c)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", qt * scale, n))
        den = jnp.maximum(den, jnp.exp(-m_new))[..., None]
        out = num / den
        return (c, n, m_new), out

    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          logi.swapaxes(0, 1), logf.swapaxes(0, 1))
    (c, n, m), out = jax.lax.scan(step, (c0, n0, m0), xs)
    return out.swapaxes(0, 1), (c, n, m)


def mlstm_block(params, x, *, cfg, state=None, ft=None):
    """x: (B, T, D) -> (y, new_state). state carries (C, n, m, conv)."""
    b, t, d = x.shape
    e = cfg.expand_factor * d
    h = cfg.num_heads
    hd = e // h

    up = dense({"w": params["w_up"]}, x, ft=ft)
    xm, xz = up[..., :e], up[..., e:]
    conv_state = None if state is None else state["conv"]
    xc, new_conv = _causal_conv(xm, params["conv_w"], conv_state)
    xc = jax.nn.silu(xc)

    q = dense({"w": params["wq"]}, xc, ft=ft).reshape(b, t, h, hd)
    k = dense({"w": params["wk"]}, xc, ft=ft).reshape(b, t, h, hd)
    v = dense({"w": params["wv"]}, xm, ft=ft).reshape(b, t, h, hd)
    logi = (xc.astype(jnp.float32) @ params["w_ig"])
    logf = jax.nn.log_sigmoid(xc.astype(jnp.float32) @ params["w_fg"]
                              + params["fg_bias"])

    if state is None:
        c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, h, hd), jnp.float32)
        m0 = jnp.zeros((b, h), jnp.float32)
    else:
        c0, n0, m0 = (state["c"].astype(jnp.float32),
                      state["n"].astype(jnp.float32),
                      state["m"].astype(jnp.float32))
    out, (c, n, m) = _mlstm_cell_scan(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        logi, logf, c0, n0, m0)
    out = out.reshape(b, t, e).astype(x.dtype)
    out = layers.rmsnorm(params["out_norm"], out, cfg.norm_eps)
    out = out * jax.nn.silu(xz)
    y = dense({"w": params["w_down"]}, out, ft=ft)
    new_state = None
    if state is not None:
        new_state = {"c": c, "n": n, "m": m,
                     "conv": new_conv.astype(state["conv"].dtype)}
    return y, new_state


def init_mlstm_state(cfg, batch, dtype=jnp.bfloat16, layers_shape=()):
    e = cfg.expand_factor * cfg.d_model
    h = cfg.num_heads
    hd = e // h
    return {
        "c": jnp.zeros(layers_shape + (batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros(layers_shape + (batch, h, hd), jnp.float32),
        "m": jnp.zeros(layers_shape + (batch, h), jnp.float32),
        "conv": jnp.zeros(layers_shape + (batch, cfg.conv1d_width - 1, e),
                          dtype),
    }


# ---------------------------------------------------------------------------
# sLSTM (xLSTM): scalar-memory cell with block-diagonal recurrence
# ---------------------------------------------------------------------------

def make_slstm_params(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    ks = jax.random.split(key, 10)
    ffs = int(round(d * 4 / 3 / 64)) * 64
    p = {}
    for j, gate in enumerate(("i", "f", "z", "o")):
        p[f"w_{gate}"] = dense_init(ks[j], (d, d), dtype)
        p[f"r_{gate}"] = dense_init(ks[4 + j], (h, hd, hd), dtype)
    p["f_bias"] = jnp.full((d,), 4.0, jnp.float32)
    p["out_norm"] = layers.make_norm_params(d)
    p["ffn"] = layers.make_mlp_params(ks[8], d, ffs, "swiglu", dtype)
    return p


def slstm_block(params, x, *, cfg, state=None, ft=None):
    """x: (B, T, D) -> (y, new_state). Strictly sequential (h->h recurrence)."""
    b, t, d = x.shape
    h = cfg.num_heads
    hd = d // h

    wi = dense({"w": params["w_i"]}, x, ft=ft).astype(jnp.float32)
    wf = (dense({"w": params["w_f"]}, x, ft=ft).astype(jnp.float32)
          + params["f_bias"])
    wz = dense({"w": params["w_z"]}, x, ft=ft).astype(jnp.float32)
    wo = dense({"w": params["w_o"]}, x, ft=ft).astype(jnp.float32)

    if state is None:
        hidden = jnp.zeros((b, d), jnp.float32)
        cell = jnp.zeros((b, d), jnp.float32)
        norm = jnp.zeros((b, d), jnp.float32)
        stab = jnp.zeros((b, d), jnp.float32)
    else:
        hidden, cell, norm, stab = (state[k].astype(jnp.float32)
                                    for k in ("h", "c", "n", "m"))

    rw = {g: params[f"r_{g}"].astype(jnp.float32) for g in "ifzo"}

    def rmat(hprev, g):
        hh = hprev.reshape(b, h, hd)
        return jnp.einsum("bhd,hde->bhe", hh, rw[g]).reshape(b, d)

    def step(carry, xs):
        hprev, c, n, m = carry
        xi, xf, xz, xo = xs
        it = xi + rmat(hprev, "i")
        ftg = xf + rmat(hprev, "f")
        zt = jnp.tanh(xz + rmat(hprev, "z"))
        ot = jax.nn.sigmoid(xo + rmat(hprev, "o"))
        logf = jax.nn.log_sigmoid(ftg)
        m_new = jnp.maximum(logf + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c = f_s * c + i_s * zt
        n = f_s * n + i_s
        hnew = ot * c / jnp.maximum(n, 1.0)
        return (hnew, c, n, m_new), hnew

    xs = (wi.swapaxes(0, 1), wf.swapaxes(0, 1), wz.swapaxes(0, 1),
          wo.swapaxes(0, 1))
    (hidden, cell, norm, stab), hs = jax.lax.scan(
        step, (hidden, cell, norm, stab), xs)
    out = hs.swapaxes(0, 1).astype(x.dtype)
    out = layers.rmsnorm(params["out_norm"], out, cfg.norm_eps)
    # cell output + its gated FFN (caller adds the outer residual)
    y = out + layers.swiglu(params["ffn"], out, ft=ft)
    new_state = None
    if state is not None:
        new_state = {"h": hidden, "c": cell, "n": norm, "m": stab}
    return y, new_state


def init_slstm_state(cfg, batch, dtype=jnp.bfloat16, layers_shape=()):
    d = cfg.d_model
    z = lambda: jnp.zeros(layers_shape + (batch, d), jnp.float32)
    return {"h": z(), "c": z(), "n": z(), "m": z()}
