"""Mixture-of-Experts: top-k routing with sort-based capacity dispatch.

Two dispatch paths:

* ``moe_block`` (portable): argsort tokens by expert, scatter into an
  (E, C, d) buffer. Compiles everywhere but the data-dependent scatter makes
  the SPMD partitioner re-replicate the buffer — measured at ~169 TB/device
  of all-reduce for deepseek-v3 train_4k (EXPERIMENTS.md §Perf baseline).
* ``moe_block_ep`` (production): explicit expert parallelism via shard_map
  over the ``model`` axis. Activations are replicated across ``model`` under
  our layout, so each device routes its *local* tokens to its *local*
  E/|model| experts with a purely local sort/scatter, and expert outputs are
  combined with one psum — wire cost drops from O(E*C*d) scatter resharding
  to exactly one (T_local, d) all-reduce per MoE layer. Used automatically
  when a mesh with a ``model`` axis is active.

Shared experts (deepseek) run densely on every token.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import abft

from . import layers
from .layers import dense_init

__all__ = ["make_moe_params", "moe_block", "moe_block_ep",
           "aux_load_balance_loss"]


def _ft_expert_matmul(buf, w, threshold, correct):
    """Per-expert checked GEMMs: vmap the two-side ABFT matmul over the
    expert axis. buf: (e, c, d) @ w: (e, d, f) -> ((e, c, f), stats with
    (e,) leaves). Batched weights are per-expert plans, so this rides the
    XLA interpreter path directly (the fused kernel takes one weight)."""
    return jax.vmap(lambda b2, w2: abft.ft_matmul(
        b2, w2, threshold=threshold, with_correction=correct))(buf, w)


def _merge_expert_stats(*stats_dicts):
    """Sum the count leaves / max the score across the three expert GEMMs
    (leaves stay (e,) vectors; FTContext.summary reduces them)."""
    out = {}
    for k in stats_dicts[0]:
        vals = [s[k] for s in stats_dicts]
        out[k] = (functools.reduce(jnp.maximum, vals) if k == "score"
                  else sum(vals))
    return out


def make_moe_params(key, cfg, dtype=jnp.float32):
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "wi_gate": dense_init(ks[1], (e, d, f), dtype, ),
        "wi_up": dense_init(ks[2], (e, d, f), dtype),
        "wo": dense_init(ks[3], (e, f, d), dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = layers.make_mlp_params(
            ks[4], d, cfg.moe_d_ff * cfg.num_shared_experts, "swiglu", dtype)
    return p


def _dispatch_compute(xf, gate_vals, gate_idx, wg, wu, wo, cap, e, *,
                      dtype, ft_args=None):
    """Sort-based capacity dispatch + expert FFN + combine (local arrays).

    xf: (T, d); gate_idx/vals: (T, k); wg/wu: (e, d, f); wo: (e, f, d).
    Expert ids in gate_idx are in [0, e) (caller rebases for EP shards;
    out-of-range ids are dropped by the capacity mask).

    ``ft_args = (threshold, correct)`` routes the three expert GEMMs
    through the two-side ABFT; returns ``(y, stats)`` with stats ``None``
    when unprotected.
    """
    t, d = xf.shape
    k = gate_idx.shape[-1]
    flat_e = jnp.clip(gate_idx.reshape(-1), 0, e)        # e == drop bucket
    valid = gate_idx.reshape(-1) == flat_e
    flat_e = jnp.where(valid, flat_e, e)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    pos_in_e = jnp.arange(t * k) - first[jnp.clip(sorted_e, 0, e - 1)]
    keep = (pos_in_e < cap) & (sorted_e < e)
    dest = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)
    src_token = order // k

    buf = jnp.zeros((e * cap + 1, d), dtype)
    buf = buf.at[dest].set(xf.astype(dtype)[src_token], mode="drop")
    buf = buf[:-1].reshape(e, cap, d)

    if ft_args is not None:
        threshold, correct = ft_args
        gate, s1 = _ft_expert_matmul(buf, wg, threshold, correct)
        up, s2 = _ft_expert_matmul(buf, wu, threshold, correct)
        act = jax.nn.silu(gate) * up
        out_buf, s3 = _ft_expert_matmul(act, wo, threshold, correct)
        stats = _merge_expert_stats(s1, s2, s3)
    else:
        gate = jnp.einsum("ecd,edf->ecf", buf, wg.astype(dtype))
        up = jnp.einsum("ecd,edf->ecf", buf, wu.astype(dtype))
        act = jax.nn.silu(gate) * up
        out_buf = jnp.einsum("ecf,efd->ecd", act, wo.astype(dtype))
        stats = None

    out_flat = out_buf.reshape(e * cap, d)
    gathered = jnp.where(keep[:, None],
                         out_flat[jnp.clip(dest, 0, e * cap - 1)], 0.0)
    unsort = jnp.argsort(order)
    contrib = gathered[unsort].reshape(t, k, d)
    return jnp.einsum("tkd,tk->td", contrib, gate_vals.astype(dtype)), stats


def moe_block_ep(params, x, cfg, mesh, *, ft=None):
    """Expert-parallel MoE: shard_map over the ``model`` axis.

    Each device handles E/|model| experts for its local tokens; combine is
    one psum. Router runs replicated (it is O(T*E), negligible).
    """
    b, t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    m_size = mesh.shape["model"]
    e_local = e // m_size
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    # per-device tokens after dp sharding of the batch; small decode batches
    # (long_500k: B=1) replicate over dp instead
    import numpy as _np
    dp_size = int(_np.prod([mesh.shape[a] for a in dp])) if dp else 1
    if b % max(dp_size, 1):
        dp = ()
        dp_size = 1
    tokens_local = max(b // max(dp_size, 1), 1) * t
    cap = max(int(np.ceil(tokens_local * k / e * cfg.capacity_factor)), 8)

    # traced arrays cannot escape the shard_map closure via FTContext.record
    # — when protected, the local stats come back as extra psum'd outputs
    # (only then, so the unprotected path's collective count is unchanged)
    ft_on = ft is not None and ft.enabled
    ft_args = ((ft.policy.threshold, True) if ft_on else None)

    def local_fn(xb, router_w, wg, wu, wo):
        # xb: (B_loc, T, d) — replicated over model; wg: (e_local, d, f)
        bl = xb.shape[0]
        xf = xb.reshape(bl * t, d)
        logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router_w)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)
        # rebase expert ids to this shard's local range
        m_idx = jax.lax.axis_index("model")
        local_idx = gate_idx - m_idx * e_local
        local_idx = jnp.where((local_idx >= 0) & (local_idx < e_local),
                              local_idx, e_local)  # -> drop bucket
        y, stats = _dispatch_compute(xf, gate_vals, local_idx, wg, wu, wo,
                                     cap, e_local, dtype=x.dtype,
                                     ft_args=ft_args)
        y = jax.lax.psum(y, "model")  # combine expert shards
        aux = aux_load_balance_loss(probs, gate_idx, e)
        if dp:
            aux = jax.lax.pmean(aux, dp)  # global mean over token shards
        if stats is None:
            return y.reshape(bl, t, d), aux
        axes = ("model",) + dp  # replicate stats across every shard
        return (y.reshape(bl, t, d), aux,
                jax.lax.psum(jnp.sum(stats["flagged"]), axes),
                jax.lax.psum(jnp.sum(stats["corrected"]), axes),
                jax.lax.pmax(jnp.max(stats["score"]), axes))

    in_specs = (P(dp if dp else None, None, None),   # x: batch over dp
                P(None, None),                        # router replicated
                P("model", None, None), P("model", None, None),
                P("model", None, None))
    out_specs = (P(dp if dp else None, None, None), P())
    if ft_on:
        out_specs = out_specs + (P(), P(), P())
    fn = shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    out = fn(x, params["router"], params["wi_gate"], params["wi_up"],
             params["wo"])
    y, aux = out[0], out[1]
    if ft_on:
        ft.record({"flagged": out[2], "corrected": out[3], "score": out[4]})
    if "shared" in params:
        y = y + layers.swiglu(params["shared"], x.reshape(b * t, d),
                              ft=ft).reshape(b, t, d)
    return y, aux


def moe_block(params, x, cfg, *, ft=None):
    """x: (B, T, D) -> (y, aux) with capacity-based top-k dispatch.

    Dispatch-path selection (measured, EXPERIMENTS.md §Perf cell 1):
    * explicit EP (shard_map) when a production mesh is active AND the
      per-device token count is large (train/prefill) — the psum combine is
      ~1000x cheaper than the scatter resharding the partitioner emits;
    * portable scatter path for tiny decode steps (~8 tokens/device), where
      EP's replicated routing + per-layer psum costs more than it saves.
    """
    from repro.parallel.sharding import current_mesh, dp_axes
    mesh = current_mesh()
    if mesh is not None and "model" in mesh.axis_names and \
            cfg.num_experts % mesh.shape["model"] == 0:
        b, t, _ = x.shape
        dp = int(np.prod([mesh.shape[a] for a in dp_axes(mesh)])) or 1
        tokens_local = (b // dp if b % dp == 0 else b) * t
        if tokens_local >= 1024:
            return moe_block_ep(params, x, cfg, mesh, ft=ft)
    return _moe_block_portable(params, x, cfg, ft=ft)


def _moe_block_portable(params, x, cfg, *, ft=None):
    """x: (B, T, D) -> (y, aux) with capacity-based top-k dispatch."""
    b, t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    tokens = b * t
    cap = int(np.ceil(tokens * k / e * cfg.capacity_factor))
    cap = max(cap, 8)

    xf = x.reshape(tokens, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)        # (T, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True)
                             + 1e-9)

    # ---- sort-based dispatch ------------------------------------------------
    flat_e = gate_idx.reshape(-1)                         # (T*k,)
    order = jnp.argsort(flat_e)                           # stable
    sorted_e = flat_e[order]
    # position of each entry within its expert
    first = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    pos_in_e = jnp.arange(tokens * k) - first[sorted_e]
    keep = pos_in_e < cap
    dest = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)  # drop slot
    src_token = order // k

    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    buf = buf.at[dest].set(xf[src_token], mode="drop")
    buf = buf[:-1].reshape(e, cap, d)
    from repro.parallel.sharding import constrain_moe_buffer
    buf = constrain_moe_buffer(buf)

    # ---- expert FFN (EP: the leading E axis is sharded over `tensor`) ------
    if ft is not None and ft.enabled:
        thr = ft.policy.threshold
        gate, s1 = _ft_expert_matmul(buf, params["wi_gate"], thr, True)
        up, s2 = _ft_expert_matmul(buf, params["wi_up"], thr, True)
        act = jax.nn.silu(gate) * up
        out_buf, s3 = _ft_expert_matmul(act, params["wo"], thr, True)
        ft.record(_merge_expert_stats(s1, s2, s3))
    else:
        gate = jnp.einsum("ecd,edf->ecf", buf,
                          params["wi_gate"].astype(x.dtype))
        up = jnp.einsum("ecd,edf->ecf", buf, params["wi_up"].astype(x.dtype))
        act = jax.nn.silu(gate) * up
        out_buf = jnp.einsum("ecf,efd->ecd", act,
                             params["wo"].astype(x.dtype))

    # ---- combine ------------------------------------------------------------
    out_flat = out_buf.reshape(e * cap, d)
    gathered = jnp.where(keep[:, None],
                         out_flat[jnp.clip(dest, 0, e * cap - 1)], 0.0)
    # unsort back to (T*k) order, weight by gates, sum over k
    unsort = jnp.argsort(order)
    contrib = gathered[unsort].reshape(tokens, k, d)
    y = jnp.einsum("tkd,tk->td", contrib, gate_vals.astype(x.dtype))

    if "shared" in params:
        y = y + layers.swiglu(params["shared"], xf, ft=ft)

    aux = aux_load_balance_loss(probs, gate_idx, e)
    return y.reshape(b, t, d), aux


def aux_load_balance_loss(probs, gate_idx, e):
    """Switch-style load-balance auxiliary loss."""
    t = probs.shape[0]
    density = jnp.zeros((e,), jnp.float32).at[gate_idx.reshape(-1)].add(1.0)
    density = density / jnp.maximum(jnp.sum(density), 1.0)
    router_prob = jnp.mean(probs, axis=0)
    return e * jnp.sum(density * router_prob)
