"""Model assembly: embeddings -> (prefix | scanned super-blocks | tail) ->
final norm -> lm head; enc-dec (whisper) and modality frontends (stubs).

Everything is pure-functional: ``Model.init`` builds the param pytree (use
``jax.eval_shape`` for abstract init — the dry-run never allocates),
``Model.apply`` runs the forward pass, ``Model.decode_step`` advances one
token against the cache pytree from ``Model.init_cache``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

from . import attention, layers, transformer
from .layers import dense, dense_init
from .transformer import (block_apply, init_block_state, layer_groups,
                          make_block_params)

__all__ = ["Model", "count_params", "model_flops_per_token"]


def _dt(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def _tree_zeros_aux():
    z = jnp.zeros((), jnp.float32)
    return {"moe_aux": z, "ft_flagged": z, "ft_corrected": z,
            "ft_max_score": z}


def _merge_aux(a, b):
    return {
        "moe_aux": a["moe_aux"] + b["moe_aux"],
        "ft_flagged": a["ft_flagged"] + b["ft_flagged"],
        "ft_corrected": a["ft_corrected"] + b["ft_corrected"],
        "ft_max_score": jnp.maximum(a["ft_max_score"], b["ft_max_score"]),
    }


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------ init
    def init(self, key) -> dict:
        cfg = self.cfg
        pdt = _dt(cfg.param_dtype)
        keys = jax.random.split(key, 16)
        params: dict = {
            "embed": {"embedding": layers.dense_init(
                keys[0], (cfg.vocab_size, cfg.d_model), pdt)},
            "final_norm": layers.make_norm_params(cfg.d_model, cfg.norm),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = {"w": layers.dense_init(
                keys[1], (cfg.d_model, cfg.vocab_size), pdt)}
        if cfg.frontend == "patch_stub":
            params["frontend"] = {"w": layers.dense_init(
                keys[2], (cfg.frontend_dim, cfg.d_model), pdt)}
        if cfg.is_encdec:
            params["encoder"] = self._init_stack(
                keys[3], ["bidir|mlp"] * cfg.encoder_layers, pdt)
            params["enc_norm"] = layers.make_norm_params(cfg.d_model,
                                                         cfg.norm)
            params["enc_pos"] = layers.dense_init(
                keys[4], (cfg.max_source_positions, cfg.d_model), pdt)
            params["dec_pos"] = layers.dense_init(
                keys[5], (cfg.max_target_positions, cfg.d_model), pdt)
            if cfg.frontend == "audio_stub":
                params["frontend"] = {"w": layers.dense_init(
                    keys[6], (cfg.frontend_dim, cfg.d_model), pdt)}
            params["decoder"] = self._init_stack(
                keys[7], ["attn|mlp"] * cfg.decoder_layers, pdt,
                cross=True)
        else:
            params["stack"] = self._init_groups(keys[8], pdt)
        return params

    def _init_groups(self, key, pdt) -> dict:
        cfg = self.cfg
        g = layer_groups(cfg)
        keys = jax.random.split(key, 3)
        out: dict = {}
        if g.prefix:
            pk = jax.random.split(keys[0], len(g.prefix))
            out["prefix"] = {
                str(i): make_block_params(pk[i], cfg, kind, pdt)
                for i, kind in enumerate(g.prefix)}
        if g.n_super:
            sk = jax.random.split(keys[1], len(g.super_block))
            scan_p = {}
            for j, kind in enumerate(g.super_block):
                lk = jax.random.split(sk[j], g.n_super)
                scan_p[f"slot{j}"] = jax.vmap(
                    lambda kk, _kind=kind: make_block_params(
                        kk, cfg, _kind, pdt))(lk)
            out["scan"] = scan_p
        if g.tail:
            tk = jax.random.split(keys[2], len(g.tail))
            out["tail"] = {
                str(i): make_block_params(tk[i], cfg, kind, pdt)
                for i, kind in enumerate(g.tail)}
        return out

    def _init_stack(self, key, kinds, pdt, *, cross=False) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, len(kinds))
        stack = {}
        for i, kind in enumerate(kinds):
            p = make_block_params(ks[i], cfg, kind, pdt)
            if cross:
                ck = jax.random.fold_in(ks[i], 1)
                p["cross_norm"] = layers.make_norm_params(cfg.d_model, cfg.norm)
                p["cross_attn"] = attention.make_attn_params(
                    ck, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                    cfg.head_dim, dtype=pdt)
            stack[str(i)] = p
        return stack

    # --------------------------------------------------------------- forward
    def apply(self, params, batch: dict, *, block_q: int = 1024,
              remat: bool = False, inject=None):
        """Full-sequence forward. Returns (logits_f32, aux).

        ``inject`` threads a traced GEMM fault descriptor into every
        protected block (see ``transformer.block_apply``).
        """
        cfg = self.cfg
        adt = _dt(cfg.dtype)
        if cfg.is_encdec:
            return self._apply_encdec(params, batch, block_q, remat)
        x, positions = self._embed_inputs(params, batch, adt)
        from repro.parallel.sharding import constrain_hidden
        x = constrain_hidden(x)
        x, aux = self._run_groups(params["stack"], x, positions, block_q,
                                  remat, inject=inject)
        return self._head(params, x), aux

    def _embed_inputs(self, params, batch, adt):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = layers.embed(params["embed"], tokens, adt)
        x = x * jnp.asarray(np.sqrt(cfg.d_model), adt)
        if cfg.frontend == "patch_stub" and "patch_embeds" in batch:
            patches = dense(params["frontend"],
                            batch["patch_embeds"].astype(adt))
            x = jnp.concatenate([patches, x], axis=1)
        positions = jnp.arange(x.shape[1])
        return x, positions

    def _head(self, params, x):
        cfg = self.cfg
        from repro.parallel.sharding import constrain_logits
        x = layers.norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
        if cfg.tie_embeddings:
            w = params["embed"]["embedding"].T
        else:
            w = params["lm_head"]["w"]
        logits = jnp.einsum("btd,dv->btv", x, w.astype(x.dtype),
                            preferred_element_type=jnp.float32)
        return constrain_logits(logits)

    def _run_groups(self, stack, x, positions, block_q, remat,
                    caches=None, cache_pos=None, inject=None):
        cfg = self.cfg
        g = layer_groups(cfg)
        ftp = cfg.ft
        aux = _tree_zeros_aux()
        new_caches: dict = {}

        def run_one(p, x, kind, cache):
            fn = functools.partial(
                block_apply, cfg=cfg, kind=kind, positions=positions,
                cache_pos=cache_pos, block_q=block_q, ftp=ftp,
                inject=inject)
            if remat and remat != "none" and cache is None:
                # per-block remat on the unrolled path (matches the scanned
                # path, which remats the whole super-block body)
                return jax.checkpoint(
                    lambda p_, x_: fn(p_, x_, cache=None))(p, x)
            return fn(p, x, cache=cache)

        for name, kinds in (("prefix", g.prefix), ):
            if kinds:
                ncl = []
                for i, kind in enumerate(kinds):
                    c = None if caches is None else caches["prefix"][str(i)]
                    x, nc, a = run_one(stack["prefix"][str(i)], x, kind, c)
                    aux = _merge_aux(aux, a)
                    ncl.append(nc)
                if caches is not None:
                    new_caches["prefix"] = {str(i): c
                                            for i, c in enumerate(ncl)}

        if g.n_super:
            slots = list(g.super_block)

            def body(carry, xs):
                x = carry
                if caches is None:
                    p_slice = xs
                    c_slice = {f"slot{j}": None for j in range(len(slots))}
                else:
                    p_slice, c_slice = xs
                a_all = _tree_zeros_aux()
                nc_out = {}
                for j, kind in enumerate(slots):
                    x, nc, a = run_one(p_slice[f"slot{j}"], x, kind,
                                       c_slice[f"slot{j}"])
                    a_all = _merge_aux(a_all, a)
                    nc_out[f"slot{j}"] = nc
                ys = a_all if caches is None else (a_all, nc_out)
                return x, ys

            if remat == "dots":
                # cheaper policy: keep matmul outputs, recompute elementwise
                body_fn = jax.checkpoint(
                    body, policy=jax.checkpoint_policies
                    .dots_with_no_batch_dims_saveable)
            elif remat and remat != "none":
                body_fn = jax.checkpoint(body)
            else:
                body_fn = body
            xs = stack["scan"] if caches is None else (stack["scan"],
                                                       caches["scan"])
            x, ys = jax.lax.scan(body_fn, x, xs)
            if caches is None:
                a_scan = ys
            else:
                a_scan, nc_scan = ys
                new_caches["scan"] = nc_scan
            aux = _merge_aux(aux, jax.tree_util.tree_map(
                lambda v: jnp.sum(v) if v.ndim else v,
                {"moe_aux": a_scan["moe_aux"],
                 "ft_flagged": a_scan["ft_flagged"],
                 "ft_corrected": a_scan["ft_corrected"],
                 "ft_max_score": jnp.max(a_scan["ft_max_score"])}))

        if g.tail:
            ncl = []
            for i, kind in enumerate(g.tail):
                c = None if caches is None else caches["tail"][str(i)]
                x, nc, a = run_one(stack["tail"][str(i)], x, kind, c)
                aux = _merge_aux(aux, a)
                ncl.append(nc)
            if caches is not None:
                new_caches["tail"] = {str(i): c for i, c in enumerate(ncl)}

        return (x, aux) if caches is None else (x, aux, new_caches)

    # --------------------------------------------------------------- enc-dec
    def _encode(self, params, batch, block_q, remat=False):
        cfg = self.cfg
        adt = _dt(cfg.dtype)
        frames = batch["frames"].astype(adt)
        h = dense(params["frontend"], frames)           # stub conv frontend
        f = h.shape[1]
        h = h + params["enc_pos"][:f].astype(adt)[None]
        positions = jnp.arange(f)
        aux = _tree_zeros_aux()
        for i in range(cfg.encoder_layers):
            h, _, a = block_apply(params["encoder"][str(i)], h, cfg=cfg,
                                  kind="bidir|mlp", positions=positions,
                                  block_q=block_q, ftp=cfg.ft)
            aux = _merge_aux(aux, a)
        return layers.norm(params["enc_norm"], h, cfg.norm, cfg.norm_eps), aux

    def _decoder_block(self, p, x, enc_out, positions, cache, cache_pos,
                       block_q):
        cfg = self.cfg
        x, nc, a = block_apply(
            {k: v for k, v in p.items() if not k.startswith("cross")},
            x, cfg=cfg, kind="attn|mlp", positions=positions,
            cache=None if cache is None else cache.get("self"),
            cache_pos=cache_pos, block_q=block_q, ftp=cfg.ft)
        h = layers.norm(p["cross_norm"], x, cfg.norm, cfg.norm_eps)
        cross_cache = None if cache is None else cache.get("cross")
        mix, cc = attention.attention(
            p["cross_attn"], h, cfg=cfg, kind="cross", positions=positions,
            cache=cross_cache, kv_source=enc_out, use_rope=False,
            block_q=block_q)
        x = x + mix
        new_cache = None
        if cache is not None:
            new_cache = {"self": nc, "cross": cc}
        return x, new_cache, a

    def _apply_encdec(self, params, batch, block_q, remat):
        cfg = self.cfg
        adt = _dt(cfg.dtype)
        enc_out, aux = self._encode(params, batch, block_q, remat)
        tokens = batch["tokens"]
        x = layers.embed(params["embed"], tokens, adt)
        x = x + params["dec_pos"][:x.shape[1]].astype(adt)[None]
        positions = jnp.arange(x.shape[1])
        for i in range(cfg.decoder_layers):
            x, _, a = self._decoder_block(params["decoder"][str(i)], x,
                                          enc_out, positions, None, None,
                                          block_q)
            aux = _merge_aux(aux, a)
        return self._head(params, x), aux

    # ---------------------------------------------------------------- decode
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        if cfg.is_encdec:
            enc_len = cfg.max_source_positions
            dec = {}
            for i in range(cfg.decoder_layers):
                dec[str(i)] = {
                    "self": init_block_state(cfg, "attn|mlp", batch, max_len,
                                             dtype),
                    "cross": attention.init_kv_cache(cfg, batch, enc_len,
                                                     dtype),
                }
            return {"decoder": dec}
        g = layer_groups(cfg)
        caches: dict = {}
        if g.prefix:
            caches["prefix"] = {
                str(i): init_block_state(cfg, kind, batch, max_len, dtype)
                for i, kind in enumerate(g.prefix)}
        if g.n_super:
            scan_c = {}
            for j, kind in enumerate(g.super_block):
                one = init_block_state(cfg, kind, batch, max_len, dtype)
                scan_c[f"slot{j}"] = jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(
                        a[None], (g.n_super,) + a.shape), one)
            caches["scan"] = scan_c
        if g.tail:
            caches["tail"] = {
                str(i): init_block_state(cfg, kind, batch, max_len, dtype)
                for i, kind in enumerate(g.tail)}
        return caches

    def decode_step(self, params, cache, tokens, pos, *, block_q: int = 0,
                    inject=None):
        """One decode step. tokens: (B, 1); pos: scalar int32 write index.

        ``inject`` threads a traced GEMM fault descriptor into every
        protected block (serving arms it per step from a FaultSchedule).
        """
        cfg = self.cfg
        adt = _dt(cfg.dtype)
        positions = pos + jnp.arange(tokens.shape[1])
        if cfg.is_encdec:
            x = layers.embed(params["embed"], tokens, adt)
            x = x + jax.lax.dynamic_slice_in_dim(
                params["dec_pos"], pos, tokens.shape[1], axis=0
            ).astype(adt)[None]
            aux = _tree_zeros_aux()
            new_dec = {}
            for i in range(cfg.decoder_layers):
                x, nc, a = self._decoder_block(
                    params["decoder"][str(i)], x, None, positions,
                    cache["decoder"][str(i)], pos, block_q)
                new_dec[str(i)] = nc
                aux = _merge_aux(aux, a)
            return self._head(params, x), {"decoder": new_dec}, aux
        x = layers.embed(params["embed"], tokens, adt)
        x = x * jnp.asarray(np.sqrt(cfg.d_model), adt)
        x, aux, new_caches = self._run_groups(
            params["stack"], x, positions, block_q, False, caches=cache,
            cache_pos=pos, inject=inject)
        return self._head(params, x), new_caches, aux


# ---------------------------------------------------------------------------
# analytics
# ---------------------------------------------------------------------------

def count_params(cfg: ModelConfig) -> int:
    """Exact parameter count via abstract init (no allocation)."""
    model = Model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return int(sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(shapes)))


def model_flops_per_token(cfg: ModelConfig, params_total: int | None = None
                          ) -> float:
    """6 * N_active per token (dense) — the §Roofline MODEL_FLOPS basis."""
    n = params_total if params_total is not None else count_params(cfg)
    n_active = n - cfg.inactive_expert_params()
    return 6.0 * n_active
