"""Assigned-architecture model substrate (pure functional JAX)."""
from .model import Model, count_params, model_flops_per_token

__all__ = ["Model", "count_params", "model_flops_per_token"]
