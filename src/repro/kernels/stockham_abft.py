"""Fused two-sided ABFT FFT kernel (paper §4.2-4.3), TPU Pallas.

One kernel instance = one transaction group. The grid is ``(G, T)``: group g
runs T sequential transactions (HBM read -> VMEM FFT -> HBM write), exactly
the paper's multi-transaction threadblock. Checksums are *fused*:

* left side (thread-level analogue): per-signal ``(e1^T W) x_b`` vs
  ``e1^T y_b`` — computed from the VMEM-resident tile, zero extra HBM traffic,
* right side (threadblock/multi-transaction analogue): ``X e2 / X e3`` input
  and output checksums accumulated in VMEM scratch **across grid steps** and
  written once on the last transaction — the reduction cost is amortized 1/T
  with no inter-transaction communication (paper: "each thread exactly maps to
  the same ABFT encoding workload").

An optional in-kernel SEU injector corrupts one output element of one tile —
simulating a transient compute-unit fault *inside* the protected region, so
tests exercise true end-to-end detect->locate->correct.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.abft.encoding import EPS, left_encoding, left_encoding_image
from repro.core.fft.plan import Plan, make_plan

from .stockham import fft_stages_value, stage_consts

__all__ = ["abft_fft_pallas"]


def _abft_kernel(stages, layout, n_const, bs, transactions, per_signal,
                 # refs:
                 xr_ref, xi_ref, ew_ref, e1_ref, inj_ref, *rest):
    const_refs = rest[:n_const]
    yr_ref, yi_ref, delta_ref, cs_ref = rest[n_const:n_const + 4]
    acc_ref = rest[n_const + 4]

    g = pl.program_id(0)
    t = pl.program_id(1)
    tile = g * transactions + t

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xr = xr_ref[...]
    xi = xi_ref[...]
    ftype = xr.dtype

    # ---- left-side input checksum: s_in[b] = sum_n (e1^T W)[n] * x[b, n]
    if per_signal:
        ewr = ew_ref[0, :]
        ewi = ew_ref[1, :]
        s_in_r = xr @ ewr - xi @ ewi
        s_in_i = xr @ ewi + xi @ ewr

    # ---- the FFT itself (all stages VMEM-resident, MXU contractions)
    consts = [c[...] for c in const_refs]
    yr, yi = fft_stages_value(xr, xi, stages, consts, layout)

    # ---- simulated SEU at the compute units (inside the protected region)
    inj = inj_ref[0, :]
    n = yr.shape[-1]
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (bs, n), 0)
    col_iota = jax.lax.broadcasted_iota(jnp.int32, (bs, n), 1)
    hit = ((inj[3] > 0) & (inj[0].astype(jnp.int32) == tile)
           & (row_iota == inj[1].astype(jnp.int32))
           & (col_iota == inj[2].astype(jnp.int32)))
    yr = yr + jnp.where(hit, inj[4].astype(ftype), 0).astype(ftype)
    yi = yi + jnp.where(hit, inj[5].astype(ftype), 0).astype(ftype)

    # ---- left-side output checksum: s_out[b] = sum_k e1[k] * y[b, k]
    if per_signal:
        e1r = e1_ref[0, :]
        e1i = e1_ref[1, :]
        s_out_r = yr @ e1r - yi @ e1i
        s_out_i = yr @ e1i + yi @ e1r
        dr = s_in_r - s_out_r
        di = s_in_i - s_out_i
        mag = jnp.sqrt(s_in_r * s_in_r + s_in_i * s_in_i) + EPS
        delta_ref[...] = (jnp.sqrt(dr * dr + di * di) / mag)[:, None]
    else:
        delta_ref[...] = jnp.zeros_like(delta_ref)

    # ---- right-side checksums, accumulated across transactions in scratch.
    # Location encoding: global 1-based signal id (paper: "each thread
    # aggregates the product of its share and the global ID for the signal").
    gid = (tile * bs + jax.lax.broadcasted_iota(jnp.int32, (bs, 1), 0)
           + 1).astype(ftype)
    acc = acc_ref[...]
    upd = jnp.stack([
        jnp.sum(xr, axis=0), jnp.sum(xi, axis=0),
        jnp.sum(gid * xr, axis=0), jnp.sum(gid * xi, axis=0),
        jnp.sum(yr, axis=0), jnp.sum(yi, axis=0),
        jnp.sum(gid * yr, axis=0), jnp.sum(gid * yi, axis=0),
    ])
    acc_ref[...] = acc + upd

    yr_ref[...] = yr
    yi_ref[...] = yi

    @pl.when(t == transactions - 1)
    def _emit():
        cs_ref[0, :, :] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("plan", "bs", "transactions", "per_signal", "encoding",
                     "inverse", "interpret"),
)
def abft_fft_pallas(
    xr: jax.Array,
    xi: jax.Array,
    *,
    plan: Plan | None = None,
    bs: int | None = None,
    transactions: int = 1,
    per_signal: bool = True,
    encoding: str = "wang",
    inverse: bool = False,
    interpret: bool = True,
    inject: jax.Array | None = None,
):
    """Fused FT-FFT: returns (yr, yi, delta, cs).

    * ``delta`` — (B,) per-signal left-checksum relative divergence
      (all-zero when ``per_signal=False``, the threadblock-level variant),
    * ``cs`` — (G, 8, N) packed right-side checksums
      [x*e2 r/i, x*e3 r/i, y*e2 r/i, y*e3 r/i] per transaction group,
    * ``inject`` — optional (6,) array [tile, row, col, enabled, eps_r, eps_i]
      (float; integer fields rounded) simulating one SEU.
    """
    b, n = xr.shape
    if inverse:
        raise NotImplementedError(
            "ABFT protection covers the forward transform (paper scope); "
            "protect ifft by conjugation: ifft(x) = conj(fft(conj(x)))/n")
    if plan is None:
        plan = make_plan(n, batch=b, itemsize=xr.dtype.itemsize,
                         inverse=inverse)
    if plan.num_passes != 1:
        raise ValueError(
            f"abft_fft_pallas is single-pass; got {plan.describe()} — "
            f"compose larger sizes at the JAX level (ops.ft_fft)")
    stages = plan.stages[0]
    if bs is None:
        bs = min(plan.bs, b)
    if b % bs != 0:
        raise ValueError(f"batch {b} is not divisible by tile size bs={bs}")
    tiles = b // bs
    if tiles % transactions != 0:
        raise ValueError(f"tiles={tiles} (batch {b} / bs={bs}) is not "
                         f"divisible by transactions={transactions}")
    groups = tiles // transactions

    np_dtype = np.float64 if xr.dtype == jnp.float64 else np.float32
    consts, layout = stage_consts(stages, np_dtype, inverse=inverse)
    const_arrays = [jnp.asarray(c) for c in consts]

    ew = left_encoding_image(n, encoding, inverse=inverse)
    e1 = left_encoding(n, encoding)
    ew_arr = jnp.asarray(
        np.stack([ew.real, ew.imag]).astype(np_dtype))          # (2, N)
    e1_arr = jnp.asarray(
        np.stack([e1.real, e1.imag]).astype(np_dtype))          # (2, N)
    if inject is None:
        inject = jnp.full((6,), -1.0, dtype=jnp.float32)
    inj_arr = jnp.reshape(inject.astype(np_dtype), (1, 6))

    grid = (groups, transactions)
    x_spec = pl.BlockSpec((bs, n), lambda g, t: (g * transactions + t, 0))
    vec_spec = pl.BlockSpec((2, n), lambda g, t: (0, 0))
    inj_spec = pl.BlockSpec((1, 6), lambda g, t: (0, 0))
    const_specs = [
        pl.BlockSpec(c.shape, lambda g, t, _nd=c.ndim: (0,) * _nd)
        for c in const_arrays
    ]
    delta_spec = pl.BlockSpec((bs, 1), lambda g, t: (g * transactions + t, 0))
    cs_spec = pl.BlockSpec((1, 8, n), lambda g, t: (g, 0, 0))

    kernel = functools.partial(_abft_kernel, stages, layout,
                               len(const_arrays), bs, transactions,
                               per_signal)
    yr, yi, delta, cs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[x_spec, x_spec, vec_spec, vec_spec, inj_spec] + const_specs,
        out_specs=[x_spec, x_spec, delta_spec, cs_spec],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), xr.dtype),
            jax.ShapeDtypeStruct((b, n), xi.dtype),
            jax.ShapeDtypeStruct((b, 1), xr.dtype),
            jax.ShapeDtypeStruct((groups, 8, n), xr.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((8, n), jnp.dtype(np_dtype))],
        interpret=interpret,
    )(xr, xi, ew_arr, e1_arr, inj_arr, *const_arrays)
    if inverse:
        scale = jnp.asarray(1.0 / n, dtype=xr.dtype)
        yr, yi, cs = yr * scale, yi * scale, cs * scale
    return yr, yi, delta[:, 0], cs
