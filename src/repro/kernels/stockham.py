"""Pallas TPU block FFT kernel — radix-<=128 Stockham, VMEM-resident.

TPU adaptation of the paper's threadblock-level FFT (§3.1): a grid tile loads
``(bs, N)`` signals HBM->VMEM, runs the plan's mixed-radix stages entirely in
VMEM, and stores back — one "transaction" in the paper's vocabulary. Each
stage contracts with a small DFT factor matrix, so stage compute lands on the
MXU (radix 128 fills the systolic contraction dimension exactly); twiddle
tables are precomputed host-side (no in-kernel trigonometry, paper §3.1
"twiddling factor table").

Complex data is carried as split real/imag float arrays: TPU Pallas has no
complex dtype, and the split layout is also what keeps lanes 128-aligned
("padding-free" in TPU terms: no relayout-inducing interleaved complex).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.fft import factors
from repro.core.fft.plan import Plan, StagePlan, make_plan

__all__ = ["block_fft_pallas", "stage_consts", "fft_stages_value"]


def _cmul(ar, ai, br, bi):
    """(ar+i*ai) * (br+i*bi) elementwise."""
    return ar * br - ai * bi, ar * bi + ai * br


def _cmatmul(wr, wi, xr, xi):
    """Complex contraction einsum('kr,...rm->...km') as 4 real MXU matmuls."""
    f32 = jnp.float32 if xr.dtype != jnp.float64 else jnp.float64
    def mm(w, x):
        return jnp.einsum("kr,...rm->...km", w, x,
                          preferred_element_type=f32).astype(xr.dtype)
    return mm(wr, xr) - mm(wi, xi), mm(wr, xi) + mm(wi, xr)


def stage_consts(stages: Sequence[StagePlan], dtype=np.float32, *,
                 inverse: bool = False):
    """Host-side constant tables for one block plan: per-stage (Wr, Wi[, Tr, Ti])."""
    consts: list[np.ndarray] = []
    layout: list[bool] = []  # has_twiddle per stage
    for st in stages:
        wr, wi = factors.dft_matrix_ri(st.radix, dtype, inverse=inverse)
        consts += [wr, wi]
        if st.m > 1:
            tr, ti = factors.stage_twiddle_ri(st.radix, st.m, dtype,
                                              inverse=inverse)
            consts += [tr, ti]
            layout.append(True)
        else:
            layout.append(False)
    return consts, tuple(layout)


def fft_stages_value(xr, xi, stages: Sequence[StagePlan], consts, layout):
    """Run the mixed-radix stages on VMEM-resident values (used by kernels).

    ``consts`` is the flat list from :func:`stage_consts` (values, not refs).
    Mirrors ``core.fft.stockham._fft_recursive`` in split real/imag form.
    """
    ci = 0

    def rec(zr, zi, si):
        nonlocal ci
        if si == len(stages):
            return zr, zi
        st = stages[si]
        r, m = st.radix, st.m
        lead = zr.shape[:-1]
        zr = zr.reshape(lead + (r, m))
        zi = zi.reshape(lead + (r, m))
        wr, wi = consts[ci], consts[ci + 1]
        ci += 2
        ar, ai = _cmatmul(wr, wi, zr, zi)
        if layout[si]:
            tr, ti = consts[ci], consts[ci + 1]
            ci += 2
            ar, ai = _cmul(ar, ai, tr, ti)
            ar, ai = rec(ar, ai, si + 1)  # FFT along the trailing m axis
        else:
            assert m == 1
        # output ordering k = k1 + r*k2: transpose (r, m) -> (m, r)
        ar = jnp.swapaxes(ar, -1, -2).reshape(lead + (r * m,))
        ai = jnp.swapaxes(ai, -1, -2).reshape(lead + (r * m,))
        return ar, ai

    return rec(xr, xi, 0)


def _fft_kernel(stages, layout, n_const, xr_ref, xi_ref, *rest):
    const_refs = rest[:n_const]
    yr_ref, yi_ref = rest[n_const:]
    consts = [c[...] for c in const_refs]
    xr = xr_ref[...]
    xi = xi_ref[...]
    yr, yi = fft_stages_value(xr, xi, stages, consts, layout)
    yr_ref[...] = yr
    yi_ref[...] = yi


@functools.partial(
    jax.jit,
    static_argnames=("plan", "bs", "interpret", "inverse"),
)
def block_fft_pallas(
    xr: jax.Array,
    xi: jax.Array,
    *,
    plan: Plan | None = None,
    bs: int | None = None,
    inverse: bool = False,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Batched single-pass FFT: (B, N) split re/im -> (B, N) split re/im.

    ``B`` must be divisible by the tile size ``bs`` (ops.py pads). N must fit
    a single VMEM pass (``plan.num_passes == 1``); larger sizes are composed
    by ``ops.fft`` at the JAX level (the paper's kernel-level N1xN2xN3).
    """
    b, n = xr.shape
    if plan is None:
        plan = make_plan(n, batch=b, itemsize=xr.dtype.itemsize,
                         inverse=inverse)
    if plan.num_passes != 1:
        raise ValueError(
            f"block_fft_pallas is single-pass; got {plan.describe()} — "
            f"compose larger sizes at the JAX level (ops.fft)")
    stages = plan.stages[0]
    if bs is None:
        bs = min(plan.bs, b)
    if b % bs != 0:
        raise ValueError(f"batch {b} is not divisible by tile size bs={bs}")

    np_dtype = np.float64 if xr.dtype == jnp.float64 else np.float32
    consts, layout = stage_consts(stages, np_dtype, inverse=inverse)
    const_arrays = [jnp.asarray(c) for c in consts]

    grid = (b // bs,)
    x_spec = pl.BlockSpec((bs, n), lambda i: (i, 0))
    const_specs = [
        pl.BlockSpec(c.shape, lambda i: (0,) * c.ndim) for c in const_arrays
    ]
    out_specs = [x_spec, x_spec]
    kernel = functools.partial(_fft_kernel, stages, layout, len(const_arrays))
    yr, yi = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[x_spec, x_spec] + const_specs,
        out_specs=out_specs,
        out_shape=[
            jax.ShapeDtypeStruct((b, n), xr.dtype),
            jax.ShapeDtypeStruct((b, n), xi.dtype),
        ],
        interpret=interpret,
    )(xr, xi, *const_arrays)
    if inverse:
        scale = jnp.asarray(1.0 / n, dtype=xr.dtype)
        yr, yi = yr * scale, yi * scale
    return yr, yi
