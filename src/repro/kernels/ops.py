"""Public jit'd wrappers around the Pallas kernels.

* :func:`fft` / :func:`ifft` — batched FFT over the last axis; single-pass
  sizes run one Pallas block kernel, larger sizes compose the paper's
  kernel-level N1xN2(xN3) passes around it.
* :func:`ft_fft` — the full TurboFFT pipeline: fused two-sided-ABFT kernel ->
  detect -> locate -> delayed batched correction. Returns an
  :class:`FTFFTResult` with the corrected outputs and the FT telemetry.

On CPU (this container) kernels default to interpret mode; on TPU they
compile natively. ``interpret=None`` auto-detects.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import abft
from repro.core.fft import api as fft_api
from repro.core.fft import factors as fft_factors
from repro.core.fft.plan import Plan, make_plan
from repro.core.fft.stockham import block_fft_stages

from .stockham import block_fft_pallas
from .stockham_abft import abft_fft_pallas

__all__ = ["fft", "ifft", "fft2", "ifft2", "ft_fft", "FTFFTResult"]

# Sentinel marking a legacy kwarg the caller did not pass. The entry points
# below are compat shims over the plan API (core.fft.api): explicitly
# passing a non-default value for one of _DEPRECATED_DEFAULTS emits a
# one-shot FFTKwargDeprecationWarning pointing at plan(FFTSpec(...)).
_UNSET = object()

_DEPRECATED_DEFAULTS = dict(mesh=None, axis="fft", natural_order=True,
                            decomp="auto", groups=None, group_size=None,
                            recompute_uncorrectable=False)


def _resolve_legacy(entry: str, kw: dict) -> dict:
    out = {}
    deprecated = []
    for k, v in kw.items():
        default = _DEPRECATED_DEFAULTS[k]
        if v is _UNSET:
            out[k] = default
        else:
            out[k] = v
            if not (v is default or v == default):
                deprecated.append(k)
    if deprecated:
        fft_api.warn_deprecated_kwargs(f"kernels.ops.{entry}", deprecated)
    return out


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _split(x):
    ftype = jnp.float64 if x.dtype == jnp.complex128 else jnp.float32
    return jnp.real(x).astype(ftype), jnp.imag(x).astype(ftype)


def _join(yr, yi):
    return jax.lax.complex(yr, yi)


def _pad_batch(x, bs):
    b = x.shape[0]
    pad = (-b) % bs
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x, b


def _block_fft_c(x2d, *, inverse, interpret, bs=None):
    """Single-pass complex block FFT via the Pallas kernel. (B, N)->(B, N)."""
    xr, xi = _split(x2d)
    plan = make_plan(x2d.shape[-1], batch=x2d.shape[0],
                     itemsize=xr.dtype.itemsize, inverse=inverse)
    if bs is None:
        bs = min(plan.bs, x2d.shape[0])
    xr, b0 = _pad_batch(xr, bs)
    xi, _ = _pad_batch(xi, bs)
    yr, yi = block_fft_pallas(xr, xi, plan=dataclasses.replace(plan),
                              bs=bs, inverse=inverse, interpret=interpret)
    return _join(yr, yi)[:b0]


def _fft_multipass(x2d, plan: Plan, *, inverse, interpret):
    """Kernel-level N1 x N2 (x N3) composition (paper Fig. 3) around the
    Pallas block kernel: per pass, one transposed batched block FFT + twiddle.
    """
    facs = plan.kernel_factors
    n = plan.n
    b = x2d.shape[0]

    def rec(z, facs):
        nloc = z.shape[-1]
        if len(facs) == 1:
            return _block_fft_c(z.reshape(-1, nloc),
                                inverse=inverse,
                                interpret=interpret).reshape(z.shape)
        f1 = facs[0]
        f2 = int(np.prod(facs[1:]))
        zz = z.reshape(z.shape[:-1] + (f1, f2))
        zz = jnp.swapaxes(zz, -1, -2)  # (..., f2, f1)
        zz = rec(zz, (f1,))
        zz = jnp.swapaxes(zz, -1, -2)  # (..., f1, f2)
        tw = jnp.asarray(fft_factors.stage_twiddle(f1, f2, inverse=inverse),
                         dtype=z.dtype)
        zz = zz * tw
        zz = rec(zz, facs[1:])
        zz = jnp.swapaxes(zz, -1, -2)
        return zz.reshape(z.shape)

    return rec(x2d, facs)


@functools.partial(jax.jit, static_argnames=("inverse", "interpret"))
def _fft_impl(x, *, inverse=False, interpret=None):
    interpret = _auto_interpret(interpret)
    shape = x.shape
    n = shape[-1]
    x2d = x.reshape((-1, n))
    plan = make_plan(n, batch=x2d.shape[0], inverse=inverse)
    if plan.num_passes == 1:
        y = _block_fft_c(x2d, inverse=inverse, interpret=interpret)
    else:
        y = _fft_multipass(x2d, plan, inverse=inverse, interpret=interpret)
        if inverse:
            y = y / n
    return y.reshape(shape)


def fft(x, *, interpret=None, mesh=_UNSET, axis=_UNSET,
        natural_order=_UNSET):
    """TurboFFT forward transform over the last axis (complex in/out).

    Compat shim over the plan API: the call builds (or LRU-hits) the
    :class:`~repro.core.fft.api.FFTPlan` for the operand and runs its
    cached executor. An ``x`` committed to an ``fft``-axis mesh plans
    distributed (the auto-dispatch contract); passing ``mesh=`` /
    ``natural_order=`` explicitly still works but is deprecated — build an
    :class:`~repro.core.fft.api.FFTSpec` once and call ``plan(spec).fft``.

    Sharding-based auto-dispatch only works on concrete (eager) operands:
    inside an enclosing ``jax.jit`` the tracer carries no committed
    sharding, so build the spec with ``mesh=`` there.
    """
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    kw = _resolve_legacy("fft", dict(mesh=mesh, axis=axis,
                                     natural_order=natural_order))
    spec = fft_api.spec_for(x, rank=1, mesh=kw["mesh"], axis=kw["axis"],
                            natural_order=kw["natural_order"],
                            interpret=interpret)
    return fft_api.plan(spec).fft(x)


def ifft(x, *, interpret=None, mesh=_UNSET, axis=_UNSET,
         natural_order=_UNSET):
    """Inverse transform; ``natural_order=False`` on the mesh path consumes
    TRANSPOSED-order input (the ``fft(..., natural_order=False)`` output)
    and returns natural-order time domain with no all-gather. Compat shim
    over ``plan(spec).ifft`` — see :func:`fft`."""
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    kw = _resolve_legacy("ifft", dict(mesh=mesh, axis=axis,
                                      natural_order=natural_order))
    spec = fft_api.spec_for(x, rank=1, mesh=kw["mesh"], axis=kw["axis"],
                            natural_order=kw["natural_order"],
                            interpret=interpret)
    return fft_api.plan(spec).ifft(x)


def fft2(x, *, interpret=None, mesh=_UNSET, axis=_UNSET,
         natural_order=_UNSET, decomp=_UNSET):
    """2-D FFT over the last two axes (complex in/out).

    Compat shim over a rank-2 plan: ``decomp`` picks the slab or pencil
    layout (``"auto"`` = the :func:`~repro.core.fft.multidim.choose_decomp`
    communication-model heuristic, resolved once at plan build).
    ``natural_order=False`` keeps a pencil result in the per-axis
    transposed digit order (a no-op for slab, whose natural order is
    free). On the local path odd / non-power-of-two axes are supported,
    and ``interpret`` routes power-of-two axes through the Pallas block
    kernel. The mesh kwargs are deprecated — see :func:`fft`.
    """
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    kw = _resolve_legacy("fft2", dict(mesh=mesh, axis=axis,
                                      natural_order=natural_order,
                                      decomp=decomp))
    spec = fft_api.spec_for(x, rank=2, mesh=kw["mesh"], axis=kw["axis"],
                            natural_order=kw["natural_order"],
                            decomp=kw["decomp"], interpret=interpret)
    return fft_api.plan(spec).fft(x)


def ifft2(x, *, interpret=None, mesh=_UNSET, axis=_UNSET,
          natural_order=_UNSET, decomp=_UNSET):
    """Inverse 2-D transform (1/(R*C) normalized); ``natural_order=False``
    on the mesh pencil path consumes the ``fft2(..., natural_order=False)``
    transposed-digit output with no redistribution. Compat shim — see
    :func:`fft2`."""
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    kw = _resolve_legacy("ifft2", dict(mesh=mesh, axis=axis,
                                       natural_order=natural_order,
                                       decomp=decomp))
    spec = fft_api.spec_for(x, rank=2, mesh=kw["mesh"], axis=kw["axis"],
                            natural_order=kw["natural_order"],
                            decomp=kw["decomp"], interpret=interpret)
    return fft_api.plan(spec).ifft(x)


# ---------------------------------------------------------------------------
# Fault-tolerant FFT (the paper's co-design)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FTFFTResult:
    """Outputs + fault-tolerance telemetry of one ft_fft call."""

    y: jax.Array                 # (B, N) corrected outputs
    delta: jax.Array             # (B,) per-signal left-checksum divergence
    group_score: jax.Array       # (G,) right-checksum divergence per group
    flagged: jax.Array           # (G,) bool — group detected an error
    location: jax.Array          # (G,) int32 — decoded corrupted signal id
    corrected: jax.Array         # scalar — number of corrections applied


def ft_fft(
    x: jax.Array,
    *,
    transactions: int = 4,
    bs: int | None = None,
    per_signal: bool = False,
    encoding: str = "wang",
    threshold: float = 1e-4,
    correct: bool = True,
    interpret: bool | None = None,
    inject: jax.Array | None = None,
    mesh=_UNSET,
    axis=_UNSET,
    groups=_UNSET,
    group_size=_UNSET,
    natural_order=_UNSET,
    recompute_uncorrectable=_UNSET,
):
    """Fault-tolerant forward FFT with online detection and correction.

    ``per_signal=False`` is the threadblock/multi-transaction scheme of the
    paper (detection via group checksums, location via the e3 encoding);
    ``per_signal=True`` additionally computes thread-level per-signal
    checksums (more compute, finer localization).

    Compat shim over an ft plan (``FFTSpec(ft=FTConfig(...))``): an ``x``
    committed to an ``fft``-axis mesh (or an explicit — deprecated —
    ``mesh=``) runs the sharded grouped two-side ABFT
    (``core.fft.distributed.ft_distributed_fft``) and returns its
    :class:`~repro.core.fft.distributed.DistFFTResult`; ``groups``/
    ``group_size`` pick the checksum group count (the mesh-level
    multi-transaction knob; auto = one group per data shard), and
    ``inject`` follows the distributed 7-field layout. On the local path
    those knobs are no-ops and the fused-kernel ``transactions`` grouping
    applies, with the kernel's 6-field ``inject`` layout.
    """
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    kw = _resolve_legacy("ft_fft", dict(
        mesh=mesh, axis=axis, groups=groups, group_size=group_size,
        natural_order=natural_order,
        recompute_uncorrectable=recompute_uncorrectable))
    ft = fft_api.FTConfig(
        threshold=threshold, correct=correct, groups=kw["groups"],
        group_size=kw["group_size"],
        recompute_uncorrectable=kw["recompute_uncorrectable"],
        transactions=transactions, per_signal=per_signal, encoding=encoding)
    spec = fft_api.spec_for(x, rank=1, mesh=kw["mesh"], axis=kw["axis"],
                            natural_order=kw["natural_order"], ft=ft,
                            interpret=interpret)
    return fft_api.plan(spec).ft_fft(x, inject=inject, bs=bs)


@functools.partial(
    jax.jit,
    static_argnames=("transactions", "bs", "per_signal", "encoding",
                     "threshold", "interpret", "correct"),
)
def _ft_fft_local(
    x: jax.Array,
    *,
    transactions: int = 4,
    bs: int | None = None,
    per_signal: bool = False,
    encoding: str = "wang",
    threshold: float = 1e-4,
    correct: bool = True,
    interpret: bool | None = None,
    inject: jax.Array | None = None,
) -> FTFFTResult:
    """The single-device fused-kernel pipeline behind :func:`ft_fft`."""
    interpret = _auto_interpret(interpret)
    b, n = x.shape
    xr, xi = _split(x)
    plan = make_plan(n, batch=b, itemsize=xr.dtype.itemsize)
    if bs is None:
        bs = min(plan.bs, b)
    # batches not divisible by bs are padded with zero signals (the same
    # treatment _block_fft_c applies) — zero rows contribute nothing to the
    # group checksums and their 1-based location ids lie beyond the real
    # batch, so detection/location/correction are unaffected; the padded
    # rows are sliced back off below. (b // bs alone silently dropped the
    # remainder signals.)
    xr, _ = _pad_batch(xr, bs)
    xi, _ = _pad_batch(xi, bs)
    bp = xr.shape[0]
    tiles = bp // bs
    txn = min(transactions, tiles)
    while tiles % txn:
        txn -= 1
    yr, yi, delta, cs = abft_fft_pallas(
        xr, xi, plan=plan, bs=bs, transactions=txn, per_signal=per_signal,
        encoding=encoding, interpret=interpret, inject=inject)
    y = _join(yr, yi)

    sums = abft.GroupChecksums.from_packed(cs)
    verdict = abft.detect_locate(
        sums, forward=lambda c: block_fft_stages(c), threshold=threshold)
    if correct:
        y, _ = abft.apply_correction(y, verdict)
    return FTFFTResult(
        y=y[:b],
        delta=delta[:b],
        group_score=verdict.error_score,
        flagged=verdict.flagged,
        location=verdict.location,
        corrected=jnp.sum(verdict.flagged.astype(jnp.int32)),
    )
