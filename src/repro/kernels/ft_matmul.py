"""Fused two-side ABFT GEMM Pallas kernel: tiled matmul + in-kernel
checksum strips.

TPU analogue of the paper's fused threadblock ABFT applied to the GEMM view
(§2.2.2): while the MXU computes C = X @ W tile-by-tile, the kernel
accumulates the *output* checksum strips in VMEM scratch —

    out2 = e2^T C   (column sums)          vs  pred2 = (e2^T X) @ W
    out3 = e3^T C   (e3 = [1..M] location) vs  pred3 = (e3^T X) @ W

— where the predicted strips are computed in the same K loop from the tiny
precomputed ``e2^T X`` / ``e3^T X`` vectors, so the two-side scheme adds
zero extra HBM traffic over the matmul itself. The caller decodes ``d2 =
pred2 - out2`` / ``d3 = pred3 - out3`` per column (``d3/d2 = row + 1``) and
corrects in place — :func:`repro.core.abft.gemm.decode_columns`, the same
decode the interpreter path uses, so both backends agree by construction.

An optional in-kernel SEU injector perturbs the computed product *before*
the output strips accumulate (modeling a MAC-unit fault the checksums must
catch, not an HBM corruption they could not).

Grid: (N/bn, M/bm, K/bk) — K innermost (accumulate), M middle (checksum
strips accumulate across M tiles), N outer (strips emitted when their last
(m, k) tile completes).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ft_matmul_pallas", "FTMatmulChecks"]


class FTMatmulChecks(NamedTuple):
    """Product + the four fused checksum strips (each ``(N,)`` float32)."""

    c: jax.Array
    out2: jax.Array    # e2^T C   — fused output column sums
    pred2: jax.Array   # (e2^T X) @ W
    out3: jax.Array    # e3^T C   — fused location checksum, e3 = [1..M]
    pred3: jax.Array   # (e3^T X) @ W


def _kernel(nm, nk, bm, bn, nf, x_ref, w_ref, xsum_ref, xloc_ref, inj_ref,
            c_ref, out2_ref, pred2_ref, out3_ref, pred3_ref,
            acc_ref, col_acc, pred2_acc, row_acc, pred3_acc):
    n_i = pl.program_id(0)
    m_i = pl.program_id(1)
    k_i = pl.program_id(2)

    @pl.when((m_i == 0) & (k_i == 0))
    def _init_strip():
        col_acc[...] = jnp.zeros_like(col_acc)
        pred2_acc[...] = jnp.zeros_like(pred2_acc)
        row_acc[...] = jnp.zeros_like(row_acc)
        pred3_acc[...] = jnp.zeros_like(pred3_acc)

    @pl.when(k_i == 0)
    def _init_tile():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    w = w_ref[...]
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    # predicted strips: (e2^T X) @ W and (e3^T X) @ W, accumulated once
    # per (n, k) from the precomputed input checksums
    @pl.when(m_i == 0)
    def _pred():
        pred2_acc[...] += (xsum_ref[...] @ w).reshape(pred2_acc.shape)
        pred3_acc[...] += (xloc_ref[...] @ w).reshape(pred3_acc.shape)

    @pl.when(k_i == nk - 1)
    def _emit_tile():
        c = acc_ref[...]
        # in-kernel SEU injection: lands in the computed product BEFORE the
        # output strips accumulate — exactly what the scheme must detect
        rows = m_i * bm + jax.lax.broadcasted_iota(jnp.float32, (bm, bn), 0)
        cols = n_i * bn + jax.lax.broadcasted_iota(jnp.float32, (bm, bn), 1)
        inj = inj_ref[...]
        for f in range(nf):
            hit = (rows == inj[f, 0]) & (cols == inj[f, 1])
            c = c + jnp.where(hit, inj[f, 2] * inj[f, 3], 0.0)
        c_ref[...] = c.astype(c_ref.dtype)
        col_acc[...] += jnp.sum(c, axis=0, keepdims=True)
        loc = (m_i * bm + 1.0
               + jax.lax.broadcasted_iota(jnp.float32, (bm, 1), 0))
        row_acc[...] += jnp.sum(c * loc, axis=0, keepdims=True)

    @pl.when((k_i == nk - 1) & (m_i == nm - 1))
    def _emit_strip():
        out2_ref[...] = col_acc[...]
        pred2_ref[...] = pred2_acc[...]
        out3_ref[...] = row_acc[...]
        pred3_ref[...] = pred3_acc[...]


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def _ft_matmul_pallas(x, w, inj, *, bm, bn, bk, interpret):
    m, k = x.shape
    _, n = w.shape
    nm, nn, nk = m // bm, n // bn, k // bk
    xf = x.astype(jnp.float32)
    xsum = jnp.sum(xf, axis=0, keepdims=True)                     # e2^T X
    xloc = (jnp.arange(1, m + 1, dtype=jnp.float32)[None] @ xf)   # e3^T X

    grid = (nn, nm, nk)
    kernel = functools.partial(_kernel, nm, nk, bm, bn, inj.shape[0])
    strip = pl.BlockSpec((1, bn), lambda ni, mi, ki: (0, ni))
    c, out2, pred2, out3, pred3 = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda ni, mi, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda ni, mi, ki: (ki, ni)),
            pl.BlockSpec((1, bk), lambda ni, mi, ki: (0, ki)),
            pl.BlockSpec((1, bk), lambda ni, mi, ki: (0, ki)),
            pl.BlockSpec(inj.shape, lambda ni, mi, ki: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda ni, mi, ki: (mi, ni)),
            strip, strip, strip, strip,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), x.dtype),
            jax.ShapeDtypeStruct((1, n), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((1, bn), jnp.float32),
            pltpu.VMEM((1, bn), jnp.float32),
            pltpu.VMEM((1, bn), jnp.float32),
            pltpu.VMEM((1, bn), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, xsum, xloc, inj)
    return FTMatmulChecks(c, out2[0], pred2[0], out3[0], pred3[0])


def ft_matmul_pallas(x, w, *, bm=128, bn=128, bk=128,
                     interpret: bool | None = None,
                     inject: jax.Array | None = None) -> FTMatmulChecks:
    """Fused product + two-side checksum strips (:class:`FTMatmulChecks`).

    Detection/correction at the caller: ``d2 = pred2 - out2`` / ``d3 =
    pred3 - out3`` through :func:`repro.core.abft.gemm.decode_columns`.
    x: (M, K), w: (K, N). Dims must be multiples of the tile sizes (the
    ``core.gemm`` plan layer falls back to the interpreter path otherwise).

    ``interpret=None`` resolves per platform: the compiled Mosaic kernel on
    TPU, the Pallas interpreter elsewhere (CPU CI). ``inject`` is an
    optional ``(4,)`` ``[row, col, enable, eps]`` descriptor — or ``(F, 4)``
    for concurrent SEUs — applied to the computed product inside the kernel.
    """
    m, k = x.shape
    k2, n = w.shape
    if k2 != k:
        raise ValueError(f"contraction mismatch: x (M={m}, K={k}) vs "
                         f"w (K={k2}, N={n})")
    if m % bm or n % bn or k % bk:
        raise ValueError(
            f"fused ABFT GEMM needs tile-aligned dims: (M, K, N)="
            f"({m}, {k}, {n}) vs tiles (bm, bk, bn)=({bm}, {bk}, {bn}) — "
            f"pad the operands or use the interpreter path "
            f"(core.abft.gemm.ft_matmul)")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if inject is None:
        inj = jnp.zeros((1, 4), jnp.float32)
    else:
        inj = jnp.reshape(jnp.asarray(inject, jnp.float32), (-1, 4))
    return _ft_matmul_pallas(x, w, inj, bm=bm, bn=bn, bk=bk,
                             interpret=bool(interpret))
