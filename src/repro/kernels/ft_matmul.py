"""Fused ABFT GEMM Pallas kernel: tiled matmul + in-kernel left-checksum.

TPU analogue of the paper's fused threadblock ABFT applied to the GEMM view
(§2.2.2): while the MXU computes C = X @ W tile-by-tile, the kernel
accumulates the *output* column checksum e1^T C in VMEM scratch and compares
it against the *predicted* checksum (e1^T X) @ W — computed in the same K
loop from the (tiny) precomputed ``xsum = e1^T X`` vector, so detection adds
zero extra HBM traffic over the matmul itself. (In a fused network layer,
``xsum`` itself is produced by the upstream op's epilogue; see
``core/abft/gemm.py`` for the right-side correction math.)

Grid: (N/bn, M/bm, K/bk) — K innermost (accumulate), M middle (column
checksums accumulate across M tiles), N outer (checksum strip emitted when
its last (m, k) tile completes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ft_matmul_pallas"]


def _kernel(nm, nk, bm, bn, x_ref, w_ref, xsum_ref, c_ref, colck_ref,
            pred_ref, acc_ref, col_acc, pred_acc):
    n_i = pl.program_id(0)
    m_i = pl.program_id(1)
    k_i = pl.program_id(2)

    @pl.when((m_i == 0) & (k_i == 0))
    def _init_strip():
        col_acc[...] = jnp.zeros_like(col_acc)
        pred_acc[...] = jnp.zeros_like(pred_acc)

    @pl.when(k_i == 0)
    def _init_tile():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    w = w_ref[...]
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)
    # predicted checksum: (e1^T X) @ W, accumulated once per (n, k)
    @pl.when(m_i == 0)
    def _pred():
        pred_acc[...] += (xsum_ref[...] @ w).reshape(pred_acc.shape)

    @pl.when(k_i == nk - 1)
    def _emit_tile():
        c = acc_ref[...]
        c_ref[...] = c.astype(c_ref.dtype)
        col_acc[...] += jnp.sum(c, axis=0, keepdims=True)

    @pl.when((k_i == nk - 1) & (m_i == nm - 1))
    def _emit_strip():
        colck_ref[...] = col_acc[...]
        pred_ref[...] = pred_acc[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def ft_matmul_pallas(x, w, *, bm=128, bn=128, bk=128, interpret=True):
    """Returns (c, colck, pred): product + fused output/predicted checksums.

    Detection at the caller: ||colck - pred|| / ||pred|| > delta. x: (M, K)
    f32, w: (K, N) f32. Dims must be multiples of the tile sizes (ops-level
    callers pad).
    """
    m, k = x.shape
    _, n = w.shape
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k)
    nm, nn, nk = m // bm, n // bn, k // bk
    xsum = jnp.sum(x.astype(jnp.float32), axis=0, keepdims=True)  # e1^T X

    grid = (nn, nm, nk)
    kernel = functools.partial(_kernel, nm, nk, bm, bn)
    c, colck, pred = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda ni, mi, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda ni, mi, ki: (ki, ni)),
            pl.BlockSpec((1, bk), lambda ni, mi, ki: (0, ki)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda ni, mi, ki: (mi, ni)),
            pl.BlockSpec((1, bn), lambda ni, mi, ki: (0, ni)),
            pl.BlockSpec((1, bn), lambda ni, mi, ki: (0, ni)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), x.dtype),
            jax.ShapeDtypeStruct((1, n), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((1, bn), jnp.float32),
            pltpu.VMEM((1, bn), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, xsum)
    return c, colck[0], pred[0]
