"""Pallas TPU kernels for the paper's compute hot spots.

stockham.py       -- radix-<=128 MXU block FFT (BlockSpec VMEM tiling)
stockham_abft.py  -- + fused two-sided ABFT, multi-transaction accumulation
ft_matmul.py      -- ABFT-protected tiled GEMM (paper's scheme generalized)
ops.py            -- jit'd public wrappers (fft / ifft / ft_fft / ft_matmul)
ref.py            -- pure-jnp oracles
"""
from . import ops, ref
from .ops import fft, ifft, ft_fft, FTFFTResult
from repro.core.fft.api import FFTSpec, FTConfig, plan

__all__ = ["ops", "ref", "fft", "ifft", "ft_fft", "FTFFTResult",
           "FFTSpec", "FTConfig", "plan"]
