"""Pure-jnp oracles for every Pallas kernel in this package.

All oracles take/return the same split real/imag layout as the kernels so
tests can assert_allclose directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fft import factors

__all__ = [
    "fft_ref", "fft_ri_ref", "abft_fft_ref", "matmul_ref", "abft_matmul_ref",
]


def fft_ref(x: jax.Array, *, inverse: bool = False) -> jax.Array:
    """Complex oracle: jnp.fft (the platform library, cuFFT analogue)."""
    y = jnp.fft.ifft(x) if inverse else jnp.fft.fft(x)
    return y.astype(x.dtype)


def fft_ri_ref(xr: jax.Array, xi: jax.Array, *, inverse: bool = False):
    """Split real/imag oracle for the block FFT kernel. (B, N) -> (B, N)."""
    ctype = jnp.complex128 if xr.dtype == jnp.float64 else jnp.complex64
    y = fft_ref((xr + 1j * xi).astype(ctype), inverse=inverse)
    return y.real.astype(xr.dtype), y.imag.astype(xi.dtype)


def abft_fft_ref(xr, xi, *, transactions: int = 1, inverse: bool = False,
                 encoding: str = "wang"):
    """Oracle for the fused two-sided ABFT FFT kernel (no error injected).

    Returns (yr, yi, delta, cs_in, cs_out) where

    * ``delta``  — (B,) per-signal left-checksum relative divergence
      | (e1^T W) x_b - e1^T y_b | / (|(e1^T W) x_b| + eps)   (paper §4.1.1),
    * ``cs_in``  — (G, 2, 2, N) right-side input checksums per transaction
      group: [e2 = ones, e3 = location] x [re, im],
    * ``cs_out`` — same for outputs.

    G = B / (bs_tile * transactions) is emulated here with bs_tile == the
    kernel's tile size; the ref uses one group per ``group_size`` signals,
    provided by the caller via reshape — for the oracle we fold the whole
    batch into ceil(B / group) groups of ``transactions`` tiles handled by
    ``ops.abft_fft`` identically.
    """
    raise NotImplementedError("use ops.abft_fft_reference instead")


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def abft_matmul_ref(a, b):
    """Oracle for the ABFT GEMM kernel: product + exact checksum rows/cols."""
    c = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
    col_ck = jnp.sum(c, axis=0)   # e^T C (left)
    row_ck = jnp.sum(c, axis=1)   # C e (right)
    return c, col_ck, row_ck
