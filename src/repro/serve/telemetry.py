"""Serving telemetry: per-bucket counters, latency percentiles, ABFT
verdict aggregation.

One :class:`Telemetry` instance is shared by the scheduler and the worker
pool, so every mutation takes the internal lock; :meth:`Telemetry.snapshot`
returns plain dicts safe to hand across threads (and to ``json.dumps``).
"""
from __future__ import annotations

import dataclasses
import threading

import numpy as np

__all__ = ["BucketStats", "Telemetry", "percentiles"]

# the latency quantiles every snapshot reports, the serving counterpart of
# the HLO-volume asserts: p50 = typical, p95/p99 = the deadline tail
QUANTILES = (50.0, 95.0, 99.0)


def percentiles(latencies_s) -> dict:
    """``{"p50_ms", "p95_ms", "p99_ms"}`` of a latency sample (seconds in,
    milliseconds out; all-zero when the sample is empty)."""
    if not len(latencies_s):
        return {f"p{int(q)}_ms": 0.0 for q in QUANTILES}
    arr = np.asarray(latencies_s, dtype=np.float64) * 1e3
    vals = np.percentile(arr, QUANTILES)
    return {f"p{int(q)}_ms": float(v) for q, v in zip(QUANTILES, vals)}


@dataclasses.dataclass
class BucketStats:
    """Mutable per-bucket accumulator (guarded by the Telemetry lock).

    ``pad_elems``/``payload_elems`` carry the bucketer's padding waste:
    a request of 1000 points served from a 1024-point bucket adds 24 to
    ``pad_elems`` and 1000 to ``payload_elems``; empty batch slots add the
    whole canonical signal. ``ft_*`` counters aggregate the ABFT verdicts
    of every ft batch the bucket executed (detected = flagged groups).
    """

    submitted: int = 0
    completed: int = 0
    failed: int = 0
    rejected: int = 0
    timeouts: int = 0
    batches: int = 0
    batched_signals: int = 0          # filled slots over all closed batches
    batch_slots: int = 0              # max_batch * batches
    pad_elems: int = 0
    payload_elems: int = 0
    latencies_s: list = dataclasses.field(default_factory=list)
    queue_s: list = dataclasses.field(default_factory=list)
    ft_injected: int = 0
    ft_detected: int = 0
    ft_corrected: int = 0
    ft_uncorrectable: int = 0
    ft_checksum_faults: int = 0
    ft_recomputed: int = 0

    def snapshot(self) -> dict:
        d = {
            "submitted": self.submitted, "completed": self.completed,
            "failed": self.failed, "rejected": self.rejected,
            "timeouts": self.timeouts, "batches": self.batches,
            "batch_occupancy": (self.batched_signals / self.batch_slots
                                if self.batch_slots else 0.0),
            "pad_waste": (self.pad_elems /
                          (self.pad_elems + self.payload_elems)
                          if self.pad_elems + self.payload_elems else 0.0),
            **percentiles(self.latencies_s),
            "queue_p50_ms": percentiles(self.queue_s)["p50_ms"],
        }
        if any((self.ft_injected, self.ft_detected, self.ft_corrected,
                self.ft_uncorrectable, self.ft_checksum_faults,
                self.ft_recomputed)):
            d.update(injected=self.ft_injected, detected=self.ft_detected,
                     corrected=self.ft_corrected,
                     uncorrectable=self.ft_uncorrectable,
                     checksum_faults=self.ft_checksum_faults,
                     recomputed=self.ft_recomputed)
        return d


class Telemetry:
    """Thread-safe per-bucket serving stats."""

    def __init__(self):
        self._lock = threading.Lock()
        self._buckets: dict = {}

    def _stats(self, key) -> BucketStats:
        # callers hold self._lock
        st = self._buckets.get(key)
        if st is None:
            st = self._buckets[key] = BucketStats()
        return st

    def record_submit(self, key, *, injected: int = 0):
        with self._lock:
            st = self._stats(key)
            st.submitted += 1
            st.ft_injected += injected

    def record_reject(self, key):
        with self._lock:
            self._stats(key).rejected += 1

    def record_timeout(self, key, n: int = 1):
        with self._lock:
            self._stats(key).timeouts += n

    def record_batch(self, key, *, fill: int, slots: int,
                     pad_elems: int, payload_elems: int):
        with self._lock:
            st = self._stats(key)
            st.batches += 1
            st.batched_signals += fill
            st.batch_slots += slots
            st.pad_elems += pad_elems
            st.payload_elems += payload_elems

    def record_done(self, key, *, latency_s: float, queue_s: float):
        with self._lock:
            st = self._stats(key)
            st.completed += 1
            st.latencies_s.append(float(latency_s))
            st.queue_s.append(float(queue_s))

    def record_failed(self, key, n: int = 1):
        with self._lock:
            self._stats(key).failed += n

    def record_ft(self, key, *, detected: int = 0, corrected: int = 0,
                  uncorrectable: int = 0, checksum_faults: int = 0,
                  recomputed: int = 0):
        with self._lock:
            st = self._stats(key)
            st.ft_detected += detected
            st.ft_corrected += corrected
            st.ft_uncorrectable += uncorrectable
            st.ft_checksum_faults += checksum_faults
            st.ft_recomputed += recomputed

    def snapshot(self) -> dict:
        """``{bucket label: stats dict}`` — a point-in-time copy."""
        with self._lock:
            return {getattr(k, "label", str(k)): st.snapshot()
                    for k, st in sorted(self._buckets.items(),
                                        key=lambda kv: str(kv[0]))}
