"""Multi-tenant FFT serving runtime: bucketed admission, deadline batching,
and a worker pool over the cached plan executors.

Architecture (the layer ``launch.serve --mode serve`` is a thin CLI over)::

    client threads          scheduler                worker pool
    ─────────────          ──────────               ───────────
    submit(x, op=..) ──> SpecBucketer.key_for
                         admission: one FFTPlan per bucket (warmup once)
                         DeadlineBatcher.submit ──> per-bucket pending
                               │ close on max_batch or deadline_ms
                               ▼
                         ready batches ──────────> N worker threads:
                                                   pad + stack payloads,
                                                   serve_plan(plan, xb),
                                                   scatter rows to handles,
                                                   telemetry per bucket

Requests are SINGLE signals (``(n,)`` or ``(r, c)``); the runtime pads each
to its bucket's canonical transform shape (zero extension — the
``np.fft.fft(x, n)`` contract, see ``bucketing``) and zero-fills empty
batch slots. One plan per bucket is built and warmed at admission, so the
steady state never traces or resolves; the shared plan LRU
(``core.plan``, thread-safe) is what keeps restarted or evicted buckets
cheap to re-admit.

``ft=True`` buckets run the ABFT pipeline online: per-request SEU
descriptors (tests / fault-injection campaigns) ride
:class:`~repro.serve.scheduler.ServeRequest.inject` with signal indices
relative to the request, and the runtime offsets them to batch rows; the
per-bucket verdict telemetry (injected/detected/corrected/uncorrectable)
aggregates over every batch the bucket executed.
"""
from __future__ import annotations

import dataclasses
import threading

import numpy as np

from repro.core.plan import FTConfig, plan_cache_info
from repro.serve.bucketing import BucketKey, SpecBucketer
from repro.serve.scheduler import (Batch, DeadlineBatcher, QueueFullError,
                                   RequestHandle, RequestTimeoutError,
                                   RuntimeClosedError, ServeRequest)
from repro.serve.specs import serve_plan
from repro.serve.telemetry import Telemetry

__all__ = ["RuntimeConfig", "ServeRuntime", "Fault"]


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injected SEU, addressed relative to the carrying request:
    perturb the request's signal at transform coordinate (``row``,
    ``col``) by ``eps_re + i*eps_im`` inside the protected region. The
    runtime translates it to the executing pipeline's descriptor format
    (fused local kernel or sharded grouped ABFT) and to the request's
    batch row."""

    col: int = 1
    row: int = 1
    eps_re: float = 200.0
    eps_im: float = 0.0


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Scheduler + pool policy for one :class:`ServeRuntime`.

    ``max_batch`` is both the coalescing limit and every bucket plan's
    batch dimension; ``deadline_ms`` bounds how long a lone request waits
    for companions; ``queue_depth`` is the backpressure bound over ALL
    pending requests; ``timeout_ms`` (None = never) fails requests that
    age out unbatched. ``ft`` is the FTConfig attached to ``ft=True``
    buckets at admission."""

    max_batch: int = 8
    deadline_ms: float = 2.0
    queue_depth: int = 64
    workers: int = 2
    timeout_ms: float | None = None
    chunks: int = 1
    ft: FTConfig = FTConfig(threshold=1e-4, correct=True,
                            recompute_uncorrectable=True)

    def __post_init__(self):
        if self.workers < 1:
            raise ValueError(f"workers must be >= 1, got {self.workers}")


class ServeRuntime:
    """The serving runtime: ``submit`` returns a
    :class:`~repro.serve.scheduler.RequestHandle`; ``close`` drains."""

    def __init__(self, config: RuntimeConfig | None = None, *, mesh=None):
        self.config = config or RuntimeConfig()
        self.mesh = mesh
        cfg = self.config
        self.bucketer = SpecBucketer(mesh=mesh, max_batch=cfg.max_batch,
                                     chunks=cfg.chunks)
        self.telemetry = Telemetry()
        self.batcher = DeadlineBatcher(
            max_batch=cfg.max_batch, deadline_ms=cfg.deadline_ms,
            queue_depth=cfg.queue_depth, timeout_ms=cfg.timeout_ms,
            on_timeout=self.telemetry.record_timeout)
        self._plans: dict[BucketKey, object] = {}
        self._admission = threading.Lock()
        # collective programs rendezvous across ALL mesh devices: two
        # worker threads launching sharded executors concurrently would
        # interleave their participants and deadlock the all-to-all, so
        # sharded dispatch is serialized (workers still overlap batch
        # assembly/scatter with the running collective)
        self._mesh_lock = threading.Lock()
        self._closed = False
        self._workers = [
            threading.Thread(target=self._worker_loop,
                             name=f"serve-worker-{i}", daemon=True)
            for i in range(cfg.workers)]
        for t in self._workers:
            t.start()

    # -- admission ---------------------------------------------------------

    def admit(self, key: BucketKey):
        """Resolve (once) the bucket's plan: build the padded batched
        FFTSpec, plan it through the shared cache, and warm the executor
        with a zero batch so the first real request never traces. Raises
        with the spec's validation error when the bucket is infeasible on
        this mesh — admission is where bad geometry surfaces."""
        p = self._plans.get(key)
        if p is not None:
            return p
        with self._admission:
            p = self._plans.get(key)
            if p is not None:
                return p
            from repro.core.fft import api
            spec = self.bucketer.spec_for(
                key, ft_config=self.config.ft if key.ft else None)
            p = api.plan(spec)
            xb = np.zeros((self.config.max_batch,) + key.tshape,
                          dtype=self._payload_dtype(p))
            if p.sharded:                       # see _mesh_lock
                with self._mesh_lock:
                    serve_plan(p, xb, op=key.op)    # warmup: trace + compile
            else:
                serve_plan(p, xb, op=key.op)
            self._plans[key] = p
            return p

    def _payload_dtype(self, plan) -> np.dtype:
        return np.dtype(plan._rdtype if plan.spec.real
                        else plan.spec.dtype)

    # -- client API --------------------------------------------------------

    def submit(self, x, *, op: str = "fft", real: bool = False,
               ft: bool = False, faults=None,
               timeout_ms: float | None = None) -> RequestHandle:
        """Admit one single-signal request; returns its handle.

        ``faults`` (ft buckets only): a :class:`Fault` or sequence of them
        to inject into THIS request's rows — the fault-injection campaign
        interface the serving benchmark drives from a ``FaultSchedule``.
        """
        if self._closed:
            raise RuntimeClosedError("serve runtime is closed")
        x = np.asarray(x)
        key = self.bucketer.key_for(x.shape, x.dtype, op=op, real=real,
                                    ft=ft)
        if faults is not None and not ft:
            raise ValueError("faults= requires an ft=True bucket")
        faults = ((faults,) if isinstance(faults, Fault)
                  else tuple(faults or ()))
        self.admit(key)
        handle = RequestHandle()
        req = ServeRequest(key=key, x=x, handle=handle, inject=faults,
                           timeout_ms=timeout_ms)
        self.telemetry.record_submit(key, injected=len(faults))
        try:
            self.batcher.submit(req)
        except (QueueFullError, RuntimeClosedError):
            self.telemetry.record_reject(key)
            raise
        return handle

    # -- worker pool -------------------------------------------------------

    def _worker_loop(self):
        while True:
            batch = self.batcher.next_batch()
            if batch is None:
                return
            try:
                self._execute(batch)
            except BaseException as e:
                for r in batch.requests:
                    if not r.handle.done():
                        r.handle.set_error(e)
                self.telemetry.record_failed(batch.key, len(batch.requests))

    def _execute(self, batch: Batch):
        key = batch.key
        plan = self._plans[key]
        cfg = self.config
        fill = len(batch.requests)
        xb = np.zeros((cfg.max_batch,) + key.tshape,
                      dtype=self._payload_dtype(plan))
        pad = payload = 0
        for i, r in enumerate(batch.requests):
            sig = np.asarray(r.x)
            if key.rank == 1:
                xb[i, :sig.shape[0]] = sig
            else:
                xb[i, :sig.shape[0], :sig.shape[1]] = sig
            pad += self.bucketer.pad_elems(key, sig.shape)
            payload += int(sig.size)
        pad += (cfg.max_batch - fill) * int(np.prod(key.tshape,
                                                    dtype=np.int64))
        inject, bs = self._build_inject(plan, batch)
        if plan.sharded:
            with self._mesh_lock:
                y, info = serve_plan(plan, xb, op=key.op, inject=inject)
                y = np.asarray(y)
        elif bs is not None:
            y, info = self._ft_with_bs(plan, xb, inject, bs)
            y = np.asarray(y)
        else:
            y, info = serve_plan(plan, xb, op=key.op, inject=inject)
            y = np.asarray(y)
        self.telemetry.record_batch(
            key, fill=fill, slots=cfg.max_batch, pad_elems=pad,
            payload_elems=payload)
        if key.ft:
            self._record_ft(key, info)
        base = {"bucket": key.label, "nfft": key.tshape,
                "batch_fill": fill}
        for i, r in enumerate(batch.requests):
            r.handle.set_result(y[i], {**base, **info})
            self.telemetry.record_done(key, latency_s=r.handle.latency_s,
                                       queue_s=r.handle.queue_s)

    def _ft_with_bs(self, plan, xb, inject, bs):
        """Local fused-kernel ft path with the runtime's fixed tile size
        (one tile = the whole batch), so injected rows address tiles
        deterministically."""
        import jax.numpy as jnp
        res = plan.ft_fft(plan.shard(xb), inject=inject, bs=bs)
        flagged = np.asarray(res.flagged)
        g = int(np.argmax(flagged)) if flagged.any() else -1
        info = {"op": "fft", "shards": plan.shards, "data": plan.dsize,
                "ft": True, "score": float(jnp.max(res.group_score)),
                "flagged": bool(flagged.any()),
                "location": int(np.asarray(res.location)[g]) if g >= 0
                else -1,
                "corrected": int(res.corrected)}
        return res.y, info

    def _build_inject(self, plan, batch: Batch):
        """Translate per-request :class:`Fault` descriptors into the
        executing pipeline's inject array (batch-row offsets applied).
        Returns ``(inject, bs)``; ``bs`` is non-None only on the local
        fused-kernel path (where the tile size must be pinned so ``tile =
        row // bs`` is well-defined)."""
        key = batch.key
        if not key.ft:
            return None, None
        rows = [(i, f) for i, r in enumerate(batch.requests)
                for f in r.inject]
        if not rows:
            return None, None
        if key.rank != 1:
            raise ValueError("runtime fault injection targets rank-1 ft "
                             "buckets (the serving campaign surface)")
        if plan.sharded:
            from repro.core.fft.distributed import make_dist_plan
            dp = make_dist_plan(key.tshape[0], plan.shards)
            n2l = dp.n2 // plan.shards
            out = []
            for brow, f in rows:
                c = f.col % dp.n2   # pass-1 output column (global n2 index)
                out.append([c // n2l, brow, f.row % dp.n1, c % n2l,
                            1.0, f.eps_re, f.eps_im])
            ftype = np.float64 if plan.spec.dtype == "complex128" \
                else np.float32
            return np.asarray(out, dtype=ftype), None
        # local fused kernel: ONE (6,) descriptor [tile, row, col, enable,
        # eps_re, eps_im]; pin bs = full batch so tile is always 0
        if len(rows) > 1:
            raise ValueError(
                "the local fused kernel injects at most one SEU per batch "
                "(single in-kernel descriptor) — space the campaign so "
                "batches carry one fault, or serve ft on a mesh")
        brow, f = rows[0]
        n = key.tshape[0]
        return (np.asarray([0, brow, f.col % n, 1, f.eps_re, f.eps_im],
                           dtype=np.float32),
                self.config.max_batch)

    def _record_ft(self, key, info: dict):
        detected = info.get("flagged", 0)
        self.telemetry.record_ft(
            key,
            detected=int(detected if not isinstance(detected, bool)
                         else detected),
            corrected=int(info.get("corrected", 0)),
            uncorrectable=int(info.get("uncorrectable", 0)),
            checksum_faults=int(info.get("checksum_faults", 0)),
            recomputed=int(info.get("recomputed", 0)))

    # -- introspection / lifecycle ----------------------------------------

    def stats(self) -> dict:
        """Telemetry snapshot + plan-cache stats + resolved bucket plans."""
        info = plan_cache_info()
        return {
            "buckets": self.telemetry.snapshot(),
            "plan_cache": {"hits": info.hits, "misses": info.misses,
                           "currsize": info.currsize},
            "plans": {k.label: repr(p) for k, p in self._plans.items()},
        }

    def drain(self):
        """Block until every pending request is batched and executed."""
        self.batcher.flush()
        while self.batcher.pending or self.batcher.ready:
            threading.Event().wait(0.002)

    def close(self, *, drain: bool = True):
        """Stop admissions; drain (or fail) pending work; join workers."""
        if self._closed:
            return
        self._closed = True
        self.batcher.close(drain=drain)
        for t in self._workers:
            t.join(timeout=30)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close(drain=not any(exc))
        return False
