"""Spec bucketer: map incoming request geometries onto a small set of
padded canonical :class:`~repro.core.fft.api.FFTSpec` buckets.

Plans are shape-specialized (``cufftPlanMany`` semantics), so serving raw
request sizes would build one plan per distinct ``n`` and thrash the shared
plan LRU. The bucketer instead rounds every transform axis up to the next
power of two and then applies the same round-up trick the real slab uses
for its ``C/2 + D`` half-spectrum transpose: pad until the mesh divides the
axis (pencil feasibility ``n >= shards^2``; ``n/2 >= shards^2`` for packed
real pencils), so every bucket's plan is mesh-feasible by construction.
A handful of buckets then absorbs the whole request distribution and the
plan cache stays hot.

Padded serving semantics: a request of ``n_req`` points served from an
``n``-point bucket receives the ``n``-point transform of its zero-padded
signal (``np.fft.fft(x, n)`` — trailing-zero extension, the standard
spectral-interpolation contract). Power-of-two requests on a feasible mesh
map to themselves (zero padding). The per-bucket padded-element waste is
recorded in telemetry (``pad_waste``).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.fft.spectral import _next_pow2 as next_pow2

__all__ = ["BucketKey", "SpecBucketer", "pad_transform_shape", "next_pow2"]

# ops the scheduler can coalesce: every request in a batch runs the same
# executor with no per-request operands beyond the signal itself.
# convolve/correlate carry per-request kernels and are served unbatched
# through serve_plan (admission rejects them with a pointer there).
BATCHABLE_OPS = ("fft", "spectrum")


def pad_transform_shape(tshape, *, shards: int = 1,
                        real: bool = False) -> tuple[int, ...]:
    """Canonical (padded) transform shape for a requested ``tshape``.

    Every axis rounds up to the next power of two; the last axis is
    additionally rounded up until the pencil digit split is feasible over
    ``shards`` devices (``n >= shards**2``; packed real pencils transform
    the half-length signal, so ``n/2 >= shards**2``) — the same
    round-up-until-the-mesh-divides logic as the half-spectrum ``C/2 + D``
    column padding. Power-of-two shard counts keep divisibility implied by
    the power-of-two rounding.
    """
    if not tshape or any(int(s) <= 0 for s in tshape):
        raise ValueError(f"transform shape must be positive, got {tshape!r}")
    padded = [next_pow2(int(s)) for s in tshape]
    if shards > 1:
        floor = shards * shards * (2 if real and len(tshape) == 1 else 1)
        padded[-1] = max(padded[-1], next_pow2(floor))
        if len(tshape) >= 2:
            # slab feasibility: shards must divide the first grid axis too
            padded[0] = max(padded[0], shards)
    return tuple(padded)


@dataclasses.dataclass(frozen=True)
class BucketKey:
    """Hashable identity of one serving bucket: the canonical transform
    the bucket's plan is built for. Two requests with the same key share a
    plan, a batch queue, and a telemetry row."""

    tshape: tuple[int, ...]      # canonical (padded) transform axes
    rank: int
    dtype: str                   # canonical complex dtype of the plan
    op: str                      # "fft" | "spectrum"
    real: bool
    ft: bool

    @property
    def label(self) -> str:
        """Short stable name for telemetry tables / logs."""
        size = "x".join(str(s) for s in self.tshape)
        tags = [self.op, size, self.dtype.replace("complex", "c")]
        if self.real:
            tags.append("real")
        if self.ft:
            tags.append("ft")
        return ":".join(tags)


class SpecBucketer:
    """Maps request geometries to :class:`BucketKey`\\ s and builds each
    bucket's :class:`~repro.core.fft.api.FFTSpec` exactly once.

    The bucketer is pure policy — it holds no queues and no plans (the
    runtime owns those); it only decides *which* canonical transform a
    request is served from and how much padding that costs.
    """

    def __init__(self, *, mesh=None, max_batch: int = 8, chunks: int = 1):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.mesh = mesh
        self.max_batch = int(max_batch)
        self.chunks = int(chunks)
        self.shards = (mesh.shape["fft"]
                       if mesh is not None and "fft" in mesh.axis_names
                       else 1)

    # -- request -> bucket -------------------------------------------------

    def key_for(self, shape, dtype, *, op: str = "fft",
                real: bool = False, ft: bool = False) -> BucketKey:
        """Bucket for one request signal of ``shape`` (a single signal —
        ``(n,)`` or ``(r, c)`` — not a batch) and ``dtype``."""
        if op not in BATCHABLE_OPS:
            raise ValueError(
                f"the scheduler buckets op in {BATCHABLE_OPS} (shared "
                f"executor, no per-request operands); got {op!r} — serve "
                f"convolve/correlate unbatched through serve_plan")
        if ft and op != "fft":
            raise ValueError(
                f"ABFT protection covers op='fft' (the grouped two-side "
                f"pipeline); got ft=True with op={op!r}")
        rank = len(tuple(shape))
        if rank not in (1, 2):
            raise ValueError(f"requests are single signals — (n,) or "
                             f"(r, c) — got shape {tuple(shape)}")
        dt = jnp.dtype(dtype)
        if real and jnp.issubdtype(dt, jnp.complexfloating):
            raise ValueError(f"real=True buckets take real signals, "
                             f"got {dt.name}")
        # canonical complex dtype of the plan (spec_for's coercion rules:
        # real f64 keeps complex128, everything narrow plans complex64)
        if jnp.issubdtype(dt, jnp.complexfloating):
            cdt = dt.name
        else:
            cdt = "complex128" if (real and dt == jnp.float64) \
                else "complex64"
        tshape = pad_transform_shape(tuple(shape), shards=self.shards,
                                     real=real)
        return BucketKey(tshape=tshape, rank=rank, dtype=cdt, op=op,
                         real=bool(real), ft=bool(ft))

    def pad_elems(self, key: BucketKey, shape) -> int:
        """Padded elements this request wastes in its bucket slot."""
        return int(np.prod(key.tshape, dtype=np.int64)
                   - np.prod(tuple(shape), dtype=np.int64))

    # -- bucket -> spec ----------------------------------------------------

    def spec_for(self, key: BucketKey, *, ft_config=None):
        """The bucket's batched :class:`~repro.core.fft.api.FFTSpec`:
        ``(max_batch, *tshape)``, one plan per bucket. ``ft_config`` (an
        :class:`~repro.core.plan.FTConfig`) attaches the ABFT pipeline to
        ``ft=True`` buckets; non-ft buckets ignore it."""
        from repro.serve.specs import build_fft_spec

        if key.ft and ft_config is None:
            raise ValueError(f"bucket {key.label} is ft=True — the runtime "
                             f"must supply its FTConfig at admission")
        kw = {}
        if key.ft:
            kw = dict(ft=True, threshold=ft_config.threshold,
                      groups=ft_config.groups,
                      group_size=ft_config.group_size,
                      recompute_uncorrectable=
                      ft_config.recompute_uncorrectable)
        return build_fft_spec(
            (self.max_batch,) + key.tshape, mesh=self.mesh, op=key.op,
            dims=key.rank, dtype=key.dtype, real=key.real,
            chunks=self.chunks, **kw)
