"""Request-description front of the serving stack: one request geometry ->
the :class:`~repro.core.fft.api.FFTSpec` its plan is built from, plus the
single-batch executor (:func:`serve_plan`) and the consolidated
``--fft-spec`` string parser.

This is the layer ``launch.serve`` (the CLI) and ``repro.serve.runtime``
(the multi-tenant scheduler) share: the CLI builds ONE plan per worker from
it; the runtime builds one plan per *bucket* from it.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["build_fft_spec", "serve_plan", "apply_fft_spec_arg",
           "SPEC_KEYS"]


def build_fft_spec(shape, *, mesh=None, op: str = "fft",
                   kernel_shape=None, dims: int | None = None,
                   decomp: str = "auto", ft: bool = False,
                   threshold: float = 1e-4, groups: int | None = None,
                   group_size: int | None = None,
                   recompute_uncorrectable: bool = True,
                   natural_order: bool | None = None,
                   dtype="complex64", real: bool = False,
                   chunks: int = 1):
    """Resolve one serving request description into the
    :class:`~repro.core.fft.api.FFTSpec` its plan is built from.

    ``shape`` is the request batch shape — ``(B, N)`` for 1-D, ``(B, R,
    C)`` for 2-D. For ``op="convolve"``/``"correlate"`` the spec describes
    the PADDED transform the spectral pipeline actually runs (last axes
    padded to a power of two covering the linear result), so one plan
    serves every request of that operand geometry. ``natural_order=None``
    resolves the per-op default: the order-agnostic periodogram stays
    transposed on a mesh (the digit restore is pure waste for ``|X|^2``),
    everything else is natural. The old serve flags are sugar over this
    builder — see ``--fft-spec``.

    ``real=True`` (``--fft-spec "real=1"``) declares real-valued request
    traffic: ``op="fft"`` serves the half-spectrum ``rfft``/``rfft2``
    executors, ``op="spectrum"`` the one-sided periodogram, and
    convolve/correlate ride the packed real pipelines — roughly half the
    C2C collective bytes on a mesh. Real plans are natural-order only.

    ``chunks`` (``--fft-spec "chunks=4"`` or ``"chunks=auto"``) is the
    multi-transaction overlap knob: the plan splits the batch into that
    many transactions so each transaction's all-to-all hides behind the
    next one's local Stockham passes (0 = auto; see
    :class:`~repro.core.fft.api.FFTSpec`).
    """
    from repro.core.fft import api, multidim, spectral

    dims = dims if dims is not None else max(1, len(shape) - 1)
    if dims not in (1, 2):
        raise ValueError(f"dims must be 1 or 2, got {dims}")
    if op not in ("fft", "convolve", "correlate", "spectrum"):
        raise ValueError(f"op must be fft|convolve|correlate|spectrum, "
                         f"got {op!r}")
    if op == "correlate" and dims == 2:
        raise ValueError("op='correlate' is 1-D only; dims=2 serves "
                         "fft|convolve|spectrum")
    if len(shape) != dims + 1:
        raise ValueError(f"dims={dims} expects a (batch, ...) shape with "
                         f"{dims} transform axes, got {tuple(shape)}")
    if real and natural_order is False:
        raise ValueError("real serve traffic is natural-order only — the "
                         "half spectrum indexes bins by k (drop "
                         "transposed=1 or real=1)")
    sharded = mesh is not None and "fft" in mesh.axis_names \
        and mesh.shape["fft"] > 1
    ft_cfg = None
    if ft and op == "fft":
        ft_cfg = api.FTConfig(threshold=threshold, groups=groups,
                              group_size=group_size,
                              recompute_uncorrectable=recompute_uncorrectable)
    if op in ("convolve", "correlate"):
        if kernel_shape is None:
            raise ValueError(f"op={op!r} needs a kernel")
        if dims == 1:
            nfft = spectral._conv_nfft(shape[-1], kernel_shape[-1], mesh,
                                       "fft")
            shape = tuple(shape[:-1]) + (nfft,)
        else:
            shards = mesh.shape["fft"] if sharded else 1
            nr = max(spectral._next_pow2(shape[-2] + kernel_shape[-2] - 1),
                     shards)
            nc = max(spectral._next_pow2(shape[-1] + kernel_shape[-1] - 1),
                     shards)
            shape = tuple(shape[:-2]) + (nr, nc)
            if real and sharded \
                    and not multidim.rslab_feasible((nr, nc), shards):
                decomp = "auto"   # the composed real path covers the rest
            else:
                decomp = "slab" if sharded else "auto"
        natural_order = True
    elif natural_order is None:
        # the per-op order default of the legacy endpoint; real spectra
        # are one-sided (bins indexed by k) and so always natural
        natural_order = real or not (sharded and op == "spectrum")
    return api.FFTSpec(shape=tuple(int(s) for s in shape),
                       dtype=jnp.dtype(dtype).name, rank=dims, mesh=mesh,
                       axis="fft", decomp="auto" if dims == 1 else decomp,
                       natural_order=bool(natural_order), ft=ft_cfg,
                       real=bool(real), chunks=int(chunks))


def _ft_telemetry(plan, res, info):
    """DistFFTResult -> the serve telemetry dict (grouped verdict counts)."""
    flagged = np.asarray(res.flagged)
    # the decoded location is only meaningful for correctable (single
    # data-fault) groups — checksum-row and multi-fault verdicts clip it
    # to an arbitrary healthy signal, which must not be reported
    correctable = np.asarray(res.correctable)
    locs = np.asarray(res.location)
    info.update(
        ft=True, groups=plan.groups,
        group_size=plan.batch // plan.groups,
        score=float(jnp.max(res.group_score)),
        flagged=int(flagged.sum()),
        locations=[int(l) for l, c in zip(locs, correctable) if c],
        corrected=int(res.corrected),
        uncorrectable=int(np.asarray(res.uncorrectable).sum()),
        checksum_faults=int(np.asarray(res.checksum_fault).sum()),
        recomputed=int(res.recomputed),
        shard_delta_max=float(jnp.max(res.shard_delta)))
    return info


def serve_plan(plan, x, *, op: str = "fft", kernel=None, mode: str = "same",
               inject=None):
    """Serve one batched request through a pre-built
    :class:`~repro.core.fft.api.FFTPlan` — the hot path: every dispatch
    decision (mesh, decomposition, ABFT groups, digit order) was resolved
    when the plan was built, so this is a straight executor call plus
    telemetry assembly. ``inject`` (ft plans only, tests/benchmarks) is
    forwarded to the ABFT pipeline's SEU injector. Returns ``(y, info)``.
    """
    x = jnp.asarray(x)
    info = {"shards": plan.shards, "data": plan.dsize, "op": op}
    if plan.chunks > 1:
        info["chunks"] = plan.chunks
    if plan.rank == 2:
        info["dims"] = 2
        info["decomp"] = plan.decomp
    if plan.spec.real:
        info["real"] = True
    transposed = (plan.sharded and not plan.spec.natural_order
                  and (plan.rank == 1 or plan.decomp == "pencil"))
    if op in ("convolve", "correlate"):
        if kernel is None:
            raise ValueError(f"op={op!r} needs a kernel")
        fn = plan.convolve if op == "convolve" else plan.correlate
        y = fn(x, kernel, mode=mode)
        info.update(order="natural",
                    collectives="2 a2a" if plan.sharded else "local")
        return y, info
    if op == "spectrum":
        y = plan.power_spectrum(x)
        info["order"] = "transposed" if transposed else "natural"
        return y, info
    if op != "fft":
        raise ValueError(f"op must be fft|convolve|correlate|spectrum, "
                         f"got {op!r}")
    xs = plan.shard(x)
    if plan.spec.ft is not None:
        res = plan.ft_fft(xs, inject=inject)
        if not plan.sharded:
            # single device: the fused-kernel two-side ABFT telemetry
            flagged = np.asarray(res.flagged)
            g = int(np.argmax(flagged)) if flagged.any() else -1
            info.update(
                ft=True, score=float(jnp.max(res.group_score)),
                flagged=bool(flagged.any()),
                location=int(np.asarray(res.location)[g]) if g >= 0 else -1,
                corrected=int(res.corrected))
            return res.y, info
        return res.y, _ft_telemetry(plan, res, info)
    y = plan.rfft(xs) if plan.spec.real else plan.fft(xs)
    info.update(ft=False)
    if plan.sharded:
        info["order"] = "transposed" if transposed else "natural"
    return y, info


def _parse_chunks(v: str) -> int:
    """``chunks=`` values: a transaction count, or ``auto`` (-> 0, the
    plan-resolved choice from the collective-volume model)."""
    if v.strip().lower() == "auto":
        return 0
    c = int(v)
    if c < 0:
        raise ValueError(f"chunks must be >= 0 (0 = auto), got {c}")
    return c


SPEC_KEYS = {
    # --fft-spec "k=v,..." keys -> (argparse dest, parser)
    "n": ("fft_n", int), "batch": ("batch", int),
    "shards": ("fft_shards", int), "data": ("fft_data", int),
    "dims": ("fft_dims", int), "rows": ("fft_rows", int),
    "cols": ("fft_cols", int), "op": ("fft_op", str),
    "decomp": ("fft_decomp", str), "ft": ("ft", None),
    "groups": ("fft_groups", int), "kernel_n": ("fft_kernel_n", int),
    "transposed": ("transposed", None), "threshold": ("fft_threshold", float),
    "real": ("fft_real", None), "chunks": ("fft_chunks", _parse_chunks),
    # serving-runtime keys (--serve-* flag dests): one string describes the
    # whole multi-tenant worker — plan geometry AND scheduler policy
    "workers": ("serve_workers", int),
    "max_batch": ("serve_max_batch", int),
    "deadline_ms": ("serve_deadline_ms", float),
    "queue": ("serve_queue_depth", int),
    "timeout_ms": ("serve_timeout_ms", float),
}


def _parse_bool(v: str) -> bool:
    if v.lower() in ("1", "true", "yes", "on", ""):
        return True
    if v.lower() in ("0", "false", "no", "off"):
        return False
    raise ValueError(f"expected a boolean, got {v!r}")


def apply_fft_spec_arg(args, s: str):
    """Apply a consolidated ``--fft-spec "n=65536,batch=8,shards=4,ft=1"``
    string onto the parsed args — one flag describing the whole worker
    plan (and, with the ``workers``/``max_batch``/``deadline_ms``/
    ``queue``/``timeout_ms`` keys, the serving runtime's scheduler policy);
    the individual ``--fft-*`` / ``--serve-*`` flags remain as sugar and
    provide the defaults the spec string overrides.

    The string is validated strictly: an empty segment (a stray comma, as
    in ``"n=8,,n=16"``) and a repeated key both raise ``ValueError`` naming
    the offending segment — a worker must not start from a plan description
    that silently dropped or last-won half of what the operator wrote."""
    seen: set[str] = set()
    for pos, item in enumerate(s.split(","), 1):
        item = item.strip()
        if not item:
            raise ValueError(
                f"--fft-spec: empty segment at position {pos} of {s!r} — "
                f"drop the stray comma")
        k, _, v = item.partition("=")
        k = k.strip()
        if k not in SPEC_KEYS:
            raise SystemExit(
                f"--fft-spec: unknown key {k!r} (valid: "
                f"{', '.join(sorted(SPEC_KEYS))})")
        if k in seen:
            raise ValueError(
                f"--fft-spec: duplicate key {k!r} (segment {pos}: {item!r} "
                f"in {s!r}) — each key may appear once; last-wins would "
                f"silently mask which value the worker plans with")
        seen.add(k)
        dest, parse = SPEC_KEYS[k]
        setattr(args, dest, _parse_bool(v) if parse is None else parse(v))
    return args
