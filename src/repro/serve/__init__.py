"""Multi-tenant FFT serving: spec bucketing, deadline batching, and a
worker pool over the shared (thread-safe) plan cache.

Quick start::

    from repro.serve import RuntimeConfig, ServeRuntime

    with ServeRuntime(RuntimeConfig(max_batch=8, deadline_ms=2.0)) as rt:
        h = rt.submit(x, op="fft")          # x: one (n,) or (r, c) signal
        y = h.result(timeout=5.0)           # padded-bucket transform of x

``launch.serve --mode serve`` is the CLI over this package; the modules
split policy from mechanism: ``bucketing`` (request -> canonical padded
spec), ``scheduler`` (deadline batching + backpressure), ``runtime`` (the
pool), ``telemetry`` (per-bucket stats), ``specs`` (spec construction and
the single-batch executor shared with the CLI).
"""
from repro.serve.bucketing import (BATCHABLE_OPS, BucketKey, SpecBucketer,
                                   pad_transform_shape)
from repro.serve.runtime import Fault, RuntimeConfig, ServeRuntime
from repro.serve.scheduler import (Batch, DeadlineBatcher, QueueFullError,
                                   RequestHandle, RequestTimeoutError,
                                   RuntimeClosedError, ServeRequest)
from repro.serve.specs import (SPEC_KEYS, apply_fft_spec_arg, build_fft_spec,
                               serve_plan)
from repro.serve.telemetry import BucketStats, Telemetry, percentiles

__all__ = [
    "BATCHABLE_OPS", "BucketKey", "SpecBucketer", "pad_transform_shape",
    "Fault", "RuntimeConfig", "ServeRuntime",
    "Batch", "DeadlineBatcher", "QueueFullError", "RequestHandle",
    "RequestTimeoutError", "RuntimeClosedError", "ServeRequest",
    "SPEC_KEYS", "apply_fft_spec_arg", "build_fft_spec", "serve_plan",
    "BucketStats", "Telemetry", "percentiles",
]
