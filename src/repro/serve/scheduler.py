"""Deadline batcher: coalesce same-bucket requests into planned batches
under a latency deadline, with bounded-queue backpressure.

The contract (documented in README "Serving runtime"):

* a batch CLOSES when its bucket holds ``max_batch`` requests or when its
  oldest request has waited ``deadline_ms`` — whichever comes first. A
  full batch closes inline on the submitting thread (no deadline-thread
  hop on the hot path); deadlines are enforced by one background timer
  thread;
* backpressure is a bounded queue over ALL pending (not-yet-closed)
  requests: ``submit`` on a full queue raises :class:`QueueFullError`
  immediately — open-loop clients must see rejection, not unbounded
  buffering;
* a request older than ``timeout_ms`` (when set) that still has not been
  batched is failed with :class:`RequestTimeoutError` and dropped by the
  timer thread — its slot returns to the queue budget;
* ``close(drain=True)`` stops admissions, flushes every partial batch to
  the workers, and wakes all waiters — graceful drain; ``drain=False``
  fails whatever is still pending with :class:`RuntimeClosedError`.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable

__all__ = ["QueueFullError", "RequestTimeoutError", "RuntimeClosedError",
           "RequestHandle", "ServeRequest", "Batch", "DeadlineBatcher"]


class QueueFullError(RuntimeError):
    """Bounded pending queue is full — backpressure; resubmit later."""


class RequestTimeoutError(TimeoutError):
    """The request exceeded its timeout before (or while) being served."""


class RuntimeClosedError(RuntimeError):
    """The runtime is shutting down and no longer accepts requests."""


class RequestHandle:
    """Client-side future for one submitted request.

    ``result(timeout=None)`` blocks until the worker pool publishes the
    request's output (or failure) and returns it / raises. Timing fields
    are filled in by the scheduler and workers for telemetry.
    """

    __slots__ = ("_event", "_result", "_error", "t_submit", "t_batched",
                 "t_done", "info")

    def __init__(self):
        self._event = threading.Event()
        self._result = None
        self._error: BaseException | None = None
        self.t_submit = 0.0
        self.t_batched = 0.0
        self.t_done = 0.0
        self.info: dict = {}

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None):
        if not self._event.wait(timeout):
            raise RequestTimeoutError(
                f"request not served within {timeout}s")
        if self._error is not None:
            raise self._error
        return self._result

    def set_result(self, value, info: dict | None = None):
        self._result = value
        if info:
            self.info = info
        self.t_done = time.monotonic()
        self._event.set()

    def set_error(self, err: BaseException):
        self._error = err
        self.t_done = time.monotonic()
        self._event.set()

    @property
    def latency_s(self) -> float:
        return max(0.0, self.t_done - self.t_submit)

    @property
    def queue_s(self) -> float:
        return max(0.0, self.t_batched - self.t_submit)


@dataclasses.dataclass
class ServeRequest:
    """One admitted request: payload + bucket + client handle."""

    key: Any                       # BucketKey
    x: Any                         # the (unpadded) signal, numpy-convertible
    handle: RequestHandle
    inject: Any = None             # per-request SEU descriptor (ft buckets)
    timeout_ms: float | None = None


@dataclasses.dataclass
class Batch:
    """A closed batch, ready for a worker: same-bucket requests in
    submission order (at most ``max_batch`` of them)."""

    key: Any
    requests: list
    t_close: float


class DeadlineBatcher:
    """Per-bucket request coalescing under ``(max_batch, deadline_ms)``."""

    def __init__(self, *, max_batch: int, deadline_ms: float,
                 queue_depth: int, timeout_ms: float | None = None,
                 on_timeout: Callable | None = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if deadline_ms < 0:
            raise ValueError(f"deadline_ms must be >= 0, got {deadline_ms}")
        if queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
        self.max_batch = int(max_batch)
        self.deadline_s = float(deadline_ms) / 1e3
        self.queue_depth = int(queue_depth)
        self.timeout_ms = timeout_ms
        self._on_timeout = on_timeout
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        # bucket key -> deque[(t_submit, ServeRequest)] of pending requests
        self._pending: dict = collections.defaultdict(collections.deque)
        self._npending = 0
        self._ready: collections.deque[Batch] = collections.deque()
        self._closed = False
        self._timer = threading.Thread(target=self._deadline_loop,
                                       name="serve-deadline", daemon=True)
        self._timer.start()

    # -- producer side -----------------------------------------------------

    def submit(self, req: ServeRequest) -> None:
        """Admit one request; raises :class:`QueueFullError` on
        backpressure and :class:`RuntimeClosedError` after close()."""
        now = time.monotonic()
        req.handle.t_submit = now
        with self._cond:
            if self._closed:
                raise RuntimeClosedError("serve runtime is closed")
            if self._npending >= self.queue_depth:
                raise QueueFullError(
                    f"pending queue full ({self.queue_depth} requests) — "
                    f"backpressure; retry after the pool drains")
            q = self._pending[req.key]
            q.append(req)
            self._npending += 1
            if len(q) >= self.max_batch:
                self._close_bucket(req.key, now)
            self._cond.notify_all()

    def _close_bucket(self, key, now: float) -> None:
        # callers hold the lock
        q = self._pending.get(key)
        if not q:
            return
        take = [q.popleft() for _ in range(min(len(q), self.max_batch))]
        self._npending -= len(take)
        for r in take:
            r.handle.t_batched = now
        self._ready.append(Batch(key=key, requests=take, t_close=now))

    # -- consumer side -----------------------------------------------------

    def next_batch(self, timeout: float | None = None) -> Batch | None:
        """Blocking take for worker threads. Returns ``None`` when the
        batcher is closed and fully drained (worker exit signal), or on
        ``timeout`` (idle poll)."""
        deadline = (time.monotonic() + timeout) if timeout is not None \
            else None
        with self._cond:
            while True:
                if self._ready:
                    return self._ready.popleft()
                if self._closed and self._npending == 0:
                    return None
                wait = None
                if deadline is not None:
                    wait = deadline - time.monotonic()
                    if wait <= 0:
                        return None
                self._cond.wait(wait if wait is not None else 0.1)

    # -- deadline / timeout enforcement ------------------------------------

    def _deadline_loop(self):
        while True:
            with self._cond:
                if self._closed and self._npending == 0:
                    return
                now = time.monotonic()
                flushed = False
                for key in list(self._pending):
                    q = self._pending[key]
                    if not q:
                        continue
                    # per-request timeout: fail requests that aged out
                    # before a batch formed (their queue slot frees up)
                    while q and self._timed_out(q[0], now):
                        r = q.popleft()
                        self._npending -= 1
                        if self._on_timeout is not None:
                            self._on_timeout(key)
                        tmo = r.timeout_ms if r.timeout_ms is not None \
                            else self.timeout_ms
                        r.handle.set_error(RequestTimeoutError(
                            f"request waited > {tmo}ms unbatched in "
                            f"bucket {getattr(key, 'label', key)}"))
                        flushed = True
                    if q and now - q[0].handle.t_submit >= self.deadline_s:
                        self._close_bucket(key, now)
                        flushed = True
                if flushed:
                    self._cond.notify_all()
                # sleep until the earliest pending wake point — a batch
                # deadline OR a per-request timeout, whichever is sooner
                # (or a coarse tick when idle, to notice close())
                def _wake(r):
                    t = r.handle.t_submit + self.deadline_s
                    tmo = r.timeout_ms if r.timeout_ms is not None \
                        else self.timeout_ms
                    if tmo is not None:
                        t = min(t, r.handle.t_submit + tmo / 1e3)
                    return t
                nxt = min((_wake(r) for q in self._pending.values()
                           for r in q), default=now + 0.05)
                self._cond.wait(max(1e-4, nxt - time.monotonic()))

    def _timed_out(self, req: ServeRequest, now: float) -> bool:
        tmo = req.timeout_ms if req.timeout_ms is not None \
            else self.timeout_ms
        return tmo is not None and (now - req.handle.t_submit) > tmo / 1e3

    # -- lifecycle ---------------------------------------------------------

    def flush(self) -> None:
        """Close every partial batch now (tests / drain)."""
        with self._cond:
            now = time.monotonic()
            for key in list(self._pending):
                while self._pending[key]:
                    self._close_bucket(key, now)
            self._cond.notify_all()

    def close(self, *, drain: bool = True) -> None:
        """Stop admissions. ``drain=True`` flushes partial batches for the
        workers to finish; ``drain=False`` fails all pending requests."""
        with self._cond:
            self._closed = True
            now = time.monotonic()
            if drain:
                for key in list(self._pending):
                    while self._pending[key]:
                        self._close_bucket(key, now)
            else:
                for key, q in self._pending.items():
                    while q:
                        r = q.popleft()
                        self._npending -= 1
                        r.handle.set_error(
                            RuntimeClosedError("runtime closed before "
                                               "the request was served"))
            self._cond.notify_all()
        self._timer.join(timeout=5)

    @property
    def pending(self) -> int:
        with self._lock:
            return self._npending

    @property
    def ready(self) -> int:
        with self._lock:
            return len(self._ready)
