"""Deterministic synthetic data pipeline.

Design requirements at 1000+ nodes:
* **step-addressable**: batch(step) is a pure function of (seed, step, shard)
  — any host can regenerate any shard, so stragglers/restarts never need
  cross-host data recovery (fault-tolerance posture, DESIGN.md §5),
* **host-sharded**: each host materializes only its slice of the global
  batch,
* **prefetchable**: an iterator wrapper keeps K steps in flight.

The token stream is a reproducible Zipf-ish mixture with enough structure
that a ~100M model measurably learns (examples/train_lm.py): a hidden Markov
walk over vocab blocks plus local repetition.
"""
from __future__ import annotations

import dataclasses
import threading
import queue
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["TokenPipeline", "make_batch", "Prefetcher"]


def make_batch(seed: int, step: int, *, batch: int, seq_len: int,
               vocab_size: int, shard: int = 0, num_shards: int = 1,
               dtype=np.int32) -> dict:
    """Pure function (seed, step, shard) -> {"tokens", "labels"}."""
    if batch % num_shards != 0:
        raise ValueError(f"batch={batch} is not divisible by "
                         f"num_shards={num_shards}")
    local = batch // num_shards
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, shard]))
    # hidden state walk over 64 vocab "topics"
    topics = rng.integers(0, 64, (local, 1 + seq_len // 64 + 1))
    base = np.repeat(topics, 64, axis=1)[:, :seq_len + 1]
    width = max(vocab_size // 64, 2)
    offs = rng.zipf(1.5, (local, seq_len + 1)) % width
    toks = (base * width + offs) % vocab_size
    # local repetition: copy 8-grams forward with prob .25
    rep = rng.random((local, seq_len + 1)) < 0.25
    toks[:, 8:] = np.where(rep[:, 8:], toks[:, :-8], toks[:, 8:])
    toks = toks.astype(dtype)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass
class TokenPipeline:
    seed: int
    batch: int
    seq_len: int
    vocab_size: int
    shard: int = 0
    num_shards: int = 1

    def __call__(self, step: int) -> dict:
        return make_batch(self.seed, step, batch=self.batch,
                          seq_len=self.seq_len, vocab_size=self.vocab_size,
                          shard=self.shard, num_shards=self.num_shards)

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of K batches (host-side overlap)."""

    def __init__(self, pipeline: TokenPipeline, start_step: int = 0,
                 depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            step = start_step
            while not self._stop.is_set():
                try:
                    self._q.put(pipeline(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def next(self) -> dict:
        return self._q.get()

    def close(self):
        self._stop.set()
