"""Deterministic synthetic data pipeline."""
from .synthetic import TokenPipeline, make_batch, Prefetcher

__all__ = ["TokenPipeline", "make_batch", "Prefetcher"]
