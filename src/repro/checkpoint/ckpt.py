"""Checkpointing: async, atomic, elastic.

* **atomic publish**: write to ``step_XXXX.tmp`` then rename — a crash
  mid-write never corrupts the restore point,
* **async**: device->host transfer happens on the caller thread (cheap),
  serialization + fsync on a background thread,
* **elastic restore**: checkpoints are stored *unsharded* (npz of full
  arrays); restore re-shards onto whatever mesh the new job has — device
  count may differ from the writer's (node failures / elastic rescale).

At real 1000-node scale the npz container would be replaced by a parallel
object-store writer per host-shard; the atomicity/elasticity contract here is
the part the rest of the framework depends on.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "CheckpointManager"]


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name in ("bfloat16",
                                                       "float8_e4m3fn",
                                                       "float8_e5m2"):
            arr = arr.astype(np.float32)  # npz can't store ml_dtypes;
            # restore casts back to the template dtype (lossless for bf16)
        flat[key] = arr
    return flat


def _unflatten_into(template, flat: dict):
    def pick(kp, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        arr = flat[key]
        return jnp.asarray(arr, dtype=leaf.dtype)
    return jax.tree_util.tree_map_with_path(pick, template)


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: dict | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "state.npz"), **flat)
    meta = {"step": step, **(extra or {})}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template: Any, step: int | None = None,
                       shardings: Any = None):
    """Restore into ``template``'s structure; apply ``shardings`` if given
    (elastic re-shard onto the current mesh)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "state.npz")) as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten_into(template, flat)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda l, s: jax.device_put(l, s), tree, shardings)
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    return tree, meta


class CheckpointManager:
    """Async save + retention. ``save`` returns immediately; ``wait`` joins."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        self._pending: list[concurrent.futures.Future] = []
        self._lock = threading.Lock()

    def save(self, step: int, tree: Any, extra: dict | None = None):
        host_tree = jax.tree_util.tree_map(np.asarray, tree)  # D2H now

        def job():
            p = save_checkpoint(self.directory, step, host_tree, extra)
            self._gc()
            return p

        with self._lock:
            self._pending = [f for f in self._pending if not f.done()]
            self._pending.append(self._pool.submit(job))

    def wait(self):
        with self._lock:
            pending = list(self._pending)
        for f in pending:
            f.result()

    def _gc(self):
        if not os.path.isdir(self.directory):
            return
        steps = sorted(int(m.group(1)) for d in os.listdir(self.directory)
                       if (m := re.fullmatch(r"step_(\d+)", d)))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
