"""Op-agnostic fault-tolerance plan layer: one frozen spec -> one cached
:class:`Plan` executor bundle, for ANY checked operator family.

TurboFFT's ABFT is derived from the GEMV view of the DFT (paper §2.2.2) —
the checksum/locate/correct machinery is a property of a *linear operator*,
not of the FFT. This module is the spec->plan->executor skeleton shared by
every kernel family that wants it:

* the spec is a frozen, hashable value object describing one workload
  (shape, dtype, layout, fault-tolerance knobs). Equal specs hash equal and
  hit the same cached plan;
* :func:`plan` resolves a spec ONCE into a :class:`Plan` subclass registered
  for its type (``core.fft.api.FFTSpec -> FFTPlan``, ``core.gemm.GEMMSpec ->
  GEMMPlan``) whose constructor does every per-call decision up front and
  binds executors to already-built jitted pipelines;
* :class:`FTConfig` is the shared fault-tolerance attachment — one config
  object (built from ``core.ft.FTPolicy.to_ft_config()``) describes the
  checked variant of any plan, so the SAME policy drives the FFT mesh ABFT
  and the GEMM two-side ABFT.

This module is deliberately free of FFT- and GEMM-specific imports: operator
families register themselves via :func:`register_plan_type` at import time
and only pay for what they use.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import threading

__all__ = ["FTConfig", "Plan", "plan", "register_plan_type",
           "plan_cache_info", "plan_cache_clear", "plan_cache_keys"]


@dataclasses.dataclass(frozen=True)
class FTConfig:
    """Fault-tolerance configuration folded into a plan spec.

    Shared knobs: ``threshold`` (detection delta) and ``correct`` (online
    correction vs detect-only). Mesh-path knobs (grouped two-side FFT ABFT):
    ``groups`` / ``group_size`` / ``recompute_uncorrectable``. Local
    fused-kernel knobs: ``transactions`` / ``per_signal`` / ``encoding``.
    A plan uses whichever subset its dispatch path needs, so ONE config
    describes the checked variant of any operator family (FFT on any mesh,
    GEMM on any backend).
    """

    threshold: float = 1e-4
    correct: bool = True
    groups: int | None = None
    group_size: int | None = None
    recompute_uncorrectable: bool = False
    transactions: int = 4
    per_signal: bool = False
    encoding: str = "wang"


class Plan:
    """Base class for pre-resolved executor bundles.

    Subclasses resolve everything in ``__init__(spec)`` — layout, kernel
    choice, checksum geometry, the analytic cost model — and bind executors
    as bound methods, so execution is a straight dispatch. Two hooks are
    part of the shared contract:

    * ``volume`` — an analytic cost/traffic model of one execution
      (``None`` when the family has no model for the resolved path);
    * :meth:`describe` — a flat dict of the resolved plan parameters, for
      telemetry and benchmark tables.

    Construct via :func:`plan` (LRU-cached on the spec), not directly.
    """

    volume = None

    def __init__(self, spec):
        self.spec = spec

    def describe(self) -> dict:
        d = {"plan": type(self).__name__,
             "spec": type(self.spec).__name__,
             "ft": getattr(self.spec, "ft", None) is not None}
        if self.volume is not None:
            d["volume"] = self.volume
        return d


_PLAN_TYPES: dict[type, type[Plan]] = {}


def register_plan_type(spec_cls: type, plan_cls: type[Plan] | None = None):
    """Register ``plan_cls`` as the :class:`Plan` for ``spec_cls``.

    Usable as a decorator on the plan class::

        @register_plan_type(GEMMSpec)
        class GEMMPlan(Plan): ...
    """
    if plan_cls is None:
        def deco(cls):
            register_plan_type(spec_cls, cls)
            return cls
        return deco
    if not (isinstance(plan_cls, type) and issubclass(plan_cls, Plan)):
        raise TypeError(f"register_plan_type needs a Plan subclass, "
                        f"got {plan_cls!r}")
    _PLAN_TYPES[spec_cls] = plan_cls
    return plan_cls


# The shared plan cache. Serving traffic hits plan() concurrently from a
# worker pool, so the cache is explicitly thread-safe: the miss path is
# guarded by per-spec in-flight events — when N threads race on the SAME
# new spec, exactly one constructs the plan (one plan object, one set of
# jit traces) and the rest block until it lands in the cache; threads
# building DISTINCT specs construct concurrently. ``functools.lru_cache``
# only serializes its bookkeeping, not the miss-path construction, which
# is where duplicate plans and duplicate traces came from.
_CACHE_MAXSIZE = 512
_cache: "collections.OrderedDict[object, Plan]" = collections.OrderedDict()
_inflight: dict[object, threading.Event] = {}
_cache_lock = threading.Lock()
_hits = 0
_misses = 0


def _plan_cached(spec) -> Plan:
    global _hits, _misses
    while True:
        with _cache_lock:
            if spec in _cache:
                _cache.move_to_end(spec)
                _hits += 1
                return _cache[spec]
            ev = _inflight.get(spec)
            if ev is None:
                _inflight[spec] = threading.Event()
                _misses += 1
                break
        # another thread is constructing this exact spec: wait for it to
        # publish (or fail), then retry the lookup
        ev.wait()
    try:
        built = _PLAN_TYPES[type(spec)](spec)
    except BaseException:
        with _cache_lock:
            ev = _inflight.pop(spec)
        ev.set()        # waiters retry; the next one becomes the builder
        raise
    with _cache_lock:
        _cache[spec] = built
        while len(_cache) > _CACHE_MAXSIZE:
            _cache.popitem(last=False)
        ev = _inflight.pop(spec)
    ev.set()
    return built


def plan(spec) -> Plan:
    """Build (or fetch from the shared LRU cache) the :class:`Plan` for
    ``spec``. Equal specs return the SAME plan object whose executors are
    bound to already-traced pipelines — the cuFFT ``plan once, exec hot``
    contract, for every registered operator family. Thread-safe: concurrent
    misses on one spec construct exactly one plan."""
    if type(spec) not in _PLAN_TYPES:
        known = ", ".join(c.__name__ for c in _PLAN_TYPES) or "none imported"
        raise TypeError(
            f"plan() takes a registered plan spec ({known}), got "
            f"{type(spec).__name__}")
    return _plan_cached(spec)


def plan_cache_info():
    """``functools``-style cache stats ``(hits, misses, maxsize, currsize)``
    of the shared plan cache."""
    with _cache_lock:
        return functools._CacheInfo(_hits, _misses, _CACHE_MAXSIZE,
                                    len(_cache))


def plan_cache_keys() -> list:
    """The cached specs, least- to most-recently used — introspection for
    the serving runtime's bucket admission (which specs are resident/hot)
    and for cache-contention diagnostics."""
    with _cache_lock:
        return list(_cache)


def plan_cache_clear():
    global _hits, _misses
    with _cache_lock:
        _cache.clear()
        _hits = 0
        _misses = 0
