"""Fault-tolerance runtime: SEU model, fault schedules, policy, statistics."""
from .injection import flip_bit, random_flip, FaultSchedule, poisson_schedule
from .policy import FTPolicy, FTStats

__all__ = ["flip_bit", "random_flip", "FaultSchedule", "poisson_schedule",
           "FTPolicy", "FTStats"]
