"""SEU fault model: bit-flips in floating point values + fault schedules.

The paper's error-injection methodology (§5.3.1): flip exactly one bit of the
32-bit (FP32) or 64-bit (FP64) representation of one element of one signal.
We reproduce that exactly for the ROC analysis, plus a Poisson fault schedule
for the sustained-injection-rate experiments (§5.3.2, "tens of errors per
minute").
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["flip_bit", "random_flip", "FaultSchedule", "poisson_schedule"]


def flip_bit(x: np.ndarray, idx: tuple, bit: int) -> np.ndarray:
    """Flip one bit of one element (host-side, numpy) — exact paper §5.3.1.

    Flips can produce inf/nan patterns (sign/exponent bits) — that is the
    point; numpy warnings about them are suppressed.
    """
    np.seterr(invalid="ignore", over="ignore")
    x = np.array(x, copy=True)
    val = x[idx]
    if x.dtype == np.float32 or x.dtype == np.complex64:
        if np.iscomplexobj(x):
            # flip in the real part's representation for bit < 32, imag above
            re = np.float32(val.real)
            im = np.float32(val.imag)
            if bit < 32:
                re = _flip32(re, bit)
            else:
                im = _flip32(im, bit - 32)
            x[idx] = re + 1j * im
        else:
            x[idx] = _flip32(np.float32(val), bit)
    elif x.dtype == np.float64 or x.dtype == np.complex128:
        if np.iscomplexobj(x):
            re, im = np.float64(val.real), np.float64(val.imag)
            if bit < 64:
                re = _flip64(re, bit)
            else:
                im = _flip64(im, bit - 64)
            x[idx] = re + 1j * im
        else:
            x[idx] = _flip64(np.float64(val), bit)
    else:
        raise TypeError(x.dtype)
    return x


def _flip32(v: np.float32, bit: int) -> np.float32:
    u = np.frombuffer(np.float32(v).tobytes(), dtype=np.uint32)[0]
    u = np.uint32(u ^ np.uint32(1) << np.uint32(bit))
    return np.frombuffer(u.tobytes(), dtype=np.float32)[0]


def _flip64(v: np.float64, bit: int) -> np.float64:
    u = np.frombuffer(np.float64(v).tobytes(), dtype=np.uint64)[0]
    u = np.uint64(u ^ np.uint64(1) << np.uint64(bit))
    return np.frombuffer(u.tobytes(), dtype=np.float64)[0]


def random_flip(rng: np.random.Generator, x: np.ndarray):
    """Flip a uniformly random bit of a uniformly random element.

    Returns (corrupted array, (flat_index, bit), eps) where eps is the
    complex-valued perturbation added (corrupted - original).
    """
    flat = int(rng.integers(x.size))
    idx = np.unravel_index(flat, x.shape)
    nbits = 64 if x.dtype in (np.complex64, np.float64) else 32
    if x.dtype == np.complex128:
        nbits = 128
    bit = int(rng.integers(nbits))
    y = flip_bit(x, idx, bit)
    eps = complex(y[idx]) - complex(x[idx])
    return y, (flat, bit), eps


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """Deterministic schedule of SEUs for a run: step -> injection descriptor.

    Each entry is (step, tile, row, col, eps_re, eps_im) matching the fused
    kernel's in-kernel injector.
    """

    entries: tuple[tuple[int, int, int, int, float, float], ...]

    def for_step(self, step: int) -> jax.Array:
        """(6,) injection descriptor for ``step`` (disabled if none)."""
        for (s, tile, row, col, er, ei) in self.entries:
            if s == step:
                return jnp.asarray([tile, row, col, 1, er, ei],
                                   dtype=jnp.float32)
        return jnp.asarray([0, 0, 0, 0, 0.0, 0.0], dtype=jnp.float32)

    def for_step_gemm(self, step: int) -> jax.Array:
        """(1, 5) GEMM fault descriptor ``[site, row, col, enable, eps]``
        for ``step`` (disabled if none) — the ``tile`` field addresses the
        protected-matmul *site* within a block and ``eps_re`` is the real
        perturbation (GEMM activations are real). Feed to
        ``Model.decode_step(inject=...)`` / ``FTContext``.
        """
        for (s, tile, row, col, er, _ei) in self.entries:
            if s == step:
                return jnp.asarray([[tile, row, col, 1, er]],
                                   dtype=jnp.float32)
        return jnp.zeros((1, 5), dtype=jnp.float32)

    @property
    def num_faults(self) -> int:
        return len(self.entries)


def poisson_schedule(
    rng: np.random.Generator,
    *,
    steps: int,
    rate_per_step: float,
    tiles: int,
    bs: int,
    n: int,
    eps_scale: float = 50.0,
) -> FaultSchedule:
    """Poisson-arrival SEU schedule (paper §5.3.2: errors per minute)."""
    entries = []
    for step in range(steps):
        if rng.poisson(rate_per_step) > 0:
            entries.append((
                step,
                int(rng.integers(tiles)),
                int(rng.integers(bs)),
                int(rng.integers(n)),
                float(rng.normal() * eps_scale),
                float(rng.normal() * eps_scale),
            ))
    return FaultSchedule(entries=tuple(entries))
