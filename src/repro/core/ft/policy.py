"""Fault-tolerance policy + run-level FT runtime statistics.

The policy object is part of every run config (``configs.base.FTConfig``
references it): it decides what is protected (FFT ops, linear layers), the
detection threshold, the transaction count, and the checkpoint cadence — the
three-legged stool from the paper's fault model: ABFT for compute SEUs, ECC
for memory (assumed), checkpoint/restart for fail-stop.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["FTPolicy", "FTStats"]


@dataclasses.dataclass(frozen=True)
class FTPolicy:
    # ABFT (compute soft errors)
    protect_fft: bool = True
    protect_linears: bool = False
    threshold: float = 1e-4          # detection threshold delta (ROC-tuned)
    transactions: int = 4            # multi-transaction group size (kernel)
    per_signal: bool = False         # thread-level checksums on top
    encoding: str = "wang"
    # mesh-path grouped ABFT (core.fft.distributed): the fault-tolerance
    # contract is one SEU per checksum GROUP per pass, so more groups =
    # more concurrent faults tolerated (at 2*G/B extra checksum traffic).
    # None = auto (one group per data shard on a 2-D batch x pencil mesh).
    mesh_groups: int | None = None   # explicit group count G, or
    group_size: int | None = None    # signals per group (G = batch / this)
    # a group hit by >1 fault decodes as uncorrectable; recompute just that
    # group's rows with the plain pipeline (SEUs are transient, so the
    # recompute is clean) instead of failing the whole transform
    recompute_uncorrectable: bool = True
    # fail-stop (checkpoint/restart)
    checkpoint_every: int = 200
    keep_checkpoints: int = 3
    # numerical guards for training
    skip_nonfinite_updates: bool = True
    # checked-GEMM backend for protected linears ("auto" resolves to the
    # fused Pallas kernel on TPU when tile-aligned, plain-XLA otherwise —
    # see core.gemm.GEMMSpec)
    gemm_backend: str = "auto"

    def kernel_kwargs(self) -> dict:
        return dict(transactions=self.transactions,
                    per_signal=self.per_signal,
                    encoding=self.encoding,
                    threshold=self.threshold)

    def to_ft_config(self):
        """The op-agnostic :class:`~repro.core.plan.FTConfig` this policy
        implies — attach it to ANY plan spec (``FFTSpec(ft=...)`` for the
        grouped mesh / fused-kernel FFT ABFT, ``GEMMSpec(ft=...)`` for the
        two-side checked matmul) and the plan runs with the policy's knobs.
        One policy, every checked operator family.
        """
        from repro.core.plan import FTConfig

        return FTConfig(
            threshold=self.threshold,
            groups=self.mesh_groups,
            group_size=self.group_size,
            recompute_uncorrectable=self.recompute_uncorrectable,
            transactions=self.transactions,
            per_signal=self.per_signal,
            encoding=self.encoding)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FTStats:
    """Device-side counters threaded through train/serve steps."""

    detected: jax.Array
    corrected: jax.Array
    max_score: jax.Array
    skipped_updates: jax.Array

    @classmethod
    def zeros(cls) -> "FTStats":
        z = jnp.zeros((), jnp.float32)
        return cls(detected=z, corrected=z, max_score=z, skipped_updates=z)

    def merge(self, other: "FTStats") -> "FTStats":
        return FTStats(
            detected=self.detected + other.detected,
            corrected=self.corrected + other.corrected,
            max_score=jnp.maximum(self.max_score, other.max_score),
            skipped_updates=self.skipped_updates + other.skipped_updates,
        )
