"""Checked GEMM plan family: two-side ABFT matmul behind the shared
spec -> cached plan -> bound executor API (``core.plan``)."""
from .api import GEMMSpec, GEMMPlan, spec_for, plan

__all__ = ["GEMMSpec", "GEMMPlan", "spec_for", "plan"]
