"""GEMM plan family on the shared op-agnostic plan layer (``core.plan``).

The paper's ABFT is derived from the GEMV view of the DFT — the same
two-side checksum scheme protects any ``Y = X @ W``. This module is the
plan/execute front door for checked GEMMs, mirroring ``core.fft.api``:

* :class:`GEMMSpec` — frozen, hashable description of one matmul workload
  ``(M, K, N)`` plus an optional :class:`~repro.core.plan.FTConfig`;
* :class:`GEMMPlan` — resolved once per spec (registered on the shared
  registry, cached by the shared LRU): picks the ABFT backend and binds
  ``matmul`` / ``ft_matmul`` executors;
* backends: ``"xla"`` is the interpreter-path two-side ABFT
  (:mod:`repro.core.abft.gemm` — plain XLA ops, the right default off-TPU),
  ``"pallas"`` the fused kernel (:mod:`repro.kernels.ft_matmul`) whose
  checksum strips are decoded by the SAME :func:`decode_columns`, so the
  two backends agree by construction. ``"auto"`` resolves to the fused
  kernel on TPU when the dims are tile-aligned, the XLA path otherwise.

Injection descriptors are ``(4,)`` (or ``(F, 4)``) float rows
``[row, col, enable, eps]`` — ``enable`` makes the descriptor jit-safe: a
disabled fault is an all-zeros add, so serving can thread one traced array
through a fixed program and flip faults on per step
(:meth:`repro.core.ft.injection.FaultSchedule.for_step_gemm`).
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from repro.core import plan as planbase
from repro.core.plan import FTConfig
from repro.core.abft import gemm as abft_gemm
from repro.core.abft.encoding import EPS
from repro.kernels.ft_matmul import ft_matmul_pallas

__all__ = ["GEMMSpec", "GEMMPlan", "spec_for", "plan"]

_BACKENDS = ("auto", "xla", "pallas")


@dataclasses.dataclass(frozen=True)
class GEMMSpec:
    """Frozen, hashable description of one ``(M, K) @ (K, N)`` workload.

    ``shape`` is ``(M, K, N)`` with M the token axis the checksums ride
    (batched ``(B, T, K)`` activations flatten to ``M = B * T`` — use
    :func:`spec_for`). ``ft`` attaches the shared :class:`FTConfig`;
    ``backend`` picks the ABFT implementation (see module docstring);
    ``tiles`` are the fused kernel's ``(bm, bk, bn)`` block sizes. Equal
    specs hash equal and hit the same cached :class:`GEMMPlan`.
    """

    shape: tuple[int, int, int]
    dtype: str = "float32"
    ft: FTConfig | None = None
    backend: str = "auto"
    tiles: tuple[int, int, int] = (128, 128, 128)

    def __post_init__(self):
        shape = tuple(int(s) for s in self.shape)
        if len(shape) != 3 or any(s <= 0 for s in shape):
            raise ValueError(f"GEMMSpec.shape must be (M, K, N) positive "
                             f"sizes, got {self.shape!r}")
        object.__setattr__(self, "shape", shape)
        object.__setattr__(self, "dtype", jnp.dtype(self.dtype).name)
        if self.backend not in _BACKENDS:
            raise ValueError(f"GEMMSpec.backend must be one of {_BACKENDS}, "
                             f"got {self.backend!r}")
        tiles = tuple(int(t) for t in self.tiles)
        if len(tiles) != 3 or any(t <= 0 for t in tiles):
            raise ValueError(f"GEMMSpec.tiles must be (bm, bk, bn) positive "
                             f"sizes, got {self.tiles!r}")
        object.__setattr__(self, "tiles", tiles)
        if self.ft is not None and not isinstance(self.ft, FTConfig):
            raise TypeError(f"GEMMSpec.ft must be an FTConfig or None, "
                            f"got {type(self.ft).__name__}")


def _tile_aligned(shape, tiles) -> bool:
    (m, k, n), (bm, bk, bn) = shape, tiles
    return m % bm == 0 and k % bk == 0 and n % bn == 0


@planbase.register_plan_type(GEMMSpec)
class GEMMPlan(planbase.Plan):
    """Resolved executor bundle for one :class:`GEMMSpec`.

    ``backend`` is the resolved ABFT implementation; :meth:`matmul` is the
    unchecked product, :meth:`ft_matmul` the checked one (requires
    ``spec.ft``). ``volume`` is the analytic flop model — the checked
    product's overhead is four rank-1 GEMVs, independent of M·N.
    """

    def __init__(self, spec: GEMMSpec):
        super().__init__(spec)
        m, k, n = spec.shape
        backend = spec.backend
        aligned = _tile_aligned(spec.shape, spec.tiles)
        if backend == "auto":
            backend = ("pallas"
                       if aligned and jax.default_backend() == "tpu"
                       else "xla")
        if backend == "pallas" and not aligned:
            raise ValueError(
                f"GEMMSpec(backend='pallas') needs tile-aligned dims: "
                f"shape={spec.shape} vs tiles={spec.tiles} — use "
                f"backend='xla' (or 'auto', which falls back)")
        self.backend = backend
        self.volume = {"flops": 2 * m * k * n}
        if spec.ft is not None:
            # e2/e3 input GEMVs (4mk) + predicted strips (4kn) + output
            # strips (3mn) + per-column decode (O(n))
            self.volume["checksum_flops"] = 4 * m * k + 4 * k * n + 3 * m * n

    def describe(self) -> dict:
        d = super().describe()
        m, k, n = self.spec.shape
        d.update(m=m, k=k, n=n, backend=self.backend,
                 dtype=self.spec.dtype, tiles=self.spec.tiles)
        return d

    # -- executors ---------------------------------------------------------
    def matmul(self, x: jax.Array, w: jax.Array) -> jax.Array:
        """Unchecked ``x @ w`` (the baseline the overhead is measured
        against)."""
        self._check_operands(x, w)
        return jnp.matmul(x, w)

    def ft_matmul(self, x: jax.Array, w: jax.Array, *,
                  inject: jax.Array | None = None):
        """Checked ``x @ w`` -> ``(y, stats)`` (see
        :func:`repro.core.abft.gemm.decode_columns` for the stats contract).

        ``inject`` is a ``(4,)``/``(F, 4)`` ``[row, col, enable, eps]``
        descriptor; rows index the flattened token axis.
        """
        cfg = self.spec.ft
        if cfg is None:
            raise ValueError("ft_matmul on a plan without an FTConfig — "
                             "build the GEMMSpec with ft=FTConfig(...)")
        self._check_operands(x, w)
        inj = _normalize_inject(inject)
        if self.backend == "pallas":
            bm, bk, bn = self.spec.tiles
            return _ft_matmul_fused(
                x, w, inj, bm=bm, bn=bn, bk=bk,
                threshold=cfg.threshold, with_correction=cfg.correct)
        # xla: fold enable into eps -> the interpreter path's (F, 3) rows
        inj3 = jnp.stack([inj[:, 0], inj[:, 1], inj[:, 2] * inj[:, 3]],
                         axis=-1)
        return abft_gemm.ft_matmul(x, w, threshold=cfg.threshold,
                                   with_correction=cfg.correct, inject=inj3)

    __call__ = matmul

    def _check_operands(self, x, w):
        m, k, n = self.spec.shape
        got = (int(math.prod(x.shape[:-1])), int(x.shape[-1]),
               int(w.shape[-1]))
        if w.ndim != 2 or int(w.shape[0]) != k or got != (m, k, n):
            raise ValueError(f"operands {tuple(x.shape)} @ {tuple(w.shape)} "
                             f"do not match GEMMSpec.shape (M, K, N)="
                             f"{(m, k, n)}")


def _normalize_inject(inject) -> jax.Array:
    """``None`` / ``(4,)`` / ``(F, 4)`` -> ``(F, 4)`` float32 (a disabled
    all-zeros row when None, so one jit trace serves both cases)."""
    if inject is None:
        return jnp.zeros((1, 4), jnp.float32)
    return jnp.reshape(jnp.asarray(inject, jnp.float32), (-1, 4))


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "threshold",
                              "with_correction"))
def _ft_matmul_fused(x, w, inj, *, bm, bn, bk, threshold, with_correction):
    x2 = x.reshape(-1, x.shape[-1])
    t = x2.shape[0]
    res = ft_matmul_pallas(x2, w, bm=bm, bn=bn, bk=bk, inject=inj)
    d2 = res.pred2 - res.out2
    d3 = res.pred3 - res.out3
    scale = jnp.sqrt(jnp.mean(res.out2 * res.out2)) + EPS
    y, stats = abft_gemm.decode_columns(
        res.c, d2, d3, scale, t=t, threshold=threshold,
        with_correction=with_correction)
    return y.reshape(x.shape[:-1] + (w.shape[-1],)).astype(x.dtype), stats


def spec_for(x: jax.Array, w: jax.Array, *, ft: FTConfig | None = None,
             backend: str = "auto",
             tiles: tuple[int, int, int] = (128, 128, 128)) -> GEMMSpec:
    """Build the :class:`GEMMSpec` describing ``x @ w`` (flattening batched
    activation leading axes into M)."""
    m = int(math.prod(x.shape[:-1]))
    return GEMMSpec(shape=(m, int(x.shape[-1]), int(w.shape[-1])),
                    dtype=jnp.dtype(x.dtype).name, ft=ft, backend=backend,
                    tiles=tiles)


def plan(spec: GEMMSpec) -> GEMMPlan:
    """Shared-cache lookup (see :func:`repro.core.plan.plan`)."""
    if not isinstance(spec, GEMMSpec):
        raise TypeError(f"core.gemm.plan() takes a GEMMSpec, got "
                        f"{type(spec).__name__}")
    return planbase.plan(spec)
