"""TurboFFT core: plans, factor/twiddle tables, Stockham FFT, large-N driver."""
from . import factors
from .plan import Plan, StagePlan, make_plan, block_radices, PLAN_TABLE
from .stockham import (fft, ifft, fft_with_plan, block_fft_stages, naive_dft,
                       radix2_fft)
from .large import fft_large

__all__ = [
    "factors", "Plan", "StagePlan", "make_plan", "block_radices", "PLAN_TABLE",
    "fft", "ifft", "fft_with_plan", "block_fft_stages", "naive_dft",
    "radix2_fft", "fft_large",
]
from .extensions import (rfft, irfft, fft2, ifft2, rfft2,  # noqa: E402
                         irfft2, ft_ifft)

__all__ += ["rfft", "irfft", "fft2", "ifft2", "rfft2", "irfft2", "ft_ifft"]

from .distributed import (DistPlan, DistFFTResult, make_dist_plan,  # noqa: E402
                          distributed_fft, distributed_ifft,
                          ft_distributed_fft, resolve_abft_groups,
                          collective_volume, spectral_volume,
                          FFT_AXIS, DATA_AXIS)

__all__ += ["DistPlan", "DistFFTResult", "make_dist_plan", "distributed_fft",
            "distributed_ifft", "ft_distributed_fft", "resolve_abft_groups",
            "collective_volume", "spectral_volume", "FFT_AXIS", "DATA_AXIS"]

from .spectral import (fft_convolve, correlate, power_spectrum,  # noqa: E402
                       conv_spec)

__all__ += ["fft_convolve", "correlate", "power_spectrum", "conv_spec"]

from .multidim import (choose_decomp, collective_volume_nd,  # noqa: E402
                       distributed_fft2, distributed_ifft2,
                       distributed_fftn, distributed_ifftn,
                       distributed_rfft2, distributed_irfft2,
                       ft_distributed_fft2, ft_distributed_rfft2,
                       fft_convolve2, rslab_feasible)

__all__ += ["choose_decomp", "collective_volume_nd", "distributed_fft2",
            "distributed_ifft2", "distributed_fftn", "distributed_ifftn",
            "distributed_rfft2", "distributed_irfft2",
            "ft_distributed_fft2", "ft_distributed_rfft2", "fft_convolve2",
            "rslab_feasible"]

# the cuFFT-style plan/execute front door (the single dispatch path every
# public entry point funnels through)
from .api import (FFTSpec, FTConfig, FFTPlan, plan, spec_for,  # noqa: E402
                  plan_cache_info, plan_cache_clear,
                  FFTKwargDeprecationWarning)

__all__ += ["FFTSpec", "FTConfig", "FFTPlan", "plan", "spec_for",
            "plan_cache_info", "plan_cache_clear",
            "FFTKwargDeprecationWarning"]
