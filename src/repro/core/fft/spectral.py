"""Transposed-order spectral consumers: convolution, correlation, spectra.

The paper's own FFT use cases (convolution, spectrum estimation) never look
at the *order* of the frequency bins — they apply a pointwise op and come
straight back. "Coded FFT and Its Communication Overhead" (Jeong et al.)
shows the natural-order redistribution dominates distributed FFT cost, so
everything here stays in the FFTW-MPI transposed digit order
``y[k1*N2 + k2] = X[k1 + N1*k2]`` end-to-end on the sharded path:

    forward  : pass 1 -> twiddle -> all-to-all -> pass 2   (transposed out)
    pointwise: multiply / conjugate-multiply / |.|^2       (shard-local)
    inverse  : pass A -> conj twiddle -> all-to-all -> pass B (transposed in)

The two transforms of a convolution's operands ride ONE all-to-all (the
kernel's rows are stacked onto the batch before the collective), and the
inverse's all-to-all splits the batch axis, so the whole round trip is
exactly TWO all-to-all ops and ZERO all-gathers — verified against the
post-partitioning HLO by benchmarks/fft_distributed.py and modeled by
:func:`repro.core.fft.distributed.spectral_volume`.

When BOTH operands are real the kernel does not even ride as stacked rows:
it rides the *imaginary part* of one packed operand ``p = a + i*v``, since
``p (.) p = a (.) a - v (.) v + 2i (a (.) v)`` makes the convolution the
imaginary half of one self-product — the kernel's forward rows vanish from
the collectives entirely (``spectral_volume(real=True)``). Correlation of
real operands is the same trick on the circularly reversed kernel.

On a 2-D batch x pencil mesh (``launch.mesh.make_fft_mesh(shards, data)``)
batch rows shard over ``data`` while signal pencils shard over ``fft``; the
collectives stay within the ``fft`` axis. Without a mesh every function
falls back to the local Stockham transforms (same math, natural order).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from . import factors
from .distributed import (_AUTO, FFT_AXIS, _local_fft, _pad_batch_rows,
                          _resolve_data_axis, _resolve_mesh, make_dist_plan,
                          resolve_chunks)
from .stockham import block_fft_stages, fft as _fft, ifft as _ifft

__all__ = ["fft_convolve", "correlate", "power_spectrum", "conv_spec"]


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1)).bit_length()


def _result_dtypes(a, v):
    """(compute complex dtype, whether the result should be real)."""
    wide = (a.dtype in (jnp.float64, jnp.complex128)
            or v.dtype in (jnp.float64, jnp.complex128))
    cdtype = jnp.complex128 if wide else jnp.complex64
    real = not (jnp.issubdtype(a.dtype, jnp.complexfloating)
                or jnp.issubdtype(v.dtype, jnp.complexfloating))
    return cdtype, real


def _crop(full, la: int, lv: int, mode: str):
    """numpy convolve/correlate mode cropping of the length la+lv-1 result.

    The signal axis of ``full`` is unsharded on every path (the inverse
    leaves whole signals resident per device), so these slices are local.
    """
    lmin, lmax = min(la, lv), max(la, lv)
    if mode == "full":
        return full
    if mode == "same":
        start = (lmin - 1) // 2
        return full[..., start:start + lmax]
    if mode == "valid":
        return full[..., lmin - 1:lmax]
    raise ValueError(f"mode must be full|same|valid, got {mode!r}")


# ---------------------------------------------------------------------------
# the fused sharded pipeline
# ---------------------------------------------------------------------------


def _chunk_within_blocks(x, shards: int, ce: int, ci: int):
    """Chunk ``ci`` of ``x``'s leading rows, taken WITHIN each of the
    ``shards`` destination blocks of a batch-splitting all-to-all.

    The inverse's a2a sends block e (rows ``[e*B/D, (e+1)*B/D)``) to device
    e; a chunk that took contiguous rows would land device d with a
    permutation of the bulk path's rows. Striding the selection — sub-rows
    ``[ci*w, (ci+1)*w)`` of EVERY block — keeps each device's chunks landing
    in bulk order, so concatenating chunk outputs reproduces the
    bulk-synchronous result bitwise.
    """
    blk = x.shape[0] // shards
    w = blk // ce
    blocks = x.reshape((shards, blk) + x.shape[1:])
    return blocks[:, ci * w:(ci + 1) * w].reshape((-1,) + x.shape[1:])


@functools.lru_cache(maxsize=None)
def _spectral_pair_fn(mesh: Mesh, axis: str, data_axis: str | None,
                      conj_kernel: bool, chunks: int = 1):
    """forward(a, v) -> pointwise product -> inverse, one shard_map body.

    Keeping everything in a single body is what pins the collective count:
    the kernel's forward transform shares the batch all-to-all with the
    signals', and no intermediate ever leaves the pencil layout.

    ``chunks > 1`` splits the round trip into that many overlapped batch
    transactions (``2 * chunks`` all-to-alls, same total bytes): chunk i's
    collectives hide behind chunk i+1's local Stockham passes. A broadcast
    kernel transforms once — it rides transaction 0's forward collective
    and its spectrum is reused by every later chunk. Results are
    bitwise-identical to the bulk path for every chunk count.
    """
    shards = mesh.shape[axis]
    dsize = mesh.shape[data_axis] if data_axis else 1

    @jax.jit
    def run(a, v):  # a: (B, N), v: (BK, N) complex, BK in {1, B}
        b, n = a.shape
        bk = v.shape[0]
        plan = make_dist_plan(n, shards, axis)
        n1, n2 = plan.n1, plan.n2
        tw_f = jnp.asarray(factors.stage_twiddle(n1, n2, inverse=False),
                           dtype=a.dtype)
        tw_i = jnp.asarray(factors.stage_twiddle(n1, n2, inverse=True),
                           dtype=a.dtype)
        za = a.reshape((b, n1, n2))
        zv = v.reshape((bk, n1, n2))
        bspec = data_axis if (data_axis and b % dsize == 0) else None
        vspec = bspec if bk == b else None
        bloc = b // (dsize if bspec else 1)
        if bloc % shards:
            raise ValueError(
                f"spectral pipeline needs batch divisible by "
                f"{'data*shards' if bspec else 'shards'}, got {b} — "
                f"fft_convolve/correlate pad the batch automatically")

        def body(al, vl):
            d = jax.lax.axis_index(axis)
            ba = al.shape[0]
            n2l = al.shape[-1]

            def fwd(zc):
                # stacked rows -> transposed spectra: ONE all-to-all
                zc = jnp.swapaxes(zc, -1, -2)
                zc = block_fft_stages(zc, inverse=False)  # FFT over n1
                zc = jnp.swapaxes(zc, -1, -2)
                twl = jax.lax.dynamic_slice_in_dim(tw_f, d * n2l, n2l,
                                                   axis=1)
                zc = zc * twl
                zc = jax.lax.all_to_all(zc, axis, split_axis=1,
                                        concat_axis=2,
                                        tiled=True)      # (.., n1/D, n2)
                return _local_fft(zc, inverse=False)     # FFT over n2

            def inv(prod):
                # transposed product -> natural time domain: batch-split a2a
                prod = _local_fft(prod, inverse=True)    # IFFT over k2
                n1l = prod.shape[-2]
                twi = jax.lax.dynamic_slice_in_dim(tw_i, d * n1l, n1l,
                                                   axis=0)
                prod = prod * twi
                prod = jax.lax.all_to_all(prod, axis, split_axis=0,
                                          concat_axis=1,
                                          tiled=True)    # (BA/D, n1, n2)
                prod = jnp.swapaxes(prod, -1, -2)
                prod = _local_fft(prod, inverse=True)    # IFFT over k1
                prod = jnp.swapaxes(prod, -1, -2)        # natural (n1, n2)
                return prod.reshape(prod.shape[0], n) / n

            def product(ya, yv):
                if conj_kernel:
                    yv = jnp.conj(yv)
                return ya * yv                           # BK==1 broadcasts

            ce = resolve_chunks(ba // shards, chunks)
            if ce == 1:
                zc = fwd(jnp.concatenate([al, vl], axis=0))
                return inv(product(zc[:ba], zc[ba:]))
            per_signal = vl.shape[0] == ba
            outs, yv = [], None
            for ci in range(ce):
                ac = _chunk_within_blocks(al, shards, ce, ci)
                if per_signal:
                    vc = _chunk_within_blocks(vl, shards, ce, ci)
                    zc = fwd(jnp.concatenate([ac, vc], axis=0))
                    ya, yvc = zc[:ac.shape[0]], zc[ac.shape[0]:]
                elif yv is None:
                    # broadcast kernel: spectrum computed once, rides
                    # transaction 0's forward collective
                    zc = fwd(jnp.concatenate([ac, vl], axis=0))
                    ya, yv = zc[:ac.shape[0]], zc[ac.shape[0]:]
                    yvc = yv
                else:
                    ya, yvc = fwd(ac), yv
                outs.append(inv(product(ya, yvc)))
            return jnp.concatenate(outs, axis=0)  # rows land in bulk order

        out = shard_map(
            body, mesh=mesh,
            in_specs=(P(bspec, None, axis), P(vspec, None, axis)),
            out_specs=P((bspec, axis) if bspec else axis, None),
            check_rep=False)(za, zv)
        return out

    return run


@functools.lru_cache(maxsize=None)
def _spectral_real_fn(mesh: Mesh, axis: str, data_axis: str | None,
                      chunks: int = 1):
    """forward(p) -> p*p -> inverse for ONE packed operand ``p = a + i*v``.

    Same transposed round trip as :func:`_spectral_pair_fn` but the kernel
    rides the imaginary part instead of stacked batch rows, so the forward
    all-to-all moves exactly the signal rows — no kernel payload at all.
    The caller takes ``imag(.) / 2`` of the natural-order circular product.
    ``chunks`` pipelines the batch exactly as in the pair path.
    """
    shards = mesh.shape[axis]
    dsize = mesh.shape[data_axis] if data_axis else 1

    @jax.jit
    def run(p):  # p: (B, N) complex, a + i*v packed
        b, n = p.shape
        plan = make_dist_plan(n, shards, axis)
        n1, n2 = plan.n1, plan.n2
        tw_f = jnp.asarray(factors.stage_twiddle(n1, n2, inverse=False),
                           dtype=p.dtype)
        tw_i = jnp.asarray(factors.stage_twiddle(n1, n2, inverse=True),
                           dtype=p.dtype)
        zp = p.reshape((b, n1, n2))
        bspec = data_axis if (data_axis and b % dsize == 0) else None
        bloc = b // (dsize if bspec else 1)
        if bloc % shards:
            raise ValueError(
                f"spectral pipeline needs batch divisible by "
                f"{'data*shards' if bspec else 'shards'}, got {b} — "
                f"fft_convolve/correlate pad the batch automatically")

        def body(zl):
            d = jax.lax.axis_index(axis)
            n2l = zl.shape[-1]

            def round_trip(zc):
                # ---- forward: one packed operand, ONE all-to-all ---------
                zc = jnp.swapaxes(zc, -1, -2)
                zc = block_fft_stages(zc, inverse=False)  # FFT over n1
                zc = jnp.swapaxes(zc, -1, -2)
                twl = jax.lax.dynamic_slice_in_dim(tw_f, d * n2l, n2l,
                                                   axis=1)
                zc = zc * twl
                zc = jax.lax.all_to_all(zc, axis, split_axis=1,
                                        concat_axis=2,
                                        tiled=True)      # (B, n1/D, n2)
                zc = _local_fft(zc, inverse=False)       # FFT over n2
                # ---- pointwise self-product in transposed order ----------
                prod = zc * zc                           # P[k]^2, any order
                # ---- inverse from transposed order: batch-split a2a ------
                prod = _local_fft(prod, inverse=True)    # IFFT over k2
                n1l = prod.shape[-2]
                twi = jax.lax.dynamic_slice_in_dim(tw_i, d * n1l, n1l,
                                                   axis=0)
                prod = prod * twi
                prod = jax.lax.all_to_all(prod, axis, split_axis=0,
                                          concat_axis=1,
                                          tiled=True)    # (B/D, n1, n2)
                prod = jnp.swapaxes(prod, -1, -2)
                prod = _local_fft(prod, inverse=True)    # IFFT over k1
                prod = jnp.swapaxes(prod, -1, -2)        # natural (n1, n2)
                return prod.reshape(prod.shape[0], n) / n

            ce = resolve_chunks(zl.shape[0] // shards, chunks)
            if ce == 1:
                return round_trip(zl)
            return jnp.concatenate(
                [round_trip(_chunk_within_blocks(zl, shards, ce, ci))
                 for ci in range(ce)], axis=0)

        out = shard_map(
            body, mesh=mesh,
            in_specs=(P(bspec, None, axis),),
            out_specs=P((bspec, axis) if bspec else axis, None),
            check_rep=False)(zp)
        return out

    return run


def _pad_tail(x, n: int):
    """Zero-pad the last axis to length n."""
    pad = n - x.shape[-1]
    if pad <= 0:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths)


def _spectral_pair(a, v, mesh, axis, data_axis, *, conj_kernel: bool,
                   out_len: int, chunks: int = 1):
    """Shared driver: pad, dispatch local vs fused sharded path, crop.

    Returns the length ``out_len`` head of the circular product's inverse
    (linear results need nfft >= la + lv - 1, which callers guarantee).
    Two real operands take the packed single-transform path
    (:func:`_spectral_real`); any complex operand takes the stacked pair.
    ``chunks`` pipelines the sharded round trip (see
    :func:`_spectral_pair_fn`); the local path ignores it.
    """
    cdtype, real = _result_dtypes(a, v)
    if real:
        return _spectral_real(a, v, mesh, axis, data_axis,
                              conj_kernel=conj_kernel, out_len=out_len,
                              cdtype=cdtype, chunks=chunks)
    a = jnp.asarray(a, cdtype)
    v = jnp.asarray(v, cdtype)
    mesh = _resolve_mesh(mesh, axis)
    if mesh is None or mesh.shape[axis] == 1:
        fv = _fft(v)
        if conj_kernel:
            fv = jnp.conj(fv)
        return _ifft(_fft(a) * fv)[..., :out_len]
    daxis = _resolve_data_axis(mesh, data_axis)
    shards = mesh.shape[axis]
    dsize = mesh.shape[daxis] if daxis else 1
    lead = a.shape[:-1]
    n = a.shape[-1]
    a2d = a.reshape((-1, n))
    v2d = v.reshape((-1, n))
    b, bk = a2d.shape[0], v2d.shape[0]
    if bk not in (1, b):
        raise ValueError(
            f"kernel batch must be 1 or match the signal batch ({b}), "
            f"got {bk}")
    # pad the batch so the inverse's batch-split all-to-all divides evenly
    # (padding rows are zero signals; the slice below is free when b already
    # divides, the common serving case)
    a2d, _ = _pad_batch_rows(a2d, dsize, shards)
    if bk == b:
        v2d, _ = _pad_batch_rows(v2d, dsize, shards)
    out = _spectral_pair_fn(mesh, axis, daxis, conj_kernel,
                            int(chunks))(a2d, v2d)
    if out.shape[0] != b:
        out = out[:b]
    return out[..., :out_len].reshape(lead + (out_len,))


def _spectral_real(a, v, mesh, axis, data_axis, *, conj_kernel: bool,
                   out_len: int, cdtype, chunks: int = 1):
    """Circular product of two REAL operands via ONE packed transform.

    ``ifft(fft(a + i*v)^2) = a(.)a - v(.)v + 2i (a(.)v)``, so the circular
    convolution is ``imag(.) / 2`` of one self-product. Correlation with a
    real kernel is convolution with the circularly reversed kernel
    ``w[k] = v[-k mod n]``, so the same path serves ``conj_kernel=True``
    and the caller's roll/crop logic applies unchanged.
    """
    rdtype = jnp.float64 if cdtype == jnp.complex128 else jnp.float32
    a = jnp.asarray(a, rdtype)
    v = jnp.asarray(v, rdtype)
    if conj_kernel:
        v = jnp.concatenate([v[..., :1], v[..., 1:][..., ::-1]], axis=-1)
    p = (a + 1j * v).astype(cdtype)      # kernel rides the imaginary part
    mesh = _resolve_mesh(mesh, axis)
    if mesh is None or mesh.shape[axis] == 1:
        fp = _fft(p)
        return (jnp.imag(_ifft(fp * fp)) * 0.5)[..., :out_len]
    daxis = _resolve_data_axis(mesh, data_axis)
    shards = mesh.shape[axis]
    dsize = mesh.shape[daxis] if daxis else 1
    lead = p.shape[:-1]
    n = p.shape[-1]
    p2d = p.reshape((-1, n))
    b = p2d.shape[0]
    p2d, _ = _pad_batch_rows(p2d, dsize, shards)
    out = _spectral_real_fn(mesh, axis, daxis, int(chunks))(p2d)
    if out.shape[0] != b:
        out = out[:b]
    out = jnp.imag(out) * 0.5
    return out[..., :out_len].reshape(lead + (out_len,))


def _conv_nfft(la: int, lv: int, mesh, axis: str) -> int:
    """FFT length for a linear result: power of two >= la + lv - 1, raised
    to the mesh's minimum pencil size (shards^2) when sharded."""
    nfft = _next_pow2(la + lv - 1)
    mesh = _resolve_mesh(mesh, axis)
    if mesh is not None and mesh.shape[axis] > 1:
        nfft = max(nfft, mesh.shape[axis] ** 2)
    return nfft


# ---------------------------------------------------------------------------
# public API — spec-builder sugar over the plan executors (core.fft.api)
# ---------------------------------------------------------------------------


def conv_spec(a, v, mesh: Mesh | None = None, *, axis: str = FFT_AXIS,
              data_axis: str | None = _AUTO, chunks: int = 1):
    """The :class:`~repro.core.fft.api.FFTSpec` of the padded C2C transform
    one convolution/correlation of ``a`` with ``v`` runs: last axis padded
    to :func:`_conv_nfft`, batch dims from ``a``, compute dtype promoted
    across both operands. Build it once and reuse
    ``plan(spec).convolve/correlate`` on serve traffic. ``chunks`` is the
    multi-transaction overlap knob (see :class:`~repro.core.fft.api
    .FFTSpec`): the spectral round trip splits into that many transactions
    per all-to-all.
    """
    from . import api

    a = jnp.asarray(a)
    v = jnp.asarray(v)
    cdtype, real = _result_dtypes(a, v)
    nfft = _conv_nfft(a.shape[-1], v.shape[-1], mesh, axis)
    return api.FFTSpec(shape=a.shape[:-1] + (nfft,),
                       dtype=jnp.dtype(cdtype).name, rank=1, mesh=mesh,
                       axis=axis, data_axis=data_axis, real=real,
                       chunks=chunks)


def fft_convolve(a, v, mesh: Mesh | None = None, *, mode: str = "full",
                 axis: str = FFT_AXIS,
                 data_axis: str | None = _AUTO) -> jax.Array:
    """Linear convolution along the last axis via the transposed pipeline.

    Matches ``jnp.convolve`` (modes full/same/valid) batched over leading
    dims; ``v`` is one kernel ``(Lv,)`` shared by the whole batch or a
    per-signal batch matching ``a``'s leading dims. Real inputs give a real
    result. On a mesh the whole op lowers to exactly two all-to-alls and
    zero all-gathers (see module docstring); without one it runs the local
    Stockham transforms. Sugar over ``plan(conv_spec(a, v, ...)).convolve``.
    """
    from . import api

    return api.plan(conv_spec(a, v, mesh, axis=axis, data_axis=data_axis)
                    ).convolve(a, v, mode=mode)


def correlate(a, v, mesh: Mesh | None = None, *, mode: str = "full",
              axis: str = FFT_AXIS,
              data_axis: str | None = _AUTO) -> jax.Array:
    """Cross-correlation along the last axis: ``c[m] = sum_k a[m+k] *
    conj(v[k])`` — ``np.correlate`` conventions (modes full/same/valid),
    batched over leading dims. Same collective budget as
    :func:`fft_convolve`: the conjugated kernel spectrum is pointwise in
    transposed order too. Sugar over ``plan(conv_spec(...)).correlate``.
    """
    from . import api

    return api.plan(conv_spec(a, v, mesh, axis=axis, data_axis=data_axis)
                    ).correlate(a, v, mode=mode)


def power_spectrum(x, mesh: Mesh | None = None, *, axis: str = FFT_AXIS,
                   data_axis: str | None = _AUTO,
                   natural_order: bool | None = None,
                   real: bool = False) -> jax.Array:
    """Periodogram ``|X[k]|^2 / N`` along the last axis (real output).

    On the sharded path the bins stay in the transposed digit order by
    default (``natural_order=None`` -> False on a mesh): the |.|^2 is
    elementwise, so the whole op is ONE all-to-all and zero all-gathers.
    Order-agnostic consumers (total power, histograms, thresholds) never
    notice; pass ``natural_order=True`` to pay the redistribution and get
    numpy bin order. The local path is always natural order.

    ``real=True`` (opt-in: it changes the output SHAPE) takes a real input
    through the packed rfft and returns the one-sided ``N/2 + 1``-bin
    spectrum ``|X[k]|^2 / N`` for ``k <= N/2`` — the half-length transform
    moves about half the C2C path's bytes. One-sided bins are indexed by
    ``k``, so this path is always natural order.
    """
    from . import api

    x = jnp.asarray(x)
    mesh_r = _resolve_mesh(mesh, axis)
    on_mesh = mesh_r is not None and mesh_r.shape[axis] > 1
    if real:
        if jnp.issubdtype(x.dtype, jnp.complexfloating):
            raise ValueError(
                f"power_spectrum(real=True) takes a real input, "
                f"got {x.dtype}")
        if natural_order is False:
            raise ValueError(
                "the one-sided real spectrum is natural-order only — the "
                "Hermitian unpack indexes bins by k")
        spec = api.spec_for(x, rank=1, mesh=mesh_r, axis=axis,
                            data_axis=data_axis, real=True)
        return api.plan(spec).power_spectrum(x)
    if natural_order is None:
        natural_order = not on_mesh
    dt = x.dtype if jnp.issubdtype(x.dtype, jnp.complexfloating) \
        else jnp.complex64
    spec = api.FFTSpec(shape=tuple(x.shape), dtype=jnp.dtype(dt).name,
                       rank=1, mesh=mesh_r, axis=axis, data_axis=data_axis,
                       natural_order=natural_order)
    return api.plan(spec).power_spectrum(x)
