"""Large-N FFT: the paper's kernel-level N1 x N2 (x N3) decomposition.

Each kernel-level factor is one HBM round trip: a batched block FFT along one
axis of the tiled signal cube, a twiddle multiply (table precomputed on host,
fused into the same pass), and a transpose that is *folded into the access
pattern* of the next pass rather than materialized separately where possible —
mirroring the paper's observation that the final-stage transposed write is the
L1-miss hot spot (§5.1.2 "Global Memory").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import factors
from .plan import Plan, make_plan
from .stockham import block_fft_stages

__all__ = ["fft_large"]


def _twiddle_table(n1: int, n2: int, dtype, inverse: bool):
    """(n1, n2) table T[k1, n2] = exp(-+2*pi*i*k1*n2/(n1*n2)) built on host."""
    t = factors.stage_twiddle(n1, n2, inverse=inverse)
    return jnp.asarray(t, dtype=dtype)


def _fft_factors(x: jax.Array, facs: tuple[int, ...], inverse: bool) -> jax.Array:
    """FFT over the last axis of ``x`` with len == prod(facs), recursively."""
    n = x.shape[-1]
    if len(facs) == 1:
        return block_fft_stages(x, inverse=inverse)
    f1, rest = facs[0], facs[1:]
    f2 = int(np.prod(rest))
    assert f1 * f2 == n
    # pass 1: FFT along the f1 axis (stride f2): X[n1, n2] = x[f2*n1 + n2]
    z = x.reshape(x.shape[:-1] + (f1, f2))
    z = jnp.swapaxes(z, -1, -2)                      # (..., f2, f1)
    z = block_fft_stages(z, inverse=inverse)         # FFT over f1 (contiguous)
    z = jnp.swapaxes(z, -1, -2)                      # (..., f1, f2) = Z[k1, n2]
    # twiddle (fused into the same logical pass)
    z = z * _twiddle_table(f1, f2, x.dtype, inverse)
    # pass 2..: FFT along the f2 axis — recurse over remaining factors
    z = _fft_rest(z, rest, inverse)
    # output ordering k = k1 + f1*k2 -> view as (f2, f1) row-major
    z = jnp.swapaxes(z, -1, -2)
    return z.reshape(x.shape[:-1] + (n,))


def _fft_rest(z: jax.Array, rest: tuple[int, ...], inverse: bool) -> jax.Array:
    """FFT along the last axis (length prod(rest)) of the (…, f1, f2) cube."""
    if len(rest) == 1:
        return block_fft_stages(z, inverse=inverse)
    return _fft_factors_nested(z, rest, inverse)


def _fft_factors_nested(z: jax.Array, facs: tuple[int, ...], inverse: bool):
    lead = z.shape[:-1]
    n = z.shape[-1]
    out = _fft_factors(z.reshape((-1, n)), facs, inverse)
    return out.reshape(lead + (n,))


def fft_large(x: jax.Array, plan: Plan | None = None) -> jax.Array:
    """Multi-pass FFT over the last axis for N beyond the VMEM budget."""
    n = x.shape[-1]
    if plan is None:
        plan = make_plan(n)
    if plan.n != n:
        raise ValueError(f"plan is for n={plan.n}, input has n={n}")
    y = _fft_factors(x, plan.kernel_factors, plan.inverse)
    if plan.inverse:
        y = y / n
    return y
