"""Distributed multi-dimensional FFT: slab + pencil fft2/fftn on a mesh.

The 1-D stack (``distributed.py``) scales a single transform axis; real
workloads (2-D/3-D convolution, imaging, PDE spectral solvers) transform
grids. "Coded FFT and Its Communication Overhead" (Jeong et al.) shows the
*decomposition* choice dominates the communication cost of multi-dim FFT, so
this module offers both classical layouts and a model-driven chooser:

**Slab** (block decomposition — small meshes, cheapest collectives).
The first transform axis block-shards over the ``fft`` mesh axis; every
other transform axis is resident, so the transform is

    local FFT over trailing axes -> ONE all-to-all (the inter-axis
    transpose: split last axis, gather first) -> local FFT over the first

— exactly one all-to-all per transform regardless of rank, and because the
sharding lands on a *true array axis* (not a digit), the natural-order
result is free: output sharded over the last transform axis, zero
all-gathers. Batch dims shard over ``data``. Feasible while the first and
last transform axes both divide by the ``fft``-axis size.

**Pencil** (digit decomposition — large meshes, one transform over the
whole 2-D mesh). The last two transform axes each run the existing 1-D
:class:`~repro.core.fft.distributed.DistPlan` pencil pipeline — the last
axis over ``fft``, the second-to-last over ``data`` — so a SINGLE transform
scales over ``data * fft`` devices (slab caps at ``fft`` alone, and needs a
batch to keep ``data`` busy). Two all-to-alls (one per mesh axis), each
confined to its own axis. The output keeps both distributed axes in the
1-D pipeline's transposed digit order; ``natural_order=True`` pays the
digit restore (all-gathers, like the 1-D natural path), which is why the
spectral consumer (:func:`fft_convolve2`) never asks for it.

:func:`choose_decomp` picks between them by evaluating the extended
communication model :func:`collective_volume_nd` (asserted model == HLO by
``benchmarks/fft_distributed.py``) over the feasible candidates — slab wins
whenever a batch keeps the data axis busy (one all-to-all vs two), pencil
wins when a single large grid must use the whole mesh.

**Grouped two-side ABFT** (:func:`ft_distributed_fft2`) composes the PR-3
grouped multi-transaction scheme with the slab row pass: per checksum
group, two right-side checksum *grids* (``cs2 = sum_b x_b``,
``cs3 = sum_b id_b x_b`` — linearity makes them signals) ride the
inter-axis transpose as extra batch rows, and the verdict is ONE psum of 3
scalars per group plus a shared energy scalar, confined to the ``fft``
axis. One SEU per group per pass is detected, located (to a signal), and
corrected elementwise; batch rows shard over ``data`` with no batch
all-gather (HLO-verified).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from . import factors
from .distributed import (_AUTO, EPS, FFT_AXIS, DistFFTResult,
                          _grouped_verdict, _local_fft, _resolve_data_axis,
                          _resolve_mesh, _splice_recomputed, make_dist_plan,
                          resolve_abft_groups, resolve_chunks)
from .stockham import naive_dft

__all__ = [
    "DECOMP_SLAB", "DECOMP_PENCIL", "choose_decomp", "collective_volume_nd",
    "distributed_fft2", "distributed_ifft2", "distributed_fftn",
    "distributed_ifftn", "ft_distributed_fft2", "fft_convolve2",
    "rslab_feasible", "distributed_rfft2", "distributed_irfft2",
    "ft_distributed_rfft2",
]

DECOMP_SLAB = "slab"
DECOMP_PENCIL = "pencil"
_DECOMPS = (DECOMP_SLAB, DECOMP_PENCIL)


def _is_pow2(n: int) -> bool:
    return n > 0 and not (n & (n - 1))


def _local_axis_fft(z: jax.Array, axis: int, *, inverse: bool) -> jax.Array:
    """Unnormalized local FFT over one axis (any position, any size).

    Power-of-two lengths run the Stockham stages; anything else falls back
    to the O(n^2) direct DFT — the local fallback that lets ``fft2`` accept
    odd grid sizes (the distributed paths stay power-of-two, like the 1-D
    pipeline).
    """
    z = jnp.moveaxis(z, axis, -1)
    if _is_pow2(z.shape[-1]):
        z = _local_fft(z, inverse)
    else:
        z = naive_dft(z, inverse=inverse)
        if inverse:          # _local_axis_fft is unnormalized by contract
            z = z * z.shape[-1]
    return jnp.moveaxis(z, -1, axis)


def _local_fftn(x: jax.Array, ndim: int, *, inverse: bool,
                interpret=None) -> jax.Array:
    """Local n-D transform over the last ``ndim`` axes (numpy conventions).

    ``interpret`` (True/False) routes power-of-two axes through the Pallas
    block kernel (``kernels.ops``); ``None`` keeps the Stockham graph path
    — the efficient choice on CPU hosts and inside larger jitted programs.
    """
    scale = 1
    if interpret is not None:
        from repro.kernels.ops import _fft_impl  # lazy: ops imports core.fft

    for ax in range(-ndim, 0):
        if interpret is not None and _is_pow2(x.shape[ax]):
            z = jnp.moveaxis(x, ax, -1)
            z = _fft_impl(z, inverse=inverse, interpret=interpret)
            if inverse:      # _fft_impl normalizes; undo, normalize once
                z = z * z.shape[-1]
            x = jnp.moveaxis(z, -1, ax)
        else:
            x = _local_axis_fft(x, ax, inverse=inverse)
        scale *= x.shape[ax]
    return x / scale if inverse else x


# ---------------------------------------------------------------------------
# decomposition choice + communication model
# ---------------------------------------------------------------------------


def slab_feasible(shape: tuple[int, ...], fft_shards: int) -> bool:
    """Slab shards ``shape[0]`` and all-to-alls ``shape[-1]``: both must
    divide by the fft-axis size (power-of-two axes, like the 1-D stack)."""
    return (len(shape) >= 2 and all(_is_pow2(s) for s in shape)
            and shape[0] % fft_shards == 0 and shape[-1] % fft_shards == 0)


def rslab_feasible(shape: tuple[int, ...], fft_shards: int) -> bool:
    """Real-input slab feasibility: a 2-D power-of-two grid whose rows AND
    packed half width both tile over the fft axis — ``D | R`` for the input
    sharding and ``D | C/2`` so the padded half spectrum ``Cp = C/2 + D``
    stays shard-divisible through the inter-axis transpose (which needs
    ``C >= 2*D``). Rank-3 real grids are not supported."""
    return (len(shape) == 2 and all(_is_pow2(s) for s in shape)
            and shape[-1] >= 2 and shape[0] % fft_shards == 0
            and (shape[-1] // 2) % fft_shards == 0)


def pencil_feasible(shape: tuple[int, ...], fft_shards: int,
                    data_shards: int = 1) -> bool:
    """Pencil digit-splits the last axis over ``fft`` and the second-to-last
    over ``data``: each needs the 1-D DistPlan constraint N >= shards^2."""
    if len(shape) < 2 or not all(_is_pow2(s) for s in shape):
        return False
    if not _is_pow2(fft_shards) or not _is_pow2(data_shards):
        return False
    return (shape[-1] >= fft_shards * fft_shards
            and shape[-2] >= data_shards * data_shards)


def collective_volume_nd(shape: tuple[int, ...], batch: int, fft_shards: int,
                         *, decomp: str = DECOMP_SLAB, itemsize: int = 8,
                         ft: bool = False, groups: int = 1,
                         data_shards: int = 1, natural_order: bool = True,
                         real: bool = False, chunks: int = 1) -> dict:
    """Analytic per-device communication model of one distributed n-D
    transform over ``shape`` (cross-checked against the post-partitioning
    HLO by ``benchmarks/fft_distributed.py``).

    **slab**: ONE all-to-all over the locally-resident block — ``rows *
    grid/D`` elements, ``rows = (batch + 2*groups if ft)/data_shards``
    (batch and its checksum grids shard over ``data``; the 2 checksum grids
    per group are the ABFT's only volume, ``2*groups/batch`` relative).
    Natural order is FREE (the output sharding lands on the last transform
    axis — no digit restore, zero all-gathers), so ``natural_order`` does
    not change the slab model. The grouped verdict psum is identical to
    the 1-D model: ``3*groups/data_shards + 1`` verdict scalars plus the
    ``5*groups/data_shards``-real replicated-stats broadcast, at ring
    factor 2.

    **pencil**: TWO all-to-alls (one per mesh axis; one when
    ``data_shards == 1``), each moving the full local block — ``batch *
    grid/(D*data)`` elements (the batch is *replicated*: pencil spends the
    data axis on the second transform axis). ``natural_order=True`` adds
    the digit restore, which GSPMD lowers to one all-gather per mesh axis:
    ``full/data_shards`` (fft gathered first) then ``full`` bytes, where
    ``full = batch * grid * itemsize``. ABFT composes with the slab
    transpose only — ``ft=True`` raises here.

    **real** (``real=True``, slab only): the transpose moves the PADDED
    half spectrum — ``Cp = C/2 + D`` columns instead of C — so every slab
    a2a/local term scales by ``(C/2 + D)/C`` (about 0.5 + D/C, the ~2x
    byte win of :func:`distributed_rfft2`); checksum grids ride at the
    same half width, and the verdict psum is unchanged. The pencil real
    path is a composition of two 1-D transforms with no closed-form nd
    model here, so ``real=True`` with ``decomp='pencil'`` raises.

    ``chunks > 1`` (pencil only — the slab pipeline is bulk-synchronous)
    models the multi-transaction pencil: each digit pass splits into
    ``chunks`` all-to-alls of ``1/chunks`` the bytes, total volume
    unchanged, with ``exposed_fraction = 1/chunks`` of the collective
    latency left unhidden (chunk i's transfer overlaps chunk i+1's local
    digit FFTs) and ``overlap_efficiency = 1 - 1/chunks``.

    ``*_wire`` entries are link-crossing bytes; ``hlo_bytes`` matches
    :func:`repro.launch.dryrun.collective_bytes` on the same program.
    """
    if decomp not in _DECOMPS:
        raise ValueError(f"decomp must be {'|'.join(_DECOMPS)}, got {decomp!r}")
    chunks = max(1, int(chunks))
    if chunks > 1 and decomp != DECOMP_PENCIL:
        raise ValueError(
            "chunked (multi-transaction) execution rides the pencil digit "
            "passes; the slab inter-axis transpose is bulk-synchronous — "
            f"got decomp={decomp!r} with chunks={chunks}")
    if real and decomp != DECOMP_SLAB:
        raise ValueError(
            "the real-input model is slab-only (rfft2 rides the padded "
            "half-spectrum transpose); the pencil real path composes two "
            "1-D transforms — model each with collective_volume(real=True)")
    cols = shape[-1] // 2 + fft_shards if real else shape[-1]
    grid = int(np.prod(shape[:-1])) * cols
    d = fft_shards
    dd = data_shards
    if decomp == DECOMP_SLAB:
        if ft and groups % dd:
            raise ValueError(f"groups={groups} must divide over "
                             f"data_shards={dd}")
        rows = (batch + (2 * groups if ft else 0)) / dd
        a2a_hlo = rows * grid * itemsize / d
        a2a_wire = a2a_hlo * (d - 1) / d
        # per-group verdict scalars + one energy scalar, plus the stats
        # extraction: grouped pipelines broadcast ONE stacked (G/dd, 5)-
        # real block, the ungrouped pipeline reduces its native scalars
        # (3 predicates + score real + s32 location) — same structure the
        # 1-D model counts; the plan auditor's per-kind psum diff pinned
        # both terms down here too
        verdict = (3 * groups // dd + 1) * (itemsize // 2)
        stats = (5 * groups // dd * (itemsize // 2) if groups > 1
                 else 3 + (itemsize // 2) + 4)
        psum_hlo = 2.0 * (verdict + stats) if ft else 0.0
        psum_wire = psum_hlo * (d - 1) / d
        # stats extraction on a batch-sharded mesh: one data-axis
        # collective-permute of the 5*groups/dd-real block (see the 1-D
        # model)
        permute_hlo = (5 * groups // dd * (itemsize // 2)
                       if ft and dd > 1 else 0.0)
        gather_hlo = gather_wire = 0.0
        a2a_count, gather_count = 1, 0
        local_bytes = rows * grid * itemsize / d
    else:
        if ft:
            raise ValueError("grouped ABFT rides the slab inter-axis "
                             "transpose; decomp='pencil' has no ft model")
        local = batch * grid * itemsize / (d * dd)
        a2a_count = (2 if dd > 1 else 1) * chunks
        a2a_hlo = (2 if dd > 1 else 1) * local
        # the two all-to-alls live on different axes with different fanouts
        a2a_wire = local * (d - 1) / d
        if dd > 1:
            a2a_wire += local * (dd - 1) / dd
        psum_hlo = psum_wire = permute_hlo = 0.0
        full = float(batch * grid * itemsize)
        if natural_order:
            gather_hlo = full + (full / dd if dd > 1 else 0.0)
            gather_wire = full * (d - 1) / d if dd == 1 else (
                (full / dd) * (d - 1) / d + full * (dd - 1) / dd)
            gather_count = 2 if dd > 1 else 1
        else:
            gather_hlo = gather_wire = 0.0
            gather_count = 0
        local_bytes = local
    return {
        "decomp": decomp,
        "shape": tuple(shape),
        "shards": d,
        "data_shards": dd,
        "groups": groups,
        "real": real,
        "chunks": chunks,
        "exposed_fraction": 1.0 / chunks,
        "overlap_efficiency": 1.0 - 1.0 / chunks,
        "all_to_all_count": a2a_count,
        "all_gather_count": gather_count,
        "all_to_all_bytes": a2a_hlo,
        "all_to_all_wire": a2a_wire,
        "gather_hlo": gather_hlo,
        "gather_wire": gather_wire,
        "psum_hlo": psum_hlo,
        "psum_wire": psum_wire,
        "permute_hlo": permute_hlo,
        "total_wire": a2a_wire + gather_wire + psum_wire + permute_hlo,
        "hlo_bytes": a2a_hlo + gather_hlo + psum_hlo + permute_hlo,
        "local_bytes": local_bytes,
        "abft_overhead": 2.0 * groups / batch if (ft and batch) else 0.0,
    }


def choose_decomp(shape: tuple[int, ...], mesh: Mesh | None, *,
                  batch: int = 1, ft: bool = False,
                  natural_order: bool = True, axis: str = FFT_AXIS,
                  data_axis: str | None = _AUTO) -> str:
    """Pick the decomposition for an n-D transform over ``shape`` on
    ``mesh`` — ``"slab"``, ``"pencil"``, or ``"local"``.

    Driven by :func:`collective_volume_nd`: among the feasible candidates
    the one moving fewer modeled bytes wins. In practice slab wins whenever
    the batch can keep the ``data`` axis busy (one all-to-all vs two of
    the same size), and pencil wins when one large grid must scale over
    the whole 2-D mesh (slab would leave ``data`` idle, paying ``dd`` times
    the per-device volume). ABFT (``ft=True``) rides the slab transpose,
    so it forces slab.
    """
    shape = tuple(int(s) for s in shape)
    mesh = _resolve_mesh(mesh, axis)
    if mesh is None or mesh.shape[axis] == 1:
        return "local"
    d = mesh.shape[axis]
    daxis = _resolve_data_axis(mesh, data_axis)
    dd = mesh.shape[daxis] if daxis else 1
    cands = []
    if slab_feasible(shape, d):
        # batch shards over data only when it divides (else it replicates
        # and the data axis buys slab nothing)
        bdd = dd if (dd > 1 and batch % dd == 0) else 1
        g = 1 if not ft else max(bdd, 1)
        cands.append((DECOMP_SLAB, collective_volume_nd(
            shape, batch, d, data_shards=bdd, ft=ft, groups=g,
            natural_order=natural_order)))
    if not ft and pencil_feasible(shape, d, dd):
        cands.append((DECOMP_PENCIL, collective_volume_nd(
            shape, batch, d, decomp=DECOMP_PENCIL, data_shards=dd,
            natural_order=natural_order)))
    if not cands:
        raise ValueError(
            f"no feasible decomposition for shape={shape} on a "
            f"{d}-way fft axis (data={dd}): slab needs fft | shape[0] and "
            f"fft | shape[-1]; pencil needs shape[-1] >= fft^2 and "
            f"shape[-2] >= data^2 (power-of-two axes throughout)")
    # fewer modeled collective bytes wins; per-device footprint breaks the
    # tie (a batch-of-one slab leaves the data axis idle, so at equal
    # volume the pencil's smaller resident block carries the day)
    cands.sort(key=lambda c: (c[1]["hlo_bytes"], c[1]["local_bytes"]))
    return cands[0][0]


# ---------------------------------------------------------------------------
# slab pipeline
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _slab_fftn_fn(mesh: Mesh, axis: str, ndim: int, inverse: bool,
                  data_axis: str | None = None):
    """Jitted slab pipeline for one (mesh, rank, direction).

    Forward: input sharded over the FIRST transform axis -> local FFT over
    the trailing axes -> one all-to-all (split last, gather first) -> local
    FFT over the first -> output sharded over the LAST transform axis.
    Inverse runs the same dataflow mirrored (input sharded over the last
    axis, output over the first), so ``ifftn(fftn(x))`` round-trips with no
    relayout between the two calls.
    """
    dsize = mesh.shape[data_axis] if data_axis else 1

    @jax.jit
    def run(x):  # x: (..., s0, ..., s_{nd-1}) complex
        shape = x.shape
        tshape = shape[-ndim:]
        z = x.reshape((-1,) + tshape)
        b = z.shape[0]
        bspec = data_axis if (data_axis and b % dsize == 0) else None
        first, last = 1, ndim   # transform-axis positions in the (B, ...) cube

        def body(zl):
            if inverse:
                # input sharded over the last axis: every other axis resident
                for ax in range(first, last):
                    zl = _local_axis_fft(zl, ax, inverse=True)
                zl = jax.lax.all_to_all(zl, axis, split_axis=first,
                                        concat_axis=last, tiled=True)
                zl = _local_axis_fft(zl, last, inverse=True)
                return zl / int(np.prod(tshape))
            # forward: input sharded over the first axis
            for ax in range(first + 1, last + 1):
                zl = _local_axis_fft(zl, ax, inverse=False)
            zl = jax.lax.all_to_all(zl, axis, split_axis=last,
                                    concat_axis=first, tiled=True)
            return _local_axis_fft(zl, first, inverse=False)

        shard_pos = (last if inverse else first, first if inverse else last)
        in_spec = [bspec] + [None] * ndim
        out_spec = [bspec] + [None] * ndim
        in_spec[shard_pos[0]] = axis
        out_spec[shard_pos[1]] = axis
        out = shard_map(body, mesh=mesh, in_specs=P(*in_spec),
                        out_specs=P(*out_spec), check_rep=False)(z)
        return out.reshape(shape)

    return run


# ---------------------------------------------------------------------------
# pencil pipeline
# ---------------------------------------------------------------------------


def _chunk_apply(zl, fn, chunks: int, caxes):
    """Run ``fn`` over ``zl`` in ``chunks`` transactions split along the
    first axis in ``caxes`` that can carry them (all candidates are
    unsharded and ``fn`` is independent along each, so contiguous chunks
    concatenate back bitwise-identically). Falls through to one bulk call
    when no axis divides."""
    for ca in caxes:
        ce = resolve_chunks(zl.shape[ca], chunks)
        if ce > 1:
            parts = jnp.split(zl, ce, axis=ca)
            return jnp.concatenate([fn(p) for p in parts], axis=ca)
    return fn(zl)


@functools.lru_cache(maxsize=None)
def _pencil_fftn_fn(mesh: Mesh, axis: str, ndim: int, inverse: bool,
                    natural_order: bool, data_axis: str | None = None,
                    chunks: int = 1):
    """Jitted pencil pipeline: the last two transform axes each run the 1-D
    DistPlan digit decomposition — last over ``axis`` (fft), second-to-last
    over ``data_axis`` — leading transform axes stay local. The cube layout
    is ``(B, lead..., r1, r2, c1, c2)``; forward output holds both
    distributed axes in transposed digit order (``k1`` sharded), and the
    inverse consumes exactly that order (TRANSPOSED_IN), so the round trip
    never redistributes. ``natural_order=True`` adds the digit restore
    outside the shard_map (GSPMD lowers it to one all-gather per mesh
    axis; see ``collective_volume_nd``).

    ``chunks > 1`` pipelines the distributed passes: the batch — or, for a
    single rank-3 grid, the leading (local) transform axis, where overlap
    matters most — splits into that many transactions so transaction i's
    all-to-alls hide behind transaction i+1's local digit FFTs. The
    leading-axis FFTs themselves run unchunked (they are local and precede
    any collective); results are bitwise-identical to the bulk path.
    """
    shards = mesh.shape[axis]
    dsize = mesh.shape[data_axis] if data_axis else 1

    @jax.jit
    def run(x):  # x: (..., s0, ..., R, C) complex
        shape = x.shape
        tshape = shape[-ndim:]
        rr, cc = tshape[-2], tshape[-1]
        pc = make_dist_plan(cc, shards)
        c1, c2 = pc.n1, pc.n2
        if dsize > 1:
            pr = make_dist_plan(rr, dsize)
            r1, r2 = pr.n1, pr.n2
        else:
            r1, r2 = rr, 1
        lead = tshape[:-2]
        nl = len(lead)
        cube = (-1,) + lead + (r1, r2, c1, c2)
        z = x.reshape(cube)
        # cube axis positions (leading batch dim at 0)
        ax_r1, ax_r2 = 1 + nl, 2 + nl
        ax_c1, ax_c2 = 3 + nl, 4 + nl
        tw_c = jnp.asarray(factors.stage_twiddle(c1, c2, inverse=inverse),
                           dtype=x.dtype)
        tw_r = (jnp.asarray(factors.stage_twiddle(r1, r2, inverse=inverse),
                            dtype=x.dtype) if dsize > 1 else None)

        def fwd_pass(zl, mesh_ax, a1, a2, tw):
            """One 1-D digit pass: FFT over the slow digit (a1), twiddle,
            all-to-all (split a1, gather a2), FFT over the fast digit."""
            i = jax.lax.axis_index(mesh_ax)
            nloc = zl.shape[a2]
            zl = _local_axis_fft(zl, a1, inverse=inverse)
            twl = jax.lax.dynamic_slice_in_dim(tw, i * nloc, nloc, axis=1)
            zl = zl * jnp.expand_dims(
                twl, [d for d in range(zl.ndim) if d not in (a1, a2)])
            zl = jax.lax.all_to_all(zl, mesh_ax, split_axis=a1,
                                    concat_axis=a2, tiled=True)
            return _local_axis_fft(zl, a2, inverse=inverse)

        def inv_pass(zl, mesh_ax, a1, a2, tw):
            """Mirror of fwd_pass consuming transposed digit order: IFFT
            over the fast digit, conjugate twiddle (sliced over the sharded
            k1 rows), all-to-all (split a2, gather a1), IFFT over the slow
            digit."""
            i = jax.lax.axis_index(mesh_ax)
            n1l = zl.shape[a1]
            zl = _local_axis_fft(zl, a2, inverse=True)
            twl = jax.lax.dynamic_slice_in_dim(tw, i * n1l, n1l, axis=0)
            zl = zl * jnp.expand_dims(
                twl, [d for d in range(zl.ndim) if d not in (a1, a2)])
            zl = jax.lax.all_to_all(zl, mesh_ax, split_axis=a2,
                                    concat_axis=a1, tiled=True)
            return _local_axis_fft(zl, a1, inverse=True)

        # chunk candidates: the (replicated) batch axis first, then the
        # leading local transform axes — the rank-3 single-grid case rides
        # the first lead axis. All are unsharded and both digit passes are
        # independent along them, so contiguous chunks are placement-safe.
        caxes = (0,) + tuple(1 + k for k in range(nl))

        def dist_fwd(zc):
            """The distributed tail of the forward: both digit passes.
            Per-transaction when chunked — chunk i's all-to-alls overlap
            chunk i+1's local digit FFTs."""
            zc = fwd_pass(zc, axis, ax_c1, ax_c2, tw_c)
            if dsize > 1:
                zc = fwd_pass(zc, data_axis, ax_r1, ax_r2, tw_r)
            else:
                zc = _local_axis_fft(zc, ax_r1, inverse=False)
            return zc

        def dist_inv(zc):
            """The distributed head of the inverse (mirror of dist_fwd)."""
            if dsize > 1:
                zc = inv_pass(zc, data_axis, ax_r1, ax_r2, tw_r)
            else:
                zc = _local_axis_fft(zc, ax_r1, inverse=True)
            return inv_pass(zc, axis, ax_c1, ax_c2, tw_c)

        def body(zl):
            if not inverse:
                for k in range(nl):                 # leading axes: local
                    zl = _local_axis_fft(zl, 1 + k, inverse=False)
                return _chunk_apply(zl, dist_fwd, chunks, caxes)
            zl = _chunk_apply(zl, dist_inv, chunks, caxes)
            for k in range(nl):
                zl = _local_axis_fft(zl, 1 + k, inverse=True)
            return zl / int(np.prod(tshape))

        daxis_spec = data_axis if dsize > 1 else None
        # forward in / inverse out: fast digits sharded (r2/data, c2/fft);
        # forward out / inverse in: slow digits sharded (transposed order)
        spec_in = [None] * (1 + nl) + [None, daxis_spec, None, axis]
        spec_t = [None] * (1 + nl) + [daxis_spec, None, axis, None]
        in_spec, out_spec = ((spec_t, spec_in) if inverse
                             else (spec_in, spec_t))
        out = shard_map(body, mesh=mesh, in_specs=P(*in_spec),
                        out_specs=P(*out_spec), check_rep=False)(z)
        if inverse or not natural_order:
            return out.reshape(shape)
        # digit restore to natural order: (k1, k2) -> (k2, k1) per
        # distributed axis — GSPMD pays one all-gather per mesh axis here
        perm = (list(range(1 + nl))
                + [ax_r2, ax_r1, ax_c2, ax_c1])
        return out.transpose(perm).reshape(shape)

    return run


def _pencil_to_transposed_cube(x, r1, r2, c1, c2):
    """Natural-order input -> the transposed-digit cube layout the pencil
    inverse consumes (the forward's ``natural_order=False`` output is
    already in this layout and skips this)."""
    shape = x.shape
    lead = shape[:-2]
    z = x.reshape(lead + (r2, r1, c2, c1))
    nl = len(lead)
    perm = list(range(nl)) + [nl + 1, nl, nl + 3, nl + 2]
    return z.transpose(perm).reshape(shape)


# ---------------------------------------------------------------------------
# real-input (half-spectrum) transforms: rfft2 / irfft2 on the slab
# ---------------------------------------------------------------------------
#
# The 1-D Hermitian packing trick (extensions.rfft) composed with the slab
# row pass: the column transform of a real (R, C) grid runs as ONE C2C FFT
# of length C/2 on z = x[2k] + i*x[2k+1], the elementwise unpack recovers
# the C/2+1 surviving half-spectrum bins, and only those columns — padded
# with D-1 dead zero columns to Cp = C/2 + D so the all-to-all's split axis
# stays shard-divisible — flow through the inter-axis transpose before the
# row FFT. Roughly HALF the all-to-all bytes of the C2C slab on the same
# grid ((C/2 + D)/C, modeled by collective_volume_nd(real=True)).


def _complex_of(dtype) -> jnp.dtype:
    return jnp.dtype(jnp.complex128 if dtype in (jnp.float64, jnp.complex128)
                     else jnp.complex64)


def _unpack_half(zf: jax.Array, cc: int) -> jax.Array:
    """Hermitian unpack of the packed half-length spectrum: (..., C/2)
    C2C bins of z = x_even + i*x_odd -> the (..., C/2+1) rfft bins."""
    half = cc // 2
    k = jnp.arange(half + 1)
    w = jnp.exp(-2j * np.pi * k / cc).astype(zf.dtype)
    zf_ext = jnp.concatenate([zf, zf[..., :1]], axis=-1)      # Z[half] = Z[0]
    zconj = jnp.conj(zf_ext[..., ::-1])                        # Z*[half-k]
    return 0.5 * (zf_ext + zconj) - 0.5j * w * (zf_ext - zconj)


def _rfft_cols(x: jax.Array) -> jax.Array:
    """Packed rfft over the (even-length) last axis: (..., C) real ->
    (..., C/2+1) half spectrum, via one half-length C2C transform."""
    cc = x.shape[-1]
    z = (x[..., 0::2] + 1j * x[..., 1::2]).astype(_complex_of(x.dtype))
    return _unpack_half(_local_axis_fft(z, -1, inverse=False), cc)


def _irfft_cols(y: jax.Array) -> jax.Array:
    """Inverse of :func:`_rfft_cols` (normalized): (..., C/2+1) half
    spectrum -> (..., C) real, C = 2*(bins-1). Recovers the packed
    half-length time signal z = x_even + i*x_odd from the spectrum's
    even/odd split, then interleaves its real and imaginary parts."""
    half = y.shape[-1] - 1
    cc = 2 * half
    k = jnp.arange(half)
    winv = jnp.exp(2j * np.pi * k / cc).astype(y.dtype)
    yh = y[..., :half]
    ymir = jnp.conj(y[..., 1:][..., ::-1])                     # Y*[half-k]
    e = 0.5 * (yh + ymir)
    o = 0.5 * winv * (yh - ymir)
    z = _local_axis_fft(e + 1j * o, -1, inverse=True) / half
    out = jnp.stack([jnp.real(z), jnp.imag(z)], axis=-1)
    return out.reshape(out.shape[:-2] + (cc,))


def _local_rfft2(x: jax.Array) -> jax.Array:
    """Local rfft2 over the last two axes ((..., R, C) real ->
    (..., R, C/2+1)); odd C runs the direct DFT and crops (the same
    fallback as the odd-n 1-D paths)."""
    cc = x.shape[-1]
    if cc % 2:
        z = _local_axis_fft(x.astype(_complex_of(x.dtype)), -1,
                            inverse=False)[..., :cc // 2 + 1]
    else:
        z = _rfft_cols(x)
    return _local_axis_fft(z, -2, inverse=False)


def _local_irfft2(y: jax.Array, *, cc: int | None = None) -> jax.Array:
    """Local irfft2: (..., R, bins) half spectrum -> (..., R, cc) real
    (default ``cc = 2*(bins-1)``; odd ``cc`` reconstructs the full
    Hermitian spectrum and runs the direct inverse DFT)."""
    bins = y.shape[-1]
    if cc is None:
        cc = 2 * (bins - 1)
    rr = y.shape[-2]
    y = y.astype(_complex_of(y.dtype))
    z = _local_axis_fft(y, -2, inverse=True) / rr
    if cc % 2:
        m = (cc + 1) // 2   # bins of an odd-length real signal
        yh = z[..., :m]
        tail = jnp.conj(yh[..., 1:][..., ::-1])
        full = jnp.concatenate([yh, tail], axis=-1)
        return jnp.real(naive_dft(full, inverse=True))
    return _irfft_cols(z[..., :cc // 2 + 1])


@functools.lru_cache(maxsize=None)
def _rslab_fft2_fn(mesh: Mesh, axis: str, data_axis: str | None = None):
    """Jitted real slab forward: input real grids sharded over R ->
    packed half-length FFT over C + Hermitian unpack (local) -> pad to
    Cp = C/2 + D -> ONE all-to-all (split columns, gather R) -> local FFT
    over R. Output is the PADDED (..., R, Cp) half spectrum sharded over
    the column axis; callers slice the C/2+1 live bins."""
    shards = mesh.shape[axis]
    dsize = mesh.shape[data_axis] if data_axis else 1

    @jax.jit
    def run(x):  # x: (..., R, C) real
        shape = x.shape
        rr, cc = shape[-2], shape[-1]
        cp = cc // 2 + shards
        z = x.reshape((-1, rr, cc))
        b = z.shape[0]
        bspec = data_axis if (data_axis and b % dsize == 0) else None

        def body(zl):                                  # (b, R/D, C) real
            hc = _rfft_cols(zl)                        # (b, R/D, C/2+1)
            hc = jnp.pad(hc, ((0, 0), (0, 0), (0, shards - 1)))
            hc = jax.lax.all_to_all(hc, axis, split_axis=2, concat_axis=1,
                                    tiled=True)        # (b, R, Cp/D)
            return _local_axis_fft(hc, 1, inverse=False)

        out = shard_map(body, mesh=mesh, in_specs=P(bspec, axis, None),
                        out_specs=P(bspec, None, axis),
                        check_rep=False)(z)
        return out.reshape(shape[:-2] + (rr, cp))

    return run


@functools.lru_cache(maxsize=None)
def _rslab_ifft2_fn(mesh: Mesh, axis: str, data_axis: str | None = None):
    """Jitted real slab inverse, mirroring :func:`_rslab_fft2_fn`: padded
    (..., R, Cp) half spectrum sharded over columns -> local IFFT over R ->
    ONE all-to-all (split R, gather columns) -> slice the live bins ->
    local Hermitian inverse over C -> (..., R, C) real sharded over R."""
    shards = mesh.shape[axis]
    dsize = mesh.shape[data_axis] if data_axis else 1

    @jax.jit
    def run(y):  # y: (..., R, Cp) complex, Cp = C/2 + D
        shape = y.shape
        rr, cp = shape[-2], shape[-1]
        half = cp - shards
        cc = 2 * half
        z = y.reshape((-1, rr, cp))
        b = z.shape[0]
        bspec = data_axis if (data_axis and b % dsize == 0) else None

        def body(zl):                                  # (b, R, Cp/D)
            zl = _local_axis_fft(zl, 1, inverse=True) / rr
            zl = jax.lax.all_to_all(zl, axis, split_axis=1, concat_axis=2,
                                    tiled=True)        # (b, R/D, Cp)
            return _irfft_cols(zl[..., :half + 1])     # (b, R/D, C) real

        out = shard_map(body, mesh=mesh, in_specs=P(bspec, None, axis),
                        out_specs=P(bspec, axis, None),
                        check_rep=False)(z)
        return out.reshape(shape[:-2] + (rr, cc))

    return run


def distributed_rfft2(x: jax.Array, mesh: Mesh | None = None, *,
                      axis: str = FFT_AXIS,
                      data_axis: str | None = _AUTO) -> jax.Array:
    """2-D real-input FFT over the last two axes -> (..., R, C/2+1) half
    spectrum, distributed over ``mesh`` via the real slab pipeline (about
    half the all-to-all bytes of :func:`distributed_fft2` on the same
    grid — see :func:`collective_volume_nd` with ``real=True``).

    Matches ``jnp.fft.rfft2``. Like the 1-D ``extensions.rfft``, sizes the
    mesh cannot split (:func:`rslab_feasible`) fall back to the local
    transform, which also covers odd grids via the direct DFT.
    """
    x = jnp.asarray(x)
    if jnp.issubdtype(x.dtype, jnp.complexfloating):
        raise ValueError(f"rfft2 takes a real input, got {x.dtype}")
    if x.ndim < 2:
        raise ValueError(f"rfft2 needs a rank >= 2 input, got {x.shape}")
    mesh = _resolve_mesh(mesh, axis)
    tshape = (int(x.shape[-2]), int(x.shape[-1]))
    if mesh is None or mesh.shape[axis] == 1 \
            or not rslab_feasible(tshape, mesh.shape[axis]):
        return _local_rfft2(x)
    daxis = _resolve_data_axis(mesh, data_axis)
    out = _rslab_fft2_fn(mesh, axis, daxis)(x)
    return out[..., :tshape[-1] // 2 + 1]


def distributed_irfft2(y: jax.Array, mesh: Mesh | None = None, *,
                       axis: str = FFT_AXIS,
                       data_axis: str | None = _AUTO) -> jax.Array:
    """Inverse of :func:`distributed_rfft2`: (..., R, bins) half spectrum
    -> (..., R, 2*(bins-1)) real grid. Matches ``jnp.fft.irfft2`` (even
    output widths; infeasible sizes run locally)."""
    y = jnp.asarray(y)
    if y.ndim < 2:
        raise ValueError(f"irfft2 needs a rank >= 2 spectrum, got {y.shape}")
    if y.shape[-1] < 2:
        raise ValueError("irfft2: a single-bin half spectrum has no "
                         "default width (2*(bins-1) = 0) — the planned "
                         "grid needs >= 2 bins")
    y = y.astype(_complex_of(y.dtype))
    half = y.shape[-1] - 1
    cc = 2 * half
    mesh = _resolve_mesh(mesh, axis)
    tshape = (int(y.shape[-2]), cc)
    if mesh is None or mesh.shape[axis] == 1 \
            or not rslab_feasible(tshape, mesh.shape[axis]):
        return _local_irfft2(y, cc=cc)
    daxis = _resolve_data_axis(mesh, data_axis)
    shards = mesh.shape[axis]
    yp = jnp.pad(y, [(0, 0)] * (y.ndim - 1) + [(0, shards - 1)])
    return _rslab_ifft2_fn(mesh, axis, daxis)(yp)


def _composed_rfft2(x: jax.Array, *, mesh: Mesh | None,
                    axis: str = FFT_AXIS,
                    data_axis: str | None = _AUTO) -> jax.Array:
    """Pencil-path rfft2: a correctness-first composition — the 1-D
    distributed rfft over the columns (half-length pencil pipeline plus
    elementwise Hermitian unpack), then a natural-order C2C pass over the
    rows. The slab is the optimized real path; this exists so explicit
    ``decomp='pencil'`` real plans still scale the column transform."""
    from . import api
    from .extensions import rfft as _rfft_ext

    y = _rfft_ext(x, mesh=mesh, axis=axis, data_axis=data_axis)
    z = jnp.moveaxis(y, -2, -1)                        # (..., C/2+1, R)
    if mesh is not None and mesh.shape[axis] > 1 \
            and api._feasible_1d(z.shape[-1], mesh.shape[axis]):
        p = api.plan(api.spec_for(z, mesh=mesh, axis=axis, data_axis=None))
        z = p.fft(z)
    else:
        z = _local_axis_fft(z, -1, inverse=False)
    return jnp.moveaxis(z, -1, -2)


def _composed_irfft2(y: jax.Array, *, cc: int, mesh: Mesh | None,
                     axis: str = FFT_AXIS,
                     data_axis: str | None = _AUTO) -> jax.Array:
    """Inverse of :func:`_composed_rfft2`: C2C inverse over the rows, then
    the 1-D distributed irfft over the columns (length ``cc``)."""
    from . import api
    from .extensions import irfft as _irfft_ext

    y = jnp.asarray(y)
    y = y.astype(_complex_of(y.dtype))
    z = jnp.moveaxis(y, -2, -1)                        # (..., bins, R)
    if mesh is not None and mesh.shape[axis] > 1 \
            and api._feasible_1d(z.shape[-1], mesh.shape[axis]):
        p = api.plan(api.spec_for(z, mesh=mesh, axis=axis, data_axis=None))
        z = p.ifft(z)
    else:
        z = _local_axis_fft(z, -1, inverse=True) / z.shape[-1]
    z = jnp.moveaxis(z, -1, -2)
    return _irfft_ext(z, n=cc, mesh=mesh, axis=axis, data_axis=data_axis)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def distributed_fftn(x: jax.Array, mesh: Mesh | None = None, *,
                     ndim: int | None = None, decomp: str = "auto",
                     inverse: bool = False, natural_order: bool = True,
                     axis: str = FFT_AXIS, data_axis: str | None = _AUTO,
                     interpret: bool | None = None,
                     chunks: int = 1) -> jax.Array:
    """N-D FFT over the last ``ndim`` axes (default: all, capped at 3),
    distributed over ``mesh``. Matches ``jnp.fft.fftn`` conventions.

    ``decomp`` picks the layout — ``"slab"``, ``"pencil"``, or ``"auto"``
    (:func:`choose_decomp` via the communication model). ``natural_order``
    only matters for pencil (slab's natural order is free; the flag is a
    no-op there): ``False`` keeps the two distributed axes in the 1-D
    pipeline's transposed digit order — ``y[.., k1*N2+k2] = X[.., k1+N1*k2]``
    per axis — and on the *inverse* declares the input to be in exactly
    that order (TRANSPOSED_IN), so a pencil round trip pays zero
    all-gathers. With ``mesh=None`` (or a trivial fft axis) this is the
    local transform; odd / non-power-of-two axes are supported there via
    the direct DFT, and ``interpret`` routes power-of-two axes through the
    Pallas block kernel.

    ``chunks > 1`` (pencil only) splits the batch — or, for a single
    rank-3 grid, the leading local transform axis — into that many
    transactions so each chunk's all-to-alls overlap the next chunk's
    local digit FFTs (see :func:`collective_volume_nd`). The slab and
    local paths ignore it (bulk-synchronous by construction); results are
    bitwise-identical for every chunk count.
    """
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    if ndim is None:
        ndim = min(x.ndim, 3)
    if ndim < 2 or ndim > 3:
        raise ValueError(f"ndim must be 2 or 3, got {ndim}")
    if x.ndim < ndim:
        raise ValueError(f"input rank {x.ndim} < ndim={ndim}")
    mesh = _resolve_mesh(mesh, axis)
    tshape = tuple(int(s) for s in x.shape[-ndim:])
    batch = int(np.prod(x.shape[:-ndim], dtype=np.int64)) if x.ndim > ndim \
        else 1
    if decomp == "auto":
        decomp = choose_decomp(tshape, mesh, batch=batch, axis=axis,
                               natural_order=natural_order,
                               data_axis=data_axis) \
            if mesh is not None and mesh.shape[axis] > 1 else "local"
    if decomp not in _DECOMPS + ("local",):
        raise ValueError(f"decomp must be auto|{'|'.join(_DECOMPS)}|local, "
                         f"got {decomp!r}")
    if decomp == "local" or mesh is None or mesh.shape[axis] == 1:
        return _local_fftn(x, ndim, inverse=inverse, interpret=interpret)
    daxis = _resolve_data_axis(mesh, data_axis)
    if decomp == DECOMP_SLAB:
        if not slab_feasible(tshape, mesh.shape[axis]):
            raise ValueError(
                f"slab needs power-of-two axes with "
                f"{mesh.shape[axis]} | {tshape[0]} and "
                f"{mesh.shape[axis]} | {tshape[-1]}, got {tshape}")
        return _slab_fftn_fn(mesh, axis, ndim, inverse, daxis)(x)
    dd = mesh.shape[daxis] if daxis else 1
    if not pencil_feasible(tshape, mesh.shape[axis], dd):
        raise ValueError(
            f"pencil needs {tshape[-1]} >= fft^2={mesh.shape[axis] ** 2} "
            f"and {tshape[-2]} >= data^2={dd * dd} (power-of-two axes), "
            f"got {tshape}")
    if inverse and natural_order:
        # natural-order input: permute into the transposed cube the
        # inverse pipeline consumes (the redistribution the transposed
        # pairing exists to skip)
        pc = make_dist_plan(tshape[-1], mesh.shape[axis])
        if dd > 1:
            pr = make_dist_plan(tshape[-2], dd)
            r1, r2 = pr.n1, pr.n2
        else:
            r1, r2 = tshape[-2], 1
        x = _pencil_to_transposed_cube(x, r1, r2, pc.n1, pc.n2)
    return _pencil_fftn_fn(mesh, axis, ndim, inverse,
                           bool(natural_order), daxis, int(chunks))(x)


def distributed_fft2(x: jax.Array, mesh: Mesh | None = None,
                     **kwargs) -> jax.Array:
    """2-D FFT over the last two axes (see :func:`distributed_fftn`)."""
    return distributed_fftn(x, mesh, ndim=2, **kwargs)


def distributed_ifft2(x: jax.Array, mesh: Mesh | None = None,
                      **kwargs) -> jax.Array:
    """Inverse 2-D FFT (normalized by 1/(R*C)); ``natural_order=False``
    consumes the forward's transposed-digit pencil output with no
    redistribution."""
    return distributed_fftn(x, mesh, ndim=2, inverse=True, **kwargs)


def distributed_ifftn(x: jax.Array, mesh: Mesh | None = None,
                      **kwargs) -> jax.Array:
    """Inverse of :func:`distributed_fftn` (normalized by 1/prod(shape))."""
    return distributed_fftn(x, mesh, inverse=True, **kwargs)


# ---------------------------------------------------------------------------
# grouped two-side ABFT on the slab pipeline (2-D)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _ft_slab_fft2_fn(mesh: Mesh, axis: str, threshold: float, correct: bool,
                     groups: int = 1, data_axis: str | None = None):
    """The slab 2-D forward with the PR-3 grouped two-side ABFT composed
    onto it: per checksum group, two right-side checksum GRIDS ride the
    inter-axis transpose as extra batch rows, and the verdict is one psum
    of 3 scalars per locally-owned group + 1 shared energy scalar,
    confined to the ``fft`` axis."""
    shards = mesh.shape[axis]
    dsize = mesh.shape[data_axis] if data_axis else 1

    @jax.jit
    def run(x, inject):  # x: (B, R, C) complex; inject: (F, 7) real
        b, rr, cc = x.shape
        g = groups
        s = b // g
        rc = rr * cc
        bspec = data_axis if (
            data_axis and b % dsize == 0 and g % dsize == 0) else None
        dloc = dsize if bspec else 1
        bl, gl = b // dloc, g // dloc
        rl = rr // shards                    # local R rows in pass 1
        ftype = np.float64 if x.dtype == jnp.complex128 else np.float32
        ids = jnp.arange(1, s + 1, dtype=ftype)[None, :, None, None]

        def body(zl):
            d = jax.lax.axis_index(axis)
            md = jax.lax.axis_index(data_axis) if bspec else jnp.int32(0)
            # checksum grids: rows [0, bl) data | [bl, bl+gl) cs2 |
            # [bl+gl, bl+2gl) cs3 — linearity makes each a signal grid
            zg = zl.reshape((gl, s, rl, cc))
            cs2_in = jnp.sum(zg, axis=1)
            cs3_in = jnp.sum(ids * zg, axis=1)
            zc = jnp.concatenate([zl, cs2_in, cs3_in], axis=0)
            # ---- pass 1: FFT over C (resident) + left checksum ------------
            zf = _local_fft(zc, False)
            res1 = jnp.abs(jnp.sum(zf, axis=-1) - cc * zc[..., 0])
            scale1 = jnp.sqrt(jnp.mean(jnp.abs(zc) ** 2, axis=-1)) + EPS
            delta = jnp.max(res1 / (float(np.sqrt(cc)) * scale1))
            zc = zf
            # ---- fault injection (tests/benchmarks): one SEU per row
            # [fft_device, signal, local_r, col, enable, eps_re, eps_im]
            # on the pass-1 output: ``local_r`` indexes this device's R
            # rows (R is sharded pre-transpose), ``col`` is the global C
            # bin. ``signal`` in [B, B+G) / [B+G, B+2G) hits a group's
            # cs2 / cs3 checksum grid. ---------------------------------
            dev = inject[:, 0].astype(jnp.int32)
            sig = inject[:, 1].astype(jnp.int32)
            row = inject[:, 2].astype(jnp.int32)
            col = inject[:, 3].astype(jnp.int32)
            eps = (inject[:, 5] + 1j * inject[:, 6]).astype(zc.dtype)
            is_data = sig < b
            is_cs2 = (sig >= b) & (sig < b + g)
            gidx = jnp.where(is_cs2, sig - b, sig - b - g)
            owner = jnp.where(is_data, sig // bl, gidx // gl)
            lrow = jnp.where(
                is_data, sig - owner * bl,
                bl + jnp.where(is_cs2, 0, gl) + gidx - owner * gl)
            amp = inject[:, 4] * ((owner == md) & (d == dev)).astype(ftype)
            onehot = (
                (jnp.arange(bl + 2 * gl)[None] == lrow[:, None])
                [:, :, None, None]
                * (jnp.arange(rl)[None] == row[:, None])[:, None, :, None]
                * (jnp.arange(cc)[None] == col[:, None])[:, None, None, :])
            zc = zc + jnp.sum((eps * amp.astype(zc.real.dtype))
                              [:, None, None, None]
                              * onehot.astype(zc.real.dtype), axis=0)
            # ---- the one collective: the inter-axis transpose -------------
            zc = jax.lax.all_to_all(zc, axis, split_axis=2, concat_axis=1,
                                    tiled=True)      # (bl+2gl, R, C/D)
            # ---- pass 2: FFT over R (resident) + left checksum ------------
            zt = jnp.swapaxes(zc, -1, -2)
            zf2 = _local_fft(zt, False)
            res2 = jnp.abs(jnp.sum(zf2, axis=-1) - rr * zt[..., 0])
            scale2 = jnp.sqrt(jnp.mean(jnp.abs(zt) ** 2, axis=-1)) + EPS
            delta = jnp.maximum(
                delta, jnp.max(res2 / (float(np.sqrt(rr)) * scale2)))
            zf2 = jnp.swapaxes(zf2, -1, -2)          # (bl+2gl, R, C/D)
            # ---- detect / locate per group --------------------------------
            yl = zf2[:bl]
            fcs2, fcs3 = zf2[bl:bl + gl], zf2[bl + gl:]
            ylg = yl.reshape((gl, s) + yl.shape[1:])
            cs2_out = jnp.sum(ylg, axis=1)
            cs3_out = jnp.sum(ids * ylg, axis=1)
            d2 = fcs2 - cs2_out                      # == -eps_y, sharded
            d3 = fcs3 - cs3_out                      # == -id_s * eps_y
            # the shared grouped two-side decode (one psum of 3*gl + 1
            # scalars on the fft axis) — the SAME helper the 1-D pipeline
            # runs, so the fault taxonomy cannot diverge; a signal here is
            # an (R, C) grid, hence n = R*C
            ylg, stats = _grouped_verdict(
                ylg, d2, d3, cs2_out, axis=axis, threshold=threshold, s=s,
                n=rc, md=md, bl=bl, gl=gl, correct=correct)
            yl = ylg.reshape((bl,) + yl.shape[1:])
            return yl, delta[None, None], stats[None]

        yl, deltas, stats = shard_map(
            body, mesh=mesh,
            in_specs=P(bspec, axis, None),
            out_specs=(P(bspec, None, axis), P(bspec, axis),
                       P(axis, bspec, None)),
            check_rep=False)(x)
        st = stats[0]                # (G, 5); fft shards agree post-psum
        flagged = st[:, 1] > 0.5
        correctable = st[:, 3] > 0.5
        return DistFFTResult(
            y=yl, shard_delta=deltas.reshape((-1,)), group_score=st[:, 0],
            flagged=flagged, location=st[:, 2].astype(jnp.int32),
            correctable=correctable, checksum_fault=st[:, 4] > 0.5,
            corrected=jnp.sum(correctable.astype(jnp.int32)) * int(correct),
            recomputed=jnp.zeros((), jnp.int32))

    return run


def _recompute_uncorrectable2(x, res, mesh, axis, groups):
    """Multi-fault-group policy fallback (the shared
    :func:`~repro.core.fft.distributed._splice_recomputed` machinery),
    recomputing with the plain slab pipeline."""
    return _splice_recomputed(
        x, res, groups,
        lambda rows: distributed_fft2(rows, mesh, axis=axis,
                                      decomp=DECOMP_SLAB, data_axis=None),
        "ft_distributed_fft2")


def ft_distributed_fft2(
    x: jax.Array,
    mesh: Mesh | None = None,
    *,
    axis: str = FFT_AXIS,
    threshold: float = 1e-4,
    correct: bool = True,
    inject: jax.Array | None = None,
    groups: int | None = None,
    group_size: int | None = None,
    data_axis: str | None = _AUTO,
    recompute_uncorrectable: bool = False,
) -> DistFFTResult:
    """Fault-tolerant slab 2-D forward FFT (grouped two-side ABFT).

    The mesh-level grouped multi-transaction scheme of
    :func:`~repro.core.fft.distributed.ft_distributed_fft`, composed with
    the 2-D slab row pass: the batch of (R, C) grids splits into G
    checksum groups, each carrying a ``cs2``/``cs3`` checksum *grid* pair
    through the inter-axis transpose (2G/B relative all-to-all overhead),
    with one verdict psum of ``3*G/data + 1`` scalars confined to the
    ``fft`` axis. One SEU per group per pass is detected, located to its
    signal, and corrected elementwise; batch rows shard over ``data`` (no
    batch all-gather). The verdict taxonomy (correctable / uncorrectable /
    checksum_fault) and the ``recompute_uncorrectable`` host fallback
    match the 1-D contract; see :class:`DistFFTResult`.

    ``inject`` rows are ``[fft_device, signal, local_r, col, enable,
    eps_re, eps_im]`` — an SEU on the pass-1 output, where ``local_r``
    indexes the device's resident R rows (R is sharded before the
    transpose) and ``col`` the global C bin; ``signal`` in ``[B, B+G)`` /
    ``[B+G, B+2G)`` targets a group's cs2 / cs3 checksum grid. The slab
    output is natural-order for free, so there is no ``natural_order``
    knob here.
    """
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    if x.ndim != 3:
        raise ValueError(
            f"ft_distributed_fft2 expects (B, R, C), got {x.shape}")
    mesh = _resolve_mesh(mesh, axis)
    if mesh is None:
        raise ValueError("ft_distributed_fft2 requires a mesh with an "
                         f"'{axis}' axis (see launch.mesh.make_fft_mesh)")
    tshape = tuple(int(s) for s in x.shape[1:])
    if not slab_feasible(tshape, mesh.shape[axis]):
        raise ValueError(
            f"the ft pipeline rides the slab transpose: needs "
            f"power-of-two axes divisible by {mesh.shape[axis]}, "
            f"got {tshape}")
    daxis = _resolve_data_axis(mesh, data_axis)
    dsize = mesh.shape[daxis] if daxis else 1
    g = resolve_abft_groups(x.shape[0], groups=groups, group_size=group_size,
                            data_shards=dsize)
    ftype = jnp.float64 if x.dtype == jnp.complex128 else jnp.float32
    if inject is None:
        inject = jnp.zeros((1, 7), ftype)
    inject = jnp.asarray(inject, ftype)
    if inject.ndim == 1:
        inject = inject[None]
    res = _ft_slab_fft2_fn(mesh, axis, float(threshold), bool(correct),
                           g, daxis)(x, inject)
    if recompute_uncorrectable:
        res = _recompute_uncorrectable2(x, res, mesh, axis, g)
    return res


# ---------------------------------------------------------------------------
# grouped two-side ABFT on the real slab pipeline
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _ft_rslab_fft2_fn(mesh: Mesh, axis: str, threshold: float, correct: bool,
                      groups: int = 1, data_axis: str | None = None):
    """The real slab forward (:func:`_rslab_fft2_fn`) with the grouped
    two-side ABFT composed onto it, on the Hermitian-symmetric checksum
    layout: the cs2/cs3 checksum grids are summed over the REAL input rows,
    and because every map in the pipeline — even/odd pack, half-length C2C
    FFT, Hermitian unpack (the conjugate-tail fold is R-linear), zero-pad,
    transpose, row FFT — is R-linear with *real* group ids,
    ``F(sum id_b x_b) = sum id_b F(x_b)`` holds elementwise on the padded
    half spectrum and the shared decode (:func:`_grouped_verdict`) applies
    unchanged with ``n = R * Cp``. The checksum grids ride the transpose
    at the packed half width — half the checksum traffic of the C2C slab
    ABFT, same relative 2G/B overhead. The pass-1 left checksum guards the
    packed half-length FFT (``sum_k Z[k] = (C/2) * z[0]``)."""
    shards = mesh.shape[axis]
    dsize = mesh.shape[data_axis] if data_axis else 1

    @jax.jit
    def run(x, inject):  # x: (B, R, C) real; inject: (F, 7) real
        b, rr, cc = x.shape
        half = cc // 2
        cp = half + shards
        g = groups
        s = b // g
        rc = rr * cp
        bspec = data_axis if (
            data_axis and b % dsize == 0 and g % dsize == 0) else None
        dloc = dsize if bspec else 1
        bl, gl = b // dloc, g // dloc
        rl = rr // shards                    # local R rows in pass 1
        ftype = np.float64 if x.dtype == jnp.float64 else np.float32
        ctype = jnp.complex128 if x.dtype == jnp.float64 else jnp.complex64
        ids = jnp.arange(1, s + 1, dtype=ftype)[None, :, None, None]

        def body(zl):
            d = jax.lax.axis_index(axis)
            md = jax.lax.axis_index(data_axis) if bspec else jnp.int32(0)
            # checksum grids summed over the REAL rows: [0, bl) data |
            # [bl, bl+gl) cs2 | [bl+gl, bl+2gl) cs3
            zg = zl.reshape((gl, s, rl, cc))
            cs2_in = jnp.sum(zg, axis=1)
            cs3_in = jnp.sum(ids * zg, axis=1)
            zc = jnp.concatenate([zl, cs2_in, cs3_in], axis=0)
            # ---- pass 1: packed half-length FFT over C + left checksum ----
            zpk = (zc[..., 0::2] + 1j * zc[..., 1::2]).astype(ctype)
            zf = _local_fft(zpk, False)
            res1 = jnp.abs(jnp.sum(zf, axis=-1) - half * zpk[..., 0])
            scale1 = jnp.sqrt(jnp.mean(jnp.abs(zpk) ** 2, axis=-1)) + EPS
            delta = jnp.max(res1 / (float(np.sqrt(half)) * scale1))
            hc = _unpack_half(zf, cc)                # (bl+2gl, rl, C/2+1)
            hc = jnp.pad(hc, ((0, 0), (0, 0), (0, shards - 1)))
            # ---- fault injection (tests/benchmarks): one SEU per row
            # [fft_device, signal, local_r, col, enable, eps_re, eps_im]
            # on the pass-1 HALF-SPECTRUM output (post-unpack): ``col``
            # addresses the padded half spectrum [0, Cp) — live bins are
            # [0, C/2+1) — and the checksum-row location encoding is the
            # C2C layout's, at the folded width. -------------------------
            dev = inject[:, 0].astype(jnp.int32)
            sig = inject[:, 1].astype(jnp.int32)
            row = inject[:, 2].astype(jnp.int32)
            col = inject[:, 3].astype(jnp.int32)
            eps = (inject[:, 5] + 1j * inject[:, 6]).astype(hc.dtype)
            is_data = sig < b
            is_cs2 = (sig >= b) & (sig < b + g)
            gidx = jnp.where(is_cs2, sig - b, sig - b - g)
            owner = jnp.where(is_data, sig // bl, gidx // gl)
            lrow = jnp.where(
                is_data, sig - owner * bl,
                bl + jnp.where(is_cs2, 0, gl) + gidx - owner * gl)
            amp = inject[:, 4] * ((owner == md) & (d == dev)).astype(ftype)
            onehot = (
                (jnp.arange(bl + 2 * gl)[None] == lrow[:, None])
                [:, :, None, None]
                * (jnp.arange(rl)[None] == row[:, None])[:, None, :, None]
                * (jnp.arange(cp)[None] == col[:, None])[:, None, None, :])
            hc = hc + jnp.sum((eps * amp.astype(hc.real.dtype))
                              [:, None, None, None]
                              * onehot.astype(hc.real.dtype), axis=0)
            # ---- the one collective: the inter-axis transpose -------------
            hc = jax.lax.all_to_all(hc, axis, split_axis=2, concat_axis=1,
                                    tiled=True)      # (bl+2gl, R, Cp/D)
            # ---- pass 2: FFT over R (resident) + left checksum ------------
            zt = jnp.swapaxes(hc, -1, -2)
            zf2 = _local_fft(zt, False)
            res2 = jnp.abs(jnp.sum(zf2, axis=-1) - rr * zt[..., 0])
            scale2 = jnp.sqrt(jnp.mean(jnp.abs(zt) ** 2, axis=-1)) + EPS
            delta = jnp.maximum(
                delta, jnp.max(res2 / (float(np.sqrt(rr)) * scale2)))
            zf2 = jnp.swapaxes(zf2, -1, -2)          # (bl+2gl, R, Cp/D)
            # ---- detect / locate per group --------------------------------
            yl = zf2[:bl]
            fcs2, fcs3 = zf2[bl:bl + gl], zf2[bl + gl:]
            ylg = yl.reshape((gl, s) + yl.shape[1:])
            cs2_out = jnp.sum(ylg, axis=1)
            cs3_out = jnp.sum(ids * ylg, axis=1)
            d2 = fcs2 - cs2_out                      # == -eps_y, sharded
            d3 = fcs3 - cs3_out                      # == -id_s * eps_y
            ylg, stats = _grouped_verdict(
                ylg, d2, d3, cs2_out, axis=axis, threshold=threshold, s=s,
                n=rc, md=md, bl=bl, gl=gl, correct=correct)
            yl = ylg.reshape((bl,) + yl.shape[1:])
            return yl, delta[None, None], stats[None]

        yl, deltas, stats = shard_map(
            body, mesh=mesh,
            in_specs=P(bspec, axis, None),
            out_specs=(P(bspec, None, axis), P(bspec, axis),
                       P(axis, bspec, None)),
            check_rep=False)(x)
        st = stats[0]                # (G, 5); fft shards agree post-psum
        flagged = st[:, 1] > 0.5
        correctable = st[:, 3] > 0.5
        return DistFFTResult(
            y=yl, shard_delta=deltas.reshape((-1,)), group_score=st[:, 0],
            flagged=flagged, location=st[:, 2].astype(jnp.int32),
            correctable=correctable, checksum_fault=st[:, 4] > 0.5,
            corrected=jnp.sum(correctable.astype(jnp.int32)) * int(correct),
            recomputed=jnp.zeros((), jnp.int32))

    return run


def ft_distributed_rfft2(
    x: jax.Array,
    mesh: Mesh | None = None,
    *,
    axis: str = FFT_AXIS,
    threshold: float = 1e-4,
    correct: bool = True,
    inject: jax.Array | None = None,
    groups: int | None = None,
    group_size: int | None = None,
    data_axis: str | None = _AUTO,
    recompute_uncorrectable: bool = False,
) -> DistFFTResult:
    """Fault-tolerant real slab 2-D forward FFT (grouped two-side ABFT on
    the Hermitian half-spectrum layout).

    :func:`ft_distributed_fft2` for REAL input grids: the checksum grids
    are real input-row sums that fold through the packing trick alongside
    the data (every pipeline map is R-linear, so the two-side decode is
    exact on the padded half spectrum), ride the transpose at the packed
    half width, and the verdict psum is unchanged. ``res.y`` carries the
    C/2+1 live half-spectrum bins. ``inject`` rows are ``[fft_device,
    signal, local_r, col, enable, eps_re, eps_im]`` — an SEU on the pass-1
    half-spectrum output, ``col`` in the padded columns ``[0, C/2 + D)``
    (live bins ``[0, C/2+1)``); ``signal`` in ``[B, B+G)`` / ``[B+G,
    B+2G)`` targets a group's cs2 / cs3 checksum grid, as in the C2C
    layout.
    """
    x = jnp.asarray(x)
    if jnp.issubdtype(x.dtype, jnp.complexfloating):
        raise ValueError(
            f"ft_distributed_rfft2 takes a real input, got {x.dtype} — "
            f"use ft_distributed_fft2 for complex grids")
    if x.ndim != 3:
        raise ValueError(
            f"ft_distributed_rfft2 expects (B, R, C), got {x.shape}")
    mesh = _resolve_mesh(mesh, axis)
    if mesh is None:
        raise ValueError("ft_distributed_rfft2 requires a mesh with an "
                         f"'{axis}' axis (see launch.mesh.make_fft_mesh)")
    tshape = tuple(int(s) for s in x.shape[1:])
    if not rslab_feasible(tshape, mesh.shape[axis]):
        raise ValueError(
            f"the real ft pipeline rides the slab transpose: needs a "
            f"power-of-two grid with {mesh.shape[axis]} | {tshape[0]} and "
            f"{mesh.shape[axis]} | {tshape[-1]}//2, got {tshape}")
    daxis = _resolve_data_axis(mesh, data_axis)
    dsize = mesh.shape[daxis] if daxis else 1
    g = resolve_abft_groups(x.shape[0], groups=groups, group_size=group_size,
                            data_shards=dsize)
    ftype = jnp.float64 if x.dtype == jnp.float64 else jnp.float32
    x = x.astype(ftype)
    if inject is None:
        inject = jnp.zeros((1, 7), ftype)
    inject = jnp.asarray(inject, ftype)
    if inject.ndim == 1:
        inject = inject[None]
    res = _ft_rslab_fft2_fn(mesh, axis, float(threshold), bool(correct),
                            g, daxis)(x, inject)
    res = dataclasses.replace(res, y=res.y[..., :tshape[-1] // 2 + 1])
    if recompute_uncorrectable:
        res = _splice_recomputed(
            x, res, g,
            lambda rows: distributed_rfft2(rows, mesh, axis=axis,
                                           data_axis=None),
            "ft_distributed_rfft2")
    return res


# ---------------------------------------------------------------------------
# 2-D spectral consumer: convolution via the slab round trip
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _conv2_pair_fn(mesh: Mesh, axis: str, data_axis: str | None):
    """forward(a, v) -> pointwise product -> inverse in ONE shard_map body.

    The 2-D analogue of ``spectral._spectral_pair_fn``: the kernel's
    forward transform shares the batch with the signals', the pointwise
    product happens in the slab's free natural order, and the inverse
    mirrors the forward's dataflow — so the whole round trip is exactly
    TWO all-to-alls and ZERO all-gathers (the restore the 1-D transposed
    pipeline must *skip*, slab never pays at all).
    """
    dsize = mesh.shape[data_axis] if data_axis else 1

    @jax.jit
    def run(a, v):  # a: (B, R, C), v: (BK, R, C) complex, BK in {1, B}
        b = a.shape[0]
        bk = v.shape[0]
        rc = a.shape[1] * a.shape[2]
        bspec = data_axis if (data_axis and b % dsize == 0) else None
        vspec = bspec if bk == b else None

        def body(al, vl):
            ba = al.shape[0]
            # ---- forward, both operands stacked: ONE all-to-all ----------
            zc = jnp.concatenate([al, vl], axis=0)   # (BA+BK, R/D, C)
            zc = _local_axis_fft(zc, 2, inverse=False)
            zc = jax.lax.all_to_all(zc, axis, split_axis=2, concat_axis=1,
                                    tiled=True)      # (BA+BK, R, C/D)
            zc = _local_axis_fft(zc, 1, inverse=False)
            # ---- pointwise in the slab's resident layout -----------------
            prod = zc[:ba] * zc[ba:]                 # BK==1 broadcasts
            # ---- inverse: mirrored dataflow, ONE all-to-all --------------
            prod = _local_axis_fft(prod, 1, inverse=True)
            prod = jax.lax.all_to_all(prod, axis, split_axis=1,
                                      concat_axis=2, tiled=True)
            prod = _local_axis_fft(prod, 2, inverse=True)
            return prod / rc                         # (BA, R/D, C)

        return shard_map(
            body, mesh=mesh,
            in_specs=(P(bspec, axis, None), P(vspec, axis, None)),
            out_specs=P(bspec, axis, None),
            check_rep=False)(a, v)

    return run


@functools.lru_cache(maxsize=None)
def _rconv2_pair_fn(mesh: Mesh, axis: str, data_axis: str | None):
    """Real-input :func:`_conv2_pair_fn`: both operands run the packed
    half-spectrum forward stacked on the batch, the pointwise product
    lives on the C/2+1 surviving bins (natural order — the Hermitian
    logic stays inside the forward/inverse passes), and the mirrored
    inverse brings back the real grid. Still exactly TWO all-to-alls and
    ZERO all-gathers, at the padded half width ``Cp = C/2 + D`` — roughly
    half the bytes of the complex round trip on the same grid."""
    shards = mesh.shape[axis]
    dsize = mesh.shape[data_axis] if data_axis else 1

    @jax.jit
    def run(a, v):  # a: (B, R, C), v: (BK, R, C) real, BK in {1, B}
        b = a.shape[0]
        bk = v.shape[0]
        rr = a.shape[1]
        bspec = data_axis if (data_axis and b % dsize == 0) else None
        vspec = bspec if bk == b else None

        def body(al, vl):
            ba = al.shape[0]
            half = al.shape[-1] // 2
            # ---- forward, both operands stacked: ONE all-to-all ----------
            zc = jnp.concatenate([al, vl], axis=0)   # (BA+BK, R/D, C) real
            hc = _rfft_cols(zc)                      # (BA+BK, R/D, C/2+1)
            hc = jnp.pad(hc, ((0, 0), (0, 0), (0, shards - 1)))
            hc = jax.lax.all_to_all(hc, axis, split_axis=2, concat_axis=1,
                                    tiled=True)      # (BA+BK, R, Cp/D)
            hc = _local_axis_fft(hc, 1, inverse=False)
            # ---- pointwise on the half spectrum --------------------------
            prod = hc[:ba] * hc[ba:]                 # BK==1 broadcasts
            # ---- inverse: mirrored dataflow, ONE all-to-all --------------
            prod = _local_axis_fft(prod, 1, inverse=True) / rr
            prod = jax.lax.all_to_all(prod, axis, split_axis=1,
                                      concat_axis=2, tiled=True)
            return _irfft_cols(prod[..., :half + 1])  # (BA, R/D, C) real

        return shard_map(
            body, mesh=mesh,
            in_specs=(P(bspec, axis, None), P(vspec, axis, None)),
            out_specs=P(bspec, axis, None),
            check_rep=False)(a, v)

    return run


def _crop2(full, sa: tuple[int, int], sv: tuple[int, int], mode: str):
    """numpy convolve mode cropping applied per transform axis."""
    from .spectral import _crop  # per-axis 1-D crop

    out = _crop(full, sa[1], sv[1], mode)
    out = jnp.swapaxes(out, -1, -2)
    out = _crop(out, sa[0], sv[0], mode)
    return jnp.swapaxes(out, -1, -2)


def fft_convolve2(a, v, mesh: Mesh | None = None, *, mode: str = "full",
                  axis: str = FFT_AXIS,
                  data_axis: str | None = _AUTO) -> jax.Array:
    """2-D linear convolution over the last two axes via the slab round
    trip — ``jnp.convolve`` mode semantics (full/same/valid) applied per
    axis, batched over leading dims.

    ``v`` is one kernel ``(Kr, Kc)`` shared by the whole batch or a
    per-signal batch matching ``a``'s leading dims; real inputs give a
    real result. On a mesh the fused pipeline is exactly two all-to-alls
    and zero all-gathers (kernel spectra ride the forward transpose
    stacked on the batch; the product comes back through the mirrored
    inverse) — modeled by :func:`collective_volume_nd` and asserted
    against the HLO in ``benchmarks/fft_distributed.py``. When BOTH
    operands are real the round trip runs the packed half-spectrum
    pipeline (:func:`_rconv2_pair_fn` — same two all-to-alls at roughly
    half the bytes, ``collective_volume_nd(real=True)``) whenever the
    padded grid is :func:`rslab_feasible`. Without a mesh it runs the
    local transforms.
    """
    from .spectral import _next_pow2, _pad_tail, _result_dtypes

    a = jnp.asarray(a)
    v = jnp.asarray(v)
    if a.ndim < 2 or v.ndim < 2:
        raise ValueError("fft_convolve2 needs 2-D operands")
    cdtype, real = _result_dtypes(a, v)
    rdtype = jnp.float64 if cdtype == jnp.dtype(jnp.complex128) \
        else jnp.float32
    # real operands stay real: the packing trick does the complex lift
    a = a.astype(rdtype if real else cdtype)
    v = v.astype(rdtype if real else cdtype)
    sa = (a.shape[-2], a.shape[-1])
    sv = (v.shape[-2], v.shape[-1])
    mesh = _resolve_mesh(mesh, axis)
    shards = mesh.shape[axis] if mesh is not None else 1
    # pad each axis to a power of two >= the linear size (and >= the shard
    # count, the slab divisibility floor)
    nr = max(_next_pow2(sa[0] + sv[0] - 1), shards)
    nc = max(_next_pow2(sa[1] + sv[1] - 1), shards)
    ap = _pad_tail(jnp.swapaxes(_pad_tail(a, nc), -1, -2), nr)
    ap = jnp.swapaxes(ap, -1, -2)
    vp = _pad_tail(jnp.swapaxes(_pad_tail(v, nc), -1, -2), nr)
    vp = jnp.swapaxes(vp, -1, -2)
    if mesh is None or shards == 1:
        if real and nc % 2 == 0:
            fa = _local_axis_fft(_rfft_cols(ap), -2, inverse=False)
            fv = _local_axis_fft(_rfft_cols(vp), -2, inverse=False)
            full = _irfft_cols(
                _local_axis_fft(fa * fv, -2, inverse=True) / nr)
        else:
            full = _local_fftn(
                _local_fftn(ap.astype(cdtype), 2, inverse=False)
                * _local_fftn(vp.astype(cdtype), 2, inverse=False),
                2, inverse=True)
    else:
        daxis = _resolve_data_axis(mesh, data_axis)
        lead = ap.shape[:-2]
        a3 = ap.reshape((-1, nr, nc))
        v3 = vp.reshape((-1, nr, nc))
        if v3.shape[0] not in (1, a3.shape[0]):
            raise ValueError(
                f"kernel batch must be 1 or match the signal batch "
                f"({a3.shape[0]}), got {v3.shape[0]}")
        if real and rslab_feasible((nr, nc), shards):
            full = _rconv2_pair_fn(mesh, axis, daxis)(a3, v3)
        else:
            full = _conv2_pair_fn(mesh, axis, daxis)(
                a3.astype(cdtype), v3.astype(cdtype))
        full = full.reshape(lead + (nr, nc))
    out = _crop2(full[..., :sa[0] + sv[0] - 1, :sa[1] + sv[1] - 1],
                 sa, sv, mode)
    return out.real if real else out
