"""cuFFT-style plan/execute API: one :class:`FFTSpec` -> a cached
:class:`FFTPlan` executor for the whole FFT stack.

The paper's core engineering idea is template-based codegen: every kernel
decision is captured once in a small parameter set and reused — which is
also how its baseline exposes FFTs (``cufftPlanMany`` -> ``cufftExec*``).
This module is the mesh-level analogue. An :class:`FFTSpec` is a frozen,
hashable description of a transform (shape, dtype, rank, mesh, decomposition,
digit order, fault-tolerance config); :func:`plan` resolves everything ONCE —
mesh axes, :func:`~repro.core.fft.multidim.choose_decomp`,
:func:`~repro.core.fft.distributed.resolve_abft_groups`, the local
:class:`~repro.core.fft.plan.Plan`, the resident PartitionSpecs — and hands
back an :class:`FFTPlan` whose executors (``plan.fft / ifft / ft_fft /
convolve / correlate / power_spectrum``) are bound to the already-built
jitted shard_map pipelines, so repeated serve traffic never re-resolves or
retraces.

Every public entry point of the stack (``kernels.ops``, ``core.fft
.extensions``, ``core.fft.spectral``, ``launch.serve``) funnels through
here: they build (or look up, via the LRU plan cache) a spec and invoke the
plan executor, so there is exactly one dispatch path from a user call to a
shard_map pipeline. The legacy per-call kwarg piles on those entry points
remain as compat shims that emit a one-shot
:class:`FFTKwargDeprecationWarning`.
"""
from __future__ import annotations

import dataclasses
import functools
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import plan as planbase
from repro.core.plan import FTConfig

from . import multidim
from .distributed import (_AUTO, FFT_AXIS, _resolve_data_axis, _resolve_mesh,
                          choose_chunks, collective_volume, distributed_fft,
                          ft_distributed_fft, make_dist_plan,
                          resolve_abft_groups, resolve_chunks)

__all__ = ["FFTSpec", "FTConfig", "FFTPlan", "plan", "spec_for",
           "plan_cache_info", "plan_cache_clear", "plan_cache_keys",
           "FFTKwargDeprecationWarning", "reset_deprecation_warnings"]

_COMPLEX_DTYPES = ("complex64", "complex128")


class FFTKwargDeprecationWarning(DeprecationWarning):
    """The legacy per-call kwarg pile (``mesh=``, ``natural_order=``,
    ``decomp=``, ``groups=``, ...) on ``kernels.ops`` entry points is
    deprecated in favor of ``plan(FFTSpec(...))`` executors."""


_warned_entries: set[tuple] = set()


def reset_deprecation_warnings() -> None:
    """Clear the one-shot :class:`FFTKwargDeprecationWarning` state so the
    next legacy-kwarg call warns again. For test isolation (pair with
    ``warnings.catch_warnings``): the one-shot set is process-global, so
    without a reset only the first test touching an entry point ever sees
    the warning."""
    _warned_entries.clear()


def warn_deprecated_kwargs(entry: str, names) -> None:
    """One-shot deprecation warning for a legacy kwarg path, keyed by entry
    point AND call site (the frame ``stacklevel=3`` attributes the warning
    to) — two different legacy callers each get their own warning, repeat
    calls from the same line stay silent."""
    try:
        fr = sys._getframe(2)
        key = (entry, fr.f_code.co_filename, fr.f_lineno)
    except ValueError:                 # shallow stack (exotic embedding)
        key = (entry,)
    if key in _warned_entries:
        return
    _warned_entries.add(key)
    warnings.warn(
        f"{entry}({', '.join(sorted(names))}=...) is deprecated: build an "
        f"FFTSpec once and call plan(spec).{entry.rsplit('.', 1)[-1]}(x) "
        f"(see repro.core.fft.api) — the plan resolves mesh/decomp/ABFT "
        f"layout once and caches the jitted executor",
        FFTKwargDeprecationWarning, stacklevel=3)


# FTConfig now lives in the op-agnostic plan layer (repro.core.plan): the
# same config object describes the checked variant of any plan family —
# this FFT instantiation and the GEMM plans in repro.core.gemm. Re-exported
# here (and from repro.core.fft) for compatibility.


@dataclasses.dataclass(frozen=True)
class FFTSpec:
    """Frozen, hashable description of one batched FFT workload.

    ``shape`` is the full operand shape — leading batch dims plus the last
    ``rank`` transform axes. ``dtype`` must be a complex dtype (executors
    coerce real inputs). ``mesh`` (with an ``axis`` mesh axis) selects the
    distributed pipelines; ``decomp`` picks slab/pencil for ``rank >= 2``
    (``"auto"`` = the :func:`~repro.core.fft.multidim.choose_decomp`
    communication-model heuristic, resolved once at plan build).
    ``natural_order=False`` is the FFTW-MPI transposed pairing (see
    ``core.fft.distributed``). ``ft`` attaches an :class:`FTConfig`;
    ``interpret`` routes local power-of-two paths through the Pallas block
    kernel. Specs are value objects: equal specs hash equal and hit the
    same cached :class:`FFTPlan`.

    ``chunks`` is the multi-transaction pipelining knob: split the batch
    (1-D / spectral; ABFT plans split whole checksum groups) or the
    pencil cube into that many transactions so transaction i's
    all-to-alls overlap transaction i+1's local Stockham passes. ``1`` =
    bulk-synchronous (the default), ``0`` = auto — an ft plan reuses
    ``FTConfig.transactions``, otherwise :func:`~repro.core.fft
    .distributed.choose_chunks` picks from the modeled all-to-all bytes.
    The plan resolves the effective count once (clamped so every
    transaction stays shard- and group-divisible; slab/local/real paths
    are bulk-synchronous and resolve to 1); results are bitwise-identical
    for every chunk count.

    ``real=True`` declares the OPERAND real-valued: ``shape`` stays the
    full real shape, ``dtype`` is the complex precision the half spectrum
    carries (``complex64``/``complex128``), and the plan binds the
    ``rfft/irfft`` (rank 1) or ``rfft2/irfft2`` (rank 2) executors — the
    packed half-length transforms that move about half the C2C path's
    collective bytes. Real plans are natural-order only (the Hermitian
    unpack indexes bins by ``k``) and their ft pipeline is the rank-2 slab
    (the 1-D real path has none).
    """

    shape: tuple[int, ...]
    dtype: str = "complex64"
    rank: int = 1
    mesh: Mesh | None = None
    axis: str = FFT_AXIS
    data_axis: str | None = _AUTO
    decomp: str = "auto"
    natural_order: bool = True
    ft: FTConfig | None = None
    interpret: bool | None = None
    real: bool = False
    chunks: int = 1

    def __post_init__(self):
        shape = tuple(int(s) for s in self.shape)
        if not shape or any(s <= 0 for s in shape):
            raise ValueError(f"FFTSpec.shape must be a non-empty tuple of "
                             f"positive sizes, got {self.shape!r}")
        object.__setattr__(self, "shape", shape)
        dt = jnp.dtype(self.dtype).name
        if dt not in _COMPLEX_DTYPES:
            raise ValueError(
                f"FFTSpec.dtype must be one of {_COMPLEX_DTYPES} (executors "
                f"coerce real inputs), got {self.dtype!r}")
        object.__setattr__(self, "dtype", dt)
        if self.rank not in (1, 2, 3):
            raise ValueError(f"FFTSpec.rank must be 1, 2, or 3, "
                             f"got {self.rank!r}")
        if len(shape) < self.rank:
            raise ValueError(f"FFTSpec.shape {shape} has fewer axes than "
                             f"rank={self.rank}")
        if self.mesh is not None and self.axis not in self.mesh.axis_names:
            raise ValueError(
                f"FFTSpec.axis {self.axis!r} is not an axis of the mesh "
                f"{tuple(self.mesh.axis_names)} — build the mesh with "
                f"launch.mesh.make_fft_mesh or pass the right axis name")
        if self.data_axis not in (None, _AUTO) and self.mesh is not None \
                and self.data_axis not in self.mesh.axis_names:
            raise ValueError(
                f"FFTSpec.data_axis {self.data_axis!r} is not an axis of "
                f"the mesh {tuple(self.mesh.axis_names)}")
        if self.rank == 1:
            if self.decomp != "auto":
                raise ValueError(
                    f"FFTSpec.decomp is a multi-dimensional knob (rank >= "
                    f"2); rank-1 transforms are always the pencil digit "
                    f"split — got decomp={self.decomp!r}")
        elif self.decomp not in ("auto", "slab", "pencil", "local"):
            raise ValueError(f"FFTSpec.decomp must be auto|slab|pencil|"
                             f"local, got {self.decomp!r}")
        if self.ft is not None and not isinstance(self.ft, FTConfig):
            raise ValueError(f"FFTSpec.ft must be an FTConfig, "
                             f"got {type(self.ft).__name__}")
        if not isinstance(self.chunks, int) or isinstance(self.chunks, bool) \
                or self.chunks < 0:
            raise ValueError(
                f"FFTSpec.chunks must be a non-negative int (0 = auto, 1 = "
                f"bulk-synchronous, k = k transactions), got "
                f"{self.chunks!r}")
        if self.real:
            if self.rank == 3:
                raise ValueError(
                    "real plans are rank 1 (rfft) or rank 2 (rfft2); rank=3 "
                    "has no real pipeline yet")
            if not self.natural_order:
                raise ValueError(
                    "real plans are natural-order only — the Hermitian "
                    "unpack indexes half-spectrum bins by k, which the "
                    "transposed digit pairing scrambles")
            if self.ft is not None and self.rank != 2:
                raise ValueError(
                    "the 1-D real path has no ft pipeline — fault-tolerant "
                    "real transforms are the rank-2 slab (rfft2 with "
                    "FFTSpec(rank=2, real=True, ft=...))")

    # -- convenience ------------------------------------------------------

    @property
    def np_dtype(self) -> np.dtype:
        return np.dtype(self.dtype)

    @property
    def tshape(self) -> tuple[int, ...]:
        """The transform axes (last ``rank`` entries of ``shape``)."""
        return self.shape[-self.rank:]

    @property
    def batch(self) -> int:
        """Total signals: product of the leading (batch) dims."""
        return int(np.prod(self.shape[:-self.rank], dtype=np.int64)) \
            if len(self.shape) > self.rank else 1


def spec_for(x, *, rank: int = 1, mesh: Mesh | None = None,
             axis: str = FFT_AXIS, data_axis: str | None = _AUTO,
             decomp: str = "auto", natural_order: bool = True,
             ft: FTConfig | None = None,
             interpret: bool | None = None, real: bool = False,
             chunks: int = 1) -> FFTSpec:
    """Build the :class:`FFTSpec` describing ``x``'s transform.

    With ``mesh=None`` the mesh is inferred from ``x``'s committed sharding
    (the legacy auto-dispatch contract of ``kernels.ops``): an operand
    already laid out over an ``axis`` mesh plans distributed. On a C2C spec
    real dtypes map to ``complex64`` — exactly the coercion the legacy
    entry points applied; on a *real* spec (``real=True``) the operand's
    precision is KEPT: ``float64`` signals plan a ``complex128`` half
    spectrum.
    """
    x = jnp.asarray(x)
    if mesh is None:
        from repro.parallel.fft_sharding import infer_fft_mesh
        mesh = infer_fft_mesh(x, axis)
    dt = x.dtype
    if not jnp.issubdtype(dt, jnp.complexfloating):
        dt = jnp.dtype(jnp.complex128 if (real and dt == jnp.float64)
                       else jnp.complex64)
    return FFTSpec(shape=tuple(x.shape), dtype=jnp.dtype(dt).name, rank=rank,
                   mesh=mesh, axis=axis, data_axis=data_axis, decomp=decomp,
                   natural_order=natural_order, ft=ft, interpret=interpret,
                   real=real, chunks=chunks)


def _feasible_1d(n: int, shards: int) -> bool:
    """Whether an n-point transform can pencil-split over ``shards``."""
    return (n > 0 and not (n & (n - 1)) and shards > 0
            and not (shards & (shards - 1)) and n >= shards * shards)


@planbase.register_plan_type(FFTSpec)
class FFTPlan(planbase.Plan):
    """Pre-resolved executor bundle for one :class:`FFTSpec` — the FFT
    instantiation of the op-agnostic plan layer (:mod:`repro.core.plan`).

    The constructor does every per-call resolution the legacy kwarg paths
    repeated — mesh/axis validation, decomposition choice, ABFT group
    layout, local plan, PartitionSpecs, the analytic collective-volume
    model — and binds the executors to the cached jitted shard_map
    pipelines underneath, so ``plan.fft(x)`` is a straight dispatch.
    Construct via :func:`plan` (LRU-cached on the spec), not directly.
    """

    def __init__(self, spec: FFTSpec):
        super().__init__(spec)
        self.rank = spec.rank
        self.tshape = spec.tshape
        self.batch = spec.batch
        self.n = int(np.prod(self.tshape, dtype=np.int64))
        mesh = _resolve_mesh(spec.mesh, spec.axis)
        self.sharded = mesh is not None and mesh.shape[spec.axis] > 1
        if spec.decomp == "local":
            # an explicit local ask is honored even on a sharded mesh (the
            # legacy distributed_fftn contract) — the plan is fully local
            self.sharded = False
        self.mesh = mesh if self.sharded else None
        self.shards = mesh.shape[spec.axis] if self.sharded else 1
        self.daxis = (_resolve_data_axis(mesh, spec.data_axis)
                      if self.sharded else None)
        self.dsize = mesh.shape[self.daxis] if self.daxis else 1
        ft = spec.ft
        self.groups = None
        if ft is not None:
            if self.rank == 3:
                raise ValueError("fault-tolerant transforms are 1-D and "
                                 "2-D (slab) only; rank=3 has no ft "
                                 "pipeline yet")
            if self.sharded:
                # groups are a mesh-path knob; on the local fused-kernel
                # path they are documented no-ops (transactions grouping
                # applies instead), so they are not resolved or validated
                self.groups = resolve_abft_groups(
                    self.batch, groups=ft.groups, group_size=ft.group_size,
                    data_shards=self.dsize)
        self._rdtype = jnp.dtype(
            jnp.float64 if spec.dtype == "complex128" else jnp.float32)
        # effective transaction count; the chunked builders re-resolve this
        # (slab / local / real paths stay bulk-synchronous)
        self.chunks = 1
        if self.rank == 1:
            self._build_1d()
        else:
            self._build_nd()

    # -- construction -----------------------------------------------------

    def _build_1d(self):
        from repro.kernels import ops as _ops  # lazy: ops imports this module
        from repro.parallel.fft_sharding import layout_specs

        spec = self.spec
        n = self.tshape[0]
        if spec.real:
            self._build_1d_real(n)
            return
        if not self.sharded:
            self.decomp = "local"
            self.dist_plan = None
            self.in_spec = self.out_spec = None
            self._fwd = functools.partial(_ops._fft_impl, inverse=False,
                                          interpret=spec.interpret)
            self._inv = functools.partial(_ops._fft_impl, inverse=True,
                                          interpret=spec.interpret)
            self.volume = None
            return
        self.decomp = "pencil"
        # raises with the exact constraint (pow2, N >= shards^2) when the
        # split is infeasible — the spec-validation contract of plan()
        self.dist_plan = make_dist_plan(n, self.shards, spec.axis)
        self.in_spec, self.out_spec = layout_specs(
            1, "pencil", axis=spec.axis, data_axis=self.daxis)
        ft = spec.ft
        base = collective_volume(
            n, max(self.batch, 1), self.shards,
            itemsize=self.spec.np_dtype.itemsize,
            ft=ft is not None, natural_order=spec.natural_order,
            groups=self.groups or 1, data_shards=self._model_dsize())
        # transactions split local batch rows (whole checksum groups on an
        # ft plan): resolve spec.chunks once against the per-device count
        rows = ((self.groups if ft is not None else max(self.batch, 1))
                // max(self._model_dsize(), 1))
        requested = spec.chunks
        if requested == 0:          # auto: ft reuses FTConfig.transactions
            requested = (ft.transactions if ft is not None
                         else choose_chunks(base["all_to_all_bytes"], rows))
        self.chunks = resolve_chunks(rows, max(1, requested)) if rows else 1
        from .distributed import _dist_fft_fn, _dist_ifft_t_fn
        self._fwd = _dist_fft_fn(self.mesh, spec.axis, False,
                                 spec.natural_order, self.daxis, self.chunks)
        if spec.natural_order:
            self._inv = _dist_fft_fn(self.mesh, spec.axis, True, True,
                                     self.daxis, self.chunks)
        else:
            _dist_ifft_t_fn(self.mesh, spec.axis, self.daxis,
                            self.chunks)                       # pre-build
            self._inv = functools.partial(
                distributed_fft, mesh=self.mesh, axis=spec.axis,
                inverse=True, natural_order=False, data_axis=self.daxis,
                chunks=self.chunks)
        if ft is not None:
            from .distributed import _ft_dist_fft_fn
            _ft_dist_fft_fn(self.mesh, spec.axis, float(ft.threshold),
                            bool(ft.correct), bool(spec.natural_order),
                            self.groups, self.daxis,
                            self.chunks)  # pre-build/trace cache
        self.volume = collective_volume(
            n, max(self.batch, 1), self.shards,
            itemsize=self.spec.np_dtype.itemsize,
            ft=ft is not None, natural_order=spec.natural_order,
            groups=self.groups or 1, data_shards=self._model_dsize(),
            chunks=self.chunks)

    def _build_1d_real(self, n: int):
        """Bind the rank-1 real executors (rfft/irfft).

        The transform itself is ``extensions.rfft``'s packed half-length
        C2C; this plan resolves once whether that half-length transform can
        pencil-split over the mesh, and models its collective volume
        (``collective_volume(real=True)`` — half the C2C bytes).
        """
        from repro.parallel.fft_sharding import layout_specs

        spec = self.spec
        self._fwd = self._inv = None          # C2C executors raise on real
        self.dist_plan = None
        self.in_spec = self.out_spec = None
        self.volume = None
        self.decomp = "local"
        if self.sharded and n % 2 == 0 \
                and _feasible_1d(n // 2, self.shards):
            self.decomp = "pencil"
            self.dist_plan = make_dist_plan(n // 2, self.shards, spec.axis)
            self.in_spec, self.out_spec = layout_specs(
                1, "pencil", axis=spec.axis, data_axis=self.daxis)
            self.volume = collective_volume(
                n, max(self.batch, 1), self.shards,
                itemsize=self.spec.np_dtype.itemsize,
                natural_order=True, data_shards=self._model_dsize(),
                real=True)
            # rfft/irfft themselves are bulk-synchronous; the chunk knob
            # feeds the spectral consumer (convolve/correlate round trip)
            rows = max(self.batch, 1) // max(self._model_dsize(), 1)
            requested = spec.chunks
            if requested == 0:
                requested = choose_chunks(
                    self.volume["all_to_all_bytes"], rows)
            self.chunks = resolve_chunks(rows, max(1, requested)) \
                if rows else 1

    def _build_nd_real(self):
        """Bind the rank-2 real executors (rfft2/irfft2).

        slab -> the native half-spectrum pipeline (packed row pass, padded
        ``C/2 + D``-column transpose, ~half the C2C bytes — see
        ``multidim.distributed_rfft2``); pencil -> the composed two-pass
        form (1-D distributed rfft over columns, C2C over rows — correct on
        meshes the slab cannot tile); no mesh -> local.
        """
        from repro.parallel.fft_sharding import layout_specs

        spec = self.spec
        ft = spec.ft
        cc = self.tshape[-1]
        if not self.sharded:
            if ft is not None:
                raise ValueError(
                    "fault-tolerant rfft2 runs the sharded grouped ABFT on "
                    "the slab transpose: the spec needs a mesh with an "
                    f"'{spec.axis}' axis of >= 2 devices")
            self.decomp = "local"
            self.in_spec = self.out_spec = None
            self.volume = None
            self._rfwd = multidim._local_rfft2
            self._rinv = functools.partial(multidim._local_irfft2, cc=cc)
            return
        decomp = spec.decomp
        feasible = multidim.rslab_feasible(self.tshape, self.shards)
        if decomp == "auto":
            decomp = (multidim.DECOMP_SLAB if feasible
                      else multidim.DECOMP_PENCIL)
        if ft is not None and decomp != multidim.DECOMP_SLAB:
            raise ValueError(
                "grouped ABFT rides the slab inter-axis transpose: an ft "
                f"real spec needs decomp='slab' (or 'auto'), got {decomp!r}")
        if decomp == multidim.DECOMP_SLAB and not feasible:
            raise ValueError(
                f"infeasible decomp: the real slab needs power-of-two axes "
                f"with {self.shards} | {self.tshape[0]} and "
                f"{self.shards} | {self.tshape[-1]}//2, got {self.tshape} — "
                f"use decomp='pencil' (the composed real path) or a smaller "
                f"fft axis")
        self.decomp = decomp
        if decomp == multidim.DECOMP_SLAB:
            self.in_spec, self.out_spec = layout_specs(
                2, decomp, axis=spec.axis, data_axis=self.daxis, real=True)
            self._rfwd = functools.partial(
                multidim.distributed_rfft2, mesh=self.mesh, axis=spec.axis,
                data_axis=self.daxis)
            self._rinv = functools.partial(
                multidim.distributed_irfft2, mesh=self.mesh, axis=spec.axis,
                data_axis=self.daxis)
            # pre-build the jitted pipelines (first execution stays a
            # straight dispatch, the plan contract)
            multidim._rslab_fft2_fn(self.mesh, spec.axis, self.daxis)
            multidim._rslab_ifft2_fn(self.mesh, spec.axis, self.daxis)
            if ft is not None:
                multidim._ft_rslab_fft2_fn(
                    self.mesh, spec.axis, float(ft.threshold),
                    bool(ft.correct), self.groups, self.daxis)
            self.volume = multidim.collective_volume_nd(
                self.tshape, max(self.batch, 1), self.shards, decomp=decomp,
                itemsize=self.spec.np_dtype.itemsize, ft=ft is not None,
                groups=self.groups or 1, data_shards=self._model_dsize(),
                natural_order=True, real=True)
            return
        # pencil: the composed two-pass real path — its collectives are the
        # 1-D pieces', so there is no single nd volume model to bind
        self.in_spec = self.out_spec = None
        self.volume = None
        self._rfwd = functools.partial(
            multidim._composed_rfft2, mesh=self.mesh, axis=spec.axis,
            data_axis=self.daxis)
        self._rinv = functools.partial(
            multidim._composed_irfft2, cc=cc, mesh=self.mesh, axis=spec.axis,
            data_axis=self.daxis)

    def _build_nd(self):
        from repro.parallel.fft_sharding import layout_specs

        spec = self.spec
        ft = spec.ft
        if spec.real:
            self._fwd = self._inv = None      # C2C executors raise on real
            self._build_nd_real()
            return
        if not self.sharded:
            if ft is not None:
                raise ValueError(
                    "fault-tolerant 2-D transforms run the sharded grouped "
                    "ABFT on the slab transpose: the spec needs a mesh with "
                    f"an '{spec.axis}' axis of >= 2 devices")
            self.decomp = "local"
            self.in_spec = self.out_spec = None
            self.volume = None
            self._fwd = functools.partial(
                multidim._local_fftn, ndim=self.rank, inverse=False,
                interpret=spec.interpret)
            self._inv = functools.partial(
                multidim._local_fftn, ndim=self.rank, inverse=True,
                interpret=spec.interpret)
            return
        decomp = spec.decomp
        if decomp == "auto":
            decomp = multidim.choose_decomp(
                self.tshape, self.mesh, batch=self.batch, ft=ft is not None,
                natural_order=spec.natural_order, axis=spec.axis,
                data_axis=spec.data_axis)
        if ft is not None and decomp != multidim.DECOMP_SLAB:
            raise ValueError(
                "grouped ABFT rides the slab inter-axis transpose: an ft "
                f"spec needs decomp='slab' (or 'auto'), got {decomp!r}")
        if decomp == multidim.DECOMP_SLAB \
                and not multidim.slab_feasible(self.tshape, self.shards):
            raise ValueError(
                f"infeasible decomp: slab needs power-of-two axes with "
                f"{self.shards} | {self.tshape[0]} and "
                f"{self.shards} | {self.tshape[-1]}, got {self.tshape} — "
                f"use decomp='pencil' or a smaller fft axis")
        if decomp == multidim.DECOMP_PENCIL and not multidim.pencil_feasible(
                self.tshape, self.shards, self.dsize):
            raise ValueError(
                f"infeasible decomp: pencil needs "
                f"{self.tshape[-1]} >= fft^2={self.shards ** 2} and "
                f"{self.tshape[-2]} >= data^2={self.dsize ** 2} "
                f"(power-of-two axes), got {self.tshape} — use "
                f"decomp='slab' or a smaller mesh")
        self.decomp = decomp
        if decomp == multidim.DECOMP_PENCIL:
            base = multidim.collective_volume_nd(
                self.tshape, max(self.batch, 1), self.shards, decomp=decomp,
                itemsize=self.spec.np_dtype.itemsize,
                data_shards=self.dsize, natural_order=spec.natural_order)
            requested = spec.chunks
            if requested == 0:
                requested = choose_chunks(base["all_to_all_bytes"],
                                          self._nd_chunk_rows())
            self.chunks = self._effective_nd_chunks(max(1, requested))
        self.in_spec, self.out_spec = layout_specs(
            self.rank, decomp, axis=spec.axis, data_axis=self.daxis)
        self._fwd = functools.partial(
            multidim.distributed_fftn, mesh=self.mesh, ndim=self.rank,
            decomp=decomp, inverse=False, natural_order=spec.natural_order,
            axis=spec.axis, data_axis=self.daxis, interpret=spec.interpret,
            chunks=self.chunks)
        self._inv = functools.partial(
            multidim.distributed_fftn, mesh=self.mesh, ndim=self.rank,
            decomp=decomp, inverse=True, natural_order=spec.natural_order,
            axis=spec.axis, data_axis=self.daxis, interpret=spec.interpret,
            chunks=self.chunks)
        # pre-build the jitted pipelines so first execution never resolves
        if decomp == multidim.DECOMP_SLAB:
            multidim._slab_fftn_fn(self.mesh, spec.axis, self.rank, False,
                                   self.daxis)
            multidim._slab_fftn_fn(self.mesh, spec.axis, self.rank, True,
                                   self.daxis)
        else:
            multidim._pencil_fftn_fn(self.mesh, spec.axis, self.rank, False,
                                     bool(spec.natural_order), self.daxis,
                                     self.chunks)
        if ft is not None:
            multidim._ft_slab_fft2_fn(
                self.mesh, spec.axis, float(ft.threshold), bool(ft.correct),
                self.groups, self.daxis)
        self.volume = multidim.collective_volume_nd(
            self.tshape, max(self.batch, 1), self.shards, decomp=decomp,
            itemsize=self.spec.np_dtype.itemsize, ft=ft is not None,
            groups=self.groups or 1,
            data_shards=(self._model_dsize()
                         if decomp == multidim.DECOMP_SLAB else self.dsize),
            natural_order=spec.natural_order, chunks=self.chunks)

    # -- helpers ----------------------------------------------------------

    def _nd_chunk_rows(self) -> int:
        """The size of the axis pencil transactions would split: the
        (replicated) batch when it has rows, else the first leading local
        transform axis (the rank-3 single-grid case)."""
        for size in (max(self.batch, 1),) + tuple(self.tshape[:-2]):
            if size > 1:
                return size
        return 1

    def _effective_nd_chunks(self, requested: int) -> int:
        """Mirror of the pencil pipeline's chunk-axis selection
        (``multidim._chunk_apply``): the first candidate axis that can
        carry more than one transaction decides the effective count."""
        for size in (max(self.batch, 1),) + tuple(self.tshape[:-2]):
            ce = resolve_chunks(size, requested)
            if ce > 1:
                return ce
        return 1

    def _model_dsize(self) -> int:
        """The data-shard count the pipeline actually uses: the batch (and
        its checksum groups, on an ft plan) must divide over the data axis,
        else the batch replicates and the model must say so too."""
        if self.dsize <= 1 or self.batch % self.dsize:
            return 1
        if self.groups is not None and self.groups % self.dsize:
            return 1
        return self.dsize

    def _coerce(self, x):
        """Match the plan dtype: a C2C plan coerces real inputs to its
        complex dtype (the legacy contract); a real plan REJECTS complex
        operands and casts to its real precision."""
        x = jnp.asarray(x)
        if self.spec.real:
            if jnp.issubdtype(x.dtype, jnp.complexfloating):
                raise ValueError(
                    f"a real plan takes a real operand, got {x.dtype} — "
                    f"build a C2C FFTSpec (real=False) for complex signals")
            return x if x.dtype == self._rdtype else x.astype(self._rdtype)
        if x.dtype != self.spec.np_dtype:
            x = x.astype(self.spec.np_dtype)
        return x

    def _check_tshape(self, x):
        if tuple(x.shape[-self.rank:]) != self.tshape:
            raise ValueError(
                f"operand transform axes {tuple(x.shape[-self.rank:])} do "
                f"not match the planned {self.tshape} — build a new "
                f"FFTSpec (plans are shape-specialized, like cufftPlanMany)")

    def shard(self, x):
        """Place ``x`` into the plan's resident input layout (a no-op
        relayout on an unsharded plan, or when the plan has no resident
        layout — local-fallback / composed real paths)."""
        x = self._coerce(x)
        if not self.sharded or self.in_spec is None:
            return x
        from repro.parallel.fft_sharding import shard_grid, shard_signals
        if self.rank == 1:
            return shard_signals(x, self.mesh, self.spec.axis,
                                 data_axis=self.daxis)
        return shard_grid(x, self.mesh, self.rank, decomp=self.decomp,
                          axis=self.spec.axis, data_axis=self.daxis)

    # -- executors --------------------------------------------------------

    def fft(self, x):
        """Forward transform over the planned axes (complex in/out)."""
        if self.spec.real:
            raise ValueError(
                "this plan is real-input — its executors are rfft/irfft "
                "(rfft2/irfft2); build a C2C FFTSpec (real=False) for "
                "fft/ifft")
        x = self._coerce(x)
        self._check_tshape(x)
        return self._fwd(x)

    def ifft(self, x):
        """Inverse transform (1/N normalized); a transposed-order plan
        consumes the forward's transposed-digit output (TRANSPOSED_IN)."""
        if self.spec.real:
            raise ValueError(
                "this plan is real-input — its executors are rfft/irfft "
                "(rfft2/irfft2); build a C2C FFTSpec (real=False) for "
                "fft/ifft")
        x = self._coerce(x)
        self._check_tshape(x)
        return self._inv(x)

    # rank-2/3 spellings (same executors; the rank lives in the spec)
    def fft2(self, x):
        if self.rank < 2:
            raise ValueError("fft2 needs a rank>=2 FFTSpec")
        return self.fft(x)

    def ifft2(self, x):
        if self.rank < 2:
            raise ValueError("ifft2 needs a rank>=2 FFTSpec")
        return self.ifft(x)

    fftn = fft2
    ifftn = ifft2

    # -- real-input executors ---------------------------------------------

    def rfft(self, x):
        """Real-input forward transform -> the ``(..., N/2+1)``-bin half
        spectrum (rank 1) or ``(..., R, C/2+1)`` (rank 2). Requires a real
        plan (``FFTSpec(real=True)``); complex operands are rejected, not
        silently truncated."""
        if not self.spec.real:
            raise ValueError(
                "this plan is C2C — build the FFTSpec with real=True for "
                "rfft/irfft")
        x = self._coerce(x)
        self._check_tshape(x)
        if self.rank == 1:
            from . import extensions
            return extensions.rfft(
                x, mesh=self.mesh if self.sharded else None,
                axis=self.spec.axis, data_axis=self.daxis)
        return self._rfwd(x)

    def irfft(self, y):
        """Inverse of :meth:`rfft`: half spectrum -> the planned real
        shape. The spectrum's transform axes must be the planned shape's
        Hermitian half (``last axis -> n//2 + 1`` bins)."""
        if not self.spec.real:
            raise ValueError(
                "this plan is C2C — build the FFTSpec with real=True for "
                "rfft/irfft")
        y = jnp.asarray(y)
        want = self.tshape[:-1] + (self.tshape[-1] // 2 + 1,)
        if tuple(y.shape[-self.rank:]) != want:
            raise ValueError(
                f"half-spectrum axes {tuple(y.shape[-self.rank:])} do not "
                f"match the planned {want} (the Hermitian half of "
                f"{self.tshape}) — build a new FFTSpec")
        if y.dtype != self.spec.np_dtype:
            y = y.astype(self.spec.np_dtype)
        if self.rank == 1:
            from . import extensions
            return extensions.irfft(
                y, n=self.tshape[0],
                mesh=self.mesh if self.sharded else None,
                axis=self.spec.axis, data_axis=self.daxis)
        return self._rinv(y)

    # rank-2 spellings (same executors; the rank lives in the spec)
    def rfft2(self, x):
        if self.rank != 2:
            raise ValueError("rfft2 needs a rank-2 FFTSpec")
        return self.rfft(x)

    def irfft2(self, y):
        if self.rank != 2:
            raise ValueError("irfft2 needs a rank-2 FFTSpec")
        return self.irfft(y)

    def ft_fft(self, x, *, inject=None, bs=None):
        """Fault-tolerant forward transform (requires ``spec.ft``).

        On a mesh: the sharded grouped two-side ABFT
        (:class:`~repro.core.fft.distributed.DistFFTResult`, 1-D pencil or
        2-D slab). Locally (rank 1): the fused-kernel pipeline
        (:class:`~repro.kernels.ops.FTFFTResult`); ``bs`` is its per-call
        block-size override.
        """
        ft = self.spec.ft
        if ft is None:
            raise ValueError("this plan has no FTConfig — set FFTSpec.ft")
        x = self._coerce(x)
        self._check_tshape(x)
        b = int(np.prod(x.shape[:-self.rank], dtype=np.int64)) \
            if x.ndim > self.rank else 1
        if b != self.batch:
            raise ValueError(
                f"operand batch {b} does not match the planned {self.batch} "
                f"— the ABFT group layout (G={self.groups}) was resolved "
                f"for the spec's batch; build a new FFTSpec")
        if self.spec.real:
            # rank-2 slab only (spec validation): the grouped two-side
            # ABFT on the Hermitian-symmetric checksum layout
            return multidim.ft_distributed_rfft2(
                x, self.mesh, axis=self.spec.axis, threshold=ft.threshold,
                correct=ft.correct, inject=inject, groups=self.groups,
                data_axis=self.daxis,
                recompute_uncorrectable=ft.recompute_uncorrectable)
        if self.rank == 1 and not self.sharded:
            from repro.kernels import ops as _ops
            res = _ops._ft_fft_local(
                x, transactions=ft.transactions, bs=bs,
                per_signal=ft.per_signal, encoding=ft.encoding,
                threshold=ft.threshold, correct=ft.correct,
                interpret=self.spec.interpret, inject=inject)
            return res
        if self.rank == 1:
            return ft_distributed_fft(
                x, self.mesh, axis=self.spec.axis, threshold=ft.threshold,
                correct=ft.correct, natural_order=self.spec.natural_order,
                inject=inject, groups=self.groups, data_axis=self.daxis,
                recompute_uncorrectable=ft.recompute_uncorrectable,
                chunks=self.chunks)
        return multidim.ft_distributed_fft2(
            x, self.mesh, axis=self.spec.axis, threshold=ft.threshold,
            correct=ft.correct, inject=inject, groups=self.groups,
            data_axis=self.daxis,
            recompute_uncorrectable=ft.recompute_uncorrectable)

    # -- spectral consumers ----------------------------------------------

    def convolve(self, a, v, *, mode: str = "full"):
        """Linear convolution via the planned transform size: 1-D through
        the transposed spectral pipeline, 2-D through the slab round trip.
        The plan's last-axis size(s) must equal the padded FFT size
        (:func:`~repro.core.fft.spectral._conv_nfft` of the operands)."""
        if self.rank == 1:
            return self._spectral_pair(a, v, conj_kernel=False, mode=mode)
        if self.rank == 2:
            return multidim.fft_convolve2(
                a, v, self.mesh, mode=mode, axis=self.spec.axis,
                data_axis=self.daxis)
        raise ValueError("convolve supports rank 1 and 2 plans")

    def correlate(self, a, v, *, mode: str = "full"):
        """Cross-correlation (``np.correlate`` conventions), rank-1 only."""
        if self.rank != 1:
            raise ValueError("correlate is 1-D only")
        return self._spectral_pair(a, v, conj_kernel=True, mode=mode)

    def _spectral_pair(self, a, v, *, conj_kernel: bool, mode: str):
        from . import spectral as spec_mod
        a = jnp.asarray(a)
        v = jnp.asarray(v)
        _, real = spec_mod._result_dtypes(a, v)
        la, lv = a.shape[-1], v.shape[-1]
        nfft = spec_mod._conv_nfft(la, lv, self.mesh, self.spec.axis)
        if nfft != self.tshape[0]:
            raise ValueError(
                f"operand lengths ({la}, {lv}) need an nfft={nfft} plan, "
                f"but this plan is for {self.tshape[0]} — build the spec "
                f"with spectral.conv_spec / fft_convolve")
        out_len = nfft if conj_kernel else la + lv - 1
        full = spec_mod._spectral_pair(
            spec_mod._pad_tail(a, nfft), spec_mod._pad_tail(v, nfft),
            self.mesh, self.spec.axis, self.daxis, conj_kernel=conj_kernel,
            out_len=out_len, chunks=self.chunks)
        if conj_kernel:
            full = jnp.roll(full, lv - 1, axis=-1)[..., :la + lv - 1]
        out = spec_mod._crop(full, la, lv, mode)
        return out.real if real else out

    def power_spectrum(self, x):
        """Periodogram ``|X|^2 / N``; on a transposed-order plan the bins
        stay in the transposed digit order (the cheap choice). A real plan
        returns the one-sided ``N/2+1``-bin spectrum via the packed rfft
        (always natural order)."""
        if self.spec.real:
            return (jnp.abs(self.rfft(x)) ** 2) / self.n
        x = self._coerce(x)
        self._check_tshape(x)
        if self.rank == 1 and not self.sharded:
            from . import stockham
            y = stockham.fft(x)     # the legacy local spectral path
        else:
            y = self._fwd(x)
        return (jnp.abs(y) ** 2) / self.n

    # -- introspection ----------------------------------------------------

    def __repr__(self):
        s = self.spec
        return (f"FFTPlan(shape={s.shape}, dtype={s.dtype}, rank={s.rank}, "
                f"decomp={self.decomp!r}, shards={self.shards}, "
                f"data={self.dsize}, groups={self.groups}, "
                f"chunks={self.chunks}, "
                f"natural_order={s.natural_order}, ft={s.ft is not None})")


def plan(spec: FFTSpec) -> FFTPlan:
    """Build (or fetch from the shared plan-layer LRU cache) the
    :class:`FFTPlan` for ``spec``. Equal specs return the SAME plan object,
    whose executors are bound to already-traced pipelines — the cuFFT
    ``plan once, exec hot`` contract for serve traffic."""
    if not isinstance(spec, FFTSpec):
        raise TypeError(f"plan() takes an FFTSpec, got "
                        f"{type(spec).__name__}")
    return planbase.plan(spec)


# the plan cache is shared across plan families (repro.core.plan); these
# aliases keep the historical FFT-side spelling working
plan_cache_info = planbase.plan_cache_info
plan_cache_clear = planbase.plan_cache_clear
plan_cache_keys = planbase.plan_cache_keys
