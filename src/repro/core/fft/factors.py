"""DFT factor matrices and twiddle tables.

The paper precomputes twiddle factors into tables to avoid in-kernel
trigonometry (critical for FP64 on GPU; on TPU transcendentals are slow in
fp32 and absent for fp64). All tables here are built on host with numpy in
float64 and cast once, so kernel inputs are pure data.
"""
from __future__ import annotations

import functools

import numpy as np

__all__ = [
    "dft_matrix",
    "dft_matrix_ri",
    "stage_twiddle",
    "stage_twiddle_ri",
    "wang_encoding",
    "ones_encoding",
    "location_encoding",
]


@functools.lru_cache(maxsize=None)
def dft_matrix(n: int, *, inverse: bool = False) -> np.ndarray:
    """The (n, n) DFT matrix W with W[j, k] = exp(-2*pi*i*j*k / n).

    Forward sign convention matches ``numpy.fft.fft``. ``inverse=True``
    returns the *unnormalized* inverse kernel exp(+2*pi*i*j*k/n); the 1/n
    normalization is applied by the caller once per full transform.
    """
    sign = 1.0 if inverse else -1.0
    j = np.arange(n)[:, None]
    k = np.arange(n)[None, :]
    # Use exact angle reduction mod n to keep fp64 twiddles accurate for
    # large n (j*k can exceed 2**53 only for n > ~94M, far beyond our sizes).
    ang = sign * 2.0 * np.pi * ((j * k) % n) / n
    return np.cos(ang) + 1j * np.sin(ang)


def dft_matrix_ri(n: int, dtype=np.float32, *, inverse: bool = False):
    """DFT matrix as a (real, imag) pair of real arrays (Pallas-friendly)."""
    w = dft_matrix(n, inverse=inverse)
    return w.real.astype(dtype), w.imag.astype(dtype)


@functools.lru_cache(maxsize=None)
def stage_twiddle(r: int, m: int, *, inverse: bool = False) -> np.ndarray:
    """Stage twiddle table T[k1, n2] = exp(-2*pi*i*k1*n2/(r*m)), shape (r, m).

    For the Cooley-Tukey split N = r*m with input index n = m*n1 + n2 and
    output index k = k1 + r*k2 the stage computes::

        Y[k1, k2] = sum_n2 ( T[k1, n2] * sum_n1 W_r[k1, n1] X[n1, n2] ) W_m[n2, k2]
    """
    n = r * m
    sign = 1.0 if inverse else -1.0
    k1 = np.arange(r)[:, None]
    n2 = np.arange(m)[None, :]
    ang = sign * 2.0 * np.pi * ((k1 * n2) % n) / n
    return np.cos(ang) + 1j * np.sin(ang)


def stage_twiddle_ri(r: int, m: int, dtype=np.float32, *, inverse: bool = False):
    t = stage_twiddle(r, m, inverse=inverse)
    return t.real.astype(dtype), t.imag.astype(dtype)


# ---------------------------------------------------------------------------
# ABFT encoding vectors (paper §2.2.2 / §4.1)
# ---------------------------------------------------------------------------

def ones_encoding(n: int, dtype=np.complex128) -> np.ndarray:
    """The all-ones vector e2. Misses opposite-sign error pairs (x+eps, x-eps)
    when used alone (paper §2.2.2) — used as the *correction-value* checksum.
    """
    return np.ones(n, dtype=dtype)


@functools.lru_cache(maxsize=None)
def wang_encoding(n: int) -> np.ndarray:
    """Wang's encoding e_Wang[k] = omega_3^k (omega_3 = exp(-2*pi*i/3)).

    Keeps the input unchanged (unlike Jou's variant) while avoiding the
    +/- eps cancellation blind spot of the ones vector [Wang & Jha 1994].
    """
    ang = -2.0 * np.pi * (np.arange(n) % 3) / 3.0
    return (np.cos(ang) + 1j * np.sin(ang)).astype(np.complex128)


def location_encoding(n: int, offset: int = 0, dtype=np.complex128) -> np.ndarray:
    """The location vector e3 = (1+o, 2+o, ..., n+o) (paper §4.1): the ratio of
    the e3-checksum divergence to the e2-checksum divergence recovers the
    (1-based, offset) index of the corrupted signal.
    """
    return (np.arange(n, dtype=np.float64) + 1.0 + offset).astype(dtype)
