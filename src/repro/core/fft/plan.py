"""FFT plans — the TPU analogue of the paper's template-based codegen.

The paper generates CUDA kernels from 7 parameters ``(N1, N2, N3, n1, n2, n3,
bs)``: the kernel-level cube (how many global-memory round trips) and the
threadblock-level cube (what fits in shared memory), plus the per-thread batch.

On TPU the same decisions are:

* ``kernel_factors`` — split N into 1-3 factors; each factor is one HBM round
  trip (a batched *block FFT* along that axis + twiddle + transpose), mirroring
  the paper's 1/2/3-kernel-launch regimes,
* ``block_radices``  — the mixed-radix decomposition of each factor executed
  entirely in VMEM. Radix choice is MXU-driven: prefer 128 (fills the systolic
  contraction dim), fall back to 64/32/16/8 (paper: registers prefer radix
  8/16; systolic arrays prefer 128),
* ``bs`` — signals per block (grid tile), sized so the VMEM working set
  (x, y, twiddles, checksum scratch) stays under a budget.

Plans are semi-empirical and overridable — ``Plan`` is a plain dataclass the
user can construct by hand, exactly like the paper's manual parameter search.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

__all__ = ["Plan", "StagePlan", "make_plan", "block_radices", "PLAN_TABLE"]

# VMEM working-set budget per kernel instance (bytes). TPU v5e VMEM is
# ~128 MiB/core but leaving headroom for double-buffering and checksum
# scratch; the tuner targets <= 8 MiB resident per block.
VMEM_BUDGET = 8 * 1024 * 1024

# Largest signal length executed in a single VMEM-resident block FFT.
MAX_BLOCK_N = 1 << 13  # 8192 complex64 = 64 KiB per signal

# MXU-preferred radices, best first (paper: thread radix 2..32; TPU: 128).
_RADICES = (128, 64, 32, 16, 8, 4, 2)


@dataclasses.dataclass(frozen=True)
class StagePlan:
    """One VMEM-resident Stockham stage: contract with W_r and twiddle."""

    radix: int
    m: int  # remaining length after this stage: stage maps (r, m) -> (r, m)

    @property
    def n(self) -> int:
        return self.radix * self.m


@dataclasses.dataclass(frozen=True)
class Plan:
    """Full plan for an N-point batched FFT.

    ``kernel_factors``: product == N; one entry per HBM pass (paper's
    N1, N2, N3). ``stages[i]`` are the in-VMEM radix stages for factor i.
    ``bs`` is the number of signals per grid tile for the Pallas kernel.
    """

    n: int
    kernel_factors: tuple[int, ...]
    stages: tuple[tuple[StagePlan, ...], ...]
    bs: int
    inverse: bool = False

    @property
    def num_passes(self) -> int:
        return len(self.kernel_factors)

    def describe(self) -> str:
        facs = "x".join(str(f) for f in self.kernel_factors)
        rads = ";".join(
            "*".join(str(s.radix) for s in st) for st in self.stages
        )
        return f"Plan(N={self.n}={facs}, radices=[{rads}], bs={self.bs})"


def block_radices(n: int) -> tuple[int, ...]:
    """Greedy mixed-radix decomposition of a power-of-two n, MXU-first."""
    if n & (n - 1):
        raise ValueError(f"only power-of-two sizes supported, got {n}")
    out: list[int] = []
    rem = n
    while rem > 1:
        for r in _RADICES:
            if rem % r == 0:
                # Avoid leaving a trailing factor smaller than 8 when we can
                # balance (e.g. 256 -> 16*16 rather than 128*2).
                q = rem // r
                if q == 1 or q >= 8 or q in (2, 4) and r <= 32:
                    out.append(r)
                    rem = q
                    break
        else:  # pragma: no cover - unreachable for powers of two
            raise AssertionError(n)
    # rebalance a trailing tiny radix (…,128,2) -> (…,64,4) style fixups
    while len(out) >= 2 and out[-1] < 8 and out[-2] > 8:
        out[-2] //= 2
        out[-1] *= 2
        out.sort(reverse=True)
    return tuple(out)


def _stage_plans(n: int) -> tuple[StagePlan, ...]:
    rads = block_radices(n)
    stages = []
    m = n
    for r in rads:
        m //= r
        stages.append(StagePlan(radix=r, m=m))
    return tuple(stages)


def _split_kernel_factors(n: int) -> tuple[int, ...]:
    """Split N into <=3 balanced factors (paper's 1/2/3-launch regimes).

    Regime boundaries follow the paper (§3.3.2): one pass for N <= 2^13, two
    passes for 2^14..2^22, three passes for 2^23..2^29. E.g. 2^23 ->
    (2^8, 2^8, 2^7), matching Table 1's (N1, N2, N3) = (2^8, 2^7, 2^8).
    """
    if n <= MAX_BLOCK_N:
        return (n,)
    log = n.bit_length() - 1
    if log <= 22:  # two passes, balanced
        l1 = (log + 1) // 2
        return (1 << l1, 1 << (log - l1))
    l1 = (log + 2) // 3
    l2 = (log - l1 + 1) // 2
    return (1 << l1, 1 << l2, 1 << (log - l1 - l2))


def _pick_bs(n_block: int, batch: int, itemsize: int) -> int:
    """Signals per grid tile: fill VMEM budget, stay lane-aligned."""
    # Working set ~= 3 buffers (in, out, twiddle/scratch) of bs * n complex.
    per_signal = 3 * 2 * itemsize * n_block
    bs = max(1, VMEM_BUDGET // max(per_signal, 1))
    # lane alignment: prefer multiples of 8 (sublane) once available
    if bs >= 8:
        bs = (bs // 8) * 8
    bs = min(bs, max(1, batch))
    # keep power-of-two-ish to divide batches evenly
    return 1 << (bs.bit_length() - 1) if bs > 0 else 1


@functools.lru_cache(maxsize=None)
def make_plan(
    n: int,
    batch: int = 1,
    itemsize: int = 4,
    *,
    inverse: bool = False,
    max_block_n: int = MAX_BLOCK_N,
) -> Plan:
    """Build the semi-empirical plan for an (batch, n) FFT workload."""
    if n <= 0 or n & (n - 1):
        raise ValueError(f"N must be a power of two, got {n}")
    factors = _split_kernel_factors(n) if n > max_block_n else (n,)
    stages = tuple(_stage_plans(f) for f in factors)
    bs = _pick_bs(max(factors), batch, itemsize)
    return Plan(n=n, kernel_factors=factors, stages=stages, bs=bs,
                inverse=inverse)


# The paper's Table 1 analogue: plans for representative sizes (T4 table shows
# N=2^10 -> 1 kernel, 2^17 -> 2 kernels, 2^23 -> 3 kernels). Our MAX_BLOCK_N
# (8192 = 2^13) reproduces the same 1/2/3-pass regime boundaries.
PLAN_TABLE = {
    1 << 10: make_plan(1 << 10, batch=1024),
    1 << 17: make_plan(1 << 17, batch=64),
    1 << 23: make_plan(1 << 23, batch=4),
}
