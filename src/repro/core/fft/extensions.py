"""FFT library extensions beyond the paper's C2C core: real-input transform,
2-D transform, and FT-protected inverse via conjugation.

These compose the validated building blocks (no new numerics):
  rfft:  real -> half-spectrum via one C2C FFT of half length (the classic
         packing trick: x_even + i*x_odd),
  fft2:  thin wrapper over a rank-2 plan (``core.fft.api`` — slab/pencil on
         a mesh, local otherwise),
  ft_ifft: ifft(x) = conj(fft(conj(x))) / N — runs the *forward* protected
         kernel, so the two-sided ABFT covers the inverse transform too.

Every function here is spec-builder sugar over ``core.fft.api``: it builds
(or LRU-hits) the :class:`~repro.core.fft.api.FFTPlan` describing the call
and runs the plan executor — the same single dispatch path ``kernels.ops``
and ``launch.serve`` use. ``rfft``/``irfft`` therefore accept ``mesh=``:
the half-length C2C transform runs the distributed pencil pipeline when the
mesh (and a power-of-two half length >= shards^2) allows, and falls back to
the local transform otherwise — including the odd-``n`` ``irfft`` branch,
which is a direct DFT (odd lengths are outside the power-of-two planner).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .distributed import _AUTO, FFT_AXIS, _resolve_mesh
from .stockham import fft as _fft, ifft as _ifft, naive_dft

__all__ = ["rfft", "irfft", "fft2", "ifft2", "ft_ifft"]


def _plan_c2c(z, mesh, axis, data_axis, *, natural_order=True):
    """The plan for one C2C helper transform of ``z`` — distributed iff the
    resolved mesh can actually split ``z``'s last axis, local otherwise."""
    from . import api

    mesh = _resolve_mesh(mesh, axis)
    if mesh is not None and mesh.shape[axis] > 1 \
            and api._feasible_1d(z.shape[-1], mesh.shape[axis]):
        return api.plan(api.spec_for(z, mesh=mesh, axis=axis,
                                     data_axis=data_axis,
                                     natural_order=natural_order))
    return None


def rfft(x: jax.Array, *, mesh=None, axis: str = FFT_AXIS,
         data_axis: str | None = _AUTO) -> jax.Array:
    """Real-input FFT over the last axis -> (..., N/2+1) half spectrum.

    ``mesh`` distributes the underlying half-length C2C transform over the
    pencil pipeline (the Hermitian unpacking is elementwise and stays
    wherever GSPMD puts it); infeasible sizes fall back to the local path.
    """
    x = jnp.asarray(x)
    n = x.shape[-1]
    assert n % 2 == 0, "even length required"
    half = n // 2
    # pack: z[k] = x[2k] + i x[2k+1]; one half-length C2C transform
    z = x[..., 0::2] + 1j * x[..., 1::2]
    z = z.astype(jnp.complex64 if x.dtype != jnp.float64 else jnp.complex128)
    p = _plan_c2c(z, mesh, axis, data_axis)
    zf = p.fft(z) if p is not None else _fft(z)
    k = jnp.arange(half + 1)
    w = jnp.exp(-2j * np.pi * k / n).astype(zf.dtype)
    zf_ext = jnp.concatenate([zf, zf[..., :1]], axis=-1)      # Z[half] = Z[0]
    zconj = jnp.conj(zf_ext[..., ::-1])                        # Z*[half-k]
    even = 0.5 * (zf_ext + zconj)
    odd = -0.5j * (zf_ext - zconj)
    return even + w * odd


def irfft(y: jax.Array, n: int | None = None, *, mesh=None,
          axis: str = FFT_AXIS, data_axis: str | None = _AUTO) -> jax.Array:
    """Inverse of rfft: (..., N/2+1) half spectrum -> (..., N) real.

    Even ``n`` keeps this library's documented semantics: reconstruct the
    ``2*(len-1)``-point signal and truncate it to ``n`` samples. Odd ``n``
    is a genuinely different transform — the spectrum then has no Nyquist
    bin and the Hermitian tail is ``conj(y[..., 1:][..., ::-1])``, not the
    even-length tail (truncating the even reconstruction silently returns
    wrong values). For odd ``n`` we therefore crop to the ``(n+1)//2`` bins
    an odd-length real signal has (numpy's convention) and invert exactly;
    the odd full length is outside the power-of-two Stockham planner, so
    that branch runs the O(n^2) direct inverse DFT — locally even when a
    ``mesh`` is passed (the documented fallback).
    """
    y = jnp.asarray(y)
    if n is None:
        n = 2 * (y.shape[-1] - 1)
    if n % 2:
        m = (n + 1) // 2   # bins of an odd-length real signal
        if y.shape[-1] < m:
            raise ValueError(
                f"irfft: spectrum has {y.shape[-1]} bins but odd n={n} "
                f"needs at least {m}")
        yh = y[..., :m]
        tail = jnp.conj(yh[..., 1:][..., ::-1])
        full = jnp.concatenate([yh, tail], axis=-1)     # length n, odd
        return jnp.real(naive_dft(full, inverse=True))
    # reconstruct the full spectrum by Hermitian symmetry, ifft, take real
    tail = jnp.conj(y[..., 1:-1][..., ::-1])
    full = jnp.concatenate([y, tail], axis=-1)
    p = _plan_c2c(full, mesh, axis, data_axis)
    inv = p.ifft(full) if p is not None else _ifft(full)
    return jnp.real(inv)[..., :n]


def fft2(x: jax.Array, *, mesh=None, interpret: bool | None = None,
         axis: str = "fft", natural_order: bool = True,
         decomp: str = "auto") -> jax.Array:
    """2-D FFT over the last two axes — spec-builder sugar over a rank-2
    plan (``core.fft.api``).

    ``mesh`` (or an ``x`` already committed to an fft-axis mesh) dispatches
    to the slab/pencil decomposition; without one this is the local
    transform (odd / non-power-of-two axes run the direct DFT, and
    ``interpret`` routes power-of-two axes through the Pallas kernel).
    """
    from . import api

    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    spec = api.spec_for(x, rank=2, mesh=mesh, axis=axis,
                        natural_order=natural_order, decomp=decomp,
                        interpret=interpret)
    return api.plan(spec).fft(x)


def ifft2(x: jax.Array, *, mesh=None, interpret: bool | None = None,
          axis: str = "fft", natural_order: bool = True,
          decomp: str = "auto") -> jax.Array:
    """Inverse of :func:`fft2` (normalized by 1/(R*C)); same mesh /
    interpret threading, see :func:`repro.core.fft.multidim.distributed_ifft2`.
    """
    from . import api

    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    spec = api.spec_for(x, rank=2, mesh=mesh, axis=axis,
                        natural_order=natural_order, decomp=decomp,
                        interpret=interpret)
    return api.plan(spec).ifft(x)


def ft_ifft(x: jax.Array, **ft_kwargs):
    """Fault-tolerant inverse FFT via conjugation around the protected
    forward kernel: ifft(x) = conj(fft(conj(x))) / N. Returns the same
    FTFFTResult as ops.ft_fft, with y already conjugated/normalized."""
    from repro.kernels import ops

    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    n = x.shape[-1]
    res = ops.ft_fft(jnp.conj(x), **ft_kwargs)
    y = jnp.conj(res.y) / n
    import dataclasses

    return dataclasses.replace(res, y=y)
