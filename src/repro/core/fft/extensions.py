"""FFT library extensions beyond the paper's C2C core: real-input transform,
2-D transform, and FT-protected inverse via conjugation.

These compose the validated building blocks (no new numerics):
  rfft:  real -> half-spectrum via one C2C FFT of half length (the classic
         packing trick: x_even + i*x_odd),
  fft2:  thin wrapper over a rank-2 plan (``core.fft.api`` — slab/pencil on
         a mesh, local otherwise),
  ft_ifft: ifft(x) = conj(fft(conj(x))) / N — runs the *forward* protected
         kernel, so the two-sided ABFT covers the inverse transform too.

Every function here is spec-builder sugar over ``core.fft.api``: it builds
(or LRU-hits) the :class:`~repro.core.fft.api.FFTPlan` describing the call
and runs the plan executor — the same single dispatch path ``kernels.ops``
and ``launch.serve`` use. ``rfft``/``irfft`` therefore accept ``mesh=``:
the half-length C2C transform runs the distributed pencil pipeline when the
mesh (and a power-of-two half length >= shards^2) allows, and falls back to
the local transform otherwise — including the odd-``n`` ``irfft`` branch,
which is a direct DFT (odd lengths are outside the power-of-two planner).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .distributed import _AUTO, FFT_AXIS, _resolve_mesh
from .stockham import fft as _fft, ifft as _ifft, naive_dft

__all__ = ["rfft", "irfft", "fft2", "ifft2", "rfft2", "irfft2", "ft_ifft"]


def _complex_for(dtype) -> jnp.dtype:
    """The complex dtype a real input of ``dtype`` promotes to: float64
    keeps double precision (complex128), everything else is complex64."""
    return jnp.dtype(jnp.complex128 if dtype == jnp.float64
                     else jnp.complex64)


def _plan_c2c(z, mesh, axis, data_axis, *, natural_order=True):
    """The plan for one C2C helper transform of ``z`` — distributed iff the
    resolved mesh can actually split ``z``'s last axis, local otherwise."""
    from . import api

    mesh = _resolve_mesh(mesh, axis)
    if mesh is not None and mesh.shape[axis] > 1 \
            and api._feasible_1d(z.shape[-1], mesh.shape[axis]):
        return api.plan(api.spec_for(z, mesh=mesh, axis=axis,
                                     data_axis=data_axis,
                                     natural_order=natural_order))
    return None


def rfft(x: jax.Array, *, mesh=None, axis: str = FFT_AXIS,
         data_axis: str | None = _AUTO) -> jax.Array:
    """Real-input FFT over the last axis -> (..., N/2+1) half spectrum.

    ``mesh`` distributes the underlying half-length C2C transform over the
    pencil pipeline (the Hermitian unpacking is elementwise and stays
    wherever GSPMD puts it); infeasible sizes fall back to the local path.
    Odd lengths cannot split into the even/odd pack, so they run the local
    O(n^2) direct DFT and crop to the ``n//2 + 1`` bins — the same
    documented fallback as the odd-``n`` :func:`irfft` branch.
    """
    x = jnp.asarray(x)
    n = x.shape[-1]
    if n == 0:
        raise ValueError("rfft: empty signal axis (n=0) has no spectrum")
    if n % 2:
        # odd n: no even/odd split — direct DFT, cropped half spectrum
        full = naive_dft(x.astype(_complex_for(x.dtype)))
        return full[..., :n // 2 + 1]
    half = n // 2
    # pack: z[k] = x[2k] + i x[2k+1]; one half-length C2C transform
    z = x[..., 0::2] + 1j * x[..., 1::2]
    z = z.astype(_complex_for(x.dtype))
    p = _plan_c2c(z, mesh, axis, data_axis)
    zf = p.fft(z) if p is not None else _fft(z)
    k = jnp.arange(half + 1)
    w = jnp.exp(-2j * np.pi * k / n).astype(zf.dtype)
    zf_ext = jnp.concatenate([zf, zf[..., :1]], axis=-1)      # Z[half] = Z[0]
    zconj = jnp.conj(zf_ext[..., ::-1])                        # Z*[half-k]
    even = 0.5 * (zf_ext + zconj)
    odd = -0.5j * (zf_ext - zconj)
    return even + w * odd


def irfft(y: jax.Array, n: int | None = None, *, mesh=None,
          axis: str = FFT_AXIS, data_axis: str | None = _AUTO) -> jax.Array:
    """Inverse of rfft: (..., N/2+1) half spectrum -> (..., N) real.

    Even ``n`` keeps this library's documented semantics: reconstruct the
    ``2*(len-1)``-point signal and truncate it to ``n`` samples. Odd ``n``
    is a genuinely different transform — the spectrum then has no Nyquist
    bin and the Hermitian tail is ``conj(y[..., 1:][..., ::-1])``, not the
    even-length tail (truncating the even reconstruction silently returns
    wrong values). For odd ``n`` we therefore crop to the ``(n+1)//2`` bins
    an odd-length real signal has (numpy's convention) and invert exactly;
    the odd full length is outside the power-of-two Stockham planner, so
    that branch runs the O(n^2) direct inverse DFT — locally even when a
    ``mesh`` is passed (the documented fallback).
    """
    y = jnp.asarray(y)
    if y.shape[-1] == 0:
        raise ValueError("irfft: empty spectrum (0 bins)")
    if n is None:
        if y.shape[-1] == 1:
            raise ValueError(
                "irfft: a single-bin spectrum has no default length "
                "(2*(bins-1) = 0) — pass n explicitly (n=1 or n=2)")
        n = 2 * (y.shape[-1] - 1)
    if n <= 0:
        raise ValueError(f"irfft: output length must be positive, got n={n}")
    if n == 1:
        # one sample: the spectrum is just the (real) DC bin
        return jnp.real(y[..., :1])
    if n % 2:
        m = (n + 1) // 2   # bins of an odd-length real signal
        if y.shape[-1] < m:
            raise ValueError(
                f"irfft: spectrum has {y.shape[-1]} bins but odd n={n} "
                f"needs at least {m}")
        yh = y[..., :m]
        tail = jnp.conj(yh[..., 1:][..., ::-1])
        full = jnp.concatenate([yh, tail], axis=-1)     # length n, odd
        return jnp.real(naive_dft(full, inverse=True))
    # reconstruct the full spectrum by Hermitian symmetry, ifft, take real
    tail = jnp.conj(y[..., 1:-1][..., ::-1])
    full = jnp.concatenate([y, tail], axis=-1)
    p = _plan_c2c(full, mesh, axis, data_axis)
    inv = p.ifft(full) if p is not None else _ifft(full)
    return jnp.real(inv)[..., :n]


def fft2(x: jax.Array, *, mesh=None, interpret: bool | None = None,
         axis: str = "fft", natural_order: bool = True,
         decomp: str = "auto") -> jax.Array:
    """2-D FFT over the last two axes — spec-builder sugar over a rank-2
    plan (``core.fft.api``).

    ``mesh`` (or an ``x`` already committed to an fft-axis mesh) dispatches
    to the slab/pencil decomposition; without one this is the local
    transform (odd / non-power-of-two axes run the direct DFT, and
    ``interpret`` routes power-of-two axes through the Pallas kernel).
    """
    from . import api

    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(_complex_for(x.dtype))
    spec = api.spec_for(x, rank=2, mesh=mesh, axis=axis,
                        natural_order=natural_order, decomp=decomp,
                        interpret=interpret)
    return api.plan(spec).fft(x)


def ifft2(x: jax.Array, *, mesh=None, interpret: bool | None = None,
          axis: str = "fft", natural_order: bool = True,
          decomp: str = "auto") -> jax.Array:
    """Inverse of :func:`fft2` (normalized by 1/(R*C)); same mesh /
    interpret threading, see :func:`repro.core.fft.multidim.distributed_ifft2`.
    """
    from . import api

    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(_complex_for(x.dtype))
    spec = api.spec_for(x, rank=2, mesh=mesh, axis=axis,
                        natural_order=natural_order, decomp=decomp,
                        interpret=interpret)
    return api.plan(spec).ifft(x)


def rfft2(x: jax.Array, *, mesh=None, interpret: bool | None = None,
          axis: str = FFT_AXIS, data_axis: str | None = _AUTO,
          decomp: str = "auto") -> jax.Array:
    """2-D real-input FFT over the last two axes -> (..., R, C/2+1) half
    spectrum — spec-builder sugar over a rank-2 *real* plan.

    On a mesh the row pass is the half-length packed C2C transform and only
    the C/2+1 surviving column pencils (padded to a shard-divisible width)
    flow through the inter-axis transpose — about half the all-to-all bytes
    of :func:`fft2` on the same grid (see ``multidim.distributed_rfft2``).
    """
    from . import api

    x = jnp.asarray(x)
    if jnp.issubdtype(x.dtype, jnp.complexfloating):
        raise ValueError(f"rfft2 takes a real input, got {x.dtype}")
    spec = api.spec_for(x, rank=2, mesh=mesh, axis=axis, data_axis=data_axis,
                        decomp=decomp, interpret=interpret, real=True)
    return api.plan(spec).rfft2(x)


def irfft2(y: jax.Array, *, mesh=None, interpret: bool | None = None,
           axis: str = FFT_AXIS, data_axis: str | None = _AUTO,
           decomp: str = "auto") -> jax.Array:
    """Inverse of :func:`rfft2`: (..., R, C/2+1) half spectrum ->
    (..., R, C) real grid with ``C = 2*(bins-1)`` (even columns only; odd
    grids go through the local :func:`irfft` per axis)."""
    from . import api
    from repro.parallel.fft_sharding import infer_fft_mesh

    y = jnp.asarray(y)
    if y.ndim < 2:
        raise ValueError(f"irfft2 needs a rank >= 2 spectrum, got {y.shape}")
    if y.shape[-1] < 2:
        raise ValueError(
            "irfft2: a single-bin half spectrum has no default width — "
            "the columns' full length 2*(bins-1) would be 0")
    cc = 2 * (y.shape[-1] - 1)
    dtype = "complex128" if y.dtype in (jnp.complex128, jnp.float64) \
        else "complex64"
    spec = api.FFTSpec(shape=y.shape[:-2] + (y.shape[-2], cc), dtype=dtype,
                       rank=2, mesh=mesh if mesh is not None
                       else infer_fft_mesh(y, axis), axis=axis,
                       data_axis=data_axis, decomp=decomp,
                       interpret=interpret, real=True)
    return api.plan(spec).irfft2(y)


def ft_ifft(x: jax.Array, **ft_kwargs):
    """Fault-tolerant inverse FFT via conjugation around the protected
    forward kernel: ifft(x) = conj(fft(conj(x))) / N. Returns the same
    FTFFTResult as ops.ft_fft, with y already conjugated/normalized."""
    from repro.kernels import ops

    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(_complex_for(x.dtype))
    n = x.shape[-1]
    res = ops.ft_fft(jnp.conj(x), **ft_kwargs)
    y = jnp.conj(res.y) / n
    import dataclasses

    return dataclasses.replace(res, y=y)
