"""Pure-JAX mixed-radix Stockham FFT (the reference / TPU-graph-level path).

Each stage is a contraction with a small DFT factor matrix followed by a
twiddle multiply — on TPU every stage therefore runs on the MXU. Complex data
is kept in native complex64/128 at this level; the Pallas kernels (see
``repro.kernels``) use the split real/imag representation instead.

Index convention (see ``factors.stage_twiddle``): for N = r*m,

    n = m*n1 + n2          (input:  reshape to (r, m), row-major)
    k = k1 + r*k2          (output: transpose (r, m) -> (m, r), flatten)

    Y[k1,k2] = sum_{n2} T[k1,n2] * (sum_{n1} Wr[k1,n1] X[n1,n2]) * Wm[n2,k2]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import factors
from .plan import Plan, make_plan

__all__ = ["fft", "ifft", "fft_with_plan", "block_fft_stages", "naive_dft",
           "radix2_fft"]


def _factor_const(r: int, dtype, inverse: bool):
    return jnp.asarray(factors.dft_matrix(r, inverse=inverse), dtype=dtype)


def _twiddle_const(r: int, m: int, dtype, inverse: bool):
    return jnp.asarray(factors.stage_twiddle(r, m, inverse=inverse),
                       dtype=dtype)


def block_fft_stages(x: jax.Array, *, inverse: bool = False) -> jax.Array:
    """In-"VMEM" mixed-radix FFT over the last axis of ``x`` (batched).

    This is the stage structure the Pallas kernel mirrors (with precomputed
    twiddle tables); at the JAX level it is also the building block of the
    large-N multi-pass driver.
    """
    n = x.shape[-1]
    if n == 1:
        return x
    plan_stages = make_plan(n).stages[0]
    return _fft_recursive(x, list(plan_stages), inverse)


def _fft_recursive(x: jax.Array, stages, inverse: bool) -> jax.Array:
    n = x.shape[-1]
    if len(stages) == 0 or n == 1:
        return x
    st = stages[0]
    r, m = st.radix, st.m
    assert r * m == n, (r, m, n)
    dtype = x.dtype
    z = x.reshape(x.shape[:-1] + (r, m))
    w = _factor_const(r, dtype, inverse)
    z = jnp.einsum("kr,...rm->...km", w, z)
    if m > 1:
        z = z * _twiddle_const(r, m, dtype, inverse)
        z = _fft_recursive(z, stages[1:], inverse)  # FFT along last axis (m)
    # k = k1 + r*k2  ->  output viewed as (m, r) row-major is Y^T
    z = jnp.swapaxes(z, -1, -2)
    return z.reshape(x.shape[:-1] + (n,))


def fft_with_plan(x: jax.Array, plan: Plan) -> jax.Array:
    """Single-pass (VMEM-sized) FFT following ``plan.stages[0]``."""
    if plan.num_passes != 1:
        raise ValueError(f"fft_with_plan is single-pass, got "
                         f"num_passes={plan.num_passes} — use "
                         f"large.fft_large for multi-pass plans")
    y = _fft_recursive(x, list(plan.stages[0]), plan.inverse)
    if plan.inverse:
        y = y / plan.n
    return y


@functools.partial(jax.jit, static_argnames=("inverse",))
def _fft_jit(x: jax.Array, *, inverse: bool) -> jax.Array:
    n = x.shape[-1]
    plan = make_plan(n, inverse=inverse)
    if plan.num_passes == 1:
        return fft_with_plan(x, plan)
    from . import large  # local import to avoid cycle

    return large.fft_large(x, plan)


def fft(x: jax.Array) -> jax.Array:
    """Forward FFT over the last axis. Matches ``jnp.fft.fft`` conventions."""
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    return _fft_jit(x, inverse=False)


def ifft(x: jax.Array) -> jax.Array:
    """Inverse FFT over the last axis (normalized by 1/N)."""
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    return _fft_jit(x, inverse=True)


# ---------------------------------------------------------------------------
# Baselines for benchmarks/stepwise_opt.py (paper Fig. 15)
# ---------------------------------------------------------------------------

def naive_dft(x: jax.Array, *, inverse: bool = False) -> jax.Array:
    """O(N^2) direct DFT — the paper's conceptual v0 lower bound."""
    n = x.shape[-1]
    w = jnp.asarray(factors.dft_matrix(n, inverse=inverse), dtype=x.dtype)
    y = jnp.einsum("kn,...n->...k", w, x)
    return y / n if inverse else y


def radix2_fft(x: jax.Array, *, inverse: bool = False) -> jax.Array:
    """Pure radix-2 Stockham (paper's TurboFFT-v0: one radix-2 per 'launch').

    log2(N) stages of radix 2 — maximally launch/stage heavy, no MXU use.
    """
    n = x.shape[-1]
    if n & (n - 1):
        raise ValueError("power of two required")
    stages = []
    m = n
    while m > 1:
        m //= 2
        from .plan import StagePlan

        stages.append(StagePlan(radix=2, m=m))
    y = _fft_recursive(x, stages, inverse)
    return y / n if inverse else y
