"""Mesh-sharded distributed FFT: the paper's kernel-level N1 x N2 decomposition
lifted from one device to a device mesh (pencil decomposition over shard_map).

The single-device multi-pass driver (``large.py``) folds the inter-pass
transpose into the access pattern of the next pass; across a mesh that
transpose is irreducibly a collective. "Coded FFT and Its Communication
Overhead" shows this all-to-all dominates distributed FFT cost, so the
decomposition here is chosen to need exactly ONE all-to-all regardless of how
many local radix passes each side of the split runs:

    x (B, N) viewed as (B, N1, N2), n = N2*n1 + n2, sharded over n2
      pass 1  : batched block FFT over n1      — local (columns are resident)
      twiddle : T[k1, n2] slice for this shard — local
      transpose: all-to-all splitting k1, concatenating n2 (the one collective)
      pass 2  : batched block FFT over n2      — local (rows now resident)
    output Z[k1, k2] = X[k1 + N1*k2], sharded over k1

The split reuses :func:`make_plan`'s ``kernel_factors`` (the paper's 1/2/3
HBM-pass regimes); factors beyond the first stay on the local side of the
all-to-all and run as ordinary local multi-pass FFTs.

Two-side ABFT in the sharded setting (the mesh-level analogue of the paper's
multi-transaction amortization, §4.2-4.3):

* left (per-pass) checksums — ``sum_k W[k, n] = r * delta(n)`` makes the
  column sum of every local block FFT predictable from its input; each shard
  verifies its own passes with ZERO extra traffic (``shard_delta``).
* right (batch) checksums — per checksum *group* (the mesh-level analogue of
  the paper's multi-transaction threadblocks), ``cs2_g = sum_{b in g} x_b``
  and ``cs3_g = sum_{b in g} id_b x_b`` are themselves signals, sharded
  exactly like the data. They ride through the same pipeline as two extra
  batch rows per group, so F(cs_in) costs no extra collective volume beyond
  2G/B of the data's. Detection and location compare them against checksums
  of the *computed* outputs; the only cross-device ABFT traffic is ONE psum
  of 3 scalars per group (plus a shared energy scalar), confined to the
  ``fft`` axis, so detect -> locate -> correct works even when the faulty
  element lives on another device — and G concurrent SEUs in G distinct
  groups are all repaired in a single pass. On a 2-D batch x pencil mesh the
  batch (and its groups) shard over ``data``; each data shard owns its
  groups outright, so the ft path composes with batch sharding instead of
  forcing replication.

Transposed order, both directions (the FFTW-MPI ``TRANSPOSED_OUT`` /
``TRANSPOSED_IN`` pairing): ``natural_order=False`` on the forward skips the
final redistribution and returns the digit-permuted spectrum
``y[k1*N2 + k2] = X[k1 + N1*k2]`` still sharded over ``k1``;
``natural_order=False`` on the *inverse* declares its input to be in exactly
that order and consumes it without any up-front redistribution. The inverse's
one all-to-all splits the *batch* axis instead of a signal digit, so the
natural-order time-domain result lands batch-sharded with every signal fully
resident on one device — a forward + pointwise + inverse round trip costs two
all-to-alls and ZERO all-gathers (see ``spectral.py`` for the consumers).

Mesh composition: every entry point takes an optional ``data_axis`` (default:
auto-detect a ``data`` axis on the mesh). Batch rows shard over ``data``
while the signal pencils shard over ``fft``, so independent transforms scale
along one mesh dimension while single-transform size scales along the other.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from . import factors
from .large import _fft_factors
from .plan import MAX_BLOCK_N, make_plan
from .stockham import block_fft_stages

# Same guard value as core.abft.encoding.EPS (not imported: core.abft itself
# imports core.fft at package level, so importing it back would be a cycle).
EPS = 1e-30

__all__ = [
    "DistPlan", "DistFFTResult", "make_dist_plan", "distributed_fft",
    "distributed_ifft", "ft_distributed_fft", "resolve_abft_groups",
    "resolve_chunks", "choose_chunks", "collective_volume",
    "spectral_volume", "FFT_AXIS", "DATA_AXIS",
]

# Canonical mesh-axis name for the signal (pencil) dimension; see
# launch.mesh.make_fft_mesh and kernels.ops auto-dispatch.
FFT_AXIS = "fft"

# Canonical mesh-axis name for the batch dimension of a 2-D batch x pencil
# mesh (make_fft_mesh(shards, data)); auto-detected by the entry points.
DATA_AXIS = "data"

# Sentinel: auto-detect DATA_AXIS on the mesh. Pass ``data_axis=None`` to
# force batch replication even when the mesh carries a data axis.
_AUTO = "auto"

# Correctability gate on the two-side id decode: id_var is the |d2|^2-weighted
# variance of the per-element id estimates d3/d2. A single fault satisfies
# d3 == id * d2 identically, so its id_var sits at the noise floor (<< 1e-3
# for any fault strong enough to detect); two faults with distinct ids in one
# group push it to ab*(i-j)^2/(a+b)^2 — >= 0.04 until one fault carries ~25x
# the other's amplitude (at which point the weak one is near the detection
# floor anyway). Misclassification is asymmetric by design: a borderline
# single fault flagged uncorrectable costs one clean recompute, while a
# mis-corrected double fault would silently corrupt a THIRD signal.
ID_VAR_TOL = 0.04


def _resolve_data_axis(mesh, data_axis):
    """The batch mesh axis to use, or None (batch replicated).

    ``_AUTO`` picks ``DATA_AXIS`` iff the mesh carries it with size > 1; an
    explicit name is validated; ``None`` disables batch sharding.
    """
    if data_axis is None:
        return None
    if data_axis == _AUTO:
        if DATA_AXIS in mesh.axis_names and mesh.shape[DATA_AXIS] > 1:
            return DATA_AXIS
        return None
    if data_axis not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no '{data_axis}' axis")
    return data_axis if mesh.shape[data_axis] > 1 else None


def resolve_chunks(rows: int, chunks: int, *, granule: int = 1) -> int:
    """The largest feasible transaction count <= ``chunks`` for ``rows``.

    A chunked pipeline splits its per-shard rows (batch rows, or whole
    checksum groups, or pencil digit planes) into ``chunks`` equal
    transactions so transaction i's all-to-all overlaps transaction i+1's
    local Stockham passes — the mesh-level analogue of the paper's
    multi-transaction threadblock design. Every transaction must carry the
    same whole number of rows, and each chunk's row count must stay a
    multiple of ``granule`` (``shards`` for the batch-splitting inverse
    all-to-all, 1 elsewhere). Static Python arithmetic — safe under jit.
    """
    c = max(1, min(int(chunks), int(rows) if rows else 1))
    while c > 1 and (rows % c or (rows // c) % max(granule, 1)):
        c -= 1
    return c


# Per-transaction fixed cost of one all-to-all, in payload-equivalent bytes
# (dispatch + link latency amortized over the message). Splitting into C
# chunks exposes ~ C*L + bytes/C of communication (first chunk's transfer
# plus per-chunk launch overhead), minimized at C* = sqrt(bytes / L). 64 KiB
# is conservative for both host meshes and TPU ICI: below it, a2a time is
# latency-dominated and chunking buys nothing.
CHUNK_LATENCY_BYTES = 1 << 16


def choose_chunks(a2a_bytes: float, rows: int, *, granule: int = 1,
                  max_chunks: int = 8) -> int:
    """Auto transaction count from the collective-volume model.

    Picks the power of two nearest below ``C* = sqrt(a2a_bytes /
    CHUNK_LATENCY_BYTES)`` (the minimizer of the exposed-cost model
    ``C*L + bytes/C``), capped at ``max_chunks``, then clamps to what
    ``rows`` can actually carry (:func:`resolve_chunks`).
    """
    c_star = int(np.sqrt(max(float(a2a_bytes), 0.0) / CHUNK_LATENCY_BYTES))
    c = 1
    while c * 2 <= min(c_star, max_chunks):
        c *= 2
    return resolve_chunks(rows, c, granule=granule)


@dataclasses.dataclass(frozen=True)
class DistPlan:
    """Distributed split of an N-point FFT over ``shards`` devices.

    ``n1`` is the distributed (pass-1) factor — FFT'd while columns are
    locally resident; ``n2 = N / n1`` is the tail executed after the
    all-to-all (itself multi-pass locally when n2 > MAX_BLOCK_N).
    """

    n: int
    n1: int
    n2: int
    shards: int
    axis: str = FFT_AXIS

    @property
    def local_in(self) -> tuple[int, int]:
        return (self.n1, self.n2 // self.shards)

    @property
    def local_out(self) -> tuple[int, int]:
        return (self.n1 // self.shards, self.n2)


def make_dist_plan(n: int, shards: int, axis: str = FFT_AXIS) -> DistPlan:
    """Choose the (n1, n2) pencil split for ``shards`` devices.

    Starts from ``make_plan(n).kernel_factors`` (the paper's HBM-pass split)
    and shifts powers of two between the sides until both are divisible by
    ``shards`` — the all-to-all needs shards | n1 and the input sharding
    needs shards | n2.
    """
    if n <= 0 or n & (n - 1):
        raise ValueError(f"N must be a power of two, got {n}")
    if shards & (shards - 1):
        raise ValueError(f"shard count must be a power of two, got {shards}")
    if n < shards * shards:
        raise ValueError(
            f"N={n} too small for a {shards}-way pencil split "
            f"(need N >= shards^2)")
    facs = make_plan(n).kernel_factors
    if len(facs) > 1:
        n1 = facs[0]
    else:
        n1 = 1 << ((n.bit_length() - 1 + 1) // 2)  # balanced split
    n2 = n // n1
    while n1 % shards and n2 > shards:
        n1 *= 2
        n2 //= 2
    while n2 % shards and n1 > shards:
        n1 //= 2
        n2 *= 2
    if n1 % shards or n2 % shards:
        raise ValueError(f"n={n} has no n1*n2 split with both factors "
                         f"divisible by shards={shards} "
                         f"(closest: {n1}x{n2})")
    return DistPlan(n=n, n1=n1, n2=n2, shards=shards, axis=axis)


def _local_fft(z: jax.Array, inverse: bool) -> jax.Array:
    """Unnormalized FFT over the last axis, entirely local to the shard.

    Lengths beyond the single-block budget run the same multi-factor
    composition the single-device driver uses — extra *local* passes, never
    extra collectives.
    """
    nloc = z.shape[-1]
    if nloc == 1:
        return z
    if nloc <= MAX_BLOCK_N:
        return block_fft_stages(z, inverse=inverse)
    return _fft_factors(z, make_plan(nloc).kernel_factors, inverse)


def _resolve_mesh(mesh, axis: str):
    if mesh is None:
        return None
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no '{axis}' axis")
    return mesh


# ---------------------------------------------------------------------------
# plain distributed transform
# ---------------------------------------------------------------------------


def _batch_spec(data_axis, b, dsize):
    """The batch-dim spec: sharded over ``data_axis`` when it divides."""
    return data_axis if (data_axis and b % dsize == 0) else None


@functools.lru_cache(maxsize=None)
def _dist_fft_fn(mesh: Mesh, axis: str, inverse: bool,
                 natural_order: bool = True, data_axis: str | None = None,
                 chunks: int = 1):
    """Build the jitted shard_map pipeline for one (mesh, axis, direction).

    With ``data_axis`` set, batch rows shard over it (each data shard runs
    the pencil pipeline on its slice; the all-to-all stays within ``axis``).
    ``chunks > 1`` splits the local batch into that many transactions —
    chunk i's all-to-all overlaps chunk i+1's pass-1 compute; results are
    bitwise-identical to the bulk-synchronous path (every per-row op is
    independent of the batch split).
    """
    shards = mesh.shape[axis]
    dsize = mesh.shape[data_axis] if data_axis else 1

    @jax.jit
    def run(x):  # x: (..., N) complex
        shape = x.shape
        n = shape[-1]
        plan = make_dist_plan(n, shards, axis)
        n1, n2 = plan.n1, plan.n2
        tw = jnp.asarray(factors.stage_twiddle(n1, n2, inverse=inverse),
                         dtype=x.dtype)
        z = x.reshape((-1, n1, n2))
        bspec = _batch_spec(data_axis, z.shape[0], dsize)

        def pipeline(zl):
            d = jax.lax.axis_index(axis)
            n2l = zl.shape[-1]
            zl = jnp.swapaxes(zl, -1, -2)
            zl = block_fft_stages(zl, inverse=inverse)   # FFT over n1
            zl = jnp.swapaxes(zl, -1, -2)                # (B, n1, n2l)
            twl = jax.lax.dynamic_slice_in_dim(tw, d * n2l, n2l, axis=1)
            zl = zl * twl
            zl = jax.lax.all_to_all(zl, axis, split_axis=1, concat_axis=2,
                                    tiled=True)          # (B, n1/D, n2)
            return _local_fft(zl, inverse)               # FFT over n2

        def body(zl):
            ce = resolve_chunks(zl.shape[0], chunks)
            if ce == 1:
                return pipeline(zl)
            # one transaction per chunk: the unrolled a2as are independent,
            # so the scheduler runs chunk i's transfer under chunk i+1's
            # pass-1 compute
            parts = jnp.split(zl, ce, axis=0)
            return jnp.concatenate([pipeline(p) for p in parts], axis=0)

        out = shard_map(body, mesh=mesh,
                        in_specs=P(bspec, None, axis),
                        out_specs=P(bspec, axis, None),
                        check_rep=False)(z)
        if natural_order:
            # k = k1 + n1*k2: transpose the cube to natural order. The
            # shard axis (k1) lands strided in the flat result, so XLA
            # materializes it with an all-gather — the unavoidable final
            # redistribution every distributed FFT pays for natural order.
            y = jnp.swapaxes(out, -1, -2).reshape((-1, n))
        else:
            # FFTW-MPI-style "transposed order": y[b, k1*N2 + k2] holds
            # X[k1 + N1*k2]. Block-sharded over k1 — zero extra collectives.
            y = out.reshape((-1, n))
        if inverse:
            y = y / n
        return y.reshape(shape)

    return run


@functools.lru_cache(maxsize=None)
def _dist_ifft_t_fn(mesh: Mesh, axis: str, data_axis: str | None = None,
                    chunks: int = 1):
    """Inverse pipeline consuming TRANSPOSED-order input (TRANSPOSED_IN).

    Input ``y[.., k1*N2 + k2] = X[k1 + N1*k2]`` — exactly what the forward
    returns with ``natural_order=False`` — binds shard-aligned (contiguous
    ``k1`` blocks), so no up-front redistribution. With n = n1*N2 + n2 and
    k = k1 + N1*k2 the inverse splits as

        x[n1, n2] = 1/N sum_k1 e^{+2pi i n1 k1/N1}
                    [ T*[k1, n2] sum_k2 X[k1, k2] e^{+2pi i n2 k2/N2} ]

    pass A (local): inverse FFT over k2 — rows are resident
    twiddle        : conjugate T rows for this shard's k1 range
    all-to-all     : splits the BATCH axis while gathering k1 — after it each
                     device holds all k1 rows for 1/D of the batch
    pass B (local): inverse FFT over k1 -> natural-order x, fully resident

    Because the transpose redistributes batch rather than a signal digit, the
    output is natural order AND flat-contiguous (batch-sharded): the round
    trip needs zero all-gathers. Requires batch % (data * shards) == 0 —
    callers pad (see distributed_ifft).
    """
    shards = mesh.shape[axis]
    dsize = mesh.shape[data_axis] if data_axis else 1

    @jax.jit
    def run(y):  # y: (..., N) complex, transposed digit order
        shape = y.shape
        n = shape[-1]
        plan = make_dist_plan(n, shards, axis)
        n1, n2 = plan.n1, plan.n2
        tw = jnp.asarray(factors.stage_twiddle(n1, n2, inverse=True),
                         dtype=y.dtype)
        z = y.reshape((-1, n1, n2))   # cube (B, k1, k2)
        b = z.shape[0]
        bspec = _batch_spec(data_axis, b, dsize)
        dloc = dsize if bspec else 1
        if (b // dloc) % shards:
            raise ValueError(
                f"transposed-order inverse needs batch divisible by "
                f"{'data*shards' if bspec else 'shards'} "
                f"({dloc}*{shards}), got {b} — pad the batch "
                f"(distributed_ifft does this automatically)")

        def pipeline(zl):
            d = jax.lax.axis_index(axis)
            n1l = zl.shape[-2]
            zl = _local_fft(zl, inverse=True)            # IFFT over k2
            twl = jax.lax.dynamic_slice_in_dim(tw, d * n1l, n1l, axis=0)
            zl = zl * twl
            zl = jax.lax.all_to_all(zl, axis, split_axis=0, concat_axis=1,
                                    tiled=True)          # (B/D, n1, n2)
            zl = jnp.swapaxes(zl, -1, -2)
            zl = _local_fft(zl, inverse=True)            # IFFT over k1
            zl = jnp.swapaxes(zl, -1, -2)                # natural (n1, n2)
            return zl.reshape(zl.shape[0], n) / n        # flat, local

        def body(zl):
            # the a2a splits the batch into ``shards`` destination blocks;
            # a chunk must take rows from WITHIN each block (a strided
            # selection), else device d's resident rows after the split
            # would be a permutation of the bulk path's
            ce = resolve_chunks(zl.shape[0] // shards, chunks)
            if ce == 1:
                return pipeline(zl)
            blocks = zl.reshape((shards, zl.shape[0] // shards)
                                + zl.shape[1:])
            w = blocks.shape[1] // ce
            outs = []
            for i in range(ce):
                part = blocks[:, i * w:(i + 1) * w]
                outs.append(pipeline(part.reshape((-1,) + zl.shape[1:])))
            return jnp.concatenate(outs, axis=0)  # rows land in bulk order

        out_spec = P((bspec, axis) if bspec else axis, None)
        out = shard_map(body, mesh=mesh,
                        in_specs=P(bspec, axis, None),
                        out_specs=out_spec,
                        check_rep=False)(z)
        return out.reshape(shape)

    return run


def distributed_fft(x: jax.Array, mesh: Mesh | None = None, *,
                    axis: str = FFT_AXIS, inverse: bool = False,
                    natural_order: bool = True,
                    data_axis: str | None = _AUTO,
                    chunks: int = 1) -> jax.Array:
    """FFT over the last axis, pencil-sharded over ``mesh.shape[axis]``
    devices. Matches ``jnp.fft.fft`` conventions. Batch dims shard over
    ``data_axis`` when the mesh carries one (auto-detected ``"data"`` by
    default; pass ``data_axis=None`` to replicate the batch instead).

    ``natural_order=False`` is the FFTW-MPI transposed pairing: on the
    forward it skips the final redistribution and returns the transposed
    digit order ``y[.., k1*N2 + k2] = X[k1 + N1*k2]``, still sharded — the
    cheap choice when the consumer is pointwise anyway (convolution, power
    spectra; see ``core.fft.spectral``). On the inverse it declares the
    *input* to be in that order (TRANSPOSED_IN) and returns natural-order
    time domain, batch-sharded — zero all-gathers either way.

    With ``mesh=None`` or a 1-sized axis this is exactly the local transform
    (where natural and transposed order coincide).

    ``chunks > 1`` splits the batch into that many overlapped transactions
    (multi-transaction pipelining; see :func:`resolve_chunks`) — results are
    bitwise-identical to the bulk-synchronous default.
    """
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    mesh = _resolve_mesh(mesh, axis)
    if mesh is None or mesh.shape[axis] == 1:
        from . import stockham
        return stockham.ifft(x) if inverse else stockham.fft(x)
    daxis = _resolve_data_axis(mesh, data_axis)
    if inverse and not natural_order:
        return _ifft_transposed(x, mesh, axis, daxis, chunks)
    return _dist_fft_fn(mesh, axis, inverse, natural_order, daxis,
                        int(chunks))(x)


def _pad_batch_rows(x2d: jax.Array, dsize: int, shards: int):
    """Pad the batch of a (B, N) array with zero rows to a multiple of
    ``dsize * shards`` — the granule that keeps it both data-shardable and
    batch-splittable by the inverse's all-to-all. Returns (padded, B).

    Padding rides the *unsharded* batch axis (a free local concat); the
    slice back to B is a no-op in the common divisible case.
    """
    b = x2d.shape[0]
    pad = (-b) % (dsize * shards)
    if pad:
        x2d = jnp.concatenate(
            [x2d, jnp.zeros((pad,) + x2d.shape[1:], x2d.dtype)], axis=0)
    return x2d, b


def _ifft_transposed(x, mesh, axis, daxis, chunks: int = 1):
    """Pad the batch so the inverse's batch-split all-to-all divides (and
    the data axis, when present, keeps dividing), run, slice back."""
    shards = mesh.shape[axis]
    dsize = mesh.shape[daxis] if daxis else 1
    lead = x.shape[:-1]
    n = x.shape[-1]
    x2d, b = _pad_batch_rows(x.reshape((-1, n)), dsize, shards)
    out = _dist_ifft_t_fn(mesh, axis, daxis, int(chunks))(x2d)
    if out.shape[0] != b:
        out = out[:b]
    return out.reshape(lead + (n,))


def distributed_ifft(x: jax.Array, mesh: Mesh | None = None, *,
                     axis: str = FFT_AXIS, natural_order: bool = True,
                     data_axis: str | None = _AUTO,
                     chunks: int = 1) -> jax.Array:
    """Inverse of :func:`distributed_fft` (normalized by 1/N).

    ``natural_order=False`` consumes TRANSPOSED-order input (the forward's
    ``natural_order=False`` output) with no up-front redistribution; the
    result is natural-order time domain, batch-sharded over the mesh.
    """
    return distributed_fft(x, mesh, axis=axis, inverse=True,
                           natural_order=natural_order, data_axis=data_axis,
                           chunks=chunks)


# ---------------------------------------------------------------------------
# sharded two-side ABFT (grouped multi-transaction)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DistFFTResult:
    """Corrected outputs + per-group FT telemetry of one sharded ft transform.

    The batch is split into G checksum groups (the mesh-level analogue of the
    fused kernel's multi-transaction threadblocks): every per-group field has
    leading dimension G, and one fault per *group* — not per transform — is
    detected, located, and corrected in a single pass.
    """

    y: jax.Array              # (B, N) corrected outputs
    shard_delta: jax.Array    # (devices,) per-shard left-checksum residual
    group_score: jax.Array    # (G,) relative right-checksum divergence
    flagged: jax.Array        # (G,) bool — group detected a divergence
    location: jax.Array       # (G,) int32 decoded global signal index
    correctable: jax.Array    # (G,) bool — single-fault signature (repaired
                              # in place when correct=True)
    checksum_fault: jax.Array  # (G,) bool — divergence decodes to a checksum
                              # row, not the data (outputs are clean)
    corrected: jax.Array      # scalar int32 — corrections applied
    recomputed: jax.Array     # scalar int32 — groups recomputed by the
                              # policy fallback (see recompute_uncorrectable)

    @property
    def uncorrectable(self) -> jax.Array:
        """(G,) bool — flagged, but neither a single data fault nor a
        checksum-row fault: multiple SEUs hit the same group; the policy
        recompute path is the only repair."""
        return self.flagged & ~self.correctable & ~self.checksum_fault


def resolve_abft_groups(batch: int, *, groups: int | None = None,
                        group_size: int | None = None,
                        data_shards: int = 1) -> int:
    """The checksum group count G for a ``batch``-signal ft transform.

    Explicit ``groups`` wins, else ``group_size`` (G = batch/group_size),
    else auto: one group per data shard when the batch divides (the minimum
    that lets each data shard own whole groups), 1 otherwise. G must divide
    the batch; on a sharded batch each group must live wholly inside one
    data shard, i.e. ``data_shards`` must divide G. A batch that does not
    divide over ``data_shards`` cannot shard at all (the pipeline falls
    back to replicating it), so the data-axis constraint is waived.
    """
    if data_shards > 1 and batch % data_shards:
        data_shards = 1  # batch replicates; groups owe the axis nothing
    if groups is not None and group_size is not None \
            and groups * group_size != batch:
        raise ValueError(f"groups={groups} x group_size={group_size} "
                         f"!= batch={batch}")
    if groups is None:
        if group_size is not None:
            if group_size <= 0 or batch % group_size:
                raise ValueError(
                    f"group_size={group_size} must divide batch={batch}")
            groups = batch // group_size
        else:
            groups = data_shards if (
                data_shards > 1 and batch % data_shards == 0) else 1
    if groups <= 0 or batch % groups:
        raise ValueError(f"groups={groups} must divide batch={batch}")
    if data_shards > 1 and groups % data_shards:
        raise ValueError(
            f"groups={groups} must be a multiple of the data-axis size "
            f"{data_shards} so each data shard owns whole groups "
            f"(or disable batch sharding with data_axis=None)")
    return groups


def _grouped_verdict(ylg, d2, d3, cs2_out, *, axis, threshold, s, n, md, bl,
                     gl, correct, row_offset=0):
    """The shared per-group two-side decode, from checksum divergences to
    verdicts — used by BOTH the 1-D pencil ft pipeline here and the 2-D
    slab ft pipeline (``multidim._ft_slab_fft2_fn``), so the fault
    taxonomy (thresholds, ``ID_VAR_TOL``, cs2/cs3 classification) cannot
    silently diverge between them.

    ``ylg`` is the grouped local output block ``(gl, s, ...)``; ``d2``/
    ``d3`` are the transported-minus-computed checksum divergences
    ``(gl, ...)`` (== -eps_y and -id*eps_y for a single fault); ``n`` is
    the per-signal element count (N for 1-D rows, R*C for 2-D grids). The
    verdict is ONE psum of 3 scalars per locally-owned group + 1 shared
    energy scalar, confined to ``axis``. Returns ``(ylg, stats)`` with the
    located signal repaired in place when ``correct``.

    ``row_offset`` is the first data row this call covers within its data
    shard — non-zero when a chunked pipeline runs one verdict per
    transaction over a slice of the local groups, so decoded ``location``
    stays a global signal index.
    """
    num = jnp.sum((d3 * jnp.conj(d2)).real, axis=(1, 2))
    den = jnp.sum(jnp.abs(d2) ** 2, axis=(1, 2))
    d3sq = jnp.sum(jnp.abs(d3) ** 2, axis=(1, 2))
    energy = jnp.sum(jnp.abs(cs2_out) ** 2)
    payload = jnp.concatenate(
        [jnp.stack([num, den, d3sq], axis=1).ravel(), energy[None]])
    payload = jax.lax.psum(payload, axis)        # 3*gl + 1 scalars
    pg = payload[:-1].reshape((gl, 3))
    num, den, d3sq = pg[:, 0], pg[:, 1], pg[:, 2]
    scale = jnp.sqrt(payload[-1] / (gl * n)) + EPS
    score2 = jnp.sqrt(den / n) / scale
    score3 = jnp.sqrt(d3sq / n) / (s * scale)
    score = jnp.maximum(score2, score3)
    # two-side location decode: lam estimates the within-group id; id_var
    # is the spread of the per-element id estimates — noise-floor for a
    # single fault (d3 == id * d2 identically), O(1) when two faults with
    # distinct ids share a group (even magnitude-symmetric pairs whose
    # mean id lands on an integer)
    lam = num / (den + EPS)
    id_var = jnp.maximum(d3sq / (den + EPS) - lam * lam, 0.0)
    rid = jnp.round(lam).astype(jnp.int32)
    flagged2 = score2 > threshold
    # lam ~ 0 with no spread: the transported cs2 row itself was hit
    # (d3 untouched) — the data is clean, nothing to correct
    cs2_fault = flagged2 & (lam < 0.5) & (id_var < ID_VAR_TOL)
    correctable = (flagged2 & ~cs2_fault & (rid >= 1) & (rid <= s)
                   & (id_var < ID_VAR_TOL))
    # d3 diverged while d2 is quiet: the cs3 row was hit
    cs3_fault = ~flagged2 & (score3 > threshold)
    checksum_fault = cs2_fault | cs3_fault
    flagged = flagged2 | cs3_fault
    loc_local = jnp.clip(rid - 1, 0, s - 1)
    location = md * bl + row_offset + jnp.arange(gl) * s + loc_local
    if correct:
        # d2 is the local slice of -eps_y: elementwise repair of the
        # located signal works no matter which shard holds the fault
        upd = jnp.where(correctable[:, None, None], d2,
                        jnp.zeros_like(d2))
        ylg = ylg.at[jnp.arange(gl), loc_local].add(upd)
    fl = lambda v: v.astype(score.dtype)
    stats = jnp.stack(
        [score, fl(flagged), fl(location), fl(correctable),
         fl(checksum_fault)], axis=1)            # (gl, 5)
    return ylg, stats


def _splice_recomputed(x, res, groups, recompute_fn, caller: str):
    """Shared host-side policy fallback for multi-fault groups: recompute
    the affected group's rows with the plain (unprotected, uninjected)
    pipeline via ``recompute_fn`` and splice them in — SEUs are transient,
    so the recompute is clean. Forces a device sync, hence opt-in."""
    if isinstance(res.flagged, jax.core.Tracer):
        raise ValueError(
            "recompute_uncorrectable is a host-side fallback (it reads the "
            "verdict to decide which group rows to recompute) and cannot "
            f"run under jax.jit — call {caller} eagerly, or pass "
            "recompute_uncorrectable=False inside jit and apply the "
            "recompute on the eager result")
    bad = np.asarray(res.uncorrectable)
    if not bad.any():
        return res
    s = x.shape[0] // groups
    y = res.y
    for gi in np.flatnonzero(bad):
        rows = slice(int(gi) * s, (int(gi) + 1) * s)
        yg = recompute_fn(x[rows])
        y = y.at[rows].set(yg.astype(y.dtype))
    return dataclasses.replace(
        res, y=y, recomputed=jnp.int32(int(bad.sum())))


@functools.lru_cache(maxsize=None)
def _ft_dist_fft_fn(mesh: Mesh, axis: str, threshold: float, correct: bool,
                    natural_order: bool = True, groups: int = 1,
                    data_axis: str | None = None, chunks: int = 1):
    shards = mesh.shape[axis]
    dsize = mesh.shape[data_axis] if data_axis else 1

    @jax.jit
    def run(x, inject):  # x: (B, N) complex; inject: (F, 7) real
        b, n = x.shape
        plan = make_dist_plan(n, shards, axis)
        n1, n2 = plan.n1, plan.n2
        tw = jnp.asarray(factors.stage_twiddle(n1, n2, inverse=False),
                         dtype=x.dtype)
        g = groups
        s = b // g                      # signals per group (wrapper-validated)
        # batch rows shard over the data axis iff every group lands wholly
        # inside one data shard (the wrapper validates explicit asks; auto
        # mode falls back to replication)
        bspec = data_axis if (
            data_axis and b % dsize == 0 and g % dsize == 0) else None
        dloc = dsize if bspec else 1
        bl, gl = b // dloc, g // dloc   # per-data-shard rows / groups
        # transactions carry WHOLE checksum groups, so each chunk's verdict
        # (including its energy normalizer) is self-contained — the paper's
        # multi-transaction amortization with the reduction riding per-chunk
        ce = resolve_chunks(gl, chunks)
        glc, blc = gl // ce, bl // ce   # per-transaction groups / rows
        # right-side encodings per group: e2 = ones (correction value),
        # e3 = 1-based within-group ids (location) — twoside.py's pipeline
        # applied along the *unsharded* batch axis so building them is local.
        ftype = np.float64 if x.dtype == jnp.complex128 else np.float32
        ids = jnp.arange(1, s + 1, dtype=ftype)[None, :, None, None]
        z = x.reshape((b, n1, n2))

        def body(zl):
            d = jax.lax.axis_index(axis)
            md = jax.lax.axis_index(data_axis) if bspec else jnp.int32(0)
            n2l = zl.shape[-1]
            # ---- fault-injection decode (tests/benchmarks): one SEU per
            # inject row [fft_device, signal, row, local_col, enable,
            # eps_re, eps_im] on the pass-1 output. ``signal`` is global:
            # [0, B) hits data rows, [B, B+G) the cs2 row of group
            # signal-B, [B+G, B+2G) the cs3 row of group signal-B-G --------
            dev = inject[:, 0].astype(jnp.int32)
            sig = inject[:, 1].astype(jnp.int32)
            row = inject[:, 2].astype(jnp.int32)
            col = inject[:, 3].astype(jnp.int32)
            is_data = sig < b
            is_cs2 = (sig >= b) & (sig < b + g)
            gidx = jnp.where(is_cs2, sig - b, sig - b - g)
            owner = jnp.where(is_data, sig // bl, gidx // gl)
            drow = sig - owner * bl      # data row, local to the data shard
            grow = gidx - owner * gl     # group index, local to the shard
            amp = inject[:, 4] * ((owner == md) & (d == dev)).astype(ftype)

            def transaction(zlc, ci):
                # input checksums ride as 2 extra rows PER GROUP:
                # rows [0, blc) data | [blc, blc+glc) cs2 | [.., +2glc) cs3
                zg = zlc.reshape((glc, s, n1, n2l))
                cs2_in = jnp.sum(zg, axis=1)
                cs3_in = jnp.sum(ids * zg, axis=1)
                zc = jnp.concatenate([zlc, cs2_in, cs3_in], axis=0)
                # ---- pass 1: FFT over n1 (local) + left checksum ----------
                zt = jnp.swapaxes(zc, -1, -2)
                zf = block_fft_stages(zt, inverse=False)
                # sum_k1 W[k1, n1] = n1*delta(n1): column sums predict from
                # x[0]; residual scaling stays in the input's real dtype (a
                # float32 constant would silently downcast the fp64
                # telemetry and inflate false-positive risk at tight
                # thresholds)
                res1 = jnp.abs(jnp.sum(zf, axis=-1) - n1 * zt[..., 0])
                scale1 = jnp.sqrt(jnp.mean(jnp.abs(zt) ** 2, axis=-1)) + EPS
                delta = jnp.max(res1 / (float(np.sqrt(n1)) * scale1))
                zc = jnp.swapaxes(zf, -1, -2)           # (blc+2glc, n1, n2l)
                twl = jax.lax.dynamic_slice_in_dim(tw, d * n2l, n2l, axis=1)
                zc = zc * twl
                # ---- injection, masked to this transaction's rows ---------
                in_chunk = jnp.where(
                    is_data,
                    (drow >= ci * blc) & (drow < (ci + 1) * blc),
                    (grow >= ci * glc) & (grow < (ci + 1) * glc))
                crow = jnp.where(
                    is_data, drow - ci * blc,
                    blc + jnp.where(is_cs2, 0, glc) + grow - ci * glc)
                eps = (inject[:, 5] + 1j * inject[:, 6]).astype(zc.dtype)
                ampc = amp * in_chunk.astype(ftype)
                onehot = (
                    (jnp.arange(blc + 2 * glc)[None] == crow[:, None])
                    [:, :, None, None]
                    * (jnp.arange(n1)[None] == row[:, None])
                    [:, None, :, None]
                    * (jnp.arange(n2l)[None] == col[:, None])
                    [:, None, None, :])
                zc = zc + jnp.sum((eps * ampc.astype(zc.real.dtype))
                                  [:, None, None, None]
                                  * onehot.astype(zc.real.dtype), axis=0)
                # ---- the one collective per transaction: the transpose ----
                zc = jax.lax.all_to_all(zc, axis, split_axis=1,
                                        concat_axis=2,
                                        tiled=True)     # (blc+2glc, n1/D, n2)
                # ---- pass 2: FFT over n2 (local) + left checksum ----------
                zf2 = _local_fft(zc, inverse=False)
                res2 = jnp.abs(jnp.sum(zf2, axis=-1) - n2 * zc[..., 0])
                scale2 = jnp.sqrt(jnp.mean(jnp.abs(zc) ** 2, axis=-1)) + EPS
                delta = jnp.maximum(
                    delta, jnp.max(res2 / (float(np.sqrt(n2)) * scale2)))
                # ---- detect / locate per group: output checksums vs
                # transported ones ------------------------------------------
                yl = zf2[:blc]
                fcs2 = zf2[blc:blc + glc]               # F(cs_in), sharded
                fcs3 = zf2[blc + glc:]
                ylg = yl.reshape((glc, s) + yl.shape[1:])
                cs2_out = jnp.sum(ylg, axis=1)
                cs3_out = jnp.sum(ids * ylg, axis=1)
                d2 = fcs2 - cs2_out                     # == -eps_y, sharded
                d3 = fcs3 - cs3_out                     # == -id_s * eps_y
                # the verdict: 3 scalars per transaction-owned group + ONE
                # energy scalar, psum'd over the fft axis only — the data
                # axis never participates (each data shard owns its groups
                # outright), and each transaction settles its own verdict
                # so correction stays online while later chunks are still
                # in flight
                ylg, stats = _grouped_verdict(
                    ylg, d2, d3, cs2_out, axis=axis, threshold=threshold,
                    s=s, n=n, md=md, bl=bl, gl=glc, correct=correct,
                    row_offset=ci * blc)
                return ylg.reshape((blc,) + yl.shape[1:]), delta, stats

            if ce == 1:
                yl, delta, stats = transaction(zl, 0)
            else:
                outs = [transaction(p, ci)
                        for ci, p in enumerate(jnp.split(zl, ce, axis=0))]
                yl = jnp.concatenate([o[0] for o in outs], axis=0)
                delta = functools.reduce(jnp.maximum,
                                         [o[1] for o in outs])
                stats = jnp.concatenate([o[2] for o in outs], axis=0)
            return yl, delta[None, None], stats[None]

        yl, deltas, stats = shard_map(
            body, mesh=mesh,
            in_specs=P(bspec, None, axis),
            out_specs=(P(bspec, axis, None), P(bspec, axis),
                       P(axis, bspec, None)),
            check_rep=False)(z)
        if natural_order:
            y = jnp.swapaxes(yl, -1, -2).reshape((b, n))
        else:
            y = yl.reshape((b, n))   # transposed digit order, k1-sharded
        st = stats[0]                # (G, 5); fft shards agree post-psum
        flagged = st[:, 1] > 0.5
        correctable = st[:, 3] > 0.5
        return DistFFTResult(
            y=y, shard_delta=deltas.reshape((-1,)), group_score=st[:, 0],
            flagged=flagged, location=st[:, 2].astype(jnp.int32),
            correctable=correctable, checksum_fault=st[:, 4] > 0.5,
            corrected=jnp.sum(correctable.astype(jnp.int32)) * int(correct),
            recomputed=jnp.zeros((), jnp.int32))

    return run


def _recompute_uncorrectable(x, res, mesh, axis, groups, natural_order):
    """Multi-fault-group policy fallback (see :func:`_splice_recomputed`),
    recomputing with the plain 1-D pipeline."""
    return _splice_recomputed(
        x, res, groups,
        lambda rows: distributed_fft(rows, mesh, axis=axis,
                                     natural_order=natural_order,
                                     data_axis=None),
        "ft_distributed_fft")


def ft_distributed_fft(
    x: jax.Array,
    mesh: Mesh | None = None,
    *,
    axis: str = FFT_AXIS,
    threshold: float = 1e-4,
    correct: bool = True,
    natural_order: bool = True,
    inject: jax.Array | None = None,
    groups: int | None = None,
    group_size: int | None = None,
    data_axis: str | None = _AUTO,
    recompute_uncorrectable: bool = False,
    chunks: int = 1,
) -> DistFFTResult:
    """Fault-tolerant sharded forward FFT (grouped two-side ABFT).

    The batch splits into G checksum groups (``groups``/``group_size``; auto:
    one group per data shard, else 1) — the mesh-level analogue of the fused
    kernel's multi-transaction threadblocks. Each group carries its own
    right-side checksum row pair through the transpose and gets its own
    detect/locate/correct verdict, so G concurrent SEUs striking G distinct
    groups are all corrected in one pass. On a 2-D batch x pencil mesh the
    batch rows SHARD over the data axis (each data shard owns G/data whole
    groups); the verdict psum — 3 scalars per group plus one shared energy
    scalar — stays confined to the ``fft`` axis.

    Per-group verdicts (see :class:`DistFFTResult`): a single data SEU is
    ``correctable`` and repaired in place; two SEUs in one group decode as
    inconsistent (``uncorrectable`` — id-estimate spread over
    ``ID_VAR_TOL``) and are repaired by ``recompute_uncorrectable=True``,
    which recomputes just the affected group rows host-side; an SEU in a
    checksum row itself decodes to ``checksum_fault`` (lam ~ 0 for cs2,
    quiet d2 with loud d3 for cs3) and triggers no correction — the data is
    clean.

    ``inject`` (optional, for tests/benchmarks) is one or more length-7
    float rows ``[device, signal, row, local_col, enable, eps_re, eps_im]``
    adding SEUs to the pass-1 output — the errors then propagate through the
    all-to-all and pass 2 exactly like real mid-pipeline faults. ``signal``
    in ``[B, B+G)`` / ``[B+G, B+2G)`` targets a group's cs2 / cs3 checksum
    row. Residuals, scores, and epsilons stay in the input's real dtype
    (fp64 for complex128), so tight fp64 thresholds remain meaningful.

    ``natural_order=False`` keeps ``y`` in the transposed digit order (still
    sharded, no final all-gather); the telemetry is order-independent.

    ``chunks > 1`` splits the local groups into that many overlapped
    transactions, each carrying whole checksum groups AND its own verdict
    psum — correction stays online per transaction. ``y``, the flag
    booleans, and decoded locations are identical to the bulk path;
    ``group_score`` normalizes against the transaction's own energy rather
    than the whole batch's (per-transaction semantics, matching the
    paper's multi-transaction reductions).
    """
    x = jnp.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    if x.ndim != 2:
        raise ValueError(f"ft_distributed_fft expects (B, N), got {x.shape}")
    mesh = _resolve_mesh(mesh, axis)
    if mesh is None:
        raise ValueError("ft_distributed_fft requires a mesh with an "
                         f"'{axis}' axis (see launch.mesh.make_fft_mesh)")
    daxis = _resolve_data_axis(mesh, data_axis)
    dsize = mesh.shape[daxis] if daxis else 1
    g = resolve_abft_groups(x.shape[0], groups=groups, group_size=group_size,
                            data_shards=dsize)
    ftype = jnp.float64 if x.dtype == jnp.complex128 else jnp.float32
    if inject is None:
        inject = jnp.zeros((1, 7), ftype)
    inject = jnp.asarray(inject, ftype)
    if inject.ndim == 1:
        inject = inject[None]
    res = _ft_dist_fft_fn(mesh, axis, float(threshold), bool(correct),
                          bool(natural_order), g, daxis,
                          int(chunks))(x, inject)
    if recompute_uncorrectable:
        res = _recompute_uncorrectable(x, res, mesh, axis, g,
                                       bool(natural_order))
    return res


# ---------------------------------------------------------------------------
# communication model
# ---------------------------------------------------------------------------


def collective_volume(n: int, batch: int, shards: int, *, itemsize: int = 8,
                      ft: bool = False, natural_order: bool = True,
                      groups: int = 1, data_shards: int = 1,
                      real: bool = False, chunks: int = 1) -> dict:
    """Analytic per-device communication model of one distributed transform.

    Three terms (cross-checked against the post-partitioning HLO by
    benchmarks/fft_distributed.py):

    * the inter-pass transpose: ONE all-to-all over the ``rows * N / D``
      locally-resident elements, of which ``(D-1)/D`` actually cross a link.
      On a 2-D batch x pencil mesh each device carries ``1/data_shards`` of
      the rows;
    * the natural-order redistribution: materializing ``k = k1 + N1*k2``
      order gathers this device's ``batch/data_shards * N`` result rows
      (skipped entirely with ``natural_order=False`` — checksum rows never
      pay it either);
    * the grouped ABFT verdict: one psum of 3 scalars per locally-owned
      checksum group plus ONE energy scalar per transaction — the
      mesh-level analogue of the paper's amortized threadblock reduction,
      and it stays confined to the ``fft`` axis (each data shard owns
      ``groups/data_shards`` groups outright). The scalars live in the
      input's *real* dtype, i.e. ``itemsize / 2`` bytes each (f64 for
      complex128 — hard-coding 4 bytes made the model diverge from the HLO
      for fp64). Extracting the replicated per-group stats block
      (``5 * groups/data_shards`` reals) from the shard_map output costs
      one more small all-reduce — GSPMD's broadcast of shard 0's copy —
      which the model counts so the HLO cross-check holds to pure relative
      tolerance. The checksum *signals* add ``2*groups/batch`` relative
      all-to-all volume (they ride the same transpose), which is the
      ``abft_overhead`` field.

    ``chunks`` is the multi-transaction pipelining degree: the payload
    splits into that many back-to-back all-to-alls (same total bytes —
    ``all_to_all_count`` reports the op count) so transaction i's transfer
    hides behind transaction i+1's local passes. The overlap-efficiency
    term models the schedule: ``exposed_fraction = 1/chunks`` of the
    transpose volume cannot overlap anything (the pipeline has to drain),
    so ``overlap_efficiency = 1 - 1/chunks`` of it is hidden. The ft
    verdict gains one energy scalar per extra transaction.

    ``real=True`` models the rfft packing trick (``extensions.rfft``):
    the executed C2C transform — and so every collective — runs at the
    packed HALF length ``n // 2`` (the Hermitian unpack is elementwise,
    collective-free), halving both the transpose and the natural-order
    gather. The 1-D real path has no ft pipeline (rank-2 ``rfft2`` rides
    the slab ABFT), so ``real=True`` with ``ft=True`` raises.

    ``*_wire`` entries are true link-crossing bytes; ``hlo_bytes`` is what
    :func:`repro.launch.dryrun.collective_bytes` counts for the same program
    (full per-device collective operand bytes, all-reduce at ring factor 2).
    """
    if ft and groups % data_shards:
        raise ValueError(f"groups={groups} must divide over "
                         f"data_shards={data_shards}")
    if real:
        if ft:
            raise ValueError(
                "the 1-D real path has no ft pipeline — grouped ABFT on "
                "real input rides the 2-D slab (collective_volume_nd with "
                "real=True)")
        n = n // 2   # the packed half-length C2C is the whole collective cost
    chunks = max(1, int(chunks))
    rows = (batch + (2 * groups if ft else 0)) / data_shards
    a2a_local = rows * n * itemsize / shards
    a2a_wire = a2a_local * (shards - 1) / shards
    gather_hlo = batch / data_shards * n * itemsize if natural_order else 0.0
    gather_wire = gather_hlo * (shards - 1) / shards
    # per-group verdict scalars + one energy scalar per transaction, plus
    # the stats extraction: grouped pipelines broadcast ONE stacked
    # (G/dd, 5)-real block, the ungrouped pipeline reduces its native
    # scalars instead — 3 predicates (1B), the score real, an s32
    # location (pinned down by the plan auditor's per-kind psum diff)
    verdict = (3 * groups // data_shards + chunks) * (itemsize // 2)
    stats = (5 * groups // data_shards * (itemsize // 2) if groups > 1
             else 3 + (itemsize // 2) + 4)
    psum_hlo = 2.0 * (verdict + stats) if ft else 0.0
    psum_wire = psum_hlo * (shards - 1) / shards
    # batch-sharded stats extraction: GSPMD routes the replicated
    # 5*groups/data_shards-real stats block across the data axis with ONE
    # collective-permute before the fft-axis broadcast (surfaced by the
    # plan auditor's per-kind diff; invisible inside the old total-bytes
    # tolerance at benchmark sizes)
    permute_hlo = (5 * groups // data_shards * (itemsize // 2)
                   if ft and data_shards > 1 else 0.0)
    return {
        "shards": shards,
        "data_shards": data_shards,
        "groups": groups,
        "real": real,
        "chunks": chunks,
        "passes": 2,  # one distributed split -> exactly one transpose
        "all_to_all_count": chunks,
        "all_gather_count": 1 if natural_order else 0,
        "all_to_all_bytes": a2a_local,
        "all_to_all_wire": a2a_wire,
        "gather_hlo": gather_hlo,
        "gather_wire": gather_wire,
        "psum_hlo": psum_hlo,
        "psum_wire": psum_wire,
        "permute_hlo": permute_hlo,
        "total_wire": a2a_wire + gather_wire + psum_wire + permute_hlo,
        "hlo_bytes": a2a_local + gather_hlo + psum_hlo + permute_hlo,
        "abft_overhead": 2.0 * groups / batch if (ft and batch) else 0.0,
        "exposed_fraction": 1.0 / chunks,
        "overlap_efficiency": 1.0 - 1.0 / chunks,
    }


def spectral_volume(n: int, batch: int, shards: int, *, kernel_batch: int = 0,
                    itemsize: int = 8, data_shards: int = 1,
                    real: bool = False, chunks: int = 1) -> dict:
    """Analytic per-device model of one transposed-order spectral round trip
    (forward -> pointwise -> inverse; see ``core.fft.spectral``).

    Exactly TWO all-to-alls and ZERO all-gathers:

    * forward transpose over ``batch / data_shards + kernel_batch`` rows —
      the second operand's spectrum rides the same collective as a stacked
      batch (one all-to-all op, bigger payload). A broadcast kernel is
      replicated per data shard, so its rows do NOT divide by
      ``data_shards``; for per-signal kernel batches (sharded like the
      data) pass ``kernel_batch = bk / data_shards``;
    * inverse batch-split transpose over ``batch / data_shards`` rows (only
      the product goes back through the inverse).

    ``kernel_batch=0`` models a plain fft -> ifft round trip
    (``distributed_ifft(distributed_fft(x, natural_order=False),
    natural_order=False)``). On a 2-D batch x pencil mesh each data shard
    moves ``1/data_shards`` of the batch rows; ``shards`` is the fft-axis
    size.

    ``real=True`` models the packed real convolution (both operands real):
    the kernel rides the imaginary part of ``a + i*v``, so its rows vanish
    from the forward transpose entirely — ``kernel_batch`` is ignored and
    both passes move exactly ``batch / data_shards`` rows.

    ``chunks`` splits the round trip into that many overlapped batch
    transactions: ``2 * chunks`` all-to-alls carrying the same total bytes
    (the kernel spectrum rides transaction 0's forward collective only),
    with ``1/chunks`` of the transpose volume exposed.
    """
    chunks = max(1, int(chunks))
    rows_fwd = batch / data_shards + (0 if real else kernel_batch)
    rows_inv = batch / data_shards
    fwd_local = rows_fwd * n * itemsize / shards
    inv_local = rows_inv * n * itemsize / shards
    wire = (fwd_local + inv_local) * (shards - 1) / shards
    return {
        "shards": shards,
        "data_shards": data_shards,
        "real": real,
        "chunks": chunks,
        "all_to_all_count": 2 * chunks,
        "all_gather_count": 0,
        "all_to_all_bytes": fwd_local + inv_local,
        "all_to_all_wire": wire,
        "gather_hlo": 0.0,
        "gather_wire": 0.0,
        "psum_hlo": 0.0,
        "psum_wire": 0.0,
        "permute_hlo": 0.0,
        "total_wire": wire,
        "hlo_bytes": fwd_local + inv_local,
        "exposed_fraction": 1.0 / chunks,
        "overlap_efficiency": 1.0 - 1.0 / chunks,
    }
