"""One-sided / offline FT-FFT baseline (paper §2.2.3, Fig. 6 red region).

The closest prior work (Pilla et al. offline FT-FFT): a *per-signal* left
checksum computed by separate passes around a library FFT, with
time-redundant recomputation on error. This doubles memory transactions
(the checksum pass re-reads all data) — the paper measures ~30-300% overhead
for the offline scheme vs 7-15% for the fused two-sided scheme.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fft import fft as turbo_fft
from .encoding import EPS, left_encoding, left_encoding_image

__all__ = ["oneside_fft"]


def oneside_fft(
    x: jax.Array,
    *,
    threshold: float = 1e-4,
    encoding: str = "wang",
    fft_fn: Callable[[jax.Array], jax.Array] | None = None,
    corrupt: Callable[[jax.Array], jax.Array] | None = None,
):
    """Offline one-sided FT-FFT: checksum pass -> FFT -> verify -> recompute.

    ``corrupt`` optionally injects an error into the FFT output (test hook).
    Returns (y, flags, recomputed_count).
    """
    fft_fn = fft_fn or turbo_fft
    n = x.shape[-1]
    ew = jnp.asarray(left_encoding_image(n, encoding), dtype=x.dtype)
    e1 = jnp.asarray(left_encoding(n, encoding), dtype=x.dtype)

    # pass 1 (extra memory transaction): per-signal input checksums
    s_in = x @ ew
    # pass 2: the FFT itself
    y = fft_fn(x)
    if corrupt is not None:
        y = corrupt(y)
    # pass 3 (extra memory transaction): per-signal output checksums
    s_out = y @ e1
    score = jnp.abs(s_in - s_out) / (jnp.abs(s_in) + EPS)
    flags = score > threshold
    # time-redundant recomputation of flagged signals (one-sided correction):
    # recompute the whole batch masked — matches the offline scheme's
    # "revert to a saved state and recalculate" cost model.
    y_re = fft_fn(x)
    y = jnp.where(flags[..., None], y_re, y)
    return y, flags, jnp.sum(flags)
