"""Two-sided ABFT: detect / locate / correct from checksum divergences.

Implements the paper's Figure 6 pipeline on *any* linear operator F (FFT here,
GEMM in ``gemm.py``), given the group checksums:

    cs2_in  = X e2 = sum_b x_b              (correction checksum)
    cs3_in  = X e3 = sum_b id_b * x_b       (location checksum)
    cs2_out = Y e2,  cs3_out = Y e3         (same over the computed outputs)

Under the SEU assumption (one corrupted signal y_s = y~_s + eps per detection
period), linearity gives

    F(cs2_in) - cs2_out = -eps                    -> correction value
    (F(cs3_in) - cs3_out) / (F(cs2_in) - cs2_out) = id_s  -> location

so the corrupted signal is repaired *without recomputation* — the delayed
batched correction that distinguishes two-sided from one-sided ABFT (Fig. 5).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .encoding import EPS

__all__ = ["GroupChecksums", "Verdict", "detect_locate", "apply_correction"]


@dataclasses.dataclass
class GroupChecksums:
    """Complex (G, N) checksum arrays for G transaction groups."""

    cs2_in: jax.Array
    cs3_in: jax.Array
    cs2_out: jax.Array
    cs3_out: jax.Array

    @classmethod
    def from_packed(cls, cs: jax.Array) -> "GroupChecksums":
        """From the kernel's packed (G, 8, N) float layout."""
        c = lambda j: cs[:, 2 * j] + 1j * cs[:, 2 * j + 1]
        return cls(cs2_in=c(0), cs3_in=c(1), cs2_out=c(2), cs3_out=c(3))


@dataclasses.dataclass
class Verdict:
    """Detection outcome per group."""

    error_score: jax.Array   # (G,) relative divergence of the e2 checksum
    flagged: jax.Array       # (G,) bool, error_score > threshold
    location: jax.Array      # (G,) int32 global signal index (valid if flagged)
    correction: jax.Array    # (G, N) complex correction value (-eps)


def detect_locate(
    cs: GroupChecksums,
    forward: Callable[[jax.Array], jax.Array],
    threshold: float,
) -> Verdict:
    """Run detection + location on group checksums.

    ``forward`` is the protected linear operator applied to the (G, N) input
    checksums — one extra F per *group*, amortized over group_size signals
    (paper: "amortizing one ABFT checksum transaction along a batch").
    """
    d2 = forward(cs.cs2_in) - cs.cs2_out          # == -eps on the error
    d3 = forward(cs.cs3_in) - cs.cs3_out          # == -id_s * eps
    scale = jnp.sqrt(jnp.mean(jnp.abs(cs.cs2_out) ** 2, axis=-1)) + EPS
    score = jnp.sqrt(jnp.mean(jnp.abs(d2) ** 2, axis=-1)) / scale
    flagged = score > threshold
    # |d2|^2-weighted estimate of id_s = d3/d2 (robust to tiny elements)
    num = jnp.sum(d3 * jnp.conj(d2), axis=-1).real
    den = jnp.sum(jnp.abs(d2) ** 2, axis=-1) + EPS
    loc = jnp.round(num / den).astype(jnp.int32) - 1  # ids are 1-based
    return Verdict(error_score=score, flagged=flagged, location=loc,
                   correction=d2)


def apply_correction(y: jax.Array, verdict: Verdict) -> tuple[jax.Array, jax.Array]:
    """Add the correction value back onto the located signals (paper §4.1.2).

    y: (B, N) complex outputs; returns (corrected y, per-group applied mask).
    """
    b = y.shape[0]
    loc = jnp.clip(verdict.location, 0, b - 1)
    applied = verdict.flagged
    upd = jnp.where(applied[:, None], verdict.correction, 0.0)
    y = y.at[loc].add(upd.astype(y.dtype), mode="drop",
                      indices_are_sorted=False, unique_indices=False)
    return y, applied
