"""Two-sided ABFT (paper §4): encoding, detect/locate/correct, baselines."""
from .encoding import left_encoding, left_encoding_image, EPS
from .twoside import GroupChecksums, Verdict, detect_locate, apply_correction
from .oneside import oneside_fft
from .gemm import ft_matmul, ft_dot_stats, decode_columns

__all__ = [
    "left_encoding", "left_encoding_image", "EPS",
    "GroupChecksums", "Verdict", "detect_locate", "apply_correction",
    "oneside_fft", "ft_matmul", "ft_dot_stats", "decode_columns",
]
