"""ABFT encoding vectors and their precomputed DFT-side images.

Left-side checksum (paper §2.2.2): compare ``(e1^T W) x`` with ``e1^T y``.
``e1^T W`` is precomputed once — and since ``(e1^T W)[n] = DFT(e1)[n]``, the
precompute is itself just one FFT of the encoding vector.

Right-side checksums (paper §4.1): ``e2 = 1`` (correction value) and
``e3 = (1, 2, ..., B)`` (location encoding) combine a *batch* of signals.
"""
from __future__ import annotations

import functools

import numpy as np

from repro.core.fft import factors

__all__ = ["left_encoding", "left_encoding_image", "EPS"]

EPS = 1e-30


def left_encoding(n: int, kind: str = "wang") -> np.ndarray:
    """The left encoding vector e1 of length n (applied to outputs)."""
    if kind == "ones":
        return factors.ones_encoding(n)
    if kind == "wang":
        return factors.wang_encoding(n)
    raise ValueError(f"unknown encoding kind {kind!r}")


@functools.lru_cache(maxsize=None)
def left_encoding_image(n: int, kind: str = "wang",
                        inverse: bool = False) -> np.ndarray:
    """``e1^T W`` (applied to inputs): one host-side FFT of e1.

    For the inverse transform W is the (unnormalized) inverse DFT kernel, so
    the image is ifft(e1) * n.
    """
    e1 = left_encoding(n, kind)
    if inverse:
        # kernels compute the *unnormalized* inverse (1/n applied outside),
        # so the image must match: e1^T W_inv = n * ifft(e1).
        return np.fft.ifft(e1) * n
    return np.fft.fft(e1)
