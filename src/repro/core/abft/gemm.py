"""Two-sided ABFT for GEMM — the paper's scheme off the DFT special case.

The paper derives its ABFT from the GEMV view of the DFT (§2.2.2): W is a
*fixed, known* matrix, so the left encoding ``e1^T W`` is free to precompute.
A neural-network linear layer is the same situation — W is the weight matrix,
X the activations. This module protects ``Y = X @ W`` for every dense layer
of the assigned architectures (``models.layers.FTLinear``):

    left  (detect):  s_in  = (X e_rows?) — we use the batch side:
                     per-tile  (e1^T X) W  vs  e1^T Y   over the batch axis,
    right (correct): X (W e2) vs Y e2 — reduction over features gives the
                     correction for a corrupted *row* (token) of Y.

Under SEU, detection costs two rank-1 GEMVs per tile and correction needs no
recomputation — delayed batched correction identical to the FFT case.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .encoding import EPS

__all__ = ["ft_matmul", "ft_dot_stats"]


def _loc_vec(n: int, dtype) -> jax.Array:
    return jnp.arange(1, n + 1, dtype=dtype)


@functools.partial(jax.jit, static_argnames=("threshold", "with_correction"))
def ft_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    threshold: float = 1e-3,
    with_correction: bool = True,
    inject: jax.Array | None = None,
):
    """Checked ``y = x @ w`` for 2-D ``x`` (tokens, d_in) @ (d_in, d_out).

    Returns ``(y, stats)`` where stats is a dict with ``flagged`` (scalar
    count), ``score`` (max divergence), both float32. ``inject`` is an
    optional (3,) array (row, col, eps) adding eps to y[row, col] *after* the
    product — simulating an SEU in the MAC units.

    The checksums ride in float32 regardless of the compute dtype (bf16
    accumulation noise would swamp detection otherwise).
    """
    t, _ = x.shape
    _, d_out = w.shape
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)

    # left: column checksums over the token axis (detect which column group)
    e2x = jnp.sum(xf, axis=0)              # e2^T X   (d_in,)
    e3x = _loc_vec(t, jnp.float32) @ xf    # e3^T X   (d_in,)
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if inject is not None:
        row = inject[0].astype(jnp.int32)
        col = inject[1].astype(jnp.int32)
        y = y.at[row, col].add(inject[2].astype(y.dtype))
    # predicted output checksums (rank-1 GEMVs against the small side)
    p2 = e2x @ wf                          # e2^T X W (d_out,)
    p3 = e3x @ wf
    o2 = jnp.sum(y.astype(jnp.float32), axis=0)
    o3 = _loc_vec(t, jnp.float32) @ y.astype(jnp.float32)
    d2 = p2 - o2                           # == -eps at the corrupted column
    d3 = p3 - o3
    scale = jnp.sqrt(jnp.mean(o2 * o2)) + EPS
    score = jnp.sqrt(jnp.mean(d2 * d2)) / scale
    flagged = score > threshold
    if with_correction:
        num = jnp.sum(d3 * d2)
        den = jnp.sum(d2 * d2) + EPS
        row_hat = jnp.clip(jnp.round(num / den).astype(jnp.int32) - 1, 0, t - 1)
        y = jnp.where(flagged,
                      y.at[row_hat].add(d2.astype(y.dtype)), y)
    stats = {
        "flagged": flagged.astype(jnp.float32),
        "score": score.astype(jnp.float32),
    }
    return y.astype(x.dtype), stats


def ft_dot_stats(stats_tree) -> dict:
    """Aggregate FTLinear stats pytree into run-level counters."""
    leaves = jax.tree_util.tree_leaves(stats_tree)
    if not leaves:
        return {"ft_flagged": jnp.zeros(()), "ft_max_score": jnp.zeros(())}
    flagged = leaves[::2]   # dict key order: 'flagged' < 'score'
    scores = leaves[1::2]
    return {
        "ft_flagged": jnp.sum(jnp.stack([jnp.sum(l) for l in flagged])),
        "ft_max_score": jnp.max(jnp.stack([jnp.max(l) for l in scores])),
    }
