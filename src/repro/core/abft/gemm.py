"""Two-sided ABFT for GEMM — the paper's scheme off the DFT special case.

The paper derives its ABFT from the GEMV view of the DFT (§2.2.2): W is a
*fixed, known* matrix, so the left encoding ``e1^T W`` is free to precompute.
A neural-network linear layer is the same situation — W is the weight matrix,
X the activations. This module protects ``Y = X @ W`` for every dense layer
of the assigned architectures (threaded via ``models.layers.dense`` and the
``core.gemm`` plan family):

    detect:  per-column   (e2^T X) W  vs  e2^T Y   over the token axis,
    locate:  the location checksum e3 = [1..T]: d3/d2 at a corrupted
             column equals (row + 1) — the two-side scheme,
    correct: add d2 back at the decoded (row, column); k concurrent SEUs in
             k distinct columns are corrected in one pass, two faults in the
             SAME column decode as uncorrectable (non-integer ratio).

Under SEU, detection costs two rank-1 GEMVs per tile and correction needs no
recomputation — delayed batched correction identical to the FFT case. The
same decode (:func:`decode_columns`) consumes the fused Pallas kernel's
checksum strips (``kernels.ft_matmul``), so the interpreter path and the
fused path agree on semantics by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .encoding import EPS

__all__ = ["ft_matmul", "ft_dot_stats", "decode_columns"]

# |d3/d2 - round(d3/d2)| above this is a non-integer location decode:
# more than one fault landed in the column (or the checksum row itself was
# hit) — classified uncorrectable rather than mis-corrected.
_LOC_TOL = 0.25


def _loc_vec(n: int, dtype) -> jax.Array:
    return jnp.arange(1, n + 1, dtype=dtype)


def decode_columns(y, d2, d3, scale, *, t: int, threshold: float,
                   with_correction: bool):
    """Two-side per-column decode shared by the interpreter and fused paths.

    ``d2 = pred2 - out2`` (== ``-eps`` at a corrupted column) and ``d3 =
    pred3 - out3`` are the (d_out,) checksum divergences; ``scale`` the
    output-checksum magnitude normalizer. Returns ``(y, stats)`` with
    float32 ``flagged`` (columns over threshold), ``corrected`` (columns
    with a valid single-fault location decode, applied when
    ``with_correction``), ``uncorrectable`` (flagged columns whose decode is
    non-integer or out of range — multi-SEU in one column), and ``score``
    (max per-column divergence, the detection statistic).
    """
    colmag = jnp.abs(d2) / scale
    score = jnp.max(colmag)
    hit = colmag > threshold
    ratio = d3 / jnp.where(jnp.abs(d2) > 0, d2, 1.0)
    row_f = jnp.round(ratio)
    valid = (hit & (jnp.abs(ratio - row_f) < _LOC_TOL)
             & (row_f >= 1) & (row_f <= t))
    if with_correction:
        row_hat = jnp.clip(row_f.astype(jnp.int32) - 1, 0, t - 1)
        upd = jnp.where(valid, d2, 0.0).astype(y.dtype)
        y = y.at[row_hat, jnp.arange(d2.shape[0])].add(upd)
    stats = {
        "flagged": jnp.sum(hit.astype(jnp.float32)),
        "corrected": (jnp.sum(valid.astype(jnp.float32))
                      if with_correction else jnp.zeros((), jnp.float32)),
        "uncorrectable": jnp.sum((hit & ~valid).astype(jnp.float32)),
        "score": score.astype(jnp.float32),
    }
    return y, stats


@functools.partial(jax.jit, static_argnames=("threshold", "with_correction"))
def _ft_matmul_2d(x, w, *, threshold, with_correction, inject=None):
    t, _ = x.shape
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)

    # left-side input checksums over the token axis (rank-1 GEMVs)
    e2x = jnp.sum(xf, axis=0)              # e2^T X   (d_in,)
    e3x = _loc_vec(t, jnp.float32) @ xf    # e3^T X   (d_in,)
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if inject is not None:
        inj = jnp.atleast_2d(inject)       # (F, 3) rows of [row, col, eps]
        rows = inj[:, 0].astype(jnp.int32)
        cols = inj[:, 1].astype(jnp.int32)
        y = y.at[rows, cols].add(inj[:, 2].astype(y.dtype))
    # predicted output checksums vs the computed ones
    p2 = e2x @ wf                          # e2^T X W (d_out,)
    p3 = e3x @ wf
    o2 = jnp.sum(y.astype(jnp.float32), axis=0)
    o3 = _loc_vec(t, jnp.float32) @ y.astype(jnp.float32)
    d2 = p2 - o2                           # == -eps at the corrupted column
    d3 = p3 - o3
    scale = jnp.sqrt(jnp.mean(o2 * o2)) + EPS
    y, stats = decode_columns(y, d2, d3, scale, t=t, threshold=threshold,
                              with_correction=with_correction)
    return y.astype(x.dtype), stats


def ft_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    threshold: float = 1e-3,
    with_correction: bool = True,
    inject: jax.Array | None = None,
):
    """Checked ``y = x @ w``: ``(T, d_in)`` or batched ``(B, T, d_in)``
    activations against a 2-D ``(d_in, d_out)`` weight.

    Returns ``(y, stats)`` — see :func:`decode_columns` for the stats
    contract. ``inject`` is an optional ``(3,)`` array ``[row, col, eps]``
    (or ``(F, 3)`` for concurrent SEUs) adding eps to ``y[row, col]``
    *after* the product — simulating SEUs in the MAC units. On batched
    input the row indexes the flattened ``B * T`` token axis (the layout
    the checksums ride).

    The checksums ride in float32 regardless of the compute dtype (bf16
    accumulation noise would swamp detection otherwise).
    """
    if w.ndim != 2:
        raise ValueError(f"ft_matmul takes a 2-D (d_in, d_out) weight, "
                         f"got w.shape={tuple(w.shape)}")
    if x.ndim == 2:
        return _ft_matmul_2d(x, w, threshold=threshold,
                             with_correction=with_correction, inject=inject)
    if x.ndim == 3:
        b, t, k = x.shape
        y, stats = _ft_matmul_2d(x.reshape(b * t, k), w,
                                 threshold=threshold,
                                 with_correction=with_correction,
                                 inject=inject)
        return y.reshape(b, t, w.shape[-1]), stats
    raise ValueError(
        f"ft_matmul activations must be (T, d_in) or batched (B, T, d_in); "
        f"got rank-{x.ndim} x.shape={tuple(x.shape)} — reshape leading axes "
        f"into one batch dim first")


def ft_dot_stats(stats_tree) -> dict:
    """Aggregate a pytree of per-layer ABFT-GEMM stats dicts into run-level
    counters, traversing by dict KEY (``flagged`` / ``corrected`` /
    ``score``) — robust to arbitrary nesting and to extra keys, unlike
    positional leaf slicing."""
    flagged, corrected, scores = [], [], []
    for path, leaf in jax.tree_util.tree_flatten_with_path(stats_tree)[0]:
        key = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                key = entry.key
                break
        if key == "flagged":
            flagged.append(jnp.sum(leaf))
        elif key == "corrected":
            corrected.append(jnp.sum(leaf))
        elif key == "score":
            scores.append(jnp.max(leaf))
    z = jnp.zeros((), jnp.float32)
    return {
        "ft_flagged": jnp.sum(jnp.stack(flagged)) if flagged else z,
        "ft_corrected": jnp.sum(jnp.stack(corrected)) if corrected else z,
        "ft_max_score": jnp.max(jnp.stack(scores)) if scores else z,
    }
