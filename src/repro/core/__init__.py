"""Core: the paper's contribution — FFT library + two-sided ABFT + FT runtime."""
