"""repro: TurboFFT-on-TPU — fault-tolerant FFT + LM training/serving framework.

FP64 (complex128) support is a first-class paper feature (the paper evaluates
both FP32 and FP64), so x64 is enabled globally. All model code uses explicit
float32/bfloat16 dtypes and is unaffected.
"""
import jax

jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"
