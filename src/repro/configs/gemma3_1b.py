"""Gemma-3 1B [dense]: 26L d=1152 4H (GQA kv=1) ff=6912 V=262144.

5:1 local:global attention, 512-token sliding window, theta 10k local /
1M global [hf:google/gemma-3-1b-pt]
"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    num_layers=26, d_model=1152, num_heads=4, num_kv_heads=1,
    head_dim=256, d_ff=6912, vocab_size=262144,
    block_pattern=("local", "local", "local", "local", "local", "global"),
    window_size=512, rope_theta=1e4, rope_theta_global=1e6,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, name="gemma3-smoke", num_layers=7, d_model=64, num_heads=2,
    num_kv_heads=1, head_dim=32, d_ff=128, vocab_size=512, window_size=16)
