"""The paper's own workload: batched FFT service configurations.

Not an LM — the 'model' is the FFT plan grid the paper benchmarks
(N = 2^3..2^29, batch 1..1024, FP32/FP64) with FT on/off.
"""
import dataclasses
from repro.core.ft import FTPolicy

@dataclasses.dataclass(frozen=True)
class FFTBenchConfig:
    name: str = "turbofft"
    log_n_range: tuple = (3, 25)
    batches: tuple = (1, 8, 64, 256, 1024)
    dtypes: tuple = ("complex64", "complex128")
    ft: FTPolicy = dataclasses.field(default_factory=FTPolicy)

CONFIG = FFTBenchConfig()
SMOKE = FFTBenchConfig(name="turbofft-smoke", log_n_range=(3, 12),
                       batches=(1, 8), dtypes=("complex64",))
