"""DeepSeek-V3 671B [moe]: 61L d=7168 128H MLA, 1 shared + 256 routed top-8
experts (moe_d_ff=2048), first 3 layers dense (ff=18432), V=129280
[arXiv:2412.19437]. MTP head omitted (training-objective add-on; noted in
DESIGN.md).
"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    d_ff=2048, dense_d_ff=18432, vocab_size=129280,
    block_pattern=("mla",),
    q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    num_experts=256, num_shared_experts=1, top_k=8, moe_d_ff=2048,
    first_k_dense=3, rope_theta=1e4,
)

SMOKE = dataclasses.replace(
    CONFIG, name="deepseek-smoke", num_layers=4, d_model=128, num_heads=4,
    num_kv_heads=4, d_ff=64, dense_d_ff=256, vocab_size=512,
    q_lora_rank=48, kv_lora_rank=32, qk_nope_head_dim=16,
    qk_rope_head_dim=8, v_head_dim=16,
    num_experts=8, num_shared_experts=1, top_k=2, moe_d_ff=64,
    first_k_dense=1)
