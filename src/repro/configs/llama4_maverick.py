"""Llama-4 Maverick 400B-A17B [moe]: 48L d=5120 40H (GQA kv=8) ff=8192,
128 routed experts top-1 + shared expert, MoE every other layer,
V=202048 [hf:meta-llama/Llama-4 family]. Text backbone (early-fusion
multimodal frontend out of scope -> dense text path).
"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    num_experts=128, num_shared_experts=1, top_k=1, moe_d_ff=8192,
    moe_interval=2, rope_theta=5e5,
)

SMOKE = dataclasses.replace(
    CONFIG, name="llama4-smoke", num_layers=4, d_model=128, num_heads=8,
    num_kv_heads=2, d_ff=256, vocab_size=512, num_experts=4,
    num_shared_experts=1, top_k=1, moe_d_ff=256)
