"""Qwen1.5-110B [dense]: 80L d=8192 64H (GQA kv=8) ff=49152 V=152064, QKV bias.

[hf:Qwen/Qwen1.5-110B family; structure per hf:Qwen/Qwen1.5-0.5B config]
"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=49152, vocab_size=152064, qkv_bias=True,
    rope_theta=1e6, block_pattern=("attn",),
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen1.5-smoke", num_layers=4, d_model=128, num_heads=8,
    num_kv_heads=2, d_ff=256, vocab_size=512)
