"""Phi-4-mini 3.8B [dense]: 32L d=3072 24H (GQA kv=8) ff=8192 V=200064.

RoPE + SwiGLU + GQA [arXiv:2412.08905]
"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b", family="dense",
    num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=8192, vocab_size=200064, rope_theta=1e4,
)

SMOKE = dataclasses.replace(
    CONFIG, name="phi4-smoke", num_layers=3, d_model=96, num_heads=6,
    num_kv_heads=2, d_ff=192, vocab_size=512)
