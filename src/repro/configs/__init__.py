"""Config registry: one module per assigned architecture + the paper's own
FFT workload. ``get_config(name)`` returns the full ModelConfig;
``get_smoke_config(name)`` returns the reduced same-family config used by CPU
smoke tests.
"""
from __future__ import annotations

import importlib

from .base import (ModelConfig, ParallelConfig, RunConfig, ShapeConfig,
                   SHAPES)

ARCHS = [
    "qwen15_110b",
    "phi3_medium_14b",
    "phi4_mini_3p8b",
    "gemma3_1b",
    "internvl2_1b",
    "xlstm_350m",
    "deepseek_v3_671b",
    "llama4_maverick",
    "recurrentgemma_2b",
    "whisper_base",
]

# canonical ids as assigned (hyphens) -> module names
_ALIASES = {
    "qwen1.5-110b": "qwen15_110b",
    "phi3-medium-14b": "phi3_medium_14b",
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "gemma3-1b": "gemma3_1b",
    "internvl2-1b": "internvl2_1b",
    "xlstm-350m": "xlstm_350m",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "whisper-base": "whisper_base",
    "turbofft": "turbofft_bench",
}


def _module(name: str):
    mod = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).SMOKE


def all_arch_names() -> list[str]:
    return list(ARCHS)


__all__ = ["ModelConfig", "ParallelConfig", "RunConfig", "ShapeConfig",
           "SHAPES", "ARCHS", "get_config", "get_smoke_config",
           "all_arch_names"]
