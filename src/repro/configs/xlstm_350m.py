"""xLSTM-350M [ssm]: 24L d=1024, alternating mLSTM/sLSTM blocks (kv ratio per
assignment header: 4H), no separate FFN (d_ff=0; blocks integrate their own
projections) [arXiv:2405.04517].
"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    num_layers=24, d_model=1024, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_pattern=("mlstm", "slstm"),
    expand_factor=2, conv1d_width=4,
)

SMOKE = dataclasses.replace(
    CONFIG, name="xlstm-smoke", num_layers=4, d_model=64, num_heads=2,
    vocab_size=512)
