"""RecurrentGemma-2B [hybrid]: 26L d=2560 10H (GQA kv=1) ff=7680 V=256000.

Griffin pattern: (RG-LRU, RG-LRU, local-attn) with 2048-token window,
lru_width=2560 [arXiv:2402.19427].
"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
    head_dim=256, d_ff=7680, vocab_size=256000,
    block_pattern=("rglru", "rglru", "local"),
    window_size=2048, conv1d_width=4, lru_width=2560,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, name="recurrentgemma-smoke", num_layers=6, d_model=64,
    num_heads=2, num_kv_heads=1, head_dim=32, d_ff=128, vocab_size=512,
    window_size=16, lru_width=64)
