"""Config schema: model / parallelism / run / shape configs.

One ``ModelConfig`` covers all ten assigned architecture families via the
per-layer ``block_pattern`` (cycled across layers) — dense attention, local
windows, MLA, MoE, RG-LRU, s/mLSTM, enc-dec. ``configs/<arch>.py`` files
instantiate the exact published configurations.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.ft import FTPolicy

__all__ = ["ModelConfig", "ParallelConfig", "ShapeConfig", "RunConfig",
           "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    # per-layer block types, cycled: "attn", "local", "global", "mla",
    # "rglru", "mlstm", "slstm". Empty -> ("attn",) * num_layers.
    block_pattern: tuple[str, ...] = ()
    # attention
    window_size: int = 4096
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rope_theta_global: float = 1e6    # gemma3 uses a larger theta globally
    logit_softcap: float = 0.0
    # MLA (deepseek)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0
    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    dense_d_ff: int = 0               # d_ff of the leading dense layers
    first_k_dense: int = 0            # deepseek: first k layers stay dense
    moe_interval: int = 1             # llama4: MoE every `interval` layers
    capacity_factor: float = 1.25
    # SSM / hybrid
    conv1d_width: int = 4
    lru_width: int = 0                # 0 -> d_model
    expand_factor: int = 2            # mlstm/rglru up-projection
    # enc-dec (whisper)
    encoder_layers: int = 0
    decoder_layers: int = 0
    max_source_positions: int = 1500
    max_target_positions: int = 8192  # learned-pos table (enc-dec decoder)
    # modality frontend stubs
    frontend: str = "none"            # none | patch_stub | audio_stub
    num_patches: int = 256
    frontend_dim: int = 0             # raw embedding dim provided by stub
    # misc
    act: str = "swiglu"               # swiglu | gelu
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"           # activation/compute dtype
    param_dtype: str = "float32"
    # fault tolerance (the paper's technique as a first-class feature)
    ft: FTPolicy = dataclasses.field(default_factory=FTPolicy)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.num_heads, 1))
        if not self.block_pattern:
            object.__setattr__(self, "block_pattern", ("attn",))
        if self.num_experts and not self.moe_d_ff:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)

    # -- derived -----------------------------------------------------------
    def layer_kinds(self) -> tuple[str, ...]:
        """Resolved per-layer block kind for the decoder-only stack."""
        pat = self.block_pattern
        kinds = []
        for i in range(self.num_layers):
            if self.num_experts and self.first_k_dense and i < self.first_k_dense:
                kinds.append(pat[i % len(pat)] + ":dense")
            else:
                kinds.append(pat[i % len(pat)])
        return tuple(kinds)

    def is_moe_layer(self, i: int) -> bool:
        if not self.num_experts:
            return False
        if i < self.first_k_dense:
            return False
        return (i - self.first_k_dense) % self.moe_interval == 0

    @property
    def is_encdec(self) -> bool:
        return self.decoder_layers > 0

    def inactive_expert_params(self) -> int:
        """Params idle per token in MoE layers (for 6*N_active*D FLOPs).

        Exact counts come from ``models.model.count_params`` (eval_shape of
        the real param tree); this analytic adjustment subtracts the routed
        experts not selected by top-k.
        """
        if not self.num_experts:
            return 0
        moe_layers = sum(self.is_moe_layer(i) for i in range(self.num_layers))
        per_expert = 3 * self.d_model * self.moe_d_ff  # swiglu: gate/up/down
        return int(moe_layers * (self.num_experts - self.top_k) * per_expert)


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """Mesh + sharding strategy knobs."""

    multi_pod: bool = False
    fsdp: bool = True                  # shard params over (pod, data)
    seq_shard_decode: bool = True      # SP for decode when batch < data size
    remat: str = "block"               # none | block | full
    microbatch: int = 1                # gradient accumulation steps
    compress_grads: bool = False       # int8 error-feedback all-reduce
    attn_block_q: int = 1024           # query-chunked attention block
    pipeline_stages: int = 1


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                          # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    parallel: ParallelConfig = dataclasses.field(default_factory=ParallelConfig)
    # training
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    seed: int = 0
