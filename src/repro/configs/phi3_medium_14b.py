"""Phi-3-medium 14B [dense]: 40L d=5120 40H (GQA kv=10) ff=17920 V=100352.

RoPE + SwiGLU + GQA [arXiv:2404.14219]
"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=10,
    d_ff=17920, vocab_size=100352, rope_theta=1e4,
)

SMOKE = dataclasses.replace(
    CONFIG, name="phi3-smoke", num_layers=3, d_model=128, num_heads=8,
    num_kv_heads=2, d_ff=256, vocab_size=512)
