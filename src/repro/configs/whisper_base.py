"""Whisper-base [audio]: 6+6 enc-dec, d=512 8H ff=2048 V=51865, GeLU MLP,
LayerNorm, learned positions; conv frontend is a STUB (input_specs provides
precomputed mel-frame embeddings (B, 1500, 80)) [arXiv:2212.04356].
"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51865,
    encoder_layers=6, decoder_layers=6,
    # whisper's architectural decoder max is 448; the assigned shape grid
    # drives the decoder to 32k, so the learned-pos table is sized for the
    # grid (documented in DESIGN.md §Arch-applicability)
    max_target_positions=32768,
    act="gelu", norm="layernorm",
    frontend="audio_stub", frontend_dim=80, max_source_positions=1500,
)

SMOKE = dataclasses.replace(
    CONFIG, name="whisper-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=512, encoder_layers=2,
    decoder_layers=2, max_source_positions=64, max_target_positions=128)
