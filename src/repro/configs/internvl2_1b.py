"""InternVL2-1B [vlm]: InternViT frontend (stub) + 24L Qwen2-0.5B-style LM:
d=896 14H (GQA kv=2) ff=4864 V=151655 [arXiv:2404.16821].

The ViT is a STUB per assignment: input_specs provides precomputed patch
embeddings (B, 256, 1024) fed through a learned projector.
"""
import dataclasses
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151655, rope_theta=1e6, qkv_bias=True,
    frontend="patch_stub", num_patches=256, frontend_dim=1024,
)

SMOKE = dataclasses.replace(
    CONFIG, name="internvl2-smoke", num_layers=3, d_model=112, num_heads=7,
    num_kv_heads=1, d_ff=224, vocab_size=512, num_patches=8, frontend_dim=32)
