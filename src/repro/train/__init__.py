"""Step builders: train / eval / serve / prefill."""
from .loop import (make_train_step, make_eval_step, make_serve_step,
                   make_prefill_step, cross_entropy)

__all__ = ["make_train_step", "make_eval_step", "make_serve_step",
           "make_prefill_step", "cross_entropy"]
