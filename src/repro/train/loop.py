"""Train/eval/serve step builders.

``make_train_step`` returns a pure (params, opt_state, batch, step) ->
(params, opt_state, metrics) function suitable for jit with in/out shardings
— the same function the multi-pod dry-run lowers. Features: f32 CE loss with
z-loss, MoE aux loss, remat, microbatched gradient accumulation, int8
compressed DP all-reduce (optional), fault-aware update skipping and ABFT
telemetry surfaced in metrics.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs.base import RunConfig
from repro.models import Model

__all__ = ["make_train_step", "make_eval_step", "make_serve_step",
           "cross_entropy"]


def cross_entropy(logits, labels, *, z_loss: float = 1e-4):
    """Token-mean CE in f32 with logit z-regularization.

    The label pick uses an iota-match reduction instead of take_along_axis so
    it stays elementwise under a vocab-sharded logits layout (no gather
    across the `model` axis -> no all-gather of the logits).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                         logits.ndim - 1)
    picked = jnp.where(vocab_ids == labels[..., None], logits, 0.0)
    ll = jnp.sum(picked, axis=-1)
    ce = jnp.mean(lse - ll)
    zl = z_loss * jnp.mean(lse ** 2)
    return ce + zl, ce


def _loss_fn(model: Model, params, batch, *, block_q, remat, moe_coef=0.01):
    logits, aux = model.apply(params, batch, block_q=block_q, remat=remat)
    labels = batch["labels"]
    logits = logits[:, -labels.shape[1]:]  # vlm: text-tail loss
    total, ce = cross_entropy(logits, labels)
    total = total + moe_coef * aux["moe_aux"]
    return total, (ce, aux)


def make_train_step(model: Model, run: RunConfig) -> Callable:
    par = run.parallel
    micro = par.microbatch

    def train_step(params, opt_state, batch, step):
        lr = optim.cosine_schedule(
            step, base_lr=run.learning_rate, warmup_steps=run.warmup_steps,
            total_steps=run.total_steps)

        loss = functools.partial(
            _loss_fn, model, block_q=par.attn_block_q,
            remat=par.remat)

        if micro <= 1:
            (total, (ce, aux)), grads = jax.value_and_grad(
                loss, has_aux=True)(params, batch)
        else:
            # gradient accumulation over microbatches (sequential scan)
            def split(x):
                return x.reshape((micro, x.shape[0] // micro) + x.shape[1:])

            mb = jax.tree_util.tree_map(split, batch)

            def acc(carry, b):
                g_acc, t_acc, ce_acc, aux_acc = carry
                (t, (ce, aux)), g = jax.value_and_grad(
                    loss, has_aux=True)(params, b)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                aux_acc = jax.tree_util.tree_map(jnp.add, aux_acc, aux)
                return (g_acc, t_acc + t, ce_acc + ce, aux_acc), None

            zeros_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zaux = {"moe_aux": jnp.zeros((), jnp.float32),
                    "ft_flagged": jnp.zeros((), jnp.float32),
                    "ft_corrected": jnp.zeros((), jnp.float32),
                    "ft_max_score": jnp.zeros((), jnp.float32)}
            (grads, total, ce, aux), _ = jax.lax.scan(
                acc, (zeros_g, jnp.zeros(()), jnp.zeros(()), zaux), mb)
            grads = jax.tree_util.tree_map(lambda g: g / micro, grads)
            total, ce = total / micro, ce / micro

        params, opt_state, info = optim.apply_updates(
            params, grads, opt_state, lr=lr,
            weight_decay=run.weight_decay, grad_clip=run.grad_clip,
            skip_nonfinite=model.cfg.ft.skip_nonfinite_updates)
        metrics = {
            "loss": total, "ce": ce, "lr": lr,
            "grad_norm": info["grad_norm"],
            "skipped_updates": info["skipped"],
            "moe_aux": aux["moe_aux"],
            "ft_flagged": aux["ft_flagged"],
            "ft_corrected": aux["ft_corrected"],
            "ft_max_score": aux["ft_max_score"],
        }
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model, run: RunConfig) -> Callable:
    def eval_step(params, batch):
        total, (ce, aux) = _loss_fn(model, params, batch,
                                    block_q=run.parallel.attn_block_q,
                                    remat=False)
        return {"loss": total, "ce": ce}
    return eval_step


def make_serve_step(model: Model, run: RunConfig, *,
                    greedy: bool = True) -> Callable:
    """One batched decode step: (params, cache, tokens, pos) ->
    (next_tokens, cache, aux)."""

    def serve_step(params, cache, tokens, pos, inject=None):
        logits, cache, aux = model.decode_step(params, cache, tokens, pos,
                                               block_q=0, inject=inject)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache, aux

    return serve_step


def make_prefill_step(model: Model, run: RunConfig) -> Callable:
    """Full-sequence forward for inference-prefill shapes (logits only)."""

    def prefill_step(params, batch):
        logits, aux = model.apply(params, batch,
                                  block_q=run.parallel.attn_block_q)
        return logits[:, -1], aux

    return prefill_step
