"""Optimizer: sharded AdamW + schedules."""
from .adamw import (AdamWState, init_state, apply_updates, cosine_schedule,
                    global_norm)

__all__ = ["AdamWState", "init_state", "apply_updates", "cosine_schedule",
           "global_norm"]
