"""AdamW with FSDP-sharded states, cosine schedule, global-norm clipping and
fault-aware update skipping (non-finite grads are dropped, counted in
FTStats — the fail-continue half of the paper's fault model applied to
training).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["AdamWState", "init_state", "apply_updates", "cosine_schedule",
           "global_norm"]


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init_state(params) -> AdamWState:
    zeros = lambda p: jax.tree_util.tree_map(
        lambda l: jnp.zeros(l.shape, jnp.float32), p)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                      nu=zeros(params))


def cosine_schedule(step, *, base_lr, warmup_steps, total_steps,
                    min_ratio=0.1):
    warm = jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
    prog = jnp.clip((step - warmup_steps) /
                    jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(np.pi * prog))
    return base_lr * warm * cos


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(
    params,
    grads,
    state: AdamWState,
    *,
    lr: jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
    skip_nonfinite: bool = True,
):
    """One AdamW step. Returns (params, state, info dict)."""
    gnorm = global_norm(grads)
    finite = jnp.isfinite(gnorm)
    scale = jnp.where(grad_clip > 0,
                      jnp.minimum(1.0, grad_clip / (gnorm + 1e-12)), 1.0)
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mhat = m2 / c1
        vhat = v2 / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if p.ndim >= 2:  # decoupled decay on matrices only
            delta = delta + weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2, v2

    new = jax.tree_util.tree_map(upd, params, grads, state.mu, state.nu)
    new_p = jax.tree_util.tree_map(lambda t: t[0], new,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], new,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], new,
                                   is_leaf=lambda t: isinstance(t, tuple))

    if skip_nonfinite:
        keep = lambda new_t, old_t: jax.tree_util.tree_map(
            lambda n, o: jnp.where(finite, n, o), new_t, old_t)
        new_p = keep(new_p, params)
        new_m = keep(new_m, state.mu)
        new_v = keep(new_v, state.nu)
        step = jnp.where(finite, step, state.step)

    info = {"grad_norm": gnorm, "lr": lr,
            "skipped": (~finite).astype(jnp.float32)}
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), info
