"""Distribution: sharding rules, compressed collectives, pipeline parallel."""
from .sharding import (dp_axes, param_specs, batch_specs, cache_specs,
                       shard_tree_specs, logical_rules)
from .collectives import compress_allreduce_mean, quantize_int8, dequantize_int8
from .pipeline import pipeline_apply
from .fft_sharding import (fft_mesh_axis, infer_fft_mesh, pencil_specs,
                           shard_signals, data_mesh_axis, abft_group_layout,
                           abft_group_spec, slab_specs, pencil_nd_specs,
                           shard_grid)

__all__ = ["dp_axes", "param_specs", "batch_specs", "cache_specs",
           "shard_tree_specs", "logical_rules", "compress_allreduce_mean",
           "quantize_int8", "dequantize_int8", "pipeline_apply",
           "fft_mesh_axis", "infer_fft_mesh", "pencil_specs",
           "shard_signals", "data_mesh_axis", "abft_group_layout",
           "abft_group_spec", "slab_specs", "pencil_nd_specs", "shard_grid"]
