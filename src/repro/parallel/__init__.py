"""Distribution: sharding rules, compressed collectives, pipeline parallel."""
from .sharding import (dp_axes, param_specs, batch_specs, cache_specs,
                       shard_tree_specs, logical_rules)
from .collectives import compress_allreduce_mean, quantize_int8, dequantize_int8
from .pipeline import pipeline_apply

__all__ = ["dp_axes", "param_specs", "batch_specs", "cache_specs",
           "shard_tree_specs", "logical_rules", "compress_allreduce_mean",
           "quantize_int8", "dequantize_int8", "pipeline_apply"]
