"""Logical-axis sharding rules: DP + FSDP + TP + EP + SP over the production
mesh (data, model) / (pod, data, model).

Params are sharded by *path pattern + shape*: weights put their contraction
feature dim on the FSDP axes (ZeRO-3 over ``(pod, data)``) and their
head/ffn/vocab/expert dim on ``model`` (TP/EP). Scan-stacked leaves carry a
leading layer axis that stays unsharded. Any dim not divisible by its target
axis falls back to replication (e.g. kv_heads=1 for gemma3).
"""
from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["dp_axes", "param_specs", "batch_specs", "cache_specs",
           "shard_tree_specs", "logical_rules", "current_mesh",
           "constrain_logits", "constrain_hidden", "constrain_moe_buffer"]


def current_mesh():
    """The mesh active via ``with mesh:`` during trace, or None."""
    try:
        import jax.interpreters.pxla as pxla
        m = pxla.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def _constrain(x, build_spec):
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = build_spec(mesh)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_logits(x):
    """(B, T, V): batch over DP, vocab over model — keeps the CE working set
    at V/|model| per device (the dominant activation for big-vocab LMs)."""
    def b(mesh):
        dp = dp_axes(mesh)
        bs = dp if _div(x.shape[0], mesh, dp) else None
        vs = "model" if _div(x.shape[-1], mesh, ("model",)) else None
        return P(*([bs] + [None] * (x.ndim - 2) + [vs]))
    return _constrain(x, b)


def constrain_hidden(x):
    """(B, T, D) residual stream: batch over DP, rest replicated."""
    def b(mesh):
        dp = dp_axes(mesh)
        bs = dp if _div(x.shape[0], mesh, dp) else None
        if bs is None and x.ndim >= 2 and _div(x.shape[1], mesh, ("data",)):
            return P(None, "data", *([None] * (x.ndim - 2)))  # SP fallback
        return P(*([bs] + [None] * (x.ndim - 1)))
    return _constrain(x, b)


def constrain_moe_buffer(x):
    """(E, C, D) expert buffer: experts over model (EP), capacity over DP."""
    def b(mesh):
        dp = dp_axes(mesh)
        es = "model" if _div(x.shape[0], mesh, ("model",)) else None
        cs = dp if _div(x.shape[1], mesh, dp) else None
        return P(es, cs, None)
    return _constrain(x, b)


def dp_axes(mesh: Mesh) -> tuple:
    """The data-parallel axes: ('pod', 'data') when multi-pod else ('data',)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _div(n: int, mesh: Mesh, axes) -> bool:
    if isinstance(axes, str):
        axes = (axes,)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return n % size == 0 and n >= size


# ---------------------------------------------------------------------------
# parameter rules: (path regex, rank) -> builder(shape, mesh) -> PartitionSpec
# ---------------------------------------------------------------------------

def _spec_for_param(path: str, shape: tuple[int, ...], mesh: Mesh,
                    fsdp: bool = True) -> P:
    f = dp_axes(mesh) if fsdp else None   # FSDP shard target
    t = "model"

    def ok(dim_size, axes):
        return axes is not None and _div(dim_size, mesh, axes)

    nd = len(shape)
    # scan-stacked leaves: leading layer axis unsharded; recurse on the rest
    stacked = bool(re.search(r"scan/slot\d+", path)) and nd >= 2
    if stacked:
        inner = _spec_for_param(path.replace("scan/", "unstacked/"),
                                shape[1:], mesh, fsdp)
        return P(*((None,) + tuple(inner)))

    if "embedding" in path:
        # (vocab, d_model): vocab on model (TP), d_model on fsdp
        return P(t if ok(shape[0], t) else None,
                 f if ok(shape[1], f) else None)
    if "lm_head" in path:
        return P(f if ok(shape[0], f) else None,
                 t if ok(shape[1], t) else None)
    if re.search(r"moe/(wi_gate|wi_up|wo)", path):
        # (E, d, f): EP over model
        return P(t if ok(shape[0], t) else None,
                 f if ok(shape[1], f) else None, None)
    if "router" in path:
        return P(f if ok(shape[0], f) else None, None)
    if re.search(r"att.*/(wq|wk|wv)$|wq_b|wkv_b|wq$", path) and nd == 2:
        # (d_in, heads*hd): TP on the head dim
        return P(f if ok(shape[0], f) else None,
                 t if ok(shape[1], t) else None)
    if re.search(r"att.*/wo$|/wo$", path) and nd == 2 and "mlp" not in path:
        return P(t if ok(shape[0], t) else None,
                 f if ok(shape[1], f) else None)
    if re.search(r"(wi_gate|wi_up|wi|w_up|w_in_gate|w_in_rec)$", path) \
            and nd == 2:
        return P(f if ok(shape[0], f) else None,
                 t if ok(shape[1], t) else None)
    if re.search(r"(wo|w_down|w_out)$", path) and nd == 2:
        return P(t if ok(shape[0], t) else None,
                 f if ok(shape[1], f) else None)
    if re.search(r"(wq_a|wkv_a)$", path) and nd == 2:
        return P(f if ok(shape[0], f) else None, None)
    if nd == 2:
        # generic matrices (recurrent gates etc.): fsdp on dim0 if divisible
        return P(f if ok(shape[0], f) else None,
                 t if ok(shape[1], t) else None)
    if nd == 3:
        return P(None,
                 f if ok(shape[1], f) else None,
                 t if ok(shape[2], t) else None)
    return P(*([None] * nd))


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(params_shape: Any, mesh: Mesh, fsdp: bool = True):
    """PartitionSpec tree matching a (possibly abstract) param tree."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: _spec_for_param(_path_str(kp), leaf.shape, mesh,
                                         fsdp),
        params_shape)


# ---------------------------------------------------------------------------
# activation / batch / cache rules
# ---------------------------------------------------------------------------

def batch_specs(batch_shape: Any, mesh: Mesh, *, seq_shard: bool = False):
    """Input batch sharding: batch dim over DP axes; optionally seq over
    'data' (SP, for decode shapes with batch < mesh data size)."""
    dp = dp_axes(mesh)

    def spec(kp, leaf):
        nd = len(leaf.shape)
        b = leaf.shape[0]
        bspec = dp if _div(b, mesh, dp) else None
        rest = [None] * (nd - 1)
        if seq_shard and nd >= 2 and bspec is None and \
                _div(leaf.shape[1], mesh, "data"):
            rest[0] = "data"
        return P(*([bspec] + rest))

    return jax.tree_util.tree_map_with_path(spec, batch_shape)


def cache_specs(cache_shape: Any, mesh: Mesh, *, seq_shard: bool = False):
    """KV/state cache sharding.

    Layout conventions (see models/): KV caches are (..., B, S, KH, hd) or
    MLA (..., B, S, r); recurrent states (..., B, W)/(..., B, H, hd, hd).
    Batch goes to DP when divisible; otherwise (long_500k, B=1) the sequence
    axis is sharded over 'data' (sequence parallelism) when possible; head
    axes go to 'model' when divisible.
    """
    dp = dp_axes(mesh)

    def spec(kp, leaf):
        path = _path_str(kp)
        shape = leaf.shape
        nd = len(shape)
        out = [None] * nd
        stacked = 1 if re.search(r"scan/slot\d+", path) else 0
        bi = stacked  # batch index
        seq_axes = []
        if nd > bi and _div(shape[bi], mesh, dp):
            out[bi] = dp
        elif seq_shard and nd > bi + 1 and re.search(r"/(k|v|ckv|kr)$",
                                                     path) \
                and _div(shape[bi + 1], mesh, "data"):
            seq_axes.append("data")
        # KV head axis over model where divisible; otherwise shard the
        # *sequence* axis over model (flash-decoding-style split-K: softmax
        # partials are psum'd by SPMD). KV heads are < 16 for every assigned
        # arch, so seq-over-model is what bounds decode KV per device.
        if re.search(r"/(k|v)$", path) and nd == bi + 4:
            if _div(shape[bi + 2], mesh, ("model",)):
                out[bi + 2] = "model"
            elif _div(shape[bi + 1], mesh, tuple(seq_axes) + ("model",)):
                seq_axes.append("model")
        if re.search(r"/(ckv|kr)$", path) and nd == bi + 3 and \
                _div(shape[bi + 1], mesh, tuple(seq_axes) + ("model",)):
            seq_axes.append("model")  # MLA latent cache: seq over model
        if seq_axes:
            out[bi + 1] = tuple(seq_axes) if len(seq_axes) > 1 else \
                seq_axes[0]
        if re.search(r"/(c|n)$", path) and nd >= bi + 3 and \
                _div(shape[bi + 1], mesh, ("model",)):
            out[bi + 1] = "model"  # mlstm per-head state over model
        return P(*out)

    return jax.tree_util.tree_map_with_path(spec, cache_shape)


def shard_tree_specs(tree, mesh: Mesh):
    """Replicated spec tree (optimizer scalars etc.)."""
    return jax.tree_util.tree_map(lambda l: P(), tree)


def logical_rules(mesh: Mesh) -> dict:
    """Documentation-oriented summary of the rule set (used by DESIGN/tests)."""
    return {
        "batch": dp_axes(mesh),
        "fsdp": dp_axes(mesh),
        "tensor": ("model",),
        "expert": ("model",),
        "seq(SP)": ("data",),
    }
