"""Sharding glue for the distributed FFT (see core/fft/distributed.py).

Keeps the mesh/spec plumbing out of the numerics module: helpers to detect an
FFT-sharded operand (so ``kernels.ops.fft`` can auto-dispatch), to place a
batch of signals into the pencil layout, and the canonical PartitionSpecs of
the pipeline's two resident layouts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.fft.distributed import FFT_AXIS, make_dist_plan

__all__ = ["fft_mesh_axis", "infer_fft_mesh", "pencil_specs",
           "shard_signals"]


def fft_mesh_axis(mesh: Mesh | None, axis: str = FFT_AXIS) -> str | None:
    """The FFT mesh axis name if ``mesh`` carries one (size > 1)."""
    if mesh is None or axis not in getattr(mesh, "axis_names", ()):
        return None
    return axis if mesh.shape[axis] > 1 else None


def infer_fft_mesh(x, axis: str = FFT_AXIS) -> Mesh | None:
    """The mesh to distribute over, inferred from ``x``'s committed sharding.

    Returns the mesh iff ``x`` lives on a NamedSharding whose mesh has a
    non-trivial ``axis`` — the signal that the caller already laid the
    operand out for a sharded transform.
    """
    try:
        sh = getattr(x, "sharding", None)
    except Exception:  # tracers inside jit have no concrete sharding
        return None
    if isinstance(sh, NamedSharding) and fft_mesh_axis(sh.mesh, axis):
        return sh.mesh
    return None


def pencil_specs(axis: str = FFT_AXIS) -> tuple[P, P]:
    """(input, inter-pass) PartitionSpecs of the (B, N1, N2) pencil cube:
    columns (n2) sharded going in, rows (k1) sharded after the all-to-all."""
    return P(None, None, axis), P(None, axis, None)


def shard_signals(x, mesh: Mesh, axis: str = FFT_AXIS):
    """Distribute a (..., N) batch: each device owns a contiguous ``N/D``
    block of the signal axis (1/D of the memory footprint).

    The transform's *pencil* layout (every ``n1`` row's ``n2``-columns on one
    device) is strided in the flat axis and cannot be expressed as a
    NamedSharding of the flat array, so the pipeline re-tiles these blocks
    into pencils when the shard_map binds its input — the ingest relayout of
    the classic block->pencil->pencil distributed FFT. Callers who keep data
    in the (..., N1, N2) cube between transforms can place it with
    ``pencil_specs()[0]`` directly and skip that ingest cost.
    """
    x = jnp.asarray(x)
    make_dist_plan(x.shape[-1], mesh.shape[axis], axis)  # validate sizes
    spec = P(*([None] * (x.ndim - 1) + [axis]))
    return jax.device_put(x, NamedSharding(mesh, spec))
