"""Sharding glue for the distributed FFT (see core/fft/distributed.py).

Keeps the mesh/spec plumbing out of the numerics module: helpers to detect an
FFT-sharded operand (so ``kernels.ops.fft`` can auto-dispatch), to place a
batch of signals into the batch x pencil layout, and the canonical
PartitionSpecs of the pipeline's resident layouts. All helpers understand the
2-D batch x pencil mesh (``make_fft_mesh(shards, data)``): batch dims shard
over ``data`` while the signal pencils shard over ``fft``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.fft.distributed import (DATA_AXIS, FFT_AXIS, make_dist_plan,
                                        resolve_abft_groups)

__all__ = ["fft_mesh_axis", "infer_fft_mesh", "pencil_specs",
           "shard_signals", "data_mesh_axis", "abft_group_layout",
           "abft_group_spec"]


def fft_mesh_axis(mesh: Mesh | None, axis: str = FFT_AXIS) -> str | None:
    """The FFT mesh axis name if ``mesh`` carries one (size > 1)."""
    if mesh is None or axis not in getattr(mesh, "axis_names", ()):
        return None
    return axis if mesh.shape[axis] > 1 else None


def data_mesh_axis(mesh: Mesh | None, axis: str = DATA_AXIS) -> str | None:
    """The batch (data) mesh axis name if ``mesh`` carries one (size > 1)."""
    if mesh is None or axis not in getattr(mesh, "axis_names", ()):
        return None
    return axis if mesh.shape[axis] > 1 else None


def abft_group_layout(mesh: Mesh | None, batch: int, *,
                      groups: int | None = None,
                      group_size: int | None = None,
                      data_axis: str = DATA_AXIS) -> tuple[int, int]:
    """Resolve the grouped-ABFT layout for ``batch`` signals on ``mesh``.

    Returns ``(G, S)`` — the checksum group count and the signals per group
    — after validating against the mesh's data axis: on a 2-D batch x pencil
    mesh every group must live wholly inside one data shard (``data | G``),
    which is what lets the ft path shard the batch instead of replicating
    it. The same resolution runs inside ``ft_distributed_fft``; callers
    (serve, benchmarks) use this to size telemetry up front.
    """
    d = data_mesh_axis(mesh, data_axis)
    dsize = mesh.shape[d] if d else 1
    g = resolve_abft_groups(batch, groups=groups, group_size=group_size,
                            data_shards=dsize)
    return g, batch // g


def abft_group_spec(mesh: Mesh | None, data_axis: str = DATA_AXIS) -> P:
    """PartitionSpec of per-group ABFT telemetry arrays (leading dim G).

    Groups shard over the data axis exactly like the batch rows they
    checksum — each data shard owns its groups' verdicts outright.
    """
    return P(data_mesh_axis(mesh, data_axis))


def infer_fft_mesh(x, axis: str = FFT_AXIS) -> Mesh | None:
    """The mesh to distribute over, inferred from ``x``'s committed sharding.

    Returns the mesh iff ``x`` lives on a NamedSharding whose mesh has a
    non-trivial ``axis`` — the signal that the caller already laid the
    operand out for a sharded transform.
    """
    try:
        sh = getattr(x, "sharding", None)
    except Exception:  # tracers inside jit have no concrete sharding
        return None
    if isinstance(sh, NamedSharding) and fft_mesh_axis(sh.mesh, axis):
        return sh.mesh
    return None


def pencil_specs(axis: str = FFT_AXIS,
                 data_axis: str | None = None) -> tuple[P, P]:
    """(input, inter-pass) PartitionSpecs of the (B, N1, N2) pencil cube:
    columns (n2) sharded going in, rows (k1) sharded after the all-to-all.
    With ``data_axis`` the batch dim shards over it as well (the 2-D
    batch x pencil layout)."""
    return (P(data_axis, None, axis), P(data_axis, axis, None))


def shard_signals(x, mesh: Mesh, axis: str = FFT_AXIS,
                  data_axis: str | None = DATA_AXIS):
    """Distribute a (..., N) batch: each device owns a contiguous block of
    the signal axis (1/D of the memory footprint), and — when the mesh has a
    non-trivial ``data_axis`` that divides the leading dim — a slice of the
    batch too, so a (data x fft) mesh holds 1/(data*fft) per device.

    The transform's *pencil* layout (every ``n1`` row's ``n2``-columns on one
    device) is strided in the flat axis and cannot be expressed as a
    NamedSharding of the flat array, so the pipeline re-tiles these blocks
    into pencils when the shard_map binds its input — the ingest relayout of
    the classic block->pencil->pencil distributed FFT. Callers who keep data
    in the (..., N1, N2) cube between transforms can place it with
    ``pencil_specs()[0]`` directly and skip that ingest cost.
    """
    x = jnp.asarray(x)
    make_dist_plan(x.shape[-1], mesh.shape[axis], axis)  # validate sizes
    daxis = data_mesh_axis(mesh, data_axis) if data_axis else None
    if daxis is not None and (x.ndim < 2 or x.shape[0] % mesh.shape[daxis]):
        daxis = None   # ragged / missing batch dim: replicate it instead
    spec = P(*([daxis] + [None] * (x.ndim - 2) + [axis] if x.ndim > 1
               else [axis]))
    return jax.device_put(x, NamedSharding(mesh, spec))
