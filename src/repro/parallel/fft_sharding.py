"""Sharding glue for the distributed FFT (see core/fft/distributed.py).

Keeps the mesh/spec plumbing out of the numerics module: helpers to detect an
FFT-sharded operand (so ``kernels.ops.fft`` can auto-dispatch), to place a
batch of signals into the batch x pencil layout, and the canonical
PartitionSpecs of the pipeline's resident layouts. All helpers understand the
2-D batch x pencil mesh (``make_fft_mesh(shards, data)``): batch dims shard
over ``data`` while the signal pencils shard over ``fft``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.fft.distributed import (DATA_AXIS, FFT_AXIS, make_dist_plan,
                                        resolve_abft_groups, resolve_chunks)

__all__ = ["fft_mesh_axis", "infer_fft_mesh", "pencil_specs",
           "shard_signals", "data_mesh_axis", "abft_group_layout",
           "abft_group_spec", "chunk_layout", "slab_specs",
           "pencil_nd_specs", "shard_grid", "layout_specs",
           "half_spectrum_shape"]


def fft_mesh_axis(mesh: Mesh | None, axis: str = FFT_AXIS) -> str | None:
    """The FFT mesh axis name if ``mesh`` carries one (size > 1)."""
    if mesh is None or axis not in getattr(mesh, "axis_names", ()):
        return None
    return axis if mesh.shape[axis] > 1 else None


def data_mesh_axis(mesh: Mesh | None, axis: str = DATA_AXIS) -> str | None:
    """The batch (data) mesh axis name if ``mesh`` carries one (size > 1)."""
    if mesh is None or axis not in getattr(mesh, "axis_names", ()):
        return None
    return axis if mesh.shape[axis] > 1 else None


def abft_group_layout(mesh: Mesh | None, batch: int, *,
                      groups: int | None = None,
                      group_size: int | None = None,
                      data_axis: str = DATA_AXIS) -> tuple[int, int]:
    """Resolve the grouped-ABFT layout for ``batch`` signals on ``mesh``.

    Returns ``(G, S)`` — the checksum group count and the signals per group
    — after validating against the mesh's data axis: on a 2-D batch x pencil
    mesh every group must live wholly inside one data shard (``data | G``),
    which is what lets the ft path shard the batch instead of replicating
    it. The same resolution runs inside ``ft_distributed_fft``; callers
    (serve, benchmarks) use this to size telemetry up front.
    """
    d = data_mesh_axis(mesh, data_axis)
    dsize = mesh.shape[d] if d else 1
    g = resolve_abft_groups(batch, groups=groups, group_size=group_size,
                            data_shards=dsize)
    return g, batch // g


def chunk_layout(mesh: Mesh | None, batch: int, chunks: int, *,
                 groups: int | None = None,
                 data_axis: str = DATA_AXIS) -> tuple[int, int]:
    """Resolve the multi-transaction layout for ``batch`` signals on
    ``mesh``: how many chunked transactions the pipeline will actually run
    and how many per-device rows each carries.

    Returns ``(C, rows_per_transaction)``. Mirrors the resolution inside
    the chunked pipelines (``resolve_chunks`` over the per-device row
    count): the batch rows resident on one data shard split into ``C``
    contiguous transactions — whole checksum groups when ``groups`` is set
    (the ft path chunks group-wise so every transaction keeps its own
    verdict psum). Callers (serve, benchmarks) use this to size overlap
    telemetry up front, like :func:`abft_group_layout` does for ABFT.
    """
    d = data_mesh_axis(mesh, data_axis)
    dsize = mesh.shape[d] if d else 1
    if dsize > 1 and batch % dsize:
        dsize = 1                      # indivisible batch replicates
    rows = (groups if groups is not None else batch) // dsize
    if groups is not None and (groups % dsize or batch % groups):
        raise ValueError(
            f"groups={groups} must divide batch={batch} and spread over "
            f"data={dsize} — resolve with abft_group_layout first")
    c = resolve_chunks(rows, max(1, int(chunks))) if rows else 1
    per = (rows // c) * (batch // groups if groups is not None else 1)
    return c, per


def abft_group_spec(mesh: Mesh | None, data_axis: str = DATA_AXIS) -> P:
    """PartitionSpec of per-group ABFT telemetry arrays (leading dim G).

    Groups shard over the data axis exactly like the batch rows they
    checksum — each data shard owns its groups' verdicts outright.
    """
    return P(data_mesh_axis(mesh, data_axis))


def infer_fft_mesh(x, axis: str = FFT_AXIS) -> Mesh | None:
    """The mesh to distribute over, inferred from ``x``'s committed sharding.

    Returns the mesh iff ``x`` lives on a NamedSharding whose mesh has a
    non-trivial ``axis`` — the signal that the caller already laid the
    operand out for a sharded transform.
    """
    try:
        sh = getattr(x, "sharding", None)
    except Exception:  # tracers inside jit have no concrete sharding
        return None
    if isinstance(sh, NamedSharding) and fft_mesh_axis(sh.mesh, axis):
        return sh.mesh
    return None


def pencil_specs(axis: str = FFT_AXIS,
                 data_axis: str | None = None) -> tuple[P, P]:
    """(input, inter-pass) PartitionSpecs of the (B, N1, N2) pencil cube:
    columns (n2) sharded going in, rows (k1) sharded after the all-to-all.
    With ``data_axis`` the batch dim shards over it as well (the 2-D
    batch x pencil layout)."""
    return (P(data_axis, None, axis), P(data_axis, axis, None))


def slab_specs(ndim: int = 2, axis: str = FFT_AXIS,
               data_axis: str | None = None) -> tuple[P, P]:
    """(input, output) PartitionSpecs of the slab n-D transform
    (``core.fft.multidim``, ``decomp="slab"``): the FIRST transform axis
    block-sharded going in, the LAST coming out (the inter-axis transpose
    moves the sharding across the grid), batch over ``data_axis``. Both
    are true array-axis shardings — slab's natural order costs nothing.
    """
    if ndim < 2 or ndim > 3:
        raise ValueError(f"ndim must be 2 or 3, got {ndim}")
    mid = [None] * (ndim - 1)
    return (P(data_axis, axis, *mid), P(data_axis, *mid, axis))


def pencil_nd_specs(ndim: int = 2, axis: str = FFT_AXIS,
                    data_axis: str | None = DATA_AXIS) -> tuple[P, P]:
    """(input, transposed-output) PartitionSpecs of the pencil n-D cube
    ``(B, lead.., r1, r2, c1, c2)`` (``core.fft.multidim``,
    ``decomp="pencil"``): fast digits (r2, c2) sharded over
    (``data_axis``, ``axis``) going in, slow digits (r1, c1) coming out in
    transposed digit order — the data axis is spent on the second
    transform axis, so a single grid scales over the whole 2-D mesh.
    """
    if ndim < 2 or ndim > 3:
        raise ValueError(f"ndim must be 2 or 3, got {ndim}")
    lead = [None] * (ndim - 2)
    return (P(None, *lead, None, data_axis, None, axis),
            P(None, *lead, data_axis, None, axis, None))


def half_spectrum_shape(shape: tuple[int, ...]) -> tuple[int, ...]:
    """The Hermitian half-spectrum shape of a real grid: the last axis
    folds to ``n//2 + 1`` bins (``rfft``/``rfft2`` output), every other
    axis is unchanged."""
    if not shape:
        raise ValueError("half_spectrum_shape needs a non-empty shape")
    return tuple(shape[:-1]) + (shape[-1] // 2 + 1,)


def layout_specs(rank: int, decomp: str, *, axis: str = FFT_AXIS,
                 data_axis: str | None = None, real: bool = False
                 ) -> tuple[P, P]:
    """(input, output) PartitionSpecs of one planned transform's resident
    layouts — the single entry point ``core.fft.api.FFTPlan`` resolves its
    specs through. Rank 1 is always the pencil digit split
    (:func:`pencil_specs`); rank >= 2 dispatches on the resolved ``decomp``
    (:func:`slab_specs` / :func:`pencil_nd_specs`).

    ``real=True`` (rank-2 slab only) describes the half-spectrum pipeline:
    the AXIS placements are the C2C slab's (real rows in over ``axis``,
    spectrum columns out over ``axis``), but the output array they apply to
    is the :func:`half_spectrum_shape` of the input — only the ``C/2 + 1``
    surviving column bins are resident.
    """
    if rank == 1:
        return pencil_specs(axis, data_axis)
    if real:
        if rank != 2 or decomp != "slab":
            raise ValueError(
                f"the real half-spectrum layout is the rank-2 slab "
                f"(rfft2); got rank={rank}, decomp={decomp!r}")
        return slab_specs(rank, axis, data_axis)
    if decomp == "slab":
        return slab_specs(rank, axis, data_axis)
    if decomp == "pencil":
        return pencil_nd_specs(rank, axis, data_axis)
    raise ValueError(f"decomp must be slab|pencil for rank {rank}, "
                     f"got {decomp!r}")


def shard_grid(x, mesh: Mesh, ndim: int = 2, *, decomp: str = "slab",
               axis: str = FFT_AXIS, data_axis: str | None = DATA_AXIS):
    """Distribute a (..., grid) batch of n-D grids for the multidim
    transform: contiguous blocks of the first (slab) or last two (pencil)
    transform axes, batch dims over ``data_axis`` when they divide.

    The slab placement matches the pipeline's resident layout exactly; the
    pencil pipeline wants *fast digits* sharded, which is strided in the
    flat axes, so (as with 1-D ``shard_signals``) the block placement here
    is re-tiled once when the shard_map binds its input.
    """
    x = jnp.asarray(x)
    if x.ndim < ndim:
        raise ValueError(f"input rank {x.ndim} < ndim={ndim}")
    nlead = x.ndim - ndim
    daxis = data_mesh_axis(mesh, data_axis) if data_axis else None
    if decomp == "slab":
        bspec = daxis if (daxis and nlead >= 1
                          and x.shape[0] % mesh.shape[daxis] == 0) else None
        spec = ([bspec] + [None] * (nlead - 1) if nlead
                else []) + [axis] + [None] * (ndim - 1)
    elif decomp == "pencil":
        gspec = [None] * (ndim - 2) + [
            daxis if (daxis and x.shape[-2] % mesh.shape[daxis] == 0)
            else None, axis]
        spec = [None] * nlead + gspec
    else:
        raise ValueError(f"decomp must be slab|pencil, got {decomp!r}")
    return jax.device_put(x, NamedSharding(mesh, P(*spec)))


def shard_signals(x, mesh: Mesh, axis: str = FFT_AXIS,
                  data_axis: str | None = DATA_AXIS):
    """Distribute a (..., N) batch: each device owns a contiguous block of
    the signal axis (1/D of the memory footprint), and — when the mesh has a
    non-trivial ``data_axis`` that divides the leading dim — a slice of the
    batch too, so a (data x fft) mesh holds 1/(data*fft) per device.

    The transform's *pencil* layout (every ``n1`` row's ``n2``-columns on one
    device) is strided in the flat axis and cannot be expressed as a
    NamedSharding of the flat array, so the pipeline re-tiles these blocks
    into pencils when the shard_map binds its input — the ingest relayout of
    the classic block->pencil->pencil distributed FFT. Callers who keep data
    in the (..., N1, N2) cube between transforms can place it with
    ``pencil_specs()[0]`` directly and skip that ingest cost.
    """
    x = jnp.asarray(x)
    make_dist_plan(x.shape[-1], mesh.shape[axis], axis)  # validate sizes
    daxis = data_mesh_axis(mesh, data_axis) if data_axis else None
    if daxis is not None and (x.ndim < 2 or x.shape[0] % mesh.shape[daxis]):
        daxis = None   # ragged / missing batch dim: replicate it instead
    spec = P(*([daxis] + [None] * (x.ndim - 2) + [axis] if x.ndim > 1
               else [axis]))
    return jax.device_put(x, NamedSharding(mesh, spec))
