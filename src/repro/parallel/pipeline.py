"""GPipe-style pipeline parallelism over a 'stage' mesh axis.

For meshes with a ``stage`` axis, a scanned layer stack is split into S
contiguous stages; microbatches stream through with ``collective_permute``
hops between neighbours. This is the PP leg of the parallelism suite —
optional (the production dry-run mesh uses DP x TP; PP is exercised by
tests/test_pipeline.py on a small mesh) but required posture at 1000+ nodes
where a single TP domain cannot span the cluster.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = ["pipeline_apply"]


def pipeline_apply(
    fn_stage: Callable,           # (stage_params, x) -> x
    stage_params,                 # leaves stacked along leading `stage` axis
    x: jax.Array,                 # (num_micro, micro_batch, ...) inputs
    mesh: Mesh,
    axis: str = "stage",
):
    """Run ``fn_stage`` as an S-stage GPipe pipeline over microbatches.

    x[m] is microbatch m; returns the stacked outputs. The schedule runs
    S + M - 1 ticks; each tick every stage processes one slot then passes it
    right (collective_permute), overlapping compute and communication.
    """
    s = mesh.shape[axis]
    m = x.shape[0]

    def per_stage(params, xs):
        stage = jax.lax.axis_index(axis)
        # strip the sharded leading stage axis from the params shard
        params = jax.tree_util.tree_map(lambda a: a[0], params)
        xs = xs[0]  # the replicated microbatch stack
        ticks = s + m - 1
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any remain); others use buf
            take = jnp.clip(t, 0, m - 1)
            inject = jax.lax.dynamic_index_in_dim(xs, take, keepdims=False)
            cur = jnp.where(stage == 0, jnp.where(t < m, inject, buf * 0),
                            buf)
            y = fn_stage(params, cur)
            # last stage emits microbatch (t - s + 1)
            emit_idx = jnp.clip(t - s + 1, 0, m - 1)
            emit = (stage == s - 1) & (t >= s - 1)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, emit_idx, axis=0),
                lambda o: o, outs)
            # pass activations rightward
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % s) for i in range(s)])
            return nxt, outs

        buf, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        return outs[None]

    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P(None)),
        out_specs=P(axis),
        check_rep=False)
    # output of every stage slot; the real result lives on the last stage —
    # slice it out (stage-major leading axis of size s)
    out_all = fn(stage_params, x[None])
    return out_all[-1]
