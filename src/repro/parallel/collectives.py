"""Distributed-optimization collectives.

* int8 error-feedback gradient compression: quantize per-leaf to int8 with a
  per-leaf scale before the DP all-reduce, carry the quantization residual —
  cuts the collective term of the roofline by ~4x for fp32 grads (measured in
  EXPERIMENTS.md §Perf).
* mean-across-DP helper used by the microbatched train loop.

Implemented with ``shard_map`` over the DP axes so the compressed payload is
what actually crosses the ICI links (checked in the lowered HLO by
tests/test_collectives.py).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = ["compress_allreduce_mean", "quantize_int8", "dequantize_int8"]


def quantize_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def compress_allreduce_mean(grads: Any, residual: Any, mesh: Mesh,
                            axes: tuple[str, ...]):
    """int8-quantized gradient mean over the DP ``axes`` with error feedback.

    Leaves of ``grads``/``residual`` carry a leading replica axis sharded
    over ``axes`` (each device holds its local gradient). Protocol:
    (1) pmax of |g| -> one global scale, (2) quantize locally to int8,
    (3) psum the quantized payload in int16 (wire = 2B/elem vs 4B f32; a
    production kernel accumulates int8 wire into int32 — int16 here bounds
    ranks <= 256), (4) dequantize + mean; residual carries the quantization
    error to the next step (error feedback). Returns (mean, new_residual)
    with the mean replicated along the replica axis.
    """
    n_ranks = int(np.prod([mesh.shape[a] for a in axes]))
    if n_ranks > 256:
        raise ValueError(f"int16 accumulation bounds the reduction to 256 "
                         f"ranks, got {n_ranks} over axes {axes}")

    def one(g, r):
        def reduce_fn(gl, rl):
            gl = gl.astype(jnp.float32) + rl
            gmax = jax.lax.pmax(jnp.max(jnp.abs(gl)), axes)
            scale = gmax / 127.0 + 1e-12
            q = jnp.clip(jnp.round(gl / scale), -127, 127)
            new_r = gl - q * scale
            summed = jax.lax.psum(q.astype(jnp.int16), axes)
            mean = summed.astype(jnp.float32) * scale / n_ranks
            return mean, new_r

        spec = P(axes)
        fn = shard_map(reduce_fn, mesh=mesh, in_specs=(spec, spec),
                       out_specs=(spec, spec), check_rep=False)
        mean, new_r = fn(g, r)
        return mean.astype(g.dtype), new_r

    flat_g, tree = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    mean = jax.tree_util.tree_unflatten(tree, [o[0] for o in out])
    new_res = jax.tree_util.tree_unflatten(tree, [o[1] for o in out])
    return mean, new_res
