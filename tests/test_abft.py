"""ABFT math + FT runtime: one-sided baseline, ABFT-GEMM, bit-flip model.

Shared rng / complex-batch helpers come from conftest.py (``rng`` / ``crand``
fixtures); the hypothesis property tests live in test_properties.py so this
module collects without optional packages.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import abft
from repro.core.ft import injection


# ---------------------------------------------------------------------------
# one-sided (offline) baseline
# ---------------------------------------------------------------------------

def test_oneside_clean(crand):
    x = crand(8, 256)
    y, flags, nre = abft.oneside_fft(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y), np.fft.fft(x), atol=1e-4)
    assert int(nre) == 0


def test_oneside_detects_and_recomputes(crand):
    x = crand(8, 256)

    def corrupt(y):
        return y.at[3, 17].add(100.0 + 50j)

    y, flags, nre = abft.oneside_fft(jnp.asarray(x), corrupt=corrupt)
    assert int(nre) == 1 and bool(np.asarray(flags)[3])
    # time-redundant recompute restored the result
    np.testing.assert_allclose(np.asarray(y), np.fft.fft(x), atol=1e-4)


# ---------------------------------------------------------------------------
# ABFT GEMM (the paper's scheme on the LM layers)
# ---------------------------------------------------------------------------

def test_ft_matmul_clean(rng):
    x = rng.standard_normal((64, 32)).astype(np.float32)
    w = rng.standard_normal((32, 48)).astype(np.float32)
    y, stats = abft.ft_matmul(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(y), x @ w, rtol=1e-5, atol=1e-4)
    assert float(stats["flagged"]) == 0.0


def test_ft_matmul_detects_and_corrects(rng):
    x = rng.standard_normal((64, 32)).astype(np.float32)
    w = rng.standard_normal((32, 48)).astype(np.float32)
    inj = jnp.asarray([13.0, 7.0, 500.0])  # row 13, col 7, eps 500
    y, stats = abft.ft_matmul(jnp.asarray(x), jnp.asarray(w), inject=inj)
    assert float(stats["flagged"]) == 1.0
    np.testing.assert_allclose(np.asarray(y), x @ w, rtol=0,
                               atol=1e-2 * np.abs(x @ w).max())


def test_ft_matmul_bf16_compute_f32_checksums(rng):
    x = rng.standard_normal((32, 64)).astype(np.float32)
    w = rng.standard_normal((64, 64)).astype(np.float32)
    xb = jnp.asarray(x, dtype=jnp.bfloat16)
    wb = jnp.asarray(w, dtype=jnp.bfloat16)
    y, stats = abft.ft_matmul(xb, wb, threshold=5e-2)
    assert float(stats["flagged"]) == 0.0  # bf16 noise below threshold


# ---------------------------------------------------------------------------
# bit-flip SEU model
# ---------------------------------------------------------------------------

def test_flip_bit_roundtrip_f32(rng):
    x = rng.standard_normal((4, 4)).astype(np.float32)
    y = injection.flip_bit(x, (1, 2), 30)
    assert y[1, 2] != x[1, 2]
    z = injection.flip_bit(y, (1, 2), 30)
    np.testing.assert_array_equal(z, x)  # involution


def test_flip_bit_complex(crand):
    x = crand(2, 4)
    y = injection.flip_bit(x, (0, 1), 40)  # imag-part bit
    assert y[0, 1].imag != x[0, 1].imag
    assert y[0, 1].real == x[0, 1].real


def test_random_flip_eps_consistent(rng, crand):
    x = crand(4, 16)
    y, (flat, bit), eps = injection.random_flip(rng, x)
    idx = np.unravel_index(flat, x.shape)
    np.testing.assert_allclose(complex(y[idx]) - complex(x[idx]), eps)


def test_poisson_schedule_deterministic():
    rng = np.random.default_rng(0)
    s = injection.poisson_schedule(rng, steps=100, rate_per_step=0.3,
                                   tiles=4, bs=8, n=256)
    assert 10 < s.num_faults < 60
    step0 = s.entries[0][0]
    d = s.for_step(step0)
    assert float(d[3]) == 1.0
    d_off = s.for_step(-1)
    assert float(d_off[3]) == 0.0
