"""Coverage for the fault-model surface: ``core.ft.injection`` (bit flips,
deterministic and Poisson fault schedules) and ``core.ft.policy`` (knob
plumbing + detection-threshold edge semantics). These modules previously had
no dedicated test file.
"""
import dataclasses
import inspect

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.ft import (FaultSchedule, FTPolicy, flip_bit,
                           poisson_schedule, random_flip)


# ---------------------------------------------------------------------------
# bit-flip SEU model (paper §5.3.1)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [np.float32, np.float64,
                                   np.complex64, np.complex128])
def test_flip_bit_is_involutive(dtype, rng):
    """Flipping the same bit twice restores the exact original pattern."""
    x = rng.standard_normal(8).astype(dtype)
    if np.iscomplexobj(x):
        x = (x + 1j * rng.standard_normal(8)).astype(dtype)
    nbits = {np.dtype(np.float32): 32, np.dtype(np.float64): 64,
             np.dtype(np.complex64): 64, np.dtype(np.complex128): 128}[
        np.dtype(dtype)]
    for bit in (0, nbits // 2 - 1, nbits - 1):
        y = flip_bit(x, (3,), bit)
        assert y[3:4].tobytes() != x[3:4].tobytes()
        z = flip_bit(y, (3,), bit)
        assert z.tobytes() == x.tobytes()  # exact bit-pattern restoration
        # every other element is untouched
        mask = np.arange(8) != 3
        np.testing.assert_array_equal(y[mask], x[mask])


def test_flip_bit_targets_real_and_imag_parts():
    x = np.ones(2, np.complex64)
    lo = flip_bit(x, (0,), 10)      # bit < 32: real representation
    hi = flip_bit(x, (0,), 32 + 10)  # bit >= 32: imag representation
    assert lo[0].real != 1.0 and lo[0].imag == 0.0
    assert hi[0].real == 1.0 and hi[0].imag != 0.0
    # sign bit of the real part negates it exactly
    neg = flip_bit(x, (1,), 31)
    assert neg[1] == -1.0 + 0.0j


def test_flip_bit_rejects_unsupported_dtype():
    with pytest.raises(TypeError):
        flip_bit(np.ones(2, np.int32), (0,), 3)


def test_random_flip_eps_consistency(rng):
    """The returned eps is exactly corrupted - original at the flip site."""
    x = (rng.standard_normal(16) + 1j * rng.standard_normal(16)
         ).astype(np.complex64)
    y, (flat, bit), eps = random_flip(rng, x.copy())
    idx = np.unravel_index(flat, x.shape)
    got = complex(y[idx]) - complex(x[idx])
    if np.isfinite(got):
        assert got == eps
    else:  # exponent-bit flips legitimately produce inf/nan
        assert not np.isfinite(eps)
    mask = np.arange(16) != flat
    np.testing.assert_array_equal(y[mask], x[mask])


def test_random_flip_is_seed_deterministic():
    x = np.ones(32, np.float32)
    a = random_flip(np.random.default_rng(42), x.copy())
    b = random_flip(np.random.default_rng(42), x.copy())
    assert a[1] == b[1] and np.array_equal(a[0], b[0])


# ---------------------------------------------------------------------------
# fault schedules
# ---------------------------------------------------------------------------


def test_fault_schedule_for_step():
    sched = FaultSchedule(entries=((3, 1, 5, 200, 60.0, -25.0),
                                   (7, 0, 2, 17, -8.0, 4.0)))
    assert sched.num_faults == 2
    hit = np.asarray(sched.for_step(3))
    np.testing.assert_allclose(hit, [1, 5, 200, 1, 60.0, -25.0])
    np.testing.assert_allclose(np.asarray(sched.for_step(7)),
                               [0, 2, 17, 1, -8.0, 4.0])
    # a step with no scheduled fault yields a disabled descriptor
    miss = np.asarray(sched.for_step(4))
    assert miss[3] == 0.0
    np.testing.assert_allclose(miss, np.zeros(6))


def test_poisson_schedule_deterministic_and_in_range():
    kw = dict(steps=200, rate_per_step=0.3, tiles=4, bs=8, n=256)
    s1 = poisson_schedule(np.random.default_rng(5), **kw)
    s2 = poisson_schedule(np.random.default_rng(5), **kw)
    assert s1.entries == s2.entries              # same seed, same schedule
    assert 0 < s1.num_faults < 200
    steps = [e[0] for e in s1.entries]
    assert steps == sorted(steps) and len(set(steps)) == len(steps)
    for (step, tile, row, col, er, ei) in s1.entries:
        assert 0 <= step < 200 and 0 <= tile < 4
        assert 0 <= row < 8 and 0 <= col < 256
    # zero rate -> empty schedule
    empty = poisson_schedule(np.random.default_rng(0), steps=50,
                             rate_per_step=0.0, tiles=4, bs=8, n=256)
    assert empty.num_faults == 0


# ---------------------------------------------------------------------------
# policy: knob plumbing + threshold edge
# ---------------------------------------------------------------------------


def test_ftpolicy_config_matches_consumer_signatures():
    """kernel_kwargs stays in sync with the local kernel call site, and
    to_ft_config() carries every policy knob into the plan API's FTConfig
    (a renamed knob would otherwise fail only at serve time)."""
    from repro.core.fft.api import FFTSpec, FTConfig
    from repro.kernels.ops import ft_fft

    pol = FTPolicy(mesh_groups=8, group_size=None,
                   recompute_uncorrectable=False)
    kernel_params = set(inspect.signature(ft_fft).parameters)
    assert set(pol.kernel_kwargs()) <= kernel_params
    cfg = pol.to_ft_config()
    assert isinstance(cfg, FTConfig)
    assert cfg.groups == 8 and cfg.group_size is None
    assert cfg.recompute_uncorrectable is False
    assert cfg.threshold == pol.threshold
    assert cfg.transactions == pol.transactions
    assert cfg.encoding == pol.encoding
    # the config is spec-embeddable (hashable, valid) as-is
    spec = FFTSpec(shape=(16, 256), ft=cfg)
    assert hash(spec) == hash(FFTSpec(shape=(16, 256), ft=pol.to_ft_config()))
    # frozen: policies are config values, not mutable state
    with pytest.raises(dataclasses.FrozenInstanceError):
        pol.threshold = 1.0


def test_detect_threshold_edge_is_strict():
    """Detection fires strictly ABOVE the threshold: a residual sitting at
    exactly the configured value must NOT flag (the ROC operating point
    counts it as noise), while any value below the score does."""
    from repro.core import abft

    n = 16
    t = 0.25  # exactly representable; sqrt(t*t) == t in fp64
    cs2_out = jnp.ones((1, n), jnp.complex128)   # scale == 1 exactly
    cs2_in = cs2_out + t                         # d2 == t everywhere
    cs3 = jnp.zeros((1, n), jnp.complex128)
    cs = abft.GroupChecksums(cs2_in=cs2_in, cs3_in=cs3,
                             cs2_out=cs2_out, cs3_out=cs3)
    ident = lambda c: c
    at = abft.detect_locate(cs, forward=ident, threshold=t)
    assert float(at.error_score[0]) == t         # engineered exact score
    assert not bool(at.flagged[0])               # score > t is strict
    below = abft.detect_locate(cs, forward=ident, threshold=t * (1 - 1e-12))
    assert bool(below.flagged[0])
    above = abft.detect_locate(cs, forward=ident, threshold=t * (1 + 1e-12))
    assert not bool(above.flagged[0])


def test_mesh_threshold_edge_matches_policy(crand):
    """The sharded path keeps the same strict-inequality semantics: a clean
    run scores far under any sane threshold, and setting the threshold to
    the exact observed score of an injected fault un-flags it while any
    smaller threshold flags it — i.e. the knob is a true ROC dial."""
    import jax

    from repro.core.fft.distributed import ft_distributed_fft

    mesh = jax.make_mesh((1,), ("fft",))
    x = crand(8, 256)
    inj = jnp.asarray([[0, 5, 3, 7, 1, 60.0, -25.0]], jnp.float32)
    res = ft_distributed_fft(x, mesh, groups=4, inject=inj)
    score = float(jnp.max(res.group_score))
    assert bool(res.flagged[2])
    at = ft_distributed_fft(x, mesh, groups=4, inject=inj, threshold=score)
    assert not bool(at.flagged.any())            # strict: score > threshold
    under = ft_distributed_fft(x, mesh, groups=4, inject=inj,
                               threshold=score * 0.99)
    assert bool(under.flagged[2])
