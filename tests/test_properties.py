"""Property-based FT tests (hypothesis). The whole module skips cleanly when
hypothesis is not installed — the deterministic versions of these contracts
live in test_abft.py / test_kernels.py, so collection never depends on an
optional package.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import assume, given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.core import abft  # noqa: E402
from repro.kernels import ops  # noqa: E402


# hypothesis: ft_matmul detects any sufficiently large injected error
@settings(max_examples=15, deadline=None)
@given(row=st.integers(0, 63), col=st.integers(0, 47),
       eps=st.floats(min_value=50.0, max_value=1e4))
def test_property_ft_matmul_detects(row, col, eps):
    rng = np.random.default_rng(row * 100 + col)
    x = rng.standard_normal((64, 32)).astype(np.float32)
    w = rng.standard_normal((32, 48)).astype(np.float32)
    y, stats = abft.ft_matmul(jnp.asarray(x), jnp.asarray(w),
                              inject=jnp.asarray([row, col, eps]))
    assert float(stats["flagged"]) == 1.0
    np.testing.assert_allclose(np.asarray(y), x @ w, rtol=0,
                               atol=2e-2 * np.abs(x @ w).max())


# hypothesis: the grouped sharded ABFT is a pure observer — ANY group count
# G dividing B leaves the transform output bitwise identical (the checksum
# rows ride alongside the data; they never touch its compute), and clean
# runs never flag at any G
@settings(max_examples=12, deadline=None)
@given(g=st.sampled_from([1, 2, 4, 8]), ln=st.integers(8, 10),
       seed=st.integers(0, 2 ** 16))
def test_property_group_count_invariance(g, ln, seed):
    import jax

    from repro.core.fft.distributed import ft_distributed_fft

    mesh = jax.make_mesh((1,), ("fft",))
    rng = np.random.default_rng(seed)
    b, n = 8, 1 << ln
    x = (rng.standard_normal((b, n)) + 1j * rng.standard_normal((b, n))
         ).astype(np.complex64)
    base = ft_distributed_fft(x, mesh, groups=1)
    res = ft_distributed_fft(x, mesh, groups=g)
    assert not bool(res.flagged.any()), np.asarray(res.group_score)
    assert np.array_equal(np.asarray(base.y), np.asarray(res.y))


# hypothesis: the chunked multi-transaction pipeline is an execution
# schedule, not a numerical change — ANY chunk count C (feasible or not:
# infeasible requests clamp) leaves the transform output bitwise identical
# to the bulk pipeline, in both digit orders, and the chunked grouped-ABFT
# path flags nothing on clean inputs
@settings(max_examples=12, deadline=None)
@given(c=st.integers(1, 8), ln=st.integers(8, 10),
       natural=st.booleans(), seed=st.integers(0, 2 ** 16))
def test_property_chunk_count_invariance(c, ln, natural, seed):
    import jax

    from repro.core.fft.distributed import (distributed_fft,
                                            ft_distributed_fft)

    mesh = jax.make_mesh((1,), ("fft",))
    rng = np.random.default_rng(seed)
    b, n = 8, 1 << ln
    x = (rng.standard_normal((b, n)) + 1j * rng.standard_normal((b, n))
         ).astype(np.complex64)
    base = distributed_fft(x, mesh, natural_order=natural)
    y = distributed_fft(x, mesh, natural_order=natural, chunks=c)
    assert np.array_equal(np.asarray(base), np.asarray(y))
    ft_base = ft_distributed_fft(x, mesh, groups=4)
    ft_res = ft_distributed_fft(x, mesh, groups=4, chunks=c)
    assert not bool(ft_res.flagged.any()), np.asarray(ft_res.group_score)
    assert np.array_equal(np.asarray(ft_base.y), np.asarray(ft_res.y))


# hypothesis: inject -> detect -> correct round trip. Any single SEU above
# the noise floor lands in exactly one group, decodes correctable at the
# right global signal, and the corrected output matches the fault-free run
# to checksum-roundoff; a disabled injection is bitwise-invisible
@settings(max_examples=15, deadline=None)
@given(
    g=st.sampled_from([1, 2, 4]),
    sig=st.integers(0, 7),
    row=st.integers(0, 15),
    col=st.integers(0, 15),
    eps_r=st.floats(-200, 200),
    eps_i=st.floats(-200, 200),
)
def test_property_injection_roundtrip(g, sig, row, col, eps_r, eps_i):
    assume(abs(eps_r) + abs(eps_i) > 5.0)  # above noise floor
    import jax

    from repro.core.fft.distributed import ft_distributed_fft

    mesh = jax.make_mesh((1,), ("fft",))
    rng = np.random.default_rng(sig * 256 + row * 16 + col)
    b, n = 8, 256
    x = (rng.standard_normal((b, n)) + 1j * rng.standard_normal((b, n))
         ).astype(np.complex64)
    clean = ft_distributed_fft(x, mesh, groups=g)
    off = jnp.asarray([[0, sig, row, col, 0, eps_r, eps_i]], jnp.float32)
    disabled = ft_distributed_fft(x, mesh, groups=g, inject=off)
    assert np.array_equal(np.asarray(clean.y), np.asarray(disabled.y))

    inj = jnp.asarray([[0, sig, row, col, 1, eps_r, eps_i]], jnp.float32)
    res = ft_distributed_fft(x, mesh, groups=g, inject=inj)
    grp = sig // (b // g)
    assert bool(res.flagged[grp]) and bool(res.correctable[grp])
    assert int(res.location[grp]) == sig
    assert int(res.corrected) == 1
    ref = np.asarray(clean.y)
    np.testing.assert_allclose(np.asarray(res.y), ref, rtol=0,
                               atol=1e-4 * np.abs(ref).max())


# hypothesis: any injected FFT error above the noise floor is detected,
# located, and corrected by the fused two-sided ABFT kernel
@settings(max_examples=20, deadline=None)
@given(
    tile=st.integers(0, 3),
    row=st.integers(0, 7),
    col=st.integers(0, 255),
    eps_r=st.floats(-200, 200),
    eps_i=st.floats(-200, 200),
    txn=st.sampled_from([1, 2, 4]),
)
def test_property_seu_detect_correct(tile, row, col, eps_r, eps_i, txn):
    assume(abs(eps_r) + abs(eps_i) > 5.0)  # above noise floor
    b, n, bs = 32, 256, 8
    rng = np.random.default_rng(tile * 1000 + row * 100 + col)
    x = (rng.standard_normal((b, n)) + 1j * rng.standard_normal((b, n))
         ).astype(np.complex64)
    want = np.fft.fft(x)
    inj = jnp.asarray([tile, row, col, 1, eps_r, eps_i], dtype=jnp.float32)
    res = ops.ft_fft(x, transactions=txn, bs=bs, inject=inj)
    sig = tile * bs + row
    flagged = np.asarray(res.flagged)
    assert flagged.sum() == 1
    assert np.asarray(res.location)[int(np.argmax(flagged))] == sig
    np.testing.assert_allclose(np.asarray(res.y), want,
                               atol=1e-4 * np.abs(want).max())


# hypothesis: the real plan round trip is exact to dtype roundoff and
# matches jnp.fft.rfft2/irfft2 for any power-of-two grid, both precisions
@settings(max_examples=12, deadline=None)
@given(lr=st.integers(3, 6), lc=st.integers(3, 7),
       f64=st.booleans(), seed=st.integers(0, 2 ** 16))
def test_property_rfft2_matches_jnp_and_roundtrips(lr, lc, f64, seed):
    from repro.core.fft.api import plan, spec_for

    rng = np.random.default_rng(seed)
    dt, tol = (np.float64, 1e-11) if f64 else (np.float32, 4e-5)
    x = rng.standard_normal((2, 1 << lr, 1 << lc)).astype(dt)
    p = plan(spec_for(x, rank=2, real=True))
    y = np.asarray(p.rfft2(x))
    want = np.asarray(jnp.fft.rfft2(x))
    assert y.shape == want.shape
    assert np.abs(y - want).max() < tol * np.abs(want).max()
    back = np.asarray(p.irfft2(jnp.asarray(y)))
    assert back.dtype == dt
    assert np.abs(back - x).max() < tol * np.abs(x).max()
    # re-running the identical plan is deterministic bit-for-bit
    assert np.array_equal(np.asarray(p.rfft2(x)), y)


# hypothesis: Parseval on the half spectrum — sum |x|^2 = (sum of the
# doubled interior bins + the DC/Nyquist bins) / N, for any even length
@settings(max_examples=15, deadline=None)
@given(ln=st.integers(4, 12), seed=st.integers(0, 2 ** 16))
def test_property_rfft_parseval_half_spectrum(ln, seed):
    from repro.core.fft.extensions import rfft

    rng = np.random.default_rng(seed)
    n = 1 << ln
    x = rng.standard_normal((3, n)).astype(np.float32)
    y = np.asarray(rfft(jnp.asarray(x)))
    w = np.full(n // 2 + 1, 2.0)
    w[0] = w[-1] = 1.0          # DC and Nyquist appear once in the full FFT
    lhs = np.sum(np.abs(x) ** 2, axis=-1)
    rhs = np.sum(w * np.abs(y) ** 2, axis=-1) / n
    np.testing.assert_allclose(lhs, rhs, rtol=2e-4)
