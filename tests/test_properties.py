"""Property-based FT tests (hypothesis). The whole module skips cleanly when
hypothesis is not installed — the deterministic versions of these contracts
live in test_abft.py / test_kernels.py, so collection never depends on an
optional package.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import assume, given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.core import abft  # noqa: E402
from repro.kernels import ops  # noqa: E402


# hypothesis: ft_matmul detects any sufficiently large injected error
@settings(max_examples=15, deadline=None)
@given(row=st.integers(0, 63), col=st.integers(0, 47),
       eps=st.floats(min_value=50.0, max_value=1e4))
def test_property_ft_matmul_detects(row, col, eps):
    rng = np.random.default_rng(row * 100 + col)
    x = rng.standard_normal((64, 32)).astype(np.float32)
    w = rng.standard_normal((32, 48)).astype(np.float32)
    y, stats = abft.ft_matmul(jnp.asarray(x), jnp.asarray(w),
                              inject=jnp.asarray([row, col, eps]))
    assert float(stats["flagged"]) == 1.0
    np.testing.assert_allclose(np.asarray(y), x @ w, rtol=0,
                               atol=2e-2 * np.abs(x @ w).max())


# hypothesis: any injected FFT error above the noise floor is detected,
# located, and corrected by the fused two-sided ABFT kernel
@settings(max_examples=20, deadline=None)
@given(
    tile=st.integers(0, 3),
    row=st.integers(0, 7),
    col=st.integers(0, 255),
    eps_r=st.floats(-200, 200),
    eps_i=st.floats(-200, 200),
    txn=st.sampled_from([1, 2, 4]),
)
def test_property_seu_detect_correct(tile, row, col, eps_r, eps_i, txn):
    assume(abs(eps_r) + abs(eps_i) > 5.0)  # above noise floor
    b, n, bs = 32, 256, 8
    rng = np.random.default_rng(tile * 1000 + row * 100 + col)
    x = (rng.standard_normal((b, n)) + 1j * rng.standard_normal((b, n))
         ).astype(np.complex64)
    want = np.fft.fft(x)
    inj = jnp.asarray([tile, row, col, 1, eps_r, eps_i], dtype=jnp.float32)
    res = ops.ft_fft(x, transactions=txn, bs=bs, inject=inj)
    sig = tile * bs + row
    flagged = np.asarray(res.flagged)
    assert flagged.sum() == 1
    assert np.asarray(res.location)[int(np.argmax(flagged))] == sig
    np.testing.assert_allclose(np.asarray(res.y), want,
                               atol=1e-4 * np.abs(want).max())
