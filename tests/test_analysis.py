"""Static-analysis layer: the HLO parser, the plan auditor, and the repo
lint (``repro.analysis`` — what ``python -m repro.analysis --strict``
gates CI on).

Three layers under test:

* ``analysis.hlo`` — the promoted op-classifying parser (stdlib-only):
  per-op kind/shape/bytes, async start/done dedupe, root signatures, and
  the legacy ``collective_bytes`` summary shape the dry-run still exposes;
* ``analysis.audit`` — the generated spec lattice is deterministic and
  covers every registered plan family; the auditor passes on the tree and
  HARD-FAILS when a volume model is broken under it (the acceptance
  demonstration: monkeypatch the model, watch the sweep catch it);
* ``analysis.lint`` — per-rule positive/negative fixtures on a synthetic
  tree, ``# noqa`` suppression, baseline round-trip (strict-on-new), and
  regression tests for every L003 site fixed to raise ValueError.
"""
from __future__ import annotations

import pathlib
import textwrap
import types

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import audit, hlo, lint

# ---------------------------------------------------------------------------
# hlo parser
# ---------------------------------------------------------------------------

_SAMPLE_HLO = textwrap.dedent("""\
    HloModule jit_fn, entry_computation_layout={(c64[8,4096]{1,0})->(c64[8,4096]{1,0}, f32[], pred[])}

    ENTRY main.42 (p0.1: c64[8,4096]) -> (c64[8,4096], f32[], pred[]) {
      %p0.1 = c64[8,4096]{1,0} parameter(0)
      %all-to-all-start = ((c64[8,4096]{1,0}), (c64[8,4096]{1,0})) all-to-all-start(%p0.1), replica_groups={{0,1,2,3}}
      %all-to-all-done = c64[8,4096]{1,0} all-to-all-done(%all-to-all-start)
      %ar = f32[3]{0} all-reduce(%bits), to_apply=%add
      %flag = pred[] all-reduce(%b0), to_apply=%or
      ROOT %t = (c64[8,4096]{1,0}, f32[], pred[]) tuple(%all-to-all-done, %s, %f)
    }
    """)


def test_hlo_parser_ops_and_async_dedupe():
    ops = hlo.parse_collectives(_SAMPLE_HLO)
    kinds = [o.kind for o in ops]
    assert kinds == ["all-to-all", "all-reduce", "all-reduce"]
    a2a = ops[0]
    # the async start tuple holds (operand, result): dedupe keeps ONE half
    assert a2a.is_async
    assert a2a.payload_bytes == 8 * 4096 * 8
    assert a2a.wire_bytes == a2a.payload_bytes  # factor 1.0 for a2a
    assert a2a.dtypes == ("c64",)
    ar = ops[1]
    assert ar.payload_bytes == 3 * 4
    assert ar.wire_bytes == 2.0 * 3 * 4  # ring factor for all-reduce
    assert ops[2].dtypes == ("pred",)


def test_hlo_root_signature():
    assert hlo.root_signature(_SAMPLE_HLO) == ("c64", "f32", "pred")
    assert hlo.root_signature("no entry line here") == ()


def test_hlo_summarize_legacy_shape():
    s = hlo.summarize(hlo.parse_collectives(_SAMPLE_HLO))
    assert set(s) == {"bytes", "count", "ops", "total_bytes"}
    assert s["count"]["all-to-all"] == 1
    assert s["count"]["all-reduce"] == 2
    assert s["total_bytes"] == pytest.approx(
        8 * 4096 * 8 + 2.0 * (3 * 4 + 1))


def test_dryrun_collective_bytes_is_compat_wrapper():
    """The dry-run's parser surface (what PR auditors and test_moe_ep
    import) now delegates to analysis.hlo with identical results."""
    from repro.launch import dryrun

    assert dryrun.collective_bytes(_SAMPLE_HLO) == hlo.summarize(
        hlo.parse_collectives(_SAMPLE_HLO))
    assert dryrun.COLLECTIVE_RE is hlo.COLLECTIVE_RE


# ---------------------------------------------------------------------------
# plan auditor
# ---------------------------------------------------------------------------

def _needs4():
    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 host devices (the CI mesh-8dev lane sets "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def test_lattice_deterministic():
    a, b = audit.lattice(), audit.lattice()
    assert [repr(s) for s in a] == [repr(s) for s in b]
    assert len(a) >= 10  # locals + gemm even on one device


def test_lattice_covers_every_registered_plan_family():
    """Every spec type in the shared plan registry appears in the audited
    lattice — a new plan family cannot ship un-audited."""
    from repro.core import plan as planbase

    covered = {type(s) for s in audit.lattice()}
    assert covered == set(planbase._PLAN_TYPES)


def test_audit_local_and_gemm_plans_single_device():
    """The device-independent lattice slice (local FFT + GEMM plans)
    audits clean anywhere — collective-free programs, exact flop model."""
    from repro.core.fft.api import FFTSpec
    from repro.core.gemm.api import GEMMSpec
    from repro.core.plan import FTConfig

    specs = [FFTSpec(shape=(8, 256)),
             GEMMSpec(shape=(64, 32, 48), backend="xla"),
             GEMMSpec(shape=(64, 32, 48), ft=FTConfig(), backend="xla")]
    rep = audit.audit_specs(specs, strict=True)
    assert rep.specs == 3 and not rep.findings


def test_check_cell_flags_missing_collective():
    """A model that promises collectives a program does not have (or vice
    versa) is a hard failure, not a warning."""
    fn = jax.jit(lambda x: x + 1)
    x = jax.ShapeDtypeStruct((8, 64), jnp.complex64)
    bad_model = {"all_to_all_count": 1, "all_gather_count": 0,
                 "hlo_bytes": 4096.0}
    with pytest.raises(audit.AuditError) as ei:
        audit.check_cell(fn, (x,), bad_model, tag="t")
    checks = {f.check for f in ei.value.findings}
    assert "all-to-all-count" in checks
    # and a local plan contract: any collective at all is a finding
    rep = audit.check_cell(fn, (x,), None, tag="t2", strict=False)
    assert not rep.findings


def test_check_cell_flags_root_dtype_downcast():
    fn = jax.jit(lambda x: jnp.abs(x).astype(jnp.float32))
    x = jax.ShapeDtypeStruct((8,), jnp.float64)
    with pytest.raises(audit.AuditError) as ei:
        audit.check_cell(fn, (x,), None, tag="t", dtype="float64")
    assert {f.check for f in ei.value.findings} == {"root-dtype"}


def test_audit_catches_broken_volume_model(monkeypatch):
    """THE acceptance demonstration: corrupt the analytic model the plan
    layer builds volumes from, clear the plan cache, and the sweep must
    fail the spec — the auditor is what stands between a silent model
    drift and CI."""
    _needs4()
    from repro.core.fft import api as fft_api
    from repro.core.fft.api import FFTSpec
    from repro.core.plan import plan_cache_clear

    real = fft_api.collective_volume

    def broken(*a, **kw):
        out = dict(real(*a, **kw))
        out["hlo_bytes"] *= 2          # model now claims double the bytes
        out["all_to_all_bytes"] *= 2
        return out

    monkeypatch.setattr(fft_api, "collective_volume", broken)
    plan_cache_clear()
    try:
        mesh = jax.make_mesh((2,), ("fft",))
        spec = FFTSpec(shape=(8, 256), mesh=mesh)
        with pytest.raises(audit.AuditError) as ei:
            audit.audit_specs([spec], strict=True)
        checks = {f.check for f in ei.value.findings}
        assert checks & {"all-to-all-bytes", "total-bytes"}
    finally:
        plan_cache_clear()             # drop plans built on the broken model


def test_audit_full_lattice_sweep():
    """The CI gate itself: the whole generated lattice lowers and matches
    the analytic models with zero findings (mesh-8dev lane)."""
    _needs4()
    rep = audit.run_audit(strict=True)
    assert rep.specs >= 60
    assert len(rep.cells) >= rep.specs
    assert not rep.findings
    fams = rep.by_family()
    assert {"fft1d", "fft2d", "fftr1d", "fftr2d", "gemm"} <= set(fams)


# ---------------------------------------------------------------------------
# repo lint
# ---------------------------------------------------------------------------

def _tree(tmp_path, files: dict) -> pathlib.Path:
    for rel, body in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(body))
    return tmp_path


def _rules(findings):
    return [f.rule for f in findings]


def test_lint_l001_deprecated_kwargs(tmp_path):
    root = _tree(tmp_path, {"src/repro/x.py": """\
        from repro.kernels import ops
        from repro.kernels.ops import fft as kfft

        def f(x, mesh):
            a = ops.fft(x, mesh=mesh)            # positive: aliased module
            b = kfft(x, natural_order=False)     # positive: aliased entry
            c = ops.fft(x, bs=4)                 # negative: live kwarg
            d = ops.fft(x, mesh=mesh)  # noqa: L001
            return a, b, c, d
    """})
    fs = lint.lint_tree(root)
    assert _rules(fs) == ["L001", "L001"]
    assert fs[0].line == 5 and fs[1].line == 6


def test_lint_l002_raw_fft_scoped_to_core(tmp_path):
    body = """\
        import jax.numpy as jnp

        def f(x):
            return jnp.fft.fft(x)
    """
    root = _tree(tmp_path, {
        "src/repro/kernels/a.py": body,       # positive
        "src/repro/core/fft/b.py": body,      # negative: core/fft owns it
        "benchmarks/c.py": body,              # negative: out of L002 scope
    })
    fs = lint.lint_tree(root)
    assert _rules(fs) == ["L002"]
    assert fs[0].path == "src/repro/kernels/a.py"


def test_lint_l003_param_asserts(tmp_path):
    root = _tree(tmp_path, {"src/repro/v.py": """\
        def f(n, shards):
            assert n % shards == 0       # positive: validates params
            local = n // shards
            assert local * shards == n   # positive: n is a param
            m = local + 1
            assert m > 0                 # negative: locals only
            return m
    """})
    assert _rules(lint.lint_tree(root)) == ["L003", "L003"]


def test_lint_l004_serve_plan_lock(tmp_path):
    root = _tree(tmp_path, {"src/repro/serve/runtime.py": """\
        from repro.serve.specs import serve_plan

        class R:
            def run(self, plan, xb):
                if plan.sharded:
                    with self._mesh_lock:
                        serve_plan(plan, xb)     # ok: under the lock
                else:
                    serve_plan(plan, xb)         # ok: unsharded branch
                serve_plan(plan, xb)             # positive: bare dispatch
    """})
    fs = lint.lint_tree(root)
    assert _rules(fs) == ["L004"]
    assert fs[0].line == 10


def test_lint_l005_frozen_setattr(tmp_path):
    root = _tree(tmp_path, {"src/repro/s.py": """\
        class S:
            def __post_init__(self):
                object.__setattr__(self, "a", 1)   # ok

            def mutate(self):
                object.__setattr__(self, "a", 2)   # positive
    """})
    fs = lint.lint_tree(root)
    assert _rules(fs) == ["L005"]
    assert "mutate" in fs[0].message


def test_lint_real_tree_has_no_unbaselined_findings():
    """Acceptance: zero NEW lint findings in this repo — everything else
    was either fixed (L003) or explicitly grandfathered (the reference
    kernel's jnp.fft usage)."""
    new, old = lint.split_baseline(lint.lint_tree(), lint.load_baseline())
    assert new == []
    assert all(f.rule == "L002" for f in old)


def test_lint_baseline_roundtrip(tmp_path):
    root = _tree(tmp_path, {"src/repro/v.py": """\
        def f(n):
            assert n > 0
    """})
    fs = lint.lint_tree(root)
    assert _rules(fs) == ["L003"]
    base = tmp_path / "baseline.txt"
    lint.save_baseline(fs, base)
    loaded = lint.load_baseline(base)
    assert loaded == {f.fingerprint for f in fs}
    new, old = lint.split_baseline(fs, loaded)
    assert new == [] and old == fs
    # fingerprints are line-number-free: prepending code must not
    # resurrect a grandfathered finding
    p = root / "src/repro/v.py"
    p.write_text("import os\n\n\n" + p.read_text())
    new, old = lint.split_baseline(lint.lint_tree(root), loaded)
    assert new == [] and len(old) == 1


# ---------------------------------------------------------------------------
# the L003 fixes: every converted site raises ValueError with the value
# ---------------------------------------------------------------------------

def test_make_batch_rejects_indivisible_sharding():
    from repro.data.synthetic import make_batch

    with pytest.raises(ValueError, match="batch=7.*num_shards=2"):
        make_batch(0, 0, batch=7, seq_len=8, vocab_size=32, num_shards=2)


def test_make_dist_plan_rejects_unsplittable_n():
    from repro.core.fft.distributed import make_dist_plan

    # n=8 over 4 shards: both pencil factors must divide by 4 -> 4x4=16 != 8
    with pytest.raises(ValueError, match="N=8 too small for a 4-way"):
        make_dist_plan(8, 4)
    with pytest.raises(ValueError, match="power of two, got 5"):
        make_dist_plan(256, 5)


def test_fft_with_plan_rejects_multipass():
    from repro.core.fft.plan import make_plan
    from repro.core.fft.stockham import fft_with_plan

    plan = make_plan(1 << 22)  # beyond one VMEM pass
    assert plan.num_passes > 1
    with pytest.raises(ValueError, match="single-pass"):
        fft_with_plan(jnp.zeros((1, 1 << 22), jnp.complex64), plan)


def test_fft_large_rejects_wrong_plan():
    from repro.core.fft.large import fft_large
    from repro.core.fft.plan import make_plan

    with pytest.raises(ValueError, match="n=512"):
        fft_large(jnp.zeros((1, 256), jnp.complex64), make_plan(512))


def test_block_fft_pallas_rejects_bad_tile():
    from repro.kernels.stockham import block_fft_pallas

    xr = jnp.zeros((8, 64), jnp.float32)
    with pytest.raises(ValueError, match="bs=5"):
        block_fft_pallas(xr, xr, bs=5)


def test_abft_fft_pallas_rejects_bad_transactions():
    from repro.kernels.stockham_abft import abft_fft_pallas

    xr = jnp.zeros((8, 64), jnp.float32)
    with pytest.raises(ValueError, match="transactions=3"):
        abft_fft_pallas(xr, xr, bs=2, transactions=3)


def test_compress_allreduce_rejects_too_many_ranks():
    from repro.parallel.collectives import compress_allreduce_mean

    fake_mesh = types.SimpleNamespace(shape={"dp": 512})
    with pytest.raises(ValueError, match="512"):
        compress_allreduce_mean({}, {}, fake_mesh, ("dp",))


def test_deep_asserts_keep_internal_invariants():
    """The L003 pass converts PARAMETER validation only: purely internal
    invariant asserts (locals derived inside the function) stay asserts
    — the linter must not flag the surviving ones in this repo."""
    findings = [f for f in lint.lint_tree() if f.rule == "L003"]
    assert findings == []
