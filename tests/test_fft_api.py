"""The cuFFT-style plan/execute API (core.fft.api): FFTSpec validation and
hashability, the LRU plan cache (same spec -> same plan object, ZERO
executor retraces), bitwise identity of the plan executors against the
legacy kwarg paths across {1-D, 2-D slab, 2-D pencil} x {plain, ft,
transposed} x {c64, c128} on 1-D and 2-D host meshes, the deprecation
shims on kernels.ops, rfft/irfft mesh routing, and FTPolicy.to_ft_config.

Multi-device cases run in-process on >= 4 forced host devices (the CI fast
lane and mesh-8dev lane both force them).
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.fft import api
from repro.core.fft.api import FFTSpec, FTConfig, plan


def _mesh1():
    return jax.make_mesh((4,), ("fft",))


def _mesh2():
    return jax.make_mesh((2, 2), ("data", "fft"))


def _need(n):
    if len(jax.devices()) < n:
        pytest.skip(f"needs >= {n} host devices")


# ---------------------------------------------------------------------------
# spec validation + hashability
# ---------------------------------------------------------------------------


def test_spec_is_hashable_value_object():
    s1 = FFTSpec(shape=(8, 1024), ft=FTConfig(groups=4))
    s2 = FFTSpec(shape=(8, 1024), ft=FTConfig(groups=4))
    assert s1 == s2 and hash(s1) == hash(s2)
    assert {s1: "a"}[s2] == "a"
    assert s1 != dataclasses.replace(s1, dtype="complex128")
    assert s1 != dataclasses.replace(s1, ft=FTConfig(groups=2))
    # canonicalization: dtype objects and list shapes normalize
    s3 = FFTSpec(shape=[8, 1024], dtype=jnp.complex64,
                 ft=FTConfig(groups=4))
    assert s3 == s1


def test_spec_validation_messages():
    with pytest.raises(ValueError, match="positive sizes"):
        FFTSpec(shape=())
    with pytest.raises(ValueError, match="complex"):
        FFTSpec(shape=(8, 64), dtype="float32")
    with pytest.raises(ValueError, match="rank"):
        FFTSpec(shape=(8, 64), rank=4)
    with pytest.raises(ValueError, match="fewer axes"):
        FFTSpec(shape=(64,), rank=2)
    with pytest.raises(ValueError, match="multi-dimensional knob"):
        FFTSpec(shape=(8, 64), decomp="slab")
    with pytest.raises(ValueError, match="decomp"):
        FFTSpec(shape=(8, 64, 64), rank=2, decomp="cube")
    with pytest.raises(ValueError, match="FTConfig"):
        FFTSpec(shape=(8, 64), ft={"groups": 4})
    with pytest.raises(TypeError, match="FFTSpec"):
        plan({"shape": (8, 64)})


def test_spec_bad_axis_names():
    _need(2)
    mesh = jax.make_mesh((2,), ("model",))
    with pytest.raises(ValueError, match="'fft' .*model"):
        FFTSpec(shape=(8, 64), mesh=mesh)
    mesh_f = jax.make_mesh((2,), ("fft",))
    with pytest.raises(ValueError, match="data_axis 'rows'"):
        FFTSpec(shape=(8, 64), mesh=mesh_f, data_axis="rows")


def test_plan_infeasible_sizes_raise_clearly():
    _need(4)
    mesh = _mesh1()
    with pytest.raises(ValueError, match="power of two"):
        plan(FFTSpec(shape=(8, 1000), mesh=mesh))
    with pytest.raises(ValueError, match="shards\\^2"):
        plan(FFTSpec(shape=(8, 8), mesh=mesh))
    with pytest.raises(ValueError, match="infeasible decomp: slab"):
        plan(FFTSpec(shape=(8, 2, 256), rank=2, mesh=mesh, decomp="slab"))
    with pytest.raises(ValueError, match="infeasible decomp: pencil"):
        plan(FFTSpec(shape=(8, 64, 8), rank=2, mesh=mesh, decomp="pencil"))
    with pytest.raises(ValueError, match="slab"):
        plan(FFTSpec(shape=(8, 64, 256), rank=2, mesh=mesh, decomp="pencil",
                     ft=FTConfig()))
    with pytest.raises(ValueError, match="groups=3"):
        plan(FFTSpec(shape=(8, 1024), mesh=mesh, ft=FTConfig(groups=3)))


# ---------------------------------------------------------------------------
# plan cache: hits, zero retrace, distinct keys
# ---------------------------------------------------------------------------


def test_plan_cache_same_spec_same_plan_zero_retrace(crand):
    _need(4)
    mesh = _mesh1()
    spec = FFTSpec(shape=(8, 4096), mesh=mesh)
    p1 = plan(spec)
    p2 = plan(dataclasses.replace(spec))
    assert p1 is p2, "equal specs must LRU-hit the same plan"
    x = jnp.asarray(crand(8, 4096))
    y1 = p1.fft(x)
    traces = p1._fwd._cache_size()      # jit cache entries after first call
    for _ in range(3):
        y2 = plan(dataclasses.replace(spec)).fft(x)
    assert p1._fwd._cache_size() == traces, "repeat dispatch retraced"
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_plan_cache_distinct_keys():
    _need(4)
    mesh = _mesh1()
    base = FFTSpec(shape=(8, 4096), mesh=mesh)
    others = [
        dataclasses.replace(base, dtype="complex128"),
        dataclasses.replace(base, mesh=None),
        dataclasses.replace(base, natural_order=False),
        dataclasses.replace(base, ft=FTConfig(groups=4)),
        dataclasses.replace(base, ft=FTConfig(groups=4, threshold=1e-6)),
    ]
    plans = [plan(s) for s in [base] + others]
    assert len({id(p) for p in plans}) == len(plans)
    # resolved once: the ft plan carries its group layout and model
    pf = plans[4]
    assert pf.groups == 4
    assert pf.volume["abft_overhead"] == pytest.approx(1.0)


def test_explicit_local_decomp_honored_on_sharded_mesh(rng):
    """decomp='local' must run the local transform even when a mesh is
    attached (the legacy distributed_fftn contract) — not be re-resolved
    by choose_decomp, which would reject odd grids and could silently
    return pencil digit order."""
    _need(4)
    mesh = _mesh1()
    x = (rng.standard_normal((2, 6, 10))
         + 1j * rng.standard_normal((2, 6, 10))).astype(np.complex64)
    p = plan(FFTSpec(shape=(2, 6, 10), rank=2, mesh=mesh, decomp="local"))
    assert p.decomp == "local" and not p.sharded
    got = np.asarray(p.fft(jnp.asarray(x)))
    np.testing.assert_allclose(got, np.fft.fft2(x), atol=1e-3)
    import warnings as _w
    from repro.kernels import ops
    with _w.catch_warnings():
        _w.simplefilter("ignore")
        got2 = np.asarray(ops.fft2(jnp.asarray(x), mesh=mesh,
                                   decomp="local"))
    np.testing.assert_array_equal(got, got2)


def test_local_ft_plan_treats_groups_as_noop(crand):
    """groups/group_size are mesh-path knobs: a LOCAL ft plan must accept
    any value as a documented no-op (ops.ft_fft contract), not validate it
    against the batch."""
    x = jnp.asarray(crand(6, 256))   # 4 does not divide 6
    p = plan(FFTSpec(shape=(6, 256), ft=FTConfig(groups=4)))
    assert p.groups is None and not p.sharded
    res = p.ft_fft(x)
    assert int(res.corrected) == 0
    np.testing.assert_allclose(np.asarray(res.y), np.fft.fft(np.asarray(x)),
                               atol=1e-3)


def test_plan_resolves_decomp_once():
    _need(4)
    mesh = _mesh1()
    p = plan(FFTSpec(shape=(8, 64, 128), rank=2, mesh=mesh))
    assert p.decomp in ("slab", "pencil")
    assert p.volume["decomp"] == p.decomp
    assert p.in_spec is not None and p.out_spec is not None
    pl = plan(FFTSpec(shape=(8, 64, 128), rank=2))
    assert pl.decomp == "local" and not pl.sharded


# ---------------------------------------------------------------------------
# bitwise identity vs the legacy kwarg paths
# ---------------------------------------------------------------------------


def _legacy_1d(x, mesh, *, ft, natural_order, ftcfg):
    from repro.core.fft.distributed import distributed_fft, ft_distributed_fft
    if ft:
        return ft_distributed_fft(
            x, mesh, threshold=ftcfg.threshold, groups=ftcfg.groups,
            natural_order=natural_order).y
    return distributed_fft(x, mesh, natural_order=natural_order)


def _legacy_2d(x, mesh, *, decomp, ft, natural_order, ftcfg):
    from repro.core.fft import multidim
    if ft:
        return multidim.ft_distributed_fft2(
            x, mesh, threshold=ftcfg.threshold, groups=ftcfg.groups).y
    return multidim.distributed_fft2(x, mesh, decomp=decomp,
                                     natural_order=natural_order)


@pytest.mark.parametrize("dtype", [np.complex64, np.complex128])
@pytest.mark.parametrize("mesh_kind", ["1d", "2d"])
@pytest.mark.parametrize(
    "case", ["1d-plain", "1d-ft", "1d-transposed",
             "2d-slab", "2d-slab-ft", "2d-pencil", "2d-pencil-transposed"])
def test_plan_bitwise_identical_to_legacy(case, mesh_kind, dtype, rng):
    """The acceptance matrix: plan executors must be BITWISE identical to
    the legacy kwarg dispatch (they bind the same cached pipelines)."""
    _need(4)
    mesh = _mesh1() if mesh_kind == "1d" else _mesh2()
    ftcfg = FTConfig(groups=4)
    rank = 1 if case.startswith("1d") else 2
    ft = case.endswith("-ft")
    natural = not case.endswith("transposed")
    decomp = "auto"
    if case.startswith("2d-slab"):
        decomp = "slab"
    elif case.startswith("2d-pencil"):
        decomp = "pencil"
    shape = (8, 4096) if rank == 1 else (8, 64, 128)
    x = (rng.standard_normal(shape)
         + 1j * rng.standard_normal(shape)).astype(dtype)
    x = jnp.asarray(x)
    spec = FFTSpec(shape=shape, dtype=np.dtype(dtype).name, rank=rank,
                   mesh=mesh, decomp=decomp, natural_order=natural,
                   ft=ftcfg if ft else None)
    p = plan(spec)
    got = p.ft_fft(x).y if ft else p.fft(x)
    if rank == 1:
        want = _legacy_1d(x, mesh, ft=ft, natural_order=natural, ftcfg=ftcfg)
    else:
        want = _legacy_2d(x, mesh, decomp=decomp, ft=ft,
                          natural_order=natural, ftcfg=ftcfg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    if not ft:
        # the inverse round-trips bitwise against the legacy inverse too
        from repro.core.fft import multidim
        from repro.core.fft.distributed import distributed_ifft
        back = p.ifft(got)
        if rank == 1:
            wback = distributed_ifft(want, mesh, natural_order=natural)
        else:
            wback = multidim.distributed_ifft2(want, mesh, decomp=decomp,
                                               natural_order=natural)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(wback))


def test_plan_spectral_matches_legacy(rng):
    _need(4)
    mesh = _mesh2()
    a = rng.standard_normal((8, 1500)).astype(np.float32)
    v = rng.standard_normal(63).astype(np.float32)
    from repro.core.fft import spectral
    got = spectral.fft_convolve(a, v, mesh, mode="same")
    want = np.stack([np.convolve(r, v, "same") for r in a])
    assert np.abs(np.asarray(got) - want).max() < 2e-4 * np.abs(want).max()
    # the plan behind it is cache-shared with an explicit conv_spec build
    sp = spectral.conv_spec(a, v, mesh)
    assert plan(sp) is plan(spectral.conv_spec(a, v, mesh))
    got2 = plan(sp).correlate(a, v, mode="same")
    wantc = np.stack([np.correlate(r, v, "same") for r in a])
    assert np.abs(np.asarray(got2) - wantc).max() < 2e-4 * np.abs(wantc).max()
    # wrong-size operands against a fixed plan fail loudly, not wrongly
    with pytest.raises(ValueError, match="nfft"):
        plan(sp).convolve(a[:, :200], v)
    ps = plan(FFTSpec(shape=(8, 4096), mesh=mesh,
                      natural_order=False)).power_spectrum(
        jnp.asarray(rng.standard_normal((8, 4096)).astype(np.float32)))
    assert ps.shape == (8, 4096) and ps.dtype == np.float32


# ---------------------------------------------------------------------------
# kernels.ops deprecation shims
# ---------------------------------------------------------------------------


def test_ops_kwargs_deprecated_but_working(crand):
    _need(4)
    from repro.kernels import ops
    mesh = _mesh1()
    x = jnp.asarray(crand(4, 4096))
    api.reset_deprecation_warnings()
    with pytest.warns(api.FFTKwargDeprecationWarning):
        y = ops.fft(x, mesh=mesh)
    want = plan(FFTSpec(shape=(4, 4096), mesh=mesh)).fft(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(want))
    # one-shot: a second deprecated call on the same entry stays silent
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", api.FFTKwargDeprecationWarning)
        ops.fft(x, mesh=mesh)
    # defaults (and explicit default values) never warn
    with _w.catch_warnings():
        _w.simplefilter("error", api.FFTKwargDeprecationWarning)
        ops.fft(x[:2, :256])
        ops.fft(x[:2, :256], mesh=None, axis="fft", natural_order=True)


def test_deprecation_warnings_resettable(crand):
    """Regression: the one-shot registry must be resettable — two isolated
    invocations (reset between, as the autouse fixture does per test) BOTH
    warn. Before ``reset_deprecation_warnings`` the module-global set made
    the second invocation permanently silent, so warning assertions passed
    or failed depending on suite order."""
    _need(4)
    from repro.kernels import ops
    mesh = _mesh1()
    x = jnp.asarray(crand(4, 4096))

    def legacy_call():                 # ONE call site, invoked repeatedly
        return ops.fft(x, mesh=mesh)

    for _ in range(2):                 # isolated invocation = fresh registry
        api.reset_deprecation_warnings()
        with pytest.warns(api.FFTKwargDeprecationWarning):
            legacy_call()
        # one-shot within an invocation: same call site stays silent
        import warnings as _w
        with _w.catch_warnings():
            _w.simplefilter("error", api.FFTKwargDeprecationWarning)
            legacy_call()
    # distinct entry points are distinct keys: ifft still warns after fft
    with pytest.warns(api.FFTKwargDeprecationWarning):
        ops.ifft(x, mesh=mesh)


def test_ops_auto_dispatch_still_silent(crand):
    _need(4)
    import warnings as _w
    from repro.kernels import ops
    from repro.parallel import shard_signals
    mesh = _mesh1()
    x = crand(4, 4096)
    xs = shard_signals(x, mesh)
    with _w.catch_warnings():
        _w.simplefilter("error", api.FFTKwargDeprecationWarning)
        y = ops.fft(xs)
    np.testing.assert_array_equal(
        np.asarray(y),
        np.asarray(plan(FFTSpec(shape=(4, 4096), mesh=mesh)).fft(xs)))


# ---------------------------------------------------------------------------
# rfft / irfft mesh routing
# ---------------------------------------------------------------------------


def test_rfft_irfft_on_mesh(rng):
    _need(4)
    from repro.core.fft.extensions import irfft, rfft
    mesh = _mesh1()
    x = rng.standard_normal((4, 1 << 13)).astype(np.float32)
    got = np.asarray(rfft(jnp.asarray(x), mesh=mesh))
    want = np.fft.rfft(x)
    assert np.abs(got - want).max() < 4e-5 * np.abs(want).max()
    back = np.asarray(irfft(jnp.asarray(got), mesh=mesh))
    assert np.abs(back - x).max() < 4e-5 * np.abs(x).max()
    # infeasible half length (too small for shards^2) falls back local
    small = rng.standard_normal((2, 16)).astype(np.float32)
    got_s = np.asarray(rfft(jnp.asarray(small), mesh=mesh))
    assert np.abs(got_s - np.fft.rfft(small)).max() < 1e-4


def test_irfft_odd_n_direct_dft_fallback_with_mesh(rng):
    """Odd n has no power-of-two plan: the documented fallback is the
    local direct inverse DFT even when a mesh is passed."""
    _need(4)
    from repro.core.fft.extensions import irfft
    mesh = _mesh1()
    x = rng.standard_normal((2, 511)).astype(np.float32)
    y = np.fft.rfft(x)
    got = np.asarray(irfft(jnp.asarray(y), n=511, mesh=mesh))
    np.testing.assert_allclose(got, np.fft.irfft(y, 511), atol=2e-4)


# ---------------------------------------------------------------------------
# serve integration: one plan per worker
# ---------------------------------------------------------------------------


def test_serve_plan_reuses_one_plan(crand):
    _need(4)
    from repro.launch.serve import build_fft_spec, serve_plan
    mesh = _mesh1()
    spec = build_fft_spec((8, 4096), mesh=mesh, ft=True, groups=4)
    assert spec.ft is not None and spec.ft.groups == 4
    p = plan(spec)
    x = crand(8, 4096)
    y1, info1 = serve_plan(p, x, op="fft")
    y2, info2 = serve_plan(p, x, op="fft")
    assert info1["groups"] == 4 and info1["flagged"] == 0
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    # spec strings on the CLI resolve through the same builder
    import argparse
    from repro.launch.serve import apply_fft_spec_arg
    ns = argparse.Namespace(fft_n=0, batch=0, fft_shards=None, fft_data=1,
                            fft_dims=1, fft_rows=0, fft_cols=0, fft_op="fft",
                            fft_decomp="auto", ft=False, fft_groups=None,
                            fft_kernel_n=63, transposed=False,
                            fft_threshold=1e-4)
    apply_fft_spec_arg(ns, "n=4096,batch=8,shards=4,ft=1,groups=4")
    assert (ns.fft_n, ns.batch, ns.fft_shards, ns.ft, ns.fft_groups) == \
        (4096, 8, 4, True, 4)
    with pytest.raises(SystemExit, match="unknown key"):
        apply_fft_spec_arg(ns, "bogus=1")


def test_build_fft_spec_op_defaults():
    _need(4)
    from repro.launch.serve import build_fft_spec
    mesh = _mesh1()
    # order-agnostic periodogram defaults to transposed on a mesh
    assert build_fft_spec((8, 4096), mesh=mesh,
                          op="spectrum").natural_order is False
    assert build_fft_spec((8, 4096), mesh=None,
                          op="spectrum").natural_order is True
    assert build_fft_spec((8, 4096), mesh=mesh, op="fft").natural_order
    # convolve specs describe the PADDED pipeline transform
    sp = build_fft_spec((8, 1500), mesh=mesh, op="convolve",
                        kernel_shape=(63,))
    assert sp.shape == (8, 2048)
    sp2 = build_fft_spec((4, 20, 24), mesh=mesh, op="convolve",
                         kernel_shape=(5, 7), dims=2)
    assert sp2.shape == (4, 32, 32) and sp2.decomp == "slab"
    with pytest.raises(ValueError, match="1-D only"):
        build_fft_spec((4, 20, 24), mesh=mesh, op="correlate", dims=2,
                       kernel_shape=(5, 7))


# ---------------------------------------------------------------------------
# FTPolicy bridge
# ---------------------------------------------------------------------------


def test_policy_to_ft_config_plans():
    from repro.core.ft.policy import FTPolicy
    pol = FTPolicy(mesh_groups=2, threshold=1e-5,
                   recompute_uncorrectable=False)
    spec = FFTSpec(shape=(8, 256), ft=pol.to_ft_config())
    p = plan(spec)
    assert p.spec.ft.threshold == 1e-5
    assert p.spec.ft.recompute_uncorrectable is False
    assert p.groups is None          # groups are a mesh-path knob
    _need(4)
    pm = plan(FFTSpec(shape=(8, 4096), mesh=_mesh1(),
                      ft=pol.to_ft_config()))
    assert pm.groups == 2
