"""Grouped multi-transaction sharded ABFT: the fault-tolerance contract is
one SEU per checksum GROUP per pass — k simultaneous SEUs in k distinct
groups are all corrected, two SEUs in one group decode as uncorrectable
(policy recompute path), and checksum-row hits are distinguished from data
corruption by the two-side location encoding.

Layout/validation/model tests run in-process everywhere. The multi-device
matrix runs in-process when the host platform carries >= 4 devices (the CI
8-device lane sets XLA_FLAGS=--xla_force_host_platform_device_count=8) and
is additionally covered by consolidated subprocess tests in the slow lane,
so local single-device tier-1 runs still exercise every scenario.
"""
import numpy as np
import pytest

from conftest import run_py

# ---------------------------------------------------------------------------
# group resolution + layout + communication model (in-process, fast)
# ---------------------------------------------------------------------------


def test_resolve_groups_auto_and_validation():
    from repro.core.fft.distributed import resolve_abft_groups

    # auto: one group per data shard when the batch divides, else 1
    assert resolve_abft_groups(8) == 1
    assert resolve_abft_groups(8, data_shards=4) == 4
    assert resolve_abft_groups(6, data_shards=4) == 1  # 4 does not divide 6
    # explicit group count / group size
    assert resolve_abft_groups(8, groups=4) == 4
    assert resolve_abft_groups(8, group_size=2) == 4
    assert resolve_abft_groups(8, groups=4, group_size=2) == 4
    with pytest.raises(ValueError):
        resolve_abft_groups(8, groups=3)            # must divide batch
    with pytest.raises(ValueError):
        resolve_abft_groups(8, group_size=3)
    with pytest.raises(ValueError):
        resolve_abft_groups(8, groups=4, group_size=4)  # inconsistent pair
    with pytest.raises(ValueError):
        # each data shard must own whole groups
        resolve_abft_groups(8, groups=2, data_shards=4)
    # a batch that cannot shard over data at all waives the data-axis
    # constraint (the pipeline replicates it) — the SAME resolution the
    # pipeline uses, so serve-side telemetry sizing can never drift
    assert resolve_abft_groups(6, groups=3, data_shards=4) == 3


def test_recompute_uncorrectable_rejects_jit():
    """The recompute fallback is host-side by design: under jit it must
    fail with an actionable error, not a TracerArrayConversionError."""
    import jax
    import jax.numpy as jnp

    from repro.core.fft.distributed import ft_distributed_fft

    mesh = jax.make_mesh((1,), ("fft",))
    x = jnp.ones((8, 256), jnp.complex64)
    with pytest.raises(ValueError, match="host-side fallback"):
        jax.jit(lambda v: ft_distributed_fft(
            v, mesh, groups=4, recompute_uncorrectable=True).y)(x)
    # without the flag the pipeline is jit-composable
    y = jax.jit(lambda v: ft_distributed_fft(v, mesh, groups=4).y)(x)
    assert y.shape == x.shape


def test_abft_group_layout_without_mesh():
    from repro.parallel import abft_group_layout, abft_group_spec

    assert abft_group_layout(None, 8, groups=4) == (4, 2)
    assert abft_group_layout(None, 8) == (1, 8)
    assert abft_group_spec(None) == __import__(
        "jax").sharding.PartitionSpec(None)


def test_collective_volume_grouped():
    """Checksum rows scale as 2G/B; the verdict traffic is 8 scalars per
    locally-owned group (3 verdict-psum + 5 replicated-stats broadcast)
    plus one shared energy scalar."""
    from repro.core.fft.distributed import collective_volume

    n, b, d = 1 << 17, 8, 4
    plain = collective_volume(n, b, d)
    g1 = collective_volume(n, b, d, ft=True)
    g4 = collective_volume(n, b, d, ft=True, groups=4)
    assert g1["abft_overhead"] == pytest.approx(2 / b)
    assert g4["abft_overhead"] == pytest.approx(8 / b)
    assert g4["all_to_all_wire"] == pytest.approx(
        plain["all_to_all_wire"] * (b + 8) / b)
    # psum payload at ring factor 2: grouped = (8G + 1) f32 scalars (the
    # 5G stats-broadcast term is the masked all-reduce XLA emits for the
    # replicated telemetry extraction); ungrouped = 4 f32 verdict scalars
    # + native-scalar stats (3 pred + f32 score + s32 location = 11B) —
    # per-kind HLO diffs in repro.analysis pinned down both layouts
    assert g4["psum_wire"] - g1["psum_wire"] == pytest.approx(
        2.0 * (33 * 4 - (4 * 4 + 11)) * (d - 1) / d)
    # data sharding divides rows, gather, and per-device verdict scalars
    half = collective_volume(n, b, d, ft=True, groups=4, data_shards=2)
    assert half["all_to_all_wire"] == pytest.approx(
        g4["all_to_all_wire"] / 2)
    assert half["gather_wire"] == pytest.approx(g4["gather_wire"] / 2)
    with pytest.raises(ValueError):
        collective_volume(n, b, d, ft=True, groups=2, data_shards=4)


# ---------------------------------------------------------------------------
# multi-device fault matrix (in-process on >= 4 host devices — the CI
# 8-device lane — and via subprocess in the slow lane below)
# ---------------------------------------------------------------------------

# One scenario catalogue drives the in-process and subprocess variants, so
# the two lanes cannot drift apart. b=8 signals, G=4 groups of 2.
_MATRIX_CODE = """
import numpy as np, jax, jax.numpy as jnp
from repro.core.fft.distributed import ft_distributed_fft

dtype = np.{dtype}
threshold = {threshold}
tol = {tol}
mesh = jax.make_mesh({mesh_shape}, {mesh_axes})
rng = np.random.default_rng(3)
b, n, g = 8, 1 << 12, 4
x = (rng.standard_normal((b, n)) + 1j * rng.standard_normal((b, n))
     ).astype(dtype)
ref = np.asarray(jnp.fft.fft(x))
mag = 60.0 if dtype == np.complex64 else 1e-6
ft = jnp.float64 if dtype == np.complex128 else jnp.float32

def run(inj, **kw):
    return ft_distributed_fft(x, mesh, threshold=threshold, groups=g,
                              inject=None if inj is None
                              else jnp.asarray(inj, ft), **kw)

def err(res):
    return np.abs(np.asarray(res.y) - ref).max() / np.abs(ref).max()

# clean pass: no verdicts, exact output
clean = run(None)
assert not np.asarray(clean.flagged).any(), np.asarray(clean.group_score)
assert err(clean) < tol

# k = 4 simultaneous SEUs in 4 distinct groups: ALL corrected in one pass
inj4 = [[0, 1, 3, 1, 1, mag, mag / 4],
        [1, 2, 5, 2, 1, -mag / 2, mag],
        [1, 5, 7, 3, 1, mag, -mag / 3],
        [0, 6, 2, 0, 1, mag / 2, mag / 2]]
res = run(inj4)
assert np.asarray(res.flagged).all()
assert np.asarray(res.correctable).all()
assert list(np.asarray(res.location)) == [1, 2, 5, 6]
assert int(res.corrected) == 4
assert err(res) < tol, err(res)

# without correction the propagated error persists (the injected epsilon
# scales with the dtype — 1e-6 for the fp64 cells — so the floor does too)
bad = run(inj4, correct=False)
assert err(bad) > 50 * tol

# 2 SEUs in ONE group (rows 4 and 5 are both group 2): detected, flagged
# uncorrectable, repaired only by the policy recompute path
inj2 = [[0, 4, 3, 1, 1, mag, mag / 4],
        [1, 5, 5, 2, 1, -mag / 2, mag]]
dbl = run(inj2)
u = np.asarray(dbl.uncorrectable)
assert list(u) == [False, False, True, False]
assert not np.asarray(dbl.correctable).any()
assert int(dbl.corrected) == 0 and err(dbl) > 50 * tol
fixed = run(inj2, recompute_uncorrectable=True)
assert int(fixed.recomputed) == 1
assert err(fixed) < tol, err(fixed)

# fault in a checksum row: flagged, classified checksum_fault (cs2 via the
# lam ~ 0 decode, cs3 via loud d3 with quiet d2), data untouched, and no
# correction is applied to the (clean) outputs
for sig, tag in ((b + 1, "cs2"), (b + g + 2, "cs3")):
    inj = [[1, sig, 4, 2, 1, mag, -mag]]
    rc = run(inj)
    fl = np.asarray(rc.checksum_fault)
    assert fl.any() and np.asarray(rc.flagged)[np.argmax(fl)], tag
    assert not np.asarray(rc.correctable).any(), tag
    assert err(rc) < tol, (tag, err(rc))
print('OK')
"""


def _matrix_params(mesh_shape, mesh_axes):
    return [
        dict(dtype="complex64", threshold=1e-4, tol=4e-5,
             mesh_shape=mesh_shape, mesh_axes=mesh_axes),
        dict(dtype="complex128", threshold=1e-10, tol=1e-11,
             mesh_shape=mesh_shape, mesh_axes=mesh_axes),
    ]


_MESHES = {"1d": ("(4,)", '("fft",)'), "2d": ("(2, 2)", '("data", "fft")')}


@pytest.mark.parametrize("meshname", sorted(_MESHES))
@pytest.mark.parametrize("dtype", ["complex64", "complex128"])
def test_group_fault_matrix_inprocess(meshname, dtype):
    """The full scenario matrix, in-process (CI 8-device lane)."""
    import jax

    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 host devices (the CI 8-device lane sets "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    shape, axes = _MESHES[meshname]
    p = [c for c in _matrix_params(shape, axes) if c["dtype"] == dtype][0]
    namespace = {"__name__": "__matrix__"}
    exec(_MATRIX_CODE.format(**p), namespace)  # raises on any failed assert


@pytest.mark.slow
@pytest.mark.parametrize("meshname", sorted(_MESHES))
def test_group_fault_matrix_subprocess(meshname):
    """Same matrix via a forced-4-device subprocess (both dtypes)."""
    shape, axes = _MESHES[meshname]
    for p in _matrix_params(shape, axes):
        out = run_py(_MATRIX_CODE.format(**p), devices=4)
        assert "OK" in out


# ---------------------------------------------------------------------------
# regression: 2-D data x fft meshes SHARD the batch (no batch all-gather)
# ---------------------------------------------------------------------------

_HLO_CODE = """
import numpy as np, jax, jax.numpy as jnp
from repro.core.fft import distributed as dist
from repro.launch.dryrun import collective_bytes

mesh = jax.make_mesh((2, 2), ("data", "fft"))
b, n, g = 8, 1 << 12, 4
x = jnp.ones((b, n), jnp.complex64)
for nat in (False, True):
    fn = dist._ft_dist_fft_fn(mesh, "fft", 1e-4, True, nat, g, "data")
    hlo = fn.lower(x, jnp.zeros((1, 7), jnp.float32)).compile().as_text()
    m = collective_bytes(hlo)
    # transposed order: ZERO all-gathers. natural order: exactly one, and
    # it is the fft-axis spectrum redistribution of THIS shard's batch
    # rows (b/data * n), not a batch all-gather (b * n) — model==HLO with
    # data_shards proves the batch stayed sharded.
    assert m["count"]["all-gather"] == (1 if nat else 0), (nat, m["count"])
    mdl = dist.collective_volume(n, b, 2, ft=True, groups=g, data_shards=2,
                                 natural_order=nat)
    assert abs(m["total_bytes"] / mdl["hlo_bytes"] - 1.0) < 1e-3, (
        nat, m["total_bytes"], mdl["hlo_bytes"])
    replicated = dist.collective_volume(n, b, 2, ft=True, groups=g,
                                        natural_order=nat)
    assert mdl["hlo_bytes"] < replicated["hlo_bytes"]
print('OK')
"""


def test_no_batch_allgather_on_2d_mesh_inprocess():
    import jax

    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 host devices")
    exec(_HLO_CODE, {"__name__": "__hlo__"})


@pytest.mark.slow
def test_no_batch_allgather_on_2d_mesh_subprocess():
    assert "OK" in run_py(_HLO_CODE, devices=4)


# ---------------------------------------------------------------------------
# serve endpoint + ops auto-dispatch carry the groups knob (subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_serve_and_ops_thread_groups():
    out = run_py("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.fft import FFTSpec, FTConfig, plan
from repro.launch.serve import serve_fft
from repro.launch.mesh import make_fft_mesh
from repro.parallel import shard_signals

rng = np.random.default_rng(5)
b, n = 8, 1 << 12
x = (rng.standard_normal((b, n)) + 1j * rng.standard_normal((b, n))
     ).astype(np.complex64)
ref = np.fft.fft(x)

# serve: 2-D data x fft mesh, grouped ft telemetry
y, info = serve_fft(x, shards=2, data=2, ft=True, groups=4)
assert info["groups"] == 4 and info["group_size"] == 2, info
assert info["flagged"] == 0 and info["recomputed"] == 0, info
assert np.abs(np.asarray(y) - ref).max() / np.abs(ref).max() < 4e-5

# an ft plan threads the groups knob to the grouped sharded path and
# accepts the distributed 7-field inject layout
mesh = make_fft_mesh(4)
xs = shard_signals(x, mesh)
inj = jnp.asarray([[1, 2, 5, 2, 1, 60.0, -25.0],
                   [2, 5, 7, 3, 1, 40.0, 35.0]], jnp.float32)
p = plan(FFTSpec(shape=x.shape, mesh=mesh, ft=FTConfig(groups=4)))
assert p.groups == 4
res = p.ft_fft(xs, inject=inj)
assert res.flagged.shape == (4,)
assert list(np.asarray(res.flagged)) == [False, True, True, False]
assert int(res.location[1]) == 2 and int(res.location[2]) == 5
assert int(res.corrected) == 2
assert np.abs(np.asarray(res.y) - ref).max() / np.abs(ref).max() < 1e-4
print('OK')
""", devices=4)
    assert "OK" in out
