"""Real-input transforms end-to-end (``FFTSpec(real=True)``):
``plan(spec).rfft2/irfft2`` vs ``jnp.fft`` on the local, slab, and pencil
paths, the half-spectrum communication models, grouped two-side ABFT on the
Hermitian-symmetric checksum layout, the packed real spectral pipeline
(convolve / correlate / power_spectrum), and the serve threading.
Multi-device cases run in-process on >= 4 host devices (the CI mesh-8dev
lane) and via subprocess in the slow lane, from one shared scenario
catalogue so the lanes cannot drift.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from conftest import run_py

# ---------------------------------------------------------------------------
# in-process: spec validation + dtype policy
# ---------------------------------------------------------------------------


def test_real_spec_validation():
    from repro.core.fft.api import FFTSpec, FTConfig

    with pytest.raises(ValueError, match="rank=3"):
        FFTSpec(shape=(8, 16, 32), rank=3, real=True)
    with pytest.raises(ValueError, match="natural-order"):
        FFTSpec(shape=(4, 1024), natural_order=False, real=True)
    with pytest.raises(ValueError, match="no ft pipeline"):
        FFTSpec(shape=(4, 1024), ft=FTConfig(), real=True)
    # rank-2 real + ft is the supported ABFT pipeline
    FFTSpec(shape=(8, 32, 64), rank=2, ft=FTConfig(), real=True)


def test_spec_for_real_dtype_policy():
    from repro.core.fft.api import spec_for

    x32 = jnp.zeros((2, 64), jnp.float32)
    x64 = jnp.zeros((2, 64), jnp.float64)
    assert spec_for(x32, real=True).dtype == "complex64"
    # a real fp64 operand keeps full precision (the C2C coercion squashes
    # every real dtype to complex64; the real spec must not)
    assert spec_for(x64, real=True).dtype == "complex128"
    assert spec_for(x64).dtype == "complex64"
    assert spec_for(x32, real=True).real and not spec_for(x32).real


def test_plan_executor_guards(rng):
    from repro.core.fft.api import FFTSpec, plan

    preal = plan(FFTSpec(shape=(2, 32, 64), rank=2, real=True))
    pc2c = plan(FFTSpec(shape=(2, 32, 64), rank=2))
    x = rng.standard_normal((2, 32, 64)).astype(np.float32)
    with pytest.raises(ValueError, match="real-input"):
        preal.fft(x)
    with pytest.raises(ValueError, match="real-input"):
        preal.ifft(x)
    with pytest.raises(ValueError, match="real=True"):
        pc2c.rfft(x)
    with pytest.raises(ValueError, match="real=True"):
        pc2c.irfft(x)
    with pytest.raises(ValueError, match="real operand"):
        preal.rfft(x.astype(np.complex64))
    # the half-spectrum shape contract: bins must be C/2 + 1
    with pytest.raises(ValueError, match="half-spectrum"):
        preal.irfft(jnp.zeros((2, 32, 64), jnp.complex64))
    with pytest.raises(ValueError, match="rank-2"):
        plan(FFTSpec(shape=(2, 1024), real=True)).rfft2(x[:, 0])


# ---------------------------------------------------------------------------
# in-process: local path vs jnp.fft / numpy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(64, 128), (32, 256), (256, 32)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_local_rfft2_matches_jnp(shape, dtype, rng, assert_spectrum_close):
    from repro.core.fft.api import plan, spec_for

    x = rng.standard_normal((3,) + shape).astype(dtype)
    p = plan(spec_for(x, rank=2, real=True))
    want = np.asarray(jnp.fft.rfft2(x))
    got = p.rfft2(x)
    assert got.shape == (3,) + shape[:-1] + (shape[-1] // 2 + 1,)
    assert_spectrum_close(got, want)
    back = p.irfft2(got)
    assert back.dtype == x.dtype
    assert_spectrum_close(back, x)


@pytest.mark.parametrize("shape", [(12, 30), (15, 64), (64, 22)])
def test_local_rfft2_odd_sizes(shape, rng, assert_spectrum_close):
    """Odd / non-power-of-two axes run the direct-DFT fallback (the
    distributed real slab stays power-of-two)."""
    from repro.core.fft.extensions import irfft2, rfft2

    x = rng.standard_normal((2,) + shape).astype(np.float32)
    want = np.asarray(jnp.fft.rfft2(x))
    got = rfft2(x)
    assert_spectrum_close(got, want)
    assert_spectrum_close(irfft2(jnp.asarray(got)), x)


def test_extensions_rfft2_rejects_complex(crand):
    from repro.core.fft.extensions import rfft2

    with pytest.raises(ValueError, match="real input"):
        rfft2(crand(2, 64).reshape(2, 8, 8))


def test_power_spectrum_real_one_sided(rng):
    from repro.core.fft.spectral import power_spectrum

    x = rng.standard_normal((3, 1024)).astype(np.float32)
    got = np.asarray(power_spectrum(x, real=True))
    want = np.abs(np.fft.rfft(x)) ** 2 / 1024
    assert got.shape == (3, 513)
    np.testing.assert_allclose(got, want, atol=4e-5 * want.max())
    with pytest.raises(ValueError, match="real input"):
        power_spectrum(x.astype(np.complex64), real=True)
    with pytest.raises(ValueError, match="natural-order"):
        power_spectrum(x, real=True, natural_order=False)


@pytest.mark.parametrize("mode", ["full", "same", "valid"])
def test_real_convolve_correlate_local(mode, rng):
    """Real operands ride the packed pipeline (kernel on the imaginary
    part — ONE C2C transform pair) and still match numpy exactly."""
    from repro.core.fft.spectral import correlate, fft_convolve

    a = rng.standard_normal((3, 200)).astype(np.float32)
    v = rng.standard_normal(31).astype(np.float32)
    got = np.asarray(fft_convolve(a, v, mode=mode))
    want = np.stack([np.convolve(r, v, mode=mode) for r in a])
    assert got.dtype == np.float32 and got.shape == want.shape
    np.testing.assert_allclose(got, want, atol=2e-4 * np.abs(want).max())
    got = np.asarray(correlate(a, v, mode=mode))
    want = np.stack([np.correlate(r, v, mode=mode) for r in a])
    assert got.shape == want.shape, mode
    np.testing.assert_allclose(got, want, atol=2e-4 * np.abs(want).max())


def test_real_convolve_fp64_local(rng):
    from repro.core.fft.spectral import fft_convolve

    a = rng.standard_normal((2, 100)).astype(np.float64)
    v = rng.standard_normal(9).astype(np.float64)
    got = np.asarray(fft_convolve(a, v, mode="full"))
    want = np.stack([np.convolve(r, v) for r in a])
    assert got.dtype == np.float64
    np.testing.assert_allclose(got, want, atol=1e-11 * np.abs(want).max())


# ---------------------------------------------------------------------------
# in-process: communication models + layout specs
# ---------------------------------------------------------------------------


def test_collective_volume_real_model():
    from repro.core.fft.distributed import collective_volume

    n, b, d = 1 << 14, 8, 4
    real = collective_volume(n, b, d, real=True)
    c2c = collective_volume(n, b, d)
    # the packed transform IS the half-length C2C pipeline
    assert real == {**collective_volume(n // 2, b, d), "real": True}
    assert real["hlo_bytes"] == c2c["hlo_bytes"] / 2
    assert real["all_to_all_wire"] == c2c["all_to_all_wire"] / 2
    with pytest.raises(ValueError, match="no ft pipeline"):
        collective_volume(n, b, d, ft=True, real=True)


def test_collective_volume_nd_real_model():
    from repro.core.fft.multidim import collective_volume_nd

    rr, cc, b, d = 128, 256, 8, 4
    real = collective_volume_nd((rr, cc), b, d, real=True)
    c2c = collective_volume_nd((rr, cc), b, d)
    cp = cc // 2 + d
    assert real["real"] is True
    assert real["all_to_all_count"] == 1 and real["all_gather_count"] == 0
    assert real["hlo_bytes"] == b * rr * cp * 8 / d
    # the headline: the padded half spectrum moves (C/2 + D)/C of the
    # C2C slab bytes — comfortably under the 0.6x acceptance line
    assert real["hlo_bytes"] / c2c["hlo_bytes"] == pytest.approx(cp / cc)
    assert real["hlo_bytes"] <= 0.6 * c2c["hlo_bytes"]
    ft = collective_volume_nd((rr, cc), b, d, ft=True, groups=4, real=True)
    # psum: (3G+1) verdict scalars + the 5G replicated-stats broadcast,
    # f32 (the auditor pins this against the lowered HLO)
    assert ft["hlo_bytes"] == pytest.approx(
        (b + 8) * rr * cp * 8 / d + 2 * (3 * 4 + 1 + 5 * 4) * 4)
    with pytest.raises(ValueError, match="slab-only"):
        collective_volume_nd((rr, cc), b, d, decomp="pencil", real=True)


def test_spectral_volume_real_model():
    from repro.core.fft.distributed import spectral_volume

    n, b, d = 1 << 14, 8, 2
    real = spectral_volume(n, b, d, kernel_batch=1, real=True)
    c2c = spectral_volume(n, b, d, kernel_batch=1)
    assert real["real"] is True
    assert real["all_to_all_count"] == 2 and real["all_gather_count"] == 0
    # the kernel rides the imaginary part: its forward rows vanish, so
    # both passes move exactly b rows — 2*b*n/D elements total
    assert real["hlo_bytes"] == 2 * b * n * 8 / d
    assert real["hlo_bytes"] == pytest.approx(
        c2c["hlo_bytes"] * (2 * b) / (2 * b + 1))


def test_layout_specs_real_and_half_spectrum_shape():
    from jax.sharding import PartitionSpec as P

    from repro.parallel.fft_sharding import (half_spectrum_shape,
                                             layout_specs, slab_specs)

    assert layout_specs(2, "slab", data_axis="data", real=True) == \
        slab_specs(2, data_axis="data")
    assert layout_specs(2, "slab", real=True) == (P(None, "fft", None),
                                                  P(None, None, "fft"))
    with pytest.raises(ValueError, match="slab"):
        layout_specs(2, "pencil", real=True)
    with pytest.raises(ValueError, match="slab"):
        layout_specs(3, "slab", real=True)
    assert half_spectrum_shape((8, 64, 128)) == (8, 64, 65)
    assert half_spectrum_shape((31,)) == (16,)
    with pytest.raises(ValueError, match="non-empty"):
        half_spectrum_shape(())


# ---------------------------------------------------------------------------
# in-process: serve threading
# ---------------------------------------------------------------------------


def test_build_fft_spec_real(rng):
    from repro.launch.serve import build_fft_spec, serve_plan
    from repro.core.fft.api import plan

    spec = build_fft_spec((4, 32, 64), op="fft", dims=2, real=True)
    assert spec.real and spec.rank == 2 and spec.natural_order
    p = plan(spec)
    x = rng.standard_normal((4, 32, 64)).astype(np.float32)
    y, info = serve_plan(p, x, op="fft")
    assert info["real"] is True
    want = np.asarray(jnp.fft.rfft2(x))
    assert np.abs(np.asarray(y) - want).max() < 4e-5 * np.abs(want).max()
    with pytest.raises(ValueError, match="natural-order"):
        build_fft_spec((4, 1024), real=True, natural_order=False)


def test_serve_fft_real_rejects_complex(crand):
    from repro.launch.serve import serve_fft

    with pytest.raises(ValueError, match="real"):
        serve_fft(crand(2, 64), real=True)


# ---------------------------------------------------------------------------
# multi-device scenario catalogue (in-process on >= 4 devices — the CI
# mesh-8dev lane — and via subprocess in the slow lane)
# ---------------------------------------------------------------------------

_REAL_EQUIV_CODE = """
import numpy as np, jax, jax.numpy as jnp
from repro.core.fft import multidim as md
from repro.core.fft.api import FFTSpec, plan, spec_for
from repro.parallel.fft_sharding import shard_grid

mesh1 = jax.make_mesh((4,), ("fft",))
mesh2 = jax.make_mesh((2, 2), ("data", "fft"))
rng = np.random.default_rng(11)

def rel(a, b):
    return np.abs(np.asarray(a) - b).max() / (np.abs(b).max() + 1e-30)

# rank-2 real plans: slab AND pencil (the composed path), fp32 AND fp64,
# on the 1-D and the 2-D mesh, through the plan API
for shape, dt, tol in [((64, 128), np.float32, 4e-5),
                       ((256, 32), np.float32, 4e-5),
                       ((32, 64), np.float64, 1e-11)]:
    x = rng.standard_normal((4,) + shape).astype(dt)
    ref = np.asarray(jnp.fft.rfft2(x))
    for mesh in (mesh1, mesh2):
        for decomp in ("slab", "pencil"):
            p = plan(spec_for(x, rank=2, mesh=mesh, decomp=decomp,
                              real=True))
            assert p.spec.dtype == (
                "complex128" if dt == np.float64 else "complex64")
            y = p.rfft2(x)
            assert y.shape == ref.shape, (decomp, y.shape)
            assert rel(y, ref) < tol, (shape, dt, decomp, rel(y, ref))
            back = p.irfft2(y)
            assert np.asarray(back).dtype == dt
            assert rel(back, x) < tol, (shape, dt, decomp, "roundtrip")
    # pre-sharded slab input dispatches identically
    p = plan(spec_for(x, rank=2, mesh=mesh1, decomp="slab", real=True))
    assert rel(p.rfft2(p.shard(x)), ref) < tol

# module-level entry points agree with the plan path
x = rng.standard_normal((4, 64, 128)).astype(np.float32)
ref = np.asarray(jnp.fft.rfft2(x))
y = md.distributed_rfft2(x, mesh1)
assert rel(y, ref) < 4e-5
assert rel(md.distributed_irfft2(y, mesh1), x) < 4e-5

# rank-1 real plan: the packed pencil path on the mesh
x1 = rng.standard_normal((8, 1 << 13)).astype(np.float32)
p1 = plan(spec_for(x1, mesh=mesh1, real=True))
ref1 = np.fft.rfft(x1)
assert rel(p1.rfft(x1), ref1) < 4e-5
assert rel(p1.irfft(jnp.asarray(ref1.astype(np.complex64))), x1) < 4e-5

# real one-sided power spectrum through the planned mesh path
ps = plan(spec_for(x1, mesh=mesh1, real=True)).power_spectrum(x1)
want_ps = np.abs(ref1) ** 2 / x1.shape[-1]
assert rel(ps, want_ps) < 4e-5

# packed real 1-D convolution / correlation on the mesh vs numpy
from repro.core.fft.spectral import correlate, fft_convolve
a = rng.standard_normal((8, 2000)).astype(np.float32)
v = rng.standard_normal(31).astype(np.float32)
for mode in ("full", "same", "valid"):
    got = np.asarray(fft_convolve(a, v, mesh1, mode=mode))
    want = np.stack([np.convolve(r, v, mode=mode) for r in a])
    assert got.dtype == np.float32 and got.shape == want.shape
    assert np.abs(got - want).max() < 2e-4 * np.abs(want).max(), mode
    got = np.asarray(correlate(a, v, mesh1, mode=mode))
    want = np.stack([np.correlate(r, v, mode=mode) for r in a])
    assert np.abs(got - want).max() < 2e-4 * np.abs(want).max(), mode

# fused REAL 2-D convolution on the mesh
a2 = rng.standard_normal((4, 20, 24)).astype(np.float32)
v2 = rng.standard_normal((5, 7)).astype(np.float32)
full = np.real(np.fft.ifft2(np.fft.fft2(a2, s=(24, 30)) *
                            np.fft.fft2(v2, s=(24, 30))))
for mesh in (mesh1, mesh2):
    got = np.asarray(md.fft_convolve2(a2, v2, mesh, mode="full"))
    assert got.shape == (4, 24, 30)
    assert np.abs(got - full).max() < 2e-4 * np.abs(full).max()
print('OK')
"""

_REAL_FT_CODE = """
import numpy as np, jax, jax.numpy as jnp
from repro.core.fft import multidim as md
from repro.core.fft.api import FFTSpec, FTConfig, plan

dtype = np.{dtype}
threshold = {threshold}
tol = {tol}
mesh = jax.make_mesh({mesh_shape}, {mesh_axes})
rng = np.random.default_rng(13)
b, rr, cc, g = 8, 32, 64, 4
shards = mesh.shape["fft"]
cp = cc // 2 + shards                       # padded half-spectrum width
x = rng.standard_normal((b, rr, cc)).astype(dtype)
ref = np.asarray(jnp.fft.rfft2(x))
mag = 60.0 if dtype == np.float32 else 1e-6
ft = jnp.float64 if dtype == np.float64 else jnp.float32
p = plan(FFTSpec(shape=(b, rr, cc), rank=2, mesh=mesh,
                 dtype="complex128" if dtype == np.float64 else "complex64",
                 ft=FTConfig(threshold=threshold, groups=g), real=True))

def run(inj, **kw):
    if kw:
        return md.ft_distributed_rfft2(
            x, mesh, threshold=threshold, groups=g,
            inject=None if inj is None else jnp.asarray(inj, ft), **kw)
    return p.ft_fft(x, inject=None if inj is None
                    else jnp.asarray(inj, ft))

def err(res):
    return np.abs(np.asarray(res.y) - ref).max() / np.abs(ref).max()

# clean: no verdicts, exact half spectrum, quiet left checksums
clean = run(None)
assert np.asarray(clean.y).shape == ref.shape
assert not np.asarray(clean.flagged).any(), np.asarray(clean.group_score)
assert float(jnp.max(clean.shard_delta)) < max(1e-4, 10 * threshold)
assert err(clean) < tol

# k = 4 SEUs in 4 distinct groups on the padded half spectrum (one in a
# live bin past C/4, one in the Hermitian-padding tail): ALL corrected
inj4 = [[0, 1, 3, 1, 1, mag, mag / 4],
        [1, 2, 5, 2, 1, -mag / 2, mag],
        [1, 5, 7, cc // 2, 1, mag, -mag / 3],
        [0, 6, 2, cp - 1, 1, mag / 2, mag / 2]]
res = run(inj4)
assert np.asarray(res.flagged).all(), np.asarray(res.group_score)
assert np.asarray(res.correctable).all()
assert list(np.asarray(res.location)) == [1, 2, 5, 6]
assert int(res.corrected) == 4
assert err(res) < tol, err(res)
bad = run(inj4, correct=False)
assert err(bad) > 50 * tol

# 2 SEUs in ONE group: uncorrectable, repaired by the recompute path
inj2 = [[0, 4, 3, 1, 1, mag, mag / 4], [1, 5, 5, 2, 1, -mag / 2, mag]]
dbl = run(inj2, correct=True)
assert list(np.asarray(dbl.uncorrectable)) == [False, False, True, False]
assert not np.asarray(dbl.correctable).any()
assert int(dbl.corrected) == 0 and err(dbl) > 50 * tol
fixed = run(inj2, correct=True, recompute_uncorrectable=True)
assert int(fixed.recomputed) == 1
assert err(fixed) < tol, err(fixed)

# checksum-grid hits (cs2 / cs3 rows at the folded width): classified,
# data untouched
for sig, tag in ((b + 1, "cs2"), (b + g + 2, "cs3")):
    rc = run([[1, sig, 4, 2, 1, mag, -mag]])
    fl = np.asarray(rc.checksum_fault)
    assert fl.any() and np.asarray(rc.flagged)[np.argmax(fl)], tag
    assert not np.asarray(rc.correctable).any(), tag
    assert err(rc) < tol, (tag, err(rc))
print('OK')
"""


def _ft_params(mesh_shape, mesh_axes):
    return [
        dict(dtype="float32", threshold=1e-4, tol=4e-5,
             mesh_shape=mesh_shape, mesh_axes=mesh_axes),
        dict(dtype="float64", threshold=1e-10, tol=1e-11,
             mesh_shape=mesh_shape, mesh_axes=mesh_axes),
    ]


_MESHES = {"1d": ("(4,)", '("fft",)'), "2d": ("(2, 2)", '("data", "fft")')}


def _needs4():
    import jax

    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 host devices (the CI mesh-8dev lane sets "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def test_real_equivalence_inprocess():
    """Slab + pencil real plans vs jnp.fft.rfft2/irfft2 on 1-D and 2-D
    meshes, fp32 and fp64, plus the packed 1-D/2-D spectral consumers
    (CI mesh-8dev lane)."""
    _needs4()
    exec(_REAL_EQUIV_CODE, {"__name__": "__requiv__"})


@pytest.mark.parametrize("meshname", sorted(_MESHES))
@pytest.mark.parametrize("dtype", ["float32", "float64"])
def test_real_ft_fault_matrix_inprocess(meshname, dtype):
    """k SEUs in k groups on the Hermitian half-spectrum checksum layout:
    detected, located, and corrected in one pass (CI mesh-8dev lane)."""
    _needs4()
    shape, axes = _MESHES[meshname]
    p = [c for c in _ft_params(shape, axes) if c["dtype"] == dtype][0]
    exec(_REAL_FT_CODE.format(**p), {"__name__": "__rft__"})


@pytest.mark.slow
def test_real_equivalence_subprocess():
    assert "OK" in run_py(_REAL_EQUIV_CODE, devices=4)


@pytest.mark.slow
@pytest.mark.parametrize("meshname", sorted(_MESHES))
def test_real_ft_fault_matrix_subprocess(meshname):
    shape, axes = _MESHES[meshname]
    for p in _ft_params(shape, axes):
        assert "OK" in run_py(_REAL_FT_CODE.format(**p), devices=4)
