"""Per-architecture smoke tests (reduced same-family configs, CPU).

For each of the 10 assigned architectures: forward shapes + finiteness, a
train-step gradient, and prefill/decode equivalence (catches cache, RoPE,
ring-buffer and recurrence bugs).
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_smoke_config
from repro.models import Model, count_params

jax.config.update("jax_platforms", "cpu")

# whole-module: per-arch forward/grad/decode sweeps dominate suite wall time
pytestmark = pytest.mark.slow


def _batch_for(cfg, b=2, t=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, t)), jnp.int32)}
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, 32, cfg.frontend_dim)), jnp.float32)
    if cfg.frontend == "patch_stub":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.num_patches, cfg.frontend_dim)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    logits, aux = m.apply(params, batch, block_q=8)
    t_expect = 16 + (cfg.num_patches if cfg.frontend == "patch_stub" else 0)
    assert logits.shape == (2, t_expect, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux["moe_aux"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_grad_finite(arch):
    cfg = get_smoke_config(arch)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    batch = _batch_for(cfg)
    labels = jnp.roll(batch["tokens"], -1, axis=1)

    def loss_fn(p):
        logits, aux = m.apply(p, batch, block_q=8)
        logits = logits[:, -labels.shape[1]:]
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)
        return -jnp.mean(ll) + 0.01 * aux["moe_aux"]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(l).all()) for l in leaves)
    # gradients actually flow (embedding at minimum)
    gnorm = sum(float(jnp.sum(jnp.abs(l))) for l in leaves)
    assert gnorm > 0


EQ_ARCHS = [a for a in ARCHS if a != "whisper_base"]


@pytest.mark.parametrize("arch", EQ_ARCHS)
def test_prefill_decode_equivalence(arch):
    """Token-by-token decode against the cache must match the parallel
    forward pass (validates KV caches, ring buffers, recurrent states)."""
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32",
                              capacity_factor=8.0)  # no MoE drops (see
    # test_serve.py note: capacity dropping is batch-dependent by design)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(2))
    b, t = 2, 8
    batch = _batch_for(cfg, b, t, seed=3)
    full_logits, _ = m.apply(params, batch, block_q=0)
    if cfg.frontend == "patch_stub":
        pytest.skip("vlm decode covers the text tail only — exercised below "
                    "via dense path")
    cache = m.init_cache(batch=b, max_len=32, dtype=jnp.float32)
    outs = []
    for i in range(t):
        logits, cache, _ = m.decode_step(
            params, cache, batch["tokens"][:, i:i + 1], jnp.int32(i))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=0, atol=2e-3 * float(
                                   jnp.abs(full_logits).max()))


def test_param_counts_in_published_range():
    """Full configs must land near the published parameter counts."""
    expected = {
        "qwen15_110b": (100e9, 120e9),
        "phi3_medium_14b": (12e9, 16e9),
        "phi4_mini_3p8b": (3.0e9, 4.6e9),
        "gemma3_1b": (0.7e9, 1.3e9),
        "internvl2_1b": (0.5e9, 1.1e9),   # LM backbone (ViT is a stub)
        "xlstm_350m": (0.25e9, 0.50e9),
        "deepseek_v3_671b": (600e9, 700e9),
        "llama4_maverick": (350e9, 440e9),
        "recurrentgemma_2b": (2.0e9, 3.2e9),
        "whisper_base": (0.05e9, 0.12e9),
    }
    from repro.configs import get_config
    for arch, (lo, hi) in expected.items():
        n = count_params(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}B, {hi/1e9}B]"


def test_layer_group_coverage():
    """Every full config's groups cover exactly num_layers."""
    from repro.configs import get_config
    from repro.models.transformer import layer_groups
    for arch in ARCHS:
        cfg = get_config(arch)
        if cfg.is_encdec:
            continue
        g = layer_groups(cfg)
        assert g.total == cfg.num_layers, (arch, g)


def test_ft_protected_forward():
    """FTLinear protection produces identical results and zero false alarms
    on a clean run (paper: FT overhead is compute, not accuracy)."""
    from repro.core.ft import FTPolicy
    cfg = get_smoke_config("phi3_medium_14b")
    cfg_ft = dataclasses.replace(
        cfg, dtype="float32",
        ft=FTPolicy(protect_linears=True, threshold=1e-2))
    cfg_plain = dataclasses.replace(cfg, dtype="float32")
    m_ft, m_plain = Model(cfg_ft), Model(cfg_plain)
    params = m_plain.init(jax.random.PRNGKey(4))
    batch = _batch_for(cfg)
    l1, aux1 = m_ft.apply(params, batch, block_q=8)
    l2, aux2 = m_plain.apply(params, batch, block_q=8)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-3)
    assert float(aux1["ft_flagged"]) == 0.0
    assert float(aux1["ft_max_score"]) > 0.0  # checksums were computed
