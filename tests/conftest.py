"""Shared test fixtures: seeded rng, complex batches, per-dtype tolerances,
host-mesh helpers, and the multi-device subprocess runner.

Every fixture is deterministic per test (seeds derive from the nodeid via
crc32, not Python's salted hash), so reordering or deselecting tests never
changes another test's data.
"""
from __future__ import annotations

import os
import subprocess
import sys
import zlib

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# atol = ATOL[dtype] * max|reference| — the suite-wide spectrum tolerance
# per complex dtype (c64 roundoff grows ~sqrt(log N); 4e-5 covers N = 2^20).
ATOL = {
    np.dtype(np.complex64): 4e-5,
    np.dtype(np.complex128): 1e-11,
    np.dtype(np.float32): 4e-5,
    np.dtype(np.float64): 1e-11,
}


@pytest.fixture(autouse=True)
def _fresh_deprecation_warnings():
    """Reset the one-shot FFT kwarg deprecation registry around every test.

    The registry is module-global (so real programs warn once per call
    site), which made warning assertions order-dependent across the suite:
    whichever test tripped a legacy path first swallowed everyone else's
    warning. Resetting per test makes each test observe its own first use.
    """
    from repro.core.fft.api import reset_deprecation_warnings

    reset_deprecation_warnings()
    yield
    reset_deprecation_warnings()


@pytest.fixture
def rng(request) -> np.random.Generator:
    """Per-test deterministic generator, seeded from the test's nodeid."""
    return np.random.default_rng(zlib.crc32(request.node.nodeid.encode()))


@pytest.fixture
def crand(rng):
    """``crand(b, n[, dtype])`` -> random complex (b, n) batch."""

    def make(b, n, dtype=np.complex64):
        x = rng.standard_normal((b, n)) + 1j * rng.standard_normal((b, n))
        return x.astype(dtype)

    return make


def spectrum_atol(ref, factor: float = 1.0, dtype=None) -> float:
    """Absolute tolerance for comparing against a reference spectrum."""
    ref = np.asarray(ref)
    return factor * ATOL[np.dtype(dtype or ref.dtype)] * (
        np.abs(ref).max() + 1e-30)


@pytest.fixture
def assert_spectrum_close():
    """``assert_spectrum_close(got, want[, factor])`` with per-dtype atol.

    The tolerance keys off the *lower-precision* side: numpy < 2 promotes
    np.fft results to complex128, which must not tighten the bound for a
    complex64 implementation under test.
    """

    def check(got, want, factor: float = 1.0):
        got, want = np.asarray(got), np.asarray(want)
        dt = min(got.dtype, want.dtype, key=lambda d: d.itemsize)
        np.testing.assert_allclose(got, want, rtol=0,
                                   atol=spectrum_atol(want, factor, dt))

    return check


@pytest.fixture
def host_mesh():
    """``host_mesh(*sizes, axes=names)`` over however many devices exist,
    clamping to a 1-D single-device mesh when the request doesn't fit."""
    import jax

    def make(*sizes, axes=("data", "model")):
        n = len(jax.devices())
        if int(np.prod(sizes)) > n:
            sizes, axes = (n,), (axes[0],)
        return jax.make_mesh(sizes, axes)

    return make


def run_py(code: str, devices: int = 8, timeout: int = 600) -> str:
    """Run ``code`` in a subprocess with a forced multi-device host platform
    (the XLA device-count flag must be set before jax initializes, so it
    cannot be applied inside the running test process)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout
