"""rfft / fft2 / FT-protected inverse — library extensions vs numpy, plus
classical transform invariants (Parseval, time-shift) that pin down scaling
and sign conventions independent of any reference implementation.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.fft.extensions import rfft, irfft, fft2, ifft2, ft_ifft
from repro.core import fft as tfft


@pytest.mark.parametrize("n", [64, 256, 1024])
def test_rfft_matches_numpy(n, rng):
    x = rng.standard_normal((3, n)).astype(np.float32)
    got = np.asarray(rfft(jnp.asarray(x)))
    want = np.fft.rfft(x)
    np.testing.assert_allclose(got, want, atol=3e-4 * np.abs(want).max())


def test_irfft_roundtrip(rng):
    x = rng.standard_normal((2, 512)).astype(np.float32)
    got = np.asarray(irfft(rfft(jnp.asarray(x))))
    np.testing.assert_allclose(got, x, atol=2e-5 * np.abs(x).max())


def test_irfft_explicit_n(rng):
    """Explicit ``n``: the default is recoverable by passing it, and a
    shorter n truncates the reconstructed signal (the documented semantics
    — unlike numpy, which crops the *spectrum* first)."""
    x = rng.standard_normal((2, 512)).astype(np.float32)
    y = rfft(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(irfft(y, n=512)),
                               np.fft.irfft(np.asarray(y), n=512),
                               atol=2e-5 * np.abs(x).max())
    got = np.asarray(irfft(y, n=500))
    assert got.shape == (2, 500)
    np.testing.assert_allclose(got, x[:, :500], atol=2e-5 * np.abs(x).max())


def test_irfft_odd_n_matches_numpy(rng):
    """Odd ``n`` has no Nyquist bin: the Hermitian tail is
    ``conj(y[..., 1:][..., ::-1])`` (regression: the even-length tail was
    used for every n, so odd n silently returned wrong values)."""
    x = rng.standard_normal((2, 511))
    y = np.fft.rfft(x)                       # (2, 256) odd-length spectrum
    got = np.asarray(irfft(jnp.asarray(y), n=511))
    assert got.shape == (2, 511)
    np.testing.assert_allclose(got, x, atol=1e-10 * np.abs(x).max())
    np.testing.assert_allclose(got, np.fft.irfft(y, 511),
                               atol=1e-10 * np.abs(x).max())


def test_irfft_odd_n_crops_spectrum_like_numpy(rng):
    """Odd n from a longer (even-origin) spectrum crops to the (n+1)//2
    bins an odd-length signal has — numpy's semantics, NOT a truncation of
    the even reconstruction (the pre-fix behaviour, off by O(1) values)."""
    x = rng.standard_normal((2, 512)).astype(np.float32)
    y = rfft(jnp.asarray(x))                 # (2, 257) even-origin spectrum
    got = np.asarray(irfft(y, n=511))
    want = np.fft.irfft(np.asarray(y), n=511)
    np.testing.assert_allclose(got, want, atol=2e-5 * np.abs(want).max())
    # the pre-fix output (truncated 512-point inverse) is measurably wrong
    wrong = np.asarray(irfft(y, n=512))[:, :511]
    assert np.abs(wrong - want).max() > 1e-3


def test_irfft_odd_n_rejects_short_spectrum():
    with pytest.raises(ValueError, match="odd n"):
        irfft(jnp.ones((4,), jnp.complex64), n=9)


def test_fft2_matches_numpy(crand):
    x = crand(2 * 64, 128).reshape(2, 64, 128)
    got = np.asarray(fft2(jnp.asarray(x)))
    want = np.fft.fft2(x)
    np.testing.assert_allclose(got, want, atol=4e-5 * np.abs(want).max())
    back = np.asarray(ifft2(jnp.asarray(want)))
    np.testing.assert_allclose(back, x, atol=2e-6 * np.abs(x).max())


@pytest.mark.parametrize("rows,cols", [(32, 256), (256, 32), (16, 1024)])
def test_fft2_rectangular(rows, cols, crand, assert_spectrum_close):
    """Non-square grids in both orientations: the row pass and column pass
    must each use their own axis length (catches any transposed-plan mixup)."""
    x = crand(rows, cols).reshape(1, rows, cols)
    assert_spectrum_close(fft2(jnp.asarray(x)), np.fft.fft2(x))
    assert_spectrum_close(ifft2(fft2(jnp.asarray(x))), x)


@pytest.mark.parametrize("rows,cols", [(11, 18), (18, 11), (27, 64), (64, 27)])
@pytest.mark.parametrize("dtype", [np.complex64, np.complex128])
def test_fft2_odd_sizes_and_roundtrip(rows, cols, dtype, crand,
                                      assert_spectrum_close):
    """Odd / non-power-of-two grids vs numpy in fp32 AND fp64, plus the
    ifft2(fft2(x)) == x round trip — the local path's direct-DFT fallback
    (the suite previously only exercised power-of-two shapes)."""
    x = crand(rows, cols, dtype=dtype).reshape(1, rows, cols)
    assert_spectrum_close(fft2(jnp.asarray(x)), np.fft.fft2(x))
    assert_spectrum_close(ifft2(fft2(jnp.asarray(x))), x)


@pytest.mark.parametrize("dtype", [np.complex64, np.complex128])
def test_fft2_roundtrip_pow2(dtype, crand, assert_spectrum_close):
    x = crand(64, 128, dtype=dtype).reshape(1, 64, 128)
    assert_spectrum_close(ifft2(fft2(jnp.asarray(x))), x)
    assert_spectrum_close(fft2(ifft2(jnp.asarray(x))), x)


def test_fft2_accepts_mesh_and_interpret_kwargs(crand, assert_spectrum_close):
    """fft2/ifft2 thread mesh=/interpret= to the multidim subsystem
    (regression: both kwargs were previously rejected outright, so the 2-D
    transform silently never reached the distributed or kernel paths)."""
    x = crand(2 * 32, 64).reshape(2, 32, 64)
    want = np.fft.fft2(x)
    assert_spectrum_close(fft2(jnp.asarray(x), mesh=None, interpret=True),
                          want)
    assert_spectrum_close(
        ifft2(fft2(jnp.asarray(x), natural_order=True), mesh=None), x)


# ---------------------------------------------------------------------------
# transform invariants (reference-free)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [256, 4096, 1 << 14])
def test_parseval(n, crand):
    """sum |x|^2 == sum |X|^2 / N — energy conservation pins the 1/N
    normalization split between fft and ifft."""
    x = crand(3, n)
    y = np.asarray(tfft.fft(x))
    e_t = np.sum(np.abs(x) ** 2, axis=-1)
    e_f = np.sum(np.abs(y) ** 2, axis=-1) / n
    np.testing.assert_allclose(e_f, e_t, rtol=1e-5)


@pytest.mark.parametrize("shift", [1, 17, 255])
def test_time_shift_theorem(shift, crand):
    """fft(roll(x, s))[k] == fft(x)[k] * exp(-2*pi*i*k*s/N) — pins the
    forward sign convention at every output index, not just index 0."""
    n = 512
    x = crand(2, n)
    lhs = np.asarray(tfft.fft(np.roll(x, shift, axis=-1)))
    k = np.arange(n)
    phase = np.exp(-2j * np.pi * k * shift / n)
    rhs = np.asarray(tfft.fft(x)) * phase
    np.testing.assert_allclose(lhs, rhs, atol=4e-5 * np.abs(rhs).max())


def test_rfft_hermitian_symmetry(rng):
    """The half spectrum implies the full one: rfft output must equal the
    first N/2+1 bins of the complex transform of the same real input."""
    x = rng.standard_normal((2, 256)).astype(np.float32)
    half = np.asarray(rfft(jnp.asarray(x)))
    full = np.asarray(tfft.fft(x.astype(np.complex64)))
    np.testing.assert_allclose(half, full[:, :129],
                               atol=3e-4 * np.abs(full).max())


def test_ft_ifft_detects_and_corrects(rng):
    x = (rng.standard_normal((16, 256)) +
         1j * rng.standard_normal((16, 256))).astype(np.complex64)
    inj = jnp.asarray([1, 2, 9, 1, 60.0, -10.0], jnp.float32)
    res = ft_ifft(jnp.asarray(x), transactions=2, bs=8, inject=inj)
    want = np.fft.ifft(x)
    assert int(res.corrected) == 1
    np.testing.assert_allclose(np.asarray(res.y), want,
                               atol=1e-4 * np.abs(want).max())


# ---------------------------------------------------------------------------
# edge-case regressions: degenerate sizes + fp64 precision
# ---------------------------------------------------------------------------


def test_rfft_odd_n_matches_numpy(rng):
    """Odd lengths have no power-of-two plan; the documented fallback is
    the direct DFT (regression: a bare power-of-two assert used to make
    every odd length an AssertionError)."""
    x = rng.standard_normal((2, 511)).astype(np.float32)
    got = np.asarray(rfft(jnp.asarray(x)))
    want = np.fft.rfft(x)
    np.testing.assert_allclose(got, want, atol=2e-4 * np.abs(want).max())


def test_rfft_irfft_degenerate_sizes_raise_valueerror(rng):
    with pytest.raises(ValueError, match="empty"):
        rfft(jnp.zeros((2, 0), jnp.float32))
    with pytest.raises(ValueError, match="empty"):
        irfft(jnp.zeros((2, 0), jnp.complex64))
    # a single-bin half spectrum has no default width (2*(bins-1) = 0)
    with pytest.raises(ValueError, match="single-bin"):
        irfft(jnp.ones((2, 1), jnp.complex64))
    with pytest.raises(ValueError, match="n"):
        irfft(jnp.ones((2, 5), jnp.complex64), n=0)


def test_irfft_n1_explicit(rng):
    """n=1 with an explicit length is well-defined: the DC bin's real
    part (numpy semantics)."""
    y = jnp.asarray([[3.5 + 2.0j], [-1.25 + 0.5j]], jnp.complex64)
    got = np.asarray(irfft(y, n=1))
    np.testing.assert_allclose(got, np.fft.irfft(np.asarray(y), 1),
                               atol=1e-6)


@pytest.mark.parametrize("fn_pair", ["fft2", "ft_ifft"])
def test_fp64_not_clobbered(fn_pair, rng):
    """complex128 operands keep full precision end-to-end (regression:
    float64 intermediates used to silently clobber to float32, capping
    fp64 accuracy at the fp32 noise floor)."""
    x = (rng.standard_normal((4, 32, 64)) +
         1j * rng.standard_normal((4, 32, 64))).astype(np.complex128)
    if fn_pair == "fft2":
        y = fft2(jnp.asarray(x))
        assert np.asarray(y).dtype == np.complex128
        want = np.fft.fft2(x)
        assert np.abs(np.asarray(y) - want).max() < 1e-11 * np.abs(want).max()
        back = ifft2(y)
        assert np.asarray(back).dtype == np.complex128
        assert np.abs(np.asarray(back) - x).max() < 1e-11 * np.abs(x).max()
    else:
        xs = x.reshape(8, 1024)[:, :256]
        res = ft_ifft(jnp.asarray(xs), transactions=2, bs=8)
        want = np.fft.ifft(xs)
        assert np.asarray(res.y).dtype == np.complex128
        assert np.abs(np.asarray(res.y) - want).max() \
            < 1e-11 * np.abs(want).max()
