"""rfft / fft2 / FT-protected inverse — library extensions vs numpy."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.fft.extensions import rfft, irfft, fft2, ifft2, ft_ifft

RNG = np.random.default_rng(11)


@pytest.mark.parametrize("n", [64, 256, 1024])
def test_rfft_matches_numpy(n):
    x = RNG.standard_normal((3, n)).astype(np.float32)
    got = np.asarray(rfft(jnp.asarray(x)))
    want = np.fft.rfft(x)
    np.testing.assert_allclose(got, want, atol=3e-4 * np.abs(want).max())


def test_irfft_roundtrip():
    x = RNG.standard_normal((2, 512)).astype(np.float32)
    got = np.asarray(irfft(rfft(jnp.asarray(x))))
    np.testing.assert_allclose(got, x, atol=2e-5 * np.abs(x).max())


def test_fft2_matches_numpy():
    x = (RNG.standard_normal((2, 64, 128)) +
         1j * RNG.standard_normal((2, 64, 128))).astype(np.complex64)
    got = np.asarray(fft2(jnp.asarray(x)))
    want = np.fft.fft2(x)
    np.testing.assert_allclose(got, want, atol=4e-5 * np.abs(want).max())
    back = np.asarray(ifft2(jnp.asarray(want)))
    np.testing.assert_allclose(back, x, atol=2e-6 * np.abs(x).max())


def test_ft_ifft_detects_and_corrects():
    x = (RNG.standard_normal((16, 256)) +
         1j * RNG.standard_normal((16, 256))).astype(np.complex64)
    inj = jnp.asarray([1, 2, 9, 1, 60.0, -10.0], jnp.float32)
    res = ft_ifft(jnp.asarray(x), transactions=2, bs=8, inject=inj)
    want = np.fft.ifft(x)
    assert int(res.corrected) == 1
    np.testing.assert_allclose(np.asarray(res.y), want,
                               atol=1e-4 * np.abs(want).max())
