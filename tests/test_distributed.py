"""Distribution-layer tests on an 8-device host mesh (subprocess so the
XLA device-count flag doesn't leak into other tests)."""
import pytest

from conftest import run_py

pytestmark = pytest.mark.slow  # every test compiles on an 8-way subprocess


def test_param_specs_shard_and_run_training_step():
    """Real sharded train step on a 4x2 mesh: params FSDP+TP sharded, loss
    finite, and the result matches the single-device run bit-for-bit."""
    out = run_py("""
import dataclasses, numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.configs.base import RunConfig, ParallelConfig
from repro.models import Model
from repro.parallel import param_specs, batch_specs
from repro.train import make_train_step
from repro import optim
from repro.data import make_batch

cfg = dataclasses.replace(get_smoke_config('phi3_medium_14b'),
                          vocab_size=128, num_layers=2, dtype='float32')
model = Model(cfg)
mesh = jax.make_mesh((4, 2), ('data', 'model'))
params = model.init(jax.random.PRNGKey(0))
specs = param_specs(jax.eval_shape(lambda: params), mesh)
sharded = jax.tree_util.tree_map(
    lambda l, sp: jax.device_put(l, NamedSharding(mesh, sp)), params, specs)
# at least one leaf actually sharded on each axis
flat = jax.tree_util.tree_leaves_with_path(specs)
names = set()
for kp, sp in flat:
    for part in sp:
        if part is not None:
            names.add(part if isinstance(part, str) else tuple(part))
assert 'model' in names, names
assert ('data',) in names or 'data' in names, names

run = RunConfig(model=cfg, parallel=ParallelConfig(remat='none'))
step_fn = make_train_step(model, run)
batch = {k: jnp.asarray(v) for k, v in
         make_batch(0, 0, batch=8, seq_len=32, vocab_size=128).items()}
opt = optim.init_state(params)
with mesh:
    p2, o2, m = jax.jit(step_fn)(sharded, opt, batch, jnp.int32(0))
print('sharded_loss', float(m['loss']))
p1, o1, m1 = jax.jit(step_fn)(params, opt, batch, jnp.int32(0))
print('single_loss', float(m1['loss']))
assert abs(float(m['loss']) - float(m1['loss'])) < 1e-3
print('OK')
""")
    assert "OK" in out


def test_cache_specs_seq_sharding_for_long_decode():
    out = run_py("""
import jax, jax.numpy as jnp
from repro.parallel import cache_specs
mesh = jax.make_mesh((4, 2), ('data', 'model'))
cache = {'scan': {'slot0': {'k': jax.ShapeDtypeStruct((3, 1, 1024, 2, 64),
                                                      jnp.bfloat16),
                            'v': jax.ShapeDtypeStruct((3, 1, 1024, 2, 64),
                                                      jnp.bfloat16)}}}
specs = cache_specs(cache, mesh, seq_shard=True)
sp = specs['scan']['slot0']['k']
assert sp[2] == 'data', sp   # batch=1 -> sequence axis sharded (SP)
print('OK', sp)
""")
    assert "OK" in out


def test_compressed_allreduce_close_to_exact():
    out = run_py("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.parallel import compress_allreduce_mean
mesh = jax.make_mesh((8,), ('data',))
rng = np.random.default_rng(0)
g_global = rng.standard_normal((8, 64, 64)).astype(np.float32)
# one different gradient shard per device: simulate with vmap-less loop
grads = {'w': jax.device_put(jnp.asarray(g_global),
                             NamedSharding(mesh, P('data')))}
res = {'w': jnp.zeros((8, 64, 64), jnp.float32)}
res = {'w': jax.device_put(res['w'], NamedSharding(mesh, P('data')))}

def f(g, r):
    return compress_allreduce_mean(g, r, mesh, ('data',))

with mesh:
    mean, new_res = jax.jit(f)(grads, res)
want = g_global.mean(axis=0, keepdims=True)
got = np.asarray(mean['w'])
# every shard got (approximately) the global mean
err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
print('rel err', err)
assert err < 0.05, err
# error feedback residual carries the quantization error
assert np.abs(np.asarray(new_res['w'])).max() > 0
print('OK')
""")
    assert "OK" in out


def test_pipeline_stage_equivalence():
    out = run_py("""
import numpy as np, jax, jax.numpy as jnp
from repro.parallel import pipeline_apply
mesh = jax.make_mesh((4,), ('stage',))
rng = np.random.default_rng(0)
ws = jnp.asarray(rng.standard_normal((4, 16, 16)).astype(np.float32) * 0.3)
xs = jnp.asarray(rng.standard_normal((6, 8, 16)).astype(np.float32))

def fn_stage(w, x):
    return jnp.tanh(x @ w)

out = pipeline_apply(fn_stage, ws, xs, mesh, axis='stage')
# reference: sequential through all 4 stages
ref = xs
for i in range(4):
    ref = jnp.tanh(ref @ ws[i])
err = float(jnp.abs(out - ref).max())
print('err', err)
assert err < 1e-5
print('OK')
""")
    assert "OK" in out


def test_dryrun_cell_on_host_mesh():
    """End-to-end dry-run machinery on a small mesh: lower+compile a reduced
    config through the same code path as the production sweep."""
    out = run_py("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.configs.base import ParallelConfig, SHAPES, ShapeConfig
import repro.launch.dryrun as dr

mesh = jax.make_mesh((4, 2), ('data', 'model'))
cfg = dataclasses.replace(get_smoke_config('gemma3_1b'), vocab_size=256)
shape = ShapeConfig('t', 64, 8, 'train')
par = ParallelConfig()
lowered, ntoks, n_params = dr._lower_cell(cfg, shape, mesh, par)
compiled = lowered.compile()
a = dr._analyze(compiled)
assert a['flops'] > 0
assert a['collectives']['total_bytes'] > 0
print('OK flops', a['flops'])
""", devices=8)
    assert "OK" in out
