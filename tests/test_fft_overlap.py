"""Chunked multi-transaction mesh pipelines (core.fft.distributed /
spectral / multidim ``chunks``): the double-buffered execution mode that
splits one bulk all-to-all into C overlapped transactions.

The contract under test, end to end:

* ``resolve_chunks`` / ``choose_chunks`` — static transaction-count
  resolution and the sqrt(bytes/latency) auto model;
* ``chunk_layout`` — the sharding-glue mirror of the pipelines' resolution;
* the volume models carry ``chunks``: C (resp. 2C) all-to-alls, conserved
  total bytes, ``exposed_fraction = 1/C``; slab refuses to pretend;
* bitwise chunk-count invariance — every chunked pipeline (1-D natural and
  transposed, spectral round trip, 2-D/3-D pencil, grouped ABFT with and
  without injection) returns results identical to the bulk pipeline, bit
  for bit: chunking is an execution schedule, never a numerical change;
* the fault-injection matrix holds on the chunked ft path — verdicts,
  locations, and corrections agree with bulk wherever the SEU lands
  (first chunk, last chunk, checksum row, double-hit group);
* ``FFTSpec(chunks=...)`` resolves once in the plan (explicit, auto via
  ``FTConfig.transactions`` or the volume model, nd pencil, slab clamp)
  and threads through serve's ``--fft-spec`` string.

Multi-device cases run in-process on >= 4 forced host devices (the CI fast
lane and mesh-8dev lane both force them).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import run_py
from repro.core.fft import distributed as dist
from repro.core.fft import multidim as md
from repro.core.fft import spectral as spec
from repro.core.fft.api import FFTSpec, FTConfig, plan
from repro.core.fft.distributed import (CHUNK_LATENCY_BYTES, choose_chunks,
                                        resolve_chunks)
from repro.parallel.fft_sharding import chunk_layout


def _mesh1():
    return jax.make_mesh((4,), ("fft",))


def _need(n):
    if len(jax.devices()) < n:
        pytest.skip(f"needs >= {n} host devices")


def _crand(rng, *shape, dtype=np.complex64):
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)
            ).astype(dtype)


# ---------------------------------------------------------------------------
# static resolution: resolve_chunks / choose_chunks / chunk_layout
# ---------------------------------------------------------------------------


def test_resolve_chunks_feasibility():
    assert resolve_chunks(8, 4) == 4
    assert resolve_chunks(8, 3) == 2          # 3 does not divide 8
    assert resolve_chunks(7, 4) == 1          # prime rows: only bulk
    assert resolve_chunks(8, 16) == 8         # never more chunks than rows
    assert resolve_chunks(0, 4) == 1
    assert resolve_chunks(8, 0) == 1
    # granule: each transaction's rows must stay a multiple of it (the
    # batch-splitting inverse all-to-all needs whole shard blocks)
    assert resolve_chunks(8, 4, granule=2) == 4
    assert resolve_chunks(4, 4, granule=2) == 2


def test_choose_chunks_latency_model():
    L = CHUNK_LATENCY_BYTES
    # C* = sqrt(bytes / L), rounded down to a power of two
    assert choose_chunks(64 * L, 64) == 8      # C* = 8, max_chunks = 8
    assert choose_chunks(64 * L, 4) == 4       # clamped by rows
    assert choose_chunks(16 * L, 64) == 4
    assert choose_chunks(L // 2, 64) == 1      # latency-dominated: bulk
    assert choose_chunks(0, 64) == 1
    assert choose_chunks(64 * L, 64, max_chunks=2) == 2
    # feasibility still wins over the model: the pow-2 pick falls back to
    # the largest divisor the rows can actually carry
    assert choose_chunks(16 * L, 6) == 3       # C* = 4, but 4 does not | 6


def test_chunk_layout_no_mesh():
    assert chunk_layout(None, 8, 4) == (4, 2)
    assert chunk_layout(None, 8, 3) == (2, 4)
    # group-wise: whole checksum groups per transaction
    assert chunk_layout(None, 8, 2, groups=4) == (2, 4)
    assert chunk_layout(None, 8, 8, groups=4) == (4, 2)
    with pytest.raises(ValueError, match="abft_group_layout"):
        chunk_layout(None, 8, 2, groups=3)


def test_chunk_layout_on_2d_mesh():
    _need(4)
    mesh = jax.make_mesh((2, 2), ("data", "fft"))
    # 8 rows over 2 data shards: 4 resident rows -> up to 4 transactions
    assert chunk_layout(mesh, 8, 8) == (4, 1)
    assert chunk_layout(mesh, 8, 2, groups=4) == (2, 2)
    # indivisible batch replicates: full rows stay available
    assert chunk_layout(mesh, 7, 7) == (7, 1)


# ---------------------------------------------------------------------------
# volume models carry chunks
# ---------------------------------------------------------------------------


def test_collective_volume_chunks_fields():
    n, b, s = 1 << 12, 8, 4
    bulk = dist.collective_volume(n, b, s)
    v4 = dist.collective_volume(n, b, s, chunks=4)
    assert bulk["all_to_all_count"] == 1 and v4["all_to_all_count"] == 4
    # chunking re-grains the transfer without adding volume
    assert v4["all_to_all_bytes"] == bulk["all_to_all_bytes"]
    assert v4["hlo_bytes"] == bulk["hlo_bytes"]
    assert v4["exposed_fraction"] == 0.25
    assert v4["overlap_efficiency"] == 0.75
    assert bulk["exposed_fraction"] == 1.0


def test_spectral_volume_chunks():
    n, b, s = 1 << 12, 8, 4
    bulk = dist.spectral_volume(n, b, s, kernel_batch=1)
    v2 = dist.spectral_volume(n, b, s, kernel_batch=1, chunks=2)
    assert bulk["all_to_all_count"] == 2 and v2["all_to_all_count"] == 4
    assert v2["hlo_bytes"] == bulk["hlo_bytes"]
    assert v2["exposed_fraction"] == 0.5


def test_volume_nd_chunks_pencil_only():
    bulk = md.collective_volume_nd((64, 128), 8, 4, decomp="pencil")
    v2 = md.collective_volume_nd((64, 128), 8, 4, decomp="pencil", chunks=2)
    assert v2["all_to_all_count"] == 2 * bulk["all_to_all_count"]
    assert v2["all_to_all_bytes"] == bulk["all_to_all_bytes"]
    assert v2["exposed_fraction"] == 0.5
    with pytest.raises(ValueError, match="pencil"):
        md.collective_volume_nd((64, 128), 8, 4, decomp="slab", chunks=2)


# ---------------------------------------------------------------------------
# bitwise chunk-count invariance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("natural", [True, False])
def test_chunk_invariance_1d(rng, natural):
    _need(4)
    mesh = _mesh1()
    x = jnp.asarray(_crand(rng, 8, 1 << 12))
    bulk = np.asarray(dist.distributed_fft(x, mesh, natural_order=natural))
    for c in (2, 4, 8):
        y = dist.distributed_fft(x, mesh, natural_order=natural, chunks=c)
        assert np.array_equal(np.asarray(y), bulk), c
    # inverse round trip, chunked both ways
    z = dist.distributed_ifft(jnp.asarray(bulk), mesh,
                              natural_order=natural, chunks=4)
    ref = dist.distributed_ifft(jnp.asarray(bulk), mesh,
                                natural_order=natural)
    assert np.array_equal(np.asarray(z), np.asarray(ref))


def test_chunk_invariance_spectral(rng):
    _need(4)
    mesh = _mesh1()
    a = jnp.asarray(_crand(rng, 8, 1 << 10))
    v = jnp.asarray(_crand(rng, 1, 1 << 10))
    bulk = np.asarray(spec.fft_convolve(a, v, mesh, mode="full"))
    for c in (2, 4):
        s = spec.conv_spec(a, v, mesh, chunks=c)
        assert s.chunks == c
        p = plan(s)
        assert p.chunks == c
        got = p.convolve(a, v, mode="full")
        assert np.array_equal(np.asarray(got), bulk), c


def test_chunk_invariance_nd_pencil(rng):
    _need(4)
    mesh = _mesh1()
    # batched 2-D grids: chunks split the (replicated) batch dim
    x = jnp.asarray(_crand(rng, 8, 32, 64))
    for nat in (True, False):
        bulk = np.asarray(md.distributed_fft2(x, mesh, decomp="pencil",
                                              natural_order=nat))
        for c in (2, 4):
            y = md.distributed_fft2(x, mesh, decomp="pencil",
                                    natural_order=nat, chunks=c)
            assert np.array_equal(np.asarray(y), bulk), (nat, c)
    # rank-3 single grid: chunks split the leading (locally transformed)
    # axis — the rank-3 pencil pipeline
    g = jnp.asarray(_crand(rng, 16, 16, 32))
    bulk3 = np.asarray(md.distributed_fftn(g, mesh, ndim=3, decomp="pencil"))
    for c in (2, 4):
        y3 = md.distributed_fftn(g, mesh, ndim=3, decomp="pencil", chunks=c)
        assert np.array_equal(np.asarray(y3), bulk3), c
    back = md.distributed_ifftn(jnp.asarray(bulk3), mesh, ndim=3,
                                decomp="pencil", chunks=2)
    ref = md.distributed_ifftn(jnp.asarray(bulk3), mesh, ndim=3,
                               decomp="pencil")
    assert np.array_equal(np.asarray(back), np.asarray(ref))


# ---------------------------------------------------------------------------
# chunked grouped ABFT: verdicts ride per-transaction, bulk-identical
# ---------------------------------------------------------------------------


def _ft_fields(res):
    return (np.asarray(res.y), np.asarray(res.flagged),
            np.asarray(res.location),
            np.asarray(res.correctable), int(res.corrected))


@pytest.mark.parametrize("inject", [
    None,                                       # clean
    [[0, 1, 3, 1, 1, 60.0, 15.0]],              # SEU in the FIRST chunk
    [[1, 6, 5, 2, 1, -30.0, 60.0]],             # SEU in the LAST chunk
    [[0, 1, 3, 1, 1, 60.0, 15.0],               # one SEU per chunk
     [1, 6, 5, 2, 1, -30.0, 60.0]],
    [[1, 9, 4, 2, 1, 60.0, -60.0]],             # checksum-row fault (cs2)
    [[0, 4, 3, 1, 1, 60.0, 15.0],               # double hit in ONE group:
     [1, 5, 5, 2, 1, -30.0, 60.0]],             # flagged uncorrectable
], ids=["clean", "first-chunk", "last-chunk", "both-chunks",
        "checksum-row", "double-hit"])
def test_chunked_ft_fault_matrix(rng, inject):
    """The grouped-ABFT fault matrix is chunk-invariant: for every fault
    placement the chunked pipeline's verdicts AND outputs match the bulk
    pipeline bit for bit (each transaction carries whole groups with its
    own verdict psum, so where a chunk boundary falls must not matter)."""
    _need(4)
    mesh = _mesh1()
    b, n, g = 8, 1 << 12, 4
    x = jnp.asarray(_crand(rng, b, n))
    inj = None if inject is None else jnp.asarray(inject, jnp.float32)
    bulk = dist.ft_distributed_fft(x, mesh, groups=g, inject=inj)
    for c in (2, 4):
        res = dist.ft_distributed_fft(x, mesh, groups=g, inject=inj,
                                      chunks=c)
        for got, want in zip(_ft_fields(res), _ft_fields(bulk)):
            assert np.array_equal(got, want), c
        # group_score is the one non-bitwise field: its energy
        # normalization is per-transaction (documented on the pipeline),
        # so it only agrees to rounding
        np.testing.assert_allclose(np.asarray(res.group_score),
                                   np.asarray(bulk.group_score), rtol=0.05)
    # semantic spot checks on the bulk reference (shared by every chunking)
    if inject is None:
        assert not _ft_fields(bulk)[1].any()
    elif len(inject) == 1 and inject[0][1] < b:
        grp = inject[0][1] // (b // g)
        assert bool(bulk.flagged[grp]) and int(bulk.location[grp]) == \
            inject[0][1]


# ---------------------------------------------------------------------------
# HLO structure: C transactions lower to exactly C all-to-alls
# ---------------------------------------------------------------------------


def test_hlo_chunk_counts(rng):
    _need(4)
    from repro.launch.dryrun import collective_bytes

    mesh = _mesh1()
    n, b = 1 << 10, 8
    x = jnp.asarray(_crand(rng, b, n))
    for c in (1, 2, 4):
        fn = dist._dist_fft_fn(mesh, "fft", False, True, None, c)
        m = collective_bytes(fn.lower(x).compile().as_text())
        mdl = dist.collective_volume(n, b, 4, chunks=c)
        assert m["count"].get("all-to-all", 0) == mdl["all_to_all_count"] \
            == c, (c, m["count"])
        assert abs(m["total_bytes"] / mdl["hlo_bytes"] - 1.0) < 1e-3
        a2a = [w for k, w in m["ops"] if k == "all-to-all"]
        assert abs(max(a2a) / sum(a2a) - mdl["exposed_fraction"]) < 1e-9


# ---------------------------------------------------------------------------
# plan threading: FFTSpec(chunks=...) resolved once in FFTPlan
# ---------------------------------------------------------------------------


def test_spec_chunks_validation():
    for bad in (-1, True, 1.5, "2"):
        with pytest.raises((ValueError, TypeError)):
            FFTSpec(shape=(8, 1024), chunks=bad)
    assert FFTSpec(shape=(8, 1024), chunks=0).chunks == 0   # 0 = auto


def test_plan_resolves_chunks(rng):
    _need(4)
    mesh = _mesh1()
    x = jnp.asarray(_crand(rng, 8, 1 << 12))
    bulk = plan(FFTSpec(shape=(8, 1 << 12), mesh=mesh))
    p4 = plan(FFTSpec(shape=(8, 1 << 12), mesh=mesh, chunks=4))
    assert bulk.chunks == 1 and p4.chunks == 4
    assert "chunks=4" in repr(p4)
    assert p4.volume["all_to_all_count"] == 4
    assert np.array_equal(np.asarray(p4.fft(x)), np.asarray(bulk.fft(x)))
    # requested counts clamp to what the rows can carry
    assert plan(FFTSpec(shape=(8, 1 << 12), mesh=mesh, chunks=3)).chunks == 2
    # auto on the ft path reuses FTConfig.transactions (clamped to groups)
    pft = plan(FFTSpec(shape=(8, 1 << 12), mesh=mesh, chunks=0,
                       ft=FTConfig(groups=4, transactions=4)))
    assert pft.chunks == 4
    r = pft.ft_fft(x)
    rb = plan(FFTSpec(shape=(8, 1 << 12), mesh=mesh,
                      ft=FTConfig(groups=4))).ft_fft(x)
    assert np.array_equal(np.asarray(r.y), np.asarray(rb.y))
    assert not np.asarray(r.flagged).any()


def test_plan_nd_chunks(rng):
    _need(4)
    mesh = _mesh1()
    x = jnp.asarray(_crand(rng, 8, 32, 64))
    pp = plan(FFTSpec(shape=(8, 32, 64), rank=2, mesh=mesh,
                      decomp="pencil", chunks=2))
    assert pp.chunks == 2
    bulk = plan(FFTSpec(shape=(8, 32, 64), rank=2, mesh=mesh,
                        decomp="pencil"))
    assert np.array_equal(np.asarray(pp.fft2(x)), np.asarray(bulk.fft2(x)))
    # slab has one bulk exchange per axis pair — chunks clamp to 1
    ps = plan(FFTSpec(shape=(8, 32, 64), rank=2, mesh=mesh,
                      decomp="slab", chunks=4))
    assert ps.chunks == 1


# ---------------------------------------------------------------------------
# serve: --fft-spec carries chunks, strict parsing
# ---------------------------------------------------------------------------


def test_serve_spec_arg_chunks_and_strictness():
    import argparse

    from repro.launch.serve import apply_fft_spec_arg, build_fft_spec

    def fresh():
        return argparse.Namespace(fft_n=1 << 12, fft_batch=8, fft_shards=1,
                                  fft_ft=False, fft_groups=None,
                                  fft_natural=True, fft_real=False,
                                  fft_chunks=1)

    a = fresh()
    apply_fft_spec_arg(a, "n=4096,chunks=4")
    assert a.fft_chunks == 4 and a.fft_n == 4096
    a = fresh()
    apply_fft_spec_arg(a, "chunks=auto")
    assert a.fft_chunks == 0
    with pytest.raises(ValueError, match="empty segment at position 2"):
        apply_fft_spec_arg(fresh(), "n=8,,batch=4")
    with pytest.raises(ValueError, match="duplicate key 'n'"):
        apply_fft_spec_arg(fresh(), "n=8,n=16")
    with pytest.raises(SystemExit, match="unknown key"):
        apply_fft_spec_arg(fresh(), "n=8,bogus=1")
    with pytest.raises(ValueError):
        apply_fft_spec_arg(fresh(), "chunks=-2")
    s = build_fft_spec((8, 1 << 12), chunks=2)
    assert s.chunks == 2


@pytest.mark.slow
def test_serve_threads_chunks_subprocess():
    out = run_py("""
import numpy as np, jax, jax.numpy as jnp
from repro.launch.serve import serve_fft

rng = np.random.default_rng(7)
b, n = 8, 1 << 12
x = (rng.standard_normal((b, n)) + 1j * rng.standard_normal((b, n))
     ).astype(np.complex64)
y0, _ = serve_fft(x, shards=4)
y2, info = serve_fft(x, shards=4, chunks=2)
assert info["chunks"] == 2, info
assert np.array_equal(np.asarray(y0), np.asarray(y2))
print('OK')
""", devices=4)
    assert "OK" in out
