"""Distributed multi-dimensional FFT (core.fft.multidim): slab + pencil
equivalence vs jnp.fft.fft2/fftn, the decomposition chooser and its
communication model, grouped ABFT on the 2-D slab pass, and the fused 2-D
convolution. Multi-device cases run in-process on >= 4 host devices (the CI
mesh-8dev lane) and via subprocess in the slow lane, from one shared
scenario catalogue so the lanes cannot drift.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from conftest import run_py

# ---------------------------------------------------------------------------
# in-process: chooser + communication model
# ---------------------------------------------------------------------------


def test_choose_decomp_model_driven():
    import jax
    from repro.core.fft.multidim import choose_decomp

    mesh1 = jax.make_mesh((1,), ("fft",))
    assert choose_decomp((64, 128), None) == "local"
    assert choose_decomp((64, 128), mesh1) == "local"
    if len(jax.devices()) < 2:
        return
    mesh = jax.make_mesh((2,), ("fft",))
    # slab feasible: wins on volume (one all-to-all) / ties
    assert choose_decomp((64, 128), mesh, batch=8) == "slab"
    # slab infeasible (first axis does not divide): pencil takes over
    assert choose_decomp((1, 256), mesh) == "pencil"
    assert choose_decomp((64, 128), mesh, batch=8, ft=True) == "slab"


def test_choose_decomp_2d_mesh_tiebreak():
    """On a batch-of-one 2-D mesh, natural order keeps slab (its natural
    order is free; pencil would pay digit-restore gathers), while
    transposed order breaks the equal-volume tie toward pencil's smaller
    per-device block (the whole-mesh single-transform case)."""
    import jax
    from repro.core.fft.multidim import choose_decomp

    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 host devices")
    mesh2 = jax.make_mesh((2, 2), ("data", "fft"))
    assert choose_decomp((64, 128), mesh2, batch=1) == "slab"
    assert choose_decomp((64, 128), mesh2, batch=1,
                         natural_order=False) == "pencil"
    assert choose_decomp((64, 128), mesh2, batch=8) == "slab"


def test_choose_decomp_infeasible_raises():
    import jax
    from repro.core.fft.multidim import choose_decomp

    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 host devices")
    mesh = jax.make_mesh((2,), ("fft",))
    with pytest.raises(ValueError, match="no feasible decomposition"):
        choose_decomp((3, 5), mesh)   # not powers of two


def test_collective_volume_nd_model():
    from repro.core.fft.multidim import collective_volume_nd

    rr, cc, b, d = 128, 256, 8, 4
    grid = rr * cc
    slab = collective_volume_nd((rr, cc), b, d)
    assert slab["all_to_all_count"] == 1 and slab["all_gather_count"] == 0
    assert slab["hlo_bytes"] == b * grid * 8 / d
    assert slab["all_to_all_wire"] == b * grid * 8 / d * (d - 1) / d
    ft = collective_volume_nd((rr, cc), b, d, ft=True, groups=4)
    assert ft["abft_overhead"] == pytest.approx(2 * 4 / b)
    # verdict psum: 3G+1 scalars + the 5G-real replicated-stats broadcast
    assert ft["hlo_bytes"] == pytest.approx(
        (b + 8) * grid * 8 / d + 2 * (3 * 4 + 1 + 5 * 4) * 4)
    # pencil: 2 a2a on a 2-D mesh, batch replicated over the data axis
    pen = collective_volume_nd((rr, cc), b, 2, decomp="pencil",
                               data_shards=2, natural_order=False)
    assert pen["all_to_all_count"] == 2 and pen["all_gather_count"] == 0
    assert pen["hlo_bytes"] == 2 * b * grid * 8 / 4
    nat = collective_volume_nd((rr, cc), b, 2, decomp="pencil",
                               data_shards=2)
    assert nat["all_gather_count"] == 2
    assert nat["hlo_bytes"] == pen["hlo_bytes"] + b * grid * 8 * 1.5
    with pytest.raises(ValueError, match="slab"):
        collective_volume_nd((rr, cc), b, d, decomp="pencil", ft=True)


def test_sharding_spec_helpers():
    from jax.sharding import PartitionSpec as P

    from repro.parallel.fft_sharding import pencil_nd_specs, slab_specs

    assert slab_specs(2, data_axis="data") == (P("data", "fft", None),
                                               P("data", None, "fft"))
    assert slab_specs(3) == (P(None, "fft", None, None),
                             P(None, None, None, "fft"))
    inp, out = pencil_nd_specs(2)
    assert inp == P(None, None, "data", None, "fft")
    assert out == P(None, "data", None, "fft", None)
    with pytest.raises(ValueError):
        slab_specs(4)


# ---------------------------------------------------------------------------
# in-process: local path (mesh=None) vs numpy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(64, 128), (32, 256), (256, 32)])
def test_local_fft2_matches_numpy(shape, crand, assert_spectrum_close):
    from repro.core.fft.multidim import distributed_fft2, distributed_ifft2

    x = crand(2 * shape[0], shape[1]).reshape((2,) + shape)
    assert_spectrum_close(distributed_fft2(x), np.fft.fft2(x))
    assert_spectrum_close(distributed_ifft2(distributed_fft2(x)), x)


@pytest.mark.parametrize("shape", [(12, 30), (15, 64), (64, 21)])
def test_local_fft2_odd_sizes(shape, rng, assert_spectrum_close):
    """Odd / non-power-of-two axes run the direct-DFT fallback on the
    local path (the distributed decompositions stay power-of-two)."""
    from repro.core.fft.multidim import distributed_fft2, distributed_ifft2

    x = (rng.standard_normal((2,) + shape)
         + 1j * rng.standard_normal((2,) + shape)).astype(np.complex64)
    assert_spectrum_close(distributed_fft2(x), np.fft.fft2(x))
    assert_spectrum_close(distributed_ifft2(distributed_fft2(x)), x)


@pytest.mark.parametrize("dtype", [np.complex64, np.complex128])
def test_local_fftn3_and_roundtrip(dtype, crand, assert_spectrum_close):
    from repro.core.fft.multidim import distributed_fftn, distributed_ifftn

    x = crand(2 * 8 * 16, 32, dtype=dtype).reshape(2, 8, 16, 32)
    want = np.fft.fftn(x, axes=(-3, -2, -1))
    assert_spectrum_close(distributed_fftn(x, ndim=3), want, factor=2)
    assert_spectrum_close(distributed_ifftn(jnp.asarray(want), ndim=3), x,
                          factor=2)


def test_fftn_validation(crand):
    from repro.core.fft.multidim import distributed_fftn, ft_distributed_fft2

    x = crand(2, 64).reshape(2, 8, 8)
    with pytest.raises(ValueError, match="ndim"):
        distributed_fftn(x, ndim=4)
    with pytest.raises(ValueError, match="rank"):
        distributed_fftn(x[0, 0], ndim=2)
    with pytest.raises(ValueError, match="decomp"):
        distributed_fftn(x, decomp="cube")
    with pytest.raises(ValueError, match="mesh"):
        ft_distributed_fft2(x)


def test_ops_and_extensions_thread_kwargs(crand, assert_spectrum_close):
    """kernels.ops.fft2 / core.fft.extensions.fft2 accept interpret / mesh
    / natural_order and agree with numpy on the local path (regression:
    the old extensions.fft2 signature rejected every kwarg outright, so
    the 2-D transform could never reach the distributed or kernel paths)."""
    from repro.core.fft.extensions import fft2, ifft2
    from repro.kernels import ops

    x = crand(2 * 32, 64).reshape(2, 32, 64)
    want = np.fft.fft2(x)
    assert_spectrum_close(ops.fft2(x), want)
    assert_spectrum_close(fft2(x, mesh=None), want)
    assert_spectrum_close(ifft2(fft2(x)), x)
    # interpret=True routes the local path through the Pallas block kernel
    assert_spectrum_close(ops.fft2(x, interpret=True), want)
    assert_spectrum_close(ops.ifft2(jnp.asarray(want), interpret=True), x)


def test_fft_convolve2_local_matches_reference(rng):
    from repro.core.fft.multidim import fft_convolve2

    a = rng.standard_normal((2, 20, 24)).astype(np.float32)
    v = rng.standard_normal((5, 7)).astype(np.float32)
    rr, cc = 24, 30
    full = np.real(np.fft.ifft2(np.fft.fft2(a, s=(rr, cc)) *
                                np.fft.fft2(v, s=(rr, cc))))
    for mode, want in (
            ("full", full),
            ("same", full[:, 2:22, 3:27]),
            ("valid", full[:, 4:20, 6:24])):
        got = np.asarray(fft_convolve2(a, v, mode=mode))
        assert got.dtype == np.float32
        assert got.shape == want.shape, (mode, got.shape)
        np.testing.assert_allclose(got, want,
                                   atol=2e-4 * np.abs(want).max())


# ---------------------------------------------------------------------------
# multi-device scenario catalogue (in-process on >= 4 devices — the CI
# mesh-8dev lane — and via subprocess in the slow lane)
# ---------------------------------------------------------------------------

_EQUIV_CODE = """
import numpy as np, jax, jax.numpy as jnp
from repro.core.fft import multidim as md
from repro.parallel.fft_sharding import shard_grid

mesh1 = jax.make_mesh((4,), ("fft",))
mesh2 = jax.make_mesh((2, 2), ("data", "fft"))
rng = np.random.default_rng(5)

def rel(a, b):
    return np.abs(np.asarray(a) - b).max() / (np.abs(b).max() + 1e-30)

for shape, dtype, tol in [((64, 128), np.complex64, 4e-5),
                          ((256, 32), np.complex64, 4e-5),
                          ((32, 64), np.complex128, 1e-11)]:
    x = (rng.standard_normal((3,) + shape) +
         1j * rng.standard_normal((3,) + shape)).astype(dtype)
    ref = np.asarray(jnp.fft.fft2(x))
    for mesh in (mesh1, mesh2):
        for decomp in ("slab", "pencil"):
            y = md.distributed_fft2(x, mesh, decomp=decomp)
            assert rel(y, ref) < tol, (shape, dtype, decomp, rel(y, ref))
            back = md.distributed_ifft2(y, mesh, decomp=decomp)
            assert rel(back, x) < tol, (shape, dtype, decomp, "roundtrip")
        # pre-sharded slab input dispatches identically
        y = md.distributed_fft2(shard_grid(x, mesh, 2), mesh, decomp="slab")
        assert rel(y, ref) < tol

# transposed digit order: the pencil forward output is the natural
# spectrum under the per-axis (k1, k2) digit swap; the transposed-in
# inverse consumes it with zero all-gathers
x = (rng.standard_normal((2, 64, 128)) +
     1j * rng.standard_normal((2, 64, 128))).astype(np.complex64)
ref = np.asarray(jnp.fft.fft2(x))
from repro.core.fft.distributed import make_dist_plan
pc = make_dist_plan(128, 2)
pr = make_dist_plan(64, 2)
yt = np.asarray(md.distributed_fft2(x, mesh2, decomp="pencil",
                                    natural_order=False))
cube = yt.reshape(2, pr.n1, pr.n2, pc.n1, pc.n2)
nat = cube.transpose(0, 2, 1, 4, 3).reshape(2, 64, 128)
assert rel(nat, ref) < 4e-5
back = md.distributed_ifft2(jnp.asarray(yt), mesh2, decomp="pencil",
                            natural_order=False)
assert rel(back, x) < 4e-5

# 3-D: slab (1 a2a) on the 1-D mesh, pencil (2 a2a) on the 2-D mesh
x3 = (rng.standard_normal((2, 8, 32, 64)) +
      1j * rng.standard_normal((2, 8, 32, 64))).astype(np.complex64)
ref3 = np.asarray(jnp.fft.fftn(x3, axes=(-3, -2, -1)))
y3 = md.distributed_fftn(x3, mesh1, ndim=3, decomp="slab")
assert rel(y3, ref3) < 2e-4, rel(y3, ref3)
assert rel(md.distributed_ifftn(y3, mesh1, ndim=3, decomp="slab"), x3) < 2e-4
y3 = md.distributed_fftn(x3, mesh2, ndim=3, decomp="pencil")
assert rel(y3, ref3) < 2e-4, rel(y3, ref3)

# fused 2-D convolution on both meshes vs the numpy spectral reference
a = rng.standard_normal((4, 20, 24)).astype(np.float32)
v = rng.standard_normal((5, 7)).astype(np.float32)
full = np.real(np.fft.ifft2(np.fft.fft2(a, s=(24, 30)) *
                            np.fft.fft2(v, s=(24, 30))))
for mesh in (mesh1, mesh2):
    got = np.asarray(md.fft_convolve2(a, v, mesh, mode="full"))
    assert got.shape == (4, 24, 30)
    assert np.abs(got - full).max() < 2e-4 * np.abs(full).max()
print('OK')
"""

_FT_CODE = """
import numpy as np, jax, jax.numpy as jnp
from repro.core.fft import multidim as md

dtype = np.{dtype}
threshold = {threshold}
tol = {tol}
mesh = jax.make_mesh({mesh_shape}, {mesh_axes})
rng = np.random.default_rng(9)
b, rr, cc, g = 8, 32, 64, 4
x = (rng.standard_normal((b, rr, cc)) +
     1j * rng.standard_normal((b, rr, cc))).astype(dtype)
ref = np.asarray(jnp.fft.fft2(x))
mag = 60.0 if dtype == np.complex64 else 1e-6
ft = jnp.float64 if dtype == np.complex128 else jnp.float32

def run(inj, **kw):
    return md.ft_distributed_fft2(x, mesh, threshold=threshold, groups=g,
                                  inject=None if inj is None
                                  else jnp.asarray(inj, ft), **kw)

def err(res):
    return np.abs(np.asarray(res.y) - ref).max() / np.abs(ref).max()

# clean: no verdicts, exact output, quiet left checksums
clean = run(None)
assert not np.asarray(clean.flagged).any(), np.asarray(clean.group_score)
assert float(jnp.max(clean.shard_delta)) < max(1e-4, 10 * threshold)
assert err(clean) < tol

# k = 4 SEUs in 4 distinct groups, spread over devices: ALL corrected
inj4 = [[0, 1, 3, 1, 1, mag, mag / 4],
        [1, 2, 5, 2, 1, -mag / 2, mag],
        [1, 5, 7, 3, 1, mag, -mag / 3],
        [0, 6, 2, 0, 1, mag / 2, mag / 2]]
res = run(inj4)
assert np.asarray(res.flagged).all(), np.asarray(res.group_score)
assert np.asarray(res.correctable).all()
assert list(np.asarray(res.location)) == [1, 2, 5, 6]
assert int(res.corrected) == 4
assert err(res) < tol, err(res)
bad = run(inj4, correct=False)
assert err(bad) > 50 * tol

# 2 SEUs in ONE group: uncorrectable, repaired by the recompute path
inj2 = [[0, 4, 3, 1, 1, mag, mag / 4], [1, 5, 5, 2, 1, -mag / 2, mag]]
dbl = run(inj2)
assert list(np.asarray(dbl.uncorrectable)) == [False, False, True, False]
assert not np.asarray(dbl.correctable).any()
assert int(dbl.corrected) == 0 and err(dbl) > 50 * tol
fixed = run(inj2, recompute_uncorrectable=True)
assert int(fixed.recomputed) == 1
assert err(fixed) < tol, err(fixed)

# checksum-grid hits: classified, data untouched
for sig, tag in ((b + 1, "cs2"), (b + g + 2, "cs3")):
    rc = run([[1, sig, 4, 2, 1, mag, -mag]])
    fl = np.asarray(rc.checksum_fault)
    assert fl.any() and np.asarray(rc.flagged)[np.argmax(fl)], tag
    assert not np.asarray(rc.correctable).any(), tag
    assert err(rc) < tol, (tag, err(rc))
print('OK')
"""

# the batch never all-gathers on a 2-D mesh (slab ft shards it over data),
# and the slab forward is exactly one all-to-all with zero gathers
_HLO_CODE = """
import numpy as np, jax, jax.numpy as jnp
from repro.core.fft import multidim as md
from repro.launch.dryrun import collective_bytes

mesh2 = jax.make_mesh((2, 2), ("data", "fft"))
b, rr, cc, g = 8, 128, 256, 4
x = jnp.ones((b, rr, cc), jnp.complex64)
fn = md._ft_slab_fft2_fn(mesh2, "fft", 1e-4, True, g, "data")
meas = collective_bytes(fn.lower(x, jnp.zeros((1, 7), jnp.float32))
                        .compile().as_text())
assert meas["count"]["all-gather"] == 0, meas["count"]
assert meas["count"]["all-to-all"] == 1, meas["count"]
mdl = md.collective_volume_nd((rr, cc), b, 2, ft=True, groups=g,
                              data_shards=2)
assert abs(meas["total_bytes"] / mdl["hlo_bytes"] - 1) < 1e-3, (
    meas["total_bytes"], mdl["hlo_bytes"])
fn = md._slab_fftn_fn(mesh2, "fft", 2, False, "data")
meas = collective_bytes(fn.lower(x).compile().as_text())
assert meas["count"]["all-to-all"] == 1, meas["count"]
assert meas["count"]["all-gather"] == 0, meas["count"]
print('OK')
"""


def _ft_params(mesh_shape, mesh_axes):
    return [
        dict(dtype="complex64", threshold=1e-4, tol=4e-5,
             mesh_shape=mesh_shape, mesh_axes=mesh_axes),
        dict(dtype="complex128", threshold=1e-10, tol=1e-11,
             mesh_shape=mesh_shape, mesh_axes=mesh_axes),
    ]


_MESHES = {"1d": ("(4,)", '("fft",)'), "2d": ("(2, 2)", '("data", "fft")')}


def _needs4():
    import jax

    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 host devices (the CI mesh-8dev lane sets "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def test_multidim_equivalence_inprocess():
    """Slab + pencil vs jnp.fft.fft2/fftn on 1-D and 2-D meshes, fp32 and
    fp64, rectangular shapes, transposed order, conv2 (CI mesh-8dev lane)."""
    _needs4()
    exec(_EQUIV_CODE, {"__name__": "__equiv__"})


@pytest.mark.parametrize("meshname", sorted(_MESHES))
@pytest.mark.parametrize("dtype", ["complex64", "complex128"])
def test_ft_fault_matrix_inprocess(meshname, dtype):
    _needs4()
    shape, axes = _MESHES[meshname]
    p = [c for c in _ft_params(shape, axes) if c["dtype"] == dtype][0]
    exec(_FT_CODE.format(**p), {"__name__": "__ft__"})


def test_no_batch_allgather_inprocess():
    _needs4()
    exec(_HLO_CODE, {"__name__": "__hlo__"})


@pytest.mark.slow
def test_multidim_equivalence_subprocess():
    assert "OK" in run_py(_EQUIV_CODE, devices=4)


@pytest.mark.slow
@pytest.mark.parametrize("meshname", sorted(_MESHES))
def test_ft_fault_matrix_subprocess(meshname):
    shape, axes = _MESHES[meshname]
    for p in _ft_params(shape, axes):
        assert "OK" in run_py(_FT_CODE.format(**p), devices=4)


@pytest.mark.slow
def test_no_batch_allgather_subprocess():
    assert "OK" in run_py(_HLO_CODE, devices=4)


# ---------------------------------------------------------------------------
# serve threading
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_serve_fft2_threads_decomp_and_ft():
    out = run_py("""
import numpy as np
from repro.launch.serve import serve_fft
rng = np.random.default_rng(0)
x = (rng.standard_normal((4, 64, 128)) +
     1j * rng.standard_normal((4, 64, 128))).astype(np.complex64)
ref = np.fft.fft2(x)
for decomp in ("slab", "pencil", "auto"):
    y, info = serve_fft(x, shards=2, data=2, dims=2, decomp=decomp)
    assert info["dims"] == 2 and info["shards"] == 2 and info["data"] == 2
    assert np.abs(np.asarray(y) - ref).max() / np.abs(ref).max() < 4e-5, decomp
y, info = serve_fft(x, shards=4, dims=2, ft=True, groups=2)
assert info["ft"] and info["groups"] == 2 and info["flagged"] == 0
assert np.abs(np.asarray(y) - ref).max() / np.abs(ref).max() < 4e-5
# ft rides the slab transpose: an explicit pencil ask must fail loudly,
# not silently serve slab results
try:
    serve_fft(x, shards=4, dims=2, ft=True, decomp="pencil")
except ValueError as e:
    assert "slab" in str(e)
else:
    raise AssertionError("ft + decomp='pencil' must raise")
a = rng.standard_normal((4, 20, 24)).astype(np.float32)
v = rng.standard_normal((5, 7)).astype(np.float32)
y, info = serve_fft(a, shards=4, dims=2, op="convolve", kernel=v,
                    mode="full")
assert info["collectives"] == "2 a2a" and y.shape == (4, 24, 30)
y, info = serve_fft(x, shards=4, dims=2, op="spectrum")
assert np.abs(np.asarray(y) -
              np.abs(ref) ** 2 / (64 * 128)).max() < 1e-2
print('OK')
""", devices=4)
    assert "OK" in out
