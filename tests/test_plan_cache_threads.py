"""Plan-cache thread safety: concurrent plan() misses construct exactly one
plan object per spec — no duplicate construction, no duplicate jit traces.

Closes the EXPERIMENTS.md open question on plan-cache contention under
concurrent serving requests: the shared LRU's miss path is guarded by
per-spec in-flight events (repro.core.plan), so a worker pool hammering
``plan()`` on identical specs gets ONE plan (and one set of traced
pipelines), while distinct specs still build concurrently.
"""
from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np
import pytest

import jax

from repro.core import plan as planbase
from repro.core.fft import api


def _hammer(fn, threads: int):
    """Run ``fn(i)`` from ``threads`` threads through a start barrier so the
    calls genuinely race; returns the per-thread results."""
    barrier = threading.Barrier(threads)
    results = [None] * threads
    errors = []

    def worker(i):
        try:
            barrier.wait()
            results[i] = fn(i)
        except BaseException as e:          # pragma: no cover - fail path
            errors.append(e)

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errors, errors
    return results


@dataclasses.dataclass(frozen=True)
class _RaceSpec:
    """Test-only spec whose plan construction is slow enough to expose the
    lost-update race lru_cache had on the miss path."""

    tag: int


class _RacePlan(planbase.Plan):
    builds: list[int] = []

    def __init__(self, spec):
        super().__init__(spec)
        _RacePlan.builds.append(spec.tag)
        time.sleep(0.05)      # hold the miss open across every racer


@pytest.fixture
def race_registry():
    planbase.register_plan_type(_RaceSpec, _RacePlan)
    _RacePlan.builds = []
    yield
    planbase._PLAN_TYPES.pop(_RaceSpec, None)
    planbase.plan_cache_clear()


def test_identical_spec_hammer_builds_exactly_once(race_registry):
    spec = _RaceSpec(tag=7)
    results = _hammer(lambda i: planbase.plan(spec), threads=16)
    assert _RacePlan.builds == [7], \
        f"plan constructed {len(_RacePlan.builds)} times under the race"
    assert all(r is results[0] for r in results), \
        "threads observed distinct plan objects for one spec"


def test_distinct_specs_hammer_builds_one_each(race_registry):
    # 4 distinct specs x 8 threads each: one construction per spec, every
    # thread of a spec sees the same object
    results = _hammer(lambda i: planbase.plan(_RaceSpec(tag=i % 4)),
                      threads=32)
    assert sorted(_RacePlan.builds) == [0, 1, 2, 3]
    for tag in range(4):
        group = [r for r in results if r.spec.tag == tag]
        assert all(r is group[0] for r in group)


def test_distinct_specs_build_concurrently(race_registry):
    # the miss-path guard is per-spec, not a single global build lock: 4
    # distinct specs each sleeping 50 ms must overlap, not serialize
    t0 = time.perf_counter()
    _hammer(lambda i: planbase.plan(_RaceSpec(tag=100 + i)), threads=4)
    assert time.perf_counter() - t0 < 0.15, \
        "distinct-spec constructions serialized behind one lock"


def test_failed_build_retries_and_does_not_poison(race_registry):
    @dataclasses.dataclass(frozen=True)
    class _FlakySpec:
        tag: int

    calls = []

    class _FlakyPlan(planbase.Plan):
        def __init__(self, spec):
            calls.append(1)
            if len(calls) == 1:
                raise RuntimeError("transient build failure")
            super().__init__(spec)

    planbase.register_plan_type(_FlakySpec, _FlakyPlan)
    try:
        with pytest.raises(RuntimeError, match="transient"):
            planbase.plan(_FlakySpec(tag=0))
        # the failure must not leave a stuck in-flight entry behind
        p = planbase.plan(_FlakySpec(tag=0))
        assert isinstance(p, _FlakyPlan)
    finally:
        planbase._PLAN_TYPES.pop(_FlakySpec, None)
        planbase.plan_cache_clear()


def test_fft_spec_hammer_one_plan_no_retrace(crand):
    """The real thing: N threads planning one FFTSpec get the identical
    FFTPlan, the cache records exactly one miss for it, and dispatching
    from every thread adds zero jit traces beyond the first call."""
    api.plan_cache_clear()
    spec = api.FFTSpec(shape=(4, 256), dtype="complex64")
    results = _hammer(lambda i: api.plan(spec), threads=12)
    p = results[0]
    assert all(r is p for r in results)
    info = api.plan_cache_info()
    assert info.misses == 1 and info.hits == 11
    assert spec in api.plan_cache_keys()

    x = crand(4, 256)
    y0 = np.asarray(p.fft(x))                  # first call traces

    def dispatch(i):
        return np.asarray(api.plan(spec).fft(x))

    for y in _hammer(dispatch, threads=8):
        np.testing.assert_array_equal(y, y0)
    assert api.plan_cache_info().misses == 1, "dispatch re-missed the cache"


def test_cache_keys_and_info_shapes():
    api.plan_cache_clear()
    s1 = api.FFTSpec(shape=(2, 64))
    s2 = api.FFTSpec(shape=(2, 128))
    p1, p2 = api.plan(s1), api.plan(s2)
    assert api.plan(s1) is p1 and api.plan(s2) is p2
    keys = api.plan_cache_keys()
    # LRU order: s2 was planned after s1, then s1/s2 re-hit in order
    assert keys[-1] == s2 and s1 in keys
    info = api.plan_cache_info()
    assert info.currsize == 2 and info.maxsize == 512
    api.plan_cache_clear()
    assert api.plan_cache_info().currsize == 0
    assert api.plan_cache_keys() == []
