"""serve_plan edge paths + the consolidated --fft-spec parser's serving
keys + _ft_telemetry completeness on mesh paths (subprocess)."""
import argparse

import numpy as np
import pytest

from conftest import run_py
from repro.core.fft import api
from repro.launch.serve import (_SPEC_KEYS, apply_fft_spec_arg,
                                build_fft_spec, serve_plan)


@pytest.fixture(autouse=True)
def _fresh_cache():
    api.plan_cache_clear()
    yield
    api.plan_cache_clear()


# -- serve_plan edge paths --------------------------------------------------

def test_serve_plan_kernel_ops_require_kernel(crand):
    p = api.plan(build_fft_spec((4, 128), op="convolve", kernel_shape=(31,)))
    x = np.asarray(crand(4, 128)).real.astype(np.float32)
    with pytest.raises(ValueError, match="needs a kernel"):
        serve_plan(p, x, op="convolve")
    with pytest.raises(ValueError, match="needs a kernel"):
        serve_plan(p, x, op="correlate")


def test_serve_plan_rejects_unknown_op(crand):
    p = api.plan(build_fft_spec((4, 128)))
    with pytest.raises(ValueError, match="op must be"):
        serve_plan(p, crand(4, 128), op="dct")


def test_serve_plan_local_ft_inject_telemetry(crand):
    """The inject= passthrough on the local fused-kernel path: one SEU ->
    flagged verdict, corrected output, complete telemetry dict."""
    from repro.core.plan import FTConfig

    x = crand(4, 256)
    spec = build_fft_spec((4, 256), ft=True, threshold=1e-4)
    assert isinstance(spec.ft, FTConfig)
    p = api.plan(spec)
    y_clean, info_clean = serve_plan(p, x)
    assert info_clean["ft"] is True and info_clean["flagged"] is False
    assert info_clean["corrected"] == 0 and info_clean["location"] == -1
    np.testing.assert_allclose(np.asarray(y_clean), np.fft.fft(x),
                               rtol=1e-3, atol=1e-3)
    inj = np.asarray([0, 1, 3, 1, 250.0, 0.0], np.float32)
    y_f, info_f = serve_plan(p, x, inject=inj)
    assert info_f["flagged"] is True
    assert info_f["corrected"] == 1
    assert info_f["location"] >= 0
    np.testing.assert_allclose(np.asarray(y_f), np.fft.fft(x),
                               rtol=1e-3, atol=1e-3)


# -- _ft_telemetry completeness on the mesh (grouped + real) ----------------

FT_KEYS = ("ft", "groups", "group_size", "score", "flagged", "locations",
           "corrected", "uncorrectable", "checksum_faults", "recomputed",
           "shard_delta_max")


@pytest.mark.slow
def test_ft_telemetry_complete_grouped_and_real_mesh():
    out = run_py(f"""
import numpy as np, jax
from repro.core.fft import api
from repro.launch.serve import build_fft_spec, serve_plan

mesh = jax.make_mesh((4,), ('fft',))
rng = np.random.default_rng(0)
KEYS = {FT_KEYS!r}

# grouped 1-D pencil ABFT: every verdict field present and typed
x = (rng.standard_normal((8, 4096)) +
     1j * rng.standard_normal((8, 4096))).astype(np.complex64)
p = api.plan(build_fft_spec((8, 4096), mesh=mesh, ft=True, groups=4))
y, info = serve_plan(p, x)
missing = [k for k in KEYS if k not in info]
assert not missing, missing
assert info['groups'] == 4 and info['group_size'] == 2
assert info['flagged'] == 0 and info['corrected'] == 0
assert isinstance(info['locations'], list) and info['locations'] == []
assert info['shard_delta_max'] < 1e-4, info
np.testing.assert_allclose(np.asarray(y), np.fft.fft(x), rtol=2e-2,
                           atol=2e-2)

# one injected SEU -> flagged group, decoded location, corrected output
inj = np.asarray([[0, 3, 1, 2, 1, 300.0, 0.0]], np.float32)
y_f, info_f = serve_plan(p, x, inject=inj)
assert info_f['flagged'] == 1 and info_f['corrected'] == 1, info_f
assert info_f['locations'], info_f
np.testing.assert_allclose(np.asarray(y_f), np.fft.fft(x), rtol=2e-2,
                           atol=2e-2)

# real (half-spectrum) grouped slab: same completeness contract
xr = rng.standard_normal((8, 64, 256)).astype(np.float32)
pr = api.plan(build_fft_spec((8, 64, 256), mesh=mesh, dims=2, real=True,
                             ft=True, groups=4))
yr, rinfo = serve_plan(pr, xr)
missing = [k for k in KEYS if k not in rinfo]
assert not missing, missing
assert rinfo['real'] is True and rinfo['flagged'] == 0
np.testing.assert_allclose(np.asarray(yr), np.fft.rfft2(xr), rtol=2e-2,
                           atol=2e-2)
print('OK')
""", devices=4)
    assert "OK" in out


# -- the consolidated spec string: serving-policy keys ----------------------

def _fresh_args():
    ns = argparse.Namespace(
        fft_n=1 << 16, batch=4, fft_shards=None, fft_data=1, fft_dims=1,
        fft_rows=256, fft_cols=256, fft_op="fft", fft_decomp="auto",
        ft=False, fft_groups=None, fft_kernel_n=63, transposed=False,
        fft_threshold=1e-4, fft_real=False, fft_chunks=1,
        serve_workers=2, serve_max_batch=8, serve_deadline_ms=2.0,
        serve_queue_depth=64, serve_timeout_ms=None)
    return ns


def test_spec_arg_serve_keys_roundtrip():
    ns = _fresh_args()
    apply_fft_spec_arg(
        ns, "n=4096,workers=4,max_batch=16,deadline_ms=1.5,queue=128,"
            "timeout_ms=250")
    assert ns.fft_n == 4096
    assert ns.serve_workers == 4
    assert ns.serve_max_batch == 16
    assert ns.serve_deadline_ms == 1.5
    assert ns.serve_queue_depth == 128
    assert ns.serve_timeout_ms == 250.0
    # untouched keys keep their flag defaults (the spec only overrides)
    assert ns.batch == 4 and ns.ft is False


def test_spec_arg_serve_keys_strictness():
    with pytest.raises(ValueError, match="duplicate key"):
        apply_fft_spec_arg(_fresh_args(), "workers=2,workers=4")
    with pytest.raises(ValueError, match="empty segment"):
        apply_fft_spec_arg(_fresh_args(), "workers=2,,queue=8")
    with pytest.raises(SystemExit, match="unknown key"):
        apply_fft_spec_arg(_fresh_args(), "max_batchez=8")


def test_spec_keys_shared_with_runtime_package():
    """launch.serve and repro.serve expose the SAME key table — the CLI
    and the runtime must never drift on what a spec string means."""
    from repro.serve import SPEC_KEYS

    assert _SPEC_KEYS is SPEC_KEYS
    for k in ("workers", "max_batch", "deadline_ms", "queue", "timeout_ms"):
        assert k in SPEC_KEYS
