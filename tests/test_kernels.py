"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + FT properties.

Shared rng / complex-batch helpers come from conftest.py (``rng`` / ``crand``
fixtures); the hypothesis property tests live in test_properties.py so this
module collects without optional packages.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.stockham import block_fft_pallas
from repro.kernels.stockham_abft import abft_fft_pallas


@pytest.mark.parametrize("n", [128, 256, 512, 1024, 2048, 4096, 8192])
@pytest.mark.parametrize("b,bs", [(8, 8), (32, 16)])
def test_block_fft_kernel_sweep(n, b, bs, crand):
    x = crand(b, n)
    yr, yi = block_fft_pallas(jnp.real(x), jnp.imag(x), bs=bs)
    got = np.asarray(yr) + 1j * np.asarray(yi)
    want = np.asarray(ref.fft_ref(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, atol=3e-5 * np.abs(want).max())


@pytest.mark.parametrize("n", [16, 64])  # small & non-128-aligned radices
def test_block_fft_kernel_small_n(n, crand):
    x = crand(8, n)
    yr, yi = block_fft_pallas(jnp.real(x), jnp.imag(x), bs=8)
    got = np.asarray(yr) + 1j * np.asarray(yi)
    np.testing.assert_allclose(got, np.fft.fft(x), atol=2e-5 * n)


def test_block_fft_kernel_fp64(crand):
    x = crand(8, 1024, np.complex128)
    yr, yi = block_fft_pallas(jnp.real(x), jnp.imag(x), bs=8)
    got = np.asarray(yr) + 1j * np.asarray(yi)
    want = np.fft.fft(x)
    np.testing.assert_allclose(got, want, atol=1e-12 * np.abs(want).max())


def test_block_fft_kernel_inverse(crand):
    x = crand(8, 512)
    yr, yi = block_fft_pallas(jnp.real(x), jnp.imag(x), bs=8, inverse=True)
    got = np.asarray(yr) + 1j * np.asarray(yi)
    want = np.fft.ifft(x)
    np.testing.assert_allclose(got, want, atol=3e-7)


@pytest.mark.parametrize("n", [1 << 14, 1 << 17])
def test_ops_fft_multipass(n, crand):
    x = crand(2, n)
    got = np.asarray(ops.fft(x))
    want = np.fft.fft(x)
    np.testing.assert_allclose(got, want, atol=4e-5 * np.abs(want).max())


def test_ops_ifft_roundtrip(crand):
    x = crand(4, 2048)
    got = np.asarray(ops.ifft(ops.fft(x)))
    np.testing.assert_allclose(got, x, atol=2e-6 * np.abs(x).max())


# ---------------------------------------------------------------------------
# Fused two-sided ABFT kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("transactions", [1, 2, 4])
@pytest.mark.parametrize("per_signal", [True, False])
def test_abft_fft_clean_no_false_alarm(transactions, per_signal, crand):
    x = crand(32, 512)
    res = ops.ft_fft(x, transactions=transactions, bs=8,
                     per_signal=per_signal)
    want = np.fft.fft(x)
    np.testing.assert_allclose(np.asarray(res.y), want,
                               atol=3e-5 * np.abs(want).max())
    assert int(res.corrected) == 0
    assert not np.asarray(res.flagged).any()
    if per_signal:
        assert float(np.asarray(res.delta).max()) < 1e-4


@pytest.mark.parametrize("transactions", [1, 2, 4])
def test_abft_fft_detect_locate_correct(transactions, crand):
    b, n, bs = 32, 512, 8
    x = crand(b, n)
    want = np.fft.fft(x)
    tile, row, col = 2, 5, 37
    sig = tile * bs + row
    inj = jnp.asarray([tile, row, col, 1, 40.0, 25.0], dtype=jnp.float32)
    res = ops.ft_fft(x, transactions=transactions, bs=bs, per_signal=True,
                     inject=inj)
    flagged = np.asarray(res.flagged)
    loc = np.asarray(res.location)
    assert flagged.sum() == 1
    g = int(np.argmax(flagged))
    assert loc[g] == sig  # e3/e2 ratio decodes the corrupted signal
    # per-signal (thread-level) checksum flags the same signal
    assert int(np.argmax(np.asarray(res.delta))) == sig
    # delayed batched correction restores the output without recompute
    np.testing.assert_allclose(np.asarray(res.y), want,
                               atol=5e-5 * np.abs(want).max())


def test_abft_fft_correction_disabled_keeps_error(crand):
    b, n, bs = 16, 256, 8
    x = crand(b, n)
    inj = jnp.asarray([0, 0, 0, 1, 100.0, 0.0], dtype=jnp.float32)
    res = ops.ft_fft(x, transactions=1, bs=bs, correct=False, inject=inj)
    err = np.abs(np.asarray(res.y) - np.fft.fft(x)).max()
    assert err > 50.0  # error still present
    assert np.asarray(res.flagged).any()


def test_abft_fft_fp64(crand):
    x = crand(16, 1024, np.complex128)
    inj = jnp.asarray([1, 2, 3, 1, 7.0, -3.0], dtype=jnp.float32)
    res = ops.ft_fft(x, transactions=2, bs=8, inject=inj, threshold=1e-8)
    want = np.fft.fft(x)
    np.testing.assert_allclose(np.asarray(res.y), want,
                               atol=1e-9 * np.abs(want).max())
    assert int(res.corrected) == 1


def test_abft_fft_ragged_batch(crand):
    """Batches not divisible by bs pad with zero signals instead of silently
    truncating the remainder (regression: tiles = b // bs dropped it, then
    the kernel's b % bs assertion fired)."""
    b, n, bs = 13, 256, 8   # prime batch, bs does not divide it
    x = crand(b, n)
    want = np.fft.fft(x)
    res = ops.ft_fft(x, transactions=1, bs=bs)
    assert res.y.shape == (b, n) and res.delta.shape == (b,)
    assert not np.asarray(res.flagged).any()
    np.testing.assert_allclose(np.asarray(res.y), want,
                               atol=4e-5 * np.abs(want).max())
    # detect -> locate -> correct still lands on the right (real) signal
    inj = jnp.asarray([0, 2, 9, 1, 60.0, -10.0], dtype=jnp.float32)
    res = ops.ft_fft(x, transactions=1, bs=bs, inject=inj)
    assert int(res.corrected) == 1
    np.testing.assert_allclose(np.asarray(res.y), want,
                               atol=1e-4 * np.abs(want).max())


def test_abft_multi_transaction_checksum_equivalence(crand):
    """T transactions accumulate exactly the same group checksums as T=1
    over the same signals (paper §4.3: 'the workload of ABFT remains the
    same'), so detection behaviour is transaction-count invariant."""
    x = crand(32, 256)
    r1 = ops.ft_fft(x, transactions=1, bs=32)
    r4 = ops.ft_fft(x, transactions=4, bs=8)
    np.testing.assert_allclose(np.asarray(r1.group_score),
                               np.asarray(r4.group_score), atol=1e-5)
    np.testing.assert_allclose(np.asarray(r1.y), np.asarray(r4.y),
                               atol=1e-5 * np.abs(np.asarray(r1.y)).max())


# ---------------------------------------------------------------------------
# fused ABFT GEMM kernel
# ---------------------------------------------------------------------------
from repro.kernels.ft_matmul import ft_matmul_pallas


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 256),
                                   (384, 128, 512)])
def test_ft_matmul_kernel_sweep(m, k, n):
    rng = np.random.default_rng(m + k + n)
    x = rng.standard_normal((m, k)).astype(np.float32)
    w = rng.standard_normal((k, n)).astype(np.float32)
    res = ft_matmul_pallas(jnp.asarray(x), jnp.asarray(w))
    want = x @ w
    np.testing.assert_allclose(np.asarray(res.c), want,
                               atol=2e-4 * np.abs(want).max())
    # fused output checksums == true column sums / location sums;
    # predictions agree on a clean run
    np.testing.assert_allclose(np.asarray(res.out2), want.sum(0),
                               atol=1e-2 * np.abs(want.sum(0)).max())
    loc = np.arange(1, m + 1, dtype=np.float64)
    want3 = loc @ want.astype(np.float64)
    np.testing.assert_allclose(np.asarray(res.out3), want3,
                               rtol=0, atol=1e-4 * np.abs(want3).max())
    for out, pred in ((res.out2, res.pred2), (res.out3, res.pred3)):
        rel = np.abs(np.asarray(out) - np.asarray(pred)).max() / (
            np.abs(np.asarray(pred)).max() + 1e-9)
        assert rel < 1e-4


def test_ft_matmul_kernel_in_kernel_injection_locates():
    """An in-kernel SEU diverges out2 vs pred2 at the hit column AND the
    location ratio d3/d2 decodes to row + 1 — the two-side contract the
    plan-layer decode (core.abft.gemm.decode_columns) relies on."""
    rng = np.random.default_rng(5)
    x = rng.standard_normal((256, 128)).astype(np.float32)
    w = rng.standard_normal((128, 128)).astype(np.float32)
    row, col, eps = 201, 13, 1000.0
    res = ft_matmul_pallas(jnp.asarray(x), jnp.asarray(w),
                           inject=jnp.array([row, col, 1.0, eps]))
    want = x @ w
    assert abs(np.asarray(res.c)[row, col] - want[row, col] - eps) < 1e-2
    d2 = np.asarray(res.pred2) - np.asarray(res.out2)
    d3 = np.asarray(res.pred3) - np.asarray(res.out3)
    div = np.abs(d2)
    assert div[col] > 100.0  # corrupted column flagged
    assert np.median(div) < 1.0
    assert abs(d3[col] / d2[col] - (row + 1)) < 0.05  # location decodes


def test_ft_matmul_kernel_rejects_unaligned():
    with pytest.raises(ValueError, match="tile-aligned"):
        ft_matmul_pallas(jnp.zeros((100, 128)), jnp.zeros((128, 128)))
