"""Expert-parallel MoE (shard_map) vs the portable scatter path."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_ep_matches_portable():
    out = run_py("""
import dataclasses, numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.models.moe import make_moe_params, moe_block, _moe_block_portable

cfg = dataclasses.replace(get_smoke_config('deepseek_v3_671b'),
                          num_experts=8, top_k=2, capacity_factor=8.0,
                          dtype='float32')
params = make_moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                      jnp.float32)
y_ref, aux_ref = _moe_block_portable(params, x, cfg)

mesh = jax.make_mesh((2, 4), ('data', 'model'))
xs = jax.device_put(x, NamedSharding(mesh, P('data', None, None)))
ps = {k: jax.device_put(v, NamedSharding(mesh, P())) if k == 'router' or
      isinstance(v, dict) else v for k, v in params.items()}
ps = jax.tree_util.tree_map(lambda l: l, ps)
for k in ('wi_gate', 'wi_up', 'wo'):
    ps[k] = jax.device_put(params[k], NamedSharding(mesh,
                                                    P('model', None, None)))
with mesh:
    y_ep, aux_ep = jax.jit(lambda p, v: moe_block(p, v, cfg))(ps, xs)
err = float(jnp.abs(y_ep - y_ref).max() / (jnp.abs(y_ref).max() + 1e-9))
assert err < 2e-5, err
assert np.isfinite(float(aux_ep))
print('OK', err)
""")
    assert "OK" in out


def test_ep_collectives_are_one_psum_per_layer():
    """The EP path's wire cost is one (T_local, d) psum, not buffer-sized
    all-reduces (the §Perf Cell-1 property)."""
    out = run_py("""
import dataclasses, re, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.models.moe import make_moe_params, moe_block

cfg = dataclasses.replace(get_smoke_config('deepseek_v3_671b'),
                          num_experts=8, top_k=2, dtype='float32')
params = jax.eval_shape(lambda: make_moe_params(jax.random.PRNGKey(0), cfg,
                                                jnp.float32))
mesh = jax.make_mesh((2, 4), ('data', 'model'))
def sds(l, sp):
    return jax.ShapeDtypeStruct(l.shape, l.dtype,
                                sharding=NamedSharding(mesh, sp))
ps = jax.tree_util.tree_map(lambda l: sds(l, P()), params)
for k in ('wi_gate', 'wi_up', 'wo'):
    ps[k] = sds(params[k], P('model', None, None))
x = sds(jax.ShapeDtypeStruct((4, 16, cfg.d_model), jnp.float32),
        P('data', None, None))
with mesh:
    hlo = jax.jit(lambda p, v: moe_block(p, v, cfg)).lower(ps, x
        ).compile().as_text()
# forward-only: exactly the combine psum crosses `model`; the expert buffer
# (e_local*cap, d) never appears in a collective
big_collectives = [l for l in hlo.splitlines()
                   if re.search(r'all-(reduce|gather)', l)
                   and f'{8 * 64}' in l]
print('n_allreduce:', hlo.count(' all-reduce('))
assert hlo.count(' all-reduce(') <= 3
print('OK')
""")
    assert "OK" in out
