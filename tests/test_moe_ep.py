"""Expert-parallel MoE (shard_map) vs the portable scatter path."""
import pytest

from conftest import run_py

pytestmark = pytest.mark.slow  # every test compiles on an 8-way subprocess


def test_ep_matches_portable():
    out = run_py("""
import dataclasses, numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.models.moe import make_moe_params, moe_block, _moe_block_portable

cfg = dataclasses.replace(get_smoke_config('deepseek_v3_671b'),
                          num_experts=8, top_k=2, capacity_factor=8.0,
                          dtype='float32')
params = make_moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                      jnp.float32)
y_ref, aux_ref = _moe_block_portable(params, x, cfg)

mesh = jax.make_mesh((2, 4), ('data', 'model'))
xs = jax.device_put(x, NamedSharding(mesh, P('data', None, None)))
ps = {k: jax.device_put(v, NamedSharding(mesh, P())) if k == 'router' or
      isinstance(v, dict) else v for k, v in params.items()}
ps = jax.tree_util.tree_map(lambda l: l, ps)
for k in ('wi_gate', 'wi_up', 'wo'):
    ps[k] = jax.device_put(params[k], NamedSharding(mesh,
                                                    P('model', None, None)))
with mesh:
    y_ep, aux_ep = jax.jit(lambda p, v: moe_block(p, v, cfg))(ps, xs)
err = float(jnp.abs(y_ep - y_ref).max() / (jnp.abs(y_ref).max() + 1e-9))
assert err < 2e-5, err
assert np.isfinite(float(aux_ep))
print('OK', err)
""")
    assert "OK" in out


def test_ep_collectives_are_one_psum_per_layer():
    """The EP path's wire cost is one (T_local, d) psum, not buffer-sized
    all-reduces (the §Perf Cell-1 property)."""
    out = run_py(r"""
import dataclasses, re, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.models.moe import make_moe_params, moe_block

cfg = dataclasses.replace(get_smoke_config('deepseek_v3_671b'),
                          num_experts=8, top_k=2, dtype='float32')
params = jax.eval_shape(lambda: make_moe_params(jax.random.PRNGKey(0), cfg,
                                                jnp.float32))
mesh = jax.make_mesh((2, 4), ('data', 'model'))
def sds(l, sp):
    return jax.ShapeDtypeStruct(l.shape, l.dtype,
                                sharding=NamedSharding(mesh, sp))
ps = jax.tree_util.tree_map(lambda l: sds(l, P()), params)
for k in ('wi_gate', 'wi_up', 'wo'):
    ps[k] = sds(params[k], P('model', None, None))
x = sds(jax.ShapeDtypeStruct((4, 16, cfg.d_model), jnp.float32),
        P('data', None, None))
with mesh:
    hlo = jax.jit(lambda p, v: moe_block(p, v, cfg)).lower(ps, x
        ).compile().as_text()
# forward-only: only token-sized collectives (the (T, d) combine psum and
# the output gather the replicated test harness forces) cross the wire —
# the expert buffer (E*cap = 512 rows) must never appear in a collective.
# Exact instruction counts vary across XLA partitioner versions, so assert
# the *size* property the docstring claims, not a count. The dryrun HLO
# parser handles tuple-shaped and async (-start) collective forms.
from repro.launch.dryrun import COLLECTIVE_RE, _shape_bytes
buffer_bytes = 8 * 64 * cfg.d_model * 4
big = []
for l in hlo.splitlines():
    m = COLLECTIVE_RE.search(l)
    if m and _shape_bytes(l, m.group(1)) >= buffer_bytes:
        big.append(l)
print('n_allreduce:', hlo.count(' all-reduce('))
assert not big, big[:2]
print('OK')
""")
    assert "OK" in out
