"""Serving loop: greedy decode against the cache matches teacher forcing."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.launch.serve import decode
from repro.models import Model

# token-by-token decode loops against full model configs dominate wall time
pytestmark = pytest.mark.slow


@pytest.mark.parametrize("arch", ["gemma3_1b", "recurrentgemma_2b",
                                  "deepseek_v3_671b"])
def test_greedy_decode_matches_teacher_forced_forward(arch):
    """The decode path's logits must match the full forward pass on the
    sequence the decoder actually produced (teacher-forced comparison —
    free-running argmax can tie-flip on random-init logits at ~1e-6)."""
    # ample expert capacity: capacity-dropping is batch-composition
    # dependent (GShard semantics), which legitimately breaks exact
    # prefill/decode equivalence — not what this test is about
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32",
                              capacity_factor=8.0)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 6)), jnp.int32)
    gen = 5
    toks = decode(model, params, prompts, gen, max_len=32)
    assert toks.shape == (2, gen)

    # teacher-forced: full forward over prompt + generated tokens; the
    # decode-path logits at every position must agree with the parallel pass
    seq = jnp.concatenate([prompts, toks], axis=1)
    full, _ = model.apply(params, {"tokens": seq}, block_q=0)
    cache = model.init_cache(batch=2, max_len=32, dtype=jnp.float32)
    for i in range(seq.shape[1] - 1):
        dec, cache, _ = model.decode_step(params, cache, seq[:, i:i + 1],
                                          jnp.int32(i))
        np.testing.assert_allclose(
            np.asarray(dec[:, 0]), np.asarray(full[:, i]), atol=2e-4 *
            float(jnp.abs(full[:, i]).max()))


def test_decode_throughput_metrics():
    cfg = dataclasses.replace(get_smoke_config("phi4_mini_3p8b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    prompts = jnp.zeros((2, 4), jnp.int32)
    toks = decode(model, params, prompts, 4, max_len=16)
    assert toks.shape == (2, 4)
    assert int(toks.max()) < cfg.vocab_size
