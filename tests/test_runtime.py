"""Distributed-runtime substrate: optimizer, data, checkpoint, FT loop."""
import dataclasses
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import optim
from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.configs import get_smoke_config
from repro.configs.base import ParallelConfig, RunConfig
from repro.data import TokenPipeline, make_batch
from repro.models import Model
from repro.train import make_train_step, make_serve_step


# ---------------------------------------------------------------------------
# config registry
# ---------------------------------------------------------------------------

def test_config_registry_builds_every_arch():
    """Every registered architecture (and its assigned-id alias) yields a
    coherent full + smoke config pair — a bad config file should fail here
    in the fast lane, not at train/serve launch."""
    from repro.configs import ARCHS, _ALIASES, get_config

    for name in ARCHS + list(_ALIASES):
        full = get_config(name)
        if not hasattr(full, "vocab_size"):
            continue   # the paper's FFT workload config, not a model
        smoke = get_smoke_config(name)
        for cfg in (full, smoke):
            assert cfg.vocab_size > 0 and cfg.num_layers > 0
            assert cfg.d_model % max(cfg.num_heads, 1) == 0
        # smoke configs must actually be reduced (CPU-runnable)
        assert smoke.num_layers <= full.num_layers
        assert smoke.d_model <= full.d_model


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def _toy_params(key):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (8, 8)),
            "b": jnp.zeros((8,))}


def test_adamw_decreases_quadratic():
    params = _toy_params(jax.random.PRNGKey(0))
    target = _toy_params(jax.random.PRNGKey(1))
    state = optim.init_state(params)

    def loss(p):
        return sum(jnp.sum((a - b) ** 2) for a, b in
                   zip(jax.tree_util.tree_leaves(p),
                       jax.tree_util.tree_leaves(target)))

    l0 = float(loss(params))
    for i in range(100):
        g = jax.grad(loss)(params)
        params, state, info = optim.apply_updates(
            params, g, state, lr=jnp.float32(3e-2), weight_decay=0.0)
    assert float(loss(params)) < 0.2 * l0
    assert int(state.step) == 100


def test_adamw_skips_nonfinite():
    params = _toy_params(jax.random.PRNGKey(0))
    state = optim.init_state(params)
    bad = jax.tree_util.tree_map(lambda p: jnp.full_like(p, jnp.nan), params)
    p2, s2, info = optim.apply_updates(params, bad, state,
                                       lr=jnp.float32(1e-2))
    assert float(info["skipped"]) == 1.0
    for a, b in zip(jax.tree_util.tree_leaves(p2),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(s2.step) == 0  # update not counted


def test_cosine_schedule_shape():
    lrs = [float(optim.cosine_schedule(jnp.int32(s), base_lr=1.0,
                                       warmup_steps=10, total_steps=100))
           for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 <= lrs[4] <= 0.2  # decayed to ~min_ratio


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_data_deterministic_and_step_addressable():
    a = make_batch(1, 7, batch=8, seq_len=32, vocab_size=1000)
    b = make_batch(1, 7, batch=8, seq_len=32, vocab_size=1000)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = make_batch(1, 8, batch=8, seq_len=32, vocab_size=1000)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_data_host_sharding_partitions_global_batch():
    full = make_batch(3, 0, batch=8, seq_len=16, vocab_size=100)
    shards = [make_batch(3, 0, batch=8, seq_len=16, vocab_size=100,
                         shard=i, num_shards=4) for i in range(4)]
    assert all(s["tokens"].shape == (2, 16) for s in shards)
    # shards differ from each other (independent streams per shard)
    assert not np.array_equal(shards[0]["tokens"], shards[1]["tokens"])


def test_data_vocab_bounds():
    b = make_batch(0, 0, batch=4, seq_len=64, vocab_size=512)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 512


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    save_checkpoint(str(tmp_path), 5, tree, extra={"note": "x"})
    assert latest_step(str(tmp_path)) == 5
    template = jax.tree_util.tree_map(jnp.zeros_like, tree)
    restored, meta = restore_checkpoint(str(tmp_path), template)
    assert meta["step"] == 5 and meta["note"] == "x"
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.ones((3,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    mgr.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]  # retention


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.ones((2,))})
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


# ---------------------------------------------------------------------------
# train loop end-to-end (tiny model learns the synthetic stream)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_train_loop_loss_decreases(tmp_path):
    cfg = dataclasses.replace(get_smoke_config("gemma3_1b"),
                              vocab_size=256, num_layers=4)
    run = RunConfig(model=cfg, parallel=ParallelConfig(remat="none"),
                    learning_rate=1e-3, warmup_steps=5, total_steps=60)
    model = Model(cfg)
    pipe = TokenPipeline(seed=0, batch=8, seq_len=64, vocab_size=256)
    params = model.init(jax.random.PRNGKey(0))
    state = optim.init_state(params)
    step_fn = jax.jit(make_train_step(model, run))
    losses = []
    for step in range(40):
        batch = {k: jnp.asarray(v) for k, v in pipe(step).items()}
        params, state, m = step_fn(params, state, batch, jnp.int32(step))
        losses.append(float(m["ce"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3
    assert all(np.isfinite(losses))


@pytest.mark.slow
def test_train_restart_determinism(tmp_path):
    """checkpoint/restart reproduces the uninterrupted run exactly."""
    cfg = dataclasses.replace(get_smoke_config("phi3_medium_14b"),
                              vocab_size=128, num_layers=2)
    run = RunConfig(model=cfg, parallel=ParallelConfig(remat="none"),
                    learning_rate=1e-3, warmup_steps=2, total_steps=20)
    model = Model(cfg)
    pipe = TokenPipeline(seed=1, batch=4, seq_len=32, vocab_size=128)
    step_fn = jax.jit(make_train_step(model, run))

    def run_steps(params, state, a, b):
        for s in range(a, b):
            batch = {k: jnp.asarray(v) for k, v in pipe(s).items()}
            params, state, m = step_fn(params, state, batch, jnp.int32(s))
        return params, state, m

    p0 = model.init(jax.random.PRNGKey(0))
    s0 = optim.init_state(p0)
    # uninterrupted
    p_a, s_a, m_a = run_steps(p0, s0, 0, 10)
    # interrupted at 5 + restored
    p_b, s_b, _ = run_steps(p0, s0, 0, 5)
    save_checkpoint(str(tmp_path), 4, (p_b, s_b))
    (p_r, s_r), _ = restore_checkpoint(str(tmp_path),
                                       (jax.tree_util.tree_map(
                                           jnp.zeros_like, p_b), s_b))
    p_c, s_c, m_c = run_steps(p_r, s_r, 5, 10)
    np.testing.assert_allclose(float(m_a["loss"]), float(m_c["loss"]),
                               rtol=1e-5)


@pytest.mark.slow
def test_microbatched_matches_full_batch():
    cfg = dataclasses.replace(get_smoke_config("phi4_mini_3p8b"),
                              vocab_size=128, num_layers=2)
    model = Model(cfg)
    pipe = TokenPipeline(seed=2, batch=8, seq_len=16, vocab_size=128)
    batch = {k: jnp.asarray(v) for k, v in pipe(0).items()}
    params = model.init(jax.random.PRNGKey(0))

    outs = {}
    for micro in (1, 2):
        run = RunConfig(model=cfg,
                        parallel=ParallelConfig(remat="none",
                                                microbatch=micro),
                        learning_rate=1e-3, warmup_steps=1, total_steps=10)
        step_fn = jax.jit(make_train_step(model, run))
        p, s, m = step_fn(params, optim.init_state(params), batch,
                          jnp.int32(0))
        outs[micro] = (p, float(m["loss"]))
    np.testing.assert_allclose(outs[1][1], outs[2][1], rtol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(outs[1][0]),
                    jax.tree_util.tree_leaves(outs[2][0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-4)
