"""Mesh-sharded distributed FFT: host-mesh equivalence, sharded ABFT,
plan/volume invariants. Multi-device cases run in a subprocess (the XLA
host-device-count flag must be set before jax initializes).
"""
import numpy as np
import pytest

from conftest import run_py

# ---------------------------------------------------------------------------
# in-process: plan + communication model + single-device fallback
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ln", [4, 10, 14, 17, 20, 23])
@pytest.mark.parametrize("shards", [1, 2, 4])
def test_dist_plan_divisible(ln, shards):
    from repro.core.fft.distributed import make_dist_plan

    n = 1 << ln
    if n < shards * shards:
        pytest.skip("pencil needs N >= shards^2")
    p = make_dist_plan(n, shards)
    assert p.n1 * p.n2 == n
    assert p.n1 % shards == 0 and p.n2 % shards == 0
    assert p.local_in == (p.n1, p.n2 // shards)
    assert p.local_out == (p.n1 // shards, p.n2)


def test_dist_plan_rejects_bad_sizes():
    from repro.core.fft.distributed import make_dist_plan

    with pytest.raises(ValueError):
        make_dist_plan(100, 2)  # not a power of two
    with pytest.raises(ValueError):
        make_dist_plan(1 << 14, 3)  # non-power-of-two shards
    with pytest.raises(ValueError):
        make_dist_plan(8, 4)  # N < shards^2


def test_collective_volume_model():
    """One all-to-all; ABFT adds 2/B volume + scalars; transposed order
    skips the natural-order gather entirely."""
    from repro.core.fft.distributed import collective_volume

    n, b, d = 1 << 17, 8, 4
    plain = collective_volume(n, b, d)
    ft = collective_volume(n, b, d, ft=True)
    transposed = collective_volume(n, b, d, natural_order=False)
    assert plain["passes"] == 2
    assert plain["all_to_all_wire"] == b * n * 8 / d * (d - 1) / d
    assert ft["abft_overhead"] == pytest.approx(2 / b)
    assert ft["all_to_all_wire"] == pytest.approx(
        plain["all_to_all_wire"] * (b + 2) / b)
    assert transposed["gather_wire"] == 0.0
    assert transposed["total_wire"] < plain["total_wire"]


def test_single_device_fallback_matches_local(crand, assert_spectrum_close):
    """mesh=None (and ops.fft without a mesh) is exactly the local path."""
    from repro.core.fft.distributed import distributed_fft
    from repro.kernels import ops

    x = crand(2, 1 << 10)
    assert_spectrum_close(distributed_fft(x), np.fft.fft(x))
    assert_spectrum_close(ops.fft(x), np.fft.fft(x))


# ---------------------------------------------------------------------------
# host-mesh equivalence (subprocess, 4 devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("shards", [1, 2, 4])
def test_distributed_fft_matches_numpy(shards):
    """1/2/4-way shardings vs jnp.fft.fft over N = 2^14 .. 2^17, plus the
    sharded ifft roundtrip and the transposed-order digit permutation."""
    out = run_py(f"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.fft.distributed import (distributed_fft, distributed_ifft,
                                        make_dist_plan)
shards = {shards}
mesh = jax.make_mesh((shards,), ("fft",)) if shards > 1 else None
rng = np.random.default_rng(shards)
for ln in (14, 15, 16, 17):
    n = 1 << ln
    x = (rng.standard_normal((2, n)) + 1j * rng.standard_normal((2, n))
         ).astype(np.complex64)
    ref = np.asarray(jnp.fft.fft(x))
    y = np.asarray(distributed_fft(x, mesh))
    err = np.abs(y - ref).max() / np.abs(ref).max()
    assert err < 4e-5, (ln, err)
    back = np.asarray(distributed_ifft(jnp.asarray(y), mesh))
    rerr = np.abs(back - x).max() / np.abs(x).max()
    assert rerr < 4e-5, (ln, rerr)
    if mesh is not None:
        # transposed order is the natural order under the (n1, n2) digit swap
        p = make_dist_plan(n, shards)
        yt = np.asarray(distributed_fft(x, mesh, natural_order=False))
        perm = yt.reshape(2, p.n1, p.n2).transpose(0, 2, 1).reshape(2, n)
        assert np.abs(perm - ref).max() / np.abs(ref).max() < 4e-5
print('OK')
""", devices=4)
    assert "OK" in out


@pytest.mark.slow
def test_ops_fft_auto_dispatches_on_sharded_input():
    """kernels.ops.fft routes to the distributed path when the operand is
    committed to an fft-axis mesh (and when a mesh is passed explicitly)."""
    out = run_py("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.fft import FFTSpec, plan
from repro.kernels import ops
from repro.launch.mesh import make_fft_mesh
from repro.parallel import shard_signals, infer_fft_mesh
mesh = make_fft_mesh(4)
rng = np.random.default_rng(0)
x = (rng.standard_normal((2, 1 << 14)) +
     1j * rng.standard_normal((2, 1 << 14))).astype(np.complex64)
ref = np.fft.fft(x)
xs = shard_signals(x, mesh)
assert infer_fft_mesh(xs) is mesh
y1 = np.asarray(ops.fft(xs))             # inferred from committed sharding
p = plan(FFTSpec(shape=x.shape, mesh=mesh))   # explicit plan
y2 = np.asarray(p.fft(x))
for y in (y1, y2):
    assert np.abs(y - ref).max() / np.abs(ref).max() < 4e-5
back = np.asarray(p.ifft(jnp.asarray(y2)))
assert np.abs(back - x).max() / np.abs(x).max() < 4e-5
print('OK')
""", devices=4)
    assert "OK" in out


# ---------------------------------------------------------------------------
# sharded two-side ABFT (subprocess, 4 devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_abft_detects_and_corrects_nonlocal_fault():
    """An SEU injected on device 2 mid-pipeline (after pass 1) is detected,
    located to the right signal, and corrected — with the verdict reduced on
    a *different* shard (device 0 reads it), proving the psum'd right-side
    checksums work across the mesh. Clean runs never false-alarm."""
    out = run_py("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.fft.distributed import ft_distributed_fft
mesh = jax.make_mesh((4,), ("fft",))
rng = np.random.default_rng(7)
b, n = 8, 1 << 14
x = (rng.standard_normal((b, n)) + 1j * rng.standard_normal((b, n))
     ).astype(np.complex64)
ref = np.fft.fft(x)

clean = ft_distributed_fft(x, mesh)
assert not bool(clean.flagged.any()), np.asarray(clean.group_score)
assert float(jnp.max(clean.shard_delta)) < 1e-4
assert np.abs(np.asarray(clean.y) - ref).max() / np.abs(ref).max() < 4e-5

# device 2 holds the fault; the verdict consumed from shard 0's copy
inj = jnp.asarray([2, 5, 7, 3, 1, 60.0, -25.0], jnp.float32)
res = ft_distributed_fft(x, mesh, inject=inj)
assert bool(res.flagged.all()) and bool(res.correctable.all())
assert int(res.location[0]) == 5
assert int(res.corrected) == 1
err = np.abs(np.asarray(res.y) - ref).max() / np.abs(ref).max()
assert err < 1e-4, err

# without correction the propagated error persists in the output
bad = ft_distributed_fft(x, mesh, inject=inj, correct=False)
res_err = np.abs(np.asarray(bad.y) - ref).max() / np.abs(ref).max()
assert res_err > 1e-2, res_err
print('OK')
""", devices=4)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_abft_fp64_telemetry():
    """complex128 inputs keep residuals/scores/injection in float64
    (regression: float32 scaling constants and a float32 inject path
    downcast the fp64 telemetry), so thresholds far below float32
    resolution work: a clean run scores < 1e-12 while a 1e-6-magnitude
    SEU — invisible at float32 — is detected, located, and corrected."""
    out = run_py("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.fft.distributed import ft_distributed_fft
mesh = jax.make_mesh((4,), ("fft",))
rng = np.random.default_rng(11)
b, n = 8, 1 << 14
x = (rng.standard_normal((b, n)) + 1j * rng.standard_normal((b, n))
     ).astype(np.complex128)
ref = np.fft.fft(x)

clean = ft_distributed_fft(x, mesh, threshold=1e-10)
assert clean.group_score.dtype == jnp.float64, clean.group_score.dtype
assert clean.shard_delta.dtype == jnp.float64
assert float(jnp.max(clean.group_score)) < 1e-12
assert float(jnp.max(clean.shard_delta)) < 1e-12
assert not bool(clean.flagged.any())
assert np.abs(np.asarray(clean.y) - ref).max() / np.abs(ref).max() < 1e-11

# an SEU far below float32 visibility, caught by the fp64 pipeline
inj = jnp.asarray([1, 3, 2, 5, 1, 1e-6, -1e-6], jnp.float64)
res = ft_distributed_fft(x, mesh, threshold=1e-10, inject=inj)
assert bool(res.flagged.all()), np.asarray(res.group_score)
assert int(res.location[0]) == 3
assert int(res.corrected) == 1
err = np.abs(np.asarray(res.y) - ref).max() / np.abs(ref).max()
assert err < 1e-11, err
print('OK')
""", devices=4)
    assert "OK" in out
