"""Transposed-order spectral consumers: convolve/correlate/power_spectrum vs
numpy on the local path, plus the sharded invariants — the forward+inverse
transposed round trip is exact and lowers to exactly TWO all-to-alls and
ZERO all-gathers, and fft_convolve on the 2-D batch x pencil mesh matches
jnp.convolve. Multi-device checks run in one consolidated subprocess (the
XLA host-device-count flag must precede jax init) sized to stay in the fast
lane.
"""
import numpy as np
import pytest

from conftest import run_py

# ---------------------------------------------------------------------------
# in-process: local path vs numpy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["full", "same", "valid"])
def test_convolve_local_matches_numpy(mode, rng):
    from repro.core.fft.spectral import fft_convolve

    a = rng.standard_normal((3, 200)).astype(np.float32)
    v = rng.standard_normal(31).astype(np.float32)
    got = np.asarray(fft_convolve(a, v, mode=mode))
    want = np.stack([np.convolve(r, v, mode) for r in a])
    assert got.shape == want.shape
    assert got.dtype == np.float32          # real in -> real out
    np.testing.assert_allclose(got, want, atol=2e-5 * np.abs(want).max())


@pytest.mark.parametrize("mode", ["full", "same", "valid"])
def test_correlate_local_matches_numpy(mode, crand):
    from repro.core.fft.spectral import correlate

    a = crand(2, 160)
    v = crand(1, 24)[0]
    got = np.asarray(correlate(a, v, mode=mode))
    want = np.stack([np.correlate(r, v, mode) for r in a])
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, atol=4e-5 * np.abs(want).max())


def test_convolve_per_signal_kernels(crand, assert_spectrum_close):
    """A batch of kernels (one per signal) convolves row-wise."""
    from repro.core.fft.spectral import fft_convolve

    a = crand(4, 120)
    v = crand(4, 17)
    got = np.asarray(fft_convolve(a, v))
    want = np.stack([np.convolve(r, k, "full") for r, k in zip(a, v)])
    assert_spectrum_close(got, want)


def test_power_spectrum_local(crand):
    from repro.core.fft.spectral import power_spectrum

    x = crand(3, 512)
    got = np.asarray(power_spectrum(x))
    want = np.abs(np.fft.fft(x)) ** 2 / 512
    assert not np.iscomplexobj(got)
    np.testing.assert_allclose(got, want, atol=1e-4 * want.max())


def test_spectral_volume_model():
    """Two all-to-alls, zero gathers; the kernel rides the forward one."""
    from repro.core.fft.distributed import collective_volume, spectral_volume

    n, b, d = 1 << 14, 8, 4
    rt = spectral_volume(n, b, d)
    cv = spectral_volume(n, b, d, kernel_batch=1)
    plain = collective_volume(n, b, d, natural_order=False)
    assert rt["all_to_all_count"] == 2 and rt["all_gather_count"] == 0
    assert rt["gather_wire"] == 0.0
    # round trip = forward + equally-sized inverse transpose
    assert rt["hlo_bytes"] == pytest.approx(2 * plain["hlo_bytes"])
    # the kernel's spectrum adds 1/B of the forward volume, nothing more
    assert cv["hlo_bytes"] - rt["hlo_bytes"] == pytest.approx(
        plain["hlo_bytes"] / b)
    # 2-D mesh: each data shard moves 1/data of the rows
    half = spectral_volume(n, b, d, data_shards=2)
    assert half["hlo_bytes"] == pytest.approx(rt["hlo_bytes"] / 2)


def test_collective_volume_psum_tracks_itemsize():
    """The grouped ABFT verdict traffic is 8 scalars per checksum group
    (3 verdict-psum + 5 replicated-stats broadcast) plus one shared energy
    scalar, in the input's REAL dtype: f64 for complex128 — the model must
    scale with both the group count and the itemsize. The UNGROUPED
    pipeline reduces its native stats scalars instead: 3 predicate flags
    (1B), the score in the real dtype, and an s32 location."""
    from repro.core.fft.distributed import collective_volume

    n, b, d = 1 << 14, 8, 4

    def psum_bytes(itemsize, groups=1):
        # transposed order isolates the psum: same a2a rows, no gather
        ft = collective_volume(n, b, d, ft=True, natural_order=False,
                               itemsize=itemsize, groups=groups)
        plain = collective_volume(n, b + 2 * groups, d, natural_order=False,
                                  itemsize=itemsize)
        return ft["hlo_bytes"] - plain["hlo_bytes"]

    assert psum_bytes(8) == pytest.approx(2.0 * (4 * 4 + 3 + 4 + 4))
    # pre-fix the verdict+score were f32-sized under complex128:
    assert psum_bytes(16) == pytest.approx(2.0 * (4 * 8 + 3 + 8 + 4))
    assert psum_bytes(8, groups=4) == pytest.approx(2.0 * 33 * 4)
    # grouped + data-sharded: each device psums only its own groups' stats
    half = collective_volume(n, b, d, ft=True, natural_order=False,
                             groups=4, data_shards=2)
    full = collective_volume(n, b, d, ft=True, natural_order=False, groups=4)
    assert half["psum_wire"] == pytest.approx(
        2.0 * 17 * 4 * (d - 1) / d)
    assert half["all_to_all_wire"] == pytest.approx(
        full["all_to_all_wire"] / 2)


# ---------------------------------------------------------------------------
# sharded invariants (one subprocess, 4 devices, fast-lane sized)
# ---------------------------------------------------------------------------


def test_transposed_order_invariants_and_convolve_on_mesh():
    """(1) ifft_t(fft_t(x)) == x with exactly 2 all-to-alls and 0 all-gathers
    (collective_bytes on the composed jit); (2) fft_convolve on the 2-D
    data x fft mesh matches jnp.convolve and meets the same collective
    budget, with HLO bytes equal to spectral_volume's model; (3) the
    kernels.ops entry points thread natural_order through."""
    out = run_py("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.fft.distributed import (distributed_fft, distributed_ifft,
                                        spectral_volume)
from repro.core.fft import spectral
from repro.launch.dryrun import collective_bytes
from repro.launch.mesh import make_fft_mesh

rng = np.random.default_rng(3)
b, n = 8, 1 << 12
x = (rng.standard_normal((b, n)) + 1j * rng.standard_normal((b, n))
     ).astype(np.complex64)

# ---- 1-D fft mesh: transposed round trip -------------------------------
mesh = jax.make_mesh((4,), ("fft",))
yt = distributed_fft(x, mesh, natural_order=False)
back = np.asarray(distributed_ifft(yt, mesh, natural_order=False))
assert np.abs(back - x).max() / np.abs(x).max() < 4e-5

rt = jax.jit(lambda v: distributed_ifft(
    distributed_fft(v, mesh, natural_order=False), mesh,
    natural_order=False))
cb = collective_bytes(rt.lower(jnp.asarray(x)).compile().as_text())
assert cb["count"]["all-to-all"] == 2, cb["count"]
assert cb["count"]["all-gather"] == 0, cb["count"]
assert cb["bytes"]["all-gather"] == 0.0
mdl = spectral_volume(n, b, 4)
assert abs(cb["total_bytes"] / mdl["hlo_bytes"] - 1.0) < 1e-3

# plan-level threading: the same pipeline through the plan executors
from repro.core.fft import FFTSpec, plan
pt = plan(FFTSpec(shape=x.shape, mesh=mesh, natural_order=False))
yt2 = pt.fft(x)
np.testing.assert_array_equal(np.asarray(yt2), np.asarray(yt))
back2 = np.asarray(pt.ifft(yt2))
assert np.abs(back2 - x).max() / np.abs(x).max() < 4e-5

# ragged batch exercises the pad+slice path (correctness, not budget)
x6 = x[:6]
back6 = np.asarray(distributed_ifft(
    distributed_fft(x6, mesh, natural_order=False), mesh,
    natural_order=False))
assert np.abs(back6 - x6).max() / np.abs(x6).max() < 4e-5

# ---- 2-D batch x pencil mesh: convolution end-to-end -------------------
mesh2 = make_fft_mesh(2, data=2)
assert dict(mesh2.shape) == {"data": 2, "fft": 2}
a = rng.standard_normal((b, 1500)).astype(np.float32)
v = rng.standard_normal(63).astype(np.float32)
got = np.asarray(spectral.fft_convolve(a, v, mesh2, mode="same"))
want = np.stack([np.asarray(jnp.convolve(jnp.asarray(r), jnp.asarray(v),
                                         "same")) for r in a])
assert got.shape == want.shape
assert np.abs(got - want).max() < 2e-4 * np.abs(want).max()

# the fused pipeline's collective budget on the 2-D mesh
aa = (rng.standard_normal((b, n)) + 1j * rng.standard_normal((b, n))
      ).astype(np.complex64)
vv = (rng.standard_normal((1, n)) + 1j * rng.standard_normal((1, n))
      ).astype(np.complex64)
fn = spectral._spectral_pair_fn(mesh2, "fft", "data", False)
cb2 = collective_bytes(
    fn.lower(jnp.asarray(aa), jnp.asarray(vv)).compile().as_text())
assert cb2["count"]["all-to-all"] == 2, cb2["count"]
assert cb2["count"]["all-gather"] == 0, cb2["count"]
mdl2 = spectral_volume(n, b, 2, kernel_batch=1, data_shards=2)
assert abs(cb2["total_bytes"] / mdl2["hlo_bytes"] - 1.0) < 1e-3

# transposed power spectrum: bins permuted, energy identical
ps = np.asarray(spectral.power_spectrum(aa, mesh2))
ref = np.abs(np.fft.fft(aa)) ** 2 / n
assert np.abs(np.sort(ps, -1) - np.sort(ref, -1)).max() < 1e-4 * ref.max()

# ragged batch on the 2-D mesh (regression: the pad quantum ignored the
# fft-shard factor when data did not divide, then raised mid-pipeline)
x5 = x[:5]
back5 = np.asarray(distributed_ifft(
    distributed_fft(x5, mesh2, natural_order=False), mesh2,
    natural_order=False))
assert np.abs(back5 - x5).max() / np.abs(x5).max() < 4e-5
got5 = np.asarray(spectral.fft_convolve(a[:5], v, mesh2, mode="same"))
assert np.abs(got5 - want[:5]).max() < 2e-4 * np.abs(want).max()
print('OK')
""", devices=4)
    assert "OK" in out
