"""Elastic restart: restoring a checkpoint onto a DIFFERENT (smaller) mesh.

Checkpoints are stored unsharded, so elasticity is a pure re-shard —
``elastic_restore`` must place params and optimizer moments by the NEW
mesh's param specs and replicate the step counter, regardless of the
geometry the checkpoint was written under.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import run_py
from repro import optim
from repro.checkpoint import save_checkpoint
from repro.launch.elastic import HeartbeatMonitor, elastic_restore


def _toy_state(seed=0):
    k = jax.random.PRNGKey(seed)
    params = {"layer": {"w": jax.random.normal(k, (16, 8)),
                        "b": jnp.zeros((8,))}}
    return params, optim.init_state(params)


def test_elastic_restore_single_device(tmp_path):
    """The restore path itself (fast lane, 1-device mesh): values survive
    the round trip and every leaf lands on the target mesh."""
    params, opt = _toy_state()
    opt = type(opt)(step=jnp.int32(7), mu=opt.mu, nu=opt.nu)
    save_checkpoint(str(tmp_path), 7, (params, opt))

    mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model"))
    template = (jax.tree_util.tree_map(jnp.zeros_like, params), opt)
    (p_r, o_r), meta = elastic_restore(str(tmp_path), template, mesh)

    jax.tree_util.tree_map(np.testing.assert_allclose, p_r, params)
    jax.tree_util.tree_map(np.testing.assert_allclose, o_r.mu, opt.mu)
    assert int(o_r.step) == 7
    for leaf in jax.tree_util.tree_leaves(p_r):
        assert leaf.sharding.mesh.shape == mesh.shape


def test_heartbeat_monitor_flags_persistent_straggler():
    mon = HeartbeatMonitor(num_hosts=4, straggle_factor=2.0, patience=2)
    fast = np.array([1.0, 1.0, 1.0, 1.0])
    slow = np.array([1.0, 1.0, 5.0, 1.0])
    assert mon.observe(slow) == []          # first strike: not yet flagged
    assert mon.observe(slow) == [2]         # persistent -> excluded
    assert mon.observe(fast) == []          # recovery resets the strikes
    assert mon.observe(slow) == []


@pytest.mark.slow
def test_elastic_restore_smaller_mesh(tmp_path):
    """Write a checkpoint from an FSDP-sharded 4x2 run, lose half the
    devices, and restore onto 2x2: same values, shardings rebuilt for the
    smaller mesh (the fail-stop elasticity contract)."""
    out = run_py(f"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding
from repro import optim
from repro.checkpoint import save_checkpoint
from repro.launch.elastic import elastic_restore
from repro.parallel import param_specs

k = jax.random.PRNGKey(0)
params = {{'layer': {{'w': jax.random.normal(k, (16, 8)),
                      'b': jnp.zeros((8,))}}}}
opt = optim.init_state(params)

# the "before" job: 4x2 mesh, leaves sharded by its param specs
big = jax.make_mesh((4, 2), ('data', 'model'))
specs = param_specs(params, big)
sharded = jax.tree_util.tree_map(
    lambda l, sp: jax.device_put(l, NamedSharding(big, sp)), params, specs)
save_checkpoint({str(tmp_path)!r}, 3, (sharded, opt))

# the "after" job: half the devices are gone
small = jax.make_mesh((2, 2), ('data', 'model'))
template = (jax.tree_util.tree_map(jnp.zeros_like, params), opt)
(p_r, o_r), meta = elastic_restore({str(tmp_path)!r}, template, small)
assert meta['step'] == 3, meta

for l_r, l in zip(jax.tree_util.tree_leaves(p_r),
                  jax.tree_util.tree_leaves(params)):
    np.testing.assert_allclose(np.asarray(l_r), np.asarray(l))
    assert l_r.sharding.mesh.shape == small.shape, l_r.sharding
# optimizer moments follow the params; the step counter is replicated
for l in jax.tree_util.tree_leaves(o_r.mu):
    assert l.sharding.mesh.shape == small.shape
assert o_r.step.sharding.is_fully_replicated
assert int(o_r.step) == 0
print('OK')
""")
    assert "OK" in out
