"""Core FFT library: plans, pure-JAX Stockham, large-N driver vs numpy.

Shared rng / complex-batch / tolerance helpers come from conftest.py
(``crand`` / ``assert_spectrum_close`` fixtures).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import fft as tfft


@pytest.mark.parametrize("n", [2, 4, 8, 16, 32, 64, 128, 256, 512, 1024,
                               2048, 4096, 8192])
def test_fft_single_pass_matches_numpy(n, crand, assert_spectrum_close):
    x = crand(4, n)
    assert_spectrum_close(tfft.fft(x), np.fft.fft(x))


@pytest.mark.parametrize("n", [1 << 14, 1 << 16, 1 << 17, 1 << 20])
def test_fft_multi_pass_matches_numpy(n, crand, assert_spectrum_close):
    x = crand(2, n)
    assert_spectrum_close(tfft.fft(x), np.fft.fft(x))


@pytest.mark.parametrize("n", [64, 1024, 1 << 14])
def test_ifft_roundtrip(n, crand, assert_spectrum_close):
    x = crand(3, n)
    assert_spectrum_close(tfft.ifft(tfft.fft(x)), x)


def test_fft_complex128(crand, assert_spectrum_close):
    x = crand(2, 1024, np.complex128)
    assert_spectrum_close(tfft.fft(x), np.fft.fft(x))


def test_naive_dft_and_radix2_agree(crand):
    x = crand(2, 256)
    ref = np.fft.fft(x)
    np.testing.assert_allclose(np.asarray(tfft.naive_dft(jnp.asarray(x))), ref,
                               atol=3e-4 * np.abs(ref).max())
    np.testing.assert_allclose(np.asarray(tfft.radix2_fft(jnp.asarray(x))),
                               ref, atol=2e-5 * np.abs(ref).max())


def test_plan_regimes_match_paper_table():
    # paper Table 1: 1 pass for small, 2 for mid, 3 for large N
    assert tfft.make_plan(1 << 10).num_passes == 1
    assert tfft.make_plan(1 << 17).num_passes == 2
    assert tfft.make_plan(1 << 23).num_passes == 3
    for n in (1 << 10, 1 << 17, 1 << 23):
        p = tfft.make_plan(n)
        assert np.prod(p.kernel_factors) == n
        for f, stages in zip(p.kernel_factors, p.stages):
            assert np.prod([s.radix for s in stages]) == f


def test_block_radices_mxu_first():
    assert tfft.block_radices(128) == (128,)
    assert tfft.block_radices(1 << 13)[0] == 128
    for n in (8, 64, 512, 4096):
        assert np.prod(tfft.block_radices(n)) == n


def test_linearity(rng, crand):
    # FFT linearity is the foundation of the two-sided ABFT (paper Eqn. 3)
    a = crand(4, 512)
    e = (rng.standard_normal(4) + 1j * rng.standard_normal(4)).astype(
        np.complex64)
    lhs = np.asarray(tfft.fft(jnp.einsum("b,bn->n", jnp.asarray(e),
                                         jnp.asarray(a))))
    rhs = np.einsum("b,bn->n", e, np.asarray(tfft.fft(a)))
    np.testing.assert_allclose(lhs, rhs, atol=3e-4 * np.abs(rhs).max())
