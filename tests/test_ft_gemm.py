"""Two-side ABFT GEMM through the shared plan layer.

Covers the op-agnostic plan registry (one FTPolicy -> FFT *and* GEMM
plans), bitwise parity between the fused Pallas kernel and the XLA
interpreter path, the SEU injection matrix (tile corners, multi-fault
correction, same-column uncorrectable), batched activations, and the
key-traversal ``ft_dot_stats`` aggregation.
"""
import inspect

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import run_py

from repro.core import gemm
from repro.core import plan as planbase
from repro.core.abft import ft_matmul, ft_dot_stats
from repro.core.ft import FTPolicy
from repro.core.plan import FTConfig

FT = FTConfig(threshold=1e-3)


def _int_mats(rng, m, k, n):
    """Integer-valued float32 operands: every sum in both backends is exact
    in f32, so parity checks can demand bitwise equality."""
    x = rng.integers(-4, 5, (m, k)).astype(np.float32)
    w = rng.integers(-4, 5, (k, n)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(w)


# ---------------------------------------------------------------------------
# plan layer
# ---------------------------------------------------------------------------

def test_plan_registry_shared_cache():
    spec = gemm.GEMMSpec(shape=(128, 128, 128), ft=FT)
    p1 = gemm.plan(spec)
    p2 = gemm.plan(gemm.GEMMSpec(shape=(128, 128, 128), ft=FT))
    assert p1 is p2                      # equal specs hash to one plan
    assert gemm.plan(gemm.GEMMSpec(shape=(128, 128, 256), ft=FT)) is not p1
    d = p1.describe()
    assert d["plan"] == "GEMMPlan" and d["ft"] and d["volume"]["flops"] > 0
    with pytest.raises(TypeError, match="GEMMSpec"):
        planbase.plan(object())


def test_plan_base_has_no_fft_imports():
    """Acceptance: the shared base is op-agnostic — operator families
    register themselves; core/plan.py must not import any of them."""
    src = inspect.getsource(planbase)
    for line in src.splitlines():
        ls = line.strip()
        if ls.startswith(("import ", "from ")):
            assert "fft" not in ls and "gemm" not in ls, ls


def test_one_policy_configures_both_families():
    """The SAME FTPolicy-derived config attaches to FFT and GEMM specs."""
    from repro.core.fft.api import FFTSpec
    from repro.core.fft.api import plan as fft_plan

    pol = FTPolicy(protect_linears=True, threshold=2e-3)
    cfg = pol.to_ft_config()
    assert isinstance(cfg, FTConfig)
    fp = fft_plan(FFTSpec(shape=(8, 64), ft=cfg))
    gp = gemm.plan(gemm.GEMMSpec(shape=(128, 64, 64), ft=cfg))
    assert fp.spec.ft is cfg and gp.spec.ft is cfg


def test_pallas_plan_requires_tile_alignment():
    with pytest.raises(ValueError, match="tile-aligned"):
        gemm.plan(gemm.GEMMSpec(shape=(100, 128, 128), ft=FT,
                                backend="pallas"))
    # auto on unaligned shapes falls back to the interpreter path
    p = gemm.plan(gemm.GEMMSpec(shape=(100, 128, 128), ft=FT))
    assert p.backend == "xla"


# ---------------------------------------------------------------------------
# fused kernel vs interpreter parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tiles", [(128, 128, 128), (64, 64, 64),
                                   (128, 64, 128), (64, 128, 64)])
def test_fused_matches_interpreter_bitwise(rng, tiles):
    m, k, n = 256, 128, 128
    x, w = _int_mats(rng, m, k, n)
    inj = jnp.array([171.0, 40.0, 1.0, 333.0])
    xla = gemm.plan(gemm.spec_for(x, w, ft=FT, backend="xla"))
    pal = gemm.plan(gemm.spec_for(x, w, ft=FT, backend="pallas",
                                  tiles=tiles))
    for inject in (None, inj):
        y1, s1 = xla.ft_matmul(x, w, inject=inject)
        y2, s2 = pal.ft_matmul(x, w, inject=inject)
        assert np.array_equal(np.asarray(y1), np.asarray(y2))
        for key in ("flagged", "corrected", "uncorrectable", "score"):
            assert float(s1[key]) == float(s2[key]), key


# ---------------------------------------------------------------------------
# injection matrix
# ---------------------------------------------------------------------------

_CORNERS = [(0, 0), (0, 255), (255, 0), (255, 255),     # output corners
            (127, 127), (128, 128), (127, 128), (128, 127)]  # tile seams


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("row,col", _CORNERS)
def test_detect_and_correct_at_tile_corners(rng, backend, row, col):
    m = n = 256
    x, w = _int_mats(rng, m, 128, n)
    p = gemm.plan(gemm.spec_for(x, w, ft=FT, backend=backend))
    clean = np.asarray(x) @ np.asarray(w)
    y, s = p.ft_matmul(x, w, inject=jnp.array([row, col, 1.0, 400.0]))
    assert float(s["flagged"]) == 1.0
    assert float(s["corrected"]) == 1.0
    assert float(s["uncorrectable"]) == 0.0
    # integer operands: the decoded correction restores the product exactly
    np.testing.assert_array_equal(np.asarray(y), clean)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_corrects_concurrent_seus_in_distinct_columns(rng, backend):
    x, w = _int_mats(rng, 256, 128, 128)
    p = gemm.plan(gemm.spec_for(x, w, ft=FT, backend=backend))
    inj = jnp.array([[3.0, 7.0, 1.0, 500.0],
                     [200.0, 90.0, 1.0, -450.0],
                     [128.0, 127.0, 1.0, 600.0]])
    y, s = p.ft_matmul(x, w, inject=inj)
    assert float(s["flagged"]) == 3.0
    assert float(s["corrected"]) == 3.0
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(x) @ np.asarray(w))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_flags_multi_seu_in_same_column_uncorrectable(rng, backend):
    x, w = _int_mats(rng, 256, 128, 128)
    p = gemm.plan(gemm.spec_for(x, w, ft=FT, backend=backend))
    inj = jnp.array([[3.0, 7.0, 1.0, 500.0],
                     [200.0, 7.0, 1.0, -450.0]])   # same column twice
    _, s = p.ft_matmul(x, w, inject=inj)
    assert float(s["flagged"]) == 1.0         # one corrupted column
    assert float(s["uncorrectable"]) == 1.0   # non-integer location ratio
    assert float(s["corrected"]) == 0.0


def test_disabled_descriptor_is_a_noop(rng):
    x, w = _int_mats(rng, 128, 128, 128)
    p = gemm.plan(gemm.spec_for(x, w, ft=FT))
    y, s = p.ft_matmul(x, w, inject=jnp.array([3.0, 7.0, 0.0, 500.0]))
    assert float(s["flagged"]) == 0.0
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(x) @ np.asarray(w))


# ---------------------------------------------------------------------------
# batched activations (attention feeds (B, T, D))
# ---------------------------------------------------------------------------

def test_batched_3d_activations_roundtrip(rng):
    b, t, k, n = 4, 64, 128, 128
    x = jnp.asarray(rng.integers(-4, 5, (b, t, k)).astype(np.float32))
    w = jnp.asarray(rng.integers(-4, 5, (k, n)).astype(np.float32))
    # rows of the descriptor index the flattened B*T token axis
    y, s = ft_matmul(x, w, inject=jnp.array([t + 5.0, 9.0, 700.0]))
    assert y.shape == (b, t, n)
    assert float(s["flagged"]) == 1.0 and float(s["corrected"]) == 1.0
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(x) @ np.asarray(w))


def test_rank_errors():
    with pytest.raises(ValueError, match="batch dim"):
        ft_matmul(jnp.zeros((2, 2, 4, 8)), jnp.zeros((8, 8)))
    with pytest.raises(ValueError, match="2-D"):
        ft_matmul(jnp.zeros((4, 8)), jnp.zeros((2, 8, 8)))


# ---------------------------------------------------------------------------
# ft_dot_stats aggregation (regression: positional [::2] slicing)
# ---------------------------------------------------------------------------

def test_ft_dot_stats_traverses_by_key():
    """The old implementation sliced tree_leaves [::2], which silently
    mis-paired leaves once stats dicts grew beyond two keys or nested —
    aggregation must key off the dict KEY, not leaf position."""
    s1 = {"flagged": jnp.float32(2.0), "corrected": jnp.float32(1.0),
          "uncorrectable": jnp.float32(1.0), "score": jnp.float32(0.5)}
    s2 = {"flagged": jnp.ones((3,)), "corrected": jnp.zeros((3,)),
          "uncorrectable": jnp.zeros((3,)), "score": 0.25 * jnp.ones((3,))}
    agg = ft_dot_stats({"attn": s1, "moe": {"experts": s2}})
    assert float(agg["ft_flagged"]) == 5.0       # 2 + sum(ones(3))
    assert float(agg["ft_corrected"]) == 1.0
    assert float(agg["ft_max_score"]) == 0.5
    # alphabetical leaf order would pair ('corrected', 'flagged', ...) — a
    # positional [::2] slice over 4-key dicts counts corrected+score
    empty = ft_dot_stats({})
    assert float(empty["ft_flagged"]) == 0.0


def test_ftcontext_site_masking(rng):
    """The (site, row, col, enable, eps) descriptor arms exactly one
    protected matmul per trace position."""
    from repro.models.layers import FTContext, dense

    pol = FTPolicy(protect_linears=True, threshold=1e-3)
    x = jnp.asarray(rng.integers(-3, 4, (32, 64)).astype(np.float32))
    p1 = {"w": jnp.asarray(rng.integers(-3, 4, (64, 64)).astype(np.float32))}
    p2 = {"w": jnp.asarray(rng.integers(-3, 4, (64, 64)).astype(np.float32))}
    ctx = FTContext(pol, inject=jnp.array([[1.0, 5.0, 9.0, 1.0, 400.0]]))
    h = dense(p1, x, ft=ctx)          # site 0: descriptor stays disarmed
    dense(p2, h, ft=ctx)              # site 1: SEU fires here
    s = ctx.summary()
    assert float(s["ft_flagged"]) == 1.0
    assert float(s["ft_corrected"]) == 1.0
    assert [float(f) for f in ctx.flagged] == [0.0, 1.0]


def test_moe_portable_ft_matches_unprotected(rng):
    """Single-device MoE: the protected expert FFNs (vmapped ABFT over the
    expert axis) reproduce the unprotected forward with zero false alarms."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models.layers import FTContext
    from repro.models.moe import make_moe_params, _moe_block_portable

    cfg = dataclasses.replace(get_smoke_config("deepseek_v3_671b"),
                              num_experts=4, top_k=2, dtype="float32")
    pol = FTPolicy(protect_linears=True, threshold=1e-2)
    params = make_moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y0, _ = _moe_block_portable(params, x, cfg)
    ctx = FTContext(pol)
    y1, _ = _moe_block_portable(params, x, cfg, ft=ctx)
    s = ctx.summary()
    assert float(s["ft_flagged"]) == 0.0
    assert float(s["ft_max_score"]) > 0.0     # checksums were computed
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=1e-4)


# ---------------------------------------------------------------------------
# attention / MoE FT paths on an 8-device mesh (mesh-8dev CI lane)
# ---------------------------------------------------------------------------

pytest_mesh = pytest.mark.slow


@pytest_mesh
def test_attention_ft_path_detects_injected_seu():
    """A protected attention+MLP block corrects an armed SEU and leaves the
    clean forward untouched (multi-device subprocess, float32)."""
    out = run_py("""
import dataclasses, numpy as np, jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.core.ft import FTPolicy
from repro.models import Model

cfg = dataclasses.replace(
    get_smoke_config('gemma3_1b'), dtype='float32',
    ft=FTPolicy(protect_linears=True, threshold=1e-2))
m = Model(cfg)
params = m.init(jax.random.PRNGKey(0))
tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
clean, a0 = m.apply(params, {'tokens': tok}, block_q=8)
assert float(a0['ft_flagged']) == 0.0, a0
assert float(a0['ft_max_score']) > 0.0   # checksums were computed
inj = jnp.array([[0.0, 3.0, 5.0, 1.0, 900.0]])  # site 0 = q projection
y, a1 = m.apply(params, {'tokens': tok}, block_q=8, inject=inj)
assert float(a1['ft_flagged']) >= 1.0, a1
assert float(a1['ft_corrected']) >= 1.0, a1
err = float(jnp.abs(y - clean).max() / (jnp.abs(clean).max() + 1e-9))
assert err < 1e-3, err   # online correction: faulty == clean forward
print('OK', err)
""")
    assert "OK" in out


@pytest_mesh
def test_moe_ep_ft_stats_escape_shard_map():
    """Expert-parallel MoE under FT: per-shard ABFT stats psum out of the
    shard_map and land in the FTContext; the protected EP forward matches
    the protected portable forward."""
    out = run_py("""
import dataclasses, numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.core.ft import FTPolicy
from repro.models.layers import FTContext
from repro.models.moe import make_moe_params, moe_block_ep, \
    _moe_block_portable

cfg = dataclasses.replace(get_smoke_config('deepseek_v3_671b'),
                          num_experts=8, top_k=2, capacity_factor=8.0,
                          dtype='float32')
pol = FTPolicy(protect_linears=True, threshold=1e-3)
params = make_moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                      jnp.float32)
ctx_ref = FTContext(pol)
y_ref, _ = _moe_block_portable(params, x, cfg, ft=ctx_ref)
ref_sum = ctx_ref.summary()

mesh = jax.make_mesh((2, 4), ('data', 'model'))
ps = dict(params)
for k in ('wi_gate', 'wi_up', 'wo'):
    ps[k] = jax.device_put(params[k], NamedSharding(mesh,
                                                    P('model', None, None)))
xs = jax.device_put(x, NamedSharding(mesh, P('data', None, None)))
def run(p, v):
    # context lives inside the trace; stats leave as jit outputs
    ctx = FTContext(pol)
    y, _ = moe_block_ep(p, v, cfg, mesh, ft=ctx)
    return y, ctx.summary()

with mesh:
    y_ep, s = jax.jit(run)(ps, xs)
assert np.isfinite(float(s['ft_flagged']))
assert float(s['ft_flagged']) == float(ref_sum['ft_flagged'])
err = float(jnp.abs(y_ep - y_ref).max() / (jnp.abs(y_ref).max() + 1e-9))
assert err < 2e-5, err
print('OK', err, float(s['ft_flagged']))
""")
    assert "OK" in out
