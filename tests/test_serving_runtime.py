"""Serving runtime unit tests: bucketing policy, deadline batching,
backpressure, and the end-to-end single-device runtime (padded-transform
correctness, telemetry, ABFT fault injection).

Everything here runs on one CPU device — the mesh serving paths are
covered by the saturation smoke in ``benchmarks/fft_serving.py`` (CI's
mesh-8dev lane).
"""
import threading
import time

import numpy as np
import pytest

from repro.core.fft import api
from repro.serve import (BucketKey, DeadlineBatcher, Fault, QueueFullError,
                         RequestHandle, RequestTimeoutError, RuntimeClosedError,
                         RuntimeConfig, ServeRequest, ServeRuntime,
                         SpecBucketer, pad_transform_shape, percentiles)


@pytest.fixture(autouse=True)
def _fresh_cache():
    api.plan_cache_clear()
    yield
    api.plan_cache_clear()


# -- bucketing policy -------------------------------------------------------

def test_pad_transform_shape_pow2():
    assert pad_transform_shape((1000,)) == (1024,)
    assert pad_transform_shape((1024,)) == (1024,)
    assert pad_transform_shape((100, 60)) == (128, 64)


def test_pad_transform_shape_mesh_floors():
    # pencil feasibility: n >= shards^2; packed real pencils need n/2 >=
    # shards^2 (the half-length signal is what the pencil splits)
    assert pad_transform_shape((8,), shards=4) == (16,)
    assert pad_transform_shape((8,), shards=4, real=True) == (32,)
    assert pad_transform_shape((64,), shards=4) == (64,)
    # 2-D: first axis must be mesh-divisible for the slab
    assert pad_transform_shape((2, 8), shards=4) == (4, 16)


def test_pad_transform_shape_rejects_bad():
    with pytest.raises(ValueError):
        pad_transform_shape(())
    with pytest.raises(ValueError):
        pad_transform_shape((0,))


def test_key_for_canonicalizes():
    b = SpecBucketer(max_batch=4)
    k = b.key_for((1000,), np.float32, op="fft")
    assert k == BucketKey(tshape=(1024,), rank=1, dtype="complex64",
                          op="fft", real=False, ft=False)
    assert k.label == "fft:1024:c64"
    # same bucket regardless of request length within the pow2 band
    assert b.key_for((513,), np.complex64, op="fft") == k
    # real f64 keeps double precision, real f32 stays single
    assert b.key_for((1000,), np.float64, op="fft",
                     real=True).dtype == "complex128"
    assert b.key_for((1000,), np.float32, op="fft",
                     real=True).dtype == "complex64"
    assert "real" in b.key_for((8,), np.float32, op="fft", real=True).label


def test_key_for_rejections():
    b = SpecBucketer(max_batch=4)
    with pytest.raises(ValueError, match="convolve"):
        b.key_for((64,), np.complex64, op="convolve")
    with pytest.raises(ValueError, match="ft=True"):
        b.key_for((64,), np.complex64, op="spectrum", ft=True)
    with pytest.raises(ValueError, match="single signals"):
        b.key_for((2, 3, 4), np.complex64)
    with pytest.raises(ValueError, match="real=True"):
        b.key_for((64,), np.complex64, real=True)


def test_pad_elems():
    b = SpecBucketer(max_batch=4)
    k = b.key_for((1000,), np.complex64)
    assert b.pad_elems(k, (1000,)) == 24
    assert b.pad_elems(k, (1024,)) == 0


def test_spec_for_requires_ft_config():
    b = SpecBucketer(max_batch=4)
    k = b.key_for((64,), np.complex64, ft=True)
    with pytest.raises(ValueError, match="FTConfig"):
        b.spec_for(k)
    spec = b.spec_for(b.key_for((64,), np.complex64))
    assert spec.shape == (4, 64) and spec.ft is None


# -- scheduler: deadline batching + backpressure ----------------------------

def _req(key="k", timeout_ms=None):
    return ServeRequest(key=key, x=None, handle=RequestHandle(),
                        timeout_ms=timeout_ms)


def test_batcher_closes_on_max_batch():
    b = DeadlineBatcher(max_batch=3, deadline_ms=10_000, queue_depth=16)
    try:
        reqs = [_req() for _ in range(3)]
        for r in reqs:
            b.submit(r)
        batch = b.next_batch(timeout=1.0)
        assert batch is not None and len(batch.requests) == 3
        assert [r.handle for r in batch.requests] == [r.handle for r in reqs]
        assert b.pending == 0
    finally:
        b.close(drain=False)


def test_batcher_closes_on_deadline():
    b = DeadlineBatcher(max_batch=64, deadline_ms=20, queue_depth=16)
    try:
        t0 = time.monotonic()
        b.submit(_req())
        batch = b.next_batch(timeout=2.0)
        dt = time.monotonic() - t0
        assert batch is not None and len(batch.requests) == 1
        assert dt >= 0.015, f"closed before the deadline ({dt*1e3:.1f}ms)"
    finally:
        b.close(drain=False)


def test_batcher_backpressure():
    b = DeadlineBatcher(max_batch=64, deadline_ms=10_000, queue_depth=2)
    try:
        b.submit(_req())
        b.submit(_req())
        with pytest.raises(QueueFullError):
            b.submit(_req())
    finally:
        b.close(drain=False)


def test_batcher_request_timeout():
    b = DeadlineBatcher(max_batch=64, deadline_ms=10_000, queue_depth=4)
    try:
        timed_out = []
        b._on_timeout = timed_out.append
        r = _req(timeout_ms=20)
        b.submit(r)
        with pytest.raises(RequestTimeoutError):
            r.handle.result(timeout=2.0)
        assert timed_out == ["k"]
        assert b.pending == 0     # the slot returned to the queue budget
    finally:
        b.close(drain=False)


def test_batcher_close_drain_flushes_partials():
    b = DeadlineBatcher(max_batch=64, deadline_ms=10_000, queue_depth=4)
    b.submit(_req("a"))
    b.submit(_req("b"))
    b.close(drain=True)
    keys = {b2.key for b2 in iter(lambda: b.next_batch(timeout=0.2), None)}
    assert keys == {"a", "b"}
    with pytest.raises(RuntimeClosedError):
        b.submit(_req())


def test_batcher_close_nodrain_fails_pending():
    b = DeadlineBatcher(max_batch=64, deadline_ms=10_000, queue_depth=4)
    r = _req()
    b.submit(r)
    b.close(drain=False)
    with pytest.raises(RuntimeClosedError):
        r.handle.result(timeout=1.0)


# -- runtime end-to-end (single device) -------------------------------------

def test_runtime_padded_fft_roundtrip():
    rng = np.random.default_rng(0)
    with ServeRuntime(RuntimeConfig(max_batch=4, deadline_ms=5.0,
                                    workers=2)) as rt:
        xs = [rng.standard_normal(n).astype(np.float32)
              for n in (1000, 1024, 513, 700)]
        handles = [rt.submit(x) for x in xs]
        for x, h in zip(xs, handles):
            y = h.result(timeout=30.0)
            assert y.shape == (1024,)
            ref = np.fft.fft(x, 1024)    # trailing-zero extension contract
            np.testing.assert_allclose(y, ref, rtol=2e-3, atol=2e-3)
            assert h.info["bucket"] == "fft:1024:c64"
        stats = rt.stats()["buckets"]["fft:1024:c64"]
        assert stats["submitted"] == 4 and stats["completed"] == 4
        assert stats["pad_waste"] > 0       # 1000/513/700 all padded
        assert stats["p50_ms"] > 0
    # one bucket -> exactly one plan spec in the shared cache
    assert api.plan_cache_info().currsize == 1


def test_runtime_one_batch_when_full():
    rng = np.random.default_rng(1)
    with ServeRuntime(RuntimeConfig(max_batch=4, deadline_ms=10_000.0,
                                    workers=1)) as rt:
        hs = [rt.submit(rng.standard_normal(256).astype(np.float32))
              for _ in range(4)]
        for h in hs:
            h.result(timeout=30.0)
        st = rt.stats()["buckets"]["fft:256:c64"]
        assert st["batches"] == 1 and st["batch_occupancy"] == 1.0


def test_runtime_mixed_buckets():
    rng = np.random.default_rng(2)
    with ServeRuntime(RuntimeConfig(max_batch=2, deadline_ms=5.0)) as rt:
        h1 = rt.submit(rng.standard_normal(100).astype(np.float32))
        h2 = rt.submit(rng.standard_normal((20, 30)).astype(np.float32))
        h3 = rt.submit(rng.standard_normal(256).astype(np.float32),
                       op="spectrum")
        assert h1.result(timeout=30.0).shape == (128,)
        assert h2.result(timeout=30.0).shape == (32, 32)
        s = h3.result(timeout=30.0)
        assert s.shape == (256,) and s.dtype.kind == "f"
        buckets = rt.stats()["buckets"]
        assert set(buckets) == {"fft:128:c64", "fft:32x32:c64",
                                "spectrum:256:c64"}
    assert api.plan_cache_info().currsize == 3


def test_runtime_real_bucket():
    rng = np.random.default_rng(3)
    with ServeRuntime(RuntimeConfig(max_batch=2, deadline_ms=5.0)) as rt:
        x = rng.standard_normal(1000).astype(np.float32)
        y = rt.submit(x, real=True).result(timeout=30.0)
        assert y.shape == (513,)     # 1024-bucket half spectrum
        np.testing.assert_allclose(y, np.fft.rfft(x, 1024),
                                   rtol=2e-3, atol=2e-3)


def test_runtime_rejects_bad_requests():
    with ServeRuntime(RuntimeConfig(max_batch=2, deadline_ms=5.0)) as rt:
        with pytest.raises(ValueError, match="convolve"):
            rt.submit(np.zeros(64, np.complex64), op="convolve")
        with pytest.raises(ValueError, match="ft=True"):
            rt.submit(np.zeros(64, np.float32), faults=Fault())
    with pytest.raises(RuntimeClosedError):
        rt.submit(np.zeros(64, np.float32))


def test_runtime_backpressure_counts_rejects():
    # 1 worker wedged on a huge deadline-less queue: fill the bounded
    # queue and confirm the overflow surfaces as QueueFullError + telemetry
    with ServeRuntime(RuntimeConfig(max_batch=64, deadline_ms=10_000.0,
                                    queue_depth=2, workers=1)) as rt:
        x = np.zeros(128, np.float32)
        rt.submit(x)
        rt.submit(x)
        with pytest.raises(QueueFullError):
            rt.submit(x)
        st = rt.stats()["buckets"]["fft:128:c64"]
        assert st["rejected"] == 1
        rt.batcher.flush()


def test_runtime_ft_injection_local():
    """One SEU per batch through the local fused-kernel ABFT: detected,
    located, corrected — and the telemetry ledger is exact."""
    rng = np.random.default_rng(4)
    cfg = RuntimeConfig(max_batch=4, deadline_ms=10_000.0, workers=1)
    with ServeRuntime(cfg) as rt:
        xs = [rng.standard_normal(256).astype(np.float32) for _ in range(4)]
        faults = [None, Fault(col=7, eps_re=300.0), None, None]
        hs = [rt.submit(x, ft=True, faults=f)
              for x, f in zip(xs, faults)]
        ys = [h.result(timeout=60.0) for h in hs]
        for x, y in zip(xs, ys):
            np.testing.assert_allclose(y, np.fft.fft(x), rtol=2e-3,
                                       atol=2e-3)
        st = rt.stats()["buckets"]["fft:256:c64:ft"]
        assert st["injected"] == 1
        assert st["detected"] == 1
        assert st["corrected"] == 1
        assert st.get("uncorrectable", 0) == 0
        assert hs[1].info["flagged"] and hs[1].info["corrected"] == 1


def test_runtime_ft_local_single_seu_limit():
    # the fused kernel carries ONE in-kernel descriptor: two faulted
    # requests in the same batch must fail loudly, not silently drop one
    with ServeRuntime(RuntimeConfig(max_batch=2, deadline_ms=10_000.0,
                                    workers=1)) as rt:
        x = np.zeros(256, np.float32)
        h1 = rt.submit(x, ft=True, faults=Fault())
        h2 = rt.submit(x, ft=True, faults=Fault())
        with pytest.raises(ValueError, match="one SEU"):
            h1.result(timeout=30.0)
        with pytest.raises(ValueError, match="one SEU"):
            h2.result(timeout=30.0)
        assert rt.stats()["buckets"]["fft:256:c64:ft"]["failed"] == 2


def test_runtime_warmup_means_one_trace():
    # admission warms the executor; the serving batches then hit the same
    # jitted callable (no per-batch trace) — observable as a single plan
    # and stable latency across repeats
    with ServeRuntime(RuntimeConfig(max_batch=2, deadline_ms=2.0,
                                    workers=1)) as rt:
        x = np.zeros(512, np.float32)
        for _ in range(3):
            rt.submit(x).result(timeout=30.0)
        assert api.plan_cache_info().currsize == 1
        assert rt.stats()["buckets"]["fft:512:c64"]["batches"] >= 1


def test_percentiles_shape():
    assert percentiles([]) == {"p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0}
    p = percentiles([0.001, 0.002, 0.100])
    assert p["p50_ms"] == pytest.approx(2.0)
    assert p["p99_ms"] > p["p50_ms"]


def test_runtime_concurrent_submitters():
    # many client threads, one runtime: every request gets its own answer
    rng = np.random.default_rng(5)
    xs = [rng.standard_normal(128).astype(np.float32) for _ in range(16)]
    results = [None] * 16
    with ServeRuntime(RuntimeConfig(max_batch=4, deadline_ms=2.0,
                                    workers=2)) as rt:
        def client(i):
            results[i] = rt.submit(xs[i]).result(timeout=60.0)
        ts = [threading.Thread(target=client, args=(i,)) for i in range(16)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        st = rt.stats()["buckets"]["fft:128:c64"]
        assert st["completed"] == 16
    for x, y in zip(xs, results):
        np.testing.assert_allclose(y, np.fft.fft(x, 128), rtol=2e-3,
                                   atol=2e-3)
