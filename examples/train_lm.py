"""End-to-end training driver: train an LM on the synthetic pipeline for a
few hundred steps with checkpoint/restart + FT hooks.

CPU-sized smoke (what EXPERIMENTS.md records):

    PYTHONPATH=src python examples/train_lm.py --steps 60

The ~100M-parameter preset (same code path, longer on CPU):

    PYTHONPATH=src python examples/train_lm.py --preset lm100m --steps 300 \
        --batch 8 --seq 512
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--arch" not in argv:
        argv = ["--arch", "gemma3-1b"] + argv
    if "--preset" not in argv:
        argv += ["--preset", "tiny"]
    sys.argv = [sys.argv[0]] + argv
    main()
