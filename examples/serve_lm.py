"""Batched greedy serving with KV caches (decode path of the dry-run).

    PYTHONPATH=src python examples/serve_lm.py --batch 4 --gen 32
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
