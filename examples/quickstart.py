"""Quickstart: the paper's two contributions in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.kernels import ops

rng = np.random.default_rng(0)
x = (rng.standard_normal((32, 1024)) +
     1j * rng.standard_normal((32, 1024))).astype(np.complex64)

# 1. High-performance FFT (Pallas kernel; interpret-mode on CPU)
y = ops.fft(x)
print("fft err vs numpy:", float(np.abs(np.asarray(y) - np.fft.fft(x)).max()))

# ... or the cuFFT-style way: describe the transform ONCE as an FFTSpec,
# plan it, and reuse the cached executor for every batch (the serving path)
from repro.kernels import FFTSpec, plan

p = plan(FFTSpec(shape=x.shape))
print("plan:", p)
print("plan == kwarg path:", bool(jnp.array_equal(p.fft(x), y)))

# 2. Fault-tolerant FFT: inject an SEU into the compute, watch the two-sided
#    ABFT detect, locate, and correct it online — no recomputation.
inj = jnp.asarray([1, 3, 100, 1, 50.0, -30.0], jnp.float32)  # tile 1, sig 3
res = ops.ft_fft(x, transactions=2, bs=8, inject=inj)
print("corrupted signal id:", 1 * 8 + 3)
print("flagged groups:", np.asarray(res.flagged))
print("decoded location:", int(np.asarray(res.location)[np.argmax(np.asarray(res.flagged))]))
print("corrections applied:", int(res.corrected))
print("post-correction err:",
      float(np.abs(np.asarray(res.y) - np.fft.fft(x)).max() /
            np.abs(np.fft.fft(x)).max()))
