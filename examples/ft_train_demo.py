"""FT training demo: ABFT-protected linears + SEU injection during training.

Shows the paper's technique as a first-class training feature: a fault is
injected into a forward GEMM mid-run; the two-sided ABFT detects and corrects
it online, and training statistics record the event. Compare the corrected
run's loss against a fault-free run.

    PYTHONPATH=src python examples/ft_train_demo.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.configs import get_smoke_config
from repro.configs.base import ParallelConfig, RunConfig
from repro.core import abft
from repro.core.ft import FTPolicy
from repro.data import TokenPipeline
from repro.models import Model
from repro.train import make_train_step

cfg = dataclasses.replace(
    get_smoke_config("phi3_medium_14b"), vocab_size=256, num_layers=2,
    dtype="float32",
    ft=FTPolicy(protect_linears=True, threshold=1e-2))
model = Model(cfg)
run = RunConfig(model=cfg, parallel=ParallelConfig(remat="none"),
                learning_rate=1e-3, warmup_steps=5, total_steps=50)
pipe = TokenPipeline(seed=0, batch=8, seq_len=64, vocab_size=256)

params = model.init(jax.random.PRNGKey(0))
state = optim.init_state(params)
step_fn = jax.jit(make_train_step(model, run))

print("step  loss    ft_flagged  ft_max_score")
for step in range(30):
    batch = {k: jnp.asarray(v) for k, v in pipe(step).items()}
    params, state, m = step_fn(params, state, batch, jnp.int32(step))
    if step % 5 == 0:
        print(f"{step:4d}  {float(m['loss']):.4f}  "
              f"{float(m['ft_flagged']):10.0f}  "
              f"{float(m['ft_max_score']):.2e}")

# standalone demonstration: a GEMM SEU detected + corrected by ft_matmul
x = jnp.asarray(np.random.default_rng(1).standard_normal((64, 32)),
                jnp.float32)
w = jnp.asarray(np.random.default_rng(2).standard_normal((32, 48)),
                jnp.float32)
y, stats = abft.ft_matmul(x, w, inject=jnp.asarray([13.0, 7.0, 500.0]))
print("\nGEMM SEU: flagged =", int(stats["flagged"]),
      " corrected err =",
      float(jnp.abs(y - x @ w).max() / jnp.abs(x @ w).max()))
