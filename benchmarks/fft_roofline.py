"""TPU roofline for the TurboFFT kernel itself (the paper's workload).

Analytic terms from the plan (exact op counts of the stage GEMMs) — the FFT
is memory-bound on TPU exactly as on GPU (paper §5.1.2 reports 90% of peak
memory bandwidth; the A100 balance point is ~13 fp32 FLOP/B vs our stage
intensity ~80 FLOP/B on v5e whose balance is 240 FLOP/B bf16).

Also quantifies the fused-ABFT roofline cost: checksum dots add ~0.6%
compute and exactly 0 HBM bytes (they read VMEM-resident tiles), so the
co-design thesis — fault tolerance below the memory roofline is free — holds
on TPU.
"""
from __future__ import annotations

import numpy as np

from repro.core.fft.plan import make_plan

from .common import emit

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def fft_terms(n: int, itemsize: int = 8):
    """(flops/signal, hbm bytes/signal, passes) for the planned FFT."""
    plan = make_plan(n)
    # Each stage transforms a factor-length-F signal by contracting with W_r
    # (4 real matmuls, 2*F*r each per signal) + a twiddle multiply
    # (6 flops/elem); a pass applies its factor's stages across all N
    # elements (N/F signals of length F).
    flops = 0.0
    for f, stages in zip(plan.kernel_factors, plan.stages):
        reps = n // f
        for st in stages:
            flops += reps * (8.0 * f * st.radix + 6.0 * f)
    bytes_hbm = 2.0 * n * itemsize * plan.num_passes  # read+write per pass
    return flops, bytes_hbm, plan.num_passes


def run(smoke: bool = True):
    rows = []
    for ln in ([10, 13, 17, 23] if smoke else list(range(6, 28))):
        n = 1 << ln
        flops, byts, passes = fft_terms(n)
        compute_s = flops / PEAK_FLOPS
        memory_s = byts / HBM_BW
        bound = max(compute_s, memory_s)
        eff_bw = byts / bound / 1e9
        frac_bw = (memory_s / bound)
        # fused ABFT deltas (per signal): left checksums = 2 complex dots
        # in + out = 2 * 8N flops, 0 extra HBM bytes; right-side adds
        # elementwise accumulate 8N flops, 0 bytes, 1/(bs*T) amortized emit
        abft_flops = 24.0 * n
        abft_overhead = abft_flops / flops
        emit(f"fft_roofline_N2^{ln}", 0.0,
             f"passes={passes};intensity={flops / byts:.0f}F/B;"
             f"bound={'memory' if memory_s >= compute_s else 'compute'};"
             f"peakBW%={100 * frac_bw:.0f};abft_flops=+{100 * abft_overhead:.1f}%;"
             f"abft_bytes=+0%")
        rows.append((n, flops, byts, abft_overhead))
    return rows


if __name__ == "__main__":
    run(smoke=False)
