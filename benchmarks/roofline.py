"""§Roofline: three-term roofline per (arch x shape) from dry-run artifacts.

    compute_s    = HLO_FLOPs_per_device    / 197e12   (bf16 MXU peak)
    memory_s     = HLO_bytes_per_device    / 819e9    (HBM bandwidth)
    collective_s = wire_bytes_per_device   / 50e9     (per-link ICI)

Per-device numbers come from the SPMD-partitioned module (the compiled HLO
is the per-device program); scan-body undercounting is corrected by the
two-point probe (see launch/dryrun.py). The reported *roofline fraction* is
    (MODEL_FLOPS_per_device / peak) / max(three terms)
i.e. the projected MFU upper bound of the compiled program on the target.
"""
from __future__ import annotations

import glob
import json
import os

import numpy as np

from .common import emit

PEAK_FLOPS = 197e12     # bf16 / chip (TPU v5e-class)
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s per link direction

CHIPS = 256             # single-pod roofline table


def _advice(dominant, rec):
    arch = rec["arch"]
    mode = rec["mode"]
    if dominant == "memory":
        if mode == "decode":
            return ("decode is KV/weight streaming-bound: quantize KV to "
                    "int8 and batch more sequences per step")
        return ("activation traffic dominates: banded local-attention "
                "(mask->slice), larger fusion regions, bf16 master weights")
    if dominant == "collective":
        return ("shard-induced resharding dominates: align layouts across "
                "layer boundary, compress DP grads (int8), overlap "
                "all-gather with compute (latency-hiding scheduler)")
    return "MXU-bound: good; raise arithmetic intensity only via microbatch"


def load(art_dir: str):
    recs = []
    for p in sorted(glob.glob(os.path.join(art_dir, "*__pod1.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def analyze(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    est = rec.get("roofline_est") or {}
    if not est or "error" in est:
        est = {
            "flops": rec["cost"]["flops"],
            "bytes_accessed": rec["cost"]["bytes_accessed"],
            "collective_bytes": rec["collectives"].get("total_bytes", 0.0),
        }
    compute_s = est["flops"] / PEAK_FLOPS
    memory_s = est["bytes_accessed"] / HBM_BW
    coll_b = est.get("collective_bytes", 0.0)
    # TPU adjustment: the CPU pipeline lowers FSDP grad reduce-scatter as
    # all-reduce(+slice) (no ReduceScatterCreator pass); the TPU pipeline
    # emits reduce-scatter, halving the dominant all-reduce wire bytes.
    by_kind = est.get("collective_bytes_by_kind")
    if by_kind:
        coll_b = (0.5 * by_kind.get("all-reduce", 0.0)
                  + sum(v for k, v in by_kind.items() if k != "all-reduce"))
    coll_s = coll_b / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    dominant = max(terms, key=terms.get)
    # fwd+bwd (6N) for train; fwd-only (2N) for prefill/decode
    flops_per_tok = rec["model_flops_per_token"]
    if rec["mode"] != "train":
        flops_per_tok *= 2.0 / 6.0
    model_flops_dev = flops_per_tok * rec["tokens_per_step"] / CHIPS
    ideal_s = model_flops_dev / PEAK_FLOPS
    bound_s = max(terms.values())
    frac = ideal_s / bound_s if bound_s > 0 else 0.0
    # XLA:CPU bytes_accessed is fusion-blind (counts every op's operands), so
    # `memory_s` is a pessimistic bound. The optimistic floor is the step's
    # true I/O: every argument read once + every output written once
    # (params/opt/grads/batch/caches) — a TPU with perfect fusion cannot do
    # better. Reality lies between the two fractions.
    mem = rec.get("memory", {})
    io_bytes = (mem.get("argument_bytes", 0) + mem.get("output_bytes", 0))
    memory_lb_s = io_bytes / HBM_BW
    bound_opt = max(compute_s, memory_lb_s, coll_s)
    frac_opt = ideal_s / bound_opt if bound_opt > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mode": rec["mode"],
        "compute_s": compute_s, "memory_s": memory_s,
        "memory_lb_s": memory_lb_s,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops_per_device": model_flops_dev,
        "hlo_flops_per_device": est["flops"],
        "useful_ratio": (model_flops_dev / est["flops"]
                         if est["flops"] else 0.0),
        "roofline_fraction": frac,
        "roofline_fraction_opt": frac_opt,
        "memory_bytes_per_device": rec.get("memory", {}).get(
            "bytes_per_device", 0),
        "advice": _advice(dominant, rec),
    }


def run(art_dir: str = "artifacts/dryrun", out_md: str | None =
        "artifacts/roofline.md", smoke: bool = True):
    rows = [a for r in load(art_dir) if (a := analyze(r))]
    rows.sort(key=lambda r: r["roofline_fraction"])
    lines = [
        "| arch | shape | compute_s | memory_s (lb) | collective_s "
        "| dominant | MODEL/HLO | frac (pess..opt) | next move |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} "
            f"| {r['memory_s']:.3f} ({r['memory_lb_s']:.3f}) "
            f"| {r['collective_s']:.3f} "
            f"| {r['dominant']} | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f}..{r['roofline_fraction_opt']:.2f} "
            f"| {r['advice'][:60]} |")
        emit(f"roofline_{r['arch']}__{r['shape']}", 0.0,
             f"dom={r['dominant']};frac={r['roofline_fraction']:.3f}"
             f"..{r['roofline_fraction_opt']:.2f};"
             f"useful={r['useful_ratio']:.2f}")
    if out_md and rows:
        os.makedirs(os.path.dirname(out_md), exist_ok=True)
        with open(out_md, "w") as f:
            f.write("\n".join(lines) + "\n")
    return rows


def compare(base_dir: str = "artifacts/dryrun_baseline",
            opt_dir: str = "artifacts/dryrun",
            out_md: str | None = "artifacts/perf_compare.md"):
    """§Perf before/after: per-cell dominant-term movement."""
    base = {(r["arch"], r["shape"]): a for r in load(base_dir)
            if (a := analyze(r))}
    opt = {(r["arch"], r["shape"]): a for r in load(opt_dir)
           if (a := analyze(r))}
    lines = ["| arch | shape | term | before_s | after_s | delta "
             "| frac before->after |", "|---|---|---|---|---|---|---|"]
    rows = []
    for key in sorted(base.keys() & opt.keys()):
        b, o = base[key], opt[key]
        term = b["dominant"]
        tb = b[f"{term}_s"]
        to = o[f"{term}_s"]
        delta = (tb - to) / tb * 100 if tb else 0.0
        lines.append(
            f"| {key[0]} | {key[1]} | {term} | {tb:.3f} | {to:.3f} "
            f"| {delta:+.0f}% | {b['roofline_fraction']:.3f} -> "
            f"{o['roofline_fraction']:.3f} |")
        rows.append((key, term, tb, to, b["roofline_fraction"],
                     o["roofline_fraction"]))
    if out_md:
        os.makedirs(os.path.dirname(out_md), exist_ok=True)
        with open(out_md, "w") as f:
            f.write("\n".join(lines) + "\n")
    return rows


if __name__ == "__main__":
    run(smoke=False)
