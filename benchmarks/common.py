"""Shared benchmark utilities: timing, CSV emit."""
from __future__ import annotations

import time

import jax
import numpy as np

ROWS: list[str] = []


def timeit(fn, *args, warmup=2, iters=5, **kw):
    """Median wall time (s) of jitted fn; blocks on results."""
    for _ in range(warmup):
        r = fn(*args, **kw)
        jax.tree_util.tree_map(
            lambda l: l.block_until_ready() if hasattr(l, "block_until_ready")
            else l, r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args, **kw)
        jax.tree_util.tree_map(
            lambda l: l.block_until_ready() if hasattr(l, "block_until_ready")
            else l, r)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def fft_gflops(n: int, batch: int, t_s: float) -> float:
    """Standard 5*N*log2(N) FFT flops convention."""
    return 5.0 * n * np.log2(max(n, 2)) * batch / t_s / 1e9


def fft_gbytes(n: int, batch: int, t_s: float, itemsize: int = 8) -> float:
    """2x problem size / time (the paper's bandwidth metric, §5.1.2)."""
    return 2.0 * n * batch * itemsize / t_s / 1e9
