"""Paper Table 1: kernel-parameter table produced by the plan 'codegen'."""
from __future__ import annotations

from repro.core.fft.plan import make_plan

from .common import emit


def run(smoke: bool = True):
    sizes = [10, 17, 23] if smoke else list(range(3, 30, 2)) + [10, 17, 23]
    out = []
    for ln in sorted(set(sizes)):
        p = make_plan(1 << ln, batch=64)
        emit(f"plan_N2^{ln}", 0.0,
             f"passes={p.num_passes};{p.describe().replace(',', ';')}")
        out.append(p)
    return out


if __name__ == "__main__":
    run(smoke=False)
