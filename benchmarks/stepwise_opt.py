"""Paper Fig. 15: stepwise optimization ladder.

v0: naive O(N^2) DFT-as-GEMV          (paper's conceptual baseline)
v1: radix-2 Stockham                  (paper's TurboFFT-v0: log2 N stages)
v2: mixed-radix, MXU-radix <=128      (architecture-aware stage choice)
v3: full plan: multi-pass + tuned bs  (kernel-parameter selection)
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import fft as tfft

from .common import emit, fft_gflops, timeit


def run(smoke: bool = True):
    rng = np.random.default_rng(1)
    n_small = 1 << 10
    b = 4 if smoke else 64
    x_small = jnp.asarray((rng.standard_normal((b, n_small)) +
                           1j * rng.standard_normal((b, n_small))
                           ).astype(np.complex64))
    ladder = [
        ("v0_naive_dft", jax.jit(tfft.naive_dft), x_small, n_small),
        ("v1_radix2", jax.jit(tfft.radix2_fft), x_small, n_small),
        ("v2_mixed_radix", jax.jit(tfft.block_fft_stages), x_small, n_small),
    ]
    n_large = 1 << (14 if smoke else 20)
    x_large = jnp.asarray((rng.standard_normal((2, n_large)) +
                           1j * rng.standard_normal((2, n_large))
                           ).astype(np.complex64))
    ladder.append(("v3_full_plan", jax.jit(tfft.fft), x_large, n_large))

    prev = None
    out = []
    for name, fn, x, n in ladder:
        t = timeit(fn, x)
        gf = fft_gflops(n, x.shape[0], t)
        # v3 runs a different (multi-pass-regime) size; compare via GF/s only
        speedup = ("" if prev is None or name == "v3_full_plan"
                   else f";vs_prev={prev / t:.2f}x")
        emit(f"stepwise_{name}_N{n}", t * 1e6, f"{gf:.2f}GF/s{speedup}")
        prev = t
        out.append((name, t, gf))
    return out


if __name__ == "__main__":
    run(smoke=False)
