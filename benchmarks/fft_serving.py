"""Multi-tenant serving benchmark: open-loop Poisson load over mixed FFT
specs through ``repro.serve.ServeRuntime``.

Three experiments:

* ``run_load`` — open-loop Poisson arrivals at a sweep of offered rates
  over a mixed request population (sizes off the pow2 grid, fft +
  spectrum, real + complex). Per rate it reports goodput (completed/s),
  rejects (bounded-queue backpressure), and the p50/p95/p99 latency —
  the latency-vs-load curve for EXPERIMENTS.md.
* ``run_saturation`` — the headline assert: at saturation (every client
  submitting back-to-back), the deadline batcher (max_batch=B) must beat
  the same runtime configured unbatched (max_batch=1) on throughput.
  Both sides run the identical machinery — the delta is batch dispatch
  amortization, which is the point of the subsystem.
* ``run_ft_campaign`` — a ``FaultSchedule``-driven SEU campaign through
  ft buckets, paced one fault per batch (the load generator submits in
  closed batch-sized groups), so the per-bucket ABFT ledger must be
  EXACT: detected == corrected == injected, zero uncorrectable.

Standalone runs force a multi-device host platform:

    PYTHONPATH=src python -m benchmarks.fft_serving
"""
from __future__ import annotations

import argparse
import os
import time

if __name__ == "__main__" and "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=4")

import numpy as np

import jax

from repro.core.ft.injection import FaultSchedule
from repro.serve import (Fault, QueueFullError, RuntimeConfig, ServeRuntime,
                         percentiles)

from .common import emit


def _mesh():
    ndev = min(4, len(jax.devices()))
    shards = 1 << (ndev.bit_length() - 1)
    if shards < 2:
        return None
    return jax.make_mesh((shards,), ("fft",))


def _request_pool(rng, smoke: bool):
    """The mixed-tenant population: off-grid sizes (so bucketing works for
    its living), two ops, real and complex traffic -> 4 buckets."""
    sizes = (1000, 1024, 700) if smoke else (1000, 1024, 700, 1800, 2048)
    pool = []
    for n in sizes:
        pool.append((rng.standard_normal(n).astype(np.float32),
                     dict(op="fft")))
        pool.append((rng.standard_normal(n).astype(np.float32),
                     dict(op="spectrum")))
        pool.append((rng.standard_normal(n).astype(np.float32),
                     dict(op="fft", real=True)))
    return pool


def run_load(smoke: bool = True, mesh=None):
    """Open-loop Poisson sweep: goodput + latency percentiles vs offered
    rate. Returns [(rate, goodput, rejected, p50, p95, p99), ...]."""
    rng = np.random.default_rng(0)
    pool = _request_pool(rng, smoke)
    duration = 2.0 if smoke else 6.0
    rates = (50, 200, 800) if smoke else (50, 200, 800, 2000, 4000)
    rows = []
    for rate in rates:
        cfg = RuntimeConfig(max_batch=8, deadline_ms=2.0, queue_depth=256,
                            workers=2)
        with ServeRuntime(cfg, mesh=mesh) as rt:
            for x, kw in pool:                      # warm every bucket
                rt.submit(x, **kw).result(timeout=120.0)
            handles, rejected = [], 0
            t0 = time.monotonic()
            next_arrival = t0
            while (now := time.monotonic()) - t0 < duration:
                if now < next_arrival:
                    time.sleep(min(next_arrival - now, 0.005))
                    continue
                next_arrival += rng.exponential(1.0 / rate)
                x, kw = pool[rng.integers(len(pool))]
                try:
                    handles.append(rt.submit(x, **kw))
                except QueueFullError:
                    rejected += 1                   # open loop: drop, note
            for h in handles:
                h.result(timeout=120.0)
            wall = time.monotonic() - t0
            lats = [h.latency_s for h in handles]
        goodput = len(handles) / wall
        p = percentiles(lats)
        emit(f"serve_load_r{rate}", p["p50_ms"] * 1e3,
             f"goodput={goodput:.0f}rps;offered={rate}rps;"
             f"rejected={rejected};p95={p['p95_ms']:.2f}ms;"
             f"p99={p['p99_ms']:.2f}ms")
        rows.append((rate, goodput, rejected, p["p50_ms"], p["p95_ms"],
                     p["p99_ms"]))
    return rows


def _pump(rt, xs, nreq: int, timeout: float = 300.0) -> float:
    """Saturation drive: submit ``nreq`` back-to-back (spinning on
    backpressure), wait for all, return wall seconds."""
    handles = []
    t0 = time.monotonic()
    for i in range(nreq):
        while True:
            try:
                handles.append(rt.submit(xs[i % len(xs)]))
                break
            except QueueFullError:
                time.sleep(0.0005)
    for h in handles:
        h.result(timeout=timeout)
    return time.monotonic() - t0


def run_saturation(smoke: bool = True, mesh=None):
    """Batched vs unbatched throughput at saturation, same machinery.
    Asserts the batched runtime wins — the subsystem's reason to exist."""
    rng = np.random.default_rng(1)
    n = 1024
    xs = [rng.standard_normal(n).astype(np.float32) for _ in range(32)]
    nreq = 256 if smoke else 2048
    thr = {}
    for label, max_batch in (("batched", 8), ("sequential", 1)):
        cfg = RuntimeConfig(max_batch=max_batch, deadline_ms=2.0,
                            queue_depth=256, workers=2)
        with ServeRuntime(cfg, mesh=mesh) as rt:
            rt.submit(xs[0]).result(timeout=120.0)   # warm the bucket
            wall = _pump(rt, xs, nreq)
            st = rt.stats()["buckets"][f"fft:{n}:c64"]
        thr[label] = nreq / wall
        emit(f"serve_saturation_{label}_b{max_batch}", wall / nreq * 1e6,
             f"throughput={thr[label]:.0f}rps;"
             f"occupancy={st['batch_occupancy']:.2f};"
             f"batches={st['batches']}")
    speedup = thr["batched"] / thr["sequential"]
    emit("serve_saturation_speedup", speedup, "batched/sequential")
    assert speedup > 1.0, (
        f"deadline batching must beat sequential at saturation: "
        f"{thr['batched']:.0f} vs {thr['sequential']:.0f} rps")
    return thr


def run_ft_campaign(smoke: bool = True, mesh=None):
    """SEU campaign through ft buckets off a ``FaultSchedule``: the load
    generator submits in closed groups of ``max_batch`` (so each group IS
    one batch) and attaches at most one scheduled fault per group — the
    per-bucket ABFT telemetry must then be exact."""
    rng = np.random.default_rng(2)
    max_batch = 4
    n = 256 if mesh is None else 1024   # mesh pencils need n >= shards^2
    groups = 8 if smoke else 32
    # one fault every other group, eps far above threshold (a detectability
    # floor keeps the ledger assert exact — near-zero flips are the ROC
    # experiment's business, not the serving ledger's)
    sched = FaultSchedule(entries=tuple(
        (g, 0, int(rng.integers(max_batch)), int(rng.integers(n)),
         float(rng.choice((-1, 1)) * (150.0 + rng.random() * 100.0)), 0.0)
        for g in range(0, groups, 2)))
    cfg = RuntimeConfig(max_batch=max_batch, deadline_ms=5.0, workers=1)
    with ServeRuntime(cfg, mesh=mesh) as rt:
        xs = [rng.standard_normal(n).astype(np.float32)
              for _ in range(max_batch)]
        rt.submit(xs[0], ft=True).result(timeout=300.0)  # warm
        t0 = time.monotonic()
        for g in range(groups):
            fault_by_row = {row: Fault(row=row, col=col, eps_re=er,
                                       eps_im=ei)
                            for (s, _t, row, col, er, ei) in sched.entries
                            if s == g}
            hs = [rt.submit(xs[i], ft=True, faults=fault_by_row.get(i))
                  for i in range(max_batch)]
            for h in hs:         # closed loop: this group = one batch
                h.result(timeout=300.0)
        wall = time.monotonic() - t0
        st = rt.stats()["buckets"][f"fft:{n}:c64:ft"]
    assert st["injected"] == sched.num_faults, st
    assert st["detected"] == sched.num_faults, st
    assert st["corrected"] == sched.num_faults, st
    assert st.get("uncorrectable", 0) == 0, st
    emit(f"serve_ft_campaign_n{n}_g{groups}",
         wall / (groups * max_batch) * 1e6,
         f"injected={st['injected']};detected={st['detected']};"
         f"corrected={st['corrected']};exact=1")
    return st


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small grids / short sweeps (CI)")
    ap.add_argument("--local", action="store_true",
                    help="skip the mesh, serve single-device buckets")
    a = ap.parse_args()
    mesh = None if a.local else _mesh()
    print(f"# serving over "
          f"{'single device' if mesh is None else f'{mesh.shape} mesh'}")
    print("name,us_per_call,derived")
    run_saturation(smoke=a.smoke, mesh=mesh)
    run_load(smoke=a.smoke, mesh=mesh)
    run_ft_campaign(smoke=a.smoke, mesh=mesh)
