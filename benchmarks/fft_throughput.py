"""Paper Fig. 1 / 10-14: TurboFFT vs the platform library (jnp.fft = the
cuFFT analogue) over the (signal length, batch) grid, FP32 + FP64.

CPU wall time is a proxy (TPU perf is the §Roofline analysis); the grid and
the relative-overhead heatmap methodology match the paper.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import fft as tfft

from .common import emit, fft_gbytes, fft_gflops, timeit


def grid(smoke: bool = True):
    if smoke:
        return [(10, 8), (12, 8), (14, 2), (17, 1)], ["complex64"]
    return ([(ln, b) for ln in range(6, 23, 2) for b in (1, 16, 256)],
            ["complex64", "complex128"])


def run(smoke: bool = True):
    cells, dtypes = grid(smoke)
    rng = np.random.default_rng(0)
    turbo = jax.jit(tfft.fft)
    ref = jax.jit(jnp.fft.fft)
    rows = []
    for dt in dtypes:
        for ln, b in cells:
            n = 1 << ln
            if b * n > (1 << 24):
                b = max(1, (1 << 24) // n)
            x = (rng.standard_normal((b, n)) +
                 1j * rng.standard_normal((b, n))).astype(dt)
            xj = jnp.asarray(x)
            t_t = timeit(turbo, xj)
            t_r = timeit(ref, xj)
            ratio = t_r / t_t
            emit(f"fft_{dt[-2:]}_N2^{ln}_b{b}_turbo", t_t * 1e6,
                 f"{fft_gflops(n, b, t_t):.2f}GF/s;"
                 f"{fft_gbytes(n, b, t_t):.2f}GB/s;vs_platform={ratio:.2f}x")
            rows.append((dt, ln, b, t_t, t_r))
    return rows


if __name__ == "__main__":
    run(smoke=False)
