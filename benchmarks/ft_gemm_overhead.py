"""ABFT-GEMM overhead: checked vs unchecked matmul through the plan layer.

The two-side scheme adds four rank-1 GEMVs and a per-column decode to one
``(M, K) @ (K, N)`` product — O(MK + KN + MN) checksum work against the
O(MKN) GEMM, so overhead shrinks with K. This cell measures it end-to-end
through ``core.gemm`` (the exact path protected linears take) on the XLA
interpreter backend and asserts the plan-layer contract the serving stack
relies on: checked GEMM costs < 25% over the unchecked baseline at
transformer-like shapes. Timing is best-of-10 (overhead claims want the
noise floor, not the median).
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import gemm
from repro.core.plan import FTConfig

from .common import emit

OVERHEAD_BUDGET = 0.25  # serving-stack contract: checked GEMM < 25% over


def _best_of(fn, *args, warmup=2, iters=10):
    """Min wall time (s) over ``iters`` runs — the overhead estimator."""
    for _ in range(warmup):
        r = fn(*args)
        jax.tree_util.tree_map(
            lambda l: l.block_until_ready() if hasattr(l, "block_until_ready")
            else l, r)
    best = np.inf
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.tree_util.tree_map(
            lambda l: l.block_until_ready() if hasattr(l, "block_until_ready")
            else l, r)
        best = min(best, time.perf_counter() - t0)
    return float(best)


def run(smoke: bool = True):
    rng = np.random.default_rng(7)
    # K large enough that the O(MKN) product dominates the O(MN) strips —
    # the transformer regime (d_ff-sized contractions); at K=512 the decode
    # passes over Y cost ~45% on CPU and the contract does not hold
    shapes = ([(512, 4096, 4096)] if smoke
              else [(1024, 4096, 1024), (512, 4096, 4096)])
    results = {}
    for m, k, n in shapes:
        x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
        p = gemm.plan(gemm.spec_for(x, w, ft=FTConfig(threshold=1e-3),
                                    backend="xla"))
        base = jax.jit(p.matmul)
        ft = jax.jit(p.ft_matmul)
        t_base = _best_of(base, x, w)
        t_ft = _best_of(ft, x, w)
        ovh = t_ft / t_base - 1
        results[(m, k, n)] = ovh
        emit(f"ft_gemm_base_m{m}_k{k}_n{n}", t_base * 1e6, "overhead=0%")
        emit(f"ft_gemm_abft_m{m}_k{k}_n{n}", t_ft * 1e6,
             f"overhead={100 * ovh:.1f}% backend={p.backend}")
        assert ovh < OVERHEAD_BUDGET, (
            f"checked GEMM overhead {100 * ovh:.1f}% blew the "
            f"{100 * OVERHEAD_BUDGET:.0f}% budget at {(m, k, n)}")
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    print("name,us_per_call,derived")
    run(smoke=not ap.parse_args().full)
