"""§Perf Cell 3B: ABFT-GEMM (FTLinear) overhead at LM-training scale.

Compiles gemma3-1b train_4k on the production pod mesh with and without
``ft.protect_linears`` and reports the compiled-HLO flops/bytes delta — the
paper's 'fused checksum overhead' claim (Figs 16-18) measured on the LM
integration instead of the FFT kernel.

    PYTHONPATH=src python -m benchmarks.ft_overhead_cell
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import dataclasses
import json

import jax


def main(arch: str = "gemma3_1b", shape: str = "train_4k"):
    from repro.configs import SHAPES, get_config
    from repro.configs.base import ParallelConfig
    from repro.core.ft import FTPolicy
    from repro.launch import dryrun as dr
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh()
    parallel = ParallelConfig()
    out = {}
    for tag, ft in (("ft_off", FTPolicy(protect_linears=False)),
                    ("ft_on", FTPolicy(protect_linears=True,
                                       threshold=1e-2))):
        cfg = dataclasses.replace(get_config(arch), ft=ft)
        lowered, ntoks, _ = dr._lower_cell(cfg, SHAPES[shape], mesh, parallel)
        with mesh:
            compiled = lowered.compile()
        out[tag] = dr._analyze(compiled)
        print(tag, "flops/dev=%.3e bytes/dev=%.3e" %
              (out[tag]["flops"], out[tag]["bytes_accessed"]), flush=True)
    f0, f1 = out["ft_off"]["flops"], out["ft_on"]["flops"]
    b0, b1 = (out["ft_off"]["bytes_accessed"],
              out["ft_on"]["bytes_accessed"])
    rec = {
        "arch": arch, "shape": shape,
        "flops_overhead_pct": 100 * (f1 / f0 - 1),
        "bytes_overhead_pct": 100 * (b1 / b0 - 1),
        "ft_off": out["ft_off"], "ft_on": out["ft_on"],
    }
    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/ft_overhead_cell.json", "w") as f:
        json.dump(rec, f, indent=1, default=str)
    print(f"ABFT-GEMM overhead: flops {rec['flops_overhead_pct']:+.2f}%  "
          f"bytes {rec['bytes_overhead_pct']:+.2f}%")
    return rec


if __name__ == "__main__":
    main()
