"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Full (non-smoke) runs:
``python -m benchmarks.<name>`` individually.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale grids (slow on CPU)")
    ap.add_argument("--skip-roofline", action="store_true")
    args, _ = ap.parse_known_args()
    smoke = not args.full

    print("name,us_per_call,derived")

    from . import plan_table
    plan_table.run(smoke=smoke)

    from . import fft_throughput
    fft_throughput.run(smoke=smoke)

    from . import stepwise_opt
    stepwise_opt.run(smoke=smoke)

    from . import fft_roofline
    fft_roofline.run(smoke=smoke)

    from . import abft_overhead
    abft_overhead.run(smoke=smoke)

    from . import ft_gemm_overhead
    ft_gemm_overhead.run(smoke=smoke)

    from . import error_injection
    error_injection.run(smoke=smoke)

    from . import fft_distributed
    fft_distributed.run(smoke=smoke)
    fft_distributed.run_mesh2d(smoke=smoke)

    if not args.skip_roofline:
        import os

        from . import roofline
        if os.path.isdir("artifacts/dryrun"):
            roofline.run(smoke=smoke)


if __name__ == "__main__":
    main()
