"""Paper Figs. 16-18: ABFT overhead ladder.

(a) offline FT-FFT  — separate checksum passes + recompute-style correction
(c) thread-level    — fused per-signal checksums (compute-heavy, zero memory)
(d) threadblock     — fused group checksums, 1 transaction
(e/f) multi-txn     — group checksums amortized over T=2/4 transactions

All variants run as single jitted XLA programs (the CPU analogue of kernel
fusion); the Pallas kernels implement the same dataflow for TPU and are
validated in tests/test_kernels.py. Overhead is reported vs the unprotected
TurboFFT baseline, as in Fig. 16's heatmaps.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import abft
from repro.core.abft.encoding import left_encoding, left_encoding_image
from repro.core.fft import block_fft_stages

from .common import emit, timeit


def _fused_twoside(x, ew, e1, txn: int, per_signal: bool):
    """jnp-level image of the fused two-sided ABFT kernel."""
    b, n = x.shape
    g = max(b // max(txn, 1), 1)
    y = block_fft_stages(x)
    outs = [y]
    if per_signal:
        s_in = x @ ew
        s_out = y @ e1
        outs.append(jnp.abs(s_in - s_out) / (jnp.abs(s_in) + 1e-30))
    # right-side group checksums (e2 = ones, e3 = location)
    loc = jnp.arange(1, b + 1, dtype=jnp.float32)[:, None]
    xg = x.reshape(g, -1, n)
    yg = y.reshape(g, -1, n)
    lg = loc.reshape(g, -1, 1)
    cs = jnp.stack([xg.sum(1).real, xg.sum(1).imag,
                    (xg * lg).sum(1).real, (xg * lg).sum(1).imag,
                    yg.sum(1).real, yg.sum(1).imag,
                    (yg * lg).sum(1).real, (yg * lg).sum(1).imag], axis=1)
    outs.append(cs)
    return tuple(outs)


def run(smoke: bool = True):
    rng = np.random.default_rng(2)
    n = 1 << (10 if smoke else 12)
    b = 64 if smoke else 1024
    x = jnp.asarray((rng.standard_normal((b, n)) +
                     1j * rng.standard_normal((b, n))).astype(np.complex64))
    ew = jnp.asarray(left_encoding_image(n, "wang"), jnp.complex64)
    e1 = jnp.asarray(left_encoding(n, "wang"), jnp.complex64)

    base = jax.jit(block_fft_stages)
    t_base = timeit(base, x)
    emit(f"abft_base_noft_N{n}_b{b}", t_base * 1e6, "overhead=0%")

    # Offline FT-FFT is by definition SEPARATE kernel launches around a
    # library FFT (checksum pass -> FFT -> verify pass [-> recompute]), so
    # its wall time is the sum of the independent launches — measured that
    # way (a single fused jit would let XLA CSE the recompute, which real
    # offline schemes cannot). One error per call (sustained-error regime).
    t_cs_in = timeit(jax.jit(lambda v: v @ ew), x)
    t_cs_out = timeit(jax.jit(lambda v: v @ e1), base(x))
    t_off = t_cs_in + t_base + t_cs_out + t_base  # + time-redundant recompute
    emit(f"abft_a_offline_N{n}_b{b}", t_off * 1e6,
         f"overhead={100 * (t_off / t_base - 1):.0f}% (1 err/call)")

    results = {"offline": t_off / t_base - 1}
    variants = [("c_thread", 1, True), ("d_block_t1", 1, False),
                ("e_block_t2", 2, False), ("f_block_t4", 4, False)]
    for name, txn, per_sig in variants:
        fn = jax.jit(functools.partial(_fused_twoside, txn=txn,
                                       per_signal=per_sig))
        t = timeit(fn, x, ew, e1)
        ovh = t / t_base - 1
        results[name] = ovh
        emit(f"abft_{name}_N{n}_b{b}", t * 1e6,
             f"overhead={100 * ovh:.0f}%")
    return results


if __name__ == "__main__":
    run(smoke=False)
