"""Paper Fig. 19/20/22: detection ROC + overhead under sustained injection.

ROC (Fig 19): 2000 signals, faults injected into half by flipping exactly one
random bit of one element (the paper's §5.3.1 methodology); the left-checksum
divergence score is swept over the threshold delta to trace (false-alarm,
detection) pairs.

Injection overhead (Fig 20/22): ft_fft pipeline driven by a Poisson fault
schedule; overhead vs the fault-free run isolates the cost of online
correction (one extra group FFT per fault — no recomputation).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.abft.encoding import left_encoding, left_encoding_image
from repro.core.fft import block_fft_stages
from repro.core.ft import injection
from repro.kernels import ops

from .common import emit, timeit


def roc(smoke: bool = True, dtype=np.complex64):
    rng = np.random.default_rng(3)
    n = 256 if smoke else 1024
    trials = 400 if smoke else 2000
    half = trials // 2
    x = (rng.standard_normal((trials, n)) +
         1j * rng.standard_normal((trials, n))).astype(dtype)
    corrupted = x.copy()
    for i in range(half):  # corrupt the first half (one bit flip each)
        corrupted[i:i + 1], _, _ = injection.random_flip(
            rng, corrupted[i:i + 1])

    ew = jnp.asarray(left_encoding_image(n, "wang"),
                     jnp.complex128 if dtype == np.complex128
                     else jnp.complex64)
    e1 = jnp.asarray(left_encoding(n, "wang"), ew.dtype)

    @jax.jit
    def scores(x_clean, x_corr):
        s_in = x_clean @ ew                      # checksum of intended input
        y = block_fft_stages(x_corr)             # compute on corrupted data
        s_out = y @ e1
        return jnp.abs(s_in - s_out) / (jnp.abs(s_in) + 1e-30)

    sc = np.asarray(scores(jnp.asarray(x), jnp.asarray(corrupted)))
    fault_scores, clean_scores = sc[:half], sc[half:]
    points = []
    for delta in np.logspace(-8, 1, 19):
        det = float(np.mean(fault_scores > delta))
        fa = float(np.mean(clean_scores > delta))
        points.append((delta, det, fa))
    # operating point: highest detection with zero false alarms
    best = max((p for p in points if p[2] == 0.0),
               key=lambda p: p[1], default=points[-1])
    emit(f"roc_{np.dtype(dtype).name}_N{n}", 0.0,
         f"delta*={best[0]:.1e};detect={best[1]:.2f};fa={best[2]:.3f}")
    return points, best


def injection_overhead(smoke: bool = True):
    rng = np.random.default_rng(4)
    n = 256 if smoke else 1024
    b, bs = 32, 8
    steps = 10 if smoke else 50
    x = jnp.asarray((rng.standard_normal((b, n)) +
                     1j * rng.standard_normal((b, n))).astype(np.complex64))
    sched = injection.poisson_schedule(
        rng, steps=steps, rate_per_step=0.5, tiles=b // bs, bs=bs, n=n)

    def run_steps(with_faults: bool):
        tot = 0.0
        for s in range(steps):
            inj = sched.for_step(s) if with_faults else None
            r = ops.ft_fft(x, transactions=2, bs=bs, inject=inj)
            r.y.block_until_ready()
        return r

    import time
    for fn, name in ((lambda: run_steps(False), "no_inject"),
                     (lambda: run_steps(True), "injected")):
        fn()  # warmup/compile
        t0 = time.perf_counter()
        fn()
        dt = (time.perf_counter() - t0) / steps
        if name == "no_inject":
            base = dt
        emit(f"ftfft_{name}_N{n}_b{b}", dt * 1e6,
             f"faults={sched.num_faults if name == 'injected' else 0};"
             f"overhead={100 * (dt / base - 1):.0f}%")
    return sched.num_faults


def run(smoke: bool = True):
    pts32, best32 = roc(smoke, np.complex64)
    pts64, best64 = roc(smoke, np.complex128)
    nf = injection_overhead(smoke)
    return {"roc_fp32": best32, "roc_fp64": best64, "faults": nf}


if __name__ == "__main__":
    run(smoke=False)
