"""§Dry-run summary table: every (arch x shape x mesh) cell's status,
memory/device, compile time — written to artifacts/dryrun_summary.md."""
from __future__ import annotations

import glob
import json
import os


def run(art_dir: str = "artifacts/dryrun",
        out_md: str = "artifacts/dryrun_summary.md"):
    recs = []
    for p in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    lines = ["| arch | shape | mesh | status | params | mem/dev GB "
             "| compile_s |", "|---|---|---|---|---|---|---|"]
    ok = skip = err = 0
    for r in recs:
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        if r["status"] == "ok":
            ok += 1
            mem = r.get("memory", {}).get("bytes_per_device", 0) / 1e9
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | ok "
                f"| {r.get('params', 0)/1e9:.1f}B | {mem:.1f} "
                f"| {r.get('compile_s', '')} |")
        elif r["status"] == "skipped":
            skip += 1
            lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | skip: "
                         f"{r.get('reason', '')[:40]} | | | |")
        else:
            err += 1
            lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | ERROR "
                         f"{r.get('error', '')[:60]} | | | |")
    header = (f"# Dry-run summary: {ok} compiled, {skip} documented skips, "
              f"{err} errors\n\n")
    with open(out_md, "w") as f:
        f.write(header + "\n".join(lines) + "\n")
    print(header.strip())
    return ok, skip, err


if __name__ == "__main__":
    run()
