"""Distributed FFT: per-pass collective volume + wall time vs single device.

For each (N, batch, shards) cell this measures three things:

* wall time of the sharded pipeline vs the single-device multi-pass driver,
* the all-to-all / psum wire bytes parsed from the post-partitioning HLO
  (launch.dryrun.collective_bytes — the same parser the LM dry-run uses),
* the analytic model ``core.fft.distributed.collective_volume`` — the two
  must agree, which is the point: ONE all-to-all per transform, ABFT adding
  only the 2/B checksum rows plus a 3-scalar psum.

The ABFT model==HLO assertion runs for BOTH complex64 and complex128 (the
verdict psum scalars are f32 vs f64 — the model derives their width from
``itemsize``) and for BOTH the single-group and the grouped
multi-transaction pipeline (G checksum groups -> 2G checksum rows on the
all-to-all + 3G+1 psum scalars). On a 2-D ``data x fft`` mesh the grouped
ft pipeline is additionally verified to shard the batch: model==HLO with
``data_shards`` and ZERO all-gathers in transposed order. The
transposed-order spectral pipeline (fft_convolve / round-trip ifft(fft)) is
verified to lower to exactly TWO all-to-alls and ZERO all-gathers, with
bytes matching ``spectral_volume``. ``run_multidim`` extends the same
contract to the 2-D transforms (core.fft.multidim): slab == one all-to-all
with free natural order (plus the grouped-ABFT checksum grids and psum,
fp32 and fp64), pencil == two all-to-alls (zero gathers transposed, the
modeled digit-restore gathers natural), and the fused 2-D convolution ==
two all-to-alls — all hard-asserted against ``collective_volume_nd``.

``run_overlap`` pins down the chunked multi-transaction pipelines: for each
chunk count C the 1-D, grouped-ABFT, and spectral pipelines must lower to
exactly C (resp. 2C) all-to-alls with unchanged total volume, the measured
exposed-communication fraction (largest single all-to-all / total) must
equal the model's ``1/C``, and every chunked output must be bitwise
identical to the bulk pipeline.

Standalone runs force a multi-device host platform:

    PYTHONPATH=src python -m benchmarks.fft_distributed
"""
from __future__ import annotations

import os

if __name__ == "__main__" and "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=4")

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import fft as tfft
from repro.core.fft import distributed as dist
from repro.core.fft import spectral as spec
from repro.launch.dryrun import collective_bytes

from .common import emit, fft_gflops, timeit


def _measured_collectives(fn, *args) -> dict:
    hlo = fn.lower(*args).compile().as_text()
    return collective_bytes(hlo)


def grid(smoke: bool = True):
    if smoke:
        return [(14, 8), (17, 2)]
    return [(ln, b) for ln in (14, 17, 20, 23) for b in (1, 8, 64)]


def run(smoke: bool = True):
    ndev = min(4, len(jax.devices()))
    shards = 1 << (ndev.bit_length() - 1)  # largest power of two that fits
    if shards < 2:
        print("# fft_distributed: single device visible — skipping "
              "(set --xla_force_host_platform_device_count)")
        return []
    mesh = jax.make_mesh((shards,), ("fft",))
    rng = np.random.default_rng(0)
    rows = []
    for ln, b in grid(smoke):
        n = 1 << ln
        x = (rng.standard_normal((b, n)) +
             1j * rng.standard_normal((b, n))).astype(np.complex64)
        xj = jnp.asarray(x)

        single = jax.jit(tfft.fft)
        t_1 = timeit(single, xj)
        t_d = timeit(lambda v: dist.distributed_fft(v, mesh), xj)
        t_ft = timeit(lambda v: dist.ft_distributed_fft(v, mesh).y, xj)

        # measured collective bytes (HLO) vs the analytic model, for the
        # natural-order, transposed-order, and ABFT pipelines
        # natural_order passed explicitly: lru_cache keys on the raw call
        # signature, so defaulting it here would double-compile the same
        # pipeline distributed_fft already built with 4 positional args
        meas = _measured_collectives(
            dist._dist_fft_fn(mesh, "fft", False, True), xj)
        meas_t = _measured_collectives(
            dist._dist_fft_fn(mesh, "fft", False, False), xj)
        meas_ft = _measured_collectives(
            dist._ft_dist_fft_fn(mesh, "fft", 1e-4, True), xj,
            jnp.zeros((1, 7), jnp.float32))
        # fp64: the ABFT verdict psum carries f64 scalars — the model must
        # track the itemsize instead of assuming 4-byte reductions
        x128 = jnp.asarray(x.astype(np.complex128))
        meas_ft64 = _measured_collectives(
            dist._ft_dist_fft_fn(mesh, "fft", 1e-4, True), x128,
            jnp.zeros((1, 7), jnp.float64))
        model = dist.collective_volume(n, b, shards)
        model_t = dist.collective_volume(n, b, shards, natural_order=False)
        model_ft = dist.collective_volume(n, b, shards, ft=True)
        model_ft64 = dist.collective_volume(n, b, shards, ft=True,
                                            itemsize=16)
        # grouped multi-transaction ABFT: G checksum groups ride as 2G rows
        # on the same all-to-all; the verdict is 3G+1 psum scalars. The
        # grouped verdict traffic must hold model==HLO in fp32 AND fp64.
        grouped_cells = []
        g = min(4, b)
        if b % g == 0 and g > 1:
            meas_g = _measured_collectives(
                dist._ft_dist_fft_fn(mesh, "fft", 1e-4, True, True, g), xj,
                jnp.zeros((1, 7), jnp.float32))
            meas_g64 = _measured_collectives(
                dist._ft_dist_fft_fn(mesh, "fft", 1e-4, True, True, g), x128,
                jnp.zeros((1, 7), jnp.float64))
            model_g = dist.collective_volume(n, b, shards, ft=True, groups=g)
            model_g64 = dist.collective_volume(n, b, shards, ft=True,
                                               groups=g, itemsize=16)
            grouped_cells = [(f"ft_g{g}", meas_g, model_g),
                             (f"ft_g{g}_c128", meas_g64, model_g64)]
        # transposed-order round trip + fused convolve: exactly 2 all-to-alls
        # and zero all-gathers (the batch-split inverse needs D | batch for
        # a pad-free pipeline, so model==HLO only holds on those cells)
        spectral_cells = []
        if b % shards == 0:
            rt = jax.jit(lambda v: dist.distributed_ifft(
                dist.distributed_fft(v, mesh, natural_order=False), mesh,
                natural_order=False))
            meas_rt = _measured_collectives(rt, xj)
            model_rt = dist.spectral_volume(n, b, shards)
            vj = jnp.asarray((rng.standard_normal((1, n)) +
                              1j * rng.standard_normal((1, n))
                              ).astype(np.complex64))
            meas_cv = _measured_collectives(
                spec._spectral_pair_fn(mesh, "fft", None, False), xj, vj)
            model_cv = dist.spectral_volume(n, b, shards, kernel_batch=1)
            spectral_cells = [("spectral_rt", meas_rt, model_rt),
                              ("spectral_conv", meas_cv, model_cv)]
            for tag, m, mdl in spectral_cells:
                assert m["count"]["all-to-all"] == mdl["all_to_all_count"], (
                    tag, m["count"])
                assert m["count"]["all-gather"] == 0, (tag, m["count"])

        emit(f"distfft_N2^{ln}_b{b}_x{shards}", t_d * 1e6,
             f"{fft_gflops(n, b, t_d):.2f}GF/s;vs_single={t_1/t_d:.2f}x;"
             f"ft_overhead={(t_ft - t_d)/t_d:+.1%}")
        for tag, m, mdl in [("natural", meas, model),
                            ("transposed", meas_t, model_t),
                            ("ft", meas_ft, model_ft),
                            ("ft_c128", meas_ft64, model_ft64),
                            ] + grouped_cells + spectral_cells:
            got = m.get("total_bytes", 0.0)
            want = mdl["hlo_bytes"]
            agree = got / want if want else float("nan")
            # hard model==HLO check, pure relative tolerance: the parser
            # dedupes async start/done tuples and the model carries the
            # replicated-stats broadcast, so there is no absolute slack
            assert want and abs(agree - 1.0) < 1e-3, (tag, got, want)
            emit(f"distfft_N2^{ln}_b{b}_wire_{tag}", got,
                 f"model={want:.0f}B;hlo/model={agree:.3f};"
                 f"wire={mdl['total_wire']:.0f}B")
        rows.append((ln, b, t_1, t_d, t_ft, meas, model, meas_ft, model_ft))
    return rows


def run_multidim(smoke: bool = True):
    """Multi-dimensional (fft2) collective-volume model == HLO, both
    decompositions (core.fft.multidim):

    * slab — ONE all-to-all, zero all-gathers even in natural order (the
      sharding lands on a true array axis), grouped-ABFT cells in fp32 AND
      fp64 (checksum grids ride the transpose + the 3G+1-scalar psum);
    * pencil — TWO all-to-alls on a 2-D ``data x fft`` mesh (one per mesh
      axis) with zero all-gathers in transposed order; natural order adds
      the modeled digit-restore gathers (``full/data + full`` bytes);
    * the fused 2-D convolution round trip — exactly two all-to-alls and
      zero all-gathers, kernel spectra riding the forward transpose.
    """
    ndev = min(4, len(jax.devices()))
    shards = 1 << (ndev.bit_length() - 1)
    if shards < 2:
        print("# fft_multidim: single device visible — skipping")
        return []
    from repro.core.fft import multidim as md

    mesh = jax.make_mesh((shards,), ("fft",))
    rng = np.random.default_rng(2)
    rows = []
    for rr, cc, b in [(128, 256, 8)] if smoke else [(128, 256, 8),
                                                    (512, 1024, 8)]:
        x = jnp.asarray((rng.standard_normal((b, rr, cc)) +
                         1j * rng.standard_normal((b, rr, cc))
                         ).astype(np.complex64))
        x128 = x.astype(jnp.complex128)
        g = 4
        cells = [
            ("slab", _measured_collectives(
                md._slab_fftn_fn(mesh, "fft", 2, False, None), x),
             md.collective_volume_nd((rr, cc), b, shards)),
            ("slab_ft", _measured_collectives(
                md._ft_slab_fft2_fn(mesh, "fft", 1e-4, True, g, None), x,
                jnp.zeros((1, 7), jnp.float32)),
             md.collective_volume_nd((rr, cc), b, shards, ft=True, groups=g)),
            ("slab_ft_c128", _measured_collectives(
                md._ft_slab_fft2_fn(mesh, "fft", 1e-4, True, g, None), x128,
                jnp.zeros((1, 7), jnp.float64)),
             md.collective_volume_nd((rr, cc), b, shards, ft=True, groups=g,
                                     itemsize=16)),
        ]
        # slab (incl. ft) never all-gathers: natural order is free
        for tag, m, mdl in cells:
            assert m["count"]["all-to-all"] == mdl["all_to_all_count"], (
                tag, m["count"])
            assert m["count"]["all-gather"] == 0, (tag, m["count"])
        # fused 2-D convolution: kernel rides the forward transpose, the
        # product comes back through the mirrored inverse — 2 a2a total
        vk = jnp.asarray((rng.standard_normal((1, rr, cc)) +
                          1j * rng.standard_normal((1, rr, cc))
                          ).astype(np.complex64))
        meas_cv = _measured_collectives(
            md._conv2_pair_fn(mesh, "fft", None), x, vk)
        fwd = md.collective_volume_nd((rr, cc), b + 1, shards)
        inv = md.collective_volume_nd((rr, cc), b, shards)
        model_cv = {
            "all_to_all_count": 2, "all_gather_count": 0,
            "total_wire": fwd["total_wire"] + inv["total_wire"],
            "hlo_bytes": fwd["hlo_bytes"] + inv["hlo_bytes"]}
        assert meas_cv["count"]["all-to-all"] == 2, meas_cv["count"]
        assert meas_cv["count"]["all-gather"] == 0, meas_cv["count"]
        cells.append(("conv2", meas_cv, model_cv))
        if len(jax.devices()) >= 4:
            mesh2 = jax.make_mesh((2, 2), ("data", "fft"))
            for nat in (False, True):
                meas_p = _measured_collectives(
                    md._pencil_fftn_fn(mesh2, "fft", 2, False, nat, "data"),
                    x)
                mdl_p = md.collective_volume_nd(
                    (rr, cc), b, 2, decomp="pencil", data_shards=2,
                    natural_order=nat)
                assert meas_p["count"]["all-to-all"] == \
                    mdl_p["all_to_all_count"], (nat, meas_p["count"])
                assert meas_p["count"]["all-gather"] == \
                    mdl_p["all_gather_count"], (nat, meas_p["count"])
                cells.append((f"pencil_{'nat' if nat else 'transposed'}",
                              meas_p, mdl_p))
            # grouped ABFT on the 2-D mesh: batch SHARDS over data, no
            # batch all-gather, verdict psum confined to the fft axis
            meas_ft2 = _measured_collectives(
                md._ft_slab_fft2_fn(mesh2, "fft", 1e-4, True, g, "data"), x,
                jnp.zeros((1, 7), jnp.float32))
            assert meas_ft2["count"]["all-gather"] == 0, meas_ft2["count"]
            cells.append(("slab_ft_2d", meas_ft2, md.collective_volume_nd(
                (rr, cc), b, 2, ft=True, groups=g, data_shards=2)))
        for tag, m, mdl in cells:
            got = m.get("total_bytes", 0.0)
            want = mdl["hlo_bytes"]
            agree = got / want if want else float("nan")
            assert want and abs(agree - 1.0) < 1e-3, (tag, got, want)
            emit(f"fft2_{rr}x{cc}_b{b}_wire_{tag}", got,
                 f"model={want:.0f}B;hlo/model={agree:.3f};"
                 f"wire={mdl['total_wire']:.0f}B")
        rows.append((rr, cc, b, cells))
    return rows


def run_mesh2d(smoke: bool = True):
    """Grouped ABFT on a 2-D ``data x fft`` mesh: the batch SHARDS over the
    data axis (each data shard owns G/data whole checksum groups), the
    verdict psum stays confined to the fft axis, and transposed order pays
    ZERO all-gathers — all asserted model==HLO with ``data_shards``."""
    if len(jax.devices()) < 4:
        print("# fft_distributed 2-D: needs 4 devices — skipping")
        return []
    mesh = jax.make_mesh((2, 2), ("data", "fft"))
    rng = np.random.default_rng(1)
    rows = []
    for ln, b, g in [(14, 8, 4)] if smoke else [(14, 8, 4), (17, 16, 8)]:
        n = 1 << ln
        x = jnp.asarray((rng.standard_normal((b, n)) +
                         1j * rng.standard_normal((b, n))
                         ).astype(np.complex64))
        for nat in (True, False):
            meas = _measured_collectives(
                dist._ft_dist_fft_fn(mesh, "fft", 1e-4, True, nat, g,
                                     "data"),
                x, jnp.zeros((1, 7), jnp.float32))
            mdl = dist.collective_volume(n, b, 2, ft=True, groups=g,
                                         data_shards=2, natural_order=nat)
            got, want = meas["total_bytes"], mdl["hlo_bytes"]
            assert want and abs(got / want - 1.0) < 1e-3, (nat, got, want)
            # the batch never all-gathers: transposed order has no gather
            # at all, natural order only the fft-axis spectrum gather
            assert meas["count"]["all-gather"] == (1 if nat else 0), (
                nat, meas["count"])
            tag = "nat" if nat else "transposed"
            emit(f"distfft2d_N2^{ln}_b{b}_g{g}_wire_{tag}", got,
                 f"model={want:.0f}B;hlo/model={got/want:.3f}")
            rows.append((ln, b, g, nat, meas, mdl))
    return rows


def run_plan_reuse(smoke: bool = True):
    """Plan-cached dispatch vs per-call kwarg dispatch, on host-mesh wall
    clock. Both paths execute the SAME cached jitted pipeline (bitwise
    asserted), so the delta is pure dispatch: the legacy path rebuilds the
    spec and re-walks the deprecation/validation/plan-lookup machinery per
    call, while the plan executor is a straight bound call. The cell
    asserts (a) plan-cached dispatch is at least as fast, (b) the
    collective-volume model==HLO invariant holds when lowering THROUGH the
    plan executor (i.e. the single api.py dispatch path did not change the
    collectives), and (c) plan.volume IS that model."""
    import time as _time
    import warnings

    from repro.core.fft import FFTSpec, FTConfig, api, plan
    from repro.kernels import ops

    ndev = min(4, len(jax.devices()))
    shards = 1 << (ndev.bit_length() - 1)
    if shards < 2:
        print("# fft_plan_reuse: single device visible — skipping")
        return []
    mesh = jax.make_mesh((shards,), ("fft",))
    rng = np.random.default_rng(3)
    rows = []
    # small N so wall clock is dispatch-dominated (the quantity under
    # test: both paths run the SAME cached jitted pipeline, so at large N
    # the compute equalizes them and the comparison is vacuous)
    for ln, b in [(10, 8)] if smoke else [(10, 8), (12, 64)]:
        n = 1 << ln
        x = jnp.asarray((rng.standard_normal((b, n)) +
                         1j * rng.standard_normal((b, n))
                         ).astype(np.complex64))
        p = plan(FFTSpec(shape=(b, n), mesh=mesh))
        xs = p.shard(x)

        def measure(fn, iters=20):
            jax.block_until_ready(fn())
            t0 = _time.perf_counter()
            r = None
            for _ in range(iters):
                r = fn()
            jax.block_until_ready(r)
            return (_time.perf_counter() - t0) / iters

        # INTERLEAVED min-of-reps: both paths run the same cached jitted
        # pipeline, so the delta under test is pure python dispatch —
        # alternating the measurements inside one rep loop cancels host
        # load drift, and min is the noise-robust estimator
        legacy_fn = lambda: ops.fft(xs, mesh=mesh)  # per-call kwarg dispatch
        plan_fn = lambda: p.fft(xs)                 # plan-cached dispatch
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", api.FFTKwargDeprecationWarning)
            y_legacy = legacy_fn()
            tl, tp = [], []
            for _ in range(10):
                tl.append(measure(legacy_fn))
                tp.append(measure(plan_fn))
            t_legacy, t_plan = min(tl), min(tp)
        y_plan = plan_fn()
        np.testing.assert_array_equal(np.asarray(y_plan),
                                      np.asarray(y_legacy))
        # the rewire must not cost throughput: cached dispatch >= legacy.
        # The typical margin (legacy's per-call spec build) is ~1-30% at
        # this size; the generous 1.5x slack keeps this a catastrophic-
        # regression guard (e.g. an executor re-resolving per call) rather
        # than a bet on shared-runner timer stability — the emitted
        # speedup column is the recorded comparison (EXPERIMENTS.md)
        assert t_plan <= t_legacy * 1.5, (t_plan, t_legacy)
        # model==HLO through the plan executor (the api.py dispatch path);
        # lowered with the uncommitted operand, like every other cell —
        # a block-committed input would add the one-off ingest relayout
        # (shard_signals docstring) on top of the pipeline's own traffic
        meas = _measured_collectives(p._fwd, x)
        model = p.volume
        assert model == dist.collective_volume(n, b, shards)
        got, want = meas["total_bytes"], model["hlo_bytes"]
        assert want and abs(got / want - 1.0) < 1e-3, (got, want)
        # ft plan: same contract, grouped verdict traffic included. Pure
        # relative tolerance — the parser dedupes async start/done tuples
        # (keeping the result half) and the model includes the replicated
        # per-group stats broadcast, so no absolute byte floor is needed
        # even on these KB-scale dispatch cells
        g = 4
        pf = plan(FFTSpec(shape=(b, n), mesh=mesh, ft=FTConfig(groups=g)))
        from repro.core.fft.distributed import _ft_dist_fft_fn
        meas_ft = _measured_collectives(
            _ft_dist_fft_fn(mesh, "fft", 1e-4, True, True, g, None), x,
            jnp.zeros((1, 7), jnp.float32))
        want_ft = pf.volume["hlo_bytes"]
        assert want_ft and \
            abs(meas_ft["total_bytes"] / want_ft - 1.0) < 1e-3, \
            (meas_ft["total_bytes"], want_ft)
        emit(f"plan_reuse_N2^{ln}_b{b}_x{shards}", t_plan * 1e6,
             f"legacy={t_legacy*1e6:.1f}us;speedup={t_legacy/t_plan:.2f}x;"
             f"hlo/model={got/want:.3f}")
        rows.append((ln, b, t_plan, t_legacy, got, want))
    return rows


def run_overlap(smoke: bool = True):
    """Chunked multi-transaction (double-buffered) pipelines: the overlap
    model == HLO structure, hard-asserted.

    For each chunk count C the chunked 1-D pipeline must lower to exactly
    C all-to-alls whose TOTAL bytes equal ``collective_volume(chunks=C)``
    — chunking re-grains the transfer, it must not add volume — and the
    measured exposed-communication fraction (the largest single
    all-to-all's bytes over the total: only one transaction's transfer has
    no neighbouring local Stockham work to hide behind) must equal the
    model's ``exposed_fraction = 1/C``. Outputs are asserted bitwise
    identical to the bulk (C=1) pipeline — chunking is an execution
    schedule, not a numerical change. The ft cell runs the grouped ABFT
    chunked (whole checksum groups per transaction, each with its own
    verdict psum); the spectral cell the 2C-all-to-all convolution round
    trip. Wall clock per chunk count is emitted UNASSERTED: host-mesh
    collectives are shared-memory memcpys with nothing to overlap, so the
    latency win is a device-network property — the structural assertions
    (count, bytes, exposed fraction, bitwise identity) are the contract.
    """
    ndev = min(4, len(jax.devices()))
    shards = 1 << (ndev.bit_length() - 1)
    if shards < 2:
        print("# fft_overlap: single device visible — skipping")
        return []
    mesh = jax.make_mesh((shards,), ("fft",))
    rng = np.random.default_rng(5)
    rows = []
    for ln, b in [(14, 8)] if smoke else [(14, 8), (17, 16)]:
        n = 1 << ln
        x = jnp.asarray((rng.standard_normal((b, n)) +
                         1j * rng.standard_normal((b, n))
                         ).astype(np.complex64))
        y_bulk = np.asarray(
            dist._dist_fft_fn(mesh, "fft", False, True, None, 1)(x))
        for c in (1, 2, 4):
            if b % c:
                continue
            fn = dist._dist_fft_fn(mesh, "fft", False, True, None, c)
            meas = _measured_collectives(fn, x)
            mdl = dist.collective_volume(n, b, shards, chunks=c)
            a2a = [w for k, w in meas["ops"] if k == "all-to-all"]
            assert len(a2a) == mdl["all_to_all_count"] == c, (c,
                                                              meas["count"])
            got, want = meas["total_bytes"], mdl["hlo_bytes"]
            assert want and abs(got / want - 1.0) < 1e-3, (c, got, want)
            exposed = max(a2a) / sum(a2a)
            assert abs(exposed - mdl["exposed_fraction"]) < 1e-9, (
                c, exposed, mdl["exposed_fraction"])
            y_c = np.asarray(fn(x))
            np.testing.assert_array_equal(y_c, y_bulk)
            t_c = timeit(fn, x)
            emit(f"overlap_N2^{ln}_b{b}_c{c}", t_c * 1e6,
                 f"a2a={len(a2a)};exposed={exposed:.3f};"
                 f"model={mdl['exposed_fraction']:.3f};"
                 f"hlo/model={got/want:.3f}")
            rows.append((ln, b, c, t_c, exposed, got, want))
        # grouped ABFT, chunked: whole checksum groups per transaction,
        # one verdict psum each — telemetry AND outputs bitwise identical
        g = min(4, b)
        if g > 1 and b % g == 0:
            inj = jnp.zeros((1, 7), jnp.float32)
            bulk_ft = dist._ft_dist_fft_fn(mesh, "fft", 1e-4, True, True, g,
                                           None, 1)
            chunk_ft = dist._ft_dist_fft_fn(mesh, "fft", 1e-4, True, True, g,
                                            None, 2)
            meas_ft = _measured_collectives(chunk_ft, x, inj)
            mdl_ft = dist.collective_volume(n, b, shards, ft=True, groups=g,
                                            chunks=2)
            a2a_ft = [w for k, w in meas_ft["ops"] if k == "all-to-all"]
            assert len(a2a_ft) == mdl_ft["all_to_all_count"] == 2, \
                meas_ft["count"]
            got, want = meas_ft["total_bytes"], mdl_ft["hlo_bytes"]
            assert want and abs(got / want - 1.0) < 1e-3, (got, want)
            exposed = max(a2a_ft) / sum(a2a_ft)
            assert abs(exposed - mdl_ft["exposed_fraction"]) < 1e-9, exposed
            rb, rc = bulk_ft(x, inj), chunk_ft(x, inj)
            np.testing.assert_array_equal(np.asarray(rb.y), np.asarray(rc.y))
            np.testing.assert_array_equal(np.asarray(rb.flagged),
                                          np.asarray(rc.flagged))
            emit(f"overlap_N2^{ln}_b{b}_ft_g{g}_c2", got,
                 f"a2a=2;exposed={exposed:.3f};hlo/model={got/want:.3f}")
        # spectral convolution round trip, chunked: 2C all-to-alls
        if b % (shards * 2) == 0:
            vj = jnp.asarray((rng.standard_normal((1, n)) +
                              1j * rng.standard_normal((1, n))
                              ).astype(np.complex64))
            bulk_cv = np.asarray(
                spec._spectral_pair_fn(mesh, "fft", None, False, 1)(x, vj))
            for c in (1, 2):
                fn = spec._spectral_pair_fn(mesh, "fft", None, False, c)
                meas_cv = _measured_collectives(fn, x, vj)
                mdl_cv = dist.spectral_volume(n, b, shards, kernel_batch=1,
                                              chunks=c)
                a2a_cv = [w for k, w in meas_cv["ops"] if k == "all-to-all"]
                assert len(a2a_cv) == mdl_cv["all_to_all_count"] == 2 * c, (
                    c, meas_cv["count"])
                got, want = meas_cv["total_bytes"], mdl_cv["hlo_bytes"]
                assert want and abs(got / want - 1.0) < 2e-3, (c, got, want)
                np.testing.assert_array_equal(np.asarray(fn(x, vj)), bulk_cv)
                emit(f"overlap_conv_N2^{ln}_b{b}_c{c}", got,
                     f"a2a={len(a2a_cv)};hlo/model={got/want:.3f}")
    return rows


def run_real(smoke: bool = True):
    """Real-input (half-spectrum) pipelines: model == HLO, and the headline
    claim hard-asserted — the rfft2 slab moves <= 0.6x the all-to-all bytes
    of the equivalent C2C fft2 on the same grid (``(C/2 + D) / C`` exactly).

    Cells:

    * rslab forward — ONE all-to-all at the padded half width
      ``Cp = C/2 + D``, zero all-gathers, bytes ==
      ``collective_volume_nd(real=True)`` (measured on the inner jitted
      pipeline: the public wrapper's eager live-bin slice may relayout);
    * grouped-ABFT rslab in fp32 AND fp64 — the Hermitian-symmetric
      checksum grids ride the same transpose at half width plus the
      3G+1-scalar verdict psum;
    * 1-D packed rfft — the half-length C2C transform's bytes ==
      ``collective_volume(real=True)`` (exactly half the C2C model);
    * packed real convolution, 1-D and 2-D — two all-to-alls, zero
      all-gathers, the kernel riding the imaginary part (1-D: forward rows
      carry NO kernel payload at all) resp. the stacked half spectrum
      (2-D), bytes == ``spectral_volume(real=True)`` /
      ``collective_volume_nd(real=True)`` sums.
    """
    ndev = min(4, len(jax.devices()))
    shards = 1 << (ndev.bit_length() - 1)
    if shards < 2:
        print("# fft_real: single device visible — skipping")
        return []
    from repro.core.fft import multidim as md

    mesh = jax.make_mesh((shards,), ("fft",))
    rng = np.random.default_rng(4)
    rows = []
    for rr, cc, b in [(128, 256, 8)] if smoke else [(128, 256, 8),
                                                    (512, 1024, 8)]:
        x = jnp.asarray(rng.standard_normal((b, rr, cc)).astype(np.float32))
        x64 = x.astype(jnp.float64)
        g = 4
        cells = [
            ("rslab", _measured_collectives(
                md._rslab_fft2_fn(mesh, "fft", None), x),
             md.collective_volume_nd((rr, cc), b, shards, real=True)),
            ("rslab_ft", _measured_collectives(
                md._ft_rslab_fft2_fn(mesh, "fft", 1e-4, True, g, None), x,
                jnp.zeros((1, 7), jnp.float32)),
             md.collective_volume_nd((rr, cc), b, shards, ft=True, groups=g,
                                     real=True)),
            ("rslab_ft_c128", _measured_collectives(
                md._ft_rslab_fft2_fn(mesh, "fft", 1e-4, True, g, None), x64,
                jnp.zeros((1, 7), jnp.float64)),
             md.collective_volume_nd((rr, cc), b, shards, ft=True, groups=g,
                                     itemsize=16, real=True)),
        ]
        for tag, m, mdl in cells:
            assert m["count"]["all-to-all"] == mdl["all_to_all_count"], (
                tag, m["count"])
            assert m["count"]["all-gather"] == 0, (tag, m["count"])
        # ---- the headline ratio: rfft2 <= 0.6x fft2 all-to-all bytes ----
        meas_r = cells[0][1]
        meas_c = _measured_collectives(
            md._slab_fftn_fn(mesh, "fft", 2, False, None),
            x.astype(jnp.complex64))
        ratio = meas_r["total_bytes"] / meas_c["total_bytes"]
        assert ratio <= 0.6, (meas_r["total_bytes"], meas_c["total_bytes"])
        emit(f"rfft2_{rr}x{cc}_b{b}_vs_c2c", meas_r["total_bytes"],
             f"c2c={meas_c['total_bytes']:.0f}B;ratio={ratio:.3f}"
             f";model={(cc // 2 + shards) / cc:.3f}")
        # ---- packed real 2-D convolution: two a2a at the half width -----
        vk = jnp.asarray(rng.standard_normal((1, rr, cc)).astype(np.float32))
        meas_cv = _measured_collectives(
            md._rconv2_pair_fn(mesh, "fft", None), x, vk)
        fwd = md.collective_volume_nd((rr, cc), b + 1, shards, real=True)
        inv = md.collective_volume_nd((rr, cc), b, shards, real=True)
        model_cv = {
            "all_to_all_count": 2, "all_gather_count": 0,
            "total_wire": fwd["total_wire"] + inv["total_wire"],
            "hlo_bytes": fwd["hlo_bytes"] + inv["hlo_bytes"]}
        assert meas_cv["count"]["all-to-all"] == 2, meas_cv["count"]
        assert meas_cv["count"]["all-gather"] == 0, meas_cv["count"]
        cells.append(("rconv2", meas_cv, model_cv))
        # ---- 1-D: packed rfft + packed real convolution -----------------
        n1 = 1 << 14
        half = jnp.asarray((rng.standard_normal((b, n1 // 2)) +
                            1j * rng.standard_normal((b, n1 // 2))
                            ).astype(np.complex64))
        meas_r1 = _measured_collectives(
            dist._dist_fft_fn(mesh, "fft", False, True), half)
        cells.append(("rfft_packed", meas_r1,
                      dist.collective_volume(n1, b, shards, real=True)))
        packed = jnp.asarray((rng.standard_normal((b, n1)) +
                              1j * rng.standard_normal((b, n1))
                              ).astype(np.complex64))
        meas_rc = _measured_collectives(
            spec._spectral_real_fn(mesh, "fft", None), packed)
        cells.append(("rconv1_packed", meas_rc,
                      dist.spectral_volume(n1, b, shards, kernel_batch=1,
                                           real=True)))
        assert meas_rc["count"]["all-to-all"] == 2, meas_rc["count"]
        assert meas_rc["count"]["all-gather"] == 0, meas_rc["count"]
        for tag, m, mdl in cells:
            got = m.get("total_bytes", 0.0)
            want = mdl["hlo_bytes"]
            agree = got / want if want else float("nan")
            assert want and abs(agree - 1.0) < 1e-3, (tag, got, want)
            emit(f"fft_real_{rr}x{cc}_b{b}_wire_{tag}", got,
                 f"model={want:.0f}B;hlo/model={agree:.3f};"
                 f"wire={mdl['total_wire']:.0f}B")
        rows.append((rr, cc, b, ratio, cells))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(smoke=True)
    run_mesh2d(smoke=True)
    run_multidim(smoke=True)
    run_plan_reuse(smoke=True)
    run_overlap(smoke=True)
    run_real(smoke=True)
